//! [`PreparedOp`] and [`OpHandle`]: the common contract behind every
//! prepare-once-execute-many handle.
//!
//! [`super::Prepared`] (matmul) and [`super::PreparedConv`] grew the
//! same surface independently — execute at the prepare-time precision,
//! execute with a per-request precision override, submit
//! asynchronously onto the micro-batcher. This module names that
//! contract once, so layer code (a QNN model walking heterogeneous
//! layers, a load generator, a test harness) can be written generically
//! over *any* prepared operator:
//!
//! ```
//! use bismo::api::{PreparedOp, OpHandle, Session, SessionConfig};
//! use bismo::coordinator::Precision;
//! use bismo::bitmatrix::IntMatrix;
//!
//! // Generic over the operator kind: works for prepared matmuls and
//! // prepared convolutions alike.
//! fn serve_twice<P: PreparedOp>(op: &P, x: &P::Input) -> Result<P::Output, bismo::api::BismoError> {
//!     let first = op.submit(x)?;     // in flight
//!     let _second = op.execute(x)?;  // synchronous
//!     first.wait()
//! }
//!
//! let session = Session::new(SessionConfig::default())?;
//! let prepared = session.prepare(IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]), Precision::unsigned(2, 2))?;
//! let resp = serve_twice(&prepared, &IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]))?;
//! assert_eq!(resp.result, IntMatrix::from_slice(2, 2, &[0, 2, 3, 7]));
//! # Ok::<(), bismo::api::BismoError>(())
//! ```
//!
//! The attention handle ([`super::PreparedAttn`]) deliberately does
//! *not* implement [`PreparedOp`]: an attention block is a DAG of
//! GEMMs with data-dependent integer staircases between stages, so it
//! has no single submit-then-wait handle — only its per-stage GEMMs
//! ride the micro-batcher (see DESIGN.md §14).

use super::conv::{ConvHandle, ConvResponse, PreparedConv};
use super::session::Prepared;
use super::BismoError;
use crate::bitmatrix::IntMatrix;
use crate::coordinator::{GemmResponse, Precision, RequestHandle};
use crate::lowering::Tensor;

/// One in-flight prepared-operator job: consume it to collect the
/// result (each result is delivered exactly once).
pub trait OpHandle {
    /// What the completed job yields.
    type Output;

    /// Block until the job completes.
    fn wait(self) -> Result<Self::Output, BismoError>;
}

impl OpHandle for RequestHandle {
    type Output = GemmResponse;

    fn wait(self) -> Result<GemmResponse, BismoError> {
        RequestHandle::wait(self)
    }
}

impl OpHandle for ConvHandle {
    type Output = ConvResponse;

    fn wait(self) -> Result<ConvResponse, BismoError> {
        ConvHandle::wait(self)
    }
}

/// The prepare-once-execute-many contract: weights resident in the
/// session cache, served against many inputs, with consistent
/// `execute` / `execute_with` / `submit` / `submit_with` signatures
/// across operator kinds.
///
/// `execute` and `execute_with` have default implementations in terms
/// of the submit paths, so every implementor's synchronous and
/// asynchronous results agree by construction.
pub trait PreparedOp {
    /// The per-request input (activation matrix, input tensor, …).
    type Input: ?Sized;
    /// The per-request result.
    type Output;
    /// The in-flight handle returned by the submit paths.
    type Handle: OpHandle<Output = Self::Output>;

    /// Declared precision of the prepare-time packing.
    fn precision(&self) -> Precision;

    /// Enqueue one job at the prepare-time precision and return the
    /// in-flight handle.
    fn submit(&self, x: &Self::Input) -> Result<Self::Handle, BismoError>;

    /// Enqueue one job at a per-execute precision override.
    fn submit_with(&self, x: &Self::Input, prec: Precision) -> Result<Self::Handle, BismoError>;

    /// Execute one job synchronously at the prepare-time precision.
    fn execute(&self, x: &Self::Input) -> Result<Self::Output, BismoError> {
        self.submit(x)?.wait()
    }

    /// Execute one job synchronously at a per-execute precision
    /// override.
    fn execute_with(&self, x: &Self::Input, prec: Precision) -> Result<Self::Output, BismoError> {
        self.submit_with(x, prec)?.wait()
    }
}

impl PreparedOp for Prepared<'_> {
    type Input = IntMatrix;
    type Output = GemmResponse;
    type Handle = RequestHandle;

    fn precision(&self) -> Precision {
        Prepared::precision(self)
    }

    // The inherent paths take `impl Into<Arc<IntMatrix>>` so owning
    // callers avoid a copy; the generic contract takes a borrow, so
    // this clones the activation matrix into the request.
    fn submit(&self, x: &IntMatrix) -> Result<RequestHandle, BismoError> {
        Prepared::submit(self, x.clone())
    }

    fn submit_with(&self, x: &IntMatrix, prec: Precision) -> Result<RequestHandle, BismoError> {
        Prepared::submit_with(self, x.clone(), prec)
    }
}

impl PreparedOp for PreparedConv<'_> {
    type Input = Tensor;
    type Output = ConvResponse;
    type Handle = ConvHandle;

    fn precision(&self) -> Precision {
        PreparedConv::precision(self)
    }

    fn submit(&self, x: &Tensor) -> Result<ConvHandle, BismoError> {
        PreparedConv::submit(self, x)
    }

    fn submit_with(&self, x: &Tensor, prec: Precision) -> Result<ConvHandle, BismoError> {
        PreparedConv::submit_with(self, x, prec)
    }
}
