//! The `bismo::api` facade: the crate's single front door.
//!
//! Three entry-point families grew side by side as the crate scaled —
//! the raw kernel functions (`kernel::gemm_tiled*`), the synchronous
//! overlay context ([`crate::coordinator::BismoContext`]), and the
//! asynchronous serving layer ([`crate::coordinator::BismoService`]).
//! This module unifies them behind three types:
//!
//! * [`Session`] — owns the serving stack: the shared
//!   [`crate::kernel::WorkerPool`], the weight-stationary
//!   [`crate::coordinator::PackingCache`], and the registered execution
//!   backends (the fast tiled engine and the cycle-accurate overlay
//!   simulator). One session serves many concurrent callers.
//! * [`MatmulBuilder`] — per-job configuration (precision, backend,
//!   stage overlap, bit-skip, verification, cache policy), validated
//!   *before* any work is queued.
//! * [`Prepared`] — the prepare-once-execute-many handle: weights are
//!   packed into the session cache once and executed against any
//!   number of activation matrices, with per-execute precision
//!   override for variable-precision workloads (cf. the run-time
//!   reconfigurable multi-precision designs this crate's ROADMAP
//!   tracks).
//! * [`ConvBuilder`] / [`PreparedConv`] — the same contract for 2-D
//!   convolutions: a [`ConvSpec`] is validated, lowered
//!   ([`crate::lowering`], im2col or kn2row) and served through the
//!   identical GEMM machinery, with the lowered weight matrices as the
//!   weight-stationary cached side.
//! * [`AttnBuilder`] / [`PreparedAttn`] — the same contract again for
//!   a quantized transformer encoder block
//!   ([`crate::qnn::QnnAttn`]): six weight matrices prepared at
//!   per-matrix precisions, per-head GEMMs micro-batched, optionally
//!   served under an input-adaptive
//!   [`crate::qnn::PrecisionPolicy`].
//!
//! Every builder carries the same [`ExecOpts`] knob surface (stamped
//! on by one macro, so the three stay byte-identical), and the
//! prepared handles share the [`PreparedOp`] submit/execute contract
//! (conv included — [`PreparedConv::submit`] returns an async
//! [`ConvHandle`]).
//!
//! Every fallible call returns the typed [`BismoError`], so callers
//! branch on failure kinds instead of parsing strings.
//!
//! ```
//! use bismo::api::{Session, SessionConfig};
//! use bismo::coordinator::Precision;
//! use bismo::bitmatrix::IntMatrix;
//!
//! let session = Session::new(SessionConfig::default())?;
//! // The paper's Fig. 1 example through the facade.
//! let l = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
//! let r = IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]);
//! let resp = session.run(l, r, Precision::unsigned(2, 2))?;
//! assert_eq!(resp.result, IntMatrix::from_slice(2, 2, &[0, 2, 3, 7]));
//! # Ok::<(), bismo::api::BismoError>(())
//! ```

mod attn;
mod conv;
mod error;
mod opts;
mod prepared;
mod session;

pub use attn::{AttnBuilder, AttnGemmRecord, AttnResponse, PreparedAttn};
pub use conv::{ConvBuilder, ConvHandle, ConvResponse, PreparedConv};
pub use error::BismoError;
pub use opts::ExecOpts;
pub use prepared::{OpHandle, PreparedOp};
pub use session::{MatmulBuilder, Prepared, Session, SessionConfig};

// The vocabulary types a facade caller needs, re-exported so
// `use bismo::api::*` is a complete import for application code.
pub use crate::coordinator::{
    Backend, CacheStats, GemmResponse, Precision, RequestHandle, RunReport, Sharding,
};
pub use crate::costmodel::{ResourceBudget, TunedProfile};
pub use crate::kernel::KernelConfig;
pub use crate::lowering::{ConvSpec, LoweringMode, Tensor};
pub use crate::scheduler::Overlap;
