//! [`Session`], [`MatmulBuilder`] and [`Prepared`]: the facade types.

use super::opts::{impl_exec_opts_knobs, ExecOpts};
use super::BismoError;
use crate::bitmatrix::IntMatrix;
use crate::coordinator::{
    BismoService, CacheStats, GemmRequest, GemmResponse, Precision, RequestHandle, ServiceConfig,
};
use crate::costmodel::TunedProfile;
use std::sync::Arc;

/// Topology and resource limits of a [`Session`] — worker lanes,
/// micro-batch size, packing-cache capacity and the overlay instance
/// behind the simulator backend. (The same shape the serving layer
/// uses; the facade and the service are configured identically.)
pub type SessionConfig = ServiceConfig;

/// One running BISMO stack: worker pool, packing cache and both
/// execution backends, shared by every job submitted through it.
///
/// `Session` is the crate's intended entry point. It wraps the
/// asynchronous serving layer, so a single session concurrently serves
/// synchronous calls ([`Session::run`]), asynchronous submissions
/// ([`MatmulBuilder::submit`]) and prepared-operand replay
/// ([`Prepared::execute`]) — all micro-batched onto the same worker
/// lanes, all sharing one weight-stationary cache.
pub struct Session {
    svc: BismoService,
}

impl Session {
    /// Start a session: validates the overlay configuration, registers
    /// the engine and simulator backends and spawns the dispatcher.
    pub fn new(cfg: SessionConfig) -> Result<Session, BismoError> {
        Ok(Session {
            svc: BismoService::new(cfg)?,
        })
    }

    /// Start a session with an explicit tuned profile (or `None` to
    /// force the analytical defaults), bypassing the on-disk lookup
    /// that [`Session::new`] performs. Tests and benchmark harnesses
    /// use this to pin behavior regardless of the host's profile
    /// directory.
    pub fn with_profile(
        cfg: SessionConfig,
        tuned: Option<TunedProfile>,
    ) -> Result<Session, BismoError> {
        Ok(Session {
            svc: BismoService::with_profile(cfg, tuned)?,
        })
    }

    /// The tuned profile this session loaded at startup, if any.
    /// `None` means every job runs on the analytical defaults.
    pub fn tuned_profile(&self) -> Option<&TunedProfile> {
        self.svc.tuned_profile()
    }

    /// A session with the default topology (4 workers, 64 MiB cache,
    /// the small test overlay behind the sim backend).
    pub fn with_defaults() -> Result<Session, BismoError> {
        Session::new(SessionConfig::default())
    }

    /// Begin configuring one matmul: `P = A · B` with `A` at
    /// `prec.wbits` and `B` at `prec.abits`. The precision is validated
    /// when the builder runs, submits or prepares — before any work is
    /// queued.
    pub fn matmul(&self, prec: Precision) -> MatmulBuilder<'_> {
        self.matmul_opts(prec, ExecOpts::new())
    }

    /// [`Session::matmul`] starting from an explicit [`ExecOpts`]
    /// value instead of the defaults — how composite workloads (the
    /// attention block) propagate one configured option set onto every
    /// GEMM they lower.
    pub fn matmul_opts(&self, prec: Precision, opts: ExecOpts) -> MatmulBuilder<'_> {
        MatmulBuilder {
            session: self,
            prec,
            opts,
        }
    }

    /// One synchronous matmul with default options (engine backend,
    /// weight-side caching). Equivalent to
    /// `self.matmul(prec).run(a, b)`.
    pub fn run(
        &self,
        a: impl Into<Arc<IntMatrix>>,
        b: impl Into<Arc<IntMatrix>>,
        prec: Precision,
    ) -> Result<GemmResponse, BismoError> {
        self.matmul(prec).run(a, b)
    }

    /// Prepare `weights` (the RHS) once for repeated execution:
    /// validates the precision, range-checks the entries and packs the
    /// bit-plane decomposition into the session cache. Every
    /// subsequent [`Prepared::execute`] reuses that packing — the
    /// weight-stationary serving pattern.
    ///
    /// ```
    /// use bismo::api::{Session, SessionConfig};
    /// use bismo::coordinator::Precision;
    /// use bismo::bitmatrix::IntMatrix;
    ///
    /// let session = Session::new(SessionConfig::default())?;
    /// let weights = IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]);
    /// let prepared = session.prepare(weights, Precision::unsigned(2, 2))?;
    ///
    /// // Execute the same prepared weights against many activations.
    /// let x1 = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
    /// let y1 = prepared.execute(x1)?;
    /// assert_eq!(y1.result, IntMatrix::from_slice(2, 2, &[0, 2, 3, 7]));
    ///
    /// let x2 = IntMatrix::from_slice(1, 2, &[3, 1]);
    /// let y2 = prepared.execute(x2)?;
    /// assert_eq!(y2.result, IntMatrix::from_slice(1, 2, &[1, 5]));
    /// // The second execute found the weights already packed.
    /// assert!(y2.rhs_cached);
    /// # Ok::<(), bismo::api::BismoError>(())
    /// ```
    pub fn prepare(
        &self,
        weights: impl Into<Arc<IntMatrix>>,
        prec: Precision,
    ) -> Result<Prepared<'_>, BismoError> {
        self.matmul(prec).prepare(weights)
    }

    /// The serving layer beneath this session, for callers that need
    /// raw [`BismoService`] access (load generators, the QNN helpers).
    pub fn service(&self) -> &BismoService {
        &self.svc
    }

    /// Packing-cache counters (hits / misses / insertions / evictions).
    pub fn cache_stats(&self) -> CacheStats {
        self.svc.cache_stats()
    }

    /// Resident packed bytes in the cache.
    pub fn cache_bytes(&self) -> usize {
        self.svc.cache_bytes()
    }

    /// Resident cache entries.
    pub fn cache_entries(&self) -> usize {
        self.svc.cache_entries()
    }

    /// Stop accepting new work; queued jobs still drain. Subsequent
    /// submissions fail with [`BismoError::ServiceShutdown`].
    pub fn shutdown(&self) {
        self.svc.shutdown()
    }
}

/// Per-job configuration, built off [`Session::matmul`]. Knob methods
/// consume and return the builder so they chain; the terminal methods
/// ([`MatmulBuilder::run`], [`MatmulBuilder::submit`],
/// [`MatmulBuilder::prepare`]) take `&self`, so one configured builder
/// can launch many jobs.
#[derive(Clone, Copy)]
pub struct MatmulBuilder<'s> {
    session: &'s Session,
    prec: Precision,
    opts: ExecOpts,
}

// The shared knob surface (backend / overlap / bit_skip / verify /
// max_instrs / cache_* / instances / shard_grid / auto_shard / tile)
// is stamped on by the macro so it stays byte-identical with the conv
// and attention builders.
impl_exec_opts_knobs!(MatmulBuilder<'_>, opts.req);

impl<'s> MatmulBuilder<'s> {
    /// The builder's precision.
    pub fn precision(&self) -> Precision {
        self.prec
    }

    /// Validate the configuration without running anything — the
    /// "build" step. `run`/`submit`/`prepare` all call this first.
    pub fn build(&self) -> Result<(), BismoError> {
        self.prec.validate()?;
        self.opts.validate()
    }

    /// The builder's execution options, as the shared [`ExecOpts`]
    /// value (composite workloads forward these onto the GEMMs they
    /// lower).
    pub fn options(&self) -> ExecOpts {
        self.opts
    }

    /// Run one job synchronously.
    pub fn run(
        &self,
        a: impl Into<Arc<IntMatrix>>,
        b: impl Into<Arc<IntMatrix>>,
    ) -> Result<GemmResponse, BismoError> {
        self.submit(a, b)?.wait()
    }

    /// Enqueue one job asynchronously. Configuration errors are
    /// reported here, before anything is queued; execution errors
    /// arrive through the returned handle.
    pub fn submit(
        &self,
        a: impl Into<Arc<IntMatrix>>,
        b: impl Into<Arc<IntMatrix>>,
    ) -> Result<RequestHandle, BismoError> {
        self.build()?;
        Ok(self
            .session
            .svc
            .submit(GemmRequest::with_opts(a, b, self.prec, self.opts.req)))
    }

    /// Pack `weights` (the RHS) into the session cache once, returning
    /// the prepare-once-execute-many handle. See [`Session::prepare`].
    ///
    /// Preparing *is* weight-side caching, so it contradicts
    /// [`MatmulBuilder::cache_rhs`]`(false)` — that combination is
    /// rejected as [`BismoError::InvalidConfig`] rather than silently
    /// repacking on every execute.
    pub fn prepare(&self, weights: impl Into<Arc<IntMatrix>>) -> Result<Prepared<'s>, BismoError> {
        self.build()?;
        if !self.opts.req.cache_rhs {
            return Err(BismoError::InvalidConfig(
                "prepare() requires weight-side caching; remove cache_rhs(false)".into(),
            ));
        }
        let weights: Arc<IntMatrix> = weights.into();
        let (packed, _resident) = self.session.svc.prepare_operand_in(
            self.opts.req.cache_namespace,
            &weights,
            self.prec.abits,
            self.prec.rsigned,
            true,
        )?;
        Ok(Prepared {
            session: self.session,
            weights,
            packed_rows: packed.rows,
            prec: self.prec,
            opts: self.opts,
        })
    }
}

/// Weights packed once, executable against many activation matrices.
///
/// Holds the source weights (`Arc`-shared, never copied per request)
/// and their declared precision. Each [`Prepared::execute`] submits
/// through the session's serving layer; the weight-side packing is
/// served from the cache, so only the fresh activations are packed per
/// call. If the cache evicts the packing under memory pressure it is
/// transparently rebuilt — results are identical either way.
pub struct Prepared<'s> {
    session: &'s Session,
    weights: Arc<IntMatrix>,
    packed_rows: usize,
    prec: Precision,
    opts: ExecOpts,
}

impl Prepared<'_> {
    /// The prepared weight matrix.
    pub fn weights(&self) -> &IntMatrix {
        &self.weights
    }

    /// Declared precision of prepare-time packing (the default for
    /// [`Prepared::execute`]).
    pub fn precision(&self) -> Precision {
        self.prec
    }

    /// Rows of the packed (transposed) weight operand — the output
    /// width `n` of every execute.
    pub fn output_cols(&self) -> usize {
        self.packed_rows
    }

    /// Execute the prepared weights against one activation matrix at
    /// the prepare-time precision.
    pub fn execute(&self, x: impl Into<Arc<IntMatrix>>) -> Result<GemmResponse, BismoError> {
        self.submit(x)?.wait()
    }

    /// [`Prepared::execute`] with a per-execute precision override —
    /// the variable-precision serving case: one resident weight matrix
    /// served at whatever precision each request asks for. The first
    /// execute at a new weight precision packs once (a distinct cache
    /// entry); repeats at that precision hit the cache again.
    pub fn execute_with(
        &self,
        x: impl Into<Arc<IntMatrix>>,
        prec: Precision,
    ) -> Result<GemmResponse, BismoError> {
        self.submit_with(x, prec)?.wait()
    }

    /// Asynchronous [`Prepared::execute`]: enqueue and return the
    /// handle.
    pub fn submit(&self, x: impl Into<Arc<IntMatrix>>) -> Result<RequestHandle, BismoError> {
        self.submit_with(x, self.prec)
    }

    /// Asynchronous [`Prepared::execute_with`]: enqueue at a
    /// per-execute precision override and return the handle. This is
    /// how variable-precision composite workloads (the attention
    /// block's policy-adjusted layers) keep independent GEMMs in
    /// flight together on the micro-batcher.
    pub fn submit_with(
        &self,
        x: impl Into<Arc<IntMatrix>>,
        prec: Precision,
    ) -> Result<RequestHandle, BismoError> {
        prec.validate()?;
        Ok(self.session.svc.submit(GemmRequest::with_opts(
            x,
            self.weights.clone(),
            prec,
            self.opts.req,
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::gemm_bitserial;
    use crate::bitmatrix::BitSerialMatrix;
    use crate::coordinator::Backend;
    use crate::costmodel::ResourceBudget;
    use crate::util::Rng;

    fn session() -> Session {
        Session::with_defaults().unwrap()
    }

    #[test]
    fn builder_validates_before_queueing() {
        let s = session();
        let bad = Precision {
            wbits: 0,
            abits: 4,
            lsigned: false,
            rsigned: false,
        };
        // submit() fails synchronously: nothing was enqueued.
        let r = s.matmul(bad).submit(IntMatrix::zeros(1, 1), IntMatrix::zeros(1, 1));
        assert!(matches!(r, Err(BismoError::PrecisionUnsupported(_))));
        assert_eq!(s.service().submitted(), 0);
        // prepare() fails the same way.
        assert!(matches!(
            s.prepare(IntMatrix::zeros(1, 1), bad),
            Err(BismoError::PrecisionUnsupported(_))
        ));
        // prepare() contradicts cache_rhs(false): rejected, not a
        // silent repack-per-execute degradation.
        assert!(matches!(
            s.matmul(Precision::unsigned(2, 2))
                .cache_rhs(false)
                .prepare(IntMatrix::zeros(2, 2)),
            Err(BismoError::InvalidConfig(_))
        ));
    }

    #[test]
    fn run_agrees_with_oracle_across_backends() {
        let s = session();
        let mut rng = Rng::new(0xFACE);
        let a = IntMatrix::random(&mut rng, 5, 130, 3, true);
        let b = IntMatrix::random(&mut rng, 130, 4, 2, false);
        let prec = Precision {
            wbits: 3,
            abits: 2,
            lsigned: true,
            rsigned: false,
        };
        let la = BitSerialMatrix::from_int(&a, 3, true);
        let rb = BitSerialMatrix::from_int_transposed(&b, 2, false);
        let expect = gemm_bitserial(&la, &rb);
        for backend in [Backend::Engine, Backend::Sim] {
            let resp = s
                .matmul(prec)
                .backend(backend)
                .verify(true)
                .run(a.clone(), b.clone())
                .unwrap();
            assert_eq!(resp.result, expect);
            assert_eq!(resp.report.is_some(), backend == Backend::Sim);
        }
    }

    #[test]
    fn prepared_reuse_skips_repacking() {
        let s = session();
        let mut rng = Rng::new(0x9E9);
        let w = IntMatrix::random(&mut rng, 96, 6, 4, true);
        let prec = Precision {
            wbits: 2,
            abits: 4,
            lsigned: false,
            rsigned: true,
        };
        let prepared = s.prepare(w.clone(), prec).unwrap();
        assert_eq!(prepared.output_cols(), 6);
        let after_prepare = s.cache_stats();
        for i in 0..3 {
            let x = IntMatrix::random(&mut rng, 2, 96, 2, false);
            let resp = prepared.execute(x.clone()).unwrap();
            assert_eq!(resp.result, x.matmul(&w), "execute {i}");
            assert!(resp.rhs_cached, "execute {i} reuses the prepared packing");
        }
        let after = s.cache_stats();
        assert_eq!(
            after.misses, after_prepare.misses,
            "no repacks after prepare"
        );
        assert_eq!(after.hits, after_prepare.hits + 3);
    }

    #[test]
    fn per_execute_precision_override() {
        let s = session();
        let mut rng = Rng::new(0x0DD);
        // Weights fit 3 bits signed; serve them at 3-bit and (padded)
        // 5-bit declared precision from the same Prepared handle.
        let w = IntMatrix::random(&mut rng, 80, 4, 3, true);
        let base = Precision {
            wbits: 2,
            abits: 3,
            lsigned: false,
            rsigned: true,
        };
        let prepared = s.prepare(w.clone(), base).unwrap();
        let x = IntMatrix::random(&mut rng, 3, 80, 2, false);
        let expect = x.matmul(&w);
        let r1 = prepared.execute(x.clone()).unwrap();
        assert_eq!(r1.result, expect);
        let wider = Precision {
            wbits: 2,
            abits: 5,
            lsigned: false,
            rsigned: true,
        };
        let r2 = prepared.execute_with(x.clone(), wider).unwrap();
        assert_eq!(r2.result, expect, "declared headroom changes nothing");
        // Same override again: the new-precision packing is now cached.
        let r3 = prepared.execute_with(x, wider).unwrap();
        assert!(r3.rhs_cached);
        assert_eq!(r3.result, expect);
    }

    #[test]
    fn async_submit_preserves_identity() {
        let s = session();
        let mut rng = Rng::new(0xA21);
        let builder = s.matmul(Precision::unsigned(2, 2));
        let jobs: Vec<(IntMatrix, IntMatrix)> = (0..6)
            .map(|_| {
                let k = rng.index(100) + 1;
                (
                    IntMatrix::random(&mut rng, 2, k, 2, false),
                    IntMatrix::random(&mut rng, k, 3, 2, false),
                )
            })
            .collect();
        let handles: Vec<RequestHandle> = jobs
            .iter()
            .map(|(a, b)| builder.submit(a.clone(), b.clone()).unwrap())
            .collect();
        for (h, (a, b)) in handles.into_iter().zip(&jobs).rev() {
            assert_eq!(h.wait().unwrap().result, a.matmul(b));
        }
    }

    #[test]
    fn instances_knob_shards_and_stays_exact() {
        let s = session();
        let mut rng = Rng::new(0x5AD);
        let a = IntMatrix::random(&mut rng, 16, 120, 3, true);
        let b = IntMatrix::random(&mut rng, 120, 12, 2, true);
        let expect = a.matmul(&b);
        for backend in [Backend::Engine, Backend::Sim] {
            let resp = s
                .matmul(Precision::signed(3, 2))
                .backend(backend)
                .instances(4)
                .run(a.clone(), b.clone())
                .unwrap();
            assert_eq!(resp.result, expect, "{}", backend.name());
            assert_eq!(resp.shards, 4);
        }
        // instances(1) is the plain single-instance path.
        let resp = s
            .matmul(Precision::signed(3, 2))
            .instances(1)
            .run(a.clone(), b.clone())
            .unwrap();
        assert_eq!(resp.shards, 1);
        // Degenerate knob values fail at build time, before queueing.
        let submitted = s.service().submitted();
        assert!(matches!(
            s.matmul(Precision::signed(3, 2))
                .instances(0)
                .submit(a.clone(), b.clone()),
            Err(BismoError::InvalidConfig(_))
        ));
        assert!(matches!(
            s.matmul(Precision::signed(3, 2))
                .shard_grid(2, 0)
                .submit(a.clone(), b.clone()),
            Err(BismoError::InvalidConfig(_))
        ));
        assert_eq!(s.service().submitted(), submitted);
    }

    #[test]
    fn auto_shard_knob_uses_the_cost_model() {
        use crate::arch::PYNQ_Z1;
        let s = session();
        let mut rng = Rng::new(0xAB5D);
        let a = IntMatrix::random(&mut rng, 24, 96, 2, false);
        let b = IntMatrix::random(&mut rng, 96, 24, 2, false);
        let expect = a.matmul(&b);
        let budget = ResourceBudget {
            luts: PYNQ_Z1.luts * 2,
            brams: PYNQ_Z1.brams * 2,
        };
        let resp = s
            .matmul(Precision::unsigned(2, 2))
            .auto_shard(budget)
            .verify(true)
            .run(a, b)
            .unwrap();
        assert_eq!(resp.result, expect);
        assert!(resp.shards >= 2, "double budget affords >1 instance");
    }

    #[test]
    fn session_shutdown_is_typed() {
        let s = session();
        s.shutdown();
        let r = s.run(
            IntMatrix::from_slice(1, 1, &[1]),
            IntMatrix::from_slice(1, 1, &[1]),
            Precision::unsigned(1, 1),
        );
        assert!(matches!(r, Err(BismoError::ServiceShutdown)));
    }
}
