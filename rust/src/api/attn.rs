//! [`AttnBuilder`] and [`PreparedAttn`]: serving a quantized
//! transformer encoder block ([`QnnAttn`]) through the session.
//!
//! The builder follows the same contract as [`crate::api::MatmulBuilder`]
//! and [`crate::api::ConvBuilder`] — the identical [`ExecOpts`] knob
//! surface (stamped on by the same macro), validation at `build()`
//! before anything is queued, and a prepare-once-execute-many handle.
//! `prepare()` packs all six weight matrices into the session cache at
//! their per-matrix precisions; every execute then only packs the
//! request's fresh activations.
//!
//! Execution plugs the session into the model's [`GemmExec`]
//! abstraction: each layer's independent GEMMs (three Q/K/V
//! projections, `heads` score GEMMs, `heads` attention·V GEMMs) are
//! all submitted before any is waited on, so they micro-batch onto the
//! session's worker lanes together.
//!
//! [`PreparedAttn::execute_with_policy`] adds the input-adaptive
//! precision layer: per GEMM layer, the activation operands' pooled
//! [`ActivationStats`] are shown to a [`PrecisionPolicy`], which picks
//! the effective bit width for that side. Bit-serial work scales with
//! the product of operand widths, so a request whose activations only
//! populate 1 of 3 calibrated bits runs its GEMMs at a third of the
//! bit-plane work — with *no* result change when the policy is
//! exactness-preserving (the declared width shrinks only down to the
//! bits actually in use). Policies may also clip (lossy, flagged per
//! decision); weights are never adjusted — their packing is the cached
//! side. Every decision is logged in the [`AttnResponse`].

use super::opts::{impl_exec_opts_knobs, ExecOpts};
use super::session::{Prepared, Session};
use super::BismoError;
use crate::bitmatrix::IntMatrix;
use crate::coordinator::{GemmResponse, Precision, RequestHandle};
use crate::qnn::attn::{AttnGemm, GemmExec, QnnAttn};
use crate::qnn::policy::{clip_unsigned, ActivationStats, PolicyDecision, PrecisionPolicy};

impl Session {
    /// Begin configuring the serving of one quantized attention block.
    /// The model is cloned into the builder (weights are `Arc`-shared,
    /// not copied).
    pub fn attn(&self, model: &QnnAttn) -> AttnBuilder<'_> {
        AttnBuilder {
            session: self,
            model: model.clone(),
            opts: ExecOpts::new(),
        }
    }
}

/// Per-block execution configuration, built off [`Session::attn`].
/// Carries the same [`ExecOpts`] knob surface as the matmul and conv
/// builders; the options apply to every GEMM the block lowers.
#[derive(Clone)]
pub struct AttnBuilder<'s> {
    session: &'s Session,
    model: QnnAttn,
    opts: ExecOpts,
}

// The shared knob surface, byte-identical with MatmulBuilder and
// ConvBuilder.
impl_exec_opts_knobs!(AttnBuilder<'_>, opts.req);

impl<'s> AttnBuilder<'s> {
    /// Validate the model (architecture, weight shapes, per-GEMM
    /// precisions) and the execution options without queueing anything.
    pub fn build(&self) -> Result<(), BismoError> {
        self.model.validate()?;
        self.opts.validate()
    }

    /// The builder's execution options, as the shared [`ExecOpts`]
    /// value.
    pub fn options(&self) -> ExecOpts {
        self.opts
    }

    /// Pack all six weight matrices into the session cache at their
    /// per-matrix precisions, returning the serving handle.
    ///
    /// Preparing *is* weight-side caching, so — exactly like
    /// [`crate::api::MatmulBuilder::prepare`] — it contradicts
    /// `cache_rhs(false)` and that combination is rejected as
    /// [`BismoError::InvalidConfig`].
    pub fn prepare(self) -> Result<PreparedAttn<'s>, BismoError> {
        self.build()?;
        let m = &self.model;
        let prep = |w: &std::sync::Arc<IntMatrix>, prec: Precision| {
            self.session.matmul_opts(prec, self.opts).prepare(w.clone())
        };
        Ok(PreparedAttn {
            wq: prep(&m.wq, m.proj_prec)?,
            wk: prep(&m.wk, m.proj_prec)?,
            wv: prep(&m.wv, m.proj_prec)?,
            wo: prep(&m.wo, m.out_prec)?,
            w1: prep(&m.w1, m.ffn1_prec)?,
            w2: prep(&m.w2, m.ffn2_prec)?,
            session: self.session,
            model: self.model,
            opts: self.opts,
        })
    }
}

/// An attention block whose weights are resident in the session cache,
/// executable against many inputs — optionally under an adaptive
/// precision policy.
///
/// Deliberately *not* a [`crate::api::PreparedOp`]: the block is a
/// GEMM DAG with data-dependent staircases between layers, so its
/// response is a structured [`AttnResponse`] rather than one
/// [`GemmResponse`], and its precision story is per-layer rather than
/// per-call (see DESIGN.md §14).
pub struct PreparedAttn<'s> {
    session: &'s Session,
    model: QnnAttn,
    wq: Prepared<'s>,
    wk: Prepared<'s>,
    wv: Prepared<'s>,
    wo: Prepared<'s>,
    w1: Prepared<'s>,
    w2: Prepared<'s>,
    opts: ExecOpts,
}

impl PreparedAttn<'_> {
    /// The model this handle serves.
    pub fn model(&self) -> &QnnAttn {
        &self.model
    }

    /// The prepared handle behind a weight name.
    fn prepared(&self, name: &str) -> &Prepared<'_> {
        match name {
            "wq" => &self.wq,
            "wk" => &self.wk,
            "wv" => &self.wv,
            "wo" => &self.wo,
            "w1" => &self.w1,
            "w2" => &self.w2,
            other => panic!("unknown attention weight {other:?}"),
        }
    }

    /// Options for the dynamic (activation × activation) GEMMs: both
    /// operands are fresh per request, so neither side is cached —
    /// caching them would churn the weight-stationary cache for zero
    /// reuse.
    fn dynamic_opts(&self) -> ExecOpts {
        self.opts.cache_lhs(false).cache_rhs(false)
    }

    /// One forward pass at the calibrated precisions. No statistics
    /// are gathered and no policy consulted — this is the static
    /// serving path (`decisions` comes back empty).
    pub fn execute(&self, x: &IntMatrix) -> Result<AttnResponse, BismoError> {
        self.run(x, None)
    }

    /// One forward pass with `policy` choosing the effective
    /// activation bit width per layer from the observed operand
    /// statistics. Exactness-preserving policies (e.g.
    /// [`crate::qnn::RangeAdaptivePolicy`]) return bit-identical
    /// output to [`PreparedAttn::execute`] at less bit-plane work;
    /// lossy policies flag each clipping decision in the response.
    pub fn execute_with_policy(
        &self,
        x: &IntMatrix,
        policy: &dyn PrecisionPolicy,
    ) -> Result<AttnResponse, BismoError> {
        self.run(x, Some(policy))
    }

    fn run(
        &self,
        x: &IntMatrix,
        policy: Option<&dyn PrecisionPolicy>,
    ) -> Result<AttnResponse, BismoError> {
        let mut exec = ServeExec {
            attn: self,
            policy,
            gemms: Vec::with_capacity(self.model.gemms_per_pass()),
            decisions: Vec::new(),
        };
        let output = self.model.forward_with(x, &mut exec)?;
        Ok(AttnResponse {
            output,
            gemms: exec.gemms,
            decisions: exec.decisions,
        })
    }
}

/// One served GEMM of a block pass: which layer it belonged to, the
/// *effective* precision it ran at (after any policy adjustment) and
/// the full serving response.
pub struct AttnGemmRecord {
    pub layer: &'static str,
    pub prec: Precision,
    pub resp: GemmResponse,
}

/// What one block pass reports: the output logits, every GEMM's
/// serving record, and the policy decision log (empty on the static
/// path).
pub struct AttnResponse {
    /// `seq × d_model` raw accumulators of the final FFN GEMM.
    pub output: IntMatrix,
    /// Per-GEMM serving records, in submission order.
    pub gemms: Vec<AttnGemmRecord>,
    /// One entry per (layer, operand side) the policy ruled on.
    pub decisions: Vec<PolicyDecision>,
}

impl AttnResponse {
    /// Total simulated cycles, when every GEMM ran on the simulator
    /// backend (`None` otherwise — the engine backend has no cycle
    /// notion).
    pub fn sim_cycles(&self) -> Option<u64> {
        self.gemms
            .iter()
            .map(|g| g.resp.report.as_ref().map(|r| r.cycles))
            .sum()
    }

    /// Whether every weight-stationary GEMM was served from the cache
    /// (true from the first pass after `prepare()`).
    pub fn weights_cached(&self) -> bool {
        self.gemms
            .iter()
            .filter(|g| matches!(g.layer, "qkv" | "out" | "ffn1" | "ffn2"))
            .all(|g| g.resp.rhs_cached)
    }

    /// Mean effective LHS (activation) width across the pass's GEMMs —
    /// the bench's one-number summary of how much bit-plane work the
    /// policy shed.
    pub fn mean_lhs_bits(&self) -> f64 {
        if self.gemms.is_empty() {
            return 0.0;
        }
        self.gemms.iter().map(|g| g.prec.wbits as f64).sum::<f64>() / self.gemms.len() as f64
    }
}

/// The session-backed [`GemmExec`]: per layer, consult the policy once
/// per operand side (pooled stats across the layer's GEMMs), then
/// submit every job before waiting on any.
struct ServeExec<'p, 's> {
    attn: &'p PreparedAttn<'s>,
    policy: Option<&'p dyn PrecisionPolicy>,
    gemms: Vec<AttnGemmRecord>,
    decisions: Vec<PolicyDecision>,
}

impl ServeExec<'_, '_> {
    /// Ask the policy for one side's effective width. Only unsigned
    /// activation sides are ever adjusted; the signed weight side of
    /// projection/FFN GEMMs keeps its calibrated width (its packing is
    /// the cached asset).
    fn decide(
        &mut self,
        layer: &'static str,
        side: &'static str,
        base_bits: u32,
        operands: &[&IntMatrix],
    ) -> (u32, bool) {
        match self.policy {
            None => (base_bits, false),
            Some(p) => {
                let stats = ActivationStats::of_many(operands);
                let d = p.decide(layer, side, base_bits, &stats);
                let out = (d.chosen_bits.clamp(1, base_bits), d.clip);
                self.decisions.push(d);
                out
            }
        }
    }
}

impl GemmExec for ServeExec<'_, '_> {
    fn run_layer(
        &mut self,
        layer: &'static str,
        jobs: Vec<AttnGemm>,
    ) -> Result<Vec<IntMatrix>, BismoError> {
        let Some(first) = jobs.first() else {
            return Ok(Vec::new());
        };
        let base = first.precision();
        let dynamic = matches!(first, AttnGemm::Dynamic { .. });
        // One decision per operand side per layer, on stats pooled
        // across the layer's GEMMs (per-head operands are slices of
        // one tensor; a single decision keeps the log bounded and the
        // layer homogeneous).
        let (lhs_bits, lhs_clip) = if base.lsigned {
            (base.wbits, false)
        } else {
            let lhs: Vec<&IntMatrix> = jobs
                .iter()
                .map(|j| match j {
                    AttnGemm::Weight { lhs, .. } | AttnGemm::Dynamic { lhs, .. } => lhs,
                })
                .collect();
            self.decide(layer, "lhs", base.wbits, &lhs)
        };
        let (rhs_bits, rhs_clip) = if dynamic && !base.rsigned {
            let rhs: Vec<&IntMatrix> = jobs
                .iter()
                .filter_map(|j| match j {
                    AttnGemm::Dynamic { rhs, .. } => Some(rhs),
                    AttnGemm::Weight { .. } => None,
                })
                .collect();
            self.decide(layer, "rhs", base.abits, &rhs)
        } else {
            (base.abits, false)
        };
        // Submit the whole layer before waiting on anything, so the
        // independent GEMMs micro-batch onto the worker lanes.
        let mut pending: Vec<(RequestHandle, Precision)> = Vec::with_capacity(jobs.len());
        for job in jobs {
            match job {
                AttnGemm::Weight { weight, lhs, prec } => {
                    let prec = Precision {
                        wbits: lhs_bits,
                        ..prec
                    };
                    let lhs = if lhs_clip {
                        clip_unsigned(&lhs, lhs_bits)
                    } else {
                        lhs
                    };
                    pending.push((self.attn.prepared(weight).submit_with(lhs, prec)?, prec));
                }
                AttnGemm::Dynamic { lhs, rhs, prec } => {
                    let prec = Precision {
                        wbits: lhs_bits,
                        abits: rhs_bits,
                        ..prec
                    };
                    let lhs = if lhs_clip {
                        clip_unsigned(&lhs, lhs_bits)
                    } else {
                        lhs
                    };
                    let rhs = if rhs_clip {
                        clip_unsigned(&rhs, rhs_bits)
                    } else {
                        rhs
                    };
                    pending.push((
                        self.attn
                            .session
                            .matmul_opts(prec, self.attn.dynamic_opts())
                            .submit(lhs, rhs)?,
                        prec,
                    ));
                }
            }
        }
        let mut out = Vec::with_capacity(pending.len());
        for (handle, prec) in pending {
            let resp = handle.wait()?;
            out.push(resp.result.clone());
            self.gemms.push(AttnGemmRecord { layer, prec, resp });
        }
        Ok(out)
    }
}
