//! [`ExecOpts`]: the shared execution-option core behind every facade
//! builder.
//!
//! The matmul, conv and attention builders all expose the same knob
//! surface — backend, stage overlap, bit-skip, verification, cache
//! policy, sharding, instruction budget, tile pinning. Before this
//! module each builder re-implemented the subset its author remembered,
//! and the subsets drifted ([`super::ConvBuilder`] shipped without
//! `max_instrs`, `overlap`, `shard_grid`, `auto_shard` or `tile`).
//! `ExecOpts` holds the knobs exactly once; the
//! [`impl_exec_opts_knobs!`] macro stamps the identical chainable
//! methods — same names, same docs, same validation — onto each
//! builder, so the knob surface cannot drift again.
//!
//! `ExecOpts` is also a public value type: APIs that previously took a
//! positional run of `backend, verify, …` arguments (the network
//! client's conv entry point, for one) now take `&ExecOpts`.

use super::BismoError;
use crate::coordinator::{Backend, RequestOptions, Sharding};
use crate::costmodel::ResourceBudget;
use crate::kernel::KernelConfig;
use crate::scheduler::Overlap;

/// The execution options shared by every facade builder, as a
/// standalone value.
///
/// Construct with [`ExecOpts::new`] (engine backend, weight-side
/// caching on — the same defaults every builder starts from), chain
/// the same knob methods the builders expose, and pass the result to
/// APIs that accept options by value:
///
/// ```
/// use bismo::api::{Backend, ExecOpts};
///
/// let opts = ExecOpts::new().backend(Backend::Sim).verify(true).max_instrs(1_000_000);
/// assert!(opts.validate().is_ok());
/// ```
#[derive(Clone, Copy, Default)]
pub struct ExecOpts {
    pub(crate) req: RequestOptions,
}

impl ExecOpts {
    /// Options with the facade defaults: engine backend, full stage
    /// overlap, weight-side caching on, activation-side caching off,
    /// single-instance execution, no instruction budget, no pinned
    /// tile.
    pub fn new() -> ExecOpts {
        ExecOpts::default()
    }

    /// Validate the combination — sharding shape and pinned tile
    /// geometry. Every builder's `build()` funnels through this, which
    /// is what makes the three builders reject degenerate knob values
    /// with *identical* typed errors.
    pub fn validate(&self) -> Result<(), BismoError> {
        self.req.validate()
    }

    /// The underlying per-request options, for layers beneath the
    /// facade (the serving layer's request structs take
    /// [`RequestOptions`] directly).
    pub fn request_options(&self) -> RequestOptions {
        self.req
    }
}

/// Stamps the shared [`ExecOpts`] knob surface onto a builder (or onto
/// `ExecOpts` itself). The single source of truth for knob names,
/// semantics and documentation; invoke as
/// `impl_exec_opts_knobs!(Builder<'_>, opts.req);` where the second
/// argument is the field path from `self` to the inner
/// [`crate::coordinator::RequestOptions`].
macro_rules! impl_exec_opts_knobs {
    ($ty:ty, $($field:ident).+) => {
        impl $ty {
            /// Select the execution backend: the fast tiled engine
            /// (default) or the cycle-accurate overlay simulator (which
            /// additionally yields a [`crate::coordinator::RunReport`]
            /// per GEMM).
            pub fn backend(mut self, backend: $crate::coordinator::Backend) -> Self {
                self.$($field).+.backend = backend;
                self
            }

            /// Stage-overlap mode of the simulated pipeline (sim
            /// backend only).
            pub fn overlap(mut self, overlap: $crate::scheduler::Overlap) -> Self {
                self.$($field).+.overlap = overlap;
                self
            }

            /// Skip all-zero bit-planes (the paper's sparse extension;
            /// sim backend — the engine always skips).
            pub fn bit_skip(mut self, on: bool) -> Self {
                self.$($field).+.bit_skip = on;
                self
            }

            /// Cross-check every result against the CPU bit-serial
            /// oracle (costs an extra software GEMM; failures surface
            /// as [`crate::api::BismoError::VerifyFailed`]).
            pub fn verify(mut self, on: bool) -> Self {
                self.$($field).+.verify = on;
                self
            }

            /// Instruction-budget watchdog for the sim backend: fail
            /// the request with a typed
            /// [`crate::sim::SimError::BudgetExceeded`] once the
            /// simulation has retired `n` instructions, instead of
            /// letting a mis-scheduled job occupy a worker
            /// indefinitely.
            pub fn max_instrs(mut self, n: u64) -> Self {
                self.$($field).+.max_instrs = Some(n);
                self
            }

            /// Cache the packed LHS (off by default: fresh activations
            /// would churn the cache).
            pub fn cache_lhs(mut self, on: bool) -> Self {
                self.$($field).+.cache_lhs = on;
                self
            }

            /// Cache the packed RHS — the weight-stationary side (on
            /// by default).
            pub fn cache_rhs(mut self, on: bool) -> Self {
                self.$($field).+.cache_rhs = on;
                self
            }

            /// Scope cache interactions to tenant namespace `ns` (`0`
            /// — the default — is the shared in-process namespace).
            /// Tenants share the cache's byte budget but can never hit
            /// each other's packed operands; the network front door
            /// ([`crate::net`]) sets this per connection.
            pub fn cache_namespace(mut self, ns: u64) -> Self {
                self.$($field).+.cache_namespace = ns;
                self
            }

            /// Execute each job across (up to) `n` overlay instances:
            /// the output splits into a shard grid factored per job
            /// shape, the shards run concurrently and merge
            /// bit-exactly. `n = 1` is the plain single-instance path;
            /// `n = 0` is rejected at `build()`.
            pub fn instances(mut self, n: usize) -> Self {
                self.$($field).+.sharding = if n == 1 {
                    $crate::coordinator::Sharding::Single
                } else {
                    $crate::coordinator::Sharding::Instances(n)
                };
                self
            }

            /// Execute each job over an explicit `rows × cols` shard
            /// grid (each axis clamped so no shard is empty; a zero
            /// axis is rejected at `build()`).
            pub fn shard_grid(mut self, rows: usize, cols: usize) -> Self {
                self.$($field).+.sharding = $crate::coordinator::Sharding::Grid { rows, cols };
                self
            }

            /// Cost-model-driven sharding: for each job,
            /// [`crate::costmodel::select_sharding`] picks the shard
            /// count and per-shard instance configuration that maximize
            /// predicted throughput under `budget` (paper Eqs 1–2).
            pub fn auto_shard(mut self, budget: $crate::costmodel::ResourceBudget) -> Self {
                self.$($field).+.sharding = $crate::coordinator::Sharding::Auto(budget);
                self
            }

            /// Pin the engine's tile geometry for this builder's jobs,
            /// overriding both the built-in default and any
            /// tuned-profile selection. Degenerate tiles (any dimension
            /// zero) are rejected at `build()`. Sim-backend jobs ignore
            /// this.
            pub fn tile(mut self, cfg: $crate::kernel::KernelConfig) -> Self {
                self.$($field).+.kernel = Some(cfg);
                self
            }
        }
    };
}

impl_exec_opts_knobs!(ExecOpts, req);

pub(crate) use impl_exec_opts_knobs;

// Referenced by the macro-generated docs and signatures; re-assert the
// imports are used even when the macro is only expanded elsewhere.
const _: fn() = || {
    let _ = |_: Backend, _: Overlap, _: Sharding, _: ResourceBudget, _: KernelConfig| {};
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Backend, Sharding};
    use crate::kernel::KernelConfig;
    use crate::scheduler::Overlap;

    #[test]
    fn defaults_match_request_options() {
        let d = ExecOpts::new().request_options();
        let r = RequestOptions::default();
        assert_eq!(d.backend, r.backend);
        assert_eq!(d.cache_lhs, r.cache_lhs);
        assert_eq!(d.cache_rhs, r.cache_rhs);
        assert_eq!(d.max_instrs, r.max_instrs);
        assert!(d.kernel.is_none());
    }

    #[test]
    fn every_knob_lands_in_request_options() {
        let o = ExecOpts::new()
            .backend(Backend::Sim)
            .overlap(Overlap::None)
            .bit_skip(true)
            .verify(true)
            .max_instrs(123)
            .cache_lhs(true)
            .cache_rhs(false)
            .cache_namespace(7)
            .shard_grid(2, 3)
            .tile(KernelConfig {
                tile_m: 4,
                tile_n: 4,
                tile_k: 64,
            })
            .request_options();
        assert_eq!(o.backend, Backend::Sim);
        assert_eq!(o.overlap, Overlap::None);
        assert!(o.bit_skip);
        assert!(o.verify);
        assert_eq!(o.max_instrs, Some(123));
        assert!(o.cache_lhs);
        assert!(!o.cache_rhs);
        assert_eq!(o.cache_namespace, 7);
        assert!(matches!(o.sharding, Sharding::Grid { rows: 2, cols: 3 }));
        assert_eq!(o.kernel.unwrap().tile_m, 4);
    }

    #[test]
    fn validate_rejects_degenerate_knobs() {
        assert!(ExecOpts::new().instances(0).validate().is_err());
        assert!(ExecOpts::new().shard_grid(0, 2).validate().is_err());
        assert!(ExecOpts::new()
            .tile(KernelConfig {
                tile_m: 0,
                tile_n: 1,
                tile_k: 1,
            })
            .validate()
            .is_err());
        assert!(ExecOpts::new().instances(1).validate().is_ok());
    }
}
