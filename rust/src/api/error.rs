//! [`BismoError`]: the crate-wide typed error.
//!
//! Every fallible public entry point in the crate returns this enum
//! instead of a bare `String`, so callers can *branch on failure
//! kinds* — retry a [`BismoError::CapacityExceeded`] with a smaller
//! tile, surface a [`BismoError::PrecisionUnsupported`] to the client
//! that picked the precision, treat [`BismoError::ServiceShutdown`] as
//! back-pressure — while the payload keeps the human-readable detail
//! the old strings carried.

use crate::sim::SimError;
use crate::util::json::JsonError;

/// Why a BISMO operation failed.
///
/// Constructed throughout arch / scheduler / isa / sim / coordinator /
/// qnn and surfaced unchanged by the [`crate::api::Session`] facade.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BismoError {
    /// A hardware configuration, platform or service topology parameter
    /// is invalid (e.g. non-power-of-two `D_k`, zero workers, unknown
    /// Table IV instance id).
    InvalidConfig(String),
    /// Operand shapes are inconsistent: `a.cols != b.rows`, packed
    /// operands disagree on `k`, or a DRAM layout does not match its
    /// job.
    ShapeMismatch(String),
    /// A precision is outside the supported range (`wbits`/`abits` must
    /// be in `1..=32` and jointly fit the accumulator), or operand
    /// entries do not fit their declared precision.
    PrecisionUnsupported(String),
    /// A resource budget was exceeded: platform LUT/BRAM under the cost
    /// model, on-chip buffer depths, or an ISA encoding field.
    CapacityExceeded(String),
    /// An instruction stream violated the ISA's legality rules (wrong
    /// queue, token imbalance, malformed encoded word).
    IllegalProgram(String),
    /// The cycle-accurate simulator faulted at run time (token
    /// deadlock or a stage fault). Validation failures are reported as
    /// [`BismoError::InvalidConfig`] / [`BismoError::IllegalProgram`]
    /// before any simulation starts.
    SimFault(SimError),
    /// A computed result failed cross-checking against the CPU
    /// bit-serial oracle.
    VerifyFailed(String),
    /// The service is shutting down and no longer accepts submissions.
    ServiceShutdown,
    /// The serving front door shed this request under load: its
    /// admission queue (global or per-tenant) is saturated. The payload
    /// is a back-off hint in milliseconds — clients should retry no
    /// sooner than that. Scales with queue depth at shed time, so it
    /// doubles as a congestion signal.
    Overloaded {
        /// Suggested client back-off before retrying, in milliseconds.
        retry_after_ms: u64,
    },
    /// A request outcome was already consumed (e.g. `try_take` followed
    /// by `wait` on the same handle).
    ResultConsumed,
    /// A worker panicked while executing a request; the payload carries
    /// the panic message.
    WorkerPanicked(String),
    /// Filesystem or OS I/O failed.
    Io(String),
    /// Input text (JSON manifest, CLI flag value) failed to parse.
    Parse(String),
}

impl BismoError {
    /// Stable lowercase kind tag, for logs and metrics dimensions.
    pub fn kind(&self) -> &'static str {
        match self {
            BismoError::InvalidConfig(_) => "invalid_config",
            BismoError::ShapeMismatch(_) => "shape_mismatch",
            BismoError::PrecisionUnsupported(_) => "precision_unsupported",
            BismoError::CapacityExceeded(_) => "capacity_exceeded",
            BismoError::IllegalProgram(_) => "illegal_program",
            BismoError::SimFault(_) => "sim_fault",
            BismoError::VerifyFailed(_) => "verify_failed",
            BismoError::ServiceShutdown => "service_shutdown",
            BismoError::Overloaded { .. } => "overloaded",
            BismoError::ResultConsumed => "result_consumed",
            BismoError::WorkerPanicked(_) => "worker_panicked",
            BismoError::Io(_) => "io",
            BismoError::Parse(_) => "parse",
        }
    }
}

impl std::fmt::Display for BismoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BismoError::InvalidConfig(m) => write!(f, "invalid config: {m}"),
            BismoError::ShapeMismatch(m) => write!(f, "shape mismatch: {m}"),
            BismoError::PrecisionUnsupported(m) => write!(f, "unsupported precision: {m}"),
            BismoError::CapacityExceeded(m) => write!(f, "capacity exceeded: {m}"),
            BismoError::IllegalProgram(m) => write!(f, "illegal program: {m}"),
            BismoError::SimFault(e) => write!(f, "simulation: {e}"),
            BismoError::VerifyFailed(m) => write!(f, "verification failed: {m}"),
            BismoError::ServiceShutdown => write!(f, "service is shutting down"),
            BismoError::Overloaded { retry_after_ms } => {
                write!(f, "overloaded: retry after {retry_after_ms} ms")
            }
            BismoError::ResultConsumed => write!(f, "request outcome already taken"),
            BismoError::WorkerPanicked(m) => write!(f, "request panicked: {m}"),
            BismoError::Io(m) => write!(f, "io: {m}"),
            BismoError::Parse(m) => write!(f, "parse: {m}"),
        }
    }
}

impl std::error::Error for BismoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BismoError::SimFault(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SimError> for BismoError {
    fn from(e: SimError) -> Self {
        BismoError::SimFault(e)
    }
}

impl From<JsonError> for BismoError {
    fn from(e: JsonError) -> Self {
        BismoError::Parse(e.to_string())
    }
}

impl From<std::io::Error> for BismoError {
    fn from(e: std::io::Error) -> Self {
        BismoError::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_detail_and_kind_is_stable() {
        let e = BismoError::PrecisionUnsupported("wbits must be in 1..=32, got 0".into());
        let s = e.to_string();
        assert!(s.contains("unsupported precision"), "{s}");
        assert!(s.contains("wbits"), "{s}");
        assert_eq!(e.kind(), "precision_unsupported");
        assert_eq!(BismoError::ServiceShutdown.kind(), "service_shutdown");
    }

    #[test]
    fn overloaded_carries_the_backoff_hint() {
        let e = BismoError::Overloaded { retry_after_ms: 25 };
        assert_eq!(e.kind(), "overloaded");
        assert!(e.to_string().contains("retry after 25 ms"), "{e}");
        // Shed responses are matchable so clients can implement typed
        // back-off instead of string-sniffing.
        match e {
            BismoError::Overloaded { retry_after_ms } => assert_eq!(retry_after_ms, 25),
            other => panic!("wrong kind: {other:?}"),
        }
    }

    #[test]
    fn sim_error_converts_and_chains() {
        use std::error::Error;
        let e: BismoError = SimError::Fault {
            stage: "execute",
            pc: 7,
            msg: "buffer access out of range".into(),
        }
        .into();
        assert_eq!(e.kind(), "sim_fault");
        assert!(e.to_string().contains("out of range"));
        assert!(e.source().is_some());
    }

    #[test]
    fn callers_can_branch_on_kind() {
        // The point of the redesign: failure kinds are matchable.
        let errs = [
            BismoError::ShapeMismatch("2x3 · 4x2".into()),
            BismoError::ServiceShutdown,
        ];
        let retriable = errs
            .iter()
            .filter(|e| matches!(e, BismoError::ServiceShutdown))
            .count();
        assert_eq!(retriable, 1);
    }
}
