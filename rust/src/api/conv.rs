//! [`ConvBuilder`], [`PreparedConv`], [`ConvHandle`] and
//! [`ConvResponse`]: the convolution entry points of the facade.
//!
//! A conv job is validated like a matmul job — spec, precision and
//! execution options checked *before* anything is queued — then
//! lowered ([`crate::lowering`]) and served through the same
//! [`crate::coordinator::BismoService`] machinery as every GEMM:
//! micro-batched worker lanes, per-request backend selection, the
//! weight-stationary packing cache (lowered weight matrices are the
//! cached side), and optional multi-instance sharding.
//!
//! The builder's knob surface is the shared [`super::ExecOpts`] core,
//! so every option a [`super::MatmulBuilder`] accepts — including
//! `max_instrs`, `overlap`, `shard_grid`, `auto_shard` and `tile` — is
//! accepted here with identical semantics and identical build-time
//! validation.

use super::opts::{impl_exec_opts_knobs, ExecOpts};
use super::session::Session;
use super::BismoError;
use crate::bitmatrix::IntMatrix;
use crate::coordinator::{GemmResponse, Precision, RequestHandle, RequestOptions};
use crate::lowering::{
    kn2row_tap_weights, pack_im2col, pack_kn2row_tap, ConvSpec, LoweringMode, Tensor,
};
use crate::partition::GemmShape;
use std::sync::Arc;

/// Everything a completed convolution reports back.
#[derive(Clone, Debug)]
pub struct ConvResponse {
    /// The `batch × out_h × out_w × out_c` output tensor (raw
    /// accumulators; requantization is the caller's layer logic).
    pub output: Tensor,
    /// The underlying GEMM responses: one for im2col, `kh·kw` (one per
    /// kernel tap) for kn2row. Carries timing, cache attribution and —
    /// on the sim backend — the per-GEMM [`crate::coordinator::RunReport`]s.
    pub gemms: Vec<GemmResponse>,
    /// Shape of one lowered GEMM ([`ConvSpec::gemm_shape`] for im2col,
    /// [`ConvSpec::kn2row_shape`] for kn2row).
    pub shape: GemmShape,
    /// The lowering that produced this response.
    pub mode: LoweringMode,
}

impl ConvResponse {
    /// Total simulated cycles across the layer's GEMMs (sim backend;
    /// 0 on the engine backend). Kn2row taps execute concurrently in
    /// reality, so this is the *work*, not the makespan.
    pub fn sim_cycles(&self) -> u64 {
        self.gemms
            .iter()
            .filter_map(|g| g.report.as_ref().map(|r| r.cycles))
            .sum()
    }

    /// Whether every weight-side packing came from the cache.
    pub fn weights_cached(&self) -> bool {
        self.gemms.iter().all(|g| g.rhs_cached)
    }
}

/// Per-job convolution configuration, built off [`Session::conv`].
/// Mirrors [`super::MatmulBuilder`]: knob methods chain, terminal
/// methods ([`ConvBuilder::run`], [`ConvBuilder::submit`],
/// [`ConvBuilder::prepare`]) take `&self`, and [`ConvBuilder::build`]
/// validates everything before any work is queued.
#[derive(Clone, Copy)]
pub struct ConvBuilder<'s> {
    session: &'s Session,
    spec: ConvSpec,
    prec: Precision,
    mode: LoweringMode,
    opts: ExecOpts,
}

impl Session {
    /// Begin configuring one convolution of `input · weights` at
    /// `prec`: activations (the lowered LHS) at `prec.wbits`, weights
    /// (the lowered RHS) at `prec.abits`. Defaults: im2col lowering,
    /// engine backend, weight-side caching on.
    pub fn conv(&self, spec: ConvSpec, prec: Precision) -> ConvBuilder<'_> {
        ConvBuilder {
            session: self,
            spec,
            prec,
            mode: LoweringMode::Im2col,
            opts: ExecOpts::new(),
        }
    }
}

// The shared knob surface, byte-identical with the matmul and
// attention builders.
impl_exec_opts_knobs!(ConvBuilder<'_>, opts.req);

impl<'s> ConvBuilder<'s> {
    /// Select the lowering: one wide im2col GEMM (default) or `kh·kw`
    /// concurrent kn2row tap GEMMs.
    pub fn lowering(mut self, mode: LoweringMode) -> Self {
        self.mode = mode;
        self
    }

    /// The builder's spec.
    pub fn spec(&self) -> ConvSpec {
        self.spec
    }

    /// The builder's execution options, as the shared [`ExecOpts`]
    /// value.
    pub fn options(&self) -> ExecOpts {
        self.opts
    }

    /// Validate spec, precision and the full execution-option set
    /// (sharding shape *and* pinned tile geometry) without running
    /// anything.
    pub fn build(&self) -> Result<(), BismoError> {
        self.spec.validate()?;
        self.prec.validate()?;
        self.opts.validate()
    }

    /// Run one convolution synchronously.
    pub fn run(
        &self,
        input: &Tensor,
        weights: impl Into<Arc<IntMatrix>>,
    ) -> Result<ConvResponse, BismoError> {
        self.submit(input, weights)?.wait()
    }

    /// Enqueue one convolution asynchronously: every lowered GEMM is
    /// submitted (micro-batched across the worker lanes) before the
    /// returned [`ConvHandle`] is waited on. Configuration errors are
    /// reported here, before anything is queued.
    pub fn submit(
        &self,
        input: &Tensor,
        weights: impl Into<Arc<IntMatrix>>,
    ) -> Result<ConvHandle, BismoError> {
        self.build()?;
        let weights: Arc<IntMatrix> = weights.into();
        self.spec.check_weights(&weights)?;
        let subs = lower_weights(&self.spec, &weights, self.mode);
        submit_conv(self.session, &self.spec, self.mode, self.prec, self.opts.req, input, &subs)
    }

    /// Lower `weights` and pack them into the session cache once,
    /// returning the prepare-once-execute-many handle (the conv
    /// counterpart of [`super::MatmulBuilder::prepare`]). Im2col
    /// prepares one matrix; kn2row prepares each of the `kh·kw` tap
    /// sub-matrices.
    pub fn prepare(
        &self,
        weights: impl Into<Arc<IntMatrix>>,
    ) -> Result<PreparedConv<'s>, BismoError> {
        self.build()?;
        if !self.opts.req.cache_rhs {
            return Err(BismoError::InvalidConfig(
                "prepare() requires weight-side caching; remove cache_rhs(false)".into(),
            ));
        }
        let weights: Arc<IntMatrix> = weights.into();
        self.spec.check_weights(&weights)?;
        let subs = lower_weights(&self.spec, &weights, self.mode);
        for sub in &subs {
            self.session.service().prepare_operand_in(
                self.opts.req.cache_namespace,
                sub,
                self.prec.abits,
                self.prec.rsigned,
                true,
            )?;
        }
        Ok(PreparedConv {
            session: self.session,
            spec: self.spec,
            mode: self.mode,
            prec: self.prec,
            opts: self.opts,
            subs,
        })
    }
}

/// Conv weights lowered and packed once, executable against many input
/// tensors — the weight-stationary serving pattern for CNN layers.
/// Like [`super::Prepared`], evicted packings are transparently
/// rebuilt, [`PreparedConv::execute_with`] serves the same resident
/// weights at a per-execute precision (the paper's variable-precision
/// claim, per layer), and [`PreparedConv::submit`] rides the
/// micro-batcher asynchronously exactly like a prepared GEMM.
pub struct PreparedConv<'s> {
    session: &'s Session,
    spec: ConvSpec,
    mode: LoweringMode,
    prec: Precision,
    opts: ExecOpts,
    /// The lowered weight matrices: one for im2col, `kh·kw` for
    /// kn2row — `Arc`-shared with every request, never copied.
    subs: Vec<Arc<IntMatrix>>,
}

impl PreparedConv<'_> {
    /// The spec this handle serves.
    pub fn spec(&self) -> ConvSpec {
        self.spec
    }

    /// Declared precision of prepare-time packing.
    pub fn precision(&self) -> Precision {
        self.prec
    }

    /// Execute against one input tensor at the prepare-time precision.
    pub fn execute(&self, input: &Tensor) -> Result<ConvResponse, BismoError> {
        self.submit(input)?.wait()
    }

    /// [`PreparedConv::execute`] with a per-execute precision override:
    /// one resident weight tensor served at whatever precision each
    /// request (layer pass) asks for. The first execute at a new
    /// weight precision packs once; repeats hit the cache again.
    pub fn execute_with(
        &self,
        input: &Tensor,
        prec: Precision,
    ) -> Result<ConvResponse, BismoError> {
        self.submit_with(input, prec)?.wait()
    }

    /// Asynchronous [`PreparedConv::execute`]: every lowered GEMM is
    /// enqueued and the in-flight [`ConvHandle`] returned, so prepared
    /// conv weights ride the micro-batcher the way prepared GEMM
    /// weights do.
    pub fn submit(&self, input: &Tensor) -> Result<ConvHandle, BismoError> {
        submit_conv(
            self.session,
            &self.spec,
            self.mode,
            self.prec,
            self.opts.req,
            input,
            &self.subs,
        )
    }

    /// Asynchronous [`PreparedConv::execute_with`].
    pub fn submit_with(&self, input: &Tensor, prec: Precision) -> Result<ConvHandle, BismoError> {
        prec.validate()?;
        submit_conv(self.session, &self.spec, self.mode, prec, self.opts.req, input, &self.subs)
    }
}

/// One in-flight convolution: every lowered GEMM has already been
/// submitted to the serving layer. [`ConvHandle::wait`] collects the
/// per-GEMM results, accumulates the kn2row taps and reshapes the
/// product rows back into an NHWC tensor.
pub struct ConvHandle {
    handles: Vec<RequestHandle>,
    shape: GemmShape,
    mode: LoweringMode,
    batch: usize,
    oh: usize,
    ow: usize,
}

impl ConvHandle {
    /// Block until every lowered GEMM completes and assemble the
    /// convolution output. Consumes the handle (each underlying result
    /// is delivered exactly once).
    pub fn wait(self) -> Result<ConvResponse, BismoError> {
        let mut acc = IntMatrix::zeros(self.shape.m, self.shape.n);
        let mut gemms = Vec::with_capacity(self.handles.len());
        for h in self.handles {
            let resp = h.wait()?;
            for i in 0..self.shape.m {
                for j in 0..self.shape.n {
                    acc.set(i, j, acc.get(i, j) + resp.result.get(i, j));
                }
            }
            gemms.push(resp);
        }
        let output = Tensor::from_gemm_rows(&acc, self.batch, self.oh, self.ow);
        Ok(ConvResponse {
            output,
            gemms,
            shape: self.shape,
            mode: self.mode,
        })
    }
}

/// The lowered weight matrices of one conv layer: the full matrix for
/// im2col, the `kh·kw` per-tap row slices for kn2row.
fn lower_weights(
    spec: &ConvSpec,
    weights: &Arc<IntMatrix>,
    mode: LoweringMode,
) -> Vec<Arc<IntMatrix>> {
    match mode {
        LoweringMode::Im2col => vec![weights.clone()],
        LoweringMode::Kn2row => (0..spec.kh)
            .flat_map(|r| (0..spec.kw).map(move |s| (r, s)))
            .map(|(r, s)| Arc::new(kn2row_tap_weights(weights, spec, r, s)))
            .collect(),
    }
}

/// The shared submit path: pack the lowered LHS directly off the
/// input tensor (zero materialization) and enqueue every lowered GEMM
/// through the serving layer without waiting on any — im2col submits
/// its one wide GEMM, kn2row submits all `kh·kw` taps so they
/// micro-batch across the session's worker lanes.
fn submit_conv(
    session: &Session,
    spec: &ConvSpec,
    mode: LoweringMode,
    prec: Precision,
    opts: RequestOptions,
    input: &Tensor,
    subs: &[Arc<IntMatrix>],
) -> Result<ConvHandle, BismoError> {
    spec.check_input(input)?;
    if !input.fits(prec.wbits, prec.lsigned) {
        return Err(BismoError::PrecisionUnsupported(format!(
            "conv input entries do not fit {} {}-bit",
            if prec.lsigned { "signed" } else { "unsigned" },
            prec.wbits
        )));
    }
    let (batch, oh, ow) = (input.n, spec.out_h(), spec.out_w());
    let svc = session.service();
    let (shape, handles) = match mode {
        LoweringMode::Im2col => {
            let la = Arc::new(pack_im2col(input, spec, prec.wbits, prec.lsigned));
            let h = svc.submit_lowered(la, subs[0].clone(), prec, opts);
            (spec.gemm_shape(batch), vec![h])
        }
        LoweringMode::Kn2row => {
            let handles = (0..spec.kh)
                .flat_map(|r| (0..spec.kw).map(move |s| (r, s)))
                .zip(subs)
                .map(|((r, s), sub)| {
                    let la = Arc::new(pack_kn2row_tap(input, spec, r, s, prec.wbits, prec.lsigned));
                    svc.submit_lowered(la, sub.clone(), prec, opts)
                })
                .collect();
            (spec.kn2row_shape(batch), handles)
        }
    };
    Ok(ConvHandle {
        handles,
        shape,
        mode,
        batch,
        oh,
        ow,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Backend;
    use crate::lowering::conv2d_direct;
    use crate::util::Rng;

    fn session() -> Session {
        Session::with_defaults().unwrap()
    }

    fn prec() -> Precision {
        Precision {
            wbits: 2,
            abits: 3,
            lsigned: false,
            rsigned: true,
        }
    }

    #[test]
    fn conv_run_matches_direct_oracle_on_both_backends_and_modes() {
        let s = session();
        let mut rng = Rng::new(0xC4A);
        let spec = ConvSpec::simple(7, 6, 3, 4, 3, 1);
        let x = Tensor::random(&mut rng, 2, 7, 6, 3, 2, false);
        let w = spec.weights_from_fn(|_, _, _, _| rng.operand(3, true));
        let want = conv2d_direct(&x, &w, &spec);
        for backend in [Backend::Engine, Backend::Sim] {
            for mode in [LoweringMode::Im2col, LoweringMode::Kn2row] {
                let resp = s
                    .conv(spec, prec())
                    .backend(backend)
                    .lowering(mode)
                    .verify(true)
                    .run(&x, w.clone())
                    .unwrap();
                assert_eq!(resp.output, want, "{} {:?}", backend.name(), mode);
                let expect_gemms = match mode {
                    LoweringMode::Im2col => 1,
                    LoweringMode::Kn2row => 9,
                };
                assert_eq!(resp.gemms.len(), expect_gemms);
                if backend == Backend::Sim {
                    assert!(resp.sim_cycles() > 0);
                }
            }
        }
    }

    #[test]
    fn prepared_conv_reuses_weight_packings() {
        let s = session();
        let mut rng = Rng::new(0xC4B);
        let spec = ConvSpec::simple(6, 6, 2, 3, 3, 1);
        let w = spec.weights_from_fn(|_, _, _, _| rng.operand(3, true));
        let prepared = s.conv(spec, prec()).prepare(w.clone()).unwrap();
        let after_prepare = s.cache_stats();
        for i in 0..3 {
            let x = Tensor::random(&mut rng, 1, 6, 6, 2, 2, false);
            let resp = prepared.execute(&x).unwrap();
            assert_eq!(resp.output, conv2d_direct(&x, &w, &spec), "execute {i}");
            assert!(resp.weights_cached(), "execute {i} reuses the packing");
        }
        let after = s.cache_stats();
        assert_eq!(after.misses, after_prepare.misses, "no repacks after prepare");
    }

    #[test]
    fn per_execute_precision_override_is_bit_exact() {
        let s = session();
        let mut rng = Rng::new(0xC4C);
        let spec = ConvSpec::simple(5, 5, 2, 2, 3, 1);
        let w = spec.weights_from_fn(|_, _, _, _| rng.operand(2, true));
        let x = Tensor::random(&mut rng, 1, 5, 5, 2, 2, false);
        let want = conv2d_direct(&x, &w, &spec);
        let base = Precision {
            wbits: 2,
            abits: 2,
            lsigned: false,
            rsigned: true,
        };
        let prepared = s.conv(spec, base).prepare(w).unwrap();
        assert_eq!(prepared.execute(&x).unwrap().output, want);
        // Serve the same resident weights at a wider declared
        // precision: distinct cache entry, identical result.
        let wider = Precision {
            wbits: 3,
            abits: 4,
            lsigned: false,
            rsigned: true,
        };
        let r = prepared.execute_with(&x, wider).unwrap();
        assert_eq!(r.output, want, "declared headroom changes nothing");
        let r2 = prepared.execute_with(&x, wider).unwrap();
        assert!(r2.weights_cached(), "override precision cached after first use");
    }

    #[test]
    fn conv_errors_are_typed_and_precede_queueing() {
        let s = session();
        let spec = ConvSpec::simple(6, 6, 2, 3, 3, 1);
        let submitted = s.service().submitted();
        // Illegal spec: pad >= kernel extent.
        let mut bad = spec;
        bad.pad = (3, 3);
        let x = Tensor::zeros(1, 6, 6, 2);
        let w = spec.weights_from_fn(|_, _, _, _| 0);
        let r = s.conv(bad, prec()).run(&x, w.clone());
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
        // Zero channels.
        let bad = ConvSpec { in_c: 0, ..spec };
        let r = s.conv(bad, prec()).run(&x, w.clone());
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
        // Wrong weight layout.
        let r = s.conv(spec, prec()).run(&x, IntMatrix::zeros(4, 3));
        assert!(matches!(r, Err(BismoError::ShapeMismatch(_))), "{r:?}");
        // Wrong input geometry.
        let r = s.conv(spec, prec()).run(&Tensor::zeros(1, 5, 6, 2), w.clone());
        assert!(matches!(r, Err(BismoError::ShapeMismatch(_))), "{r:?}");
        // Input outside the declared activation precision.
        let hot = Tensor::from_fn(1, 6, 6, 2, |_, _, _, _| 9);
        let r = s.conv(spec, prec()).run(&hot, w);
        assert!(matches!(r, Err(BismoError::PrecisionUnsupported(_))), "{r:?}");
        assert_eq!(s.service().submitted(), submitted, "nothing was queued");
    }

    #[test]
    fn sharded_conv_stays_exact() {
        let s = session();
        let mut rng = Rng::new(0xC4D);
        let spec = ConvSpec::simple(8, 8, 2, 4, 3, 1);
        let x = Tensor::random(&mut rng, 2, 8, 8, 2, 2, false);
        let w = spec.weights_from_fn(|_, _, _, _| rng.operand(3, true));
        let want = conv2d_direct(&x, &w, &spec);
        let resp = s.conv(spec, prec()).instances(4).verify(true).run(&x, w).unwrap();
        assert_eq!(resp.output, want);
        assert!(resp.gemms[0].shards > 1, "the lowered GEMM actually sharded");
    }

    #[test]
    fn async_conv_submit_matches_run() {
        let s = session();
        let mut rng = Rng::new(0xC4E);
        let spec = ConvSpec::simple(6, 6, 2, 3, 3, 1);
        let w = spec.weights_from_fn(|_, _, _, _| rng.operand(3, true));
        let prepared = s.conv(spec, prec()).prepare(w.clone()).unwrap();
        // Submit several inputs before waiting on any: the lowered
        // GEMMs of all of them micro-batch together.
        let inputs: Vec<Tensor> = (0..3)
            .map(|_| Tensor::random(&mut rng, 1, 6, 6, 2, 2, false))
            .collect();
        let handles: Vec<ConvHandle> =
            inputs.iter().map(|x| prepared.submit(x).unwrap()).collect();
        for (h, x) in handles.into_iter().zip(&inputs).rev() {
            assert_eq!(h.wait().unwrap().output, conv2d_direct(x, &w, &spec));
        }
    }
}
