//! `bismo` — command-line interface to the overlay reproduction.
//!
//! Subcommands (hand-rolled parser; no clap in the offline registry):
//!
//! ```text
//! bismo quickstart                          tiny end-to-end check
//! bismo simulate [--instance N] [--m M --k K --n N --wbits W --abits A]
//!                [--signed] [--no-overlap] [--bit-skip]
//! bismo schedule [--instance N] [--m M --k K --n N ...]   dump queues
//! bismo bench [--quick] [--out PATH] [--threads N]   CPU kernel suite
//!                                           -> BENCH_gemm.json
//! bismo costmodel [--instance N]            LUT/BRAM prediction
//! bismo synth [--dk N]                      DPU virtual synthesis
//! bismo power                               Table V power model
//! bismo instances                           Table IV presets
//! bismo info                                config + artifact status
//! ```

use bismo::arch::{all_instances, instance, BismoConfig, PYNQ_Z1};
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{BismoContext, MatmulOptions, Precision};
use bismo::costmodel::CostModel;
use bismo::power::{PowerModel, TABLE_V};
use bismo::report::{f, pct, Table};
use bismo::scheduler::Overlap;
use bismo::synth::{synth_dpu, synth_instance};
use bismo::util::Rng;
use std::collections::HashMap;

fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut pos = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            let is_bool = matches!(
                name,
                "signed" | "no-overlap" | "bit-skip" | "verify" | "help" | "quick"
            );
            if is_bool {
                flags.insert(name.to_string(), "true".to_string());
            } else if i + 1 < args.len() {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 1;
            } else {
                flags.insert(name.to_string(), String::new());
            }
        } else {
            pos.push(a.clone());
        }
        i += 1;
    }
    (flags, pos)
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, k: &str, default: T) -> T {
    flags
        .get(k)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn config_from(flags: &HashMap<String, String>) -> BismoConfig {
    instance(get(flags, "instance", 1u32))
}

fn cmd_quickstart() -> Result<(), String> {
    let ctx = BismoContext::new(instance(1))?;
    let mut rng = Rng::new(1);
    let a = IntMatrix::random(&mut rng, 16, 256, 3, true);
    let b = IntMatrix::random(&mut rng, 256, 16, 3, true);
    let opts = MatmulOptions {
        verify: true,
        ..Default::default()
    };
    let (_, rep) = ctx.matmul(&a, &b, Precision::signed(3, 3), opts)?;
    println!(
        "16x256x16 signed 3x3-bit: {} cycles, {} GOPS ({} of peak), verified OK",
        rep.cycles,
        f(rep.gops, 1),
        pct(rep.efficiency)
    );
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<(), String> {
    let cfg = config_from(flags);
    let ctx = BismoContext::new(cfg)?;
    let m = get(flags, "m", 64usize);
    let k = get(flags, "k", 1024usize);
    let n = get(flags, "n", 64usize);
    let w = get(flags, "wbits", 2u32);
    let a = get(flags, "abits", 2u32);
    let signed = flags.contains_key("signed");
    let mut rng = Rng::new(get(flags, "seed", 7u64));
    let am = IntMatrix::random(&mut rng, m, k, w, signed);
    let bm = IntMatrix::random(&mut rng, k, n, a, signed);
    let prec = Precision {
        wbits: w,
        abits: a,
        lsigned: signed,
        rsigned: signed,
    };
    let opts = MatmulOptions {
        overlap: if flags.contains_key("no-overlap") {
            Overlap::None
        } else {
            Overlap::Full
        },
        bit_skip: flags.contains_key("bit-skip"),
        verify: true,
    };
    let (_, rep) = ctx.matmul(&am, &bm, prec, opts)?;
    let mut t = Table::new(
        &format!(
            "simulate {m}x{k}x{n} w{w}a{a} on (Dm={},Dk={},Dn={})",
            cfg.dm, cfg.dk, cfg.dn
        ),
        &["metric", "value"],
    );
    t.rowf(&[&"cycles", &rep.cycles]);
    t.rowf(&[&"seconds", &format!("{:.3e}", rep.seconds)]);
    t.rowf(&[&"GOPS", &f(rep.gops, 2)]);
    t.rowf(&[&"efficiency", &pct(rep.efficiency)]);
    t.rowf(&[&"fetch busy", &rep.stats.fetch_busy]);
    t.rowf(&[&"execute busy", &rep.stats.execute_busy]);
    t.rowf(&[&"result busy", &rep.stats.result_busy]);
    t.rowf(&[&"execute stall", &rep.stats.execute_stall]);
    t.rowf(&[&"bytes fetched", &rep.stats.bytes_fetched]);
    t.rowf(&[&"bytes written", &rep.stats.bytes_written]);
    t.rowf(&[&"instructions", &rep.instructions.total]);
    t.rowf(&[&"power (W)", &f(rep.power_w, 2)]);
    t.rowf(&[&"GOPS/W", &f(rep.gops_per_w, 1)]);
    t.rowf(&[
        &"planes (lhs x rhs)",
        &format!("{}x{}", rep.lhs_planes, rep.rhs_planes),
    ]);
    t.print();
    println!("verified against CPU bit-serial oracle OK");
    Ok(())
}

fn cmd_schedule(flags: &HashMap<String, String>) -> Result<(), String> {
    use bismo::bitmatrix::dram::{OperandLayout, ResultLayout};
    use bismo::scheduler::{compile, MatmulJob};
    use bismo::util::round_up;
    let cfg = config_from(flags);
    let m = get(flags, "m", 4usize);
    let k = get(flags, "k", 128usize);
    let n = get(flags, "n", 4usize);
    let w = get(flags, "wbits", 2u32);
    let a = get(flags, "abits", 2u32);
    let lhs = OperandLayout::new(0, m, k, w, cfg.dk);
    let rhs = OperandLayout::new(round_up(lhs.total_bytes(), 8), n, k, a, cfg.dk);
    let res = ResultLayout::new(round_up(rhs.base + rhs.total_bytes(), 8), m, n);
    let job = MatmulJob {
        m,
        k,
        n,
        wbits: w,
        abits: a,
        lsigned: false,
        rsigned: false,
        lhs,
        rhs,
        res,
    };
    let overlap = if flags.contains_key("no-overlap") {
        Overlap::None
    } else {
        Overlap::Full
    };
    let prog = compile(&job, &cfg, overlap)?;
    print!("{}", prog.disassemble());
    let st = prog.stats();
    println!(
        "{} instructions total ({} fetch / {} execute / {} result / {} sync), {} bytes encoded",
        st.total,
        st.fetch_runs,
        st.execute_runs,
        st.result_runs,
        st.waits + st.signals,
        prog.encoded_bytes()
    );
    Ok(())
}

/// One benchmark case of the GEMM suite.
struct BenchCase {
    m: usize,
    k: usize,
    n: usize,
    wbits: u32,
    abits: u32,
    signed: bool,
}

impl BenchCase {
    fn name(&self) -> String {
        format!(
            "{}x{}x{}_w{}a{}_{}",
            self.m,
            self.k,
            self.n,
            self.wbits,
            self.abits,
            if self.signed { "s" } else { "u" }
        )
    }
}

/// `bismo bench`: the CPU bit-serial GEMM suite — naive baseline vs the
/// tiled kernel engine, across precisions, signedness and ragged
/// shapes. Verifies bit-exactness on every case and writes the
/// machine-readable trajectory to `BENCH_gemm.json`.
fn cmd_bench(flags: &HashMap<String, String>) -> Result<(), String> {
    use bismo::baseline::{binary_ops, gemm_bitserial};
    use bismo::bitmatrix::BitSerialMatrix;
    use bismo::kernel::{gemm_tiled, gemm_tiled_parallel};
    use bismo::util::bench::{report, BenchTimer};
    use bismo::util::Json;
    use std::collections::BTreeMap;

    let quick = flags.contains_key("quick");
    let out_path = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_gemm.json".to_string());
    let default_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    let threads = get(flags, "threads", default_threads).max(1);

    let mk = |m, k, n, wbits, abits, signed| BenchCase {
        m,
        k,
        n,
        wbits,
        abits,
        signed,
    };
    // `--quick` is the CI smoke suite; the full suite sweeps precision
    // 1..8 plus ragged (k, n not multiples of 64/tile) and deep-k
    // shapes, ending with the 8x8-bit headline case the perf-regression
    // gate tracks.
    let cases: Vec<BenchCase> = if quick {
        vec![
            mk(32, 256, 32, 1, 1, false),
            mk(32, 256, 32, 4, 4, false),
            mk(33, 100, 17, 2, 3, true),
            mk(64, 512, 64, 8, 8, false),
        ]
    } else {
        vec![
            mk(128, 1024, 128, 1, 1, false),
            mk(128, 1024, 128, 2, 2, false),
            mk(128, 1024, 128, 3, 3, true),
            mk(128, 1024, 128, 4, 4, false),
            mk(128, 1024, 128, 6, 6, true),
            mk(128, 1024, 128, 8, 8, false),
            mk(96, 1000, 96, 3, 5, true),
            mk(64, 8192, 64, 4, 4, false),
            mk(256, 2048, 256, 8, 8, false),
        ]
    };
    let headline_name = cases.last().map(|c| c.name()).unwrap_or_default();
    let timer = if quick {
        BenchTimer::smoke()
    } else {
        BenchTimer::heavy()
    };

    let mut rng = Rng::new(0xBE7C);
    let mut jcases = Vec::new();
    let mut headline_speedup = 0.0f64;
    for case in &cases {
        let a = IntMatrix::random(&mut rng, case.m, case.k, case.wbits, case.signed);
        let b = IntMatrix::random(&mut rng, case.k, case.n, case.abits, case.signed);
        let la = BitSerialMatrix::from_int(&a, case.wbits, case.signed);
        let rb = BitSerialMatrix::from_int_transposed(&b, case.abits, case.signed);

        // Correctness gate first: the engine must be bit-exact against
        // the oracle on every case it is timed on.
        let oracle = gemm_bitserial(&la, &rb);
        if gemm_tiled(&la, &rb) != oracle {
            return Err(format!("tiled kernel mismatch on {}", case.name()));
        }
        if gemm_tiled_parallel(&la, &rb, threads) != oracle {
            return Err(format!("parallel tiled kernel mismatch on {}", case.name()));
        }

        let ops = binary_ops(
            case.m as u64,
            case.k as u64,
            case.n as u64,
            case.wbits,
            case.abits,
        ) as f64;
        let name = case.name();
        let base = timer.run(|| gemm_bitserial(&la, &rb));
        report(&format!("baseline_{name}_1t"), &base, Some((ops, "binop")));
        let tiled = timer.run(|| gemm_tiled(&la, &rb));
        report(&format!("tiled_{name}_1t"), &tiled, Some((ops, "binop")));
        let tiled_mt = timer.run(|| gemm_tiled_parallel(&la, &rb, threads));
        report(
            &format!("tiled_{name}_{threads}t"),
            &tiled_mt,
            Some((ops, "binop")),
        );

        let speedup_1t = base.median() / tiled.median();
        if name == headline_name {
            headline_speedup = speedup_1t;
        }
        let mut jc = BTreeMap::new();
        jc.insert("name".to_string(), Json::str(&name));
        jc.insert("m".to_string(), Json::num(case.m as f64));
        jc.insert("k".to_string(), Json::num(case.k as f64));
        jc.insert("n".to_string(), Json::num(case.n as f64));
        jc.insert("wbits".to_string(), Json::num(case.wbits as f64));
        jc.insert("abits".to_string(), Json::num(case.abits as f64));
        jc.insert("signed".to_string(), Json::Bool(case.signed));
        jc.insert("binary_ops".to_string(), Json::num(ops));
        jc.insert("baseline_ns".to_string(), Json::num(base.median()));
        jc.insert("tiled_ns".to_string(), Json::num(tiled.median()));
        jc.insert("tiled_mt_ns".to_string(), Json::num(tiled_mt.median()));
        jc.insert(
            "baseline_gops".to_string(),
            Json::num(ops / base.median()),
        );
        jc.insert("tiled_gops".to_string(), Json::num(ops / tiled.median()));
        jc.insert(
            "tiled_mt_gops".to_string(),
            Json::num(ops / tiled_mt.median()),
        );
        jc.insert("speedup_1t".to_string(), Json::num(speedup_1t));
        jc.insert(
            "speedup_mt".to_string(),
            Json::num(base.median() / tiled_mt.median()),
        );
        jcases.push(Json::Obj(jc));
    }

    let mut headline = BTreeMap::new();
    headline.insert("case".to_string(), Json::str(&headline_name));
    headline.insert("speedup_1t".to_string(), Json::num(headline_speedup));
    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), Json::str("bismo-bench-gemm/v1"));
    root.insert(
        "mode".to_string(),
        Json::str(if quick { "quick" } else { "full" }),
    );
    root.insert("threads".to_string(), Json::num(threads as f64));
    root.insert(
        "generated_unix".to_string(),
        Json::num(
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs() as f64)
                .unwrap_or(0.0),
        ),
    );
    root.insert("cases".to_string(), Json::Arr(jcases));
    root.insert("headline".to_string(), Json::Obj(headline));
    let doc = Json::Obj(root);
    std::fs::write(&out_path, doc.pretty(2) + "\n")
        .map_err(|e| format!("writing {out_path}: {e}"))?;
    println!(
        "wrote {out_path}: headline {} speedup {:.2}x (tiled vs baseline, 1 thread)",
        headline_name, headline_speedup
    );
    Ok(())
}

fn cmd_costmodel(flags: &HashMap<String, String>) -> Result<(), String> {
    let model = CostModel::paper();
    let fitted = CostModel::fit_from_synth();
    let mut t = Table::new(
        "cost model (Eq. 1-2)",
        &["instance", "LUT (paper const)", "LUT (fitted)", "BRAM", "fits Z7020"],
    );
    if let Some(inst) = flags.get("instance") {
        let cfg = instance(inst.parse().map_err(|_| "bad --instance")?);
        t.rowf(&[
            inst,
            &f(model.lut_total(&cfg), 0),
            &f(fitted.lut_total(&cfg), 0),
            &model.bram_total(&cfg),
            &model.fits(&cfg, &PYNQ_Z1),
        ]);
    } else {
        for (id, cfg) in all_instances() {
            t.rowf(&[
                &id,
                &f(model.lut_total(&cfg), 0),
                &f(fitted.lut_total(&cfg), 0),
                &model.bram_total(&cfg),
                &model.fits(&cfg, &PYNQ_Z1),
            ]);
        }
    }
    t.print();
    println!(
        "fitted constants: alpha={:.2} beta={:.1} (paper: 2.04 / 109.41)",
        fitted.alpha_dpu, fitted.beta_dpu
    );
    Ok(())
}

fn cmd_synth(flags: &HashMap<String, String>) -> Result<(), String> {
    if let Some(dk) = flags.get("dk") {
        let dk: u32 = dk.parse().map_err(|_| "bad --dk")?;
        let r = synth_dpu(dk, 32);
        println!(
            "DPU(Dk={dk}): {} LUTs ({} LUT/bin.op), {} FFs, Fmax {} MHz",
            f(r.luts, 0),
            f(r.luts / (2.0 * dk as f64), 2),
            f(r.ffs, 0),
            f(r.fmax_mhz, 0)
        );
    } else {
        let mut t = Table::new(
            "virtual synthesis of Table IV instances",
            &["instance", "LUTs", "BRAMs", "DPU Fmax", "Fmax (DMA-capped)"],
        );
        for (id, cfg) in all_instances() {
            let s = synth_instance(&cfg);
            t.rowf(&[
                &id,
                &f(s.total_luts, 0),
                &s.brams,
                &f(s.dpu.fmax_mhz, 0),
                &f(s.fmax_mhz, 0),
            ]);
        }
        t.print();
    }
    Ok(())
}

fn cmd_power() -> Result<(), String> {
    let m = PowerModel::calibrated();
    let mut t = Table::new(
        "power model vs paper Table V",
        &["config", "idle W", "+exec W", "+f&r W", "full W", "paper full W", "GOPS/W"],
    );
    for row in &TABLE_V {
        let cfg = instance(row.instance).at_clock(row.fclk_mhz);
        t.rowf(&[
            &format!("(#{}, {} MHz)", row.instance, row.fclk_mhz),
            &f(m.idle_w(&cfg), 2),
            &f(m.exec_increment_w(&cfg), 2),
            &f(m.fetch_result_increment_w(&cfg), 2),
            &f(m.full_w(&cfg), 2),
            &f(row.full_w, 2),
            &f(row.gops / m.full_w(&cfg), 1),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_instances() -> Result<(), String> {
    let mut t = Table::new(
        "Table IV instance presets",
        &["#", "Dm", "Dk", "Dn", "Bm", "Bn", "peak GOPS @ 200 MHz"],
    );
    for (id, cfg) in all_instances() {
        t.rowf(&[
            &id,
            &cfg.dm,
            &cfg.dk,
            &cfg.dn,
            &cfg.bm,
            &cfg.bn,
            &f(cfg.peak_binary_gops(), 1),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_info() -> Result<(), String> {
    println!("bismo — bit-serial matrix multiplication overlay (reproduction)");
    println!("platform model: {}", PYNQ_Z1.name);
    #[cfg(feature = "xla")]
    {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            match bismo::runtime::ArtifactManifest::load(&dir) {
                Ok(m) => {
                    println!("artifacts ({}):", dir.display());
                    for name in m.artifacts.keys() {
                        println!("  {name}");
                    }
                }
                Err(e) => println!("artifact manifest error: {e}"),
            }
        } else {
            println!("artifacts: not built (run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("artifacts: PJRT runtime disabled (build with --features xla)");
    Ok(())
}

const USAGE: &str = "usage: bismo <quickstart|simulate|schedule|bench|costmodel|synth|power|instances|info> [flags]
flags: --instance N  --m M --k K --n N  --wbits W --abits A  --signed --no-overlap --bit-skip  --seed S  --dk N
bench: --quick  --out PATH (default BENCH_gemm.json)  --threads N";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (flags, pos) = parse_flags(&args);
    let cmd = pos.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "quickstart" => cmd_quickstart(),
        "simulate" => cmd_simulate(&flags),
        "schedule" => cmd_schedule(&flags),
        "bench" => cmd_bench(&flags),
        "costmodel" => cmd_costmodel(&flags),
        "synth" => cmd_synth(&flags),
        "power" => cmd_power(),
        "instances" => cmd_instances(),
        "info" => cmd_info(),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
