//! `bismo` — command-line interface to the overlay reproduction.
//!
//! Subcommands (hand-rolled parser; no clap in the offline registry):
//!
//! ```text
//! bismo quickstart                          tiny end-to-end check
//! bismo simulate [--instance N] [--m M --k K --n N --wbits W --abits A]
//!                [--signed] [--no-overlap] [--bit-skip]
//! bismo schedule [--instance N] [--m M --k K --n N ...]   dump queues
//! bismo bench [--quick] [--out PATH] [--threads N]   CPU kernel suite
//!                                           -> BENCH_gemm.json
//! bismo tune [--quick] [--out PATH] [--dir DIR] [--threads N] [--seed S]
//!                closed-loop autotuner: measures candidate tile
//!                geometries and shard plans per shape class (each
//!                verified bit-exact before timing), refits the cost
//!                model, persists the per-machine profile under DIR
//!                (default tuned/, override BISMO_TUNE_DIR) keyed by
//!                CPU identity, and writes BENCH_tune.json; sessions
//!                load the profile automatically at startup
//! bismo serve [--host H] [--port P] [--workers W] [--batch B]
//!                [--cache-mb M] [--max-in-flight N] [--tenant-in-flight N]
//!                [--tenant-weight-mb M] [--instance N]
//!                host the TCP front door (binary wire protocol,
//!                multi-tenant cache namespaces, admission control);
//!                prints the bound address, serves until stdin closes,
//!                then drains gracefully
//! bismo serve-bench [--quick] [--backend engine|sim] [--requests N]
//!                [--rate RPS] [--layers L] [--workers W] [--batch B]
//!                [--m M --k K --n N --wbits W --abits A] [--out PATH]
//!                [--remote] [--clients C] [--addr HOST:PORT]
//!                [--max-in-flight N] [--tenant-in-flight N]
//!                open-loop load generator against the async serving
//!                layer -> BENCH_serve.json (latency percentiles,
//!                throughput, packing-cache repack-avoidance win);
//!                --remote adds a closed-loop phase over real TCP
//!                sockets (self-hosted ephemeral port unless --addr)
//!                reporting client-observed p50/p95/p99 and the shed
//!                rate into a `remote` section
//! bismo shard-bench [--quick] [--backend engine|sim] [--reps N]
//!                [--max-shards S] [--m M --k K --n N --wbits W --abits A]
//!                [--budget-luts L --budget-brams B] [--out PATH]
//!                sweep shard count (multi-instance execution) on one
//!                workload -> BENCH_shard.json scaling curve, plus the
//!                cost model's Auto pick under the budget
//! bismo cnn-bench [--quick] [--batch B] [--reps N] [--out PATH]
//!                quantized-CNN serving benchmark: both conv lowerings
//!                (im2col / kn2row) end to end on the engine backend
//!                (throughput) and the sim backend (per-layer cycles)
//!                -> BENCH_cnn.json
//! bismo attn-bench [--quick] [--seq S] [--requests N] [--reps N] [--out PATH]
//!                quantized transformer encoder block serving
//!                benchmark: static vs input-adaptive precision arms
//!                over a request mix of varying activation range,
//!                every static/range-adaptive pass gated bit-exact
//!                against the i64 oracle on both backends
//!                -> BENCH_attn.json
//! bismo bench-check --baseline PATH --current PATH [--tolerance F]
//!                CI regression gate: compares two BENCH_gemm.json
//!                (or BENCH_tune.json / BENCH_attn.json) files,
//!                failing on schema drift or on speedup regression
//!                beyond the tolerance
//! bismo fuzz [--iters N] [--seed S] [--mode legal|mutation|differential|wire|all]
//!                [--out PATH]               seeded structured fuzzing of
//!                the ISA decoder, simulator and serving backends; every
//!                failure prints a one-line replay seed and the full
//!                list is written to PATH (default FUZZ_failures.json)
//!                on failure
//! bismo snapshot [--regen]                  golden simulator-snapshot
//!                gate: compares the deterministic snapshot/replay
//!                report against ci/sim_snapshots.json (--regen
//!                rewrites the baseline)
//! bismo costmodel [--instance N]            LUT/BRAM prediction
//! bismo synth [--dk N]                      DPU virtual synthesis
//! bismo power                               Table V power model
//! bismo instances                           Table IV presets
//! bismo info                                config + artifact status
//! ```

use bismo::api::{Backend, BismoError, Overlap, Precision, Session, SessionConfig};
use bismo::arch::{all_instances, try_instance, BismoConfig, PYNQ_Z1};
use bismo::bitmatrix::IntMatrix;
use bismo::costmodel::CostModel;
use bismo::power::{PowerModel, TABLE_V};
use bismo::report::{f, pct, Table};
use bismo::synth::{synth_dpu, synth_instance};
use bismo::util::Rng;
use std::collections::HashMap;

fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut pos = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            let is_bool = matches!(
                name,
                "signed"
                    | "no-overlap"
                    | "bit-skip"
                    | "verify"
                    | "help"
                    | "quick"
                    | "regen"
                    | "remote"
            );
            if is_bool {
                flags.insert(name.to_string(), "true".to_string());
            } else if i + 1 < args.len() {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 1;
            } else {
                flags.insert(name.to_string(), String::new());
            }
        } else {
            pos.push(a.clone());
        }
        i += 1;
    }
    (flags, pos)
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, k: &str, default: T) -> T {
    flags
        .get(k)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Resolve `--instance` through the fallible Table IV lookup, so an
/// unknown id is reported as a proper CLI error instead of a panic.
fn config_from(flags: &HashMap<String, String>) -> Result<BismoConfig, BismoError> {
    let raw = flags.get("instance").map(String::as_str).unwrap_or("1");
    let id: u32 = raw
        .parse()
        .map_err(|_| BismoError::Parse(format!("bad --instance {raw:?} (expect a number)")))?;
    try_instance(id)
}

fn cmd_quickstart() -> Result<(), BismoError> {
    let session = Session::new(SessionConfig {
        overlay: try_instance(1)?,
        ..Default::default()
    })?;
    let mut rng = Rng::new(1);
    let a = IntMatrix::random(&mut rng, 16, 256, 3, true);
    let b = IntMatrix::random(&mut rng, 256, 16, 3, true);
    let resp = session
        .matmul(Precision::signed(3, 3))
        .backend(Backend::Sim)
        .verify(true)
        .run(a, b)?;
    let rep = resp.report.expect("sim backend carries a report");
    println!(
        "16x256x16 signed 3x3-bit: {} cycles, {} GOPS ({} of peak), verified OK",
        rep.cycles,
        f(rep.gops, 1),
        pct(rep.efficiency)
    );
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    let cfg = config_from(flags)?;
    let session = Session::new(SessionConfig {
        overlay: cfg,
        ..Default::default()
    })?;
    let m = get(flags, "m", 64usize);
    let k = get(flags, "k", 1024usize);
    let n = get(flags, "n", 64usize);
    let w = get(flags, "wbits", 2u32);
    let a = get(flags, "abits", 2u32);
    let signed = flags.contains_key("signed");
    let mut rng = Rng::new(get(flags, "seed", 7u64));
    let am = IntMatrix::random(&mut rng, m, k, w, signed);
    let bm = IntMatrix::random(&mut rng, k, n, a, signed);
    let prec = Precision::try_new(w, a, signed, signed)?;
    let resp = session
        .matmul(prec)
        .backend(Backend::Sim)
        .overlap(if flags.contains_key("no-overlap") {
            Overlap::None
        } else {
            Overlap::Full
        })
        .bit_skip(flags.contains_key("bit-skip"))
        .verify(true)
        .run(am, bm)?;
    let rep = resp.report.expect("sim backend carries a report");
    let mut t = Table::new(
        &format!(
            "simulate {m}x{k}x{n} w{w}a{a} on (Dm={},Dk={},Dn={})",
            cfg.dm, cfg.dk, cfg.dn
        ),
        &["metric", "value"],
    );
    t.rowf(&[&"cycles", &rep.cycles]);
    t.rowf(&[&"seconds", &format!("{:.3e}", rep.seconds)]);
    t.rowf(&[&"GOPS", &f(rep.gops, 2)]);
    t.rowf(&[&"efficiency", &pct(rep.efficiency)]);
    t.rowf(&[&"fetch busy", &rep.stats.fetch_busy]);
    t.rowf(&[&"execute busy", &rep.stats.execute_busy]);
    t.rowf(&[&"result busy", &rep.stats.result_busy]);
    t.rowf(&[&"execute stall", &rep.stats.execute_stall]);
    t.rowf(&[&"bytes fetched", &rep.stats.bytes_fetched]);
    t.rowf(&[&"bytes written", &rep.stats.bytes_written]);
    t.rowf(&[&"instructions", &rep.instructions.total]);
    t.rowf(&[&"power (W)", &f(rep.power_w, 2)]);
    t.rowf(&[&"GOPS/W", &f(rep.gops_per_w, 1)]);
    t.rowf(&[
        &"planes (lhs x rhs)",
        &format!("{}x{}", rep.lhs_planes, rep.rhs_planes),
    ]);
    t.print();
    println!("verified against CPU bit-serial oracle OK");
    Ok(())
}

fn cmd_schedule(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::bitmatrix::dram::{OperandLayout, ResultLayout};
    use bismo::scheduler::{compile, MatmulJob};
    use bismo::util::round_up;
    let cfg = config_from(flags)?;
    let m = get(flags, "m", 4usize);
    let k = get(flags, "k", 128usize);
    let n = get(flags, "n", 4usize);
    let w = get(flags, "wbits", 2u32);
    let a = get(flags, "abits", 2u32);
    let lhs = OperandLayout::new(0, m, k, w, cfg.dk);
    let rhs = OperandLayout::new(round_up(lhs.total_bytes(), 8), n, k, a, cfg.dk);
    let res = ResultLayout::new(round_up(rhs.base + rhs.total_bytes(), 8), m, n);
    let job = MatmulJob {
        m,
        k,
        n,
        wbits: w,
        abits: a,
        lsigned: false,
        rsigned: false,
        lhs,
        rhs,
        res,
    };
    let overlap = if flags.contains_key("no-overlap") {
        Overlap::None
    } else {
        Overlap::Full
    };
    let prog = compile(&job, &cfg, overlap)?;
    print!("{}", prog.disassemble());
    let st = prog.stats();
    println!(
        "{} instructions total ({} fetch / {} execute / {} result / {} sync), {} bytes encoded",
        st.total,
        st.fetch_runs,
        st.execute_runs,
        st.result_runs,
        st.waits + st.signals,
        prog.encoded_bytes()
    );
    Ok(())
}

/// One benchmark case of the GEMM suite.
struct BenchCase {
    m: usize,
    k: usize,
    n: usize,
    wbits: u32,
    abits: u32,
    signed: bool,
}

impl BenchCase {
    fn name(&self) -> String {
        format!(
            "{}x{}x{}_w{}a{}_{}",
            self.m,
            self.k,
            self.n,
            self.wbits,
            self.abits,
            if self.signed { "s" } else { "u" }
        )
    }
}

/// `bismo bench`: the CPU bit-serial GEMM suite — naive baseline vs the
/// tiled kernel engine, across precisions, signedness and ragged
/// shapes. Verifies bit-exactness on every case and writes the
/// machine-readable trajectory to `BENCH_gemm.json`.
fn cmd_bench(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::baseline::{binary_ops, gemm_bitserial};
    use bismo::bitmatrix::BitSerialMatrix;
    use bismo::kernel::{gemm_tiled, gemm_tiled_with, KernelConfig, WorkerPool};
    let mt = |la: &BitSerialMatrix, rb: &BitSerialMatrix, threads: usize| {
        gemm_tiled_with(la, rb, &KernelConfig::default(), Some((WorkerPool::global(), threads)))
            .expect("bench shapes are valid")
    };
    use bismo::util::bench::{report, BenchTimer};
    use bismo::util::Json;
    use std::collections::BTreeMap;

    // Resolve the SIMD tier before timing anything: an invalid
    // BISMO_SIMD override becomes a typed CLI error here, and the
    // resolved tier is recorded in the report.
    let tier = bismo::simd::DispatchTier::resolve()?;
    println!("simd tier: {tier}");

    let quick = flags.contains_key("quick");
    let out_path = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_gemm.json".to_string());
    let default_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    let threads = get(flags, "threads", default_threads).max(1);

    let mk = |m, k, n, wbits, abits, signed| BenchCase {
        m,
        k,
        n,
        wbits,
        abits,
        signed,
    };
    // `--quick` is the CI smoke suite; the full suite sweeps precision
    // 1..8 plus ragged (k, n not multiples of 64/tile) and deep-k
    // shapes, ending with the 8x8-bit headline case the perf-regression
    // gate tracks.
    let cases: Vec<BenchCase> = if quick {
        vec![
            mk(32, 256, 32, 1, 1, false),
            mk(32, 256, 32, 4, 4, false),
            mk(33, 100, 17, 2, 3, true),
            mk(64, 512, 64, 8, 8, false),
        ]
    } else {
        vec![
            mk(128, 1024, 128, 1, 1, false),
            mk(128, 1024, 128, 2, 2, false),
            mk(128, 1024, 128, 3, 3, true),
            mk(128, 1024, 128, 4, 4, false),
            mk(128, 1024, 128, 6, 6, true),
            mk(128, 1024, 128, 8, 8, false),
            mk(96, 1000, 96, 3, 5, true),
            mk(64, 8192, 64, 4, 4, false),
            mk(256, 2048, 256, 8, 8, false),
        ]
    };
    let headline_name = cases.last().map(|c| c.name()).unwrap_or_default();
    let timer = if quick {
        BenchTimer::smoke()
    } else {
        BenchTimer::heavy()
    };

    let mut rng = Rng::new(0xBE7C);
    let mut jcases = Vec::new();
    let mut headline_speedup = 0.0f64;
    for case in &cases {
        let a = IntMatrix::random(&mut rng, case.m, case.k, case.wbits, case.signed);
        let b = IntMatrix::random(&mut rng, case.k, case.n, case.abits, case.signed);
        let la = BitSerialMatrix::from_int(&a, case.wbits, case.signed);
        let rb = BitSerialMatrix::from_int_transposed(&b, case.abits, case.signed);

        // Correctness gate first: the engine must be bit-exact against
        // the oracle on every case it is timed on.
        let oracle = gemm_bitserial(&la, &rb);
        if gemm_tiled(&la, &rb)? != oracle {
            return Err(BismoError::VerifyFailed(format!(
                "tiled kernel mismatch on {}",
                case.name()
            )));
        }
        if mt(&la, &rb, threads) != oracle {
            return Err(BismoError::VerifyFailed(format!(
                "parallel tiled kernel mismatch on {}",
                case.name()
            )));
        }

        let ops = binary_ops(
            case.m as u64,
            case.k as u64,
            case.n as u64,
            case.wbits,
            case.abits,
        ) as f64;
        let name = case.name();
        let base = timer.run(|| gemm_bitserial(&la, &rb));
        report(&format!("baseline_{name}_1t"), &base, Some((ops, "binop")));
        let tiled = timer.run(|| gemm_tiled(&la, &rb).expect("verified above"));
        report(&format!("tiled_{name}_1t"), &tiled, Some((ops, "binop")));
        let tiled_mt = timer.run(|| mt(&la, &rb, threads));
        report(
            &format!("tiled_{name}_{threads}t"),
            &tiled_mt,
            Some((ops, "binop")),
        );

        let speedup_1t = base.median() / tiled.median();
        if name == headline_name {
            headline_speedup = speedup_1t;
        }
        let mut jc = BTreeMap::new();
        jc.insert("name".to_string(), Json::str(&name));
        jc.insert("m".to_string(), Json::num(case.m as f64));
        jc.insert("k".to_string(), Json::num(case.k as f64));
        jc.insert("n".to_string(), Json::num(case.n as f64));
        jc.insert("wbits".to_string(), Json::num(case.wbits as f64));
        jc.insert("abits".to_string(), Json::num(case.abits as f64));
        jc.insert("signed".to_string(), Json::Bool(case.signed));
        jc.insert("binary_ops".to_string(), Json::num(ops));
        jc.insert("baseline_ns".to_string(), Json::num(base.median()));
        jc.insert("tiled_ns".to_string(), Json::num(tiled.median()));
        jc.insert("tiled_mt_ns".to_string(), Json::num(tiled_mt.median()));
        jc.insert(
            "baseline_gops".to_string(),
            Json::num(ops / base.median()),
        );
        jc.insert("tiled_gops".to_string(), Json::num(ops / tiled.median()));
        jc.insert(
            "tiled_mt_gops".to_string(),
            Json::num(ops / tiled_mt.median()),
        );
        jc.insert("speedup_1t".to_string(), Json::num(speedup_1t));
        jc.insert(
            "speedup_mt".to_string(),
            Json::num(base.median() / tiled_mt.median()),
        );
        jcases.push(Json::Obj(jc));
    }

    let mut headline = BTreeMap::new();
    headline.insert("case".to_string(), Json::str(&headline_name));
    headline.insert("speedup_1t".to_string(), Json::num(headline_speedup));
    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), Json::str("bismo-bench-gemm/v1"));
    root.insert(
        "mode".to_string(),
        Json::str(if quick { "quick" } else { "full" }),
    );
    root.insert("simd_tier".to_string(), Json::str(tier.name()));
    root.insert("threads".to_string(), Json::num(threads as f64));
    root.insert(
        "generated_unix".to_string(),
        Json::num(
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs() as f64)
                .unwrap_or(0.0),
        ),
    );
    root.insert("cases".to_string(), Json::Arr(jcases));
    root.insert("headline".to_string(), Json::Obj(headline));
    let doc = Json::Obj(root);
    std::fs::write(&out_path, doc.pretty(2) + "\n")
        .map_err(|e| BismoError::Io(format!("writing {out_path}: {e}")))?;
    println!(
        "wrote {out_path}: headline {} speedup {:.2}x (tiled vs baseline, 1 thread)",
        headline_name, headline_speedup
    );
    Ok(())
}

/// `bismo serve-bench`: open-loop load generator against the async
/// serving layer ([`bismo::coordinator::BismoService`]).
///
/// The workload is the weight-stationary QNN serving pattern: `layers`
/// weight matrices (`k×n`, signed `wbits`) are reused round-robin as
/// the RHS while every request carries a fresh activation matrix
/// (`m×k`, unsigned `abits`). Requests arrive open-loop with
/// exponential inter-arrival times at `rate` req/s, are micro-batched
/// by the service, and per-request latency is measured submit→complete.
///
/// The same request stream then replays against a cache-disabled
/// service, and the difference in packing time is reported as the
/// repack-avoidance win. Results go to `BENCH_serve.json`
/// (schema documented in the README).
fn cmd_serve_bench(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::coordinator::{BismoService, GemmRequest, RequestOptions, ServiceConfig};
    use bismo::util::bench::Samples;
    use bismo::util::Json;
    use std::collections::BTreeMap;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    struct Phase {
        lat: Samples,
        wall_s: f64,
        pack_ns: u64,
        exec_ns: u64,
        queue_ns: u64,
        rhs_hits: u64,
        cache: bismo::coordinator::CacheStats,
        cache_entries: usize,
        cache_resident_bytes: usize,
    }

    // Packing-cache capacity of the cache-on phase; also what the
    // emitted `service.cache_capacity_bytes` field reports.
    const SERVE_CACHE_BYTES: usize = 256 << 20;

    let quick = flags.contains_key("quick");
    let requests = get(flags, "requests", if quick { 64usize } else { 384 }).max(1);
    let layers = get(flags, "layers", 3usize).max(1);
    let m = get(flags, "m", 16usize);
    let k = get(flags, "k", 512usize);
    let n = get(flags, "n", 128usize);
    let wbits = get(flags, "wbits", 4u32); // weight (RHS) precision, signed
    let abits = get(flags, "abits", 2u32); // activation (LHS) precision, unsigned
    let default_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    // Clamp to the pool's real lane count so the JSON reports the
    // concurrency that actually executed, not an aspirational figure.
    let workers = get(flags, "workers", default_threads)
        .max(1)
        .min(bismo::kernel::WorkerPool::global().lanes());
    let max_batch = get(flags, "batch", 16usize).max(1);
    let rate: f64 = get(flags, "rate", if quick { 4000.0 } else { 2000.0 });
    let backend = match flags.get("backend").map(|s| s.as_str()) {
        None | Some("engine") => Backend::Engine,
        Some("sim") => Backend::Sim,
        Some(other) => {
            return Err(BismoError::Parse(format!(
                "unknown --backend {other} (engine|sim)"
            )))
        }
    };
    let out_path = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_serve.json".to_string());
    let overlay = config_from(flags)?;
    let seed = get(flags, "seed", 0x5E17Eu64);
    if rate <= 0.0 {
        return Err(BismoError::InvalidConfig("--rate must be positive".into()));
    }

    // The weight-stationary workload: reused weights, fresh activations.
    let mut rng = Rng::new(seed);
    let prec = Precision {
        wbits: abits, // LHS = activations
        abits: wbits, // RHS = weights
        lsigned: false,
        rsigned: true,
    };
    let weights: Vec<Arc<IntMatrix>> = (0..layers)
        .map(|_| Arc::new(IntMatrix::random(&mut rng, k, n, wbits, true)))
        .collect();
    let acts: Vec<Arc<IntMatrix>> = (0..requests)
        .map(|_| Arc::new(IntMatrix::random(&mut rng, m, k, abits, false)))
        .collect();
    // Open-loop arrival schedule: exponential inter-arrival at `rate`.
    let mut arrivals = Vec::with_capacity(requests);
    let mut t = 0.0f64;
    for _ in 0..requests {
        t += -(1.0 - rng.f64()).ln() / rate;
        arrivals.push(Duration::from_secs_f64(t));
    }

    let run_phase = |cache_bytes: usize| -> Result<Phase, BismoError> {
        let svc = BismoService::new(ServiceConfig {
            workers,
            max_batch,
            cache_bytes,
            overlay,
        })?;
        let opts = RequestOptions {
            backend,
            ..Default::default()
        };
        let t0 = Instant::now();
        let mut handles = Vec::with_capacity(requests);
        for i in 0..requests {
            loop {
                let el = t0.elapsed();
                if el >= arrivals[i] {
                    break;
                }
                std::thread::sleep((arrivals[i] - el).min(Duration::from_micros(500)));
            }
            handles.push(svc.submit(GemmRequest::with_opts(
                acts[i].clone(),
                weights[i % layers].clone(),
                prec,
                opts,
            )));
        }
        let mut lat = Vec::with_capacity(requests);
        let (mut pack_ns, mut exec_ns, mut queue_ns, mut rhs_hits) = (0u64, 0u64, 0u64, 0u64);
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.wait()?;
            // Correctness gate on the first pass over the weight set.
            if i < layers && r.result != acts[i].matmul(&weights[i % layers]) {
                return Err(BismoError::VerifyFailed(format!(
                    "request {i}: service result != reference"
                )));
            }
            lat.push(r.total_ns as f64);
            pack_ns += r.pack_ns;
            exec_ns += r.exec_ns;
            queue_ns += r.queue_ns;
            rhs_hits += r.rhs_cached as u64;
        }
        let wall_s = t0.elapsed().as_secs_f64();
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(Phase {
            lat: Samples { ns: lat },
            wall_s,
            pack_ns,
            exec_ns,
            queue_ns,
            rhs_hits,
            cache: svc.cache_stats(),
            cache_entries: svc.cache_entries(),
            cache_resident_bytes: svc.cache_bytes(),
        })
    };

    println!(
        "serve-bench: {requests} requests, {layers} reused weight(s) {k}x{n} w{wbits}s, \
         activations {m}x{k} a{abits}u, {} backend, open loop at {rate} req/s",
        backend.name()
    );
    let on = run_phase(SERVE_CACHE_BYTES)?;
    let off = run_phase(0)?;

    // `--remote`: a closed-loop phase over real TCP sockets. Each
    // client thread owns one connection and one tenant; latency is
    // client-observed (wire + serving stack), and requests the
    // admission gate sheds are counted instead of retried blindly.
    let remote_json = if flags.contains_key("remote") {
        use bismo::net::{NetClient, NetServer, ServeConfig};

        let clients = get(flags, "clients", 4usize).max(1);
        let ext_addr = flags.get("addr").filter(|v| !v.is_empty()).cloned();
        let mut server = None;
        let addr = match &ext_addr {
            Some(a) => a.clone(),
            None => {
                let s = NetServer::bind(
                    "127.0.0.1:0",
                    ServeConfig {
                        session: SessionConfig {
                            workers,
                            max_batch,
                            cache_bytes: SERVE_CACHE_BYTES,
                            overlay,
                        },
                        max_in_flight: get(flags, "max-in-flight", 64usize).max(1),
                        tenant_max_in_flight: get(flags, "tenant-in-flight", 16usize).max(1),
                        ..ServeConfig::default()
                    },
                )?;
                let a = s.local_addr().to_string();
                server = Some(s);
                a
            }
        };
        let per_client = requests.div_ceil(clients);
        let t0 = Instant::now();
        let joined: Result<Vec<(Vec<f64>, u64)>, BismoError> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    let addr = addr.clone();
                    let acts = &acts;
                    let weights = &weights;
                    scope.spawn(move || -> Result<(Vec<f64>, u64), BismoError> {
                        let mut cli = NetClient::connect(addr.as_str(), &format!("bench-{c}"))?;
                        let mut lat = Vec::with_capacity(per_client);
                        let mut shed = 0u64;
                        for i in 0..per_client {
                            let a = &acts[(c + i * clients) % acts.len()];
                            let w = &weights[i % weights.len()];
                            let t = Instant::now();
                            match cli.matmul(a, w, prec, backend, false) {
                                Ok(r) => {
                                    lat.push(t.elapsed().as_nanos() as f64);
                                    // One correctness gate per client:
                                    // the wire path must be bit-exact.
                                    if i == 0 && r.result != a.matmul(w) {
                                        return Err(BismoError::VerifyFailed(format!(
                                            "remote client {c}: result != reference"
                                        )));
                                    }
                                }
                                Err(BismoError::Overloaded { retry_after_ms }) => {
                                    shed += 1;
                                    std::thread::sleep(Duration::from_millis(
                                        retry_after_ms.min(20),
                                    ));
                                }
                                Err(e) => return Err(e),
                            }
                        }
                        Ok((lat, shed))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("remote client thread panicked"))
                .collect()
        });
        let per_client_results = joined?;
        let wall_s = t0.elapsed().as_secs_f64();
        let mut lat: Vec<f64> = Vec::new();
        let mut shed = 0u64;
        for (l, s) in per_client_results {
            lat.extend(l);
            shed += s;
        }
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let completed = lat.len();
        let attempts = completed as u64 + shed;
        let samples = Samples { ns: lat };
        let (server_served, server_shed) = match &mut server {
            Some(s) => {
                let pair = (s.served_total(), s.shed_total());
                s.shutdown();
                (Json::num(pair.0 as f64), Json::num(pair.1 as f64))
            }
            None => (Json::Null, Json::Null),
        };

        let mut remote = BTreeMap::new();
        remote.insert("clients".to_string(), Json::num(clients as f64));
        remote.insert(
            "addr_kind".to_string(),
            Json::str(if ext_addr.is_some() {
                "external"
            } else {
                "self-hosted"
            }),
        );
        remote.insert("attempts".to_string(), Json::num(attempts as f64));
        remote.insert("completed".to_string(), Json::num(completed as f64));
        remote.insert("shed".to_string(), Json::num(shed as f64));
        remote.insert(
            "shed_rate".to_string(),
            Json::num(if attempts == 0 {
                0.0
            } else {
                shed as f64 / attempts as f64
            }),
        );
        // An all-shed run has no latency distribution; report zeros
        // rather than panicking on an empty percentile.
        let q = |p: f64| {
            if samples.ns.is_empty() {
                0.0
            } else {
                samples.percentile(p)
            }
        };
        let mut l = BTreeMap::new();
        l.insert("p50".to_string(), Json::num(q(50.0)));
        l.insert("p95".to_string(), Json::num(q(95.0)));
        l.insert("p99".to_string(), Json::num(q(99.0)));
        l.insert("max".to_string(), Json::num(q(100.0)));
        l.insert(
            "mean".to_string(),
            Json::num(if samples.ns.is_empty() {
                0.0
            } else {
                samples.mean()
            }),
        );
        remote.insert("latency_ns".to_string(), Json::Obj(l));
        remote.insert(
            "throughput_rps".to_string(),
            Json::num(completed as f64 / wall_s),
        );
        remote.insert("server_served_total".to_string(), server_served);
        remote.insert("server_shed_total".to_string(), server_shed);
        println!(
            "remote phase: {clients} clients, {completed}/{attempts} completed, {shed} shed, \
             p50 {:.0} µs  p99 {:.0} µs",
            q(50.0) / 1e3,
            q(99.0) / 1e3,
        );
        Some(Json::Obj(remote))
    } else {
        None
    };

    let repack_avoided_ns = off.pack_ns.saturating_sub(on.pack_ns);
    let pack_speedup = if on.pack_ns == 0 {
        0.0
    } else {
        off.pack_ns as f64 / on.pack_ns as f64
    };
    let throughput = requests as f64 / on.wall_s;

    let lat_json = |s: &Samples| {
        let mut o = BTreeMap::new();
        o.insert("p50".to_string(), Json::num(s.percentile(50.0)));
        o.insert("p90".to_string(), Json::num(s.percentile(90.0)));
        o.insert("p99".to_string(), Json::num(s.percentile(99.0)));
        o.insert("max".to_string(), Json::num(s.max()));
        o.insert("mean".to_string(), Json::num(s.mean()));
        o
    };

    let mut workload = BTreeMap::new();
    workload.insert("requests".to_string(), Json::num(requests as f64));
    workload.insert("layers".to_string(), Json::num(layers as f64));
    workload.insert("m".to_string(), Json::num(m as f64));
    workload.insert("k".to_string(), Json::num(k as f64));
    workload.insert("n".to_string(), Json::num(n as f64));
    workload.insert("wbits".to_string(), Json::num(wbits as f64));
    workload.insert("abits".to_string(), Json::num(abits as f64));
    workload.insert("rate_rps".to_string(), Json::num(rate));
    workload.insert("seed".to_string(), Json::num(seed as f64));

    let mut service = BTreeMap::new();
    service.insert("workers".to_string(), Json::num(workers as f64));
    service.insert("max_batch".to_string(), Json::num(max_batch as f64));
    service.insert(
        "cache_capacity_bytes".to_string(),
        Json::num(SERVE_CACHE_BYTES as f64),
    );

    let mut cache = BTreeMap::new();
    cache.insert("hits".to_string(), Json::num(on.cache.hits as f64));
    cache.insert("misses".to_string(), Json::num(on.cache.misses as f64));
    cache.insert("hit_rate".to_string(), Json::num(on.cache.hit_rate()));
    cache.insert("evictions".to_string(), Json::num(on.cache.evictions as f64));
    cache.insert("entries".to_string(), Json::num(on.cache_entries as f64));
    cache.insert(
        "resident_bytes".to_string(),
        Json::num(on.cache_resident_bytes as f64),
    );
    cache.insert(
        "rhs_hit_requests".to_string(),
        Json::num(on.rhs_hits as f64),
    );

    let mut pack = BTreeMap::new();
    pack.insert("cache_on_total_ns".to_string(), Json::num(on.pack_ns as f64));
    pack.insert(
        "cache_off_total_ns".to_string(),
        Json::num(off.pack_ns as f64),
    );
    pack.insert(
        "avoided_ns".to_string(),
        Json::num(repack_avoided_ns as f64),
    );
    pack.insert(
        "avoided_ns_per_request".to_string(),
        Json::num(repack_avoided_ns as f64 / requests as f64),
    );
    pack.insert("speedup".to_string(), Json::num(pack_speedup));

    let mut per_request = BTreeMap::new();
    per_request.insert(
        "queue_ns_mean".to_string(),
        Json::num(on.queue_ns as f64 / requests as f64),
    );
    per_request.insert(
        "pack_ns_mean".to_string(),
        Json::num(on.pack_ns as f64 / requests as f64),
    );
    per_request.insert(
        "exec_ns_mean".to_string(),
        Json::num(on.exec_ns as f64 / requests as f64),
    );

    let mut cache_off = BTreeMap::new();
    cache_off.insert("latency_ns".to_string(), Json::Obj(lat_json(&off.lat)));
    cache_off.insert(
        "throughput_rps".to_string(),
        Json::num(requests as f64 / off.wall_s),
    );

    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), Json::str("bismo-bench-serve/v1"));
    root.insert(
        "mode".to_string(),
        Json::str(if quick { "quick" } else { "full" }),
    );
    root.insert("backend".to_string(), Json::str(backend.name()));
    root.insert(
        "simd_tier".to_string(),
        Json::str(bismo::simd::DispatchTier::active().name()),
    );
    root.insert(
        "generated_unix".to_string(),
        Json::num(
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs() as f64)
                .unwrap_or(0.0),
        ),
    );
    root.insert("workload".to_string(), Json::Obj(workload));
    root.insert("service".to_string(), Json::Obj(service));
    root.insert("latency_ns".to_string(), Json::Obj(lat_json(&on.lat)));
    root.insert("throughput_rps".to_string(), Json::num(throughput));
    root.insert("cache".to_string(), Json::Obj(cache));
    root.insert("pack".to_string(), Json::Obj(pack));
    root.insert("per_request".to_string(), Json::Obj(per_request));
    root.insert("cache_off".to_string(), Json::Obj(cache_off));
    if let Some(remote) = remote_json {
        root.insert("remote".to_string(), remote);
    }
    let doc = Json::Obj(root);
    std::fs::write(&out_path, doc.pretty(2) + "\n")
        .map_err(|e| BismoError::Io(format!("writing {out_path}: {e}")))?;

    println!(
        "wrote {out_path}: p50 {:.0} µs  p99 {:.0} µs  throughput {:.0} req/s",
        on.lat.percentile(50.0) / 1e3,
        on.lat.percentile(99.0) / 1e3,
        throughput
    );
    println!(
        "packing cache: {} hits / {} misses (hit rate {:.0}%), repack avoided {:.1} µs/request \
         ({:.2}x less packing than cache-off)",
        on.cache.hits,
        on.cache.misses,
        on.cache.hit_rate() * 100.0,
        repack_avoided_ns as f64 / requests as f64 / 1e3,
        pack_speedup
    );
    Ok(())
}

/// `bismo serve`: host the TCP front door.
///
/// Prints the bound address (port 0 picks an ephemeral one — the line
/// is machine-parseable for harnesses), serves until stdin reaches
/// EOF, then drains gracefully: in-flight requests finish, new ones
/// are refused, every thread is joined.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::net::{NetServer, ServeConfig};

    let host = flags
        .get("host")
        .filter(|v| !v.is_empty())
        .cloned()
        .unwrap_or_else(|| "127.0.0.1".to_string());
    let port: u16 = get(flags, "port", 7410u16);
    let default_threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    let workers = get(flags, "workers", default_threads)
        .max(1)
        .min(bismo::kernel::WorkerPool::global().lanes());
    let defaults = ServeConfig::default();
    let weight_mb = get(flags, "tenant-weight-mb", defaults.tenant_max_weight_bytes >> 20);
    let cfg = ServeConfig {
        session: SessionConfig {
            workers,
            max_batch: get(flags, "batch", 16usize).max(1),
            cache_bytes: get(flags, "cache-mb", 256usize) << 20,
            overlay: config_from(flags)?,
        },
        max_in_flight: get(flags, "max-in-flight", defaults.max_in_flight),
        tenant_max_in_flight: get(flags, "tenant-in-flight", defaults.tenant_max_in_flight),
        tenant_max_weight_bytes: weight_mb << 20,
    };
    let mut server = NetServer::bind(&format!("{host}:{port}"), cfg)?;
    println!("bismo serve: listening on {}", server.local_addr());
    println!(
        "bismo serve: {} workers, {} global / {} per-tenant in flight; close stdin to drain",
        workers, cfg.max_in_flight, cfg.tenant_max_in_flight
    );
    // The serving work all happens on the server's own threads; this
    // thread just waits for the operator (or harness) to close stdin.
    let mut sink = String::new();
    loop {
        sink.clear();
        match std::io::stdin().read_line(&mut sink) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
    }
    server.shutdown();
    println!(
        "bismo serve: drained ({} served, {} shed)",
        server.served_total(),
        server.shed_total()
    );
    Ok(())
}

/// `bismo shard-bench`: the multi-instance scaling sweep.
///
/// One fixed GEMM workload is executed through the session facade at
/// shard counts 1, 2, 4, ... (`--max-shards`), i.e. split across that
/// many concurrent overlay instances by the partition layer and merged
/// bit-exactly. Per-request latency is measured over `--reps`
/// repetitions (operands stay cached, so the sweep isolates execution
/// scaling from packing). The cost model's `Sharding::Auto`
/// selection under `--budget-luts`/`--budget-brams` (default: 2× the
/// PYNQ-Z1 fabric) is reported alongside. Results go to
/// `BENCH_shard.json` (schema in the README).
fn cmd_shard_bench(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::baseline::binary_ops;
    use bismo::costmodel::{select_sharding, CostModel, ResourceBudget};
    use bismo::partition::{GemmShape, ShardPlan};
    use bismo::util::bench::Samples;
    use bismo::util::Json;
    use std::collections::BTreeMap;
    use std::sync::Arc;
    use std::time::Instant;

    let quick = flags.contains_key("quick");
    let m = get(flags, "m", if quick { 128usize } else { 256 });
    let k = get(flags, "k", 1024usize);
    let n = get(flags, "n", if quick { 128usize } else { 256 });
    let wbits = get(flags, "wbits", 2u32);
    let abits = get(flags, "abits", 2u32);
    let reps = get(flags, "reps", if quick { 3usize } else { 7 }).max(1);
    let max_shards = get(flags, "max-shards", if quick { 4usize } else { 8 }).max(1);
    let budget = ResourceBudget {
        luts: get(flags, "budget-luts", PYNQ_Z1.luts * 2),
        brams: get(flags, "budget-brams", PYNQ_Z1.brams * 2),
    };
    let backend = match flags.get("backend").map(|s| s.as_str()) {
        None | Some("engine") => Backend::Engine,
        Some("sim") => Backend::Sim,
        Some(other) => {
            return Err(BismoError::Parse(format!(
                "unknown --backend {other} (engine|sim)"
            )))
        }
    };
    let out_path = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_shard.json".to_string());

    let session = Session::new(SessionConfig {
        overlay: config_from(flags)?,
        ..Default::default()
    })?;
    let mut rng = Rng::new(get(flags, "seed", 0x5AA3Du64));
    let a = Arc::new(IntMatrix::random(&mut rng, m, k, wbits, false));
    let b = Arc::new(IntMatrix::random(&mut rng, k, n, abits, false));
    let expect = a.matmul(&b);
    let prec = Precision::unsigned(wbits, abits);
    let ops = binary_ops(m as u64, k as u64, n as u64, wbits, abits) as f64;

    let mut counts: Vec<usize> = std::iter::successors(Some(1usize), |s| Some(s * 2))
        .take_while(|&s| s <= max_shards)
        .collect();
    if counts.last() != Some(&max_shards) {
        counts.push(max_shards);
    }

    println!(
        "shard-bench: {m}x{k}x{n} w{wbits}a{abits}, {} backend, {} reps per shard count",
        backend.name(),
        reps
    );
    let mut entries = Vec::new();
    let mut single_ns = 0.0f64;
    let mut best = (1usize, 1.0f64);
    for &shards in &counts {
        let builder = session
            .matmul(prec)
            .backend(backend)
            .instances(shards)
            // Both operands stay resident so every rep measures
            // execution, not packing.
            .cache_lhs(true)
            .cache_rhs(true);
        // Warm-up rep doubles as the bit-exactness gate.
        let resp = builder.run(a.clone(), b.clone())?;
        if resp.result != expect {
            return Err(BismoError::VerifyFailed(format!(
                "sharded result mismatch at {shards} shard(s)"
            )));
        }
        // Same resolution the service used; the cross-check below turns
        // any future drift into a loud failure instead of a benchmark
        // artifact that misreports the grid it timed.
        let grid = ShardPlan::for_instances(m, n, shards);
        if resp.shards != grid.count() {
            return Err(BismoError::VerifyFailed(format!(
                "service executed {} shard(s), CLI derived {}",
                resp.shards,
                grid.count()
            )));
        }
        let mut lat = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            let r = builder.run(a.clone(), b.clone())?;
            lat.push(t0.elapsed().as_nanos() as f64);
            if r.result != expect {
                return Err(BismoError::VerifyFailed(format!(
                    "sharded result mismatch at {shards} shard(s)"
                )));
            }
        }
        lat.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let samples = Samples { ns: lat };
        let median = samples.median();
        if shards == 1 {
            single_ns = median;
        }
        let speedup = if median > 0.0 { single_ns / median } else { 0.0 };
        if speedup > best.1 {
            best = (shards, speedup);
        }
        println!(
            "  {:>2} shard(s) [{}x{} grid]: median {:>9.0} ns  {:>7.2} GOPS  speedup {:.2}x",
            resp.shards,
            grid.rows.count(),
            grid.cols.count(),
            median,
            ops / median,
            speedup
        );
        let mut e = BTreeMap::new();
        e.insert("shards".to_string(), Json::num(resp.shards as f64));
        e.insert("grid_rows".to_string(), Json::num(grid.rows.count() as f64));
        e.insert("grid_cols".to_string(), Json::num(grid.cols.count() as f64));
        e.insert("median_ns".to_string(), Json::num(median));
        e.insert("mean_ns".to_string(), Json::num(samples.mean()));
        e.insert("gops".to_string(), Json::num(ops / median));
        e.insert("speedup_vs_single".to_string(), Json::num(speedup));
        entries.push(Json::Obj(e));
    }

    // The cost model's own pick for this workload under the budget.
    let shape = GemmShape { m, k, n };
    let auto = select_sharding(&CostModel::paper(), &shape, budget)?;
    println!(
        "auto under budget ({} LUTs, {} BRAMs): {} instance(s) of Dm={} Dk={} Dn={} \
         ({:.0} LUTs, {} BRAMs total, {:.0} peak GOPS)",
        budget.luts,
        budget.brams,
        auto.shards,
        auto.config.dm,
        auto.config.dk,
        auto.config.dn,
        auto.total_luts,
        auto.total_brams,
        auto.peak_gops
    );

    let mut workload = BTreeMap::new();
    workload.insert("m".to_string(), Json::num(m as f64));
    workload.insert("k".to_string(), Json::num(k as f64));
    workload.insert("n".to_string(), Json::num(n as f64));
    workload.insert("wbits".to_string(), Json::num(wbits as f64));
    workload.insert("abits".to_string(), Json::num(abits as f64));
    workload.insert("binary_ops".to_string(), Json::num(ops));
    workload.insert("reps".to_string(), Json::num(reps as f64));

    let mut auto_j = BTreeMap::new();
    auto_j.insert("budget_luts".to_string(), Json::num(budget.luts as f64));
    auto_j.insert("budget_brams".to_string(), Json::num(budget.brams as f64));
    auto_j.insert("shards".to_string(), Json::num(auto.shards as f64));
    auto_j.insert("grid_rows".to_string(), Json::num(auto.grid.0 as f64));
    auto_j.insert("grid_cols".to_string(), Json::num(auto.grid.1 as f64));
    auto_j.insert("dm".to_string(), Json::num(auto.config.dm as f64));
    auto_j.insert("dk".to_string(), Json::num(auto.config.dk as f64));
    auto_j.insert("dn".to_string(), Json::num(auto.config.dn as f64));
    auto_j.insert("total_luts".to_string(), Json::num(auto.total_luts));
    auto_j.insert("total_brams".to_string(), Json::num(auto.total_brams as f64));
    auto_j.insert("peak_gops".to_string(), Json::num(auto.peak_gops));

    let mut headline = BTreeMap::new();
    headline.insert("best_shards".to_string(), Json::num(best.0 as f64));
    headline.insert("best_speedup".to_string(), Json::num(best.1));

    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), Json::str("bismo-bench-shard/v1"));
    root.insert(
        "mode".to_string(),
        Json::str(if quick { "quick" } else { "full" }),
    );
    root.insert("backend".to_string(), Json::str(backend.name()));
    root.insert(
        "simd_tier".to_string(),
        Json::str(bismo::simd::DispatchTier::active().name()),
    );
    root.insert(
        "generated_unix".to_string(),
        Json::num(
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs() as f64)
                .unwrap_or(0.0),
        ),
    );
    root.insert("workload".to_string(), Json::Obj(workload));
    root.insert("entries".to_string(), Json::Arr(entries));
    root.insert("headline".to_string(), Json::Obj(headline));
    root.insert("auto".to_string(), Json::Obj(auto_j));
    let doc = Json::Obj(root);
    std::fs::write(&out_path, doc.pretty(2) + "\n")
        .map_err(|e| BismoError::Io(format!("writing {out_path}: {e}")))?;
    println!(
        "wrote {out_path}: best speedup {:.2}x at {} shard(s)",
        best.1, best.0
    );
    Ok(())
}

/// `bismo cnn-bench`: end-to-end quantized-CNN serving benchmark.
///
/// The 28×28 [`QnnCnn`](bismo::qnn::QnnCnn) preset (conv–pool–conv–
/// pool–dense, per-layer precisions w3/w2/w3 at 2-bit activations) is
/// prepared once per lowering mode and served through a
/// [`Session`]: the engine backend measures end-to-end wall-clock
/// throughput over `--reps` repetitions, the sim backend reports
/// per-layer cycle counts. Every timed inference is gated bit-exact
/// against the direct-convolution reference first. Results go to
/// `BENCH_cnn.json` (schema in the README).
fn cmd_cnn_bench(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::baseline::binary_ops;
    use bismo::lowering::{LoweringMode, Tensor};
    use bismo::qnn::QnnCnn;
    use bismo::util::bench::Samples;
    use bismo::util::Json;
    use std::collections::BTreeMap;
    use std::time::Instant;

    let quick = flags.contains_key("quick");
    let batch = get(flags, "batch", if quick { 2usize } else { 8 }).max(1);
    let reps = get(flags, "reps", if quick { 2usize } else { 5 }).max(1);
    let seed = get(flags, "seed", 0xC2215u64);
    let out_path = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_cnn.json".to_string());
    let overlay = config_from(flags)?;
    let session = Session::new(SessionConfig {
        overlay,
        ..Default::default()
    })?;
    let cnn = QnnCnn::digits(seed);
    let mut rng = Rng::new(seed.wrapping_add(1));
    let spec1 = cnn.conv1.spec;
    let x = Tensor::random(&mut rng, batch, spec1.in_h, spec1.in_w, 1, cnn.abits, false);
    let want = cnn.forward_reference(&x);

    // Static per-layer facts (identical across lowering modes: kn2row
    // splits k across taps, the total work is the same).
    struct Layer {
        name: &'static str,
        m: usize,
        k: usize,
        n: usize,
        wbits: u32,
        abits: u32,
    }
    let shape1 = spec1.gemm_shape(batch);
    let shape2 = cnn.conv2.spec.gemm_shape(batch);
    let layers = [
        Layer {
            name: "conv1",
            m: shape1.m,
            k: shape1.k,
            n: shape1.n,
            wbits: cnn.conv1.prec.wbits,
            abits: cnn.conv1.prec.abits,
        },
        Layer {
            name: "conv2",
            m: shape2.m,
            k: shape2.k,
            n: shape2.n,
            wbits: cnn.conv2.prec.wbits,
            abits: cnn.conv2.prec.abits,
        },
        Layer {
            name: "fc",
            m: batch,
            k: cnn.fc.rows,
            n: cnn.fc.cols,
            wbits: cnn.fc_prec.wbits,
            abits: cnn.fc_prec.abits,
        },
    ];

    println!(
        "cnn-bench: 28x28 QnnCnn preset, batch {batch}, {reps} reps per lowering mode \
         (engine throughput + sim cycles)"
    );
    let mut layers_json = Vec::new();
    let mut modes_json = BTreeMap::new();
    let mut headline_rate = 0.0f64;
    for mode in [LoweringMode::Im2col, LoweringMode::Kn2row] {
        // Engine: bit-exactness gate, per-layer exec attribution, then
        // end-to-end timing.
        let served = cnn.serve(&session, mode, Backend::Engine)?;
        let (logits, gemms) = served.infer(&x)?;
        if logits != want {
            return Err(BismoError::VerifyFailed(format!(
                "served CNN logits != direct-conv reference ({} engine)",
                mode.name()
            )));
        }
        // gemms order: conv1 taps, conv2 taps, fc — tap counts derived
        // per layer from its own kernel, so the attribution stays right
        // if the preset's kernel sizes ever diverge.
        let tap_count = |spec: &bismo::lowering::ConvSpec| match mode {
            LoweringMode::Im2col => 1,
            LoweringMode::Kn2row => spec.kh * spec.kw,
        };
        let (taps1, taps2) = (tap_count(&spec1), tap_count(&cnn.conv2.spec));
        let split = [0, taps1, taps1 + taps2, taps1 + taps2 + 1];
        let engine_ns: Vec<u64> = (0..3)
            .map(|li| gemms[split[li]..split[li + 1]].iter().map(|g| g.exec_ns).sum())
            .collect();
        let mut lat = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            let (l, _) = served.infer(&x)?;
            lat.push(t0.elapsed().as_nanos() as f64);
            if l != want {
                return Err(BismoError::VerifyFailed(format!(
                    "served CNN logits drifted during timing ({})",
                    mode.name()
                )));
            }
        }
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let samples = Samples { ns: lat };
        let median_ns = samples.median();
        let rate = batch as f64 / (median_ns / 1e9);
        if mode == LoweringMode::Im2col {
            headline_rate = rate;
        }

        // Sim: per-layer cycle counts (and the same exactness gate).
        let sim_served = cnn.serve(&session, mode, Backend::Sim)?;
        let (sim_logits, sim_gemms) = sim_served.infer(&x)?;
        if sim_logits != want {
            return Err(BismoError::VerifyFailed(format!(
                "served CNN logits != direct-conv reference ({} sim)",
                mode.name()
            )));
        }
        let sim_cycles: Vec<u64> = (0..3)
            .map(|li| {
                sim_gemms[split[li]..split[li + 1]]
                    .iter()
                    .filter_map(|g| g.report.as_ref().map(|r| r.cycles))
                    .sum()
            })
            .collect();
        let total_cycles: u64 = sim_cycles.iter().sum();

        for (li, layer) in layers.iter().enumerate() {
            let lowering = if layer.name == "fc" { "dense" } else { mode.name() };
            if layer.name == "fc" && mode == LoweringMode::Kn2row {
                continue; // the dense head is identical across modes
            }
            let ops = binary_ops(
                layer.m as u64,
                layer.k as u64,
                layer.n as u64,
                layer.wbits,
                layer.abits,
            ) as f64;
            println!(
                "  {:<6} [{}] {}x{}x{} w{}a{}: {} GEMM(s), engine {:>9} ns, sim {:>9} cycles",
                layer.name,
                lowering,
                layer.m,
                layer.k,
                layer.n,
                layer.abits,
                layer.wbits,
                split[li + 1] - split[li],
                engine_ns[li],
                sim_cycles[li]
            );
            let mut jl = BTreeMap::new();
            jl.insert("name".to_string(), Json::str(layer.name));
            jl.insert("lowering".to_string(), Json::str(lowering));
            jl.insert("m".to_string(), Json::num(layer.m as f64));
            jl.insert("k".to_string(), Json::num(layer.k as f64));
            jl.insert("n".to_string(), Json::num(layer.n as f64));
            // Explicit role names: the crate-internal Precision struct
            // calls the LHS width `wbits`, which for a QNN layer is the
            // *activation* side — emitting role names avoids the
            // w-means-weights ambiguity in the workload shorthand.
            jl.insert(
                "activation_bits".to_string(),
                Json::num(layer.wbits as f64),
            );
            jl.insert("weight_bits".to_string(), Json::num(layer.abits as f64));
            jl.insert(
                "gemms".to_string(),
                Json::num((split[li + 1] - split[li]) as f64),
            );
            jl.insert("binary_ops".to_string(), Json::num(ops));
            jl.insert("engine_exec_ns".to_string(), Json::num(engine_ns[li] as f64));
            jl.insert(
                "engine_gops".to_string(),
                Json::num(ops / (engine_ns[li].max(1) as f64)),
            );
            jl.insert("sim_cycles".to_string(), Json::num(sim_cycles[li] as f64));
            layers_json.push(Json::Obj(jl));
        }

        let sim_s_per_batch = total_cycles as f64 / (overlay.fclk_mhz as f64 * 1e6);
        println!(
            "  {} end to end: median {:.2} ms/batch on the engine ({:.0} inf/s), \
             {} sim cycles ({:.2} ms at {} MHz)",
            mode.name(),
            median_ns / 1e6,
            rate,
            total_cycles,
            sim_s_per_batch * 1e3,
            overlay.fclk_mhz
        );
        let mut jm = BTreeMap::new();
        jm.insert("engine_median_ns".to_string(), Json::num(median_ns));
        jm.insert("engine_mean_ns".to_string(), Json::num(samples.mean()));
        jm.insert("inferences_per_s".to_string(), Json::num(rate));
        jm.insert("sim_total_cycles".to_string(), Json::num(total_cycles as f64));
        jm.insert(
            "sim_ms_per_batch".to_string(),
            Json::num(sim_s_per_batch * 1e3),
        );
        modes_json.insert(mode.name().to_string(), Json::Obj(jm));
    }

    let cs = session.cache_stats();
    let mut cache = BTreeMap::new();
    cache.insert("hits".to_string(), Json::num(cs.hits as f64));
    cache.insert("misses".to_string(), Json::num(cs.misses as f64));
    cache.insert("hit_rate".to_string(), Json::num(cs.hit_rate()));

    let mut headline = BTreeMap::new();
    headline.insert("lowering".to_string(), Json::str("im2col"));
    headline.insert("inferences_per_s".to_string(), Json::num(headline_rate));

    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), Json::str("bismo-bench-cnn/v1"));
    root.insert(
        "mode".to_string(),
        Json::str(if quick { "quick" } else { "full" }),
    );
    root.insert("batch".to_string(), Json::num(batch as f64));
    root.insert("reps".to_string(), Json::num(reps as f64));
    root.insert("seed".to_string(), Json::num(seed as f64));
    root.insert(
        "simd_tier".to_string(),
        Json::str(bismo::simd::DispatchTier::active().name()),
    );
    root.insert(
        "generated_unix".to_string(),
        Json::num(
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs() as f64)
                .unwrap_or(0.0),
        ),
    );
    root.insert("layers".to_string(), Json::Arr(layers_json));
    root.insert("end_to_end".to_string(), Json::Obj(modes_json));
    root.insert("cache".to_string(), Json::Obj(cache));
    root.insert("headline".to_string(), Json::Obj(headline));
    let doc = Json::Obj(root);
    std::fs::write(&out_path, doc.pretty(2) + "\n")
        .map_err(|e| BismoError::Io(format!("writing {out_path}: {e}")))?;
    println!(
        "wrote {out_path}: headline {:.0} inferences/s (im2col, engine backend)",
        headline_rate
    );
    Ok(())
}

/// `bismo attn-bench`: quantized transformer encoder block serving
/// benchmark, static vs input-adaptive precision.
///
/// The [`QnnAttn::demo`](bismo::qnn::QnnAttn::demo) preset (32-wide
/// model, 4 heads, 48-wide FFN, 3-bit activations, per-matrix weight
/// precisions) is prepared once and served a request mix whose
/// activation dynamic range cycles over 1..=abits populated bits —
/// the headroom an input-adaptive policy converts into fewer bit
/// planes. Every arm is measured on the engine backend
/// (tokens/second) and the static and range-adaptive arms are gated
/// bit-exact against the pure-i64 reference oracle on *both*
/// backends; the sim backend additionally reports the deterministic
/// cycle reduction. Results go to `BENCH_attn.json` (schema in the
/// README).
fn cmd_attn_bench(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::api::AttnResponse;
    use bismo::qnn::{
        ClampPolicy, EntropyAdaptivePolicy, PrecisionPolicy, QnnAttn, RangeAdaptivePolicy,
    };
    use bismo::util::bench::Samples;
    use bismo::util::Json;
    use std::collections::BTreeMap;
    use std::time::Instant;

    let quick = flags.contains_key("quick");
    let seq = get(flags, "seq", if quick { 8usize } else { 16 }).max(1);
    let requests = get(flags, "requests", if quick { 4usize } else { 12 }).max(1);
    let reps = get(flags, "reps", if quick { 2usize } else { 5 }).max(1);
    let seed = get(flags, "seed", 0xA77Bu64);
    let out_path = flags
        .get("out")
        .cloned()
        .unwrap_or_else(|| "BENCH_attn.json".to_string());
    let overlay = config_from(flags)?;
    let session = Session::new(SessionConfig {
        overlay,
        ..Default::default()
    })?;
    let model = QnnAttn::demo(seed, seq);
    let prepared = session.attn(&model).backend(Backend::Engine).prepare()?;

    // The request mix: per-request activation dynamic range cycles
    // over 1..=abits populated bits, so some requests only use a
    // subset of the calibrated bit planes.
    let mut rng = Rng::new(seed ^ 1);
    let inputs: Vec<IntMatrix> = (0..requests)
        .map(|i| model.random_input(&mut rng, seq, (i as u32 % model.abits) + 1))
        .collect();
    let refs: Vec<IntMatrix> = inputs
        .iter()
        .map(|x| model.forward_reference(x))
        .collect::<Result<_, _>>()?;
    let tokens = (requests * seq) as f64;

    println!(
        "attn-bench: QnnAttn demo preset (d_model {}, {} heads, d_ff {}), seq {seq}, \
         {requests} requests x {reps} reps per arm",
        model.spec.d_model, model.spec.heads, model.spec.d_ff
    );

    // Per-layer GEMM shape table (identical across arms).
    let mut layers_json = Vec::new();
    for l in model.layer_shapes(seq) {
        println!(
            "  {:<7} {} GEMM(s) {}x{}x{} a{}w{}",
            l.name, l.gemms, l.m, l.k, l.n, l.activation_bits, l.weight_bits
        );
        let mut jl = BTreeMap::new();
        jl.insert("name".to_string(), Json::str(l.name));
        jl.insert("gemms".to_string(), Json::num(l.gemms as f64));
        jl.insert("m".to_string(), Json::num(l.m as f64));
        jl.insert("k".to_string(), Json::num(l.k as f64));
        jl.insert("n".to_string(), Json::num(l.n as f64));
        jl.insert(
            "activation_bits".to_string(),
            Json::num(l.activation_bits as f64),
        );
        jl.insert("weight_bits".to_string(), Json::num(l.weight_bits as f64));
        layers_json.push(Json::Obj(jl));
    }

    // Simulator: the same bit-exactness gate, plus the deterministic
    // cycle count — the machine-independent proof that the adaptive
    // policy sheds real bit-plane work.
    let sim_prepared = session.attn(&model).backend(Backend::Sim).prepare()?;
    let range_policy = RangeAdaptivePolicy::default();
    let cycles_of = |r: &AttnResponse, what: &str| -> Result<u64, BismoError> {
        r.sim_cycles().ok_or_else(|| {
            BismoError::VerifyFailed(format!("{what}: sim pass missing cycle reports"))
        })
    };
    let mut static_cycles = 0u64;
    let mut adaptive_cycles = 0u64;
    for (i, x) in inputs.iter().enumerate() {
        let s = sim_prepared.execute(x)?;
        if s.output != refs[i] {
            return Err(BismoError::VerifyFailed(format!(
                "served attention output != i64 reference (sim static, request {i})"
            )));
        }
        static_cycles += cycles_of(&s, "sim static")?;
        let a = sim_prepared.execute_with_policy(x, &range_policy)?;
        if a.output != refs[i] {
            return Err(BismoError::VerifyFailed(format!(
                "served attention output != i64 reference (sim adaptive, request {i})"
            )));
        }
        adaptive_cycles += cycles_of(&a, "sim adaptive")?;
    }
    let cycle_ratio = static_cycles as f64 / adaptive_cycles.max(1) as f64;
    println!(
        "  sim: static {static_cycles} cycles, adaptive {adaptive_cycles} cycles \
         ({cycle_ratio:.2}x fewer under the range policy, bit-exact)"
    );

    // The measured arms: static full precision, a lossy static clamp
    // (accuracy contrast), and the two adaptive policies. `exact`
    // arms are gated bit-identical to the oracle.
    struct Arm {
        name: &'static str,
        policy: Option<Box<dyn PrecisionPolicy>>,
        exact: bool,
    }
    let arms: Vec<Arm> = vec![
        Arm {
            name: "static_full",
            policy: None,
            exact: true,
        },
        Arm {
            name: "static_low",
            policy: Some(Box::new(ClampPolicy { bits: 2 })),
            exact: false,
        },
        Arm {
            name: "adaptive",
            policy: Some(Box::new(RangeAdaptivePolicy::default())),
            exact: true,
        },
        Arm {
            name: "adaptive_entropy",
            policy: Some(Box::new(EntropyAdaptivePolicy::default())),
            exact: false,
        },
    ];

    let run_one = |arm: &Arm, x: &IntMatrix| -> Result<AttnResponse, BismoError> {
        match &arm.policy {
            None => prepared.execute(x),
            Some(p) => prepared.execute_with_policy(x, p.as_ref()),
        }
    };
    let mut t = Table::new(
        "attn-bench (engine backend)",
        &["arm", "tokens/s", "accuracy proxy", "mean lhs bits"],
    );
    let mut arms_json = BTreeMap::new();
    let mut rate_of: BTreeMap<&str, f64> = BTreeMap::new();
    let mut adaptive_accuracy = 0.0f64;
    let mut decisions_json = Vec::new();
    for arm in &arms {
        // One untimed pass per request: exactness gate, accuracy
        // proxy, effective precision, decision log.
        let outs: Vec<AttnResponse> = inputs
            .iter()
            .map(|x| run_one(arm, x))
            .collect::<Result<_, _>>()?;
        for (i, o) in outs.iter().enumerate() {
            if arm.exact && o.output != refs[i] {
                return Err(BismoError::VerifyFailed(format!(
                    "served attention output != i64 reference (engine {}, request {i})",
                    arm.name
                )));
            }
        }
        // Accuracy proxy: fraction of output elements identical to
        // the full-precision reference (1.0 = bit-exact).
        let (mut same, mut total) = (0usize, 0usize);
        for (o, want) in outs.iter().zip(&refs) {
            total += want.data().len();
            same += o
                .output
                .data()
                .iter()
                .zip(want.data())
                .filter(|(a, b)| a == b)
                .count();
        }
        let accuracy = same as f64 / total.max(1) as f64;
        let mean_bits =
            outs.iter().map(AttnResponse::mean_lhs_bits).sum::<f64>() / outs.len() as f64;
        if arm.name == "adaptive" {
            adaptive_accuracy = accuracy;
            for d in &outs[0].decisions {
                let mut jd = BTreeMap::new();
                jd.insert("layer".to_string(), Json::str(d.layer));
                jd.insert("side".to_string(), Json::str(d.side));
                jd.insert("base_bits".to_string(), Json::num(d.base_bits as f64));
                jd.insert("chosen_bits".to_string(), Json::num(d.chosen_bits as f64));
                jd.insert("clip".to_string(), Json::Bool(d.clip));
                jd.insert("reason".to_string(), Json::str(&d.reason));
                decisions_json.push(Json::Obj(jd));
            }
        }

        // Timed passes over the whole request mix.
        let mut lat = Vec::with_capacity(reps);
        for _ in 0..reps {
            let t0 = Instant::now();
            for x in &inputs {
                run_one(arm, x)?;
            }
            lat.push(t0.elapsed().as_nanos() as f64);
        }
        lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let samples = Samples { ns: lat };
        let rate = tokens / (samples.median() / 1e9);
        rate_of.insert(arm.name, rate);
        t.rowf(&[&arm.name, &f(rate, 0), &f(accuracy, 4), &f(mean_bits, 2)]);

        let mut ja = BTreeMap::new();
        ja.insert(
            "policy".to_string(),
            Json::str(arm.policy.as_ref().map_or("none", |p| p.name())),
        );
        ja.insert("tokens_per_s".to_string(), Json::num(rate));
        ja.insert("median_ns".to_string(), Json::num(samples.median()));
        ja.insert("mean_ns".to_string(), Json::num(samples.mean()));
        ja.insert("accuracy_proxy".to_string(), Json::num(accuracy));
        ja.insert("mean_lhs_bits".to_string(), Json::num(mean_bits));
        arms_json.insert(arm.name.to_string(), Json::Obj(ja));
    }
    t.print();

    let adaptive_speedup = rate_of["adaptive"] / rate_of["static_full"].max(f64::MIN_POSITIVE);
    println!(
        "  adaptive vs static_full: {adaptive_speedup:.2}x tokens/s at accuracy proxy \
         {adaptive_accuracy:.4} (floor 1.0), sim cycle ratio {cycle_ratio:.2}x"
    );

    let cs = session.cache_stats();
    let mut cache = BTreeMap::new();
    cache.insert("hits".to_string(), Json::num(cs.hits as f64));
    cache.insert("misses".to_string(), Json::num(cs.misses as f64));
    cache.insert("hit_rate".to_string(), Json::num(cs.hit_rate()));

    let mut jmodel = BTreeMap::new();
    jmodel.insert("d_model".to_string(), Json::num(model.spec.d_model as f64));
    jmodel.insert("heads".to_string(), Json::num(model.spec.heads as f64));
    jmodel.insert("d_ff".to_string(), Json::num(model.spec.d_ff as f64));
    jmodel.insert("abits".to_string(), Json::num(model.abits as f64));
    jmodel.insert("max_seq".to_string(), Json::num(model.spec.max_seq as f64));

    let mut sim_j = BTreeMap::new();
    sim_j.insert("static_cycles".to_string(), Json::num(static_cycles as f64));
    sim_j.insert(
        "adaptive_cycles".to_string(),
        Json::num(adaptive_cycles as f64),
    );
    sim_j.insert("cycle_ratio".to_string(), Json::num(cycle_ratio));

    let mut headline = BTreeMap::new();
    headline.insert("adaptive_speedup".to_string(), Json::num(adaptive_speedup));
    headline.insert("sim_cycle_ratio".to_string(), Json::num(cycle_ratio));
    headline.insert(
        "accuracy_proxy".to_string(),
        Json::num(adaptive_accuracy),
    );
    headline.insert("accuracy_floor".to_string(), Json::num(1.0));
    headline.insert("tokens_per_s".to_string(), Json::num(rate_of["adaptive"]));

    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), Json::str("bismo-bench-attn/v1"));
    root.insert(
        "mode".to_string(),
        Json::str(if quick { "quick" } else { "full" }),
    );
    root.insert("seq".to_string(), Json::num(seq as f64));
    root.insert("requests".to_string(), Json::num(requests as f64));
    root.insert("reps".to_string(), Json::num(reps as f64));
    root.insert("seed".to_string(), Json::num(seed as f64));
    root.insert(
        "simd_tier".to_string(),
        Json::str(bismo::simd::DispatchTier::active().name()),
    );
    root.insert(
        "generated_unix".to_string(),
        Json::num(
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_secs() as f64)
                .unwrap_or(0.0),
        ),
    );
    root.insert("model".to_string(), Json::Obj(jmodel));
    root.insert("layers".to_string(), Json::Arr(layers_json));
    root.insert("arms".to_string(), Json::Obj(arms_json));
    root.insert("sim".to_string(), Json::Obj(sim_j));
    root.insert("decisions".to_string(), Json::Arr(decisions_json));
    root.insert("cache".to_string(), Json::Obj(cache));
    root.insert("headline".to_string(), Json::Obj(headline));
    let doc = Json::Obj(root);
    std::fs::write(&out_path, doc.pretty(2) + "\n")
        .map_err(|e| BismoError::Io(format!("writing {out_path}: {e}")))?;
    println!(
        "wrote {out_path}: adaptive {:.0} tokens/s, {adaptive_speedup:.2}x vs static_full \
         (bit-exact on both backends)",
        rate_of["adaptive"]
    );
    Ok(())
}

/// `bismo tune`: the closed-loop autotuner. Benchmarks candidate tile
/// geometries and shard plans on *this* host across the shape classes
/// (every candidate verified bit-exact against the software oracle
/// before it is timed), refits the cost-model constants, persists the
/// per-machine profile content-addressed by CPU identity, and writes
/// the measurement record to `BENCH_tune.json`. Sessions pick the
/// profile up automatically on their next start.
fn cmd_tune(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::costmodel::{profile_dir, tune_host, TuneConfig};
    use bismo::kernel::KernelConfig;
    use bismo::util::Json;
    use std::collections::BTreeMap;

    let quick = flags.contains_key("quick");
    let out_path = flags
        .get("out")
        .filter(|v| !v.is_empty())
        .cloned()
        .unwrap_or_else(|| "BENCH_tune.json".to_string());
    let dir = flags
        .get("dir")
        .filter(|v| !v.is_empty())
        .map(std::path::PathBuf::from)
        .unwrap_or_else(profile_dir);
    let cfg = TuneConfig {
        quick,
        threads: get(flags, "threads", 0usize),
        seed: get(flags, "seed", TuneConfig::default().seed),
    };

    println!(
        "tuning ({} mode) — every candidate is verified against the bit-serial oracle before timing",
        if quick { "quick" } else { "full" }
    );
    let outcome = tune_host(&cfg)?;
    let profile = &outcome.profile;
    let profile_path = profile.save_in(&dir)?;

    // `tile_k == usize::MAX` is the whole-k sentinel; rendered as "K"
    // in the table and as 0 in JSON (the profile's disk convention).
    let tile_name = |t: &KernelConfig| {
        if t.tile_k == usize::MAX {
            format!("{}x{}xK", t.tile_m, t.tile_n)
        } else {
            format!("{}x{}x{}", t.tile_m, t.tile_n, t.tile_k)
        }
    };
    let tile_k_json = |t: &KernelConfig| {
        Json::num(if t.tile_k == usize::MAX {
            0.0
        } else {
            t.tile_k as f64
        })
    };

    let mut t = Table::new(
        &format!("tuned picks ({})", profile.key()),
        &["class", "workload", "default GOPS", "tuned GOPS", "tile", "shards", "speedup"],
    );
    let mut jclasses = Vec::new();
    for c in &outcome.classes {
        t.rowf(&[
            &c.class,
            &format!("{} w{}a{}", c.shape, c.wbits, c.abits),
            &f(c.default_gops, 3),
            &f(c.tuned_gops, 3),
            &tile_name(&c.tile),
            &format!("{} ({}x{})", c.shards, c.grid.0, c.grid.1),
            &f(c.speedup(), 3),
        ]);

        let mut dflt = BTreeMap::new();
        let default_tile = KernelConfig::default();
        dflt.insert("tile_m".into(), Json::num(default_tile.tile_m as f64));
        dflt.insert("tile_n".into(), Json::num(default_tile.tile_n as f64));
        dflt.insert("tile_k".into(), tile_k_json(&default_tile));
        dflt.insert("ns".into(), Json::num(c.default_ns));
        dflt.insert("gops".into(), Json::num(c.default_gops));
        let mut tuned = BTreeMap::new();
        tuned.insert("tile_m".into(), Json::num(c.tile.tile_m as f64));
        tuned.insert("tile_n".into(), Json::num(c.tile.tile_n as f64));
        tuned.insert("tile_k".into(), tile_k_json(&c.tile));
        tuned.insert("shards".into(), Json::num(c.shards as f64));
        tuned.insert("grid_rows".into(), Json::num(c.grid.0 as f64));
        tuned.insert("grid_cols".into(), Json::num(c.grid.1 as f64));
        tuned.insert("ns".into(), Json::num(c.tuned_ns));
        tuned.insert("gops".into(), Json::num(c.tuned_gops));
        let mut jc = BTreeMap::new();
        jc.insert("class".into(), Json::str(c.class.name()));
        jc.insert("m".into(), Json::num(c.shape.m as f64));
        jc.insert("k".into(), Json::num(c.shape.k as f64));
        jc.insert("n".into(), Json::num(c.shape.n as f64));
        jc.insert("wbits".into(), Json::num(c.wbits as f64));
        jc.insert("abits".into(), Json::num(c.abits as f64));
        jc.insert("binary_ops".into(), Json::num(c.binary_ops as f64));
        jc.insert("candidates".into(), Json::num(c.candidates as f64));
        jc.insert("default".into(), Json::Obj(dflt));
        jc.insert("tuned".into(), Json::Obj(tuned));
        jc.insert("speedup".into(), Json::num(c.speedup()));
        jclasses.push(Json::Obj(jc));
    }
    t.print();

    let mut jmodel = BTreeMap::new();
    jmodel.insert("alpha_dpu".into(), Json::num(profile.cost_model.alpha_dpu));
    jmodel.insert("beta_dpu".into(), Json::num(profile.cost_model.beta_dpu));
    jmodel.insert("lut_base".into(), Json::num(profile.cost_model.lut_base));
    jmodel.insert("lut_res".into(), Json::num(profile.cost_model.lut_res));
    jmodel.insert(
        "bram_base".into(),
        Json::num(profile.cost_model.bram_base as f64),
    );
    let mut jfit = BTreeMap::new();
    jfit.insert("ns_per_op".into(), Json::num(profile.sw_fit.ns_per_op));
    jfit.insert("ns_base".into(), Json::num(profile.sw_fit.ns_base));

    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::str("bismo-tune/v1"));
    root.insert(
        "mode".into(),
        Json::str(if quick { "quick" } else { "full" }),
    );
    root.insert(
        "simd_tier".into(),
        Json::str(&profile.fingerprint.simd_tier),
    );
    root.insert("cores".into(), Json::num(profile.fingerprint.cores as f64));
    root.insert(
        "generated_unix".into(),
        Json::num(profile.generated_unix as f64),
    );
    root.insert("profile_key".into(), Json::str(&profile.key()));
    root.insert(
        "profile_path".into(),
        Json::str(&profile_path.display().to_string()),
    );
    root.insert("cost_model".into(), Json::Obj(jmodel));
    root.insert("sw_fit".into(), Json::Obj(jfit));
    root.insert("classes".into(), Json::Arr(jclasses));
    let doc = Json::Obj(root);
    std::fs::write(&out_path, doc.pretty(2) + "\n")
        .map_err(|e| BismoError::Io(format!("writing {out_path}: {e}")))?;

    let worst = outcome
        .classes
        .iter()
        .map(|c| c.speedup())
        .fold(f64::INFINITY, f64::min);
    println!(
        "wrote {out_path}; profile {} -> {} (worst-class tuned/default ratio {:.3})",
        profile.key(),
        profile_path.display(),
        worst
    );
    Ok(())
}

/// `bismo bench-check`: the CI bench-regression gate.
///
/// Compares a committed baseline `BENCH_gemm.json` against a freshly
/// generated one. Two failure classes, both fatal (non-zero exit):
///
/// * **Schema drift** — different schema/mode, a case set that does
///   not match one-to-one by name, per-case shape facts
///   (`m/k/n/wbits/abits/binary_ops`) that disagree, or missing
///   required fields. Catches silent bench rewrites that would make
///   the regression comparison meaningless.
/// * **Regression** — a case's `speedup_1t` (tiled kernel vs naive
///   baseline, single-threaded — a machine-relative ratio, so the
///   gate is portable across runner hardware) dropping below
///   `baseline · (1 − tolerance)`; likewise the headline speedup.
fn cmd_bench_check(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::util::Json;
    use std::collections::BTreeMap;

    let path_of = |key: &str| -> Result<String, BismoError> {
        flags
            .get(key)
            .filter(|v| !v.is_empty())
            .cloned()
            .ok_or_else(|| BismoError::Parse(format!("--{key} PATH is required")))
    };
    let baseline_path = path_of("baseline")?;
    let current_path = path_of("current")?;
    // An explicitly supplied but unparsable tolerance must fail, not
    // silently loosen the gate to the default.
    let tolerance: f64 = match flags.get("tolerance") {
        None => 0.35,
        Some(v) => v.parse().map_err(|_| {
            BismoError::Parse(format!("bad --tolerance {v:?} (expect a fraction)"))
        })?,
    };
    if !(0.0..1.0).contains(&tolerance) {
        return Err(BismoError::InvalidConfig(format!(
            "--tolerance must be in [0, 1), got {tolerance}"
        )));
    }
    let read = |p: &str| -> Result<Json, BismoError> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| BismoError::Io(format!("reading {p}: {e}")))?;
        Json::parse(&text).map_err(|e| BismoError::Parse(format!("{p}: {e}")))
    };
    let base = read(&baseline_path)?;
    let cur = read(&current_path)?;

    // `bench-check` gates three report schemas: the GEMM suite
    // (bismo-bench-gemm/v1), the autotuner record (bismo-tune/v1) and
    // the attention serving benchmark (bismo-bench-attn/v1). The
    // documents' schema fields select the comparison.
    if base.get("schema").and_then(Json::as_str) == Some("bismo-tune/v1")
        || cur.get("schema").and_then(Json::as_str) == Some("bismo-tune/v1")
    {
        return bench_check_tune(&base, &cur, &baseline_path, &current_path, tolerance);
    }
    if base.get("schema").and_then(Json::as_str) == Some("bismo-bench-attn/v1")
        || cur.get("schema").and_then(Json::as_str) == Some("bismo-bench-attn/v1")
    {
        return bench_check_attn(&base, &cur, &baseline_path, &current_path, tolerance);
    }

    const SCHEMA: &str = "bismo-bench-gemm/v1";
    // Shape facts that must be *identical* (deterministic workload
    // identity) vs timing fields that must merely be present.
    const IDENTITY_NUM: [&str; 6] = ["m", "k", "n", "wbits", "abits", "binary_ops"];
    const TIMING_NUM: [&str; 8] = [
        "baseline_ns",
        "tiled_ns",
        "tiled_mt_ns",
        "baseline_gops",
        "tiled_gops",
        "tiled_mt_gops",
        "speedup_1t",
        "speedup_mt",
    ];

    let mut drift: Vec<String> = Vec::new();
    for (which, doc) in [("baseline", &base), ("current", &cur)] {
        match doc.get("schema").and_then(Json::as_str) {
            Some(SCHEMA) => {}
            other => drift.push(format!("{which}: schema {other:?}, expected {SCHEMA:?}")),
        }
    }
    let mode = |doc: &Json| doc.get("mode").and_then(Json::as_str).map(str::to_string);
    if mode(&base) != mode(&cur) {
        drift.push(format!(
            "bench mode differs: baseline {:?} vs current {:?}",
            mode(&base),
            mode(&cur)
        ));
    }

    // Index cases by name, validating required fields as we go.
    let index = |doc: &Json, which: &str, drift: &mut Vec<String>| {
        let mut by_name: BTreeMap<String, BTreeMap<String, f64>> = BTreeMap::new();
        let cases = doc.get("cases").and_then(Json::as_arr).unwrap_or(&[]);
        if cases.is_empty() {
            drift.push(format!("{which}: no cases array"));
        }
        for case in cases {
            let Some(name) = case.get("name").and_then(Json::as_str) else {
                drift.push(format!("{which}: case without a name"));
                continue;
            };
            let mut fields = BTreeMap::new();
            for f in IDENTITY_NUM.iter().chain(TIMING_NUM.iter()) {
                match case.get(f).and_then(Json::as_f64) {
                    Some(v) => {
                        fields.insert(f.to_string(), v);
                    }
                    None => drift.push(format!("{which}: case {name} missing field {f}")),
                }
            }
            by_name.insert(name.to_string(), fields);
        }
        by_name
    };
    let base_cases = index(&base, "baseline", &mut drift);
    let cur_cases = index(&cur, "current", &mut drift);
    for name in base_cases.keys() {
        if !cur_cases.contains_key(name) {
            drift.push(format!("case {name} present in baseline, missing in current"));
        }
    }
    for name in cur_cases.keys() {
        if !base_cases.contains_key(name) {
            drift.push(format!("case {name} present in current, not in baseline"));
        }
    }
    for (name, bf) in &base_cases {
        let Some(cf) = cur_cases.get(name) else { continue };
        for f in IDENTITY_NUM.iter() {
            if let (Some(bv), Some(cv)) = (bf.get(*f), cf.get(*f)) {
                if bv != cv {
                    drift.push(format!("case {name}: {f} drifted ({bv} -> {cv})"));
                }
            }
        }
    }
    if !drift.is_empty() {
        for d in &drift {
            eprintln!("schema drift: {d}");
        }
        return Err(BismoError::VerifyFailed(format!(
            "bench-check: {} schema drift issue(s) between {baseline_path} and {current_path}",
            drift.len()
        )));
    }

    // Regression gate on the machine-relative speedups.
    let mut t = Table::new(
        &format!("bench-check (tolerance {tolerance})"),
        &["case", "baseline speedup", "current speedup", "floor", "status"],
    );
    let mut regressions = 0usize;
    let mut check = |name: &str, basev: f64, curv: f64| {
        let floor = basev * (1.0 - tolerance);
        let ok = curv >= floor;
        t.rowf(&[
            &name,
            &f(basev, 3),
            &f(curv, 3),
            &f(floor, 3),
            &if ok { "ok" } else { "REGRESSION" },
        ]);
        if !ok {
            regressions += 1;
        }
    };
    for (name, bf) in &base_cases {
        let cf = &cur_cases[name];
        check(name, bf["speedup_1t"], cf["speedup_1t"]);
    }
    let headline_speedup = |doc: &Json, which: &str| -> Result<f64, BismoError> {
        doc.get("headline")
            .and_then(|h| h.get("speedup_1t"))
            .and_then(Json::as_f64)
            .ok_or_else(|| {
                BismoError::Parse(format!("{which}: headline.speedup_1t missing"))
            })
    };
    check(
        "headline",
        headline_speedup(&base, "baseline")?,
        headline_speedup(&cur, "current")?,
    );
    t.print();
    if regressions > 0 {
        return Err(BismoError::VerifyFailed(format!(
            "bench-check: {regressions} case(s) regressed beyond tolerance {tolerance}"
        )));
    }
    println!(
        "bench-check OK: {} case(s) + headline within tolerance {tolerance}",
        base_cases.len()
    );
    Ok(())
}

/// The `bismo-tune/v1` arm of the bench-check gate. Same two failure
/// classes as the GEMM arm — schema drift (mode/class set/workload
/// identity) and regression — but with two regression conditions per
/// class: the tuned/default speedup must not drop below
/// `baseline · (1 − tolerance)`, and it must never drop below 1.0
/// (the tuned pick is an argmax over a candidate set that contains
/// the analytical default, so tuned ≥ default holds by construction;
/// anything less means the sweep itself is broken).
fn bench_check_tune(
    base: &bismo::util::Json,
    cur: &bismo::util::Json,
    baseline_path: &str,
    current_path: &str,
    tolerance: f64,
) -> Result<(), BismoError> {
    use bismo::util::Json;
    use std::collections::BTreeMap;

    const SCHEMA: &str = "bismo-tune/v1";
    const IDENTITY_NUM: [&str; 6] = ["m", "k", "n", "wbits", "abits", "binary_ops"];

    let mut drift: Vec<String> = Vec::new();
    for (which, doc) in [("baseline", base), ("current", cur)] {
        match doc.get("schema").and_then(Json::as_str) {
            Some(SCHEMA) => {}
            other => drift.push(format!("{which}: schema {other:?}, expected {SCHEMA:?}")),
        }
    }
    let mode = |doc: &Json| doc.get("mode").and_then(Json::as_str).map(str::to_string);
    if mode(base) != mode(cur) {
        drift.push(format!(
            "tune mode differs: baseline {:?} vs current {:?}",
            mode(base),
            mode(cur)
        ));
    }

    // Per class: the identity facts, the speedup, and the tuned/default
    // throughputs (present-check only; absolute GOPS are not compared
    // across documents — they are machine-local).
    let index = |doc: &Json, which: &str, drift: &mut Vec<String>| {
        let mut by_class: BTreeMap<String, BTreeMap<String, f64>> = BTreeMap::new();
        let classes = doc.get("classes").and_then(Json::as_arr).unwrap_or(&[]);
        if classes.is_empty() {
            drift.push(format!("{which}: no classes array"));
        }
        for class in classes {
            let Some(name) = class.get("class").and_then(Json::as_str) else {
                drift.push(format!("{which}: class entry without a class name"));
                continue;
            };
            let mut fields = BTreeMap::new();
            for f in IDENTITY_NUM.iter() {
                match class.get(f).and_then(Json::as_f64) {
                    Some(v) => {
                        fields.insert(f.to_string(), v);
                    }
                    None => drift.push(format!("{which}: class {name} missing field {f}")),
                }
            }
            match class.get("speedup").and_then(Json::as_f64) {
                Some(v) => {
                    fields.insert("speedup".to_string(), v);
                }
                None => drift.push(format!("{which}: class {name} missing field speedup")),
            }
            for (section, field) in [("default", "gops"), ("tuned", "gops")] {
                match class
                    .get(section)
                    .and_then(|s| s.get(field))
                    .and_then(Json::as_f64)
                {
                    Some(v) => {
                        fields.insert(format!("{section}_{field}"), v);
                    }
                    None => drift.push(format!(
                        "{which}: class {name} missing field {section}.{field}"
                    )),
                }
            }
            by_class.insert(name.to_string(), fields);
        }
        by_class
    };
    let base_classes = index(base, "baseline", &mut drift);
    let cur_classes = index(cur, "current", &mut drift);
    for name in base_classes.keys() {
        if !cur_classes.contains_key(name) {
            drift.push(format!("class {name} present in baseline, missing in current"));
        }
    }
    for name in cur_classes.keys() {
        if !base_classes.contains_key(name) {
            drift.push(format!("class {name} present in current, not in baseline"));
        }
    }
    for (name, bf) in &base_classes {
        let Some(cf) = cur_classes.get(name) else { continue };
        for f in IDENTITY_NUM.iter() {
            if let (Some(bv), Some(cv)) = (bf.get(*f), cf.get(*f)) {
                if bv != cv {
                    drift.push(format!("class {name}: {f} drifted ({bv} -> {cv})"));
                }
            }
        }
    }
    if !drift.is_empty() {
        for d in &drift {
            eprintln!("schema drift: {d}");
        }
        return Err(BismoError::VerifyFailed(format!(
            "bench-check: {} schema drift issue(s) between {baseline_path} and {current_path}",
            drift.len()
        )));
    }

    let mut t = Table::new(
        &format!("bench-check tune (tolerance {tolerance})"),
        &["class", "baseline speedup", "current speedup", "floor", "status"],
    );
    let mut regressions = 0usize;
    for (name, bf) in &base_classes {
        let cf = &cur_classes[name];
        // The 1.0 floor is absolute: tuned < default means the argmax
        // invariant broke, regardless of how lenient the tolerance is.
        let floor = (bf["speedup"] * (1.0 - tolerance)).max(1.0);
        let ok = cf["speedup"] >= floor;
        t.rowf(&[
            name,
            &f(bf["speedup"], 3),
            &f(cf["speedup"], 3),
            &f(floor, 3),
            &if ok { "ok" } else { "REGRESSION" },
        ]);
        if !ok {
            regressions += 1;
        }
    }
    t.print();
    if regressions > 0 {
        return Err(BismoError::VerifyFailed(format!(
            "bench-check: {regressions} tuned class(es) regressed beyond tolerance {tolerance}"
        )));
    }
    println!(
        "bench-check OK: {} tuned class(es) within tolerance {tolerance}",
        base_classes.len()
    );
    Ok(())
}

/// The `bismo-bench-attn/v1` arm of the bench-check gate. Schema
/// drift covers the workload identity (seq/requests/seed, the model
/// architecture, the per-layer GEMM shape table, the arm set);
/// regression covers three headline numbers:
///
/// * `adaptive_speedup` (adaptive vs static_full tokens/s, same run,
///   so machine-relative) must not drop below
///   `max(baseline, 1.0) · (1 − tolerance)` — adaptive serving must
///   keep beating the highest static precision, up to noise;
/// * `sim_cycle_ratio` (deterministic bit-plane work reduction on the
///   simulator) must not drop below `baseline · (1 − tolerance)`;
/// * the adaptive arm's `accuracy_proxy` must meet the *current*
///   document's `accuracy_floor` absolutely — the range policy is
///   exactness-preserving by construction, so any loss is a bug, not
///   a regression to tolerate.
fn bench_check_attn(
    base: &bismo::util::Json,
    cur: &bismo::util::Json,
    baseline_path: &str,
    current_path: &str,
    tolerance: f64,
) -> Result<(), BismoError> {
    use bismo::util::Json;
    use std::collections::BTreeMap;

    const SCHEMA: &str = "bismo-bench-attn/v1";
    const ROOT_IDENTITY: [&str; 4] = ["seq", "requests", "reps", "seed"];
    const MODEL_IDENTITY: [&str; 5] = ["d_model", "heads", "d_ff", "abits", "max_seq"];
    const LAYER_IDENTITY: [&str; 6] = ["gemms", "m", "k", "n", "activation_bits", "weight_bits"];

    let mut drift: Vec<String> = Vec::new();
    for (which, doc) in [("baseline", base), ("current", cur)] {
        match doc.get("schema").and_then(Json::as_str) {
            Some(SCHEMA) => {}
            other => drift.push(format!("{which}: schema {other:?}, expected {SCHEMA:?}")),
        }
    }
    let mode = |doc: &Json| doc.get("mode").and_then(Json::as_str).map(str::to_string);
    if mode(base) != mode(cur) {
        drift.push(format!(
            "bench mode differs: baseline {:?} vs current {:?}",
            mode(base),
            mode(cur)
        ));
    }
    // Workload identity: root facts and model architecture must be
    // numerically identical.
    let ident = |doc: &Json, which: &str, drift: &mut Vec<String>| {
        let mut out: BTreeMap<String, f64> = BTreeMap::new();
        for k in ROOT_IDENTITY {
            match doc.get(k).and_then(Json::as_f64) {
                Some(v) => {
                    out.insert(k.to_string(), v);
                }
                None => drift.push(format!("{which}: missing field {k}")),
            }
        }
        for k in MODEL_IDENTITY {
            match doc.get("model").and_then(|m| m.get(k)).and_then(Json::as_f64) {
                Some(v) => {
                    out.insert(format!("model.{k}"), v);
                }
                None => drift.push(format!("{which}: missing field model.{k}")),
            }
        }
        out
    };
    let bi = ident(base, "baseline", &mut drift);
    let ci = ident(cur, "current", &mut drift);
    for (k, bv) in &bi {
        if let Some(cv) = ci.get(k) {
            if bv != cv {
                drift.push(format!("{k} drifted ({bv} -> {cv})"));
            }
        }
    }
    // Per-layer GEMM shape table: matched one-to-one by name.
    let layers = |doc: &Json, which: &str, drift: &mut Vec<String>| {
        let mut by_name: BTreeMap<String, BTreeMap<String, f64>> = BTreeMap::new();
        let arr = doc.get("layers").and_then(Json::as_arr).unwrap_or(&[]);
        if arr.is_empty() {
            drift.push(format!("{which}: no layers array"));
        }
        for l in arr {
            let Some(name) = l.get("name").and_then(Json::as_str) else {
                drift.push(format!("{which}: layer without a name"));
                continue;
            };
            let mut fields = BTreeMap::new();
            for f in LAYER_IDENTITY {
                match l.get(f).and_then(Json::as_f64) {
                    Some(v) => {
                        fields.insert(f.to_string(), v);
                    }
                    None => drift.push(format!("{which}: layer {name} missing field {f}")),
                }
            }
            by_name.insert(name.to_string(), fields);
        }
        by_name
    };
    let base_layers = layers(base, "baseline", &mut drift);
    let cur_layers = layers(cur, "current", &mut drift);
    for name in base_layers.keys() {
        if !cur_layers.contains_key(name) {
            drift.push(format!("layer {name} present in baseline, missing in current"));
        }
    }
    for name in cur_layers.keys() {
        if !base_layers.contains_key(name) {
            drift.push(format!("layer {name} present in current, not in baseline"));
        }
    }
    for (name, bf) in &base_layers {
        let Some(cf) = cur_layers.get(name) else { continue };
        for f in LAYER_IDENTITY {
            if let (Some(bv), Some(cv)) = (bf.get(f), cf.get(f)) {
                if bv != cv {
                    drift.push(format!("layer {name}: {f} drifted ({bv} -> {cv})"));
                }
            }
        }
    }
    // Arm set: same names, each with throughput + accuracy present.
    let arm_names = |doc: &Json, which: &str, drift: &mut Vec<String>| -> Vec<String> {
        match doc.get("arms") {
            Some(Json::Obj(m)) => {
                for (name, arm) in m {
                    for f in ["tokens_per_s", "accuracy_proxy"] {
                        if arm.get(f).and_then(Json::as_f64).is_none() {
                            drift.push(format!("{which}: arm {name} missing field {f}"));
                        }
                    }
                }
                m.keys().cloned().collect()
            }
            _ => {
                drift.push(format!("{which}: no arms object"));
                Vec::new()
            }
        }
    };
    let base_arms = arm_names(base, "baseline", &mut drift);
    let cur_arms = arm_names(cur, "current", &mut drift);
    if base_arms != cur_arms {
        drift.push(format!(
            "arm set differs: baseline {base_arms:?} vs current {cur_arms:?}"
        ));
    }
    if !drift.is_empty() {
        for d in &drift {
            eprintln!("schema drift: {d}");
        }
        return Err(BismoError::VerifyFailed(format!(
            "bench-check: {} schema drift issue(s) between {baseline_path} and {current_path}",
            drift.len()
        )));
    }

    let headline_num = |doc: &Json, which: &str, field: &str| -> Result<f64, BismoError> {
        doc.get("headline")
            .and_then(|h| h.get(field))
            .and_then(Json::as_f64)
            .ok_or_else(|| BismoError::Parse(format!("{which}: headline.{field} missing")))
    };
    let mut t = Table::new(
        &format!("bench-check attn (tolerance {tolerance})"),
        &["metric", "baseline", "current", "floor", "status"],
    );
    let mut regressions = 0usize;
    let mut check = |name: &str, basev: f64, curv: f64, floor: f64| {
        let ok = curv >= floor;
        t.rowf(&[
            &name,
            &f(basev, 3),
            &f(curv, 3),
            &f(floor, 3),
            &if ok { "ok" } else { "REGRESSION" },
        ]);
        if !ok {
            regressions += 1;
        }
    };
    // Adaptive must keep beating static_full: the floor never drops
    // below (1 − tolerance) even from a weak baseline.
    let b_speed = headline_num(base, "baseline", "adaptive_speedup")?;
    let c_speed = headline_num(cur, "current", "adaptive_speedup")?;
    check(
        "adaptive_speedup",
        b_speed,
        c_speed,
        b_speed.max(1.0) * (1.0 - tolerance),
    );
    let b_cycles = headline_num(base, "baseline", "sim_cycle_ratio")?;
    let c_cycles = headline_num(cur, "current", "sim_cycle_ratio")?;
    check(
        "sim_cycle_ratio",
        b_cycles,
        c_cycles,
        b_cycles * (1.0 - tolerance),
    );
    // Accuracy is absolute: the floor is the current document's own
    // declared floor, not tolerance-scaled.
    let floor = headline_num(cur, "current", "accuracy_floor")?;
    check(
        "accuracy_proxy",
        headline_num(base, "baseline", "accuracy_proxy")?,
        headline_num(cur, "current", "accuracy_proxy")?,
        floor,
    );
    t.print();
    if regressions > 0 {
        return Err(BismoError::VerifyFailed(format!(
            "bench-check: {regressions} attention metric(s) regressed beyond tolerance {tolerance}"
        )));
    }
    println!("bench-check OK: attention headline metrics within tolerance {tolerance}");
    Ok(())
}

fn cmd_costmodel(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    let model = CostModel::paper();
    let fitted = CostModel::fit_from_synth();
    let mut t = Table::new(
        "cost model (Eq. 1-2)",
        &["instance", "LUT (paper const)", "LUT (fitted)", "BRAM", "fits Z7020"],
    );
    if let Some(inst) = flags.get("instance") {
        let cfg = try_instance(
            inst.parse()
                .map_err(|_| BismoError::Parse(format!("bad --instance {inst:?}")))?,
        )?;
        t.rowf(&[
            inst,
            &f(model.lut_total(&cfg), 0),
            &f(fitted.lut_total(&cfg), 0),
            &model.bram_total(&cfg),
            &model.fits(&cfg, &PYNQ_Z1),
        ]);
    } else {
        for (id, cfg) in all_instances() {
            t.rowf(&[
                &id,
                &f(model.lut_total(&cfg), 0),
                &f(fitted.lut_total(&cfg), 0),
                &model.bram_total(&cfg),
                &model.fits(&cfg, &PYNQ_Z1),
            ]);
        }
    }
    t.print();
    println!(
        "fitted constants: alpha={:.2} beta={:.1} (paper: 2.04 / 109.41)",
        fitted.alpha_dpu, fitted.beta_dpu
    );
    Ok(())
}

fn cmd_synth(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    if let Some(dk) = flags.get("dk") {
        let dk: u32 = dk
            .parse()
            .map_err(|_| BismoError::Parse(format!("bad --dk {dk:?}")))?;
        let r = synth_dpu(dk, 32);
        println!(
            "DPU(Dk={dk}): {} LUTs ({} LUT/bin.op), {} FFs, Fmax {} MHz",
            f(r.luts, 0),
            f(r.luts / (2.0 * dk as f64), 2),
            f(r.ffs, 0),
            f(r.fmax_mhz, 0)
        );
    } else {
        let mut t = Table::new(
            "virtual synthesis of Table IV instances",
            &["instance", "LUTs", "BRAMs", "DPU Fmax", "Fmax (DMA-capped)"],
        );
        for (id, cfg) in all_instances() {
            let s = synth_instance(&cfg);
            t.rowf(&[
                &id,
                &f(s.total_luts, 0),
                &s.brams,
                &f(s.dpu.fmax_mhz, 0),
                &f(s.fmax_mhz, 0),
            ]);
        }
        t.print();
    }
    Ok(())
}

fn cmd_power() -> Result<(), BismoError> {
    let m = PowerModel::calibrated();
    let mut t = Table::new(
        "power model vs paper Table V",
        &["config", "idle W", "+exec W", "+f&r W", "full W", "paper full W", "GOPS/W"],
    );
    for row in &TABLE_V {
        let cfg = try_instance(row.instance)?.at_clock(row.fclk_mhz);
        t.rowf(&[
            &format!("(#{}, {} MHz)", row.instance, row.fclk_mhz),
            &f(m.idle_w(&cfg), 2),
            &f(m.exec_increment_w(&cfg), 2),
            &f(m.fetch_result_increment_w(&cfg), 2),
            &f(m.full_w(&cfg), 2),
            &f(row.full_w, 2),
            &f(row.gops / m.full_w(&cfg), 1),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_instances() -> Result<(), BismoError> {
    let mut t = Table::new(
        "Table IV instance presets",
        &["#", "Dm", "Dk", "Dn", "Bm", "Bn", "peak GOPS @ 200 MHz"],
    );
    for (id, cfg) in all_instances() {
        t.rowf(&[
            &id,
            &cfg.dm,
            &cfg.dk,
            &cfg.dn,
            &cfg.bm,
            &cfg.bn,
            &f(cfg.peak_binary_gops(), 1),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_info() -> Result<(), BismoError> {
    use bismo::simd::DispatchTier;
    println!("bismo — bit-serial matrix multiplication overlay (reproduction)");
    println!("platform model: {}", PYNQ_Z1.name);
    let tier = DispatchTier::resolve()?;
    let supported: Vec<&str> = DispatchTier::supported()
        .into_iter()
        .map(|t| t.name())
        .collect();
    println!(
        "simd tier: {tier} (detected {}; host supports {}; override with BISMO_SIMD=auto|avx512|avx2|neon|scalar)",
        DispatchTier::detect(),
        supported.join(", ")
    );
    {
        use bismo::costmodel::{profile_dir, CpuFingerprint, TunedProfile};
        let dir = profile_dir();
        match CpuFingerprint::detect() {
            Ok(fp) => match TunedProfile::load_for(&dir, &fp) {
                Ok(Some(p)) => println!(
                    "tuned profile: {} ({} classes, fitted alpha={:.2} beta={:.1}) loaded from {}",
                    p.key(),
                    p.classes.len(),
                    p.cost_model.alpha_dpu,
                    p.cost_model.beta_dpu,
                    dir.display()
                ),
                Ok(None) => println!(
                    "tuned profile: none for {} in {} — analytical defaults in use (run `bismo tune`; BISMO_TUNE_DIR overrides the directory)",
                    fp.key(),
                    dir.display()
                ),
                Err(e) => println!(
                    "tuned profile: rejected ({e}) — analytical defaults in use"
                ),
            },
            Err(e) => println!("tuned profile: fingerprint unavailable ({e})"),
        }
    }
    #[cfg(feature = "xla")]
    {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            match bismo::runtime::ArtifactManifest::load(&dir) {
                Ok(m) => {
                    println!("artifacts ({}):", dir.display());
                    for name in m.artifacts.keys() {
                        println!("  {name}");
                    }
                }
                Err(e) => println!("artifact manifest error: {e}"),
            }
        } else {
            println!("artifacts: not built (run `make artifacts`)");
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("artifacts: PJRT runtime disabled (build with --features xla)");
    Ok(())
}

/// `bismo fuzz`: run the seeded fuzz modes; on any failure, write the
/// replayable failure list to `--out` and exit non-zero.
fn cmd_fuzz(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::fuzz::{failures_to_json, fuzz_differential, fuzz_legal, fuzz_mutation, fuzz_wire};

    let iters: u64 = get(flags, "iters", 200u64);
    let seed: u64 = get(flags, "seed", 42u64);
    let mode = flags.get("mode").map(|s| s.as_str()).unwrap_or("all");
    let out = flags
        .get("out")
        .filter(|v| !v.is_empty())
        .cloned()
        .unwrap_or_else(|| "FUZZ_failures.json".to_string());

    let runs: Vec<fn(u64, u64) -> bismo::fuzz::FuzzOutcome> = match mode {
        "legal" => vec![fuzz_legal],
        "mutation" => vec![fuzz_mutation],
        "differential" => vec![fuzz_differential],
        "wire" => vec![fuzz_wire],
        "all" => vec![fuzz_legal, fuzz_mutation, fuzz_differential, fuzz_wire],
        other => {
            return Err(BismoError::Parse(format!(
                "bad --mode {other:?} (expect legal|mutation|differential|wire|all)"
            )))
        }
    };

    let mut outcomes = Vec::new();
    let mut failed = 0usize;
    for run in runs {
        let o = run(iters, seed);
        println!(
            "fuzz {:<13} {} iters  {} failures",
            o.mode,
            o.iters,
            o.failures.len()
        );
        for f in &o.failures {
            println!(
                "  FAIL {} case {}: replay seed {:#x}: {}",
                f.mode, f.index, f.seed, f.detail
            );
        }
        failed += o.failures.len();
        outcomes.push(o);
    }
    if failed > 0 {
        let text = failures_to_json(&outcomes);
        std::fs::write(&out, &text).map_err(|e| BismoError::Io(format!("writing {out}: {e}")))?;
        return Err(BismoError::VerifyFailed(format!(
            "{failed} fuzz failure(s); replay seeds written to {out}"
        )));
    }
    println!("all fuzz modes clean (seed {seed}, {iters} iters each)");
    Ok(())
}

/// `bismo snapshot`: golden snapshot/replay gate against
/// `ci/sim_snapshots.json` (`--regen` rewrites the baseline).
fn cmd_snapshot(flags: &HashMap<String, String>) -> Result<(), BismoError> {
    use bismo::util::Json;

    let path = flags
        .get("baseline")
        .filter(|v| !v.is_empty())
        .cloned()
        .unwrap_or_else(|| "ci/sim_snapshots.json".to_string());
    let report = bismo::fuzz::golden_snapshot_report()?;

    if flags.contains_key("regen") {
        std::fs::write(&path, &report)
            .map_err(|e| BismoError::Io(format!("writing {path}: {e}")))?;
        println!("golden snapshot baseline regenerated -> {path}");
        return Ok(());
    }

    let baseline_text = std::fs::read_to_string(&path)
        .map_err(|e| BismoError::Io(format!("reading {path}: {e}")))?;
    let baseline =
        Json::parse(&baseline_text).map_err(|e| BismoError::Parse(format!("{path}: {e}")))?;
    if baseline.get("status").and_then(Json::as_str) == Some("bootstrap") {
        println!("golden snapshot baseline is a bootstrap placeholder; run");
        println!("  bismo snapshot --regen");
        println!("on a trusted build to commit real goldens. Gate skipped.");
        return Ok(());
    }
    let current = Json::parse(&report).expect("generated report is valid JSON");
    if baseline.dump() != current.dump() {
        return Err(BismoError::VerifyFailed(format!(
            "simulator snapshot/replay state drifted from the golden baseline {path}; \
             if the change is intended, regenerate with `bismo snapshot --regen`"
        )));
    }
    println!("golden snapshot gate clean ({path})");
    Ok(())
}

const USAGE: &str = "usage: bismo <quickstart|simulate|schedule|bench|tune|serve|serve-bench|shard-bench|cnn-bench|attn-bench|bench-check|fuzz|snapshot|costmodel|synth|power|instances|info> [flags]
flags: --instance N  --m M --k K --n N  --wbits W --abits A  --signed --no-overlap --bit-skip  --seed S  --dk N
bench: --quick  --out PATH (default BENCH_gemm.json)  --threads N
tune: --quick  --out PATH (default BENCH_tune.json)  --dir DIR (default tuned/ or $BISMO_TUNE_DIR)  --threads N  --seed S
serve: --host H (default 127.0.0.1)  --port P (default 7410; 0 = ephemeral)  --workers W  --batch B  --cache-mb M  --max-in-flight N  --tenant-in-flight N  --tenant-weight-mb M
serve-bench: --quick  --backend engine|sim  --requests N  --rate RPS  --layers L  --workers W  --batch B  --out PATH (default BENCH_serve.json)  --remote  --clients C  --addr HOST:PORT  --max-in-flight N  --tenant-in-flight N
shard-bench: --quick  --backend engine|sim  --reps N  --max-shards S  --budget-luts L --budget-brams B  --out PATH (default BENCH_shard.json)
cnn-bench: --quick  --batch B  --reps N  --out PATH (default BENCH_cnn.json)
attn-bench: --quick  --seq S  --requests N  --reps N  --seed S  --out PATH (default BENCH_attn.json)
bench-check: --baseline PATH  --current PATH  --tolerance F (default 0.35)
fuzz: --iters N (default 200)  --seed S (default 42)  --mode legal|mutation|differential|wire|all  --out PATH (default FUZZ_failures.json)
snapshot: --regen  --baseline PATH (default ci/sim_snapshots.json)
env: BISMO_SIMD=auto|avx512|avx2|neon|scalar forces the SIMD dispatch tier (default auto-detect; see `bismo info`)";

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (flags, pos) = parse_flags(&args);
    let cmd = pos.first().map(|s| s.as_str()).unwrap_or("");
    let result = match cmd {
        "quickstart" => cmd_quickstart(),
        "simulate" => cmd_simulate(&flags),
        "schedule" => cmd_schedule(&flags),
        "bench" => cmd_bench(&flags),
        "tune" => cmd_tune(&flags),
        "serve" => cmd_serve(&flags),
        "serve-bench" => cmd_serve_bench(&flags),
        "shard-bench" => cmd_shard_bench(&flags),
        "cnn-bench" => cmd_cnn_bench(&flags),
        "attn-bench" => cmd_attn_bench(&flags),
        "bench-check" => cmd_bench_check(&flags),
        "fuzz" => cmd_fuzz(&flags),
        "snapshot" => cmd_snapshot(&flags),
        "costmodel" => cmd_costmodel(&flags),
        "synth" => cmd_synth(&flags),
        "power" => cmd_power(),
        "instances" => cmd_instances(),
        "info" => cmd_info(),
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
