//! Virtual synthesis: structural circuit generation + 6-LUT technology
//! mapping + timing estimation for the BISMO datapath components.
//!
//! This module stands in for the paper's Vivado out-of-context synthesis
//! runs (§IV-A). Every characterized number comes from *constructing the
//! circuit* — e.g. the popcount compressor tree is actually built, level
//! by level, for the requested width — and mapping it onto Xilinx
//! 7-series primitives (6-input LUTs, CARRY4 chains) with documented
//! packing rules (the `lutmap` mapper behind [`MappedCircuit`]).
//! Delay/Fmax comes from the mapped depth and a simple wire-load model
//! (the `timing` module behind [`fmax_mhz`]).
//!
//! What this preserves from real synthesis (and what the paper's figures
//! demonstrate): the *structural scaling* of each component — popcount
//! ≈ 1 LUT/bit, DPU cost linear in `D_k` with a fixed
//! shifter/negator/accumulator overhead, bit-parallel DPUs cheaper per
//! binary-op-equivalent but fixed-precision. What it cannot reproduce:
//! Vivado's local optimizations on small designs (the paper itself
//! reports those as its main source of model error, Fig. 9).

mod bitparallel;
mod lutmap;
mod netlist;
mod popcount;
mod stages;
mod timing;

pub use bitparallel::{bitparallel_ops, synth_bitparallel_dpu};
pub use lutmap::MappedCircuit;
pub use netlist::{Netlist, NodeId};
pub use popcount::{build_popcount, synth_popcount};
pub use stages::{fetch_stage_luts, result_stage_luts, synth_dpu, synth_instance, InstanceSynth};
pub use timing::fmax_mhz;

/// Synthesis result for one component.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SynthReport {
    /// Mapped 6-input LUTs.
    pub luts: f64,
    /// Flip-flops (registers, incl. pipeline registers).
    pub ffs: f64,
    /// Combinational LUT levels on the critical path *between pipeline
    /// registers* (retimed, as the paper does).
    pub stage_depth: f64,
    /// Estimated maximum clock frequency.
    pub fmax_mhz: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn popcount_about_one_lut_per_bit() {
        // The paper's Fig. 6 headline: ~1 LUT per input bit.
        for n in [32u32, 64, 128, 256, 512, 1024] {
            let r = synth_popcount(n);
            let per_bit = r.luts / n as f64;
            assert!(
                (0.7..=1.4).contains(&per_bit),
                "popcount({n}): {per_bit:.2} LUT/bit out of Fig. 6 band"
            );
        }
    }

    #[test]
    fn popcount_fmax_in_paper_band() {
        // Fig. 6 reports 320–650 MHz across widths.
        for n in [32u32, 64, 128, 256, 512, 1024] {
            let r = synth_popcount(n);
            assert!(
                (320.0..=650.0).contains(&r.fmax_mhz),
                "popcount({n}): Fmax {:.0} MHz out of band",
                r.fmax_mhz
            );
        }
    }

    #[test]
    fn dpu_cost_per_op_decreases_with_dk() {
        // Fig. 7: 2.8 LUT/op at D_k=32 falling to ~1.07 at D_k=1024.
        let per_op = |dk: u32| synth_dpu(dk, 32).luts / (2.0 * dk as f64);
        let c32 = per_op(32);
        let c1024 = per_op(1024);
        assert!(c32 > 2.0 && c32 < 3.6, "Dk=32: {c32:.2}");
        assert!(c1024 > 0.8 && c1024 < 1.4, "Dk=1024: {c1024:.2}");
        assert!(c32 > 1.8 * c1024, "amortization too weak");
        // Monotone decreasing across the sweep.
        let mut prev = f64::INFINITY;
        for dk in [32u32, 64, 128, 256, 512, 1024] {
            let c = per_op(dk);
            assert!(c < prev, "per-op cost must fall with D_k");
            prev = c;
        }
    }

    #[test]
    fn dpu_fmax_in_paper_band() {
        // Fig. 7 text: 300–350 MHz for tested widths.
        for dk in [32u32, 64, 128, 256, 512, 1024] {
            let f = synth_dpu(dk, 32).fmax_mhz;
            assert!(
                (280.0..=380.0).contains(&f),
                "DPU({dk}) Fmax {f:.0} out of band"
            );
        }
    }

    #[test]
    fn bitparallel_cheaper_per_op_but_gap_closes() {
        // Fig. 11: bit-parallel 3×3 ≈ 0.73 LUT/op; BISMO gap ≤ ~0.5
        // LUT/op at large D_k.
        let dk = 256;
        let bs = synth_dpu(dk, 32).luts / (2.0 * dk as f64);
        let bp33 = synth_bitparallel_dpu(3, 3, dk).luts / (2.0 * 3.0 * 3.0 * dk as f64);
        assert!(bp33 < bs, "bit-parallel must be cheaper per op");
        assert!(bp33 > 0.5 && bp33 < 1.1, "3x3 per-op {bp33:.2}");
        let gap = bs - bp33;
        assert!(gap < 0.9, "gap {gap:.2} too wide at D_k={dk}");
    }
}
