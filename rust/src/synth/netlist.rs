//! A small structural netlist for virtual synthesis.
//!
//! Nodes are *mapped primitives*, not raw gates: generators emit the
//! Xilinx 7-series structures a synthesis tool would produce for these
//! well-understood datapath circuits (compressors, carry-chain adders,
//! mux stages). Each node records its LUT cost, register count and the
//! delay it adds on top of its deepest predecessor; the mapper
//! (`super::lutmap`) folds the graph into totals.

/// Handle to a netlist node. `NodeId(0)` is the primary-input pseudo
/// node (depth 0, zero cost).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NodeId(pub usize);

/// Mapped-primitive kinds with their packing rules.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Prim {
    /// Generic k-input (k ≤ 6) logic function: 1 LUT, 1 level.
    Lut6,
    /// 6:3 bit-count compressor: 3 LUT6 sharing 6 inputs, 1 level.
    Compressor63,
    /// 3:2 full-adder compressor: 2 LUTs (sum + carry), 1 level.
    Compressor32,
    /// Ripple-carry adder, `w` bits: `w` LUTs + CARRY4 chain. One LUT
    /// level plus fast carry propagation (`w/4` CARRY4 hops).
    AdderCarry { w: u32 },
    /// `w`-bit 4:1 mux stage (2 select bits): `w` LUTs, 1 level.
    Mux4 { w: u32 },
    /// Register bank, `w` bits: 0 LUTs, `w` FFs; cuts the timing path.
    Reg { w: u32 },
}

struct Node {
    #[allow(dead_code)] // kept for netlist dumps / debugging
    prim: Prim,
    /// Combinational depth at this node's output, in equivalent LUT
    /// levels since the last register cut.
    depth: f64,
}

/// The netlist under construction.
pub struct Netlist {
    nodes: Vec<Node>,
    luts: f64,
    ffs: f64,
    /// Deepest combinational path between register cuts seen anywhere.
    max_stage_depth: f64,
}

impl Netlist {
    pub fn new() -> Self {
        Netlist {
            nodes: vec![Node {
                prim: Prim::Reg { w: 0 },
                depth: 0.0,
            }],
            luts: 0.0,
            ffs: 0.0,
            max_stage_depth: 0.0,
        }
    }

    /// Primary-input pseudo node.
    pub fn input(&self) -> NodeId {
        NodeId(0)
    }

    fn prim_cost(prim: Prim) -> (f64, f64, f64) {
        // (luts, ffs, delay in LUT levels)
        match prim {
            Prim::Lut6 => (1.0, 0.0, 1.0),
            Prim::Compressor63 => (3.0, 0.0, 1.0),
            Prim::Compressor32 => (2.0, 0.0, 1.0),
            // Carry chains are much faster than LUT hops: count the
            // chain at 1/8 LUT-level per CARRY4 (two bits per half hop).
            Prim::AdderCarry { w } => (w as f64, 0.0, 1.0 + w as f64 / 4.0 * 0.125),
            Prim::Mux4 { w } => (w as f64, 0.0, 1.0),
            Prim::Reg { w } => (0.0, w as f64, 0.0),
        }
    }

    /// Add a node fed by `preds`. Returns its id.
    pub fn add(&mut self, prim: Prim, preds: &[NodeId]) -> NodeId {
        let in_depth = preds
            .iter()
            .map(|p| self.nodes[p.0].depth)
            .fold(0.0, f64::max);
        let (l, f, d) = Self::prim_cost(prim);
        self.luts += l;
        self.ffs += f;
        let depth = if matches!(prim, Prim::Reg { .. }) {
            // Register: path ends here; record the cut stage depth.
            self.max_stage_depth = self.max_stage_depth.max(in_depth);
            0.0
        } else {
            let depth = in_depth + d;
            self.max_stage_depth = self.max_stage_depth.max(depth);
            depth
        };
        self.nodes.push(Node { prim, depth });
        NodeId(self.nodes.len() - 1)
    }

    /// Totals so far: (luts, ffs).
    pub fn cost(&self) -> (f64, f64) {
        (self.luts, self.ffs)
    }

    /// Deepest combinational stage (LUT levels between registers).
    pub fn stage_depth(&self) -> f64 {
        self.max_stage_depth
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
}

impl Default for Netlist {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_accumulate() {
        let mut nl = Netlist::new();
        let i = nl.input();
        let a = nl.add(Prim::Compressor63, &[i]);
        let b = nl.add(Prim::Compressor63, &[i]);
        let s = nl.add(Prim::AdderCarry { w: 4 }, &[a, b]);
        nl.add(Prim::Reg { w: 4 }, &[s]);
        let (luts, ffs) = nl.cost();
        assert_eq!(luts, 3.0 + 3.0 + 4.0);
        assert_eq!(ffs, 4.0);
    }

    #[test]
    fn depth_tracks_critical_path() {
        let mut nl = Netlist::new();
        let i = nl.input();
        let a = nl.add(Prim::Lut6, &[i]); // depth 1
        let b = nl.add(Prim::Lut6, &[a]); // depth 2
        let _c = nl.add(Prim::Lut6, &[i]); // depth 1 (parallel)
        assert_eq!(nl.stage_depth(), 2.0);
        let r = nl.add(Prim::Reg { w: 1 }, &[b]); // cut
        let d = nl.add(Prim::Lut6, &[r]); // new stage: depth 1
        let _ = d;
        assert_eq!(nl.stage_depth(), 2.0); // still the deepest stage
    }

    #[test]
    fn register_resets_stage() {
        let mut nl = Netlist::new();
        let i = nl.input();
        let mut x = i;
        for _ in 0..3 {
            let y = nl.add(Prim::Lut6, &[x]);
            x = nl.add(Prim::Reg { w: 1 }, &[y]); // pipeline every level
        }
        assert_eq!(nl.stage_depth(), 1.0);
        assert_eq!(nl.cost(), (3.0, 3.0));
    }

    #[test]
    fn adder_carry_delay_scales_slowly() {
        let (_, _, d8) = Netlist::prim_cost(Prim::AdderCarry { w: 8 });
        let (_, _, d64) = Netlist::prim_cost(Prim::AdderCarry { w: 64 });
        assert!(d64 > d8);
        assert!(d64 < 4.0, "carry chain must stay far below LUT-hop cost");
    }
}
