//! Folding a generated netlist into a mapped-circuit summary.

use super::netlist::Netlist;
use super::timing::fmax_mhz;
use super::SynthReport;

/// Summary of a mapped circuit (thin wrapper; generators build the
/// netlist, this attaches timing).
#[derive(Clone, Copy, Debug)]
pub struct MappedCircuit {
    pub luts: f64,
    pub ffs: f64,
    pub stage_depth: f64,
}

impl MappedCircuit {
    /// Fold a netlist.
    pub fn of(nl: &Netlist) -> Self {
        let (luts, ffs) = nl.cost();
        MappedCircuit {
            luts,
            ffs,
            stage_depth: nl.stage_depth(),
        }
    }

    /// Attach the wire-load timing model; `fanout_hint` approximates
    /// congestion (number of LUTs competing for routing).
    pub fn report(&self, fanout_hint: f64) -> SynthReport {
        SynthReport {
            luts: self.luts,
            ffs: self.ffs,
            stage_depth: self.stage_depth,
            fmax_mhz: fmax_mhz(self.stage_depth, fanout_hint),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::synth::netlist::{Netlist, Prim};

    #[test]
    fn fold_matches_netlist() {
        let mut nl = Netlist::new();
        let i = nl.input();
        let a = nl.add(Prim::Compressor32, &[i]);
        nl.add(Prim::Reg { w: 2 }, &[a]);
        let m = MappedCircuit::of(&nl);
        assert_eq!(m.luts, 2.0);
        assert_eq!(m.ffs, 2.0);
        assert_eq!(m.stage_depth, 1.0);
        let r = m.report(10.0);
        assert!(r.fmax_mhz > 0.0);
    }
}
