//! DPU and full-instance virtual synthesis (paper Figs 7–8, Table IV).

use super::lutmap::MappedCircuit;
use super::netlist::{Netlist, NodeId, Prim};
use super::popcount::compress_columns;
use super::SynthReport;
use crate::arch::BismoConfig;
use crate::util::ceil_div;

/// Build and characterize one bit-serial DPU (paper Fig. 4 / Fig. 7):
/// `D_k`-wide AND, popcount compressor tree, barrel shifter for the
/// software-controlled weight, negation folded into the accumulator's
/// carry-in, `acc_bits`-wide accumulator register.
pub fn synth_dpu(dk: u32, acc_bits: u32) -> SynthReport {
    let mut nl = Netlist::new();
    let input = nl.input();

    // AND stage: one LUT per product bit. (Packing two AND2s per
    // fractured LUT6 is defeated in practice by the compressor absorbing
    // the LUT inputs — matches the paper's fitted ~2 LUT/bit total.)
    let products: Vec<NodeId> = (0..dk)
        .map(|_| {
            let a = nl.add(Prim::Lut6, &[input]);
            // Registered AND stage (retimed pipeline boundary).
            nl.add(Prim::Reg { w: 1 }, &[a])
        })
        .collect();

    // Popcount tree over the product bits.
    let pc = compress_columns(&mut nl, vec![products]);

    // Barrel shifter: the popcount result (≤ log2(Dk)+1 bits) shifts by
    // 0..=62 into the accumulator's width: ceil(6/2) = 3 Mux4 stages of
    // acc_bits width, registered between stages (the paper adds
    // registers to critical paths and retimes).
    let mut x = pc.first().copied().unwrap_or(input);
    for _ in 0..3 {
        x = nl.add(Prim::Mux4 { w: acc_bits }, &[x]);
        x = nl.add(Prim::Reg { w: acc_bits }, &[x]);
    }
    let sh = x;

    // Accumulator: add/sub with negation via carry-in (XOR packs into
    // the adder LUTs), then the accumulator register.
    let sum = nl.add(Prim::AdderCarry { w: acc_bits }, &[sh]);
    nl.add(Prim::Reg { w: acc_bits }, &[sum]);

    let m = MappedCircuit::of(&nl);
    m.report(m.luts)
}

/// Fetch-stage LUT cost for a `D_m × D_n` array with one 64-bit memory
/// channel. The paper characterizes this as `1.89·(D_m+D_n) + 463`
/// (§IV-A3); the DMA engine RTL is not specified in enough detail to
/// re-derive structurally, so the measured characterization is used
/// directly (documented substitution).
pub fn fetch_stage_luts(dm: u32, dn: u32) -> f64 {
    1.89 * (dm + dn) as f64 + 463.0
}

/// Result-stage LUT cost: result buffers (`87.3·D_m·D_n`) plus DMA
/// engine + downsizer (`32.8·D_m·D_n + 255`), per the paper's §IV-A3
/// characterization.
pub fn result_stage_luts(dm: u32, dn: u32) -> f64 {
    (87.3 + 32.8) * (dm * dn) as f64 + 255.0
}

/// Virtual synthesis of a whole instance.
#[derive(Clone, Copy, Debug)]
pub struct InstanceSynth {
    /// One DPU's characterization.
    pub dpu: SynthReport,
    /// DPA LUTs: `D_m·D_n` DPUs + per-DPU result-stage cost.
    pub array_luts: f64,
    /// Size-independent infrastructure (fetch + result DMA bases).
    pub base_luts: f64,
    /// Total mapped LUTs.
    pub total_luts: f64,
    /// BRAMs (36-kbit blocks) for the matrix buffers + base.
    pub brams: u64,
    /// Overall Fmax bound: min(DPU, DMA engine 200 MHz paper limit).
    pub fmax_mhz: f64,
}

/// Cross-boundary optimization factor: synthesis tools share and trim
/// logic across module boundaries, and do so disproportionately well on
/// small designs (more placement freedom, better packing). This is the
/// effect the paper identifies as its cost model's main error source
/// ("smaller designs tend to be overestimated ... likely due to the
/// effect of additional synthesis optimizations applied by Vivado for
/// small designs", Fig. 9). Calibrated so the validation sweep lands at
/// the paper's ~94% mean model accuracy with the same error-vs-size
/// shape.
pub fn vivado_trim(raw_luts: f64) -> f64 {
    1.0 - 0.12 * (-raw_luts / 30_000.0).exp()
}

/// Characterize a full BISMO instance (the "actual" side of Fig. 8).
pub fn synth_instance(cfg: &BismoConfig) -> InstanceSynth {
    let dpu = synth_dpu(cfg.dk, cfg.acc_bits);
    let ndpu = (cfg.dm * cfg.dn) as f64;
    let res_per_dpu = result_stage_luts(cfg.dm, cfg.dn) - 255.0;
    let raw = ndpu * dpu.luts + res_per_dpu + fetch_stage_luts(cfg.dm, cfg.dn) + 255.0;
    let trim = vivado_trim(raw);
    let array_luts = (ndpu * dpu.luts + res_per_dpu) * trim;
    let base_luts = (fetch_stage_luts(cfg.dm, cfg.dn) + 255.0) * trim;

    // BRAM: Eq. 2 of the paper — `ceil(Dk/32)` 36-kbit lanes (32 data
    // bits used) per buffer, `ceil(depth/1024)` deep.
    let lanes = ceil_div(cfg.dk as u64, 32);
    let bram_array = lanes
        * (cfg.dm as u64 * ceil_div(cfg.bm as u64, 1024)
            + cfg.dn as u64 * ceil_div(cfg.bn as u64, 1024));
    let bram_base = 1; // DMA alignment buffer; instruction queues are LUTRAM.

    InstanceSynth {
        dpu,
        array_luts,
        base_luts,
        total_luts: array_luts + base_luts,
        brams: bram_array + bram_base,
        fmax_mhz: dpu.fmax_mhz.min(200.0), // DMA engine limits to 200 MHz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::instance;

    #[test]
    fn dpu_linear_in_dk() {
        // Fit LUTs = α·Dk + β over the Fig. 7 sweep; α should be ~2 and
        // β a fixed overhead ~100–180 (paper: 2.04, 109.4).
        let dks = [32u32, 64, 128, 256, 512, 1024];
        let pts: Vec<(f64, f64)> = dks
            .iter()
            .map(|&dk| (dk as f64, synth_dpu(dk, 32).luts))
            .collect();
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let alpha = (n * sxy - sx * sy) / (n * sxx - sx * sx);
        let beta = (sy - alpha * sx) / n;
        assert!((1.6..=2.5).contains(&alpha), "alpha {alpha:.2} vs paper 2.04");
        assert!((60.0..=220.0).contains(&beta), "beta {beta:.1} vs paper 109.4");
    }

    #[test]
    fn table4_bram_counts_close_to_paper() {
        // Paper Table IV: instance #1 → 121 BRAM, #2..#6 → 129.
        let expect = [121u64, 129, 129, 129, 129, 129];
        for (i, &e) in expect.iter().enumerate() {
            let s = synth_instance(&instance(i as u32 + 1));
            let err = (s.brams as i64 - e as i64).abs() as f64 / e as f64;
            assert!(
                err <= 0.12,
                "instance {} BRAM {} vs paper {e}",
                i + 1,
                s.brams
            );
        }
    }

    #[test]
    fn table4_lut_counts_same_order() {
        // Paper Table IV LUT counts; our virtual synthesis should land
        // within ±35% (it models the datapath, not Vivado's exact
        // packing).
        let expect = [19545.0, 27740.0, 45573.0, 13352.0, 24202.0, 21755.0];
        for (i, &e) in expect.iter().enumerate() {
            let s = synth_instance(&instance(i as u32 + 1));
            let rel = (s.total_luts - e).abs() / e;
            assert!(
                rel <= 0.35,
                "instance {}: {} LUTs vs paper {e} ({:.0}% off)",
                i + 1,
                s.total_luts,
                rel * 100.0
            );
        }
    }

    #[test]
    fn instance_fmax_capped_by_dma() {
        let s = synth_instance(&instance(1));
        assert_eq!(s.fmax_mhz, 200.0);
    }

    #[test]
    fn stage_formulas_match_paper_constants() {
        // LUT_base = 463 + 255 = 718 (paper §IV-A3).
        assert_eq!(fetch_stage_luts(0, 0) + 255.0, 718.0);
        // LUT_res = 120.1 per DPU.
        assert!((result_stage_luts(1, 1) - 255.0 - 120.1).abs() < 1e-9);
    }
}
