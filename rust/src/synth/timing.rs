//! Delay / Fmax model for mapped circuits.
//!
//! A pipeline stage of depth `d` LUT levels has period
//!
//! ```text
//! T = t_clk_overhead + d · (t_lut + t_route(fanout))
//! ```
//!
//! with a congestion-dependent routing delay: bigger blocks spread over
//! more of the die and pay longer nets. Constants are calibrated so the
//! characterized components land in the paper's reported bands
//! (popcount 320–650 MHz, DPU 300–350 MHz, Fig. 6–7) on the Zynq-7000
//! (-1 speed grade) process.

/// Clock-to-Q + setup + clock skew (ns).
const T_CLK_NS: f64 = 0.65;
/// LUT6 propagation delay (ns).
const T_LUT_NS: f64 = 0.35;
/// Base net delay between LUTs (ns).
const T_ROUTE_BASE_NS: f64 = 0.45;
/// Congestion growth: extra net delay per doubling of block size (ns).
const T_ROUTE_GROWTH_NS: f64 = 0.037;

/// Estimated Fmax (MHz) of a pipeline stage `depth` LUT levels deep in
/// a block of roughly `fanout_hint` LUTs.
pub fn fmax_mhz(depth: f64, fanout_hint: f64) -> f64 {
    let congestion = T_ROUTE_GROWTH_NS * fanout_hint.max(1.0).log2();
    let t_level = T_LUT_NS + T_ROUTE_BASE_NS + congestion;
    let period_ns = T_CLK_NS + depth.max(0.5) * t_level;
    1000.0 / period_ns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deeper_is_slower() {
        assert!(fmax_mhz(1.0, 64.0) > fmax_mhz(3.0, 64.0));
    }

    #[test]
    fn bigger_blocks_are_slower() {
        assert!(fmax_mhz(2.0, 32.0) > fmax_mhz(2.0, 2048.0));
    }

    #[test]
    fn shallow_small_block_in_plausible_range() {
        // A 2-level stage in a small block: a few hundred MHz on Zynq-7000.
        let f = fmax_mhz(2.0, 64.0);
        assert!((350.0..700.0).contains(&f), "{f}");
    }
}
