//! Bit-parallel DPU variant (paper §IV-A6, Fig. 11): `w×a`-bit
//! multipliers instead of AND, a ternary adder tree instead of popcount,
//! and no shifter/negator. Performs `2·w·a·D_k` binary-op equivalents
//! per cycle.

use super::lutmap::MappedCircuit;
use super::netlist::{Netlist, NodeId, Prim};
use super::popcount::compress_columns;
use super::SynthReport;

/// Characterize a bit-parallel DPU.
///
/// The efficient structure (and what Vivado converges to for small
/// operand widths): partial products of *all* `D_k` multipliers are kept
/// in redundant carry-save form and compressed in one global
/// column tree — no per-multiplier carry-propagate adders — followed by
/// a single carry-chain add and the accumulator. Partial-product AND
/// gates pack two per fractured LUT6.
pub fn synth_bitparallel_dpu(w: u32, a: u32, dk: u32) -> SynthReport {
    assert!(w >= 1 && a >= 1 && dk >= 1);
    let mut nl = Netlist::new();
    let input = nl.input();

    // Global weight columns: multiplier lane d contributes its w·a
    // partial-product bits at weights i+j.
    let cols_n = (w + a - 1) as usize;
    let mut cols: Vec<Vec<NodeId>> = vec![Vec::new(); cols_n];
    let mut pending = 0u32;
    let mut last_and: Option<NodeId> = None;
    for _d in 0..dk {
        for i in 0..w {
            for j in 0..a {
                // Two AND2 partial products per fractured LUT6.
                let node = if pending % 2 == 0 {
                    let n = nl.add(Prim::Lut6, &[input]);
                    last_and = Some(n);
                    n
                } else {
                    last_and.unwrap()
                };
                pending += 1;
                cols[(i + j) as usize].push(node);
            }
        }
    }
    // But each packed LUT6 is still one LUT for two bits; cost already
    // counted once per pair above.
    let sum = compress_columns(&mut nl, cols);
    let s = sum.first().copied().unwrap_or(input);

    // Accumulator (32-bit, like the bit-serial DPU's A).
    let acc = nl.add(Prim::AdderCarry { w: 32 }, &[s]);
    nl.add(Prim::Reg { w: 32 }, &[acc]);

    let m = MappedCircuit::of(&nl);
    m.report(m.luts)
}

/// Binary-op equivalents per cycle for this DPU (paper convention).
pub fn bitparallel_ops(w: u32, a: u32, dk: u32) -> u64 {
    2 * w as u64 * a as u64 * dk as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn per_op(w: u32, a: u32, dk: u32) -> f64 {
        synth_bitparallel_dpu(w, a, dk).luts / bitparallel_ops(w, a, dk) as f64
    }

    #[test]
    fn per_op_cost_falls_with_precision_then_flattens() {
        // Fig. 11: 1.1 LUT/op at 2×1 down to 0.73 at 3×3, flat beyond.
        let dk = 256;
        let c21 = per_op(2, 1, dk);
        let c22 = per_op(2, 2, dk);
        let c33 = per_op(3, 3, dk);
        let c44 = per_op(4, 4, dk);
        assert!(c21 > c22 && c22 > c33, "{c21:.2} {c22:.2} {c33:.2}");
        assert!((0.5..=1.6).contains(&c21), "2x1 {c21:.2}");
        assert!((0.4..=1.1).contains(&c33), "3x3 {c33:.2}");
        // Beyond 3×3 the paper saw no further improvement (±20%).
        assert!(c44 > 0.8 * c33, "4x4 {c44:.2} vs 3x3 {c33:.2}");
    }

    #[test]
    fn cheaper_than_bit_serial_at_same_dk() {
        use crate::synth::stages::synth_dpu;
        for dk in [64u32, 256, 1024] {
            let bs = synth_dpu(dk, 32).luts / (2.0 * dk as f64);
            assert!(
                per_op(3, 3, dk) < bs,
                "bit-parallel must beat bit-serial per op at Dk={dk}"
            );
        }
    }

    #[test]
    fn multiplier_cost_grows_with_operand_width() {
        let dk = 64;
        let l22 = synth_bitparallel_dpu(2, 2, dk).luts;
        let l44 = synth_bitparallel_dpu(4, 4, dk).luts;
        assert!(l44 > l22);
    }

    #[test]
    fn degenerate_1x1_is_and_plus_popcount() {
        // 1×1 bit-parallel ≈ binary DPU without shifter: should cost
        // close to 1–2 LUT/op.
        let c = per_op(1, 1, 256);
        assert!((0.5..=2.0).contains(&c), "{c}");
    }
}
