//! Popcount compressor-tree generator (paper Fig. 6).
//!
//! Builds the circuit a synthesis tool produces for `+` over bits: a
//! Wallace-style tree of 6:3 and 3:2 compressors over weight columns,
//! pipelined every two levels (the paper adds registers and lets Vivado
//! retime), finished by one carry-chain adder when every column is down
//! to ≤ 2 bits.

use super::lutmap::MappedCircuit;
use super::netlist::{Netlist, NodeId, Prim};
use super::SynthReport;

/// How many compressor levels between pipeline registers.
const PIPELINE_EVERY: u32 = 2;

/// Reduce weight columns until each holds ≤ 2 bits, then add the final
/// carry-propagate adder. Returns the result bit nodes.
pub fn compress_columns(nl: &mut Netlist, mut cols: Vec<Vec<NodeId>>) -> Vec<NodeId> {
    let mut level = 0u32;
    loop {
        let worst = cols.iter().map(|c| c.len()).max().unwrap_or(0);
        if worst <= 2 {
            break;
        }
        // One compressor level across all columns.
        let mut next: Vec<Vec<NodeId>> = vec![Vec::new(); cols.len() + 3];
        for (w, col) in cols.iter().enumerate() {
            let mut i = 0;
            // 6:3 compressors while at least 6 bits remain.
            while col.len() - i >= 6 {
                let n = nl.add(Prim::Compressor63, &col[i..i + 6]);
                next[w].push(n);
                next[w + 1].push(n);
                next[w + 2].push(n);
                i += 6;
            }
            // 3:2 full adders for 3..5 leftovers.
            while col.len() - i >= 3 {
                let n = nl.add(Prim::Compressor32, &col[i..i + 3]);
                next[w].push(n);
                next[w + 1].push(n);
                i += 3;
            }
            // 1–2 leftover bits pass through.
            for &b in &col[i..] {
                next[w].push(b);
            }
        }
        while next.last().map(|c| c.is_empty()) == Some(true) {
            next.pop();
        }
        cols = next;
        level += 1;
        if level % PIPELINE_EVERY == 0 {
            // Register every live bit (retiming-friendly pipelining).
            for col in cols.iter_mut() {
                for b in col.iter_mut() {
                    *b = nl.add(Prim::Reg { w: 1 }, &[*b]);
                }
            }
        }
    }
    // Final carry-propagate add of the two remaining rows.
    let width = cols.len() as u32;
    let all: Vec<NodeId> = cols.iter().flatten().copied().collect();
    if all.is_empty() {
        return Vec::new();
    }
    let needs_adder = cols.iter().any(|c| c.len() > 1);
    if needs_adder {
        let sum = nl.add(Prim::AdderCarry { w: width }, &all);
        let reg = nl.add(Prim::Reg { w: width + 1 }, &[sum]);
        vec![reg]
    } else {
        all
    }
}

/// Build a popcount unit of width `n` into `nl`. Returns result node(s).
pub fn build_popcount(nl: &mut Netlist, n: u32) -> Vec<NodeId> {
    let input = nl.input();
    let cols = vec![vec![input; n as usize]];
    compress_columns(nl, cols)
}

/// Characterize a popcount unit (the paper's Fig. 6 experiment).
pub fn synth_popcount(n: u32) -> SynthReport {
    let mut nl = Netlist::new();
    build_popcount(&mut nl, n);
    let m = MappedCircuit::of(&nl);
    m.report(m.luts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_widths() {
        // popcount(3): one 3:2 compressor (2 LUTs), no adder needed.
        let r = synth_popcount(3);
        assert_eq!(r.luts, 2.0);
        // popcount(6): one 6:3 (3 LUTs).
        let r = synth_popcount(6);
        assert_eq!(r.luts, 3.0);
    }

    #[test]
    fn linear_scaling_like_fig6() {
        // Least-squares slope over the Fig. 6 sweep should be ~1 LUT/bit.
        let widths = [32u32, 64, 128, 256, 512, 1024];
        let pts: Vec<(f64, f64)> = widths
            .iter()
            .map(|&n| (n as f64, synth_popcount(n).luts))
            .collect();
        let n = pts.len() as f64;
        let sx: f64 = pts.iter().map(|p| p.0).sum();
        let sy: f64 = pts.iter().map(|p| p.1).sum();
        let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
        let slope = (n * sxy - sx * sy) / (n * sxx - sx * sx);
        assert!(
            (0.8..=1.3).contains(&slope),
            "slope {slope:.3} LUT/bit vs Fig. 6's ~1"
        );
    }

    #[test]
    fn monotone_in_width() {
        let mut prev = 0.0;
        for n in [8u32, 16, 32, 64, 128, 256] {
            let l = synth_popcount(n).luts;
            assert!(l > prev);
            prev = l;
        }
    }

    #[test]
    fn pipelining_bounds_stage_depth() {
        // Even popcount(1024) must keep stages ≤ PIPELINE_EVERY levels +
        // the final adder's carry tail.
        let mut nl = Netlist::new();
        build_popcount(&mut nl, 1024);
        assert!(
            nl.stage_depth() <= 4.0,
            "stage depth {} not pipelined",
            nl.stage_depth()
        );
    }
}
