//! Board-level power model (paper Table V).
//!
//! The paper measures PYNQ-Z1 wall power with a USB power meter while
//! looping individual stages. We cannot measure a board, so this module
//! implements an analytic CMOS-style model
//!
//! ```text
//! P_idle  = c0 + (c1 + c2·LUT)·f_clk          (static + clock tree)
//! ΔP_exec = c3·(D_m·D_n·D_k)·f_clk            (DPA switching)
//! ΔP_f&r  = c4 + c5·f_clk                     (DMA + DRAM I/O activity)
//! P_full  = P_idle + ΔP_exec + ΔP_f&r
//! ```
//!
//! whose six constants are **calibrated by least squares against the
//! paper's own Table V measurements** (the documented substitution for
//! the power meter). The regenerated table therefore reproduces the
//! paper's qualitative findings — execute contributes ~10% of full
//! power, fetch+result ~27%, idle ~66%, and a large-slow design beats a
//! small-fast one by ~1.5× in GOPS/W — while the per-row numbers carry
//! the model's residual error (reported in EXPERIMENTS.md).

use crate::arch::BismoConfig;
use crate::costmodel::{least_squares, CostModel};

/// Calibrated power model.
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Static power (W).
    pub c0: f64,
    /// Clock-tree power per MHz (W/MHz).
    pub c1: f64,
    /// Clock-tree power per LUT per MHz (W/(LUT·MHz)).
    pub c2: f64,
    /// DPA switching power per (DPU·bit) per MHz.
    pub c3: f64,
    /// DMA static adder (W).
    pub c4: f64,
    /// DMA/DRAM activity power per MHz.
    pub c5: f64,
}

/// One calibration / validation row: Table V of the paper.
#[derive(Clone, Copy, Debug)]
pub struct TableVRow {
    pub instance: u32,
    pub fclk_mhz: u32,
    pub idle_w: f64,
    pub exec_inc_w: f64,
    pub fr_inc_w: f64,
    pub full_w: f64,
    pub gops: f64,
}

/// The paper's Table V measurements (calibration data).
pub const TABLE_V: [TableVRow; 6] = [
    TableVRow { instance: 1, fclk_mhz: 200, idle_w: 2.53, exec_inc_w: 0.33, fr_inc_w: 1.09, full_w: 4.07, gops: 1638.0 },
    TableVRow { instance: 2, fclk_mhz: 100, idle_w: 2.10, exec_inc_w: 0.19, fr_inc_w: 0.87, full_w: 3.11, gops: 1638.0 },
    TableVRow { instance: 3, fclk_mhz: 50, idle_w: 1.76, exec_inc_w: 0.30, fr_inc_w: 0.63, full_w: 2.53, gops: 1638.0 },
    TableVRow { instance: 4, fclk_mhz: 200, idle_w: 2.53, exec_inc_w: 0.34, fr_inc_w: 1.09, full_w: 3.86, gops: 1638.0 },
    TableVRow { instance: 5, fclk_mhz: 100, idle_w: 2.05, exec_inc_w: 0.24, fr_inc_w: 0.92, full_w: 3.06, gops: 1638.0 },
    TableVRow { instance: 3, fclk_mhz: 200, idle_w: 2.87, exec_inc_w: 0.71, fr_inc_w: 1.19, full_w: 4.64, gops: 6554.0 },
];

impl PowerModel {
    /// Fit the six constants to the paper's Table V.
    pub fn calibrated() -> Self {
        let lut = |i: u32| {
            CostModel::paper().lut_total(&crate::arch::instance(i))
        };
        // Idle: c0 + c1·f + c2·LUT·f.
        let idle_x: Vec<Vec<f64>> = TABLE_V
            .iter()
            .map(|r| {
                vec![
                    1.0,
                    r.fclk_mhz as f64,
                    lut(r.instance) * r.fclk_mhz as f64,
                ]
            })
            .collect();
        let idle_y: Vec<f64> = TABLE_V.iter().map(|r| r.idle_w).collect();
        let bi = least_squares(&idle_x, &idle_y).expect("Table V idle fit is well-conditioned");

        // Exec increment: c3·(Dm·Dn·Dk)·f (single coefficient).
        let ex: Vec<f64> = TABLE_V
            .iter()
            .map(|r| {
                let c = crate::arch::instance(r.instance);
                (c.dm * c.dn * c.dk) as f64 * r.fclk_mhz as f64
            })
            .collect();
        let c3 = {
            let num: f64 = TABLE_V
                .iter()
                .zip(&ex)
                .map(|(r, x)| r.exec_inc_w * x)
                .sum();
            let den: f64 = ex.iter().map(|x| x * x).sum();
            num / den
        };

        // Fetch+result increment: c4 + c5·f.
        let fr_x: Vec<Vec<f64>> = TABLE_V
            .iter()
            .map(|r| vec![1.0, r.fclk_mhz as f64])
            .collect();
        let fr_y: Vec<f64> = TABLE_V.iter().map(|r| r.fr_inc_w).collect();
        let bf = least_squares(&fr_x, &fr_y).expect("Table V fetch/result fit is well-conditioned");

        PowerModel {
            c0: bi[0],
            c1: bi[1],
            c2: bi[2],
            c3,
            c4: bf[0],
            c5: bf[1],
        }
    }

    pub fn idle_w(&self, cfg: &BismoConfig) -> f64 {
        let lut = CostModel::paper().lut_total(cfg);
        self.c0 + (self.c1 + self.c2 * lut) * cfg.fclk_mhz as f64
    }

    pub fn exec_increment_w(&self, cfg: &BismoConfig) -> f64 {
        self.c3 * (cfg.dm * cfg.dn * cfg.dk) as f64 * cfg.fclk_mhz as f64
    }

    pub fn fetch_result_increment_w(&self, cfg: &BismoConfig) -> f64 {
        self.c4 + self.c5 * cfg.fclk_mhz as f64
    }

    pub fn full_w(&self, cfg: &BismoConfig) -> f64 {
        self.idle_w(cfg) + self.exec_increment_w(cfg) + self.fetch_result_increment_w(cfg)
    }

    /// Peak binary GOPS per watt at full power.
    pub fn gops_per_w(&self, cfg: &BismoConfig) -> f64 {
        cfg.peak_binary_gops() / self.full_w(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::instance;

    #[test]
    fn calibration_residuals_small() {
        let m = PowerModel::calibrated();
        for r in &TABLE_V {
            let cfg = instance(r.instance).at_clock(r.fclk_mhz);
            let idle = m.idle_w(&cfg);
            assert!(
                (idle - r.idle_w).abs() < 0.25,
                "idle({},{}MHz) {idle:.2} vs {}",
                r.instance,
                r.fclk_mhz,
                r.idle_w
            );
            let full = m.full_w(&cfg);
            assert!(
                (full - r.full_w).abs() / r.full_w < 0.12,
                "full({},{}MHz) {full:.2} vs {}",
                r.instance,
                r.fclk_mhz,
                r.full_w
            );
        }
    }

    #[test]
    fn component_shares_match_paper_story() {
        // Paper: exec ≈ 9.7%, fetch+result ≈ 27.2%, idle ≈ 65.6% of
        // full power on average.
        let m = PowerModel::calibrated();
        let mut shares = [0.0f64; 3];
        for r in &TABLE_V {
            let cfg = instance(r.instance).at_clock(r.fclk_mhz);
            let full = m.full_w(&cfg);
            shares[0] += m.idle_w(&cfg) / full;
            shares[1] += m.exec_increment_w(&cfg) / full;
            shares[2] += m.fetch_result_increment_w(&cfg) / full;
        }
        let n = TABLE_V.len() as f64;
        assert!((shares[0] / n - 0.656).abs() < 0.06, "idle share {}", shares[0] / n);
        assert!((shares[1] / n - 0.097).abs() < 0.05, "exec share {}", shares[1] / n);
        assert!((shares[2] / n - 0.272).abs() < 0.06, "f&r share {}", shares[2] / n);
    }

    #[test]
    fn large_slow_beats_small_fast() {
        // Paper: #3 at 50 MHz is ~1.5× more efficient than #1 at 200 MHz
        // for the same 1638 GOPS.
        let m = PowerModel::calibrated();
        let small_fast = m.gops_per_w(&instance(1).at_clock(200));
        let large_slow = 1638.4 / m.full_w(&instance(3).at_clock(50));
        let ratio = large_slow / small_fast;
        assert!(
            (1.25..=1.9).contains(&ratio),
            "efficiency ratio {ratio:.2} vs paper ~1.5×"
        );
    }

    #[test]
    fn headline_efficiency_band() {
        // Paper: #3 @ 200 MHz → 1413 GOPS/W (DRAM included).
        let m = PowerModel::calibrated();
        let g = m.gops_per_w(&instance(3).at_clock(200));
        assert!(
            (1100.0..=1800.0).contains(&g),
            "headline GOPS/W {g:.0} vs paper 1413"
        );
    }

    #[test]
    fn power_increases_with_clock() {
        let m = PowerModel::calibrated();
        let p50 = m.full_w(&instance(3).at_clock(50));
        let p200 = m.full_w(&instance(3).at_clock(200));
        assert!(p200 > p50);
    }
}
