//! The software half of BISMO (paper §III-C): compiles a matrix
//! multiplication job into the three per-stage instruction streams.
//!
//! Given a [`MatmulJob`] (dimensions, precisions, DRAM layouts) and a
//! [`BismoConfig`], the scheduler:
//!
//! 1. **Tiles** the output into `D_m × D_n` tiles and the inner `k`
//!    dimension into `D_k`-bit chunks ([`plan()`]).
//! 2. Picks a **schedule mode**: `RhsResident` keeps a group of RHS
//!    tile-columns on-chip and streams LHS tiles past them
//!    (double-buffered), minimizing DRAM traffic; `Streaming` falls back
//!    to per-tile-pair fetching with `k`-slicing when buffers are too
//!    small to hold full dot products.
//! 3. **Emits** fetch/execute/result instructions with the token
//!    protocol that lets the three stages overlap ([`emit()`]), or a
//!    fully serialized variant ([`Overlap::None`]) used for the paper's
//!    stage-overlap experiment (§IV-B3).
//!
//! The sparse **bit-skip** extension (paper §III: "dynamically skip bit
//! positions for sparse or approximate computing") drops all-zero
//! bit-planes from the plane lists before emission.

mod emit;
mod plan;

pub use emit::emit;
pub use plan::{plan, MatmulJob, Mode, Plan};

use crate::api::BismoError;
use crate::arch::BismoConfig;
use crate::bitmatrix::{plane_sign, BitSerialMatrix};
use crate::isa::{ExecuteRun, Instr, Program, Stage};

/// How aggressively stages may run concurrently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Overlap {
    /// Double-buffered fetch, pipelined result drain — the paper's
    /// intended operating mode.
    Full,
    /// Every stage round-trips with its neighbours; used as the
    /// baseline in the paper's 2.2× stage-overlap experiment.
    None,
}

/// One operand's bit-planes as scheduled: `(plane index, negate)`.
/// Derived from precision + signedness, optionally with zero planes
/// skipped.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlaneList {
    pub planes: Vec<(u32, bool)>,
    /// Declared operand precision (for weight computation).
    pub bits: u32,
}

impl PlaneList {
    /// All planes of a `bits`-wide (signed?) operand.
    pub fn full(bits: u32, signed: bool) -> Self {
        PlaneList {
            planes: (0..bits)
                .map(|i| (i, plane_sign(i, bits, signed) < 0))
                .collect(),
            bits,
        }
    }

    /// Planes of `m` that are not entirely zero (bit-skip extension).
    /// Uses the shared [`BitSerialMatrix::nonzero_planes`] filter — the
    /// same zero-plane test the tiled software kernel applies.
    pub fn nonzero(m: &BitSerialMatrix) -> Self {
        PlaneList {
            planes: m
                .nonzero_planes()
                .into_iter()
                .map(|i| (i, plane_sign(i, m.bits, m.signed) < 0))
                .collect(),
            bits: m.bits,
        }
    }

    pub fn len(&self) -> usize {
        self.planes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.planes.is_empty()
    }
}

/// Compile `job` into a program for `cfg`.
///
/// Convenience wrapper over [`plan()`] + [`emit()`] with full plane lists.
pub fn compile(
    job: &MatmulJob,
    cfg: &BismoConfig,
    overlap: Overlap,
) -> Result<Program, BismoError> {
    let lhs_planes = PlaneList::full(job.wbits, job.lsigned);
    let rhs_planes = PlaneList::full(job.abits, job.rsigned);
    compile_with_planes(job, cfg, overlap, &lhs_planes, &rhs_planes)
}

/// Compile with explicit plane lists (bit-skip or custom precision).
pub fn compile_with_planes(
    job: &MatmulJob,
    cfg: &BismoConfig,
    overlap: Overlap,
    lhs_planes: &PlaneList,
    rhs_planes: &PlaneList,
) -> Result<Program, BismoError> {
    let p = plan(job, cfg, lhs_planes.len() as u32, rhs_planes.len() as u32)?;
    emit(job, cfg, &p, overlap, lhs_planes, rhs_planes)
}

/// Build the execute-only benchmark program used by the paper's
/// "peak binary compute" experiment (Fig. 12): `bursts` accumulation
/// groups, each a burst of `pairs` back-to-back RunExecutes over
/// `k_chunks` chunks, with no fetch/result stages involved (data is
/// whatever resides in the buffers — timing is data-independent).
pub fn peak_execute_program(
    cfg: &BismoConfig,
    k_chunks: u32,
    bursts: u32,
    pairs: u32,
) -> Result<Program, BismoError> {
    let max_off = k_chunks as u64;
    if max_off > cfg.bm as u64 || max_off > cfg.bn as u64 {
        return Err(BismoError::CapacityExceeded(format!(
            "k_chunks {} exceeds buffer depth (bm {}, bn {})",
            k_chunks, cfg.bm, cfg.bn
        )));
    }
    let mut prog = Program::new();
    for _ in 0..bursts {
        for p in 0..pairs {
            prog.push(
                Stage::Execute,
                Instr::Execute(ExecuteRun {
                    lhs_offset: 0,
                    rhs_offset: 0,
                    num_chunks: k_chunks,
                    shift: (p % 2) as u8,
                    negate: false,
                    acc_reset: p == 0,
                    commit_result: false,
                }),
            );
        }
    }
    Ok(prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitmatrix::IntMatrix;

    #[test]
    fn plane_list_full_unsigned() {
        let p = PlaneList::full(3, false);
        assert_eq!(p.planes, vec![(0, false), (1, false), (2, false)]);
    }

    #[test]
    fn plane_list_full_signed_msb_negated() {
        let p = PlaneList::full(3, true);
        assert_eq!(p.planes, vec![(0, false), (1, false), (2, true)]);
    }

    #[test]
    fn plane_list_nonzero_skips() {
        // Values {0, 2}: plane 0 all-zero, plane 1 populated.
        let m = IntMatrix::from_slice(2, 2, &[0, 2, 2, 0]);
        let bs = BitSerialMatrix::from_int(&m, 3, false);
        let p = PlaneList::nonzero(&bs);
        assert_eq!(p.planes, vec![(1, false)]);
        assert_eq!(p.bits, 3);
    }

    #[test]
    fn peak_program_shape() {
        let cfg = BismoConfig::small();
        let p = peak_execute_program(&cfg, 8, 3, 4).unwrap();
        assert_eq!(p.execute.len(), 12);
        assert!(p.fetch.is_empty() && p.result.is_empty());
        p.validate().unwrap();
        // First of each burst resets; others accumulate.
        let resets: Vec<bool> = p
            .execute
            .iter()
            .map(|i| match i {
                Instr::Execute(e) => e.acc_reset,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(resets.iter().filter(|&&r| r).count(), 3);
        assert!(resets[0] && resets[4] && resets[8]);
    }

    #[test]
    fn peak_program_checks_depth() {
        let cfg = BismoConfig::small();
        assert!(peak_execute_program(&cfg, 5000, 1, 1).is_err());
    }
}
