//! Schedule-mode selection over the shared tiling plan.
//!
//! The tiling arithmetic itself — how `m`, `n` and `k` divide into
//! `D_m × D_n × D_k` tiles — lives in [`crate::partition::TilePlan`];
//! this module decides what the overlay *does* with those tiles
//! (RHS-resident grouping vs `k`-sliced streaming) under the buffer
//! capacities of a [`BismoConfig`].

use crate::api::BismoError;
use crate::arch::BismoConfig;
use crate::bitmatrix::dram::{OperandLayout, ResultLayout};
use crate::coordinator::Precision;
use crate::partition::TilePlan;
use crate::util::ceil_div;

/// A matrix multiplication job: `P(m×n) = L(m×k) · R(k×n)`, with the
/// RHS stored transposed (`n×k`) as the overlay requires.
#[derive(Clone, Copy, Debug)]
pub struct MatmulJob {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// LHS precision in bits.
    pub wbits: u32,
    /// RHS precision in bits.
    pub abits: u32,
    pub lsigned: bool,
    pub rsigned: bool,
    /// DRAM placement of the LHS (`m×k`, `wbits` planes).
    pub lhs: OperandLayout,
    /// DRAM placement of the transposed RHS (`n×k`, `abits` planes).
    pub rhs: OperandLayout,
    /// DRAM placement of the `m×n` i32 result.
    pub res: ResultLayout,
}

impl MatmulJob {
    /// Check internal consistency and compatibility with `cfg`.
    pub fn validate(&self, cfg: &BismoConfig) -> Result<(), BismoError> {
        if self.m == 0 || self.k == 0 || self.n == 0 {
            return Err(BismoError::ShapeMismatch(
                "matrix dimensions must be non-zero".into(),
            ));
        }
        // The shared precision gate: 1..=32 bits per side, combined
        // width inside the accumulator's weight range.
        Precision {
            wbits: self.wbits,
            abits: self.abits,
            lsigned: self.lsigned,
            rsigned: self.rsigned,
        }
        .validate()?;
        let checks = [
            (self.lhs.rows == self.m, "lhs layout rows != m"),
            (self.lhs.cols == self.k, "lhs layout cols != k"),
            (self.lhs.bits == self.wbits, "lhs layout bits != wbits"),
            (self.rhs.rows == self.n, "rhs layout rows != n (must be transposed)"),
            (self.rhs.cols == self.k, "rhs layout cols != k"),
            (self.rhs.bits == self.abits, "rhs layout bits != abits"),
            (self.lhs.dk == cfg.dk, "lhs layout chunk width != D_k"),
            (self.rhs.dk == cfg.dk, "rhs layout chunk width != D_k"),
            (self.res.rows == self.m, "result layout rows != m"),
            (self.res.cols == self.n, "result layout cols != n"),
        ];
        for (ok, msg) in checks {
            if !ok {
                return Err(BismoError::ShapeMismatch(msg.into()));
            }
        }
        // Region overlap in DRAM would corrupt operands with results.
        let spans = [
            (self.lhs.base, self.lhs.base + self.lhs.total_bytes()),
            (self.rhs.base, self.rhs.base + self.rhs.total_bytes()),
            (self.res.base, self.res.base + self.res.total_bytes()),
        ];
        for i in 0..3 {
            for j in (i + 1)..3 {
                let (a0, a1) = spans[i];
                let (b0, b1) = spans[j];
                if a0 < b1 && b0 < a1 {
                    return Err(BismoError::InvalidConfig(format!(
                        "DRAM regions overlap: [{a0},{a1}) vs [{b0},{b1})"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Total binary operations of this job (paper convention).
    pub fn binary_ops(&self) -> u64 {
        crate::baseline::binary_ops(
            self.m as u64,
            self.k as u64,
            self.n as u64,
            self.wbits,
            self.abits,
        )
    }
}

/// Schedule structure chosen by [`plan()`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// A group of `tiles_per_group` RHS tile-columns stays resident in
    /// the RHS buffers while LHS tiles stream past (double-buffered).
    RhsResident { tiles_per_group: usize },
    /// Both operands streamed per tile pair, `k` sliced into
    /// `slice_chunks`-chunk pieces that fit half a buffer.
    Streaming { slice_chunks: usize },
}

/// The scheduling decisions for one job on one configuration: the
/// shared [`TilePlan`] (hardware-tile geometry) plus the chosen
/// [`Mode`] and the effective plane counts.
#[derive(Clone, Copy, Debug)]
pub struct Plan {
    pub mode: Mode,
    /// The `D_m × D_n × D_k` tiling of the job — the same
    /// [`TilePlan`] abstraction the software kernel tiler consumes.
    pub tiles: TilePlan,
    /// Effective plane counts being scheduled.
    pub lhs_planes: u32,
    pub rhs_planes: u32,
}

impl Plan {
    /// Output row tiles: `ceil(m / D_m)`.
    pub fn tm(&self) -> usize {
        self.tiles.row_tiles()
    }

    /// Output column tiles: `ceil(n / D_n)`.
    pub fn tn(&self) -> usize {
        self.tiles.col_tiles()
    }

    /// `k` chunks per full dot product: `ceil(k / D_k)`.
    pub fn kc(&self) -> usize {
        self.tiles.k_chunks()
    }

    /// Result-tile commits the schedule will perform (= `tm · tn`).
    pub fn commits(&self) -> usize {
        self.tiles.commits()
    }

    /// Number of RHS-resident groups (`RhsResident` mode), else 0.
    pub fn groups(&self) -> usize {
        match self.mode {
            Mode::RhsResident { tiles_per_group } => {
                ceil_div(self.tn() as u64, tiles_per_group as u64) as usize
            }
            Mode::Streaming { .. } => 0,
        }
    }

    /// Number of `k` slices per dot product (`Streaming` mode), else 1.
    pub fn slices(&self) -> usize {
        match self.mode {
            Mode::RhsResident { .. } => 1,
            Mode::Streaming { slice_chunks } => {
                ceil_div(self.kc() as u64, slice_chunks as u64) as usize
            }
        }
    }
}

/// Decide tiling + mode for `job` on `cfg` with the given effective
/// plane counts (post bit-skip).
pub fn plan(
    job: &MatmulJob,
    cfg: &BismoConfig,
    lhs_planes: u32,
    rhs_planes: u32,
) -> Result<Plan, BismoError> {
    job.validate(cfg)?;
    cfg.validate()?;
    if lhs_planes == 0 || rhs_planes == 0 {
        return Err(BismoError::InvalidConfig(
            "plane lists must be non-empty (all-zero operand: result is zero; \
             short-circuit upstream)"
                .into(),
        ));
    }
    // The tile geometry comes from the shared partition layer — the
    // same arithmetic the software kernel's tiler uses.
    let tiles = TilePlan::new(
        job.m,
        job.n,
        job.k,
        cfg.dm as usize,
        cfg.dn as usize,
        cfg.dk as usize,
    );
    let (tn, kc) = (tiles.col_tiles(), tiles.k_chunks());

    let lhs_words_needed = lhs_planes as usize * kc; // per LHS buffer, per m-tile
    let rhs_words_needed = rhs_planes as usize * kc; // per RHS buffer, per n-tile
    let lhs_half = (cfg.bm as usize) / 2;

    let mode = if lhs_words_needed <= lhs_half && rhs_words_needed <= cfg.bn as usize {
        // Full dot products fit: keep as many RHS tile-columns resident
        // as the RHS buffers hold, stream LHS double-buffered.
        let tiles_per_group = ((cfg.bn as usize) / rhs_words_needed).min(tn.max(1)).max(1);
        Mode::RhsResident { tiles_per_group }
    } else {
        // k must be sliced: the largest slice that fits half of each
        // buffer for every scheduled plane.
        let s_l = lhs_half / lhs_planes as usize;
        let s_r = (cfg.bn as usize / 2) / rhs_planes as usize;
        let slice_chunks = s_l.min(s_r).min(kc);
        if slice_chunks == 0 {
            return Err(BismoError::CapacityExceeded(format!(
                "buffers too small for precision: bm/2={} words for {} LHS planes, \
                 bn/2={} for {} RHS planes",
                lhs_half,
                lhs_planes,
                cfg.bn / 2,
                rhs_planes
            )));
        }
        Mode::Streaming { slice_chunks }
    };

    // Encoding limits (14-bit words_per_buf, 16-bit num_chunks).
    let max_words = match mode {
        Mode::RhsResident { .. } => kc,
        Mode::Streaming { slice_chunks } => slice_chunks,
    };
    if max_words >= (1 << 14) {
        return Err(BismoError::CapacityExceeded(format!(
            "schedule needs {max_words}-word fetches, exceeding the 14-bit ISA field"
        )));
    }

    Ok(Plan {
        mode,
        tiles,
        lhs_planes,
        rhs_planes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_job(m: usize, k: usize, n: usize, w: u32, a: u32, dk: u32) -> MatmulJob {
        let lhs = OperandLayout::new(0, m, k, w, dk);
        let rhs = OperandLayout::new(lhs.base + lhs.total_bytes(), n, k, a, dk);
        let res = ResultLayout::new(rhs.base + rhs.total_bytes(), m, n);
        MatmulJob {
            m,
            k,
            n,
            wbits: w,
            abits: a,
            lsigned: false,
            rsigned: false,
            lhs,
            rhs,
            res,
        }
    }

    #[test]
    fn small_job_is_rhs_resident() {
        let cfg = BismoConfig::small(); // 2×64×2, bm=bn=1024
        let job = mk_job(4, 256, 4, 2, 2, 64);
        let p = plan(&job, &cfg, 2, 2).unwrap();
        assert_eq!(p.tm(), 2);
        assert_eq!(p.tn(), 2);
        assert_eq!(p.kc(), 4);
        assert_eq!(p.commits(), 4);
        match p.mode {
            Mode::RhsResident { tiles_per_group } => {
                // 1024 / (2 planes · 4 chunks) = 128, capped at tn = 2.
                assert_eq!(tiles_per_group, 2);
                assert_eq!(p.groups(), 1);
            }
            _ => panic!("expected RhsResident"),
        }
    }

    #[test]
    fn huge_k_forces_streaming() {
        let cfg = BismoConfig::small();
        // kc = 4096 chunks > bm/2=512 per plane → stream with slices.
        let job = mk_job(2, 64 * 4096, 2, 1, 1, 64);
        let p = plan(&job, &cfg, 1, 1).unwrap();
        match p.mode {
            Mode::Streaming { slice_chunks } => {
                assert_eq!(slice_chunks, 512); // bm/2 / 1 plane, capped by bn/2
                assert_eq!(p.slices(), 8);
            }
            _ => panic!("expected Streaming"),
        }
    }

    #[test]
    fn high_precision_shrinks_slices() {
        let cfg = BismoConfig::small();
        let job = mk_job(2, 64 * 4096, 2, 8, 8, 64);
        let p = plan(&job, &cfg, 8, 8).unwrap();
        match p.mode {
            Mode::Streaming { slice_chunks } => {
                assert_eq!(slice_chunks, 512 / 8);
            }
            _ => panic!("expected Streaming"),
        }
    }

    #[test]
    fn buffer_too_small_detected() {
        let cfg = BismoConfig {
            bm: 4,
            bn: 4,
            ..BismoConfig::small()
        };
        let job = mk_job(2, 64 * 4096, 2, 8, 8, 64);
        assert!(plan(&job, &cfg, 8, 8).is_err());
    }

    #[test]
    fn job_validation_catches_mismatches() {
        let cfg = BismoConfig::small();
        let mut job = mk_job(4, 128, 4, 2, 2, 64);
        job.m = 5; // layout says 4
        assert!(job.validate(&cfg).is_err());
        let job2 = mk_job(4, 128, 4, 2, 2, 128); // layout dk != cfg dk
        assert!(job2.validate(&cfg).is_err());
        let mut job3 = mk_job(4, 128, 4, 2, 2, 64);
        job3.res = ResultLayout::new(0, 4, 4); // overlaps lhs
        assert!(job3.validate(&cfg).is_err());
    }

    #[test]
    fn partial_tiles_counted() {
        let cfg = BismoConfig::small(); // 2×2 DPA
        let job = mk_job(5, 100, 3, 1, 1, 64);
        let p = plan(&job, &cfg, 1, 1).unwrap();
        assert_eq!(p.tm(), 3); // ceil(5/2)
        assert_eq!(p.tn(), 2); // ceil(3/2)
        assert_eq!(p.kc(), 2); // ceil(100/64)
        // The hardware tile spans come from the shared partition layer.
        assert_eq!(p.tiles.rows.span(2), 4..5);
    }
}
