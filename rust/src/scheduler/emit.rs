//! Instruction emission: turns a [`Plan`] into the three synchronized
//! instruction queues.
//!
//! Internally builds a round-based IR — fetch rounds paired 1:1 with
//! execute rounds — then lowers it to the anonymous-token protocol:
//!
//! * `FetchToExecute`: one signal per fetch round; execute waits once
//!   per fetch round it consumes, in order.
//! * `ExecuteToFetch`: "buffer region free" tokens. Each fetch round
//!   that reuses a region records the execute round that must complete
//!   first; since token FIFOs pair waits with signals positionally, the
//!   required milestones are made non-decreasing (running max) and
//!   execute emits the matching signals right after each round.
//! * `ExecuteToResult` / `ResultToExecute`: result-buffer slot
//!   handshake. With [`Overlap::Full`], execute only waits once the
//!   `B_r` slots could all be in flight; with [`Overlap::None`], every
//!   commit round-trips through the result writer (the paper's
//!   serialized baseline).

use super::plan::{MatmulJob, Mode, Plan};
use super::{Overlap, PlaneList};
use crate::api::BismoError;
use crate::arch::BismoConfig;
use crate::isa::{ExecuteRun, FetchRun, Instr, Program, ResultRun, Stage, SyncChannel};
use crate::partition::BlockSplit;

/// IR: one fetch round (a set of RunFetch instructions that execute as a
/// unit and are acknowledged by a single FetchToExecute token).
struct FetchRound {
    instrs: Vec<FetchRun>,
    /// Execute round (by index) that must fully complete before this
    /// round may touch its destination region.
    requires_exec: Option<usize>,
}

/// IR: one burst of back-to-back RunExecutes (one accumulation group or
/// one slice of it), optionally committing a result tile.
struct Burst {
    execs: Vec<ExecuteRun>,
    commit: Option<ResultRun>,
}

/// IR: one execute round, consuming `consumes` fetch rounds.
struct ExecRound {
    consumes: usize,
    bursts: Vec<Burst>,
}

/// Emit the program for `job` under `plan`.
pub fn emit(
    job: &MatmulJob,
    cfg: &BismoConfig,
    plan: &Plan,
    overlap: Overlap,
    lhs_planes: &PlaneList,
    rhs_planes: &PlaneList,
) -> Result<Program, BismoError> {
    assert_eq!(lhs_planes.len() as u32, plan.lhs_planes);
    assert_eq!(rhs_planes.len() as u32, plan.rhs_planes);
    let ir = match plan.mode {
        Mode::RhsResident { tiles_per_group } => {
            build_rhs_resident(job, cfg, plan, overlap, lhs_planes, rhs_planes, tiles_per_group)
        }
        Mode::Streaming { slice_chunks } => {
            build_streaming(job, cfg, plan, overlap, lhs_planes, rhs_planes, slice_chunks)
        }
    }?;
    lower(ir, cfg, overlap)
}

/// Fetch-block size sanity vs the 16-bit (in 8-byte units) ISA field.
fn check_block(bytes: u64) -> Result<u32, BismoError> {
    if bytes / 8 >= (1 << 16) {
        return Err(BismoError::CapacityExceeded(format!(
            "fetch block of {bytes} bytes exceeds the ISA block-size field"
        )));
    }
    Ok(bytes as u32)
}

#[allow(clippy::too_many_arguments)]
fn build_rhs_resident(
    job: &MatmulJob,
    cfg: &BismoConfig,
    plan: &Plan,
    overlap: Overlap,
    lhs_planes: &PlaneList,
    rhs_planes: &PlaneList,
    tiles_per_group: usize,
) -> Result<(Vec<FetchRound>, Vec<ExecRound>), BismoError> {
    let dm = cfg.dm as usize;
    let kc = plan.kc() as u32;
    let regions = if overlap == Overlap::Full { 2 } else { 1 };
    let region_words = (cfg.bm as usize) / regions;
    let dist = regions; // LHS region reuse distance in rounds

    let mut fetch_rounds = Vec::new();
    let mut exec_rounds = Vec::new();
    let groups = plan.groups();
    for g in 0..groups {
        let tn_lo = g * tiles_per_group;
        let tn_hi = ((g + 1) * tiles_per_group).min(plan.tn());

        // RHS group fetch round: all planes of all tiles in the group.
        let mut rhs_instrs = Vec::new();
        for (u, tn) in (tn_lo..tn_hi).enumerate() {
            let cspan = plan.tiles.cols.span(tn);
            let cols = cspan.len();
            for (j_idx, &(pj, _)) in rhs_planes.planes.iter().enumerate() {
                rhs_instrs.push(FetchRun {
                    dram_base: job.rhs.addr(pj, cspan.start, 0),
                    block_bytes: check_block(job.rhs.row_bytes())?,
                    block_stride_bytes: check_block(job.rhs.row_bytes())?,
                    num_blocks: cols as u32,
                    buf_offset: (u * rhs_planes.len() as usize + j_idx) as u32 * kc,
                    buf_start: dm as u8,
                    buf_range: cols as u8,
                    words_per_buf: kc,
                });
            }
        }
        fetch_rounds.push(FetchRound {
            instrs: rhs_instrs,
            // The previous group's RHS data is in use until its last
            // execute round completes.
            requires_exec: if g > 0 { Some(g * plan.tm() - 1) } else { None },
        });

        for tm_i in 0..plan.tm() {
            let l_global = g * plan.tm() + tm_i;
            let rspan = plan.tiles.rows.span(tm_i);
            let rows = rspan.len();
            let region_base = ((l_global % regions) * region_words) as u32;

            // LHS tile fetch round (one RunFetch per scheduled plane).
            let mut lhs_instrs = Vec::new();
            for (i_idx, &(pi, _)) in lhs_planes.planes.iter().enumerate() {
                lhs_instrs.push(FetchRun {
                    dram_base: job.lhs.addr(pi, rspan.start, 0),
                    block_bytes: check_block(job.lhs.row_bytes())?,
                    block_stride_bytes: check_block(job.lhs.row_bytes())?,
                    num_blocks: rows as u32,
                    buf_offset: region_base + i_idx as u32 * kc,
                    buf_start: 0,
                    buf_range: rows as u8,
                    words_per_buf: kc,
                });
            }
            fetch_rounds.push(FetchRound {
                instrs: lhs_instrs,
                requires_exec: l_global.checked_sub(dist),
            });

            // Execute round: one burst per resident RHS tile.
            let mut bursts = Vec::new();
            for (u, tn) in (tn_lo..tn_hi).enumerate() {
                let cspan = plan.tiles.cols.span(tn);
                let cols = cspan.len();
                let mut execs = Vec::new();
                let npairs = lhs_planes.len() * rhs_planes.len();
                let mut pair = 0usize;
                for (i_idx, &(pi, ni)) in lhs_planes.planes.iter().enumerate() {
                    for (j_idx, &(pj, nj)) in rhs_planes.planes.iter().enumerate() {
                        execs.push(ExecuteRun {
                            lhs_offset: region_base + i_idx as u32 * kc,
                            rhs_offset: (u * rhs_planes.len() + j_idx) as u32 * kc,
                            num_chunks: kc,
                            shift: (pi + pj) as u8,
                            negate: ni ^ nj,
                            acc_reset: pair == 0,
                            commit_result: pair + 1 == npairs,
                        });
                        pair += 1;
                    }
                }
                bursts.push(Burst {
                    execs,
                    commit: Some(ResultRun {
                        dram_base: job.res.base,
                        offset: (rspan.start * job.n + cspan.start) as u64 * 4,
                        rows: rows as u8,
                        cols: cols as u8,
                        row_stride_bytes: job.n as u32 * 4,
                    }),
                });
            }
            exec_rounds.push(ExecRound {
                consumes: 1 + (tm_i == 0) as usize,
                bursts,
            });
        }
    }
    Ok((fetch_rounds, exec_rounds))
}

#[allow(clippy::too_many_arguments)]
fn build_streaming(
    job: &MatmulJob,
    cfg: &BismoConfig,
    plan: &Plan,
    overlap: Overlap,
    lhs_planes: &PlaneList,
    rhs_planes: &PlaneList,
    slice_chunks: usize,
) -> Result<(Vec<FetchRound>, Vec<ExecRound>), BismoError> {
    let dm = cfg.dm as usize;
    let regions = if overlap == Overlap::Full { 2 } else { 1 };
    let l_region_words = (cfg.bm as usize) / regions;
    let r_region_words = (cfg.bn as usize) / regions;
    let dist = regions;
    // The k-slice walk is itself a block split of the chunk axis.
    let kslices = BlockSplit::new(plan.kc(), slice_chunks);
    let slices = kslices.count();
    debug_assert_eq!(slices, plan.slices());
    let wpc = job.lhs.words_per_chunk as u64;

    let mut fetch_rounds = Vec::new();
    let mut exec_rounds = Vec::new();
    let mut round = 0usize;
    for tm_i in 0..plan.tm() {
        let rspan = plan.tiles.rows.span(tm_i);
        let rows = rspan.len();
        for tn_i in 0..plan.tn() {
            let cspan = plan.tiles.cols.span(tn_i);
            let cols = cspan.len();
            for s in 0..slices {
                let kspan = kslices.span(s);
                let (c0, sc) = (kspan.start, kspan.len());
                let l_base = ((round % regions) * l_region_words) as u32;
                let r_base = ((round % regions) * r_region_words) as u32;

                let mut instrs = Vec::new();
                for (i_idx, &(pi, _)) in lhs_planes.planes.iter().enumerate() {
                    instrs.push(FetchRun {
                        dram_base: job.lhs.addr(pi, rspan.start, c0),
                        block_bytes: check_block(sc as u64 * wpc * 8)?,
                        block_stride_bytes: check_block(job.lhs.row_bytes())?,
                        num_blocks: rows as u32,
                        buf_offset: l_base + (i_idx * slice_chunks) as u32,
                        buf_start: 0,
                        buf_range: rows as u8,
                        words_per_buf: sc as u32,
                    });
                }
                for (j_idx, &(pj, _)) in rhs_planes.planes.iter().enumerate() {
                    instrs.push(FetchRun {
                        dram_base: job.rhs.addr(pj, cspan.start, c0),
                        block_bytes: check_block(sc as u64 * wpc * 8)?,
                        block_stride_bytes: check_block(job.rhs.row_bytes())?,
                        num_blocks: cols as u32,
                        buf_offset: r_base + (j_idx * slice_chunks) as u32,
                        buf_start: dm as u8,
                        buf_range: cols as u8,
                        words_per_buf: sc as u32,
                    });
                }
                fetch_rounds.push(FetchRound {
                    instrs,
                    requires_exec: round.checked_sub(dist),
                });

                // One burst: all plane pairs over this slice.
                let mut execs = Vec::new();
                let npairs = lhs_planes.len() * rhs_planes.len();
                let mut pair = 0usize;
                for (i_idx, &(pi, ni)) in lhs_planes.planes.iter().enumerate() {
                    for (j_idx, &(pj, nj)) in rhs_planes.planes.iter().enumerate() {
                        execs.push(ExecuteRun {
                            lhs_offset: l_base + (i_idx * slice_chunks) as u32,
                            rhs_offset: r_base + (j_idx * slice_chunks) as u32,
                            num_chunks: sc as u32,
                            shift: (pi + pj) as u8,
                            negate: ni ^ nj,
                            // Fresh accumulation only on the tile's first
                            // slice; later slices extend the dot product.
                            acc_reset: pair == 0 && s == 0,
                            commit_result: pair + 1 == npairs && s + 1 == slices,
                        });
                        pair += 1;
                    }
                }
                let commit = if s + 1 == slices {
                    Some(ResultRun {
                        dram_base: job.res.base,
                        offset: (rspan.start * job.n + cspan.start) as u64 * 4,
                        rows: rows as u8,
                        cols: cols as u8,
                        row_stride_bytes: job.n as u32 * 4,
                    })
                } else {
                    None
                };
                exec_rounds.push(ExecRound {
                    consumes: 1,
                    bursts: vec![Burst { execs, commit }],
                });
                round += 1;
            }
        }
    }
    Ok((fetch_rounds, exec_rounds))
}

/// Lower the IR to the token protocol (see module docs).
fn lower(
    ir: (Vec<FetchRound>, Vec<ExecRound>),
    cfg: &BismoConfig,
    overlap: Overlap,
) -> Result<Program, BismoError> {
    let (fetch_rounds, exec_rounds) = ir;
    let mut prog = Program::new();

    // 1. Non-decreasing region-free milestones (positional pairing).
    let mut adjusted: Vec<Option<usize>> = Vec::with_capacity(fetch_rounds.len());
    let mut running: Option<usize> = None;
    for fr in &fetch_rounds {
        let adj = match (running, fr.requires_exec) {
            (None, r) => r,
            (Some(a), None) => Some(a), // keep monotone: later waits pop later tokens
            (Some(a), Some(r)) => Some(a.max(r)),
        };
        // Only rounds that *have* a requirement wait; rounds without one
        // must not consume tokens.
        adjusted.push(fr.requires_exec.map(|_| adj.unwrap()));
        if fr.requires_exec.is_some() {
            running = adj;
        }
    }

    // 2. Signals execute must emit after each of its rounds.
    let mut signals_after = vec![0usize; exec_rounds.len()];
    for adj in adjusted.iter().flatten() {
        if *adj >= exec_rounds.len() {
            return Err(BismoError::IllegalProgram(format!(
                "internal: milestone {adj} beyond {} exec rounds",
                exec_rounds.len()
            )));
        }
        signals_after[*adj] += 1;
    }

    // 3. Fetch queue.
    for (fr, adj) in fetch_rounds.iter().zip(&adjusted) {
        if adj.is_some() {
            prog.push(Stage::Fetch, Instr::Wait(SyncChannel::ExecuteToFetch));
        }
        for f in &fr.instrs {
            prog.push(Stage::Fetch, Instr::Fetch(*f));
        }
        prog.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
    }

    // 4. Execute + result queues.
    let total_commits: usize = exec_rounds
        .iter()
        .flat_map(|e| e.bursts.iter())
        .filter(|b| b.commit.is_some())
        .count();
    let br = cfg.br as usize;
    let mut commit_idx = 0usize;
    let mut result_queue: Vec<ResultRun> = Vec::with_capacity(total_commits);
    for (e, er) in exec_rounds.iter().enumerate() {
        for _ in 0..er.consumes {
            prog.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        }
        for burst in &er.bursts {
            let last = burst.execs.len() - 1;
            for (x, ex) in burst.execs.iter().enumerate() {
                let committing = x == last && burst.commit.is_some();
                debug_assert_eq!(ex.commit_result, committing);
                if committing && overlap == Overlap::Full && commit_idx >= br {
                    // A slot must have drained before this commit.
                    prog.push(Stage::Execute, Instr::Wait(SyncChannel::ResultToExecute));
                }
                prog.push(Stage::Execute, Instr::Execute(*ex));
                if committing {
                    prog.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToResult));
                    if overlap == Overlap::None {
                        // Serialized baseline: wait for our own drain.
                        prog.push(Stage::Execute, Instr::Wait(SyncChannel::ResultToExecute));
                    }
                    result_queue.push(burst.commit.unwrap());
                    commit_idx += 1;
                }
            }
        }
        for _ in 0..signals_after[e] {
            prog.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToFetch));
        }
    }

    for (c, rr) in result_queue.iter().enumerate() {
        prog.push(Stage::Result, Instr::Wait(SyncChannel::ExecuteToResult));
        prog.push(Stage::Result, Instr::Result(*rr));
        let do_signal = match overlap {
            Overlap::Full => c + br < total_commits,
            Overlap::None => true,
        };
        if do_signal {
            prog.push(Stage::Result, Instr::Signal(SyncChannel::ResultToExecute));
        }
    }

    prog.validate()?;
    Ok(prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PYNQ_Z1;
    use crate::baseline::gemm_bitserial;
    use crate::bitmatrix::dram::{DramImage, OperandLayout, ResultLayout};
    use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
    use crate::scheduler::{compile, Overlap};
    use crate::sim::Simulation;
    use crate::util::{property_sweep, Rng};

    /// Full pipeline check: build DRAM image, compile, simulate, compare
    /// against both oracles.
    fn run_case(
        cfg: &BismoConfig,
        rng: &mut Rng,
        m: usize,
        k: usize,
        n: usize,
        w: u32,
        a: u32,
        ls: bool,
        rs: bool,
        overlap: Overlap,
    ) -> (IntMatrix, crate::sim::RunStats) {
        let am = IntMatrix::random(rng, m, k, w, ls);
        let bm = IntMatrix::random(rng, k, n, a, rs);
        let labits = BitSerialMatrix::from_int(&am, w, ls);
        let rabits = BitSerialMatrix::from_int(&bm.transpose(), a, rs);
        let lhs = OperandLayout::new(0, m, k, w, cfg.dk);
        let rhs = OperandLayout::new(lhs.base + lhs.total_bytes(), n, k, a, cfg.dk);
        let res = ResultLayout::new(
            crate::util::round_up(rhs.base + rhs.total_bytes(), 8),
            m,
            n,
        );
        let mut dram = DramImage::new((res.base + res.total_bytes()) as usize);
        lhs.store(&mut dram, &labits);
        rhs.store(&mut dram, &rabits);
        let job = MatmulJob {
            m,
            k,
            n,
            wbits: w,
            abits: a,
            lsigned: ls,
            rsigned: rs,
            lhs,
            rhs,
            res,
        };
        let prog = compile(&job, cfg, overlap).expect("compile");
        let mut sim = Simulation::new(*cfg, &PYNQ_Z1, dram).expect("sim");
        let stats = sim.run(&prog).expect("run");
        let got = res.load(&sim.dram);
        let expect = am.matmul(&bm);
        assert_eq!(got, expect, "sim vs i64 reference");
        assert_eq!(
            gemm_bitserial(&labits, &rabits),
            expect,
            "cpu bit-serial oracle"
        );
        (got, stats)
    }

    #[test]
    fn exact_tile_binary() {
        let cfg = BismoConfig::small();
        let mut rng = Rng::new(101);
        run_case(&cfg, &mut rng, 2, 64, 2, 1, 1, false, false, Overlap::Full);
    }

    #[test]
    fn multi_tile_multi_bit() {
        let cfg = BismoConfig::small();
        let mut rng = Rng::new(102);
        let (_, stats) = run_case(&cfg, &mut rng, 6, 256, 6, 3, 2, true, false, Overlap::Full);
        assert_eq!(stats.commits, 9); // 3×3 tiles
    }

    #[test]
    fn partial_tiles_everywhere() {
        let cfg = BismoConfig::small();
        let mut rng = Rng::new(103);
        // m=5 (2+2+1), n=3 (2+1), k=100 (2 chunks, last partial).
        run_case(&cfg, &mut rng, 5, 100, 3, 2, 2, true, true, Overlap::Full);
    }

    #[test]
    fn streaming_mode_large_k() {
        let cfg = BismoConfig {
            bm: 64,
            bn: 64,
            ..BismoConfig::small()
        };
        let mut rng = Rng::new(104);
        // kc = 32 chunks > bm/2 per 2 planes → streaming with slices.
        let job_k = 64 * 32;
        let (_, stats) = run_case(&cfg, &mut rng, 4, job_k, 4, 2, 2, false, true, Overlap::Full);
        assert!(stats.cycles > 0);
    }

    #[test]
    fn overlap_none_matches_numerics_and_is_slower() {
        let cfg = BismoConfig::small();
        let mut rng1 = Rng::new(105);
        let mut rng2 = Rng::new(105);
        let (r_full, s_full) =
            run_case(&cfg, &mut rng1, 8, 512, 8, 2, 2, false, false, Overlap::Full);
        let (r_none, s_none) =
            run_case(&cfg, &mut rng2, 8, 512, 8, 2, 2, false, false, Overlap::None);
        assert_eq!(r_full, r_none);
        assert!(
            s_none.cycles > s_full.cycles,
            "serialized {} should exceed overlapped {}",
            s_none.cycles,
            s_full.cycles
        );
    }

    #[test]
    fn random_shape_sweep() {
        let cfg = BismoConfig::small();
        property_sweep(0x5CED, 15, |rng, _| {
            let m = rng.index(10) + 1;
            let k = rng.index(300) + 1;
            let n = rng.index(10) + 1;
            let w = rng.index(4) as u32 + 1;
            let a = rng.index(4) as u32 + 1;
            let (ls, rs) = (rng.chance(0.5), rng.chance(0.5));
            let ov = if rng.chance(0.5) {
                Overlap::Full
            } else {
                Overlap::None
            };
            run_case(&cfg, rng, m, k, n, w, a, ls, rs, ov);
        });
    }

    #[test]
    fn bit_skip_schedules_fewer_pairs() {
        use crate::scheduler::{compile_with_planes, PlaneList};
        let cfg = BismoConfig::small();
        let mut rng = Rng::new(106);
        // Operand with only even values: plane 0 is all-zero.
        let m = 4;
        let k = 128;
        let n = 4;
        let am = IntMatrix::from_fn(m, k, |r, c| (((r + c) % 4) * 2) as i64);
        let bm = IntMatrix::random(&mut rng, k, n, 2, false);
        let labits = BitSerialMatrix::from_int(&am, 3, false);
        let rabits = BitSerialMatrix::from_int(&bm.transpose(), 2, false);
        let lhs = OperandLayout::new(0, m, k, 3, cfg.dk);
        let rhs = OperandLayout::new(lhs.total_bytes(), n, k, 2, cfg.dk);
        let res = ResultLayout::new(rhs.base + rhs.total_bytes(), m, n);
        let mut dram = DramImage::new((res.base + res.total_bytes()) as usize);
        lhs.store(&mut dram, &labits);
        rhs.store(&mut dram, &rabits);
        let job = MatmulJob {
            m,
            k,
            n,
            wbits: 3,
            abits: 2,
            lsigned: false,
            rsigned: false,
            lhs,
            rhs,
            res,
        };
        let lp = PlaneList::nonzero(&labits);
        assert_eq!(lp.len(), 2); // plane 0 skipped
        let rp = PlaneList::full(2, false);
        let skip = compile_with_planes(&job, &cfg, Overlap::Full, &lp, &rp).unwrap();
        let full = compile(&job, &cfg, Overlap::Full).unwrap();
        assert!(skip.stats().execute_runs < full.stats().execute_runs);
        let mut sim = Simulation::new(cfg, &PYNQ_Z1, dram).unwrap();
        sim.run(&skip).unwrap();
        assert_eq!(res.load(&sim.dram), am.matmul(&bm));
    }
}
