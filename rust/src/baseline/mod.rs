//! CPU bit-serial matrix multiplication — the software baseline of
//! Umuroglu & Jahre ("Streamlined deployment for quantized neural
//! networks", the paper's reference [5]) reimplemented in Rust.
//!
//! Serves three roles:
//!
//! 1. The **correctness oracle** for the overlay simulator, the PJRT
//!    runtime path and the JAX/Pallas kernels (all must agree with it,
//!    and it must agree with [`IntMatrix::matmul`]).
//! 2. The **CPU comparison row** of Table VI.
//! 3. A realistic performance baseline for the §Perf pass: word-level
//!    AND + popcount is exactly what the DPU does, at 64-bit width.

mod gemm;

pub use gemm::{gemm_bitserial, gemm_bitserial_parallel};

use crate::bitmatrix::IntMatrix;

/// Binary-operation count of a `m×k×n` matmul at `w×a` bits, using the
/// paper's convention: a binary dot product of length `k` is `2k` ops
/// (AND + popcount-add), and the bit-serial expansion multiplies by the
/// `w·a` plane pairs.
pub fn binary_ops(m: u64, k: u64, n: u64, wbits: u32, abits: u32) -> u64 {
    2 * m * k * n * wbits as u64 * abits as u64
}

/// Reference i64 matmul (bit-parallel CPU baseline; wraps
/// [`IntMatrix::matmul`] for discoverability).
pub fn gemm_i64(l: &IntMatrix, r: &IntMatrix) -> IntMatrix {
    l.matmul(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_ops_counts_plane_pairs() {
        // 1-bit 2×2×2: 2·2·2·2 = 16 ops.
        assert_eq!(binary_ops(2, 2, 2, 1, 1), 16);
        // Scaling with precision is multiplicative.
        assert_eq!(binary_ops(2, 2, 2, 3, 2), 96);
    }
}
