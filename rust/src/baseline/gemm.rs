//! Word-parallel bit-serial GEMM kernels (Algorithm 1 on u64 words).
//!
//! [`gemm_bitserial`] is the crate's bit-exact reference oracle — keep
//! it simple and obviously correct. The fast path lives in
//! [`crate::kernel`] (tiled, plane-fused, zero-plane-skipping) and is
//! property-tested against this oracle.

use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
use crate::kernel::WorkerPool;
use std::sync::Mutex;

/// Bit-serial GEMM: `P = L · Rᵀ` where `L` is `m×k` and `r_t` is the
/// *transposed* right-hand side (`n×k`), both bit-plane decomposed.
///
/// This is Algorithm 1 with the two inner loops vectorized over 64-bit
/// words: for every plane pair `(i, j)` and every output `(r, c)`,
/// `popcount(L[i]_r & R[j]_c)` weighted by `±2^{i+j}`.
pub fn gemm_bitserial(l: &BitSerialMatrix, r_t: &BitSerialMatrix) -> IntMatrix {
    assert_eq!(
        l.cols, r_t.cols,
        "k mismatch: lhs {}×{}, rhs(T) {}×{}",
        l.rows, l.cols, r_t.rows, r_t.cols
    );
    let m = l.rows;
    let n = r_t.rows;
    let mut out = IntMatrix::zeros(m, n);
    gemm_rows(l, r_t, 0..m, &mut |r, c, v| out.set(r, c, v));
    out
}

/// Multi-threaded variant: splits output rows across up to `threads`
/// lanes of the shared persistent [`WorkerPool`] (no per-call thread
/// spawning).
pub fn gemm_bitserial_parallel(
    l: &BitSerialMatrix,
    r_t: &BitSerialMatrix,
    threads: usize,
) -> IntMatrix {
    assert_eq!(l.cols, r_t.cols, "k mismatch");
    let m = l.rows;
    let n = r_t.rows;
    if m == 0 || n == 0 {
        return IntMatrix::zeros(m, n);
    }
    let threads = threads.max(1).min(m);
    let mut data = vec![0i64; m * n];
    let rows_per = (m + threads - 1) / threads;
    let chunks: Vec<Mutex<&mut [i64]>> = data.chunks_mut(rows_per * n).map(Mutex::new).collect();
    WorkerPool::global().run_limited(chunks.len(), threads, &|t| {
        let lo = t * rows_per;
        let hi = (lo + rows_per).min(m);
        let mut guard = chunks[t].lock().unwrap();
        let chunk: &mut [i64] = &mut guard;
        gemm_rows(l, r_t, lo..hi, &mut |r, c, v| {
            chunk[(r - lo) * n + c] = v;
        });
    });
    drop(chunks);
    IntMatrix::from_slice(m, n, &data)
}

/// Compute output rows `rows` of the bit-serial product, reporting each
/// finished element through `sink(row, col, value)`.
fn gemm_rows(
    l: &BitSerialMatrix,
    r_t: &BitSerialMatrix,
    rows: std::ops::Range<usize>,
    sink: &mut dyn FnMut(usize, usize, i64),
) {
    let n = r_t.rows;
    for r in rows {
        for c in 0..n {
            let mut acc = 0i64;
            for i in 0..l.bits {
                let lrow = l.plane_row(i, r);
                let wl = l.plane_weight(i);
                for j in 0..r_t.bits {
                    let rrow = r_t.plane_row(j, c);
                    // Inner loop: the DPU operation at 64-bit width.
                    let mut pc = 0u64;
                    for (&x, &y) in lrow.iter().zip(rrow.iter()) {
                        pc += (x & y).count_ones() as u64;
                    }
                    acc += wl * r_t.plane_weight(j) * pc as i64;
                }
            }
            sink(r, c, acc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{property_sweep, Rng};

    fn check_against_reference(
        rng: &mut Rng,
        m: usize,
        k: usize,
        n: usize,
        wbits: u32,
        abits: u32,
        lsigned: bool,
        rsigned: bool,
    ) {
        let a = IntMatrix::random(rng, m, k, wbits, lsigned);
        let b = IntMatrix::random(rng, k, n, abits, rsigned);
        let expect = a.matmul(&b);
        let la = BitSerialMatrix::from_int(&a, wbits, lsigned);
        let rb = BitSerialMatrix::from_int(&b.transpose(), abits, rsigned);
        assert_eq!(
            gemm_bitserial(&la, &rb),
            expect,
            "m={m} k={k} n={n} w={wbits} a={abits} ls={lsigned} rs={rsigned}"
        );
    }

    #[test]
    fn paper_fig1_example() {
        let mut rng = Rng::new(0);
        let _ = &mut rng;
        let l = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
        let r = IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]);
        let lb = BitSerialMatrix::from_int(&l, 2, false);
        let rb = BitSerialMatrix::from_int(&r.transpose(), 2, false);
        assert_eq!(gemm_bitserial(&lb, &rb), l.matmul(&r));
    }

    #[test]
    fn matches_reference_sweep() {
        property_sweep(0x6E66, 40, |rng, _| {
            let m = rng.index(9) + 1;
            let k = rng.index(200) + 1;
            let n = rng.index(9) + 1;
            let w = rng.index(6) as u32 + 1;
            let a = rng.index(6) as u32 + 1;
            let (ls, rs) = (rng.chance(0.5), rng.chance(0.5));
            check_against_reference(rng, m, k, n, w, a, ls, rs);
        });
    }

    #[test]
    fn signed_extremes() {
        // All-minimum values stress the negative-MSB weighting.
        let mut rng = Rng::new(9);
        for bits in [2u32, 4, 8] {
            let lo = -(1i64 << (bits - 1));
            let a = IntMatrix::from_fn(3, 70, |_, _| lo);
            let b = IntMatrix::from_fn(70, 3, |_, _| lo);
            let la = BitSerialMatrix::from_int(&a, bits, true);
            let rb = BitSerialMatrix::from_int(&b.transpose(), bits, true);
            assert_eq!(gemm_bitserial(&la, &rb), a.matmul(&b), "bits={bits}");
        }
        let _ = &mut rng;
    }

    #[test]
    fn parallel_matches_serial() {
        property_sweep(0x9A4, 10, |rng, _| {
            let m = rng.index(33) + 1;
            let k = rng.index(300) + 1;
            let n = rng.index(17) + 1;
            let a = IntMatrix::random(rng, m, k, 3, true);
            let b = IntMatrix::random(rng, k, n, 3, true);
            let la = BitSerialMatrix::from_int(&a, 3, true);
            let rb = BitSerialMatrix::from_int(&b.transpose(), 3, true);
            let serial = gemm_bitserial(&la, &rb);
            for threads in [1, 2, 3, 8] {
                assert_eq!(gemm_bitserial_parallel(&la, &rb, threads), serial);
            }
        });
    }

    #[test]
    fn mixed_precision_sides() {
        let mut rng = Rng::new(31);
        check_against_reference(&mut rng, 4, 100, 4, 1, 8, false, true);
        check_against_reference(&mut rng, 4, 100, 4, 8, 1, true, false);
    }
}
