//! Input-adaptive precision policies: pick the *activation* bit width
//! per layer, per request, from the statistics of the activations
//! actually flowing through the network.
//!
//! The paper motivates bit-serial hardware with the observation that
//! "precision requirements may vary between different application
//! phases or depend on input data". The serving stack already supports
//! the mechanism — every prepared operator takes a per-execute
//! [`crate::coordinator::Precision`] override, and bit-serial work
//! scales with `wbits · abits` — this module supplies the *decision*:
//! a [`PrecisionPolicy`] inspects the [`ActivationStats`] of each
//! layer's input (range, entropy, sparsity) and chooses how many
//! bit-planes the activation side actually needs.
//!
//! Two regimes, deliberately separated:
//!
//! * **Exactness-preserving** ([`RangeAdaptivePolicy`]): never chooses
//!   fewer bits than the observed values need, so the GEMM results are
//!   bit-identical to the full-precision run — only the plane count
//!   (and therefore the work) drops. Falls back to the declared width
//!   whenever the statistics are degenerate (empty, negative, or
//!   over-range inputs).
//! * **Lossy** ([`ClampPolicy`], [`EntropyAdaptivePolicy`]): may
//!   saturate outliers to reach a narrower width. The accuracy cost is
//!   what `bismo attn-bench` measures as the accuracy proxy.
//!
//! Weight-side widths are never touched: weights are packed and cached
//! at their declared precision, and repacking them per request would
//! defeat the weight-stationary cache.
//!
//! Every choice is recorded as a [`PolicyDecision`] and surfaced in
//! the response, so a serving operator can audit exactly which width
//! served which layer of which request.

use crate::bitmatrix::IntMatrix;
use crate::util::ceil_log2;
use std::collections::BTreeMap;

/// Statistics of one layer's activation operand(s), the input to a
/// [`PrecisionPolicy`].
#[derive(Clone, Debug)]
pub struct ActivationStats {
    /// Total elements inspected.
    pub elements: usize,
    /// Smallest value observed.
    pub min: i64,
    /// Largest value observed.
    pub max: i64,
    /// Unsigned bits needed to represent every observed value exactly
    /// (`>= 1`; meaningful only when `min >= 0`).
    pub bits_needed: u32,
    /// Shannon entropy of the value distribution, in bits. Bounded by
    /// `bits_needed` for non-negative integer data, so it measures how
    /// much of the representable range the distribution actually uses.
    pub entropy_bits: f64,
    /// Fraction of non-zero elements (bit-serial work also scales with
    /// operand density when bit-skipping is on).
    pub nonzero_frac: f64,
}

impl ActivationStats {
    /// Statistics over one matrix.
    pub fn of(m: &IntMatrix) -> ActivationStats {
        ActivationStats::of_many(&[m])
    }

    /// Pooled statistics over several matrices — one layer's
    /// independent GEMM operands (e.g. the per-head score matrices)
    /// are decided together, so they pool.
    pub fn of_many(ms: &[&IntMatrix]) -> ActivationStats {
        let mut hist: BTreeMap<i64, usize> = BTreeMap::new();
        let (mut min, mut max) = (i64::MAX, i64::MIN);
        let mut elements = 0usize;
        let mut nonzero = 0usize;
        for m in ms {
            for &v in m.data() {
                elements += 1;
                min = min.min(v);
                max = max.max(v);
                nonzero += (v != 0) as usize;
                *hist.entry(v).or_insert(0) += 1;
            }
        }
        if elements == 0 {
            return ActivationStats {
                elements: 0,
                min: 0,
                max: 0,
                bits_needed: 1,
                entropy_bits: 0.0,
                nonzero_frac: 0.0,
            };
        }
        let n = elements as f64;
        let entropy_bits = hist
            .values()
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.log2()
            })
            .sum::<f64>();
        let bits_needed = if max <= 0 {
            1
        } else {
            ceil_log2(max as u64 + 1).max(1)
        };
        ActivationStats {
            elements,
            min,
            max,
            bits_needed,
            entropy_bits,
            nonzero_frac: nonzero as f64 / n,
        }
    }

    /// Degenerate statistics a conservative policy must not act on:
    /// nothing observed, negative values (these layers are unsigned
    /// activation domains), or values that do not even fit the
    /// declared width (the service will reject them range-checked —
    /// the policy must not mask that by clipping).
    pub fn degenerate_for(&self, base_bits: u32) -> bool {
        self.elements == 0 || self.min < 0 || self.bits_needed > base_bits
    }
}

/// One audited width choice: which layer and operand side, what the
/// declared width was, what was chosen, and why.
#[derive(Clone, Debug)]
pub struct PolicyDecision {
    /// Layer name (e.g. `"qkv"`, `"scores"`, `"ffn1"`).
    pub layer: &'static str,
    /// Operand side the choice applies to (`"lhs"` or `"rhs"`).
    pub side: &'static str,
    /// The declared (static) activation width.
    pub base_bits: u32,
    /// The width this request's layer actually ran at.
    pub chosen_bits: u32,
    /// Whether values must be saturated to fit `chosen_bits` (lossy
    /// policies only; exactness-preserving policies never set this).
    pub clip: bool,
    /// Largest activation observed when deciding.
    pub observed_max: i64,
    /// Entropy of the activation distribution, bits.
    pub entropy_bits: f64,
    /// Human-readable rationale (`"static"`, `"range"`, `"clamp"`,
    /// `"entropy"`, `"fallback: …"`).
    pub reason: String,
}

impl PolicyDecision {
    fn keep(
        layer: &'static str,
        side: &'static str,
        base_bits: u32,
        stats: &ActivationStats,
        reason: String,
    ) -> PolicyDecision {
        PolicyDecision {
            layer,
            side,
            base_bits,
            chosen_bits: base_bits,
            clip: false,
            observed_max: stats.max,
            entropy_bits: stats.entropy_bits,
            reason,
        }
    }
}

/// A per-request, per-layer activation-width chooser. Implementations
/// must be deterministic in their inputs: the same statistics must
/// yield the same decision, so replayed requests reproduce.
pub trait PrecisionPolicy {
    /// Stable policy name (decision logs, bench JSON).
    fn name(&self) -> &'static str;

    /// Choose the width for one layer's operand side. `base_bits` is
    /// the declared static width; implementations return it unchanged
    /// to opt out.
    fn decide(
        &self,
        layer: &'static str,
        side: &'static str,
        base_bits: u32,
        stats: &ActivationStats,
    ) -> PolicyDecision;
}

/// The do-nothing policy: every layer runs at its declared width.
/// This is also the conservative fallback the adaptive policies
/// degrade to on degenerate statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct StaticPolicy;

impl PrecisionPolicy for StaticPolicy {
    fn name(&self) -> &'static str {
        "static"
    }

    fn decide(
        &self,
        layer: &'static str,
        side: &'static str,
        base_bits: u32,
        stats: &ActivationStats,
    ) -> PolicyDecision {
        PolicyDecision::keep(layer, side, base_bits, stats, "static".into())
    }
}

/// Exactness-preserving adaptive policy: run each layer at exactly the
/// bits its observed activation range needs (floored at `min_bits`,
/// capped at the declared width). Because the chosen width always
/// holds every observed value, the GEMM result is bit-identical to the
/// full-width run — the policy changes the *work*, never the answer.
#[derive(Clone, Copy, Debug)]
pub struct RangeAdaptivePolicy {
    /// Never go below this many bits (1 is the natural floor).
    pub min_bits: u32,
}

impl Default for RangeAdaptivePolicy {
    fn default() -> Self {
        RangeAdaptivePolicy { min_bits: 1 }
    }
}

impl PrecisionPolicy for RangeAdaptivePolicy {
    fn name(&self) -> &'static str {
        "adaptive-range"
    }

    fn decide(
        &self,
        layer: &'static str,
        side: &'static str,
        base_bits: u32,
        stats: &ActivationStats,
    ) -> PolicyDecision {
        if stats.degenerate_for(base_bits) {
            return PolicyDecision::keep(
                layer,
                side,
                base_bits,
                stats,
                format!(
                    "fallback: degenerate stats (elements={}, min={}, max={})",
                    stats.elements, stats.min, stats.max
                ),
            );
        }
        let chosen = stats.bits_needed.max(self.min_bits).min(base_bits);
        PolicyDecision {
            layer,
            side,
            base_bits,
            chosen_bits: chosen,
            clip: false,
            observed_max: stats.max,
            entropy_bits: stats.entropy_bits,
            reason: "range".into(),
        }
    }
}

/// Lossy static clamp: every layer runs at `bits` (capped at the
/// declared width), saturating whatever does not fit. This is the
/// "static low precision" arm of the bench — the thing an adaptive
/// policy has to beat on accuracy at comparable throughput.
#[derive(Clone, Copy, Debug)]
pub struct ClampPolicy {
    /// Target width.
    pub bits: u32,
}

impl PrecisionPolicy for ClampPolicy {
    fn name(&self) -> &'static str {
        "static-clamp"
    }

    fn decide(
        &self,
        layer: &'static str,
        side: &'static str,
        base_bits: u32,
        stats: &ActivationStats,
    ) -> PolicyDecision {
        let chosen = self.bits.max(1).min(base_bits);
        PolicyDecision {
            layer,
            side,
            base_bits,
            chosen_bits: chosen,
            clip: chosen < stats.bits_needed || stats.min < 0,
            observed_max: stats.max,
            entropy_bits: stats.entropy_bits,
            reason: "clamp".into(),
        }
    }
}

/// Entropy-driven lossy policy: size the width to the *information* in
/// the distribution rather than its range, saturating rare outliers.
/// `ceil(entropy) + headroom` bits hold the bulk of a concentrated
/// distribution; a heavy tail costs accuracy, which the bench's proxy
/// makes visible. Falls back to the declared width on degenerate
/// statistics, like the range policy.
#[derive(Clone, Copy, Debug)]
pub struct EntropyAdaptivePolicy {
    /// Never go below this many bits.
    pub min_bits: u32,
    /// Extra bits on top of the measured entropy.
    pub headroom_bits: u32,
}

impl Default for EntropyAdaptivePolicy {
    fn default() -> Self {
        EntropyAdaptivePolicy {
            min_bits: 1,
            headroom_bits: 1,
        }
    }
}

impl PrecisionPolicy for EntropyAdaptivePolicy {
    fn name(&self) -> &'static str {
        "adaptive-entropy"
    }

    fn decide(
        &self,
        layer: &'static str,
        side: &'static str,
        base_bits: u32,
        stats: &ActivationStats,
    ) -> PolicyDecision {
        if stats.degenerate_for(base_bits) {
            return PolicyDecision::keep(
                layer,
                side,
                base_bits,
                stats,
                format!(
                    "fallback: degenerate stats (elements={}, min={}, max={})",
                    stats.elements, stats.min, stats.max
                ),
            );
        }
        let info = stats.entropy_bits.ceil() as u32 + self.headroom_bits;
        let chosen = info.max(self.min_bits).min(base_bits);
        PolicyDecision {
            layer,
            side,
            base_bits,
            chosen_bits: chosen,
            clip: chosen < stats.bits_needed,
            observed_max: stats.max,
            entropy_bits: stats.entropy_bits,
            reason: "entropy".into(),
        }
    }
}

/// Saturate every entry of `m` into unsigned `bits` range — how the
/// serving path applies a lossy decision before packing (the packer
/// itself range-checks and refuses, by design; clipping is an explicit
/// policy choice, never an implicit truncation).
pub fn clip_unsigned(m: &IntMatrix, bits: u32) -> IntMatrix {
    let hi = (1i64 << bits) - 1;
    IntMatrix::from_fn(m.rows, m.cols, |r, c| m.get(r, c).clamp(0, hi))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_measure_range_entropy_and_density() {
        let m = IntMatrix::from_slice(2, 4, &[0, 1, 1, 0, 3, 1, 0, 1]);
        let s = ActivationStats::of(&m);
        assert_eq!(s.elements, 8);
        assert_eq!((s.min, s.max), (0, 3));
        assert_eq!(s.bits_needed, 2);
        assert_eq!(s.nonzero_frac, 5.0 / 8.0);
        // Three distinct values → entropy strictly between 0 and 2,
        // and never above bits_needed.
        assert!(s.entropy_bits > 0.0 && s.entropy_bits <= s.bits_needed as f64);
        // Pooling two copies changes nothing distributional.
        let pooled = ActivationStats::of_many(&[&m, &m]);
        assert_eq!(pooled.elements, 16);
        assert_eq!(pooled.bits_needed, 2);
        assert!((pooled.entropy_bits - s.entropy_bits).abs() < 1e-12);
    }

    #[test]
    fn stats_edge_cases() {
        let zero = ActivationStats::of(&IntMatrix::zeros(2, 2));
        assert_eq!(zero.bits_needed, 1);
        assert_eq!(zero.entropy_bits, 0.0);
        assert_eq!(zero.nonzero_frac, 0.0);
        let empty = ActivationStats::of(&IntMatrix::zeros(0, 4));
        assert_eq!(empty.elements, 0);
        assert!(empty.degenerate_for(8));
        let neg = ActivationStats::of(&IntMatrix::from_slice(1, 2, &[-1, 2]));
        assert!(neg.degenerate_for(8));
    }

    #[test]
    fn range_policy_is_exactness_preserving() {
        let p = RangeAdaptivePolicy::default();
        // 2-bit data under an 8-bit declaration → 2 bits, no clip.
        let narrow = ActivationStats::of(&IntMatrix::from_slice(1, 3, &[0, 1, 3]));
        let d = p.decide("qkv", "lhs", 8, &narrow);
        assert_eq!(d.chosen_bits, 2);
        assert!(!d.clip);
        assert_eq!(d.reason, "range");
        // Full-range data → the declared width, still exact.
        let wide = ActivationStats::of(&IntMatrix::from_slice(1, 2, &[0, 255]));
        let d = p.decide("qkv", "lhs", 8, &wide);
        assert_eq!(d.chosen_bits, 8);
        assert!(!d.clip);
        // Over-range / negative data → conservative fallback to base.
        let over = ActivationStats::of(&IntMatrix::from_slice(1, 2, &[0, 300]));
        let d = p.decide("qkv", "lhs", 8, &over);
        assert_eq!(d.chosen_bits, 8);
        assert!(!d.clip);
        assert!(d.reason.starts_with("fallback"));
    }

    #[test]
    fn lossy_policies_flag_the_clip() {
        let stats = ActivationStats::of(&IntMatrix::from_slice(1, 4, &[0, 1, 2, 7]));
        let d = ClampPolicy { bits: 2 }.decide("ffn1", "lhs", 3, &stats);
        assert_eq!(d.chosen_bits, 2);
        assert!(d.clip, "7 does not fit 2 bits");
        // A clamp that happens to hold the data is not a clip.
        let d = ClampPolicy { bits: 3 }.decide("ffn1", "lhs", 3, &stats);
        assert_eq!(d.chosen_bits, 3);
        assert!(!d.clip);
        // Entropy policy on a concentrated distribution with one
        // outlier narrows below bits_needed and flags the clip.
        let spiky: Vec<i64> = std::iter::repeat_n(1, 63).chain([255]).collect();
        let s = ActivationStats::of(&IntMatrix::from_slice(8, 8, &spiky));
        let d = EntropyAdaptivePolicy::default().decide("scores", "lhs", 8, &s);
        assert!(d.chosen_bits < s.bits_needed, "{d:?}");
        assert!(d.clip);
    }

    #[test]
    fn clip_unsigned_saturates() {
        let m = IntMatrix::from_slice(1, 4, &[-2, 0, 3, 9]);
        assert_eq!(clip_unsigned(&m, 2), IntMatrix::from_slice(1, 4, &[0, 0, 3, 3]));
    }

    #[test]
    fn static_policy_never_deviates() {
        let s = ActivationStats::of(&IntMatrix::from_slice(1, 2, &[0, 1]));
        let d = StaticPolicy.decide("out", "lhs", 6, &s);
        assert_eq!((d.base_bits, d.chosen_bits, d.clip), (6, 6, false));
    }
}
