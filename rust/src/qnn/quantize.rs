//! Post-training quantization to the overlay's operand precisions.

use crate::bitmatrix::IntMatrix;

/// Quantize activations in `[0,1]` to unsigned `bits`-bit levels:
/// `q = round(x · (2^bits − 1))`.
pub fn quantize_activations(x: &[f32], bits: u32) -> Vec<i64> {
    let levels = ((1u32 << bits) - 1) as f32;
    x.iter()
        .map(|&v| (v.clamp(0.0, 1.0) * levels).round() as i64)
        .collect()
}

/// Symmetric per-tensor weight quantization to signed `bits`-bit:
/// `scale = max|w| / (2^{bits−1} − 1)`, `q = clamp(round(w / scale))`.
/// Returns the quantized matrix and the scale.
pub fn quantize_weights_symmetric(
    w: &[f32],
    rows: usize,
    cols: usize,
    bits: u32,
) -> (IntMatrix, f32) {
    assert_eq!(w.len(), rows * cols);
    let qmax = ((1i64 << (bits - 1)) - 1) as f32;
    let absmax = w.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-12);
    let scale = absmax / qmax;
    let data: Vec<i64> = w
        .iter()
        .map(|&v| ((v / scale).round() as i64).clamp(-(qmax as i64) - 1, qmax as i64))
        .collect();
    (IntMatrix::from_slice(rows, cols, &data), scale)
}

/// Integer-only requantization + ReLU, matching the L2 model's
/// `requantize` exactly: `clip(max(acc,0) >> shift, 0, 2^bits − 1)`.
pub fn requantize(acc: &IntMatrix, shift: u32, out_bits: u32) -> IntMatrix {
    let hi = (1i64 << out_bits) - 1;
    IntMatrix::from_fn(acc.rows, acc.cols, |r, c| {
        ((acc.get(r, c).max(0)) >> shift).min(hi)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn activation_levels() {
        let q = quantize_activations(&[0.0, 0.32, 0.34, 0.66, 1.0, 2.0, -1.0], 2);
        assert_eq!(q, vec![0, 1, 1, 2, 3, 3, 0]);
    }

    #[test]
    fn weight_quantization_symmetric() {
        let w = [-1.0f32, -0.5, 0.0, 0.5, 1.0];
        let (q, scale) = quantize_weights_symmetric(&w, 1, 5, 4);
        // qmax = 7; ±0.5/scale = 3.4999996 in f32 → rounds to ±3.
        assert_eq!(q.data(), &[-7, -3, 0, 3, 7]);
        assert!((scale - 1.0 / 7.0).abs() < 1e-6);
        assert!(q.fits(4, true));
    }

    #[test]
    fn weight_extreme_clamps_to_range() {
        let w = [1.0f32, -1.0];
        let (q, _) = quantize_weights_symmetric(&w, 1, 2, 2);
        // 2-bit signed: [-2, 1]; +1.0/scale = qmax = 1.
        assert_eq!(q.data(), &[1, -1]);
        assert!(q.fits(2, true));
    }

    #[test]
    fn requantize_matches_l2_semantics() {
        let acc = IntMatrix::from_slice(1, 5, &[-5, 0, 63, 64, 1000]);
        let out = requantize(&acc, 4, 2);
        assert_eq!(out.data(), &[0, 0, 3, 3, 3]);
    }
}
