//! Integer-only QNN inference, with every GEMM on the overlay.

use super::mlp::FloatMlp;
use super::quantize::{quantize_activations, quantize_weights_symmetric, requantize};
use crate::api::BismoError;
use crate::bitmatrix::IntMatrix;
use crate::coordinator::{
    BismoContext, BismoService, GemmRequest, GemmResponse, MatmulOptions, Precision,
    RequestOptions, RunReport,
};
use std::sync::Arc;

/// A quantized 3-layer MLP ready for the overlay.
///
/// Weights are `Arc`-shared so serving-layer requests reference them
/// without copying (the weight-stationary contract: the matrices are
/// packed once by the service's cache and never cloned per request).
pub struct QnnMlp {
    pub w1: Arc<IntMatrix>,
    pub w2: Arc<IntMatrix>,
    pub w3: Arc<IntMatrix>,
    pub wbits: u32,
    pub abits: u32,
    /// Requantization shifts after layers 1 and 2 (static, like the
    /// exported JAX artifact).
    pub shifts: (u32, u32),
}

impl QnnMlp {
    /// Quantize a trained float MLP (weights symmetric signed `wbits`).
    pub fn from_float(mlp: &FloatMlp, wbits: u32, abits: u32, shifts: (u32, u32)) -> Self {
        let [d0, d1, d2, d3] = mlp.dims;
        let (w1, _) = quantize_weights_symmetric(&mlp.w[0], d0, d1, wbits);
        let (w2, _) = quantize_weights_symmetric(&mlp.w[1], d1, d2, wbits);
        let (w3, _) = quantize_weights_symmetric(&mlp.w[2], d2, d3, wbits);
        QnnMlp {
            w1: Arc::new(w1),
            w2: Arc::new(w2),
            w3: Arc::new(w3),
            wbits,
            abits,
            shifts,
        }
    }

    /// Quantize a batch of float inputs to the activation precision.
    pub fn quantize_input(&self, xs: &[Vec<f32>]) -> IntMatrix {
        let rows = xs.len();
        let cols = xs.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(rows * cols);
        for x in xs {
            data.extend(quantize_activations(x, self.abits));
        }
        IntMatrix::from_slice(rows, cols, &data)
    }

    /// Pure-integer reference forward pass (no overlay). Semantically
    /// identical to the exported JAX artifact.
    pub fn forward_reference(&self, x: &IntMatrix) -> IntMatrix {
        let h = requantize(&x.matmul(&self.w1), self.shifts.0, self.abits);
        let h = requantize(&h.matmul(&self.w2), self.shifts.1, self.abits);
        h.matmul(&self.w3)
    }

    /// Forward pass with all three GEMMs on the overlay; returns logits
    /// and the per-layer run reports.
    pub fn forward_on_overlay(
        &self,
        ctx: &BismoContext,
        x: &IntMatrix,
        opts: MatmulOptions,
    ) -> Result<(IntMatrix, Vec<RunReport>), BismoError> {
        let prec = |_layer: usize| Precision {
            wbits: self.abits, // LHS = activations (unsigned)
            abits: self.wbits, // RHS = weights (signed)
            lsigned: false,
            rsigned: true,
        };
        let mut reports = Vec::with_capacity(3);
        let (acc1, r1) = ctx.matmul(x, &self.w1, prec(0), opts)?;
        reports.push(r1);
        let h1 = requantize(&acc1, self.shifts.0, self.abits);
        let (acc2, r2) = ctx.matmul(&h1, &self.w2, prec(1), opts)?;
        reports.push(r2);
        let h2 = requantize(&acc2, self.shifts.1, self.abits);
        let (logits, r3) = ctx.matmul(&h2, &self.w3, prec(2), opts)?;
        reports.push(r3);
        Ok((logits, reports))
    }

    /// Forward pass through the serving layer: each GEMM is submitted
    /// to a persistent [`BismoService`] and executed on the backend the
    /// options select. Layer weights are identical across calls, so the
    /// service's weight-stationary packing cache serves them without
    /// repacking from the second inference on — the QNN serving pattern
    /// the cache exists for.
    ///
    /// Returns the logits plus the per-layer [`GemmResponse`]s (timing,
    /// cache attribution, and — on the sim backend — full
    /// [`RunReport`]s).
    pub fn forward_on_service(
        &self,
        svc: &BismoService,
        x: impl Into<Arc<IntMatrix>>,
        opts: RequestOptions,
    ) -> Result<(IntMatrix, Vec<GemmResponse>), BismoError> {
        let prec = Precision {
            wbits: self.abits, // LHS = activations (unsigned)
            abits: self.wbits, // RHS = weights (signed)
            lsigned: false,
            rsigned: true,
        };
        // Layers are data-dependent, so submit→wait per layer; the
        // weight (RHS) packings still reuse across calls via the cache.
        // `x` moves in (callers that still need it pass a clone or Arc).
        let x: Arc<IntMatrix> = x.into();
        let r1 = svc
            .submit(GemmRequest::with_opts(x, self.w1.clone(), prec, opts))
            .wait()?;
        let h1 = requantize(&r1.result, self.shifts.0, self.abits);
        let r2 = svc
            .submit(GemmRequest::with_opts(h1, self.w2.clone(), prec, opts))
            .wait()?;
        let h2 = requantize(&r2.result, self.shifts.1, self.abits);
        let r3 = svc
            .submit(GemmRequest::with_opts(h2, self.w3.clone(), prec, opts))
            .wait()?;
        let logits = r3.result.clone();
        Ok((logits, vec![r1, r2, r3]))
    }

    /// Argmax predictions from logits.
    pub fn predictions(logits: &IntMatrix) -> Vec<usize> {
        (0..logits.rows)
            .map(|r| {
                (0..logits.cols)
                    .max_by_key(|&c| logits.get(r, c))
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Classification accuracy of logits vs labels.
    pub fn accuracy(logits: &IntMatrix, labels: &[usize]) -> f64 {
        let preds = Self::predictions(logits);
        let correct = preds.iter().zip(labels).filter(|(p, y)| p == y).count();
        correct as f64 / labels.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::BismoConfig;
    use crate::qnn::dataset::SyntheticDigits;

    fn quantized_model() -> (QnnMlp, SyntheticDigits) {
        let d = SyntheticDigits::generate(42, 300, 60, 0.15);
        let mut mlp = FloatMlp::new(7, [784, 32, 32, 10]);
        for e in 0..3 {
            mlp.train_epoch(&d.train_x, &d.train_y, 0.02, e);
        }
        (QnnMlp::from_float(&mlp, 4, 2, (6, 4)), d)
    }

    #[test]
    fn weights_fit_declared_precision() {
        let (q, _) = quantized_model();
        assert!(q.w1.fits(4, true));
        assert!(q.w2.fits(4, true));
        assert!(q.w3.fits(4, true));
    }

    #[test]
    fn overlay_matches_reference_exactly() {
        let (q, d) = quantized_model();
        let ctx = BismoContext::new(BismoConfig::small()).unwrap();
        let x = q.quantize_input(&d.test_x[..4]);
        let want = q.forward_reference(&x);
        let (got, reports) = q
            .forward_on_overlay(&ctx, &x, MatmulOptions::default())
            .unwrap();
        assert_eq!(got, want);
        assert_eq!(reports.len(), 3);
        assert!(reports.iter().all(|r| r.cycles > 0));
    }

    #[test]
    fn service_matches_reference_and_reuses_weight_packings() {
        use crate::coordinator::{Backend, ServiceConfig};
        let (q, d) = quantized_model();
        let svc = BismoService::new(ServiceConfig::default()).unwrap();
        let opts = RequestOptions {
            backend: Backend::Engine,
            ..Default::default()
        };
        for chunk in d.test_x[..8].chunks(4) {
            let x = q.quantize_input(chunk);
            let want = q.forward_reference(&x);
            let (got, responses) = q.forward_on_service(&svc, x.clone(), opts).unwrap();
            assert_eq!(got, want);
            assert_eq!(responses.len(), 3);
        }
        // Second inference onward, every layer's weight packing is a
        // cache hit: 3 layers × 1 repeat here = 3 hits minimum.
        assert!(
            svc.cache_stats().hits >= 3,
            "weight reuse must hit the packing cache: {:?}",
            svc.cache_stats()
        );
        // Sim backend agrees bit-exactly and carries reports.
        let x = q.quantize_input(&d.test_x[..2]);
        let sim_opts = RequestOptions {
            backend: Backend::Sim,
            ..Default::default()
        };
        let (sim_logits, responses) = q.forward_on_service(&svc, x.clone(), sim_opts).unwrap();
        assert_eq!(sim_logits, q.forward_reference(&x));
        assert!(responses.iter().all(|r| r.report.is_some()));
    }

    #[test]
    fn quantized_model_still_classifies() {
        let (q, d) = quantized_model();
        let x = q.quantize_input(&d.test_x);
        let logits = q.forward_reference(&x);
        let acc = QnnMlp::accuracy(&logits, &d.test_y);
        assert!(acc > 0.5, "quantized accuracy {acc:.2} too low");
    }

    #[test]
    fn activation_range_respected() {
        let (q, d) = quantized_model();
        let x = q.quantize_input(&d.test_x[..8]);
        assert!(x.fits(2, false));
    }
}
