//! Integer-only QNN inference, with every GEMM on the overlay.

use super::mlp::FloatMlp;
use super::quantize::{quantize_activations, quantize_weights_symmetric, requantize};
use crate::bitmatrix::IntMatrix;
use crate::coordinator::{BismoContext, MatmulOptions, Precision, RunReport};

/// A quantized 3-layer MLP ready for the overlay.
pub struct QnnMlp {
    pub w1: IntMatrix,
    pub w2: IntMatrix,
    pub w3: IntMatrix,
    pub wbits: u32,
    pub abits: u32,
    /// Requantization shifts after layers 1 and 2 (static, like the
    /// exported JAX artifact).
    pub shifts: (u32, u32),
}

impl QnnMlp {
    /// Quantize a trained float MLP (weights symmetric signed `wbits`).
    pub fn from_float(mlp: &FloatMlp, wbits: u32, abits: u32, shifts: (u32, u32)) -> Self {
        let [d0, d1, d2, d3] = mlp.dims;
        let (w1, _) = quantize_weights_symmetric(&mlp.w[0], d0, d1, wbits);
        let (w2, _) = quantize_weights_symmetric(&mlp.w[1], d1, d2, wbits);
        let (w3, _) = quantize_weights_symmetric(&mlp.w[2], d2, d3, wbits);
        QnnMlp {
            w1,
            w2,
            w3,
            wbits,
            abits,
            shifts,
        }
    }

    /// Quantize a batch of float inputs to the activation precision.
    pub fn quantize_input(&self, xs: &[Vec<f32>]) -> IntMatrix {
        let rows = xs.len();
        let cols = xs.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(rows * cols);
        for x in xs {
            data.extend(quantize_activations(x, self.abits));
        }
        IntMatrix::from_slice(rows, cols, &data)
    }

    /// Pure-integer reference forward pass (no overlay). Semantically
    /// identical to the exported JAX artifact.
    pub fn forward_reference(&self, x: &IntMatrix) -> IntMatrix {
        let h = requantize(&x.matmul(&self.w1), self.shifts.0, self.abits);
        let h = requantize(&h.matmul(&self.w2), self.shifts.1, self.abits);
        h.matmul(&self.w3)
    }

    /// Forward pass with all three GEMMs on the overlay; returns logits
    /// and the per-layer run reports.
    pub fn forward_on_overlay(
        &self,
        ctx: &BismoContext,
        x: &IntMatrix,
        opts: MatmulOptions,
    ) -> Result<(IntMatrix, Vec<RunReport>), String> {
        let prec = |_layer: usize| Precision {
            wbits: self.abits, // LHS = activations (unsigned)
            abits: self.wbits, // RHS = weights (signed)
            lsigned: false,
            rsigned: true,
        };
        let mut reports = Vec::with_capacity(3);
        let (acc1, r1) = ctx.matmul(x, &self.w1, prec(0), opts)?;
        reports.push(r1);
        let h1 = requantize(&acc1, self.shifts.0, self.abits);
        let (acc2, r2) = ctx.matmul(&h1, &self.w2, prec(1), opts)?;
        reports.push(r2);
        let h2 = requantize(&acc2, self.shifts.1, self.abits);
        let (logits, r3) = ctx.matmul(&h2, &self.w3, prec(2), opts)?;
        reports.push(r3);
        Ok((logits, reports))
    }

    /// Argmax predictions from logits.
    pub fn predictions(logits: &IntMatrix) -> Vec<usize> {
        (0..logits.rows)
            .map(|r| {
                (0..logits.cols)
                    .max_by_key(|&c| logits.get(r, c))
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Classification accuracy of logits vs labels.
    pub fn accuracy(logits: &IntMatrix, labels: &[usize]) -> f64 {
        let preds = Self::predictions(logits);
        let correct = preds.iter().zip(labels).filter(|(p, y)| p == y).count();
        correct as f64 / labels.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::BismoConfig;
    use crate::qnn::dataset::SyntheticDigits;

    fn quantized_model() -> (QnnMlp, SyntheticDigits) {
        let d = SyntheticDigits::generate(42, 300, 60, 0.15);
        let mut mlp = FloatMlp::new(7, [784, 32, 32, 10]);
        for e in 0..3 {
            mlp.train_epoch(&d.train_x, &d.train_y, 0.02, e);
        }
        (QnnMlp::from_float(&mlp, 4, 2, (6, 4)), d)
    }

    #[test]
    fn weights_fit_declared_precision() {
        let (q, _) = quantized_model();
        assert!(q.w1.fits(4, true));
        assert!(q.w2.fits(4, true));
        assert!(q.w3.fits(4, true));
    }

    #[test]
    fn overlay_matches_reference_exactly() {
        let (q, d) = quantized_model();
        let ctx = BismoContext::new(BismoConfig::small()).unwrap();
        let x = q.quantize_input(&d.test_x[..4]);
        let want = q.forward_reference(&x);
        let (got, reports) = q
            .forward_on_overlay(&ctx, &x, MatmulOptions::default())
            .unwrap();
        assert_eq!(got, want);
        assert_eq!(reports.len(), 3);
        assert!(reports.iter().all(|r| r.cycles > 0));
    }

    #[test]
    fn quantized_model_still_classifies() {
        let (q, d) = quantized_model();
        let x = q.quantize_input(&d.test_x);
        let logits = q.forward_reference(&x);
        let acc = QnnMlp::accuracy(&logits, &d.test_y);
        assert!(acc > 0.5, "quantized accuracy {acc:.2} too low");
    }

    #[test]
    fn activation_range_respected() {
        let (q, d) = quantized_model();
        let x = q.quantize_input(&d.test_x[..8]);
        assert!(x.fits(2, false));
    }
}
