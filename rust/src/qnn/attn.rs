//! Quantized transformer encoder block on the overlay: the
//! heterogeneous-precision GEMM workload the BISMO journal extension
//! argues bit-serial hardware is built for.
//!
//! One [`QnnAttn`] block is a DAG of integer GEMMs — Q/K/V
//! projections, per-head `Q·Kᵀ` score GEMMs, attention·V, an output
//! projection and a two-layer FFN — each with its *own*
//! [`Precision`]: activations are unsigned `abits`-bit on the LHS,
//! weights signed at per-matrix widths on the RHS, and the score /
//! attention·V GEMMs multiply two activation operands. Every float
//! non-linearity of the textbook block is substituted by an integer
//! construction in the spirit of FINN-style [`Thresholding`]:
//!
//! * softmax → [`SoftmaxStaircase`]: a row-wise fixed-point staircase
//!   on `score − rowmax` producing unsigned `abits`-bit attention
//!   weights (monotone in the score, row maximum saturates; the
//!   row-sum normalization is dropped — it rescales every product of
//!   a row identically, and the requantizing staircase after
//!   attention·V absorbs scale, so the *integer* pipeline stays
//!   deterministic and exactly reproducible);
//! * layernorm + activation → per-stage [`Thresholding`] staircases,
//!   data-calibrated (FINN-style) to the accumulator range the
//!   producing GEMM emits on a seeded calibration batch;
//! * residual adds are omitted: raw accumulator scales differ per
//!   branch and integer residual rescaling is a calibration concern,
//!   orthogonal to the serving claims under test (see DESIGN.md §14).
//!
//! The block's forward pass is written once, over an abstract
//! [`GemmExec`] — [`QnnAttn::forward_reference`] plugs in the pure
//! i64 [`IntMatrix::matmul`] oracle, the serving path
//! ([`crate::api::PreparedAttn`]) plugs in the session. The two
//! executions run the *same* staircase/slicing code, so any result
//! divergence is attributable to the GEMM engine alone — that is the
//! bit-exactness claim the tests and `bismo attn-bench` gate on.

use crate::api::BismoError;
use crate::bitmatrix::IntMatrix;
use crate::coordinator::Precision;
use crate::qnn::cnn::Thresholding;
use crate::util::Rng;
use std::sync::Arc;

/// Architecture of one encoder block, plus the serving-time sequence
/// bound the integer staircases are calibrated against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnSpec {
    /// Model (embedding) width; the per-head width is
    /// `d_model / heads`.
    pub d_model: usize,
    /// Attention heads.
    pub heads: usize,
    /// FFN hidden width.
    pub d_ff: usize,
    /// Largest sequence length this block serves. The staircases are
    /// data-calibrated on inputs of this length; longer inputs are
    /// rejected at execute time.
    pub max_seq: usize,
}

impl AttnSpec {
    /// Per-head width.
    pub fn d_head(&self) -> usize {
        self.d_model / self.heads
    }

    /// Reject degenerate architectures with a typed error before any
    /// weight is allocated or packed.
    pub fn validate(&self) -> Result<(), BismoError> {
        if self.d_model == 0 || self.heads == 0 || self.d_ff == 0 || self.max_seq == 0 {
            return Err(BismoError::InvalidConfig(format!(
                "attention spec dimensions must be >= 1 (got d_model={}, heads={}, d_ff={}, max_seq={})",
                self.d_model, self.heads, self.d_ff, self.max_seq
            )));
        }
        if self.d_model % self.heads != 0 {
            return Err(BismoError::InvalidConfig(format!(
                "d_model ({}) must divide evenly into {} heads",
                self.d_model, self.heads
            )));
        }
        Ok(())
    }
}

/// Integer softmax substitute: a row-wise staircase on the score gap
/// to the row maximum.
///
/// For a score `s` in a row with maximum `m`, the attention weight is
/// `max(0, levels − ((m − s) >> shift))` with `levels = 2^abits − 1`:
/// the row maximum always maps to `levels`, scores fade linearly (in
/// `2^shift`-sized steps) to zero, and every weight fits unsigned
/// `abits`-bit — the declared LHS precision of the attention·V GEMM.
/// Monotone in `s`, pure integer, and calibrated once from the
/// worst-case score spread (like the [`Thresholding`] staircases).
#[derive(Clone, Copy, Debug)]
pub struct SoftmaxStaircase {
    /// log2 of the score gap per attention-weight step.
    pub shift: u32,
    /// `2^abits − 1`: the weight of the row maximum.
    pub levels: i64,
}

impl SoftmaxStaircase {
    /// Calibrate for `abits`-bit attention weights against a score
    /// spread bound, placing the staircase's reach just under it so
    /// the weights actually spread (the same rule the thresholding
    /// staircases use).
    pub fn for_bounds(abits: u32, max_spread: i64) -> SoftmaxStaircase {
        let levels = (1i64 << abits) - 1;
        let mut shift = 0u32;
        while (levels << (shift + 1)) <= max_spread {
            shift += 1;
        }
        SoftmaxStaircase { shift, levels }
    }

    /// Attention weight for one score `gap = rowmax − s` (`gap >= 0`).
    #[inline]
    pub fn weight(&self, gap: i64) -> i64 {
        (self.levels - (gap >> self.shift)).max(0)
    }

    /// Apply row-wise to a score matrix.
    pub fn apply(&self, scores: &IntMatrix) -> IntMatrix {
        IntMatrix::from_fn(scores.rows, scores.cols, |r, c| {
            let rowmax = scores.row(r).iter().copied().max().unwrap_or(0);
            self.weight(rowmax - scores.get(r, c))
        })
    }
}

/// Per-matrix weight widths of one block (signed weights; the
/// activation side is the block-wide unsigned `abits`).
#[derive(Clone, Copy, Debug)]
pub struct AttnWeightBits {
    /// Q/K/V projection weights.
    pub proj: u32,
    /// Output projection weights.
    pub out: u32,
    /// FFN first layer weights.
    pub ffn1: u32,
    /// FFN second layer weights.
    pub ffn2: u32,
}

impl Default for AttnWeightBits {
    fn default() -> Self {
        // Four GEMM families at three different weight widths: the
        // heterogeneous-precision workload in one block.
        AttnWeightBits {
            proj: 3,
            out: 2,
            ffn1: 3,
            ffn2: 2,
        }
    }
}

/// One GEMM of an attention layer, as seen by a [`GemmExec`].
pub enum AttnGemm {
    /// Activations against one of the block's weight matrices,
    /// identified by name (`"wq"`, `"wk"`, `"wv"`, `"wo"`, `"w1"`,
    /// `"w2"`) — the weight-stationary side.
    Weight {
        weight: &'static str,
        lhs: IntMatrix,
        prec: Precision,
    },
    /// Activation × activation (scores, attention·V): both operands
    /// fresh per request.
    Dynamic {
        lhs: IntMatrix,
        rhs: IntMatrix,
        prec: Precision,
    },
}

impl AttnGemm {
    /// The declared precision of this GEMM.
    pub fn precision(&self) -> Precision {
        match self {
            AttnGemm::Weight { prec, .. } | AttnGemm::Dynamic { prec, .. } => *prec,
        }
    }
}

/// The GEMM engine a [`QnnAttn`] forward pass runs on. One layer's
/// jobs are independent, so an implementation may (and the serving
/// path does) submit them all before waiting on any; results come
/// back in job order.
pub trait GemmExec {
    /// Execute one layer's independent GEMMs.
    fn run_layer(
        &mut self,
        layer: &'static str,
        jobs: Vec<AttnGemm>,
    ) -> Result<Vec<IntMatrix>, BismoError>;
}

/// A quantized transformer encoder block: six weight matrices, four
/// threshold staircases, an integer softmax, and a distinct
/// [`Precision`] per GEMM family.
#[derive(Clone)]
pub struct QnnAttn {
    pub spec: AttnSpec,
    /// `d_model × d_model` Q/K/V/output projection weights.
    pub wq: Arc<IntMatrix>,
    pub wk: Arc<IntMatrix>,
    pub wv: Arc<IntMatrix>,
    pub wo: Arc<IntMatrix>,
    /// `d_model × d_ff` and `d_ff × d_model` FFN weights.
    pub w1: Arc<IntMatrix>,
    pub w2: Arc<IntMatrix>,
    /// Q/K/V projection GEMMs: unsigned `abits` LHS, signed
    /// `wbits.proj` RHS.
    pub proj_prec: Precision,
    /// Per-head `Q·Kᵀ`: both sides unsigned `abits` activations.
    pub score_prec: Precision,
    /// Per-head attention·V: both sides unsigned `abits`.
    pub av_prec: Precision,
    /// Output projection.
    pub out_prec: Precision,
    /// FFN layers.
    pub ffn1_prec: Precision,
    pub ffn2_prec: Precision,
    /// Requantizing staircases after the projection, context, output
    /// and FFN-hidden accumulators.
    pub t_qkv: Thresholding,
    pub t_ctx: Thresholding,
    pub t_out: Thresholding,
    pub t_ffn: Thresholding,
    /// The integer softmax substitute.
    pub softmax: SoftmaxStaircase,
    /// Activation width (unsigned) throughout the block.
    pub abits: u32,
}

/// Threshold shift placing the staircase's reach just under `max_acc`
/// (the same rule the CNN staircases use).
fn staircase_shift(max_acc: i64, abits: u32) -> u32 {
    let levels = (1i64 << abits) - 1;
    let mut shift = 0u32;
    while (levels << (shift + 1)) <= max_acc {
        shift += 1;
    }
    shift
}

impl QnnAttn {
    /// Build a seeded-random block: weights uniform in their signed
    /// width, staircases data-calibrated on a seeded batch.
    pub fn random(seed: u64, spec: AttnSpec, abits: u32, wbits: AttnWeightBits) -> QnnAttn {
        let mut rng = Rng::new(seed);
        let d = spec.d_model;
        let mut w = |rows: usize, cols: usize, bits: u32| {
            Arc::new(IntMatrix::from_fn(rows, cols, |_, _| rng.operand(bits, true)))
        };
        let wq = w(d, d, wbits.proj);
        let wk = w(d, d, wbits.proj);
        let wv = w(d, d, wbits.proj);
        let wo = w(d, d, wbits.out);
        let w1 = w(d, spec.d_ff, wbits.ffn1);
        let w2 = w(spec.d_ff, d, wbits.ffn2);
        let dh = spec.d_head();
        // Staircase calibration, FINN-style, on a small seeded batch.
        // A worst-case accumulator bound (k · max|lhs| · max|rhs|)
        // would put the first threshold far above anything a zero-mean
        // signed-weight GEMM actually produces, silencing the block —
        // so each staircase is instead placed just under the largest
        // accumulator its producing GEMM emits on the batch, stage by
        // stage (inputs past the observed range saturate to the top
        // step, exactly like FINN thresholds on unseen data).
        let cal: Vec<IntMatrix> = (0..4)
            .map(|_| IntMatrix::random(&mut rng, spec.max_seq, d, abits, false))
            .collect();
        let observed = |ms: &[IntMatrix]| {
            ms.iter()
                .flat_map(|m| m.data().iter().copied())
                .max()
                .unwrap_or(0)
                .max(1)
        };
        let mut qkv_accs = Vec::new();
        for x in &cal {
            for w in [&wq, &wk, &wv] {
                qkv_accs.push(x.matmul(w));
            }
        }
        let t_qkv = Thresholding::uniform(staircase_shift(observed(&qkv_accs), abits), abits);
        // Per-head score spread (the gap to the row maximum is the
        // softmax staircase's input domain).
        let mut spread = 1i64;
        let mut score_mats: Vec<Vec<IntMatrix>> = Vec::new();
        let mut vs: Vec<IntMatrix> = Vec::new();
        for x in &cal {
            let q = t_qkv.apply_matrix(&x.matmul(&wq));
            let k = t_qkv.apply_matrix(&x.matmul(&wk));
            vs.push(t_qkv.apply_matrix(&x.matmul(&wv)));
            let mut per_head = Vec::new();
            for h in 0..spec.heads {
                let s = col_block(&q, h * dh, dh).matmul(&col_block(&k, h * dh, dh).transpose());
                for r in 0..s.rows {
                    let row = s.row(r);
                    let hi = row.iter().copied().max().unwrap_or(0);
                    let lo = row.iter().copied().min().unwrap_or(0);
                    spread = spread.max(hi - lo);
                }
                per_head.push(s);
            }
            score_mats.push(per_head);
        }
        let softmax = SoftmaxStaircase::for_bounds(abits, spread);
        let mut ctx_accs = Vec::new();
        for (per_head, v) in score_mats.iter().zip(&vs) {
            let heads: Vec<IntMatrix> = per_head
                .iter()
                .enumerate()
                .map(|(h, s)| softmax.apply(s).matmul(&col_block(v, h * dh, dh)))
                .collect();
            ctx_accs.push(concat_cols(&heads));
        }
        let t_ctx = Thresholding::uniform(staircase_shift(observed(&ctx_accs), abits), abits);
        let o_accs: Vec<IntMatrix> = ctx_accs
            .iter()
            .map(|ctx| t_ctx.apply_matrix(ctx).matmul(&wo))
            .collect();
        let t_out = Thresholding::uniform(staircase_shift(observed(&o_accs), abits), abits);
        let h1_accs: Vec<IntMatrix> = o_accs
            .iter()
            .map(|o| t_out.apply_matrix(o).matmul(&w1))
            .collect();
        let t_ffn = Thresholding::uniform(staircase_shift(observed(&h1_accs), abits), abits);
        let unsigned_pair = Precision::unsigned(abits, abits);
        QnnAttn {
            spec,
            wq,
            wk,
            wv,
            wo,
            w1,
            w2,
            proj_prec: Precision {
                wbits: abits,
                abits: wbits.proj,
                lsigned: false,
                rsigned: true,
            },
            score_prec: unsigned_pair,
            av_prec: unsigned_pair,
            out_prec: Precision {
                wbits: abits,
                abits: wbits.out,
                lsigned: false,
                rsigned: true,
            },
            ffn1_prec: Precision {
                wbits: abits,
                abits: wbits.ffn1,
                lsigned: false,
                rsigned: true,
            },
            ffn2_prec: Precision {
                wbits: abits,
                abits: wbits.ffn2,
                lsigned: false,
                rsigned: true,
            },
            t_qkv,
            t_ctx,
            t_out,
            t_ffn,
            softmax,
            abits,
        }
    }

    /// The benchmark/demo preset: 32-wide model, 4 heads, 48-wide FFN,
    /// 3-bit activations, weights at 3/2/3/2 bits.
    pub fn demo(seed: u64, max_seq: usize) -> QnnAttn {
        QnnAttn::random(
            seed,
            AttnSpec {
                d_model: 32,
                heads: 4,
                d_ff: 48,
                max_seq,
            },
            3,
            AttnWeightBits::default(),
        )
    }

    /// Validate architecture, weight shapes and per-GEMM precisions.
    pub fn validate(&self) -> Result<(), BismoError> {
        self.spec.validate()?;
        let d = self.spec.d_model;
        for (name, m, rows, cols) in [
            ("wq", &self.wq, d, d),
            ("wk", &self.wk, d, d),
            ("wv", &self.wv, d, d),
            ("wo", &self.wo, d, d),
            ("w1", &self.w1, d, self.spec.d_ff),
            ("w2", &self.w2, self.spec.d_ff, d),
        ] {
            if (m.rows, m.cols) != (rows, cols) {
                return Err(BismoError::ShapeMismatch(format!(
                    "{name} is {}×{}, expected {rows}×{cols}",
                    m.rows, m.cols
                )));
            }
        }
        for prec in [
            self.proj_prec,
            self.score_prec,
            self.av_prec,
            self.out_prec,
            self.ffn1_prec,
            self.ffn2_prec,
        ] {
            prec.validate()?;
        }
        Ok(())
    }

    /// The weight matrix behind a [`AttnGemm::Weight`] name.
    pub fn weight(&self, name: &str) -> &Arc<IntMatrix> {
        match name {
            "wq" => &self.wq,
            "wk" => &self.wk,
            "wv" => &self.wv,
            "wo" => &self.wo,
            "w1" => &self.w1,
            "w2" => &self.w2,
            other => panic!("unknown attention weight {other:?}"),
        }
    }

    /// Reject inputs this block was not calibrated for: wrong width,
    /// sequence over the staircase bound, or entries outside the
    /// activation precision.
    pub fn check_input(&self, x: &IntMatrix) -> Result<(), BismoError> {
        if x.cols != self.spec.d_model || x.rows == 0 || x.rows > self.spec.max_seq {
            return Err(BismoError::ShapeMismatch(format!(
                "attention input is {}×{}, expected seq×{} with 1 <= seq <= {}",
                x.rows, x.cols, self.spec.d_model, self.spec.max_seq
            )));
        }
        if !x.fits(self.abits, false) {
            return Err(BismoError::PrecisionUnsupported(format!(
                "attention input entries do not fit unsigned {}-bit",
                self.abits
            )));
        }
        Ok(())
    }

    /// A random valid input: `seq × d_model` with unsigned `bits`-bit
    /// entries (callers vary `bits <= abits` to model inputs of
    /// varying dynamic range — what the adaptive precision policy
    /// exploits).
    pub fn random_input(&self, rng: &mut Rng, seq: usize, bits: u32) -> IntMatrix {
        IntMatrix::random(rng, seq, self.spec.d_model, bits, false)
    }

    /// The forward pass, over an abstract GEMM engine. All slicing,
    /// staircase and softmax arithmetic lives here — shared verbatim
    /// by the oracle and the serving path — so executor results are
    /// comparable bit for bit.
    pub fn forward_with<E: GemmExec>(
        &self,
        x: &IntMatrix,
        exec: &mut E,
    ) -> Result<IntMatrix, BismoError> {
        self.check_input(x)?;
        let dh = self.spec.d_head();
        // Q/K/V projections: three weight GEMMs off the same input.
        let qkv = exec.run_layer(
            "qkv",
            ["wq", "wk", "wv"]
                .into_iter()
                .map(|weight| AttnGemm::Weight {
                    weight,
                    lhs: x.clone(),
                    prec: self.proj_prec,
                })
                .collect(),
        )?;
        let [q_acc, k_acc, v_acc]: [IntMatrix; 3] = qkv
            .try_into()
            .map_err(|_| BismoError::ShapeMismatch("qkv layer must yield 3 results".into()))?;
        let q = self.t_qkv.apply_matrix(&q_acc);
        let k = self.t_qkv.apply_matrix(&k_acc);
        let v = self.t_qkv.apply_matrix(&v_acc);
        // Per-head scores Q_h · K_hᵀ — all heads submitted together.
        let scores = exec.run_layer(
            "scores",
            (0..self.spec.heads)
                .map(|h| AttnGemm::Dynamic {
                    lhs: col_block(&q, h * dh, dh),
                    rhs: col_block(&k, h * dh, dh).transpose(),
                    prec: self.score_prec,
                })
                .collect(),
        )?;
        // Integer softmax per head, then attention·V — again all
        // heads in flight together.
        let ctx_heads = exec.run_layer(
            "attn_v",
            scores
                .iter()
                .enumerate()
                .map(|(h, s)| AttnGemm::Dynamic {
                    lhs: self.softmax.apply(s),
                    rhs: col_block(&v, h * dh, dh),
                    prec: self.av_prec,
                })
                .collect(),
        )?;
        let ctx = self.t_ctx.apply_matrix(&concat_cols(&ctx_heads));
        // Output projection.
        let o_acc = one(exec.run_layer(
            "out",
            vec![AttnGemm::Weight {
                weight: "wo",
                lhs: ctx,
                prec: self.out_prec,
            }],
        )?)?;
        let h0 = self.t_out.apply_matrix(&o_acc);
        // Two-layer FFN; the final GEMM's raw accumulators are the
        // block output (logit domain — requantization would belong to
        // the next block).
        let h1_acc = one(exec.run_layer(
            "ffn1",
            vec![AttnGemm::Weight {
                weight: "w1",
                lhs: h0,
                prec: self.ffn1_prec,
            }],
        )?)?;
        let h1 = self.t_ffn.apply_matrix(&h1_acc);
        one(exec.run_layer(
            "ffn2",
            vec![AttnGemm::Weight {
                weight: "w2",
                lhs: h1,
                prec: self.ffn2_prec,
            }],
        )?)
    }

    /// Pure-i64 reference forward pass: every GEMM is
    /// [`IntMatrix::matmul`], everything else is the shared
    /// [`QnnAttn::forward_with`] code. The oracle both backends and
    /// every policy run are gated against.
    pub fn forward_reference(&self, x: &IntMatrix) -> Result<IntMatrix, BismoError> {
        struct RefExec<'m>(&'m QnnAttn);
        impl GemmExec for RefExec<'_> {
            fn run_layer(
                &mut self,
                _layer: &'static str,
                jobs: Vec<AttnGemm>,
            ) -> Result<Vec<IntMatrix>, BismoError> {
                Ok(jobs
                    .into_iter()
                    .map(|j| match j {
                        AttnGemm::Weight { weight, lhs, .. } => lhs.matmul(self.0.weight(weight)),
                        AttnGemm::Dynamic { lhs, rhs, .. } => lhs.matmul(&rhs),
                    })
                    .collect())
            }
        }
        self.forward_with(x, &mut RefExec(self))
    }

    /// GEMMs one forward pass performs: `6 + 2 · heads`.
    pub fn gemms_per_pass(&self) -> usize {
        6 + 2 * self.spec.heads
    }

    /// Shape table of the block's GEMM layers at sequence length
    /// `seq` (the bench's per-layer identity record).
    pub fn layer_shapes(&self, seq: usize) -> Vec<AttnLayerShape> {
        let d = self.spec.d_model;
        let dh = self.spec.d_head();
        vec![
            AttnLayerShape::new("qkv", 3, seq, d, d, self.proj_prec),
            AttnLayerShape::new("scores", self.spec.heads, seq, dh, seq, self.score_prec),
            AttnLayerShape::new("attn_v", self.spec.heads, seq, seq, dh, self.av_prec),
            AttnLayerShape::new("out", 1, seq, d, d, self.out_prec),
            AttnLayerShape::new("ffn1", 1, seq, d, self.spec.d_ff, self.ffn1_prec),
            AttnLayerShape::new("ffn2", 1, seq, self.spec.d_ff, d, self.ffn2_prec),
        ]
    }
}

/// One row of [`QnnAttn::layer_shapes`].
#[derive(Clone, Copy, Debug)]
pub struct AttnLayerShape {
    pub name: &'static str,
    /// Independent GEMMs this layer submits per pass.
    pub gemms: usize,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Declared LHS (activation) width.
    pub activation_bits: u32,
    /// Declared RHS width (weight width, or the activation width for
    /// the dynamic scores/attention·V GEMMs).
    pub weight_bits: u32,
}

impl AttnLayerShape {
    fn new(
        name: &'static str,
        gemms: usize,
        m: usize,
        k: usize,
        n: usize,
        prec: Precision,
    ) -> Self {
        AttnLayerShape {
            name,
            gemms,
            m,
            k,
            n,
            activation_bits: prec.wbits,
            weight_bits: prec.abits,
        }
    }
}

impl Thresholding {
    /// Threshold every matrix element (the [`IntMatrix`] counterpart
    /// of [`Thresholding::apply`]).
    pub fn apply_matrix(&self, m: &IntMatrix) -> IntMatrix {
        IntMatrix::from_fn(m.rows, m.cols, |r, c| self.value(m.get(r, c)))
    }
}

/// Columns `[lo, lo + width)` of `m` — one head's slice.
fn col_block(m: &IntMatrix, lo: usize, width: usize) -> IntMatrix {
    IntMatrix::from_fn(m.rows, width, |r, c| m.get(r, lo + c))
}

/// Horizontal concatenation — reassembling the per-head contexts.
fn concat_cols(parts: &[IntMatrix]) -> IntMatrix {
    let rows = parts.first().map_or(0, |p| p.rows);
    let cols: usize = parts.iter().map(|p| p.cols).sum();
    let mut out = IntMatrix::zeros(rows, cols);
    let mut at = 0;
    for p in parts {
        for r in 0..p.rows {
            for c in 0..p.cols {
                out.set(r, at + c, p.get(r, c));
            }
        }
        at += p.cols;
    }
    out
}

/// Exactly-one-result helper for single-GEMM layers.
fn one(mut v: Vec<IntMatrix>) -> Result<IntMatrix, BismoError> {
    match v.pop() {
        Some(m) if v.is_empty() => Ok(m),
        _ => Err(BismoError::ShapeMismatch(
            "single-GEMM layer must yield exactly 1 result".into(),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> AttnSpec {
        AttnSpec {
            d_model: 8,
            heads: 2,
            d_ff: 12,
            max_seq: 6,
        }
    }

    #[test]
    fn spec_validation_is_typed() {
        assert!(spec().validate().is_ok());
        let r = AttnSpec { heads: 0, ..spec() }.validate();
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
        let r = AttnSpec { heads: 3, ..spec() }.validate();
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
    }

    #[test]
    fn softmax_staircase_is_monotone_bounded_and_saturating() {
        let sm = SoftmaxStaircase::for_bounds(3, 1000);
        assert_eq!(sm.levels, 7);
        // The reach covers a meaningful part of the spread without
        // overshooting: levels << (shift+1) > max_spread >= levels << shift.
        assert!(7i64 << (sm.shift + 1) > 1000);
        // Row maximum always gets full weight; weights never exceed
        // levels, never go negative, and are monotone in the score.
        let scores = IntMatrix::from_slice(2, 4, &[100, 40, 99, -900, 5, 5, 5, 5]);
        let w = sm.apply(&scores);
        assert_eq!(w.get(0, 0), 7, "rowmax saturates");
        assert_eq!(w.get(1, 0), 7, "uniform row is all-max");
        for r in 0..2 {
            for c in 0..4 {
                assert!((0..=7).contains(&w.get(r, c)), "weight in range");
            }
        }
        assert!(w.get(0, 2) >= w.get(0, 1), "monotone in score");
        assert_eq!(w.get(0, 3), 0, "distant score fades to zero");
    }

    #[test]
    fn reference_forward_is_deterministic_and_shaped() {
        let model = QnnAttn::random(7, spec(), 3, AttnWeightBits::default());
        model.validate().unwrap();
        let mut rng = Rng::new(11);
        let x = model.random_input(&mut rng, 5, 3);
        let y1 = model.forward_reference(&x).unwrap();
        let y2 = model.forward_reference(&x).unwrap();
        assert_eq!(y1, y2);
        assert_eq!((y1.rows, y1.cols), (5, 8), "seq × d_model logits");
        // Activations inside the block stay in the unsigned abits
        // domain; the output is raw accumulators and may be signed.
        assert!(y1.value_range().0 < 0 || y1.value_range().1 > 0, "non-trivial output");
    }

    #[test]
    fn forward_counts_gemms_and_layers() {
        struct Counting {
            model: QnnAttn,
            layers: Vec<(&'static str, usize)>,
        }
        impl GemmExec for Counting {
            fn run_layer(
                &mut self,
                layer: &'static str,
                jobs: Vec<AttnGemm>,
            ) -> Result<Vec<IntMatrix>, BismoError> {
                self.layers.push((layer, jobs.len()));
                Ok(jobs
                    .into_iter()
                    .map(|j| match j {
                        AttnGemm::Weight { weight, lhs, .. } => {
                            lhs.matmul(self.model.weight(weight))
                        }
                        AttnGemm::Dynamic { lhs, rhs, .. } => lhs.matmul(&rhs),
                    })
                    .collect())
            }
        }
        let model = QnnAttn::random(3, spec(), 2, AttnWeightBits::default());
        let mut rng = Rng::new(4);
        let x = model.random_input(&mut rng, 4, 2);
        let mut exec = Counting {
            model: model.clone(),
            layers: Vec::new(),
        };
        model.forward_with(&x, &mut exec).unwrap();
        assert_eq!(
            exec.layers,
            vec![
                ("qkv", 3),
                ("scores", 2),
                ("attn_v", 2),
                ("out", 1),
                ("ffn1", 1),
                ("ffn2", 1)
            ]
        );
        assert_eq!(
            exec.layers.iter().map(|(_, n)| n).sum::<usize>(),
            model.gemms_per_pass()
        );
    }

    #[test]
    fn input_checks_are_typed() {
        let model = QnnAttn::random(9, spec(), 3, AttnWeightBits::default());
        // Wrong width.
        let r = model.forward_reference(&IntMatrix::zeros(2, 7));
        assert!(matches!(r, Err(BismoError::ShapeMismatch(_))), "{r:?}");
        // Sequence over the calibration bound.
        let r = model.forward_reference(&IntMatrix::zeros(7, 8));
        assert!(matches!(r, Err(BismoError::ShapeMismatch(_))), "{r:?}");
        // Entries outside the activation precision.
        let hot = IntMatrix::from_fn(2, 8, |_, _| 9);
        let r = model.forward_reference(&hot);
        assert!(matches!(r, Err(BismoError::PrecisionUnsupported(_))), "{r:?}");
    }

    #[test]
    fn staircases_keep_activations_in_range() {
        let model = QnnAttn::random(21, spec(), 3, AttnWeightBits::default());
        let mut rng = Rng::new(5);
        // Full-range input: every intermediate staircase output must
        // fit unsigned abits (checked indirectly — forward_reference
        // would feed out-of-range values into matmuls whose declared
        // precisions the serving path enforces; here we check the
        // staircase outputs directly).
        let x = model.random_input(&mut rng, 6, 3);
        let acc = x.matmul(&model.wq);
        let q = model.t_qkv.apply_matrix(&acc);
        assert!(q.fits(3, false), "staircase output fits abits");
        let (lo, hi) = q.value_range();
        assert!(lo >= 0 && hi <= 7);
    }

    #[test]
    fn layer_shapes_cover_every_gemm() {
        let model = QnnAttn::random(2, spec(), 3, AttnWeightBits::default());
        let shapes = model.layer_shapes(5);
        assert_eq!(shapes.len(), 6);
        assert_eq!(
            shapes.iter().map(|l| l.gemms).sum::<usize>(),
            model.gemms_per_pass()
        );
        let scores = shapes.iter().find(|l| l.name == "scores").unwrap();
        assert_eq!((scores.m, scores.k, scores.n), (5, 4, 5));
        assert_eq!(scores.gemms, 2);
    }
}
