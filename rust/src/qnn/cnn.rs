//! Quantized CNN layers on the overlay: the convolution-dominated
//! workload the paper motivates BISMO with.
//!
//! [`QnnCnn`] is a small conv–pool–conv–pool–dense classifier whose
//! conv layers lower onto the GEMM stack through [`crate::lowering`]
//! and whose every GEMM is served by [`crate::coordinator::BismoService`].
//! Layer weights are prepared once ([`QnnCnn::serve`] →
//! [`crate::api::PreparedConv`] / [`crate::api::Prepared`]) and reused
//! across inferences — the weight-stationary pattern — and each layer
//! carries its *own* operand precision, exercising the paper's claim
//! that "precision requirements may vary between different application
//! phases" at layer granularity.
//!
//! Weights are synthetic (seeded random): the claim under test is
//! bit-exactness of the full lowered serving path against the naive
//! direct-convolution reference ([`QnnCnn::forward_reference`]), plus
//! the serving-layer properties (cache reuse, per-layer precision
//! override) — not classification accuracy.

use crate::api::{BismoError, Prepared, PreparedConv, Session};
use crate::bitmatrix::IntMatrix;
use crate::coordinator::{Backend, GemmResponse, Precision};
use crate::lowering::{conv2d_direct, ConvSpec, LoweringMode, Tensor};
use crate::qnn::quantize::quantize_activations;
use crate::util::Rng;
use std::sync::Arc;

/// One quantized convolution layer: spec, lowered-layout weights and
/// the layer's operand precision (`wbits` = activation bits, unsigned
/// LHS; `abits` = weight bits, signed RHS — the same orientation the
/// MLP layers use).
#[derive(Clone)]
pub struct Conv2d {
    pub spec: ConvSpec,
    pub weights: Arc<IntMatrix>,
    pub prec: Precision,
}

impl Conv2d {
    /// Random signed `wbits`-bit weights for `spec`, served at
    /// `abits`-bit unsigned activations.
    pub fn random(rng: &mut Rng, spec: ConvSpec, abits: u32, wbits: u32) -> Conv2d {
        let weights = spec.weights_from_fn(|_, _, _, _| rng.operand(wbits, true));
        Conv2d {
            spec,
            weights: Arc::new(weights),
            prec: Precision {
                wbits: abits,
                abits: wbits,
                lsigned: false,
                rsigned: true,
            },
        }
    }

    /// Direct-convolution reference for this layer.
    pub fn forward_reference(&self, x: &Tensor) -> Tensor {
        conv2d_direct(x, &self.weights, &self.spec)
    }
}

/// 2-D max pooling (per channel, no padding).
#[derive(Clone, Copy, Debug)]
pub struct MaxPool2d {
    pub kernel: usize,
    pub stride: usize,
}

impl MaxPool2d {
    pub fn new(kernel: usize, stride: usize) -> MaxPool2d {
        assert!(kernel >= 1 && stride >= 1, "pool kernel/stride must be >= 1");
        MaxPool2d { kernel, stride }
    }

    /// Output height/width for an input of `h × w`.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        assert!(h >= self.kernel && w >= self.kernel, "pool window exceeds input");
        (
            (h - self.kernel) / self.stride + 1,
            (w - self.kernel) / self.stride + 1,
        )
    }

    /// Apply the pool to every image and channel.
    pub fn apply(&self, t: &Tensor) -> Tensor {
        let (oh, ow) = self.out_hw(t.h, t.w);
        Tensor::from_fn(t.n, oh, ow, t.c, |b, oy, ox, c| {
            let mut best = i64::MIN;
            for dy in 0..self.kernel {
                for dx in 0..self.kernel {
                    best = best.max(t.get(b, oy * self.stride + dy, ox * self.stride + dx, c));
                }
            }
            best
        })
    }
}

/// FINN-style thresholding activation: the output is the number of
/// thresholds the accumulator meets or exceeds — a monotonic staircase
/// that folds ReLU and requantization into one integer comparison
/// chain. With `2^bits − 1` thresholds the output fits unsigned
/// `bits`-bit, i.e. the next layer's activation precision.
#[derive(Clone, Debug)]
pub struct Thresholding {
    pub thresholds: Vec<i64>,
}

impl Thresholding {
    /// Uniformly spaced thresholds `j · 2^shift` for
    /// `j = 1 ..= 2^bits − 1`.
    pub fn uniform(shift: u32, bits: u32) -> Thresholding {
        Thresholding {
            thresholds: (1..(1i64 << bits)).map(|j| j << shift).collect(),
        }
    }

    /// Threshold one accumulator.
    #[inline]
    pub fn value(&self, v: i64) -> i64 {
        self.thresholds.iter().filter(|&&t| v >= t).count() as i64
    }

    /// Threshold every element.
    pub fn apply(&self, t: &Tensor) -> Tensor {
        t.map(|v| self.value(v))
    }
}

/// A quantized conv–pool–conv–pool–dense classifier, every GEMM of
/// which runs on the overlay stack.
pub struct QnnCnn {
    pub conv1: Conv2d,
    pub t1: Thresholding,
    pub pool1: MaxPool2d,
    pub conv2: Conv2d,
    pub t2: Thresholding,
    pub pool2: MaxPool2d,
    /// Dense head: `(h·w·c after pool2) × classes`, lowered-GEMM RHS.
    pub fc: Arc<IntMatrix>,
    pub fc_prec: Precision,
    /// Activation precision (network input and thresholded layers).
    pub abits: u32,
}

/// Threshold shift placing the top threshold just under the layer's
/// worst-case accumulator, so the staircase actually spreads.
fn shift_for(spec: &ConvSpec, abits: u32, wbits: u32) -> u32 {
    let max_acc =
        (spec.weight_rows() as i64) * ((1i64 << abits) - 1) * (1i64 << (wbits - 1));
    let levels = (1i64 << abits) - 1;
    let mut shift = 0u32;
    while (levels << (shift + 1)) <= max_acc {
        shift += 1;
    }
    shift
}

impl QnnCnn {
    /// Build a seeded-random CNN for `in_h × in_w` single-channel
    /// inputs: 3×3/pad-1 convs to `c1` then `c2` channels (each
    /// followed by thresholding and 2×2/2 max-pool), then a dense head
    /// to 10 classes. Per-layer precision: conv1 weights are 3-bit,
    /// conv2 weights 2-bit, dense weights 3-bit — three different
    /// precisions served by one session.
    pub fn new(seed: u64, in_h: usize, in_w: usize, c1: usize, c2: usize, abits: u32) -> QnnCnn {
        let mut rng = Rng::new(seed);
        let pool = MaxPool2d::new(2, 2);
        let spec1 = ConvSpec::simple(in_h, in_w, 1, c1, 3, 1);
        let conv1 = Conv2d::random(&mut rng, spec1, abits, 3);
        let (h1, w1) = pool.out_hw(spec1.out_h(), spec1.out_w());
        let spec2 = ConvSpec::simple(h1, w1, c1, c2, 3, 1);
        let conv2 = Conv2d::random(&mut rng, spec2, abits, 2);
        let (h2, w2) = pool.out_hw(spec2.out_h(), spec2.out_w());
        let fc_in = h2 * w2 * c2;
        let fc = IntMatrix::from_fn(fc_in, 10, |_, _| rng.operand(3, true));
        QnnCnn {
            t1: Thresholding::uniform(shift_for(&spec1, abits, 3), abits),
            t2: Thresholding::uniform(shift_for(&spec2, abits, 2), abits),
            conv1,
            conv2,
            pool1: pool,
            pool2: pool,
            fc: Arc::new(fc),
            fc_prec: Precision {
                wbits: abits,
                abits: 3,
                lsigned: false,
                rsigned: true,
            },
            abits,
        }
    }

    /// The 28×28 "digits" preset matching [`super::SyntheticDigits`]:
    /// 1→8→16 channels, 7·7·16 = 784 dense inputs.
    pub fn digits(seed: u64) -> QnnCnn {
        QnnCnn::new(seed, 28, 28, 8, 16, 2)
    }

    /// Quantize a batch of float images (row-major `in_h · in_w`
    /// pixels in `[0,1]`) to the network's activation precision.
    pub fn quantize_input(&self, xs: &[Vec<f32>]) -> Tensor {
        let (h, w) = (self.conv1.spec.in_h, self.conv1.spec.in_w);
        let quant: Vec<Vec<i64>> = xs
            .iter()
            .map(|x| {
                assert_eq!(x.len(), h * w, "image size mismatch");
                quantize_activations(x, self.abits)
            })
            .collect();
        Tensor::from_fn(xs.len(), h, w, 1, |b, y, x, _| quant[b][y * w + x])
    }

    /// Pure-integer reference forward pass: direct convolution, no
    /// lowering machinery. Returns `batch × 10` logits.
    pub fn forward_reference(&self, x: &Tensor) -> IntMatrix {
        let a1 = self.t1.apply(&self.conv1.forward_reference(x));
        let p1 = self.pool1.apply(&a1);
        let a2 = self.t2.apply(&self.conv2.forward_reference(&p1));
        let p2 = self.pool2.apply(&a2);
        p2.flatten().matmul(&self.fc)
    }

    /// Prepare every layer's weights in `session`'s cache once and
    /// return the serving handle. `mode` selects the conv lowering,
    /// `backend` the execution backend for all layers.
    pub fn serve<'s>(
        &self,
        session: &'s Session,
        mode: LoweringMode,
        backend: Backend,
    ) -> Result<CnnSession<'s>, BismoError> {
        let conv1 = session
            .conv(self.conv1.spec, self.conv1.prec)
            .lowering(mode)
            .backend(backend)
            .prepare(self.conv1.weights.clone())?;
        let conv2 = session
            .conv(self.conv2.spec, self.conv2.prec)
            .lowering(mode)
            .backend(backend)
            .prepare(self.conv2.weights.clone())?;
        let fc = session.matmul(self.fc_prec).backend(backend).prepare(self.fc.clone())?;
        Ok(CnnSession {
            conv1,
            conv2,
            fc,
            t1: self.t1.clone(),
            t2: self.t2.clone(),
            pool1: self.pool1,
            pool2: self.pool2,
        })
    }

    /// Argmax predictions from logits.
    pub fn predictions(logits: &IntMatrix) -> Vec<usize> {
        super::QnnMlp::predictions(logits)
    }
}

/// A [`QnnCnn`] whose weights are resident in a session's packing
/// cache: the prepare-once-execute-many handle for whole-network
/// inference.
pub struct CnnSession<'s> {
    conv1: PreparedConv<'s>,
    conv2: PreparedConv<'s>,
    fc: Prepared<'s>,
    t1: Thresholding,
    t2: Thresholding,
    pool1: MaxPool2d,
    pool2: MaxPool2d,
}

impl CnnSession<'_> {
    /// One batched inference at the layers' prepared precisions.
    /// Returns `batch × 10` logits and the per-GEMM responses (conv1
    /// taps, conv2 taps, dense — in execution order).
    pub fn infer(&self, x: &Tensor) -> Result<(IntMatrix, Vec<GemmResponse>), BismoError> {
        self.infer_inner(x, None)
    }

    /// [`CnnSession::infer`] with a per-layer precision override on
    /// the second conv layer: the same resident weights served at a
    /// different declared precision — the variable-precision serving
    /// case at layer granularity.
    pub fn infer_with_conv2(
        &self,
        x: &Tensor,
        conv2_prec: Precision,
    ) -> Result<(IntMatrix, Vec<GemmResponse>), BismoError> {
        self.infer_inner(x, Some(conv2_prec))
    }

    fn infer_inner(
        &self,
        x: &Tensor,
        conv2_prec: Option<Precision>,
    ) -> Result<(IntMatrix, Vec<GemmResponse>), BismoError> {
        let r1 = self.conv1.execute(x)?;
        let p1 = self.pool1.apply(&self.t1.apply(&r1.output));
        let r2 = match conv2_prec {
            None => self.conv2.execute(&p1)?,
            Some(p) => self.conv2.execute_with(&p1, p)?,
        };
        let p2 = self.pool2.apply(&self.t2.apply(&r2.output));
        let r3 = self.fc.execute(p2.flatten())?;
        let logits = r3.result.clone();
        let mut gemms = r1.gemms;
        gemms.extend(r2.gemms);
        gemms.push(r3);
        Ok((logits, gemms))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SessionConfig;

    fn tiny() -> QnnCnn {
        QnnCnn::new(0xC22, 8, 8, 3, 4, 2)
    }

    fn random_input(rng: &mut Rng, cnn: &QnnCnn, batch: usize) -> Tensor {
        let spec = cnn.conv1.spec;
        Tensor::random(rng, batch, spec.in_h, spec.in_w, 1, cnn.abits, false)
    }

    #[test]
    fn geometry_chains_through_the_network() {
        let cnn = tiny();
        assert_eq!(cnn.conv1.spec.out_h(), 8);
        assert_eq!(cnn.conv2.spec.in_h, 4);
        assert_eq!(cnn.fc.rows, 2 * 2 * 4);
        let digits = QnnCnn::digits(1);
        assert_eq!(digits.fc.rows, 7 * 7 * 16, "28→14→7 spatial chain");
    }

    #[test]
    fn thresholding_is_a_monotonic_staircase_that_fits() {
        let t = Thresholding::uniform(3, 2);
        assert_eq!(t.thresholds, vec![8, 16, 24]);
        assert_eq!(t.value(-5), 0);
        assert_eq!(t.value(7), 0);
        assert_eq!(t.value(8), 1);
        assert_eq!(t.value(1000), 3);
        let x = Tensor::from_fn(1, 2, 2, 1, |_, y, xp, _| (y * 16 + xp * 8) as i64);
        assert!(t.apply(&x).fits(2, false));
    }

    #[test]
    fn maxpool_matches_hand_example() {
        let x = Tensor::from_fn(1, 4, 4, 1, |_, y, xp, _| (y * 4 + xp) as i64);
        let p = MaxPool2d::new(2, 2).apply(&x);
        assert_eq!((p.h, p.w), (2, 2));
        assert_eq!(p.get(0, 0, 0, 0), 5);
        assert_eq!(p.get(0, 1, 1, 0), 15);
    }

    #[test]
    fn served_cnn_is_bit_exact_on_both_backends_and_modes() {
        let cnn = tiny();
        let mut rng = Rng::new(0x11F);
        let session = Session::new(SessionConfig::default()).unwrap();
        let x = random_input(&mut rng, &cnn, 2);
        let want = cnn.forward_reference(&x);
        for backend in [Backend::Engine, Backend::Sim] {
            for mode in [LoweringMode::Im2col, LoweringMode::Kn2row] {
                let served = cnn.serve(&session, mode, backend).unwrap();
                let (logits, gemms) = served.infer(&x).unwrap();
                assert_eq!(logits, want, "{} {:?}", backend.name(), mode);
                let conv_gemms = match mode {
                    LoweringMode::Im2col => 2,
                    LoweringMode::Kn2row => 18,
                };
                assert_eq!(gemms.len(), conv_gemms + 1);
                if backend == Backend::Sim {
                    assert!(gemms.iter().all(|g| g.report.is_some()));
                }
            }
        }
    }

    #[test]
    fn repeated_inference_reuses_every_weight_packing() {
        let cnn = tiny();
        let mut rng = Rng::new(0x120);
        let session = Session::new(SessionConfig::default()).unwrap();
        let served = cnn.serve(&session, LoweringMode::Im2col, Backend::Engine).unwrap();
        let after_prepare = session.cache_stats();
        for i in 0..3 {
            let x = random_input(&mut rng, &cnn, 1);
            let (logits, gemms) = served.infer(&x).unwrap();
            assert_eq!(logits, cnn.forward_reference(&x), "inference {i}");
            assert!(gemms.iter().all(|g| g.rhs_cached), "inference {i} hits the cache");
        }
        let after = session.cache_stats();
        assert_eq!(after.misses, after_prepare.misses, "no repacks after prepare");
    }

    #[test]
    fn conv2_precision_override_serves_same_weights_wider() {
        let cnn = tiny();
        let mut rng = Rng::new(0x121);
        let session = Session::new(SessionConfig::default()).unwrap();
        let served = cnn.serve(&session, LoweringMode::Im2col, Backend::Engine).unwrap();
        let x = random_input(&mut rng, &cnn, 2);
        let (base_logits, _) = served.infer(&x).unwrap();
        // Declared headroom on conv2 (activations 3-bit, weights
        // 4-bit) must not change a single logit.
        let wider = Precision {
            wbits: 3,
            abits: 4,
            lsigned: false,
            rsigned: true,
        };
        let (logits, _) = served.infer_with_conv2(&x, wider).unwrap();
        assert_eq!(logits, base_logits);
        // The override packing is resident from its first use.
        let (logits2, gemms) = served.infer_with_conv2(&x, wider).unwrap();
        assert_eq!(logits2, base_logits);
        assert!(gemms.iter().all(|g| g.rhs_cached));
    }
}
