//! Float MLP (784-256-256-10) with minibatch SGD: the model that gets
//! quantized onto the overlay. Deliberately dependency-free and small;
//! training a ~270k-parameter MLP on the synthetic set takes well under
//! a second per epoch.

use crate::util::Rng;

/// Row-major dense layer weights (in_dim × out_dim), no bias (keeps the
/// integer pipeline bias-free like the overlay's accumulator path).
pub struct FloatMlp {
    pub dims: [usize; 4],
    pub w: [Vec<f32>; 3],
}

fn matvec(w: &[f32], x: &[f32], in_dim: usize, out_dim: usize, out: &mut [f32]) {
    out.iter_mut().for_each(|o| *o = 0.0);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * out_dim..(i + 1) * out_dim];
        for (o, &wij) in out.iter_mut().zip(row) {
            *o += xi * wij;
        }
    }
    debug_assert_eq!(x.len(), in_dim);
}

fn relu(x: &mut [f32]) {
    x.iter_mut().for_each(|v| *v = v.max(0.0));
}

fn softmax_xent_grad(logits: &[f32], label: usize, grad: &mut [f32]) -> f32 {
    let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&l| (l - max).exp()).collect();
    let z: f32 = exps.iter().sum();
    for (g, e) in grad.iter_mut().zip(&exps) {
        *g = e / z;
    }
    grad[label] -= 1.0;
    -(exps[label] / z).max(1e-12).ln()
}

impl FloatMlp {
    /// He-initialized random MLP.
    pub fn new(seed: u64, dims: [usize; 4]) -> Self {
        let mut rng = Rng::new(seed);
        let mut init = |i: usize, o: usize| -> Vec<f32> {
            let scale = (2.0 / i as f64).sqrt();
            (0..i * o)
                .map(|_| ((rng.f64() * 2.0 - 1.0) * scale) as f32)
                .collect()
        };
        let w = [
            init(dims[0], dims[1]),
            init(dims[1], dims[2]),
            init(dims[2], dims[3]),
        ];
        FloatMlp { dims, w }
    }

    /// Forward pass returning all activations (for backprop).
    fn forward_full(&self, x: &[f32]) -> [Vec<f32>; 3] {
        let [d0, d1, d2, d3] = self.dims;
        let mut h1 = vec![0.0; d1];
        matvec(&self.w[0], x, d0, d1, &mut h1);
        relu(&mut h1);
        let mut h2 = vec![0.0; d2];
        matvec(&self.w[1], &h1, d1, d2, &mut h2);
        relu(&mut h2);
        let mut out = vec![0.0; d3];
        matvec(&self.w[2], &h2, d2, d3, &mut out);
        [h1, h2, out]
    }

    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        let [_, _, out] = self.forward_full(x);
        out
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let l = self.logits(x);
        l.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0
    }

    pub fn accuracy(&self, xs: &[Vec<f32>], ys: &[usize]) -> f64 {
        let correct = xs
            .iter()
            .zip(ys)
            .filter(|(x, &y)| self.predict(x) == y)
            .count();
        correct as f64 / xs.len().max(1) as f64
    }

    /// One epoch of plain SGD; returns mean loss.
    pub fn train_epoch(&mut self, xs: &[Vec<f32>], ys: &[usize], lr: f32, seed: u64) -> f64 {
        let [d0, d1, d2, d3] = self.dims;
        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut rng = Rng::new(seed);
        for i in (1..order.len()).rev() {
            order.swap(i, rng.index(i + 1));
        }
        let mut total = 0.0f64;
        let mut g3 = vec![0.0f32; d3];
        for &s in &order {
            let x = &xs[s];
            let [h1, h2, out] = self.forward_full(x);
            total += softmax_xent_grad(&out, ys[s], &mut g3) as f64;
            // Backprop layer 3.
            let mut g2 = vec![0.0f32; d2];
            for (i, &h) in h2.iter().enumerate() {
                let row = &mut self.w[2][i * d3..(i + 1) * d3];
                let mut acc = 0.0;
                for (j, w) in row.iter_mut().enumerate() {
                    acc += *w * g3[j];
                    *w -= lr * h * g3[j];
                }
                g2[i] = if h > 0.0 { acc } else { 0.0 };
            }
            // Layer 2.
            let mut g1 = vec![0.0f32; d1];
            for (i, &h) in h1.iter().enumerate() {
                let row = &mut self.w[1][i * d2..(i + 1) * d2];
                let mut acc = 0.0;
                for (j, w) in row.iter_mut().enumerate() {
                    acc += *w * g2[j];
                    *w -= lr * h * g2[j];
                }
                g1[i] = if h > 0.0 { acc } else { 0.0 };
            }
            // Layer 1.
            for (i, &xi) in x.iter().enumerate() {
                if xi == 0.0 {
                    continue;
                }
                let row = &mut self.w[0][i * d1..(i + 1) * d1];
                for (j, w) in row.iter_mut().enumerate() {
                    *w -= lr * xi * g1[j];
                }
            }
            debug_assert_eq!(x.len(), d0);
        }
        total / xs.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qnn::dataset::SyntheticDigits;

    #[test]
    fn learns_synthetic_digits() {
        let d = SyntheticDigits::generate(42, 400, 100, 0.15);
        let mut mlp = FloatMlp::new(7, [784, 64, 64, 10]);
        let before = mlp.accuracy(&d.test_x, &d.test_y);
        let mut loss_first = 0.0;
        let mut loss_last = 0.0;
        for e in 0..3 {
            let loss = mlp.train_epoch(&d.train_x, &d.train_y, 0.02, e);
            if e == 0 {
                loss_first = loss;
            }
            loss_last = loss;
        }
        let after = mlp.accuracy(&d.test_x, &d.test_y);
        assert!(loss_last < loss_first, "loss {loss_first} -> {loss_last}");
        assert!(
            after > before.max(0.5),
            "accuracy {before:.2} -> {after:.2}"
        );
    }

    #[test]
    fn softmax_grad_sums_to_zero() {
        let logits = [1.0, 2.0, 0.5];
        let mut g = [0.0; 3];
        let loss = softmax_xent_grad(&logits, 1, &mut g);
        assert!(loss > 0.0);
        assert!(g.iter().sum::<f32>().abs() < 1e-6);
        assert!(g[1] < 0.0);
    }

    #[test]
    fn predict_in_range() {
        let mlp = FloatMlp::new(1, [784, 16, 16, 10]);
        let x = vec![0.5; 784];
        assert!(mlp.predict(&x) < 10);
    }
}
