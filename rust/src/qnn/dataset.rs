//! Synthetic digit-classification dataset.
//!
//! 10 class prototypes drawn uniformly in `[0,1]^784`, samples =
//! prototype + Gaussian noise (clipped back to `[0,1]`). Chosen so a
//! small MLP reaches high accuracy quickly while quantization still
//! costs measurable accuracy — the phenomenon the paper's
//! variable-precision story is about. Stands in for MNIST (no dataset
//! downloads in this offline environment).

use crate::util::Rng;

/// A generated dataset: features in `[0,1]`, labels `0..10`.
pub struct SyntheticDigits {
    pub dim: usize,
    pub classes: usize,
    pub train_x: Vec<Vec<f32>>,
    pub train_y: Vec<usize>,
    pub test_x: Vec<Vec<f32>>,
    pub test_y: Vec<usize>,
}

/// Standard normal via Box–Muller.
fn gaussian(rng: &mut Rng) -> f32 {
    let u1 = rng.f64().max(1e-12);
    let u2 = rng.f64();
    ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
}

impl SyntheticDigits {
    /// Generate with `noise` standard deviation around the prototypes.
    pub fn generate(seed: u64, train_n: usize, test_n: usize, noise: f32) -> Self {
        let dim = 784;
        let classes = 10;
        let mut rng = Rng::new(seed);
        let protos: Vec<Vec<f32>> = (0..classes)
            .map(|_| (0..dim).map(|_| rng.f64() as f32).collect())
            .collect();
        let sample = |rng: &mut Rng| {
            let y = rng.index(classes);
            let x: Vec<f32> = protos[y]
                .iter()
                .map(|&p| (p + noise * gaussian(rng)).clamp(0.0, 1.0))
                .collect();
            (x, y)
        };
        let mut train_x = Vec::with_capacity(train_n);
        let mut train_y = Vec::with_capacity(train_n);
        for _ in 0..train_n {
            let (x, y) = sample(&mut rng);
            train_x.push(x);
            train_y.push(y);
        }
        let mut test_x = Vec::with_capacity(test_n);
        let mut test_y = Vec::with_capacity(test_n);
        for _ in 0..test_n {
            let (x, y) = sample(&mut rng);
            test_x.push(x);
            test_y.push(y);
        }
        SyntheticDigits {
            dim,
            classes,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let d = SyntheticDigits::generate(1, 100, 40, 0.15);
        assert_eq!(d.train_x.len(), 100);
        assert_eq!(d.test_x.len(), 40);
        assert!(d.train_x.iter().all(|x| x.len() == 784));
        assert!(d
            .train_x
            .iter()
            .flatten()
            .all(|&v| (0.0..=1.0).contains(&v)));
        assert!(d.train_y.iter().all(|&y| y < 10));
        // All classes present in a 100-sample draw (w.h.p.).
        let mut seen = [false; 10];
        for &y in &d.train_y {
            seen[y] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 8);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = SyntheticDigits::generate(7, 10, 5, 0.1);
        let b = SyntheticDigits::generate(7, 10, 5, 0.1);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.test_y, b.test_y);
        let c = SyntheticDigits::generate(8, 10, 5, 0.1);
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| gaussian(&mut rng)).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
