//! Quantized neural network layer: the application the paper motivates
//! BISMO with (QNN inference à la FINN / Park et al.).
//!
//! * [`dataset`] — synthetic 784-dimensional "digits" (10 Gaussian
//!   class prototypes) standing in for MNIST (no dataset downloads in
//!   this environment; documented substitution).
//! * [`mlp`] — a small float MLP (784-256-256-10) trained in-crate with
//!   SGD: the model that gets quantized.
//! * [`quantize`] — symmetric weight quantization + activation
//!   quantization to the overlay's operand precisions.
//! * [`infer`] — integer-only inference: a reference path (pure i64),
//!   the overlay path where every GEMM runs through
//!   [`crate::coordinator::BismoContext`], and the serving path where
//!   GEMMs are submitted to [`crate::coordinator::BismoService`] (layer
//!   weights are weight-stationary, so the service's packing cache
//!   skips repacking them per request); all must agree bit-exactly
//!   with the AOT-compiled JAX artifact.
//! * [`cnn`] — quantized CNN layers ([`Conv2d`] lowered onto the GEMM
//!   stack via [`crate::lowering`], [`MaxPool2d`], [`Thresholding`])
//!   and the [`QnnCnn`] conv–pool–conv–pool–dense classifier served
//!   end to end with per-layer precision.
//! * [`attn`] — a quantized transformer encoder block ([`QnnAttn`]):
//!   per-head attention + FFN as a DAG of integer GEMMs with a
//!   distinct [`crate::coordinator::Precision`] per matrix, integer
//!   softmax by fixed-point staircase, served via
//!   [`crate::api::Session::attn`].
//! * [`policy`] — input-adaptive precision: [`PrecisionPolicy`]
//!   implementations that inspect per-request [`ActivationStats`] and
//!   pick the activation bit width each layer actually needs (fewer
//!   bit planes → proportionally less bit-serial work).

pub mod attn;
pub mod cnn;
pub mod dataset;
pub mod infer;
pub mod mlp;
pub mod policy;
pub mod quantize;

pub use attn::{AttnSpec, AttnWeightBits, QnnAttn, SoftmaxStaircase};
pub use cnn::{CnnSession, Conv2d, MaxPool2d, QnnCnn, Thresholding};
pub use dataset::SyntheticDigits;
pub use infer::QnnMlp;
pub use mlp::FloatMlp;
pub use policy::{
    ActivationStats, ClampPolicy, EntropyAdaptivePolicy, PolicyDecision, PrecisionPolicy,
    RangeAdaptivePolicy, StaticPolicy,
};
pub use quantize::{quantize_activations, quantize_weights_symmetric};
