//! Weight-stationary packing cache: content-addressed, LRU-evicted
//! storage of packed bit-serial operands.
//!
//! Packing an operand — bit-plane decomposition, plus the fused
//! transpose for the RHS — is a full pass over the matrix and sits on
//! the request path of every GEMM. QNN serving replays the same weight
//! matrices across requests (the *weight-stationary* case the paper's
//! motivating workload exhibits layer by layer), so
//! [`crate::coordinator::BismoService`] keys packed operands by
//! [`IntMatrix::content_hash`] and serves repeat requests straight from
//! this cache, skipping the repack entirely.
//!
//! Identity is the 64-bit content hash plus shape/precision/layout; a
//! hash collision between *different* matrices of identical shape would
//! alias them. At 64 bits this is accepted and documented rather than
//! defended against (the alternative — comparing full contents on every
//! hit — would cost a pass comparable to the repack being avoided).

use crate::api::BismoError;
use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

/// Cache identity of one packed operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PackKey {
    /// [`IntMatrix::content_hash`] of the source matrix.
    pub content: u64,
    /// Source shape (pre-transpose).
    pub rows: usize,
    pub cols: usize,
    /// Operand precision the planes were decomposed at.
    pub bits: u32,
    pub signed: bool,
    /// Packed via [`BitSerialMatrix::from_int_transposed`] (RHS layout)
    /// rather than [`BitSerialMatrix::from_int`] (LHS layout).
    pub transposed: bool,
    /// Tenant namespace the packing belongs to. Part of the identity:
    /// tenants share this cache's byte budget and LRU order but can
    /// never address each other's entries — identical weights uploaded
    /// by two tenants are two entries. `0` is the default (in-process)
    /// namespace used by every non-network caller.
    pub namespace: u64,
}

impl PackKey {
    /// Key for packing `m` at `bits`/`signed`, direct or transposed, in
    /// the default namespace `0`.
    pub fn of(m: &IntMatrix, bits: u32, signed: bool, transposed: bool) -> PackKey {
        PackKey {
            content: m.content_hash(),
            rows: m.rows,
            cols: m.cols,
            bits,
            signed,
            transposed,
            namespace: 0,
        }
    }

    /// The same key scoped to tenant namespace `ns` (the network front
    /// door derives `ns` from the tenant name; see `bismo::net`).
    pub fn in_namespace(mut self, ns: u64) -> PackKey {
        self.namespace = ns;
        self
    }
}

/// The packing a [`PackKey`] identifies: bit-plane decomposition in
/// either layout. The single pack path shared by the cache and the
/// serving layer, so identity (key) and content (this function) cannot
/// drift apart. Callers must range-check first ([`check_fits`]) — the
/// decomposition itself panics on out-of-range entries.
pub fn pack_operand(m: &IntMatrix, bits: u32, signed: bool, transposed: bool) -> BitSerialMatrix {
    if transposed {
        BitSerialMatrix::from_int_transposed(m, bits, signed)
    } else {
        BitSerialMatrix::from_int(m, bits, signed)
    }
}

/// Range validation shared by every pack path: every entry of `m` must
/// fit the declared precision before bit-plane decomposition. `side`
/// labels the operand in the error ("lhs"/"rhs").
pub fn check_fits(m: &IntMatrix, bits: u32, signed: bool, side: &str) -> Result<(), BismoError> {
    if m.fits(bits, signed) {
        Ok(())
    } else {
        Err(BismoError::PrecisionUnsupported(format!(
            "{side} entries do not fit {} {bits}-bit",
            if signed { "signed" } else { "unsigned" },
        )))
    }
}

/// Hit/miss/eviction counters (monotonic over the cache's lifetime).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Fraction of lookups served from the cache.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

struct Entry {
    packed: Arc<BitSerialMatrix>,
    bytes: usize,
    /// Monotonic tick of the last lookup hit (or insertion).
    last_used: u64,
}

/// LRU cache of packed operands, bounded by total packed bytes.
///
/// Single-threaded by itself; the serving layer wraps it in a `Mutex`
/// and keeps the critical sections to lookup/insert (packing happens
/// outside the lock). Recency is a tick-ordered side index, so
/// eviction is `O(log n)` instead of a full scan — churn workloads
/// (e.g. `cache_lhs` with fresh activations) evict on every insert.
pub struct PackingCache {
    map: HashMap<PackKey, Entry>,
    /// `last_used` tick → key. Ticks are unique (monotonic, one per
    /// touch), so the first entry is always the least recently used.
    lru: BTreeMap<u64, PackKey>,
    capacity_bytes: usize,
    bytes: usize,
    tick: u64,
    stats: CacheStats,
}

impl PackingCache {
    /// A cache holding at most `capacity_bytes` of packed operand data.
    /// Zero capacity disables caching (every lookup misses, nothing is
    /// stored) — the serving layer's cache-off mode.
    pub fn new(capacity_bytes: usize) -> PackingCache {
        PackingCache {
            map: HashMap::new(),
            lru: BTreeMap::new(),
            capacity_bytes,
            bytes: 0,
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// Look `key` up, counting a hit or miss and refreshing LRU order.
    pub fn get(&mut self, key: &PackKey) -> Option<Arc<BitSerialMatrix>> {
        self.tick += 1;
        match self.map.get_mut(key) {
            Some(e) => {
                self.lru.remove(&e.last_used);
                e.last_used = self.tick;
                self.lru.insert(self.tick, *key);
                self.stats.hits += 1;
                Some(e.packed.clone())
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Is `key` resident? Does not touch LRU order or the counters.
    pub fn contains(&self, key: &PackKey) -> bool {
        self.map.contains_key(key)
    }

    /// Insert a packed operand, evicting least-recently-used entries
    /// until it fits. An operand larger than the whole capacity is not
    /// cached at all.
    pub fn insert(&mut self, key: PackKey, packed: Arc<BitSerialMatrix>) {
        let bytes = packed.packed_bytes();
        // The capacity-0 check keeps cache-off mode honest even for
        // zero-byte packings (0-row/0-col operands).
        if self.capacity_bytes == 0 || bytes > self.capacity_bytes {
            return;
        }
        if let Some(old) = self.map.remove(&key) {
            // Re-insert of a racing miss: replace, keep accounting exact.
            self.lru.remove(&old.last_used);
            self.bytes -= old.bytes;
        }
        while self.bytes + bytes > self.capacity_bytes {
            let (_, lru_key) = self
                .lru
                .pop_first()
                .expect("bytes > 0 implies a resident entry");
            let evicted = self.map.remove(&lru_key).unwrap();
            self.bytes -= evicted.bytes;
            self.stats.evictions += 1;
        }
        self.tick += 1;
        self.lru.insert(self.tick, key);
        self.map.insert(
            key,
            Entry {
                packed,
                bytes,
                last_used: self.tick,
            },
        );
        self.bytes += bytes;
        self.stats.insertions += 1;
    }

    /// Look up, packing and inserting on a miss; errs on operands
    /// outside the declared precision (same [`check_fits`] gate as the
    /// serving layer, skipped on hits). Returns the packed operand and
    /// whether it was served from the cache.
    ///
    /// Single-threaded convenience: unlike the serving layer's
    /// pack-outside-the-lock path, this packs while holding `&mut self`
    /// — do not call it under a contended mutex.
    pub fn get_or_pack(
        &mut self,
        m: &IntMatrix,
        bits: u32,
        signed: bool,
        transposed: bool,
    ) -> Result<(Arc<BitSerialMatrix>, bool), BismoError> {
        let key = PackKey::of(m, bits, signed, transposed);
        if let Some(hit) = self.get(&key) {
            return Ok((hit, true));
        }
        check_fits(m, bits, signed, "operand")?;
        let packed = Arc::new(pack_operand(m, bits, signed, transposed));
        self.insert(key, packed.clone());
        Ok((packed, false))
    }

    /// Resident entry count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Resident packed bytes.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Configured capacity in bytes.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes
    }

    /// Lifetime counters.
    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Drop every entry (counters are kept).
    pub fn clear(&mut self) {
        self.map.clear();
        self.lru.clear();
        self.bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{property_sweep, Rng};

    fn mat(rng: &mut Rng, rows: usize, cols: usize, bits: u32, signed: bool) -> IntMatrix {
        IntMatrix::random(rng, rows, cols, bits, signed)
    }

    #[test]
    fn hit_and_miss_accounting() {
        let mut c = PackingCache::new(1 << 20);
        let mut rng = Rng::new(1);
        let a = mat(&mut rng, 4, 64, 2, false);
        let (p1, hit1) = c.get_or_pack(&a, 2, false, false).unwrap();
        assert!(!hit1);
        let (p2, hit2) = c.get_or_pack(&a, 2, false, false).unwrap();
        assert!(hit2);
        assert!(Arc::ptr_eq(&p1, &p2), "hit returns the resident packing");
        // Same matrix, different precision / layout: distinct entries.
        let (_, hit3) = c.get_or_pack(&a, 3, false, false).unwrap();
        assert!(!hit3);
        let (_, hit4) = c.get_or_pack(&a, 2, false, true).unwrap();
        assert!(!hit4);
        let s = c.stats();
        assert_eq!(s.hits, 1);
        assert_eq!(s.misses, 3);
        assert_eq!(s.insertions, 3);
        assert_eq!(s.evictions, 0);
        assert!((s.hit_rate() - 0.25).abs() < 1e-12);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn lru_eviction_order() {
        let mut rng = Rng::new(2);
        // Three same-shape operands: identical packed size, so a
        // capacity of exactly two packings forces LRU eviction.
        let a = mat(&mut rng, 4, 64, 2, false);
        let b = mat(&mut rng, 4, 64, 2, false);
        let d = mat(&mut rng, 4, 64, 2, false);
        let one = BitSerialMatrix::from_int(&a, 2, false).packed_bytes();
        let mut c = PackingCache::new(2 * one);
        let ka = PackKey::of(&a, 2, false, false);
        let kb = PackKey::of(&b, 2, false, false);
        let kd = PackKey::of(&d, 2, false, false);
        c.get_or_pack(&a, 2, false, false).unwrap();
        c.get_or_pack(&b, 2, false, false).unwrap();
        assert_eq!(c.len(), 2);
        // Touch `a`, making `b` the least recently used.
        let (_, hit) = c.get_or_pack(&a, 2, false, false).unwrap();
        assert!(hit);
        c.get_or_pack(&d, 2, false, false).unwrap();
        assert!(c.contains(&ka), "recently-touched entry survives");
        assert!(!c.contains(&kb), "LRU entry evicted");
        assert!(c.contains(&kd));
        assert_eq!(c.stats().evictions, 1);
        assert_eq!(c.bytes(), 2 * one);
    }

    #[test]
    fn cached_packing_is_bit_exact_on_signed_and_ragged_shapes() {
        // Cached-vs-fresh must be indistinguishable across signedness,
        // ragged k (not a multiple of 64) and both layouts.
        property_sweep(0xCAC4E, 20, |rng, _| {
            let rows = rng.index(9) + 1;
            let cols = rng.index(150) + 1; // frequently ragged
            let bits = rng.index(8) as u32 + 1;
            let signed = rng.chance(0.5);
            let transposed = rng.chance(0.5);
            let m = IntMatrix::random(rng, rows, cols, bits, signed);
            let mut c = PackingCache::new(1 << 22);
            let (fresh, h0) = c.get_or_pack(&m, bits, signed, transposed).unwrap();
            let (cached, h1) = c.get_or_pack(&m, bits, signed, transposed).unwrap();
            assert!(!h0 && h1);
            let expect = if transposed {
                BitSerialMatrix::from_int_transposed(&m, bits, signed)
            } else {
                BitSerialMatrix::from_int(&m, bits, signed)
            };
            assert_eq!(*fresh, expect);
            assert_eq!(*cached, expect);
        });
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let mut c = PackingCache::new(0);
        let mut rng = Rng::new(3);
        let a = mat(&mut rng, 2, 64, 1, false);
        let (_, hit1) = c.get_or_pack(&a, 1, false, false).unwrap();
        let (_, hit2) = c.get_or_pack(&a, 1, false, false).unwrap();
        assert!(!hit1 && !hit2);
        // Degenerate zero-byte packings must not sneak past cache-off.
        let empty = IntMatrix::zeros(0, 5);
        let (_, h1) = c.get_or_pack(&empty, 1, false, false).unwrap();
        let (_, h2) = c.get_or_pack(&empty, 1, false, false).unwrap();
        assert!(!h1 && !h2, "zero-byte packing cached in cache-off mode");
        assert_eq!(c.len(), 0);
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.stats().insertions, 0);
    }

    #[test]
    fn oversized_entry_is_not_cached_and_evicts_nothing() {
        let mut rng = Rng::new(4);
        let small = mat(&mut rng, 2, 64, 1, false);
        let one = BitSerialMatrix::from_int(&small, 1, false).packed_bytes();
        let mut c = PackingCache::new(one);
        c.get_or_pack(&small, 1, false, false).unwrap();
        assert_eq!(c.len(), 1);
        // 8 planes of a bigger matrix cannot fit the single-packing cap.
        let big = mat(&mut rng, 16, 256, 8, false);
        let (_, hit) = c.get_or_pack(&big, 8, false, false).unwrap();
        assert!(!hit);
        assert_eq!(c.len(), 1, "oversized insert is a no-op");
        assert!(c.contains(&PackKey::of(&small, 1, false, false)));
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn out_of_range_operand_errs_instead_of_panicking() {
        let mut c = PackingCache::new(1 << 20);
        let m = IntMatrix::from_slice(1, 2, &[3, 100]);
        let err = c.get_or_pack(&m, 2, false, false).unwrap_err();
        assert!(
            matches!(err, BismoError::PrecisionUnsupported(_)),
            "{err:?}"
        );
        assert!(err.to_string().contains("do not fit"), "{err}");
        assert!(c.is_empty(), "failed pack must not insert");
        // The range is re-derived per precision: same matrix fits 7-bit.
        let (_, hit) = c.get_or_pack(&m, 7, false, false).unwrap();
        assert!(!hit);
    }

    #[test]
    fn namespaces_partition_identity_not_storage() {
        let mut c = PackingCache::new(1 << 20);
        let mut rng = Rng::new(6);
        let m = mat(&mut rng, 4, 64, 2, false);
        let k0 = PackKey::of(&m, 2, false, true);
        let ka = k0.in_namespace(0xA);
        let kb = k0.in_namespace(0xB);
        assert_ne!(ka, kb);
        let packed = Arc::new(pack_operand(&m, 2, false, true));
        c.insert(ka, packed.clone());
        // Tenant B (and the default namespace) miss on tenant A's entry
        // even though content/shape/precision are identical.
        assert!(c.get(&kb).is_none());
        assert!(c.get(&k0).is_none());
        assert!(c.get(&ka).is_some());
        // Same backing store: both tenants' entries count against one
        // byte budget.
        c.insert(kb, packed.clone());
        assert_eq!(c.len(), 2);
        assert_eq!(c.bytes(), 2 * packed.packed_bytes());
    }

    #[test]
    fn clear_keeps_counters() {
        let mut c = PackingCache::new(1 << 20);
        let mut rng = Rng::new(5);
        let a = mat(&mut rng, 2, 64, 1, false);
        c.get_or_pack(&a, 1, false, false).unwrap();
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
        assert_eq!(c.stats().misses, 1);
        // Re-packing after clear is a fresh miss, not a corrupted hit.
        let (_, hit) = c.get_or_pack(&a, 1, false, false).unwrap();
        assert!(!hit);
    }
}
