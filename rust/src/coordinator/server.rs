//! Batched job execution: the request-loop topology.
//!
//! A deployment of BISMO serves many independent GEMM jobs (e.g. the
//! layers of many concurrent QNN inferences). [`BismoBatchRunner`] owns
//! a pool of worker threads, each standing for one overlay instance,
//! draining a shared queue — the same leader/worker shape a PCIe
//! multi-FPGA host process would use, with the simulator in place of
//! the device.

use super::context::{BismoContext, MatmulOptions, Precision, RunReport};
use crate::arch::BismoConfig;
use crate::bitmatrix::IntMatrix;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Result of one job in a batch.
pub struct BatchOutcome {
    pub index: usize,
    pub result: Result<(IntMatrix, RunReport), String>,
}

/// Fixed pool of simulated overlay workers.
pub struct BismoBatchRunner {
    cfg: BismoConfig,
    workers: usize,
}

impl BismoBatchRunner {
    pub fn new(cfg: BismoConfig, workers: usize) -> Result<Self, String> {
        // Validate once up front (each worker builds its own context).
        BismoContext::new(cfg)?;
        Ok(BismoBatchRunner {
            cfg,
            workers: workers.max(1),
        })
    }

    /// Run all jobs, preserving input order in the output.
    pub fn run_batch(
        &self,
        jobs: &[(IntMatrix, IntMatrix, Precision, MatmulOptions)],
    ) -> Vec<BatchOutcome> {
        let next = AtomicUsize::new(0);
        let out: Mutex<Vec<Option<BatchOutcome>>> =
            Mutex::new((0..jobs.len()).map(|_| None).collect());
        std::thread::scope(|scope| {
            for _ in 0..self.workers.min(jobs.len().max(1)) {
                scope.spawn(|| {
                    // One overlay per worker.
                    let ctx = match BismoContext::new(self.cfg) {
                        Ok(c) => c,
                        Err(_) => return, // validated in new(); unreachable
                    };
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        let (a, b, prec, opts) = &jobs[i];
                        let result = ctx.matmul(a, b, *prec, *opts);
                        out.lock().unwrap()[i] = Some(BatchOutcome { index: i, result });
                    }
                });
            }
        });
        out.into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("all jobs completed"))
            .collect()
    }

    /// Aggregate throughput of a batch: total binary ops / total
    /// simulated seconds (jobs run on `workers` parallel overlays).
    pub fn batch_gops(&self, outcomes: &[BatchOutcome]) -> f64 {
        let mut total_ops = 0.0;
        let mut total_secs = 0.0f64;
        for o in outcomes {
            if let Ok((_, rep)) = &o.result {
                total_ops += rep.gops * rep.seconds * 1e9;
                total_secs += rep.seconds;
            }
        }
        if total_secs == 0.0 {
            0.0
        } else {
            total_ops / (total_secs / self.workers as f64) / 1e9
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn batch_matches_serial_and_orders() {
        let runner = BismoBatchRunner::new(BismoConfig::small(), 4).unwrap();
        let mut rng = Rng::new(77);
        let jobs: Vec<_> = (0..10)
            .map(|_| {
                let k = rng.index(128) + 1;
                let a = IntMatrix::random(&mut rng, 4, k, 2, false);
                let b = IntMatrix::random(&mut rng, k, 4, 2, false);
                (a, b, Precision::unsigned(2, 2), MatmulOptions::default())
            })
            .collect();
        let outcomes = runner.run_batch(&jobs);
        assert_eq!(outcomes.len(), 10);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.index, i);
            let (p, _) = o.result.as_ref().unwrap();
            assert_eq!(*p, jobs[i].0.matmul(&jobs[i].1), "job {i}");
        }
        assert!(runner.batch_gops(&outcomes) > 0.0);
    }

    #[test]
    fn single_worker_works() {
        let runner = BismoBatchRunner::new(BismoConfig::small(), 1).unwrap();
        let mut rng = Rng::new(78);
        let a = IntMatrix::random(&mut rng, 2, 64, 1, false);
        let b = IntMatrix::random(&mut rng, 64, 2, 1, false);
        let jobs = vec![(a, b, Precision::unsigned(1, 1), MatmulOptions::default())];
        let outcomes = runner.run_batch(&jobs);
        assert!(outcomes[0].result.is_ok());
    }

    #[test]
    fn empty_batch_ok() {
        let runner = BismoBatchRunner::new(BismoConfig::small(), 2).unwrap();
        let outcomes = runner.run_batch(&[]);
        assert!(outcomes.is_empty());
        assert_eq!(runner.batch_gops(&outcomes), 0.0);
    }
}
