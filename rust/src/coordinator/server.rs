//! Batched job execution: the request-loop topology.
//!
//! A deployment of BISMO serves many independent GEMM jobs (e.g. the
//! layers of many concurrent QNN inferences). [`BismoBatchRunner`]
//! models `workers` overlay instances draining a shared queue — the
//! same leader/worker shape a PCIe multi-FPGA host process would use,
//! with the simulator in place of the device.
//!
//! The runner validates its [`BismoContext`] once at construction and
//! shares it across jobs (`matmul` is stateless per call), and drains
//! batches on the persistent process-wide [`WorkerPool`] instead of
//! spawning scoped threads per batch.
//!
//! The batch runner is the *closed-loop* shape: the caller assembles a
//! batch, blocks, and gets every outcome back at once. For an open
//! request stream — asynchronous submission, dynamic micro-batching,
//! per-request backend choice and operand-packing reuse — use
//! [`super::BismoService`] (see `DESIGN.md` §Serving-Layer).

use super::context::{BismoContext, MatmulOptions, Precision, RunReport};
use crate::api::BismoError;
use crate::arch::BismoConfig;
use crate::bitmatrix::IntMatrix;
use crate::kernel::WorkerPool;
use std::sync::Mutex;

/// Result of one job in a batch.
pub struct BatchOutcome {
    pub index: usize,
    pub result: Result<(IntMatrix, RunReport), BismoError>,
}

/// Fixed set of simulated overlay workers sharing one validated
/// context and the global worker pool.
pub struct BismoBatchRunner {
    ctx: BismoContext,
    workers: usize,
}

impl BismoBatchRunner {
    pub fn new(cfg: BismoConfig, workers: usize) -> Result<Self, BismoError> {
        // Validate once up front; every job reuses this context instead
        // of rebuilding (and revalidating) one per worker per batch.
        Ok(BismoBatchRunner {
            ctx: BismoContext::new(cfg)?,
            workers: workers.max(1),
        })
    }

    /// The shared, pre-validated overlay context.
    pub fn context(&self) -> &BismoContext {
        &self.ctx
    }

    /// Configured number of overlay instances (the concurrency cap).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run all jobs, preserving input order in the output. Jobs drain
    /// from a shared index queue across up to `workers` pool lanes.
    pub fn run_batch(
        &self,
        jobs: &[(IntMatrix, IntMatrix, Precision, MatmulOptions)],
    ) -> Vec<BatchOutcome> {
        let out: Vec<Mutex<Option<BatchOutcome>>> =
            (0..jobs.len()).map(|_| Mutex::new(None)).collect();
        WorkerPool::global().run_limited(jobs.len(), self.workers, &|i| {
            let (a, b, prec, opts) = &jobs[i];
            let result = self.ctx.matmul(a, b, *prec, *opts);
            *out[i].lock().unwrap() = Some(BatchOutcome { index: i, result });
        });
        out.into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("all jobs completed"))
            .collect()
    }

    /// Aggregate throughput of a batch: total binary ops / total
    /// simulated seconds (jobs run on `workers` parallel overlays),
    /// counted over the *successful* outcomes only. Convenience
    /// wrapper over [`BismoBatchRunner::batch_throughput`], which also
    /// reports how many outcomes were excluded — an all-failures batch
    /// returns `0.0` here, indistinguishable from an empty one, so
    /// callers that care must check the failure count.
    pub fn batch_gops(&self, outcomes: &[BatchOutcome]) -> f64 {
        self.batch_throughput(outcomes).0
    }

    /// Aggregate throughput of a batch plus its failure count:
    /// `(gops, failed)`. Failed outcomes contribute no ops and no
    /// simulated time — they are excluded, not zero-counted — and the
    /// second element makes that exclusion explicit instead of letting
    /// an all-failures batch masquerade as an empty one.
    pub fn batch_throughput(&self, outcomes: &[BatchOutcome]) -> (f64, usize) {
        let mut total_ops = 0.0;
        let mut total_secs = 0.0f64;
        let mut failed = 0usize;
        for o in outcomes {
            match &o.result {
                Ok((_, rep)) => {
                    total_ops += rep.gops * rep.seconds * 1e9;
                    total_secs += rep.seconds;
                }
                Err(_) => failed += 1,
            }
        }
        let gops = if total_secs == 0.0 {
            0.0
        } else {
            total_ops / (total_secs / self.workers as f64) / 1e9
        };
        (gops, failed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn batch_matches_serial_and_orders() {
        let runner = BismoBatchRunner::new(BismoConfig::small(), 4).unwrap();
        let mut rng = Rng::new(77);
        let jobs: Vec<_> = (0..10)
            .map(|_| {
                let k = rng.index(128) + 1;
                let a = IntMatrix::random(&mut rng, 4, k, 2, false);
                let b = IntMatrix::random(&mut rng, k, 4, 2, false);
                (a, b, Precision::unsigned(2, 2), MatmulOptions::default())
            })
            .collect();
        let outcomes = runner.run_batch(&jobs);
        assert_eq!(outcomes.len(), 10);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.index, i);
            let (p, _) = o.result.as_ref().unwrap();
            assert_eq!(*p, jobs[i].0.matmul(&jobs[i].1), "job {i}");
        }
        assert!(runner.batch_gops(&outcomes) > 0.0);
    }

    #[test]
    fn pooled_runner_matches_per_job_serial_results() {
        // The pooled drain must agree job-for-job (results AND reports)
        // with running each job alone on a fresh context.
        let runner = BismoBatchRunner::new(BismoConfig::small(), 3).unwrap();
        let serial_ctx = BismoContext::new(BismoConfig::small()).unwrap();
        let mut rng = Rng::new(0x0B7);
        let jobs: Vec<_> = (0..8)
            .map(|j| {
                let k = rng.index(200) + 1;
                let a = IntMatrix::random(&mut rng, 3 + j % 3, k, 3, true);
                let b = IntMatrix::random(&mut rng, k, 2 + j % 4, 2, false);
                let prec = Precision {
                    wbits: 3,
                    abits: 2,
                    lsigned: true,
                    rsigned: false,
                };
                (a, b, prec, MatmulOptions::default())
            })
            .collect();
        let outcomes = runner.run_batch(&jobs);
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.index, i, "ordering preserved");
            let (p, rep) = o.result.as_ref().unwrap();
            let (sp, srep) = serial_ctx
                .matmul(&jobs[i].0, &jobs[i].1, jobs[i].2, jobs[i].3)
                .unwrap();
            assert_eq!(*p, sp, "job {i} result");
            assert_eq!(rep.cycles, srep.cycles, "job {i} cycles deterministic");
        }
    }

    #[test]
    fn runner_is_reusable_across_batches() {
        let runner = BismoBatchRunner::new(BismoConfig::small(), 2).unwrap();
        let mut rng = Rng::new(0x2E5E);
        for _ in 0..3 {
            let jobs: Vec<_> = (0..4)
                .map(|_| {
                    let a = IntMatrix::random(&mut rng, 2, 64, 1, false);
                    let b = IntMatrix::random(&mut rng, 64, 2, 1, false);
                    (a, b, Precision::unsigned(1, 1), MatmulOptions::default())
                })
                .collect();
            let outcomes = runner.run_batch(&jobs);
            assert_eq!(outcomes.len(), 4);
            for (i, o) in outcomes.iter().enumerate() {
                assert_eq!(o.index, i);
                assert!(o.result.is_ok());
            }
        }
    }

    #[test]
    fn single_worker_works() {
        let runner = BismoBatchRunner::new(BismoConfig::small(), 1).unwrap();
        let mut rng = Rng::new(78);
        let a = IntMatrix::random(&mut rng, 2, 64, 1, false);
        let b = IntMatrix::random(&mut rng, 64, 2, 1, false);
        let jobs = vec![(a, b, Precision::unsigned(1, 1), MatmulOptions::default())];
        let outcomes = runner.run_batch(&jobs);
        assert!(outcomes[0].result.is_ok());
    }

    #[test]
    fn empty_batch_ok() {
        let runner = BismoBatchRunner::new(BismoConfig::small(), 2).unwrap();
        let outcomes = runner.run_batch(&[]);
        assert!(outcomes.is_empty());
        assert_eq!(runner.batch_gops(&outcomes), 0.0);
        assert_eq!(runner.batch_throughput(&outcomes), (0.0, 0));
    }

    #[test]
    fn failed_outcomes_are_counted_not_silently_skipped() {
        let runner = BismoBatchRunner::new(BismoConfig::small(), 2).unwrap();
        let mut rng = Rng::new(0xFA11);
        // A mixed batch: healthy jobs plus one with mismatched shapes.
        let jobs: Vec<_> = (0..3)
            .map(|_| {
                let a = IntMatrix::random(&mut rng, 2, 64, 1, false);
                let b = IntMatrix::random(&mut rng, 64, 2, 1, false);
                (a, b, Precision::unsigned(1, 1), MatmulOptions::default())
            })
            .chain(std::iter::once((
                IntMatrix::zeros(2, 64),
                IntMatrix::zeros(63, 2),
                Precision::unsigned(1, 1),
                MatmulOptions::default(),
            )))
            .collect();
        let outcomes = runner.run_batch(&jobs);
        let (gops, failed) = runner.batch_throughput(&outcomes);
        assert!(gops > 0.0, "healthy jobs still report throughput");
        assert_eq!(failed, 1, "the shape-mismatch job is counted");
        assert_eq!(runner.batch_gops(&outcomes), gops, "wrapper agrees");
        // All-failures: 0.0 gops like an empty batch, but the failure
        // count disambiguates the two.
        let bad: Vec<_> = outcomes
            .into_iter()
            .filter(|o| o.result.is_err())
            .collect();
        assert_eq!(runner.batch_throughput(&bad), (0.0, 1));
    }
}
