//! One overlay instance: pack → schedule → simulate → report.

use crate::api::BismoError;
use crate::arch::{BismoConfig, Platform, PYNQ_Z1};
use crate::baseline::gemm_bitserial;
use crate::bitmatrix::dram::{DramImage, OperandLayout, ResultLayout};
use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
use crate::costmodel::CostModel;
use crate::power::PowerModel;
use crate::scheduler::{self, MatmulJob, Overlap, PlaneList};
use crate::sim::{RunStats, Simulation};
use crate::util::round_up;

/// Operand precision for a matmul job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Precision {
    pub wbits: u32,
    pub abits: u32,
    pub lsigned: bool,
    pub rsigned: bool,
}

impl Precision {
    /// Widest supported operand precision per side.
    pub const MAX_BITS: u32 = 32;

    pub fn unsigned(wbits: u32, abits: u32) -> Self {
        Precision {
            wbits,
            abits,
            lsigned: false,
            rsigned: false,
        }
    }

    pub fn signed(wbits: u32, abits: u32) -> Self {
        Precision {
            wbits,
            abits,
            lsigned: true,
            rsigned: true,
        }
    }

    /// Validated construction: rejects zero widths, widths above
    /// [`Precision::MAX_BITS`], and combined widths whose plane-pair
    /// weight `2^{i+j}` would overflow the accumulator's weight range
    /// — the garbage-in cases that used to surface as wrong products
    /// deep inside the scheduler.
    pub fn try_new(
        wbits: u32,
        abits: u32,
        lsigned: bool,
        rsigned: bool,
    ) -> Result<Self, BismoError> {
        let p = Precision {
            wbits,
            abits,
            lsigned,
            rsigned,
        };
        p.validate()?;
        Ok(p)
    }

    /// The precision gate every facade/service/scheduler entry point
    /// shares. See [`Precision::try_new`].
    pub fn validate(&self) -> Result<(), BismoError> {
        for (side, bits) in [("wbits", self.wbits), ("abits", self.abits)] {
            if bits == 0 || bits > Self::MAX_BITS {
                return Err(BismoError::PrecisionUnsupported(format!(
                    "{side} must be in 1..={}, got {bits}",
                    Self::MAX_BITS
                )));
            }
        }
        if self.wbits + self.abits > 62 {
            return Err(BismoError::PrecisionUnsupported(format!(
                "wbits + abits = {} exceeds the accumulator's 2^62 weight range",
                self.wbits + self.abits
            )));
        }
        Ok(())
    }
}

/// Per-job options.
#[derive(Clone, Copy, Debug)]
pub struct MatmulOptions {
    /// Stage overlap mode (default: full overlap).
    pub overlap: Overlap,
    /// Skip all-zero bit-planes (the paper's sparse extension).
    pub bit_skip: bool,
    /// Cross-check the simulator result against the CPU bit-serial
    /// oracle (costs an extra software gemm).
    pub verify: bool,
    /// Abort the simulation with a typed
    /// [`crate::sim::SimError::BudgetExceeded`] after this many retired
    /// instructions (`None` = unbounded). A watchdog for serving paths:
    /// a mis-scheduled or hostile job fails fast instead of occupying a
    /// worker for an unbounded run.
    pub max_instrs: Option<u64>,
}

impl Default for MatmulOptions {
    fn default() -> Self {
        MatmulOptions {
            overlap: Overlap::Full,
            bit_skip: false,
            verify: false,
            max_instrs: None,
        }
    }
}

/// Everything measured about one executed job.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Simulated clock cycles.
    pub cycles: u64,
    /// Wall-clock seconds at the configured clock.
    pub seconds: f64,
    /// Achieved binary GOPS.
    pub gops: f64,
    /// Fraction of the configuration's peak binary throughput.
    pub efficiency: f64,
    /// Full simulator statistics.
    pub stats: RunStats,
    /// Instruction counts (fetch/execute/result runs, syncs).
    pub instructions: crate::isa::ProgramStats,
    /// Estimated board power during the run (W).
    pub power_w: f64,
    /// Achieved GOPS per watt.
    pub gops_per_w: f64,
    /// Bit-planes actually scheduled (post bit-skip) on each side.
    pub lhs_planes: u32,
    pub rhs_planes: u32,
}

impl RunReport {
    /// Aggregate the per-shard reports of one sharded job as `N`
    /// instances running in parallel: makespan (cycles, seconds) is the
    /// slowest instance, work (ops, bytes, busy/stall time, commits,
    /// instructions, power) sums, and throughput/efficiency are
    /// recomputed from the aggregates — achieved GOPS over the summed
    /// work at the parallel makespan, efficiency against the combined
    /// peak of all instances. Returns `None` for an empty slice.
    pub fn merge_parallel(reports: &[RunReport]) -> Option<RunReport> {
        let first = reports.first()?;
        if reports.len() == 1 {
            return Some(first.clone());
        }
        let mut stats = RunStats::default();
        let mut instructions = crate::isa::ProgramStats::default();
        let mut power_w = 0.0;
        let mut seconds = 0.0f64;
        let mut peak_gops = 0.0;
        let mut lhs_planes = 0;
        let mut rhs_planes = 0;
        for r in reports {
            stats.cycles = stats.cycles.max(r.stats.cycles);
            stats.fetch_busy += r.stats.fetch_busy;
            stats.execute_busy += r.stats.execute_busy;
            stats.result_busy += r.stats.result_busy;
            stats.fetch_stall += r.stats.fetch_stall;
            stats.execute_stall += r.stats.execute_stall;
            stats.result_stall += r.stats.result_stall;
            stats.bytes_fetched += r.stats.bytes_fetched;
            stats.bytes_written += r.stats.bytes_written;
            stats.binary_ops += r.stats.binary_ops;
            stats.pipeline_fill_cycles += r.stats.pipeline_fill_cycles;
            stats.commits += r.stats.commits;
            stats.acc_overflows += r.stats.acc_overflows;
            instructions.fetch_runs += r.instructions.fetch_runs;
            instructions.execute_runs += r.instructions.execute_runs;
            instructions.result_runs += r.instructions.result_runs;
            instructions.waits += r.instructions.waits;
            instructions.signals += r.instructions.signals;
            instructions.total += r.instructions.total;
            power_w += r.power_w;
            seconds = seconds.max(r.seconds);
            if r.efficiency > 0.0 {
                peak_gops += r.gops / r.efficiency;
            }
            lhs_planes = lhs_planes.max(r.lhs_planes);
            rhs_planes = rhs_planes.max(r.rhs_planes);
        }
        let gops = if seconds > 0.0 {
            stats.binary_ops as f64 / seconds / 1e9
        } else {
            0.0
        };
        Some(RunReport {
            cycles: stats.cycles,
            seconds,
            gops,
            efficiency: if peak_gops > 0.0 { gops / peak_gops } else { 0.0 },
            stats,
            instructions,
            power_w,
            gops_per_w: if power_w > 0.0 { gops / power_w } else { 0.0 },
            lhs_planes,
            rhs_planes,
        })
    }
}

/// Shared guard for every consumer of pre-packed operand pairs (the
/// context's packed path and the serving backends): both packings must
/// run along the same `k`.
pub(crate) fn check_packed_pair(
    la: &BitSerialMatrix,
    rb: &BitSerialMatrix,
) -> Result<(), BismoError> {
    if la.cols != rb.cols {
        return Err(BismoError::ShapeMismatch(format!(
            "packed lhs {}×{} vs rhs(T) {}×{}",
            la.rows, la.cols, rb.rows, rb.cols
        )));
    }
    Ok(())
}

/// One configured overlay + its evaluation models.
pub struct BismoContext {
    cfg: BismoConfig,
    platform: Platform,
    cost: CostModel,
    power: PowerModel,
}

impl BismoContext {
    /// Build a context, checking the configuration is valid and fits
    /// the platform's resource budget under the cost model.
    pub fn new(cfg: BismoConfig) -> Result<Self, BismoError> {
        Self::on_platform(cfg, PYNQ_Z1)
    }

    pub fn on_platform(cfg: BismoConfig, platform: Platform) -> Result<Self, BismoError> {
        cfg.validate()?;
        let cost = CostModel::paper();
        if !cost.fits(&cfg, &platform) {
            return Err(BismoError::CapacityExceeded(format!(
                "configuration needs {:.0} LUTs / {} BRAMs; {} has {} / {}",
                cost.lut_total(&cfg),
                cost.bram_total(&cfg),
                platform.name,
                platform.luts,
                platform.brams
            )));
        }
        Ok(BismoContext {
            cfg,
            platform,
            cost,
            power: PowerModel::calibrated(),
        })
    }

    pub fn config(&self) -> &BismoConfig {
        &self.cfg
    }

    pub fn platform(&self) -> &Platform {
        &self.platform
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// `P = A · B` on the overlay. `A` is `m×k` at `wbits`, `B` is
    /// `k×n` at `abits`.
    ///
    /// Packs both operands, compiles the instruction streams, runs the
    /// functional + cycle-level simulator, and returns the product with
    /// a full [`RunReport`]. Pre-packed operands (e.g. from the serving
    /// layer's cache) can skip the packing step via
    /// [`BismoContext::matmul_packed`].
    ///
    /// Application code should usually go through the
    /// [`crate::api::Session`] facade instead, which adds backend
    /// selection, micro-batching and the weight-stationary packing
    /// cache on top of this context.
    ///
    /// ```
    /// use bismo::arch::BismoConfig;
    /// use bismo::bitmatrix::IntMatrix;
    /// use bismo::coordinator::{BismoContext, MatmulOptions, Precision};
    ///
    /// let ctx = BismoContext::new(BismoConfig::small())?;
    /// // The paper's Fig. 1 example: L·R with 2-bit unsigned operands.
    /// let l = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
    /// let r = IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]);
    /// let (p, report) =
    ///     ctx.matmul(&l, &r, Precision::unsigned(2, 2), MatmulOptions::default())?;
    /// assert_eq!(p, IntMatrix::from_slice(2, 2, &[0, 2, 3, 7]));
    /// assert!(report.cycles > 0);
    /// # Ok::<(), bismo::api::BismoError>(())
    /// ```
    pub fn matmul(
        &self,
        a: &IntMatrix,
        b: &IntMatrix,
        prec: Precision,
        opts: MatmulOptions,
    ) -> Result<(IntMatrix, RunReport), BismoError> {
        prec.validate()?;
        if a.cols != b.rows {
            return Err(BismoError::ShapeMismatch(format!(
                "{}×{} · {}×{}",
                a.rows, a.cols, b.rows, b.cols
            )));
        }
        let la = BitSerialMatrix::from_int(a, prec.wbits, prec.lsigned);
        // Transpose fused into packing (§Perf: saves an 8B/element pass).
        let rb = BitSerialMatrix::from_int_transposed(b, prec.abits, prec.rsigned);
        self.matmul_packed(&la, &rb, opts)
    }

    /// [`BismoContext::matmul`] over pre-packed operands: `la` is the
    /// bit-plane-decomposed LHS (`m×k`), `rb` the decomposed *transposed*
    /// RHS (`n×k`, as produced by
    /// [`BitSerialMatrix::from_int_transposed`]). Precision and
    /// signedness are carried by the packed operands themselves.
    ///
    /// This is the entry point the serving layer uses: its
    /// weight-stationary packing cache hands the same packed operand to
    /// many requests without repeating the decomposition pass.
    pub fn matmul_packed(
        &self,
        la: &BitSerialMatrix,
        rb: &BitSerialMatrix,
        opts: MatmulOptions,
    ) -> Result<(IntMatrix, RunReport), BismoError> {
        check_packed_pair(la, rb)?;
        let (m, k, n) = (la.rows, la.cols, rb.rows);
        let prec = Precision {
            wbits: la.bits,
            abits: rb.bits,
            lsigned: la.signed,
            rsigned: rb.signed,
        };

        // DRAM placement: lhs | rhs | result, 8-byte aligned.
        let lhs = OperandLayout::new(0, m, k, prec.wbits, self.cfg.dk);
        let rhs = OperandLayout::new(
            round_up(lhs.base + lhs.total_bytes(), 8),
            n,
            k,
            prec.abits,
            self.cfg.dk,
        );
        let res = ResultLayout::new(round_up(rhs.base + rhs.total_bytes(), 8), m, n);
        let mut dram = DramImage::new((res.base + res.total_bytes()) as usize);
        lhs.store(&mut dram, la);
        rhs.store(&mut dram, rb);

        let job = MatmulJob {
            m,
            k,
            n,
            wbits: prec.wbits,
            abits: prec.abits,
            lsigned: prec.lsigned,
            rsigned: prec.rsigned,
            lhs,
            rhs,
            res,
        };

        // Plane lists (bit-skip drops all-zero planes).
        let lhs_planes = if opts.bit_skip {
            PlaneList::nonzero(la)
        } else {
            PlaneList::full(prec.wbits, prec.lsigned)
        };
        let rhs_planes = if opts.bit_skip {
            PlaneList::nonzero(rb)
        } else {
            PlaneList::full(prec.abits, prec.rsigned)
        };
        if lhs_planes.is_empty() || rhs_planes.is_empty() {
            // An all-zero operand: result is all zeros, zero cycles.
            let report = RunReport {
                cycles: 0,
                seconds: 0.0,
                gops: 0.0,
                efficiency: 0.0,
                stats: RunStats::default(),
                instructions: Default::default(),
                power_w: self.power.idle_w(&self.cfg),
                gops_per_w: 0.0,
                lhs_planes: 0,
                rhs_planes: 0,
            };
            return Ok((IntMatrix::zeros(m, n), report));
        }

        let prog = scheduler::compile_with_planes(
            &job,
            &self.cfg,
            opts.overlap,
            &lhs_planes,
            &rhs_planes,
        )?;
        let instructions = prog.stats();

        let mut sim = Simulation::new(self.cfg, &self.platform, dram)?;
        let stats = match opts.max_instrs {
            None => sim.run(&prog)?,
            Some(budget) => {
                sim.begin(&prog)?;
                match sim.step(&prog, budget)? {
                    crate::sim::StepOutcome::Completed(stats) => stats,
                    crate::sim::StepOutcome::Suspended => {
                        return Err(crate::sim::SimError::BudgetExceeded { budget }.into());
                    }
                }
            }
        };
        let result = res.load(&sim.dram);

        if opts.verify {
            let expect = gemm_bitserial(la, rb);
            if result != expect {
                return Err(BismoError::VerifyFailed(
                    "simulator result != CPU oracle".into(),
                ));
            }
        }

        let seconds = stats.seconds_at(self.cfg.fclk_mhz);
        let gops = stats.gops_at(self.cfg.fclk_mhz);
        let power_w = self.power.full_w(&self.cfg);
        let report = RunReport {
            cycles: stats.cycles,
            seconds,
            gops,
            efficiency: stats.efficiency(self.cfg.binary_ops_per_cycle()),
            stats,
            instructions,
            power_w,
            gops_per_w: gops / power_w,
            lhs_planes: lhs_planes.len() as u32,
            rhs_planes: rhs_planes.len() as u32,
        };
        Ok((result, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{property_sweep, Rng};

    fn ctx() -> BismoContext {
        BismoContext::new(BismoConfig::small()).unwrap()
    }

    #[test]
    fn matmul_matches_reference() {
        let c = ctx();
        let mut rng = Rng::new(0xC0DE);
        let a = IntMatrix::random(&mut rng, 6, 200, 3, true);
        let b = IntMatrix::random(&mut rng, 200, 6, 3, true);
        let (p, rep) = c
            .matmul(&a, &b, Precision::signed(3, 3), MatmulOptions::default())
            .unwrap();
        assert_eq!(p, a.matmul(&b));
        assert!(rep.cycles > 0);
        assert!(rep.gops > 0.0);
        assert!(rep.efficiency > 0.0 && rep.efficiency <= 1.0);
        assert!(rep.power_w > 1.0);
        assert_eq!(rep.lhs_planes, 3);
    }

    #[test]
    fn matmul_packed_matches_matmul() {
        // Pre-packing must be observationally identical to the packing
        // matmul does internally — results AND timing.
        let c = ctx();
        let mut rng = Rng::new(0x9ACD);
        let a = IntMatrix::random(&mut rng, 5, 150, 3, true);
        let b = IntMatrix::random(&mut rng, 150, 7, 2, false);
        let prec = Precision {
            wbits: 3,
            abits: 2,
            lsigned: true,
            rsigned: false,
        };
        let la = BitSerialMatrix::from_int(&a, prec.wbits, prec.lsigned);
        let rb = BitSerialMatrix::from_int_transposed(&b, prec.abits, prec.rsigned);
        let (p1, r1) = c.matmul(&a, &b, prec, MatmulOptions::default()).unwrap();
        let (p2, r2) = c.matmul_packed(&la, &rb, MatmulOptions::default()).unwrap();
        assert_eq!(p1, p2);
        assert_eq!(r1.cycles, r2.cycles);
        // k mismatch between packed operands is caught.
        let short = BitSerialMatrix::from_int_transposed(
            &IntMatrix::zeros(64, 7),
            prec.abits,
            prec.rsigned,
        );
        assert!(c.matmul_packed(&la, &short, MatmulOptions::default()).is_err());
    }

    #[test]
    fn verify_option_passes() {
        let c = ctx();
        let mut rng = Rng::new(2);
        let a = IntMatrix::random(&mut rng, 4, 64, 2, false);
        let b = IntMatrix::random(&mut rng, 64, 4, 2, false);
        let opts = MatmulOptions {
            verify: true,
            ..Default::default()
        };
        c.matmul(&a, &b, Precision::unsigned(2, 2), opts).unwrap();
    }

    #[test]
    fn precision_scales_runtime() {
        // The paper's headline: runtime ≈ w·a·t of the binary case.
        let c = ctx();
        let mut rng = Rng::new(3);
        let a1 = IntMatrix::random(&mut rng, 8, 2048, 1, false);
        let b1 = IntMatrix::random(&mut rng, 2048, 8, 1, false);
        let (_, r1) = c
            .matmul(&a1, &b1, Precision::unsigned(1, 1), MatmulOptions::default())
            .unwrap();
        let a4 = IntMatrix::random(&mut rng, 8, 2048, 2, false);
        let b4 = IntMatrix::random(&mut rng, 2048, 8, 2, false);
        let (_, r4) = c
            .matmul(&a4, &b4, Precision::unsigned(2, 2), MatmulOptions::default())
            .unwrap();
        let ratio = r4.cycles as f64 / r1.cycles as f64;
        assert!(
            ratio > 1.5 && ratio <= 4.2,
            "2x2-bit vs binary cycle ratio {ratio:.2} (expect ≲ 4)"
        );
    }

    #[test]
    fn bit_skip_saves_cycles_and_stays_exact() {
        let c = ctx();
        // Even-valued operand: LSB plane empty.
        let a = IntMatrix::from_fn(4, 128, |r, q| (((r + q) % 4) as i64) * 2);
        let mut rng = Rng::new(4);
        let b = IntMatrix::random(&mut rng, 128, 4, 2, false);
        let dense = c
            .matmul(&a, &b, Precision::unsigned(3, 2), MatmulOptions::default())
            .unwrap();
        let skip = c
            .matmul(
                &a,
                &b,
                Precision::unsigned(3, 2),
                MatmulOptions {
                    bit_skip: true,
                    ..Default::default()
                },
            )
            .unwrap();
        assert_eq!(dense.0, skip.0);
        assert!(skip.1.cycles < dense.1.cycles);
        assert_eq!(skip.1.lhs_planes, 2);
    }

    #[test]
    fn zero_operand_short_circuits() {
        let c = ctx();
        let a = IntMatrix::zeros(4, 64);
        let mut rng = Rng::new(5);
        let b = IntMatrix::random(&mut rng, 64, 4, 2, false);
        let opts = MatmulOptions {
            bit_skip: true,
            ..Default::default()
        };
        let (p, rep) = c.matmul(&a, &b, Precision::unsigned(2, 2), opts).unwrap();
        assert_eq!(p, IntMatrix::zeros(4, 4));
        assert_eq!(rep.cycles, 0);
    }

    #[test]
    fn precision_validated_at_construction() {
        // Zero widths, overwide sides and accumulator-overflowing
        // combinations are all PrecisionUnsupported — not garbage output.
        for (w, a) in [(0u32, 2u32), (2, 0), (33, 2), (2, 33), (32, 32)] {
            match Precision::try_new(w, a, false, false) {
                Err(BismoError::PrecisionUnsupported(_)) => {}
                other => panic!("w{w}a{a}: expected PrecisionUnsupported, got {other:?}"),
            }
        }
        assert!(Precision::try_new(1, 1, false, false).is_ok());
        assert!(Precision::try_new(32, 30, true, true).is_ok());
        // The context applies the same gate before packing.
        let c = ctx();
        let a = IntMatrix::zeros(2, 64);
        let b = IntMatrix::zeros(64, 2);
        let bad = Precision {
            wbits: 0,
            abits: 2,
            lsigned: false,
            rsigned: false,
        };
        match c.matmul(&a, &b, bad, MatmulOptions::default()) {
            Err(BismoError::PrecisionUnsupported(_)) => {}
            other => panic!("expected PrecisionUnsupported, got {other:?}"),
        }
    }

    #[test]
    fn oversized_config_rejected() {
        let cfg = BismoConfig {
            dm: 32,
            dk: 1024,
            dn: 32,
            ..BismoConfig::small()
        };
        assert!(BismoContext::new(cfg).is_err());
    }

    #[test]
    fn random_jobs_property() {
        let c = ctx();
        property_sweep(0xAB5, 8, |rng, _| {
            let m = rng.index(12) + 1;
            let k = rng.index(256) + 1;
            let n = rng.index(12) + 1;
            let w = rng.index(4) as u32 + 1;
            let ab = rng.index(4) as u32 + 1;
            let a = IntMatrix::random(rng, m, k, w, true);
            let b = IntMatrix::random(rng, k, n, ab, false);
            let prec = Precision {
                wbits: w,
                abits: ab,
                lsigned: true,
                rsigned: false,
            };
            let opts = MatmulOptions {
                bit_skip: rng.chance(0.5),
                ..Default::default()
            };
            let (p, _) = c.matmul(&a, &b, prec, opts).unwrap();
            assert_eq!(p, a.matmul(&b));
        });
    }
}
