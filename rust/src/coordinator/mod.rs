//! The coordinator: BISMO's public matrix-multiplication API.
//!
//! [`BismoContext`] owns one overlay configuration and provides
//! [`BismoContext::matmul`]: pack the operands into the bit-serial DRAM
//! layout, compile the instruction streams, run the functional+timing
//! simulator, and return the result with a full [`RunReport`]
//! (cycles, GOPS, efficiency, stage breakdown, power estimate).
//!
//! [`BismoBatchRunner`] adds the request-loop shape: a pool of worker
//! threads, each with its own simulated overlay instance, draining a
//! shared job queue — the software topology a multi-accelerator
//! deployment of BISMO would use.

mod context;
mod server;

pub use context::{BismoContext, MatmulOptions, Precision, RunReport};
pub use server::{BatchOutcome, BismoBatchRunner};
