//! The coordinator: the matrix-multiplication machinery beneath the
//! [`crate::api::Session`] facade. Application code should usually
//! enter through [`crate::api`]; the types here remain public as the
//! documented low-level layer (and the facade's vocabulary).
//!
//! [`BismoContext`] owns one overlay configuration and provides
//! [`BismoContext::matmul`]: pack the operands into the bit-serial DRAM
//! layout, compile the instruction streams, run the functional+timing
//! simulator, and return the result with a full [`RunReport`]
//! (cycles, GOPS, efficiency, stage breakdown, power estimate).
//! [`BismoContext::matmul_packed`] is the same contract over
//! pre-packed operands.
//!
//! [`BismoBatchRunner`] adds the request-loop shape: a pool of worker
//! threads, each with its own simulated overlay instance, draining a
//! shared job queue — the software topology a multi-accelerator
//! deployment of BISMO would use.
//!
//! [`BismoService`] is the serving layer on top (see `DESIGN.md`
//! §Serving-Layer): an asynchronous submission queue with dynamic
//! micro-batching, per-request backend selection through the
//! [`ExecBackend`] trait (fast tiled engine vs cycle-accurate
//! simulator), a weight-stationary [`PackingCache`] that skips
//! repacking operands reused across requests, and multi-instance
//! sharded execution ([`Sharding`], `DESIGN.md` §Partitioning): one
//! request split across concurrent overlay instances by a
//! [`crate::partition::ShardPlan`] and merged bit-exactly.

mod cache;
mod context;
mod server;
mod service;

pub use cache::{check_fits, pack_operand, CacheStats, PackKey, PackingCache};
pub use context::{BismoContext, MatmulOptions, Precision, RunReport};
pub use server::{BatchOutcome, BismoBatchRunner};
pub use service::{
    Backend, BismoService, EngineBackend, ExecBackend, GemmRequest, GemmResponse, RequestHandle,
    RequestOptions, ServiceConfig, Sharding, SimBackend,
};
