//! The asynchronous serving layer: [`BismoService`].
//!
//! [`BismoBatchRunner`](super::BismoBatchRunner) drains one
//! pre-assembled batch synchronously; a production deployment instead
//! sees an *open stream* of independent GEMM requests (the layers of
//! many concurrent QNN inferences). `BismoService` is that request
//! loop:
//!
//! * **Submission queue** — [`BismoService::submit`] enqueues a
//!   [`GemmRequest`] and returns a [`RequestHandle`] immediately; a
//!   dispatcher thread forms *dynamic micro-batches* (whatever is
//!   queued, up to [`ServiceConfig::max_batch`]) and drains each batch
//!   concurrently on the shared [`WorkerPool`], capped at
//!   [`ServiceConfig::workers`] lanes. Unlike the batch runner, the
//!   caller never assembles a batch — but each micro-batch *does* drain
//!   as a unit before the next is formed, so one slow request can hold
//!   up to `max_batch − 1` peers plus the queue behind it.
//!   [`ServiceConfig::max_batch`] bounds that head-of-line window:
//!   keep it small (≈`workers`) for mixed sim/engine traffic, larger
//!   for uniform throughput-oriented streams.
//! * **Per-request backend selection** — the [`ExecBackend`] trait
//!   abstracts "execute one GEMM over packed operands".
//!   [`EngineBackend`] runs the fast tiled software engine
//!   ([`crate::kernel::gemm_tiled`]); [`SimBackend`] runs the
//!   cycle-accurate overlay simulator via
//!   [`BismoContext::matmul_packed`] and returns a full [`RunReport`].
//!   Requests pick per call via [`RequestOptions::backend`].
//! * **Multi-instance sharded execution** — a request may ask to be
//!   split across several overlay instances ([`RequestOptions::sharding`]):
//!   a [`ShardPlan`] decomposes the output into row/column blocks, each
//!   shard executes concurrently (engine shards as worker-pool lanes
//!   over zero-copy block views of the packed operands, sim shards as
//!   independent simulator instances), and the partial products merge
//!   bit-exactly before the response completes. [`Sharding::Auto`]
//!   sizes the split with the paper's cost model
//!   ([`crate::costmodel::select_sharding`], Eqs 1–2) under a LUT/BRAM
//!   budget.
//! * **Weight-stationary packing cache** — packed operands are cached
//!   by content hash ([`PackingCache`]), so requests that reuse an
//!   operand (QNN layer weights, the weight-stationary case) skip the
//!   bit-plane decomposition entirely. By default only the RHS (the
//!   weight side) is cached; one-shot LHS activations would churn the
//!   cache, but [`RequestOptions::cache_lhs`] opts them in when they
//!   recur. Packing happens outside the cache lock; only lookup/insert
//!   are serialized.
//!
//! Results are bit-exact regardless of backend, caching or concurrency
//! — property-tested against the CPU oracle in
//! `rust/tests/service_concurrent.rs`.

use super::cache::{check_fits, pack_operand, CacheStats, PackKey, PackingCache};
use super::context::{check_packed_pair, BismoContext, MatmulOptions, Precision, RunReport};
use crate::api::BismoError;
use crate::arch::{BismoConfig, Platform};
use crate::baseline::gemm_bitserial;
use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
use crate::costmodel::tune::{load_host_profile, TunedProfile};
use crate::costmodel::{select_sharding, CostModel, ResourceBudget};
use crate::kernel::{gemm_tiled_block, gemm_tiled_with, KernelConfig, WorkerPool};
use crate::partition::{GemmShape, Shard, ShardPlan};
use crate::scheduler::Overlap;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Which execution backend serves a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The fast tiled software engine (`kernel::engine`): lowest
    /// latency, no hardware timing model ([`GemmResponse::report`] is
    /// `None`).
    Engine,
    /// The cycle-accurate overlay simulator: every request additionally
    /// yields a [`RunReport`] (cycles, GOPS, efficiency, power).
    Sim,
}

impl Backend {
    /// Stable lowercase name (CLI flag value / JSON field).
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Engine => "engine",
            Backend::Sim => "sim",
        }
    }
}

/// How one request splits across overlay instances.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sharding {
    /// One virtual instance executes the whole job (the default).
    Single,
    /// A fixed `rows × cols` shard grid over the output (each axis
    /// clamped so no shard is empty).
    Grid { rows: usize, cols: usize },
    /// Up to `n` instances; the grid is factored per request shape
    /// ([`ShardPlan::for_instances`]).
    Instances(usize),
    /// Cost-model-driven: [`select_sharding`] picks the shard count
    /// *and* the per-shard instance configuration under this LUT/BRAM
    /// budget (paper Eqs 1–2).
    Auto(ResourceBudget),
}

impl Sharding {
    /// Reject degenerate parameters (zero grid axes, zero instances).
    /// Shared by [`BismoService::submit`]'s request validation and
    /// [`crate::api::MatmulBuilder::build`], so the facade and the
    /// direct-service path cannot drift apart.
    pub fn validate(&self) -> Result<(), BismoError> {
        match *self {
            Sharding::Grid { rows, cols } if rows == 0 || cols == 0 => Err(
                BismoError::InvalidConfig("shard grid dimensions must be >= 1".into()),
            ),
            Sharding::Instances(0) => Err(BismoError::InvalidConfig(
                "instance count must be >= 1".into(),
            )),
            _ => Ok(()),
        }
    }
}

/// One GEMM over pre-packed bit-serial operands. `la` is the decomposed
/// LHS (`m×k`), `rb` the decomposed *transposed* RHS (`n×k`); both come
/// from the packing cache or a fresh pack. Implementations must be
/// bit-exact against [`crate::baseline::gemm_bitserial`].
pub trait ExecBackend: Send + Sync {
    /// Stable backend name for reports.
    fn name(&self) -> &'static str;

    /// Execute, returning the `m×n` product and — if the backend models
    /// hardware time — a [`RunReport`].
    fn execute(
        &self,
        la: &BitSerialMatrix,
        rb: &BitSerialMatrix,
        opts: &MatmulOptions,
    ) -> Result<(IntMatrix, Option<RunReport>), BismoError>;

    /// Execute one [`Shard`] of the job: the output block
    /// `shard.rows × shard.cols` (optionally restricted to a group of
    /// LHS bit-planes). Must equal the corresponding block of
    /// [`ExecBackend::execute`]'s result — [`ShardPlan::assemble`]
    /// relies on that to merge bit-exactly.
    fn execute_block(
        &self,
        la: &BitSerialMatrix,
        rb: &BitSerialMatrix,
        shard: &Shard,
        opts: &MatmulOptions,
    ) -> Result<(IntMatrix, Option<RunReport>), BismoError>;
}

/// [`ExecBackend`] over the tiled plane-fused kernel engine.
#[derive(Default)]
pub struct EngineBackend {
    /// Tile geometry handed to the engine.
    pub kernel: KernelConfig,
}

impl ExecBackend for EngineBackend {
    fn name(&self) -> &'static str {
        "engine"
    }

    fn execute(
        &self,
        la: &BitSerialMatrix,
        rb: &BitSerialMatrix,
        _opts: &MatmulOptions,
    ) -> Result<(IntMatrix, Option<RunReport>), BismoError> {
        check_packed_pair(la, rb)?;
        // Single-lane inside the request: the micro-batch already runs
        // `workers` requests concurrently on the pool, so per-request
        // parallelism would only oversubscribe it.
        Ok((gemm_tiled_with(la, rb, &self.kernel, None)?, None))
    }

    fn execute_block(
        &self,
        la: &BitSerialMatrix,
        rb: &BitSerialMatrix,
        shard: &Shard,
        _opts: &MatmulOptions,
    ) -> Result<(IntMatrix, Option<RunReport>), BismoError> {
        check_packed_pair(la, rb)?;
        // The block kernel packs its shard straight out of the cached
        // operands' plane-row views — no per-shard repack of the source
        // matrices (and plane-group shards are supported natively).
        Ok((
            gemm_tiled_block(
                la,
                rb,
                shard.rows.clone(),
                shard.cols.clone(),
                shard.planes.clone(),
                &self.kernel,
                None,
            )?,
            None,
        ))
    }
}

/// [`ExecBackend`] over the cycle-accurate simulator (one validated
/// [`BismoContext`] shared by every request).
pub struct SimBackend {
    ctx: BismoContext,
}

impl SimBackend {
    pub fn new(cfg: BismoConfig) -> Result<SimBackend, BismoError> {
        Ok(SimBackend {
            ctx: BismoContext::new(cfg)?,
        })
    }

    /// A backend whose instances are sized against an explicit
    /// platform (the auto-sharding path validates the cost-model's
    /// instance choice against the *budget*, not the default board).
    pub fn on_platform(cfg: BismoConfig, platform: Platform) -> Result<SimBackend, BismoError> {
        Ok(SimBackend {
            ctx: BismoContext::on_platform(cfg, platform)?,
        })
    }

    /// The shared overlay context.
    pub fn context(&self) -> &BismoContext {
        &self.ctx
    }
}

impl ExecBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn execute(
        &self,
        la: &BitSerialMatrix,
        rb: &BitSerialMatrix,
        opts: &MatmulOptions,
    ) -> Result<(IntMatrix, Option<RunReport>), BismoError> {
        self.ctx
            .matmul_packed(la, rb, *opts)
            .map(|(p, rep)| (p, Some(rep)))
    }

    fn execute_block(
        &self,
        la: &BitSerialMatrix,
        rb: &BitSerialMatrix,
        shard: &Shard,
        opts: &MatmulOptions,
    ) -> Result<(IntMatrix, Option<RunReport>), BismoError> {
        if shard.planes.as_ref().is_some_and(|p| *p != (0..la.bits)) {
            return Err(BismoError::InvalidConfig(
                "bit-plane-group shards are supported by the engine backend only".into(),
            ));
        }
        check_packed_pair(la, rb)?;
        // Each shard is an independent smaller GEMM on its own
        // simulator instance (`matmul_packed` spins up a fresh
        // `Simulation` per call, so concurrent shards never share
        // mutable overlay state). Row blocks of the cached packings are
        // materialized by per-plane memcpy, not re-decomposition.
        let la_block = la.row_block(shard.rows.clone());
        let rb_block = rb.row_block(shard.cols.clone());
        self.ctx
            .matmul_packed(&la_block, &rb_block, *opts)
            .map(|(p, rep)| (p, Some(rep)))
    }
}

/// Per-request serving options.
#[derive(Clone, Copy, Debug)]
pub struct RequestOptions {
    pub backend: Backend,
    /// Stage-overlap mode of the simulated pipeline ([`Backend::Sim`]
    /// only; the engine has no stages to overlap).
    pub overlap: Overlap,
    /// Skip all-zero bit-planes (sim backend; the engine always skips).
    pub bit_skip: bool,
    /// Cross-check the result against the CPU bit-serial oracle before
    /// completing the request (costs an extra software GEMM).
    pub verify: bool,
    /// Cache this request's packed LHS. Off by default: in the served
    /// workloads the LHS is a fresh activation matrix, and inserting
    /// one-shot packings would only churn the cache. Flip it on when
    /// the LHS genuinely recurs.
    pub cache_lhs: bool,
    /// Cache this request's packed RHS (the weight-stationary side).
    /// On by default.
    pub cache_rhs: bool,
    /// Multi-instance split of this request: the output is decomposed
    /// by a [`ShardPlan`], shards execute concurrently (engine shards
    /// on worker lanes, sim shards on independent simulator instances)
    /// and merge bit-exactly before the response completes.
    pub sharding: Sharding,
    /// Instruction-budget watchdog for the sim backend: a request whose
    /// simulation retires more than this many instructions fails with a
    /// typed [`crate::sim::SimError::BudgetExceeded`] instead of
    /// occupying a worker indefinitely (`None` = unbounded; engine
    /// backend ignores it).
    pub max_instrs: Option<u64>,
    /// Tenant namespace for this request's cache interactions (both
    /// sides). `0` — the default — is the shared in-process namespace;
    /// the network front door (`bismo::net`) assigns each tenant a
    /// nonzero namespace so tenants share the cache's byte budget but
    /// can never hit each other's packed operands.
    pub cache_namespace: u64,
    /// Explicit engine tile geometry for this request. `None` — the
    /// default — selects from the service's loaded [`TunedProfile`]
    /// (by the request's [`crate::costmodel::ShapeClass`]), falling
    /// back to [`KernelConfig::default`] when nothing is tuned. The
    /// sim backend ignores it (its tiling is the overlay's `D_m×D_n`).
    pub kernel: Option<KernelConfig>,
}

impl RequestOptions {
    /// Reject degenerate options before anything is queued: sharding
    /// parameters and — now that tile geometry is user-reachable — the
    /// explicit kernel config, if any.
    pub fn validate(&self) -> Result<(), BismoError> {
        self.sharding.validate()?;
        if let Some(kernel) = &self.kernel {
            kernel.validate()?;
        }
        Ok(())
    }
}

impl Default for RequestOptions {
    fn default() -> Self {
        RequestOptions {
            backend: Backend::Engine,
            overlap: Overlap::Full,
            bit_skip: false,
            verify: false,
            cache_lhs: false,
            cache_rhs: true,
            sharding: Sharding::Single,
            max_instrs: None,
            cache_namespace: 0,
            kernel: None,
        }
    }
}

/// One GEMM request: `a · b` at `prec`. Operands are `Arc`-shared so a
/// weight matrix reused across thousands of requests is never copied.
#[derive(Clone)]
pub struct GemmRequest {
    pub a: Arc<IntMatrix>,
    pub b: Arc<IntMatrix>,
    pub prec: Precision,
    pub opts: RequestOptions,
}

impl GemmRequest {
    /// Request with default options (engine backend, cache on).
    pub fn new(
        a: impl Into<Arc<IntMatrix>>,
        b: impl Into<Arc<IntMatrix>>,
        prec: Precision,
    ) -> GemmRequest {
        Self::with_opts(a, b, prec, RequestOptions::default())
    }

    pub fn with_opts(
        a: impl Into<Arc<IntMatrix>>,
        b: impl Into<Arc<IntMatrix>>,
        prec: Precision,
        opts: RequestOptions,
    ) -> GemmRequest {
        GemmRequest {
            a: a.into(),
            b: b.into(),
            prec,
            opts,
        }
    }
}

/// Everything a completed request reports back.
#[derive(Clone, Debug)]
pub struct GemmResponse {
    /// The `m×n` product.
    pub result: IntMatrix,
    /// Cycle-accurate report ([`Backend::Sim`] only).
    pub report: Option<RunReport>,
    pub backend: Backend,
    /// Wall-clock time from submission to the start of execution
    /// (queueing + micro-batch formation), nanoseconds.
    pub queue_ns: u64,
    /// Wall-clock time spent packing operands (zero-ish on cache hits).
    pub pack_ns: u64,
    /// Wall-clock time inside the backend.
    pub exec_ns: u64,
    /// Wall-clock time from submission to completion.
    pub total_ns: u64,
    /// Whether the packed LHS / RHS came from the cache.
    pub lhs_cached: bool,
    pub rhs_cached: bool,
    /// How many shards (overlay instances) executed this request.
    pub shards: usize,
}

/// Completion slot shared between a [`RequestHandle`] and the worker
/// that fills it. `done` is tracked separately from the take-once
/// outcome so a `wait` after `try_take` errors instead of parking on a
/// condvar nobody will signal again.
#[derive(Default)]
struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

#[derive(Default)]
struct SlotState {
    outcome: Option<Result<GemmResponse, BismoError>>,
    done: bool,
}

impl Slot {
    fn fill(&self, outcome: Result<GemmResponse, BismoError>) {
        let mut g = self.state.lock().unwrap();
        g.outcome = Some(outcome);
        g.done = true;
        self.cv.notify_all();
    }
}

/// Handle to an in-flight request.
pub struct RequestHandle {
    slot: Arc<Slot>,
}

impl RequestHandle {
    /// Block until the request completes. Errs
    /// ([`BismoError::ResultConsumed`], rather than hanging) if the
    /// outcome was already consumed by [`RequestHandle::try_take`].
    pub fn wait(self) -> Result<GemmResponse, BismoError> {
        let mut g = self.slot.state.lock().unwrap();
        loop {
            if g.done {
                return g
                    .outcome
                    .take()
                    .unwrap_or_else(|| Err(BismoError::ResultConsumed));
            }
            g = self.slot.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking poll; returns the outcome once, if complete.
    pub fn try_take(&self) -> Option<Result<GemmResponse, BismoError>> {
        let mut g = self.slot.state.lock().unwrap();
        if g.done {
            g.outcome.take()
        } else {
            None
        }
    }
}

/// Service topology and resource limits.
#[derive(Clone, Copy, Debug)]
pub struct ServiceConfig {
    /// Concurrent requests per micro-batch (the modeled number of
    /// overlay instances).
    pub workers: usize,
    /// Maximum requests drained into one micro-batch.
    pub max_batch: usize,
    /// Packing-cache capacity in bytes; 0 disables the cache.
    pub cache_bytes: usize,
    /// Overlay configuration behind the [`Backend::Sim`] path.
    pub overlay: BismoConfig,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            max_batch: 16,
            cache_bytes: 64 << 20,
            overlay: BismoConfig::small(),
        }
    }
}

struct Inner {
    cfg: ServiceConfig,
    /// This host's tuned profile, if one was loaded at startup — the
    /// source of per-shape-class tile picks and the measured cost
    /// model. `None` = analytical defaults throughout.
    tuned: Option<TunedProfile>,
    /// What `Sharding::Auto` scores candidates with: the tuned
    /// profile's measured-constant model, or [`CostModel::paper`].
    cost_model: CostModel,
    sim: SimBackend,
    queue: Mutex<VecDeque<Pending>>,
    queue_cv: Condvar,
    cache: Mutex<PackingCache>,
    shutdown: AtomicBool,
    submitted: AtomicU64,
    completed: AtomicU64,
}

/// The LHS of a queued job: a dense matrix the service packs (and may
/// cache), or an operand the caller already bit-plane-decomposed —
/// the convolution lowering layer's zero-materialization path, where
/// the im2col patch matrix is packed straight off the input tensor and
/// a dense LHS never exists ([`BismoService::submit_lowered`]).
enum LhsOperand {
    Dense(Arc<IntMatrix>),
    Packed(Arc<BitSerialMatrix>),
}

struct Pending {
    lhs: LhsOperand,
    rhs: Arc<IntMatrix>,
    prec: Precision,
    opts: RequestOptions,
    slot: Arc<Slot>,
    since: Instant,
}

struct PackedOperands {
    la: Arc<BitSerialMatrix>,
    rb: Arc<BitSerialMatrix>,
    lhs_cached: bool,
    rhs_cached: bool,
    pack_ns: u64,
}

/// A persistent, asynchronous GEMM service over the overlay stack.
///
/// Migration note: [`crate::api::Session`] wraps this service and is
/// the intended entry point — it adds builder-style per-job options
/// and the prepared-operand contract on top of `submit`/`run`.
///
/// ```
/// use bismo::bitmatrix::IntMatrix;
/// use bismo::coordinator::{BismoService, GemmRequest, Precision, ServiceConfig};
///
/// let svc = BismoService::new(ServiceConfig::default())?;
/// let a = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
/// let b = IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]);
/// // Submission returns immediately; `wait` blocks for the result.
/// let handle = svc.submit(GemmRequest::new(a, b, Precision::unsigned(2, 2)));
/// let resp = handle.wait()?;
/// assert_eq!(resp.result, IntMatrix::from_slice(2, 2, &[0, 2, 3, 7]));
/// # Ok::<(), bismo::api::BismoError>(())
/// ```
pub struct BismoService {
    inner: Arc<Inner>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl BismoService {
    /// Start the service: validates the overlay configuration, loads
    /// this host's [`TunedProfile`] if one exists (see
    /// [`load_host_profile`] — any missing/corrupt/mismatched profile
    /// silently falls back to analytical defaults), and spawns the
    /// dispatcher thread.
    pub fn new(cfg: ServiceConfig) -> Result<BismoService, BismoError> {
        Self::with_profile(cfg, load_host_profile())
    }

    /// [`BismoService::new`] with an explicit tuned profile (or an
    /// explicit `None` for pure analytical defaults) instead of the
    /// host-profile lookup — the deterministic entry point for tests
    /// and for callers managing profiles themselves.
    pub fn with_profile(
        cfg: ServiceConfig,
        tuned: Option<TunedProfile>,
    ) -> Result<BismoService, BismoError> {
        if cfg.workers == 0 || cfg.max_batch == 0 {
            return Err(BismoError::InvalidConfig(
                "service workers and max_batch must be >= 1".into(),
            ));
        }
        // Resolve the SIMD dispatch tier up front so an invalid
        // BISMO_SIMD override surfaces as a typed error instead of a
        // panic on the first kernel call.
        crate::simd::DispatchTier::resolve()?;
        let cost_model = tuned
            .as_ref()
            .map(|t| t.cost_model)
            .unwrap_or_else(CostModel::paper);
        let inner = Arc::new(Inner {
            tuned,
            cost_model,
            sim: SimBackend::new(cfg.overlay)?,
            queue: Mutex::new(VecDeque::new()),
            queue_cv: Condvar::new(),
            cache: Mutex::new(PackingCache::new(cfg.cache_bytes)),
            shutdown: AtomicBool::new(false),
            submitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            cfg,
        });
        let dispatcher = {
            let inner = inner.clone();
            std::thread::spawn(move || inner.dispatch_loop())
        };
        Ok(BismoService {
            inner,
            dispatcher: Some(dispatcher),
        })
    }

    /// Enqueue a request. Returns at once; the result arrives through
    /// the handle. Malformed requests fail with an error instead of
    /// poisoning the pipeline: shape/precision mismatches complete
    /// immediately (checked here in O(1)), while out-of-range operand
    /// entries are caught at packing time (the scan is skipped on
    /// cache hits, so reused weights are not rescanned per request).
    pub fn submit(&self, req: GemmRequest) -> RequestHandle {
        let check = validate(&req);
        let GemmRequest { a, b, prec, opts } = req;
        self.enqueue(LhsOperand::Dense(a), b, prec, opts, check)
    }

    /// Enqueue one GEMM whose LHS the caller already bit-plane
    /// decomposed (`la` in the [`BitSerialMatrix::from_int`] layout,
    /// `m×k`). This is the convolution lowering layer's entry point:
    /// [`crate::lowering::pack_im2col`] builds the patch matrix's
    /// planes directly from the input tensor, so no dense LHS exists
    /// to hand to [`BismoService::submit`]. The packed LHS bypasses
    /// the packing cache (it is request-specific by construction);
    /// the dense RHS is cached as usual — the weight-stationary side
    /// of a lowered conv layer.
    ///
    /// The declared precision must match the packing: `la.bits ==
    /// prec.wbits` and `la.signed == prec.lsigned`, checked before
    /// anything is queued.
    pub fn submit_lowered(
        &self,
        la: Arc<BitSerialMatrix>,
        b: impl Into<Arc<IntMatrix>>,
        prec: Precision,
        opts: RequestOptions,
    ) -> RequestHandle {
        let b: Arc<IntMatrix> = b.into();
        let check = validate_lowered(&la, &b, &prec, &opts);
        self.enqueue(LhsOperand::Packed(la), b, prec, opts, check)
    }

    fn enqueue(
        &self,
        lhs: LhsOperand,
        rhs: Arc<IntMatrix>,
        prec: Precision,
        opts: RequestOptions,
        check: Result<(), BismoError>,
    ) -> RequestHandle {
        let slot = Arc::new(Slot::default());
        let handle = RequestHandle { slot: slot.clone() };
        if let Err(e) = check {
            slot.fill(Err(e));
            return handle;
        }
        {
            // Enqueue under the lock so a concurrent shutdown either
            // sees this request (and drains it) or rejects it here —
            // nothing is accepted into a queue nobody will drain.
            let mut q = self.inner.queue.lock().unwrap();
            if self.inner.shutdown.load(Ordering::SeqCst) {
                drop(q);
                slot.fill(Err(BismoError::ServiceShutdown));
                return handle;
            }
            self.inner.submitted.fetch_add(1, Ordering::SeqCst);
            q.push_back(Pending {
                lhs,
                rhs,
                prec,
                opts,
                slot,
                since: Instant::now(),
            });
        }
        self.inner.queue_cv.notify_one();
        handle
    }

    /// Submit and wait — the synchronous convenience path.
    pub fn run(&self, req: GemmRequest) -> Result<GemmResponse, BismoError> {
        self.submit(req).wait()
    }

    /// Pack one operand through the service's weight-stationary cache
    /// without executing anything: the *prepare* half of the facade's
    /// prepare-once-execute-many contract
    /// ([`crate::api::Session::prepare`] /
    /// [`crate::api::MatmulBuilder::prepare`]). Returns the packed
    /// operand and whether it was already resident. With the cache
    /// disabled (`cache_bytes == 0`) the pack still happens — it just
    /// is not retained.
    pub fn prepare_operand(
        &self,
        m: &IntMatrix,
        bits: u32,
        signed: bool,
        transposed: bool,
    ) -> Result<(Arc<BitSerialMatrix>, bool), BismoError> {
        self.prepare_operand_in(0, m, bits, signed, transposed)
    }

    /// [`BismoService::prepare_operand`] scoped to a tenant cache
    /// namespace (`0` is the default in-process namespace). The network
    /// front door uses this for prepared-weight uploads so one tenant's
    /// packings are invisible to every other tenant.
    pub fn prepare_operand_in(
        &self,
        namespace: u64,
        m: &IntMatrix,
        bits: u32,
        signed: bool,
        transposed: bool,
    ) -> Result<(Arc<BitSerialMatrix>, bool), BismoError> {
        self.inner.pack_one(
            m,
            PackParams {
                bits,
                signed,
                transposed,
                use_cache: true,
                namespace,
                side: "prepared operand",
            },
        )
    }

    /// Stop accepting new submissions. Already-queued requests still
    /// drain (every accepted handle completes); later submissions fail
    /// with [`BismoError::ServiceShutdown`]. Dropping the service calls
    /// this implicitly and then joins the dispatcher.
    pub fn shutdown(&self) {
        // The flag must flip while holding the queue mutex: the
        // dispatcher checks it under this lock before parking on
        // `queue_cv`, so storing it lock-free could land between that
        // check and the park — a lost wakeup.
        let _guard = self.inner.queue.lock().unwrap();
        self.inner.shutdown.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
    }

    /// Packing-cache counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.inner.cache.lock().unwrap().stats()
    }

    /// Resident packed bytes in the cache.
    pub fn cache_bytes(&self) -> usize {
        self.inner.cache.lock().unwrap().bytes()
    }

    /// Resident cache entries.
    pub fn cache_entries(&self) -> usize {
        self.inner.cache.lock().unwrap().len()
    }

    /// Drop all cached packings (counters are kept).
    pub fn clear_cache(&self) {
        self.inner.cache.lock().unwrap().clear();
    }

    /// Requests submitted over the service's lifetime.
    pub fn submitted(&self) -> u64 {
        self.inner.submitted.load(Ordering::SeqCst)
    }

    /// Requests completed over the service's lifetime.
    pub fn completed(&self) -> u64 {
        self.inner.completed.load(Ordering::SeqCst)
    }

    /// Requests currently queued (not yet dispatched).
    pub fn queue_depth(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    /// The tuned profile this service loaded at startup, if any —
    /// `None` means every request runs on analytical defaults.
    pub fn tuned_profile(&self) -> Option<&TunedProfile> {
        self.inner.tuned.as_ref()
    }
}

impl Drop for BismoService {
    /// Graceful shutdown: the dispatcher drains every queued request
    /// (no handle is left dangling), then exits.
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

/// Constant-time request validation, run on the submitter thread.
/// The O(elements) precision-range scan deliberately does NOT happen
/// here: it runs at packing time ([`Inner::pack_one`]), where a cache
/// hit proves the operand fit and skips the scan entirely — otherwise
/// every request would rescan the shared weight matrix on the
/// submitter's hot path.
fn validate(req: &GemmRequest) -> Result<(), BismoError> {
    if req.a.cols != req.b.rows {
        return Err(BismoError::ShapeMismatch(format!(
            "{}×{} · {}×{}",
            req.a.rows, req.a.cols, req.b.rows, req.b.cols
        )));
    }
    req.opts.validate()?;
    req.prec.validate()
}

/// [`validate`] for a pre-packed LHS ([`BismoService::submit_lowered`]):
/// the packing must agree with the declared precision, or the product
/// would silently be computed at the wrong width.
fn validate_lowered(
    la: &BitSerialMatrix,
    b: &IntMatrix,
    prec: &Precision,
    opts: &RequestOptions,
) -> Result<(), BismoError> {
    if la.cols != b.rows {
        return Err(BismoError::ShapeMismatch(format!(
            "{}×{} (packed) · {}×{}",
            la.rows, la.cols, b.rows, b.cols
        )));
    }
    opts.validate()?;
    prec.validate()?;
    if la.bits != prec.wbits || la.signed != prec.lsigned {
        return Err(BismoError::PrecisionUnsupported(format!(
            "packed lhs is {} {}-bit but the request declares {} {}-bit",
            if la.signed { "signed" } else { "unsigned" },
            la.bits,
            if prec.lsigned { "signed" } else { "unsigned" },
            prec.wbits
        )));
    }
    Ok(())
}

impl Inner {
    /// Dispatcher: form a micro-batch from whatever is queued, drain it
    /// concurrently, repeat. Exits only once shutdown is flagged AND
    /// the queue is empty, so every accepted request completes.
    fn dispatch_loop(&self) {
        loop {
            let batch: Vec<Pending> = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if !q.is_empty() {
                        break;
                    }
                    if self.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    q = self.queue_cv.wait(q).unwrap();
                }
                let take = q.len().min(self.cfg.max_batch);
                q.drain(..take).collect()
            };
            self.run_batch(&batch);
        }
    }

    fn run_batch(&self, batch: &[Pending]) {
        WorkerPool::global().run_limited(batch.len(), self.cfg.workers, &|i| {
            let p = &batch[i];
            // A panic inside a request (a backend assertion, say) must
            // fail that request, not kill the dispatcher and hang every
            // future submitter.
            let outcome =
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.execute_one(p)))
                    .unwrap_or_else(|payload| {
                        Err(BismoError::WorkerPanicked(panic_msg(&payload)))
                    });
            p.slot.fill(outcome);
            self.completed.fetch_add(1, Ordering::SeqCst);
        });
    }

    fn execute_one(&self, p: &Pending) -> Result<GemmResponse, BismoError> {
        let queue_ns = p.since.elapsed().as_nanos() as u64;
        let packed = self.pack_operands(p)?;
        let t_exec = Instant::now();
        let mopts = MatmulOptions {
            overlap: p.opts.overlap,
            bit_skip: p.opts.bit_skip,
            verify: false,
            max_instrs: p.opts.max_instrs,
        };
        let shape = GemmShape {
            m: packed.la.rows,
            k: packed.la.cols,
            n: packed.rb.rows,
        };
        let resolved = resolve_sharding(&p.opts.sharding, &shape, &self.cost_model)?;
        // Tile geometry: the request's explicit pick wins, else the
        // tuned profile's entry for this shape's class, else the
        // analytical default. The backend is per-request and cheap
        // (a `Copy` config) — mirroring the auto_sim pattern below.
        let kernel = p
            .opts
            .kernel
            .or_else(|| self.tuned.as_ref().and_then(|t| t.tile_for(&shape)))
            .unwrap_or_default();
        let engine = EngineBackend { kernel };
        // For the cost-model-driven path on the sim backend, execution
        // runs on instances of the *selected* configuration (validated
        // against the budget the caller named) — also when the
        // selection came out as a single instance.
        let auto_sim: Option<SimBackend> = match (p.opts.backend, resolved.auto) {
            (Backend::Sim, Some((cfg, budget))) => {
                Some(SimBackend::on_platform(cfg, budget.as_platform())?)
            }
            _ => None,
        };
        let backend: &dyn ExecBackend = match p.opts.backend {
            Backend::Engine => &engine,
            Backend::Sim => auto_sim
                .as_ref()
                .map(|b| b as &dyn ExecBackend)
                .unwrap_or(&self.sim),
        };
        let (result, report, shards) = if resolved.plan.is_single() {
            let (r, rep) = backend.execute(&packed.la, &packed.rb, &mopts)?;
            (r, rep, 1)
        } else {
            self.execute_sharded(backend, &packed, &resolved, &mopts)?
        };
        let exec_ns = t_exec.elapsed().as_nanos() as u64;
        if p.opts.verify {
            let expect = gemm_bitserial(&packed.la, &packed.rb);
            if result != expect {
                return Err(BismoError::VerifyFailed(format!(
                    "{} backend != CPU oracle ({} shard(s))",
                    p.opts.backend.name(),
                    shards
                )));
            }
        }
        Ok(GemmResponse {
            result,
            report,
            backend: p.opts.backend,
            queue_ns,
            pack_ns: packed.pack_ns,
            exec_ns,
            total_ns: p.since.elapsed().as_nanos() as u64,
            lhs_cached: packed.lhs_cached,
            rhs_cached: packed.rhs_cached,
            shards,
        })
    }

    /// Multi-instance execution of one request: every shard of the
    /// plan runs concurrently — engine shards as worker-pool lanes over
    /// zero-copy block views of the cached packings, sim shards as
    /// independent simulator instances — and the partial products merge
    /// through [`ShardPlan::assemble`] before the response completes.
    fn execute_sharded(
        &self,
        backend: &dyn ExecBackend,
        packed: &PackedOperands,
        resolved: &ResolvedSharding,
        mopts: &MatmulOptions,
    ) -> Result<(IntMatrix, Option<RunReport>, usize), BismoError> {
        let shards = resolved.plan.shards();
        type ShardOutcome = Result<(IntMatrix, Option<RunReport>), BismoError>;
        let slots: Vec<Mutex<Option<ShardOutcome>>> =
            shards.iter().map(|_| Mutex::new(None)).collect();
        // One lane per shard (the modeled instance count). When this
        // runs inside a micro-batch drain the pool is busy and the
        // shards fall back to scoped threads — per-request parallelism
        // is preserved either way.
        WorkerPool::global().run_limited(shards.len(), shards.len(), &|i| {
            let out = backend.execute_block(&packed.la, &packed.rb, &shards[i], mopts);
            *slots[i].lock().unwrap() = Some(out);
        });
        let mut parts = Vec::with_capacity(shards.len());
        let mut reports = Vec::new();
        for slot in slots {
            match slot.into_inner().unwrap().expect("shard executed") {
                Ok((part, rep)) => {
                    if let Some(r) = rep {
                        reports.push(r);
                    }
                    parts.push(part);
                }
                Err(e) => return Err(e),
            }
        }
        let merged = resolved.plan.assemble(&parts)?;
        Ok((merged, RunReport::merge_parallel(&reports), shards.len()))
    }

    fn pack_operands(&self, p: &Pending) -> Result<PackedOperands, BismoError> {
        let t0 = Instant::now();
        let (la, lhs_cached) = match &p.lhs {
            // Already decomposed by the caller (conv lowering): no
            // pack, no cache interaction — the packing is
            // request-specific by construction.
            LhsOperand::Packed(la) => (la.clone(), false),
            LhsOperand::Dense(a) => self.pack_one(a, PackParams::lhs(&p.prec, &p.opts))?,
        };
        let (rb, rhs_cached) = self.pack_one(&p.rhs, PackParams::rhs(&p.prec, &p.opts))?;
        Ok(PackedOperands {
            la,
            rb,
            lhs_cached,
            rhs_cached,
            pack_ns: t0.elapsed().as_nanos() as u64,
        })
    }

    /// Cache-aware packing of one operand. Lookup and insert are short
    /// critical sections; the pack itself runs outside the lock (two
    /// racing misses may both pack — the second insert replaces the
    /// first, and both results are identical by construction). A cache
    /// hit proves the operand fit its declared precision when first
    /// packed, so the range scan only runs on actual packs.
    fn pack_one(
        &self,
        m: &IntMatrix,
        p: PackParams,
    ) -> Result<(Arc<BitSerialMatrix>, bool), BismoError> {
        if !p.use_cache || self.cfg.cache_bytes == 0 {
            check_fits(m, p.bits, p.signed, p.side)?;
            return Ok((Arc::new(pack_operand(m, p.bits, p.signed, p.transposed)), false));
        }
        let key = PackKey::of(m, p.bits, p.signed, p.transposed).in_namespace(p.namespace);
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Ok((hit, true));
        }
        check_fits(m, p.bits, p.signed, p.side)?;
        let packed = Arc::new(pack_operand(m, p.bits, p.signed, p.transposed));
        self.cache.lock().unwrap().insert(key, packed.clone());
        Ok((packed, false))
    }
}

/// The slice of one request's [`Precision`] + [`RequestOptions`] that
/// applies to a single operand side. Each side's routing — which bit
/// width, which signedness, whether the packing is transposed, which
/// cache policy — is derived in exactly one constructor, so the
/// option-to-side mapping cannot drift between call sites.
struct PackParams {
    bits: u32,
    signed: bool,
    transposed: bool,
    use_cache: bool,
    namespace: u64,
    side: &'static str,
}

impl PackParams {
    /// LHS (activation side): `wbits`/`lsigned`, packed row-major,
    /// cached only on request (fresh activations would churn the
    /// cache).
    fn lhs(prec: &Precision, opts: &RequestOptions) -> PackParams {
        PackParams {
            bits: prec.wbits,
            signed: prec.lsigned,
            transposed: false,
            use_cache: opts.cache_lhs,
            namespace: opts.cache_namespace,
            side: "lhs",
        }
    }

    /// RHS (weight-stationary side): `abits`/`rsigned`, packed
    /// transposed, cached by default.
    fn rhs(prec: &Precision, opts: &RequestOptions) -> PackParams {
        PackParams {
            bits: prec.abits,
            signed: prec.rsigned,
            transposed: true,
            use_cache: opts.cache_rhs,
            namespace: opts.cache_namespace,
            side: "rhs",
        }
    }
}

/// A request's [`Sharding`] resolved against its concrete shape.
struct ResolvedSharding {
    plan: ShardPlan,
    /// `Auto` only: the selected per-instance config and the budget it
    /// was priced against (the sim backend instantiates it).
    auto: Option<(BismoConfig, ResourceBudget)>,
}

fn resolve_sharding(
    s: &Sharding,
    shape: &GemmShape,
    model: &CostModel,
) -> Result<ResolvedSharding, BismoError> {
    Ok(match *s {
        Sharding::Single => ResolvedSharding {
            plan: ShardPlan::single(shape.m, shape.n),
            auto: None,
        },
        Sharding::Grid { rows, cols } => ResolvedSharding {
            plan: ShardPlan::grid(shape.m, shape.n, rows, cols),
            auto: None,
        },
        Sharding::Instances(n) => ResolvedSharding {
            plan: ShardPlan::for_instances(shape.m, shape.n, n),
            auto: None,
        },
        Sharding::Auto(budget) => {
            // The model is the tuned profile's measured-constant fit
            // when one is loaded, the paper constants otherwise.
            let choice = select_sharding(model, shape, budget)?;
            ResolvedSharding {
                plan: ShardPlan::grid(shape.m, shape.n, choice.grid.0, choice.grid.1),
                auto: Some((choice.config, budget)),
            }
        }
    })
}

fn panic_msg(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "opaque panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn svc() -> BismoService {
        BismoService::new(ServiceConfig::default()).unwrap()
    }

    #[test]
    fn single_request_round_trip_engine_and_sim() {
        let s = svc();
        let mut rng = Rng::new(0x5EB);
        let a = IntMatrix::random(&mut rng, 4, 100, 3, true);
        let b = IntMatrix::random(&mut rng, 100, 5, 2, false);
        let expect = a.matmul(&b);
        let prec = Precision {
            wbits: 3,
            abits: 2,
            lsigned: true,
            rsigned: false,
        };
        for backend in [Backend::Engine, Backend::Sim] {
            let opts = RequestOptions {
                backend,
                ..Default::default()
            };
            let resp = s
                .run(GemmRequest::with_opts(a.clone(), b.clone(), prec, opts))
                .unwrap();
            assert_eq!(resp.result, expect, "{}", backend.name());
            assert_eq!(resp.report.is_some(), backend == Backend::Sim);
            assert!(resp.total_ns >= resp.exec_ns);
        }
        assert_eq!(s.submitted(), 2);
        assert_eq!(s.completed(), 2);
    }

    #[test]
    fn weight_reuse_is_served_from_cache() {
        let s = svc();
        let mut rng = Rng::new(0xCAFE);
        let w = Arc::new(IntMatrix::random(&mut rng, 96, 8, 4, true));
        let prec = Precision {
            wbits: 2,
            abits: 4,
            lsigned: false,
            rsigned: true,
        };
        let mut first = true;
        for _ in 0..6 {
            let x = IntMatrix::random(&mut rng, 3, 96, 2, false);
            let expect = x.matmul(&w);
            let resp = s.run(GemmRequest::new(x, w.clone(), prec)).unwrap();
            assert_eq!(resp.result, expect);
            assert_eq!(resp.rhs_cached, !first, "weight packing cached after first use");
            assert!(!resp.lhs_cached, "fresh activations always miss");
            first = false;
        }
        let stats = s.cache_stats();
        assert_eq!(stats.hits, 5);
        assert!(s.cache_entries() >= 1);
        assert!(s.cache_bytes() > 0);
    }

    #[test]
    fn invalid_requests_fail_cleanly_and_service_survives() {
        let s = svc();
        // Shape mismatch — and the caller can branch on the kind.
        let bad = GemmRequest::new(
            IntMatrix::zeros(2, 3),
            IntMatrix::zeros(4, 2),
            Precision::unsigned(1, 1),
        );
        assert!(matches!(s.run(bad), Err(BismoError::ShapeMismatch(_))));
        // Zero-width precision is rejected at submission.
        let zero_bits = GemmRequest::new(
            IntMatrix::zeros(1, 1),
            IntMatrix::zeros(1, 1),
            Precision {
                wbits: 0,
                abits: 1,
                lsigned: false,
                rsigned: false,
            },
        );
        assert!(matches!(
            s.run(zero_bits),
            Err(BismoError::PrecisionUnsupported(_))
        ));
        // Operand outside the declared precision.
        let too_wide = GemmRequest::new(
            IntMatrix::from_slice(1, 1, &[100]),
            IntMatrix::zeros(1, 1),
            Precision::unsigned(2, 2),
        );
        assert!(matches!(
            s.run(too_wide),
            Err(BismoError::PrecisionUnsupported(_))
        ));
        // A valid request afterwards still completes.
        let ok = GemmRequest::new(
            IntMatrix::from_slice(1, 1, &[1]),
            IntMatrix::from_slice(1, 1, &[1]),
            Precision::unsigned(1, 1),
        );
        assert_eq!(s.run(ok).unwrap().result, IntMatrix::from_slice(1, 1, &[1]));
    }

    #[test]
    fn micro_batch_preserves_per_request_results() {
        let s = BismoService::new(ServiceConfig {
            workers: 3,
            max_batch: 4,
            ..Default::default()
        })
        .unwrap();
        let mut rng = Rng::new(0xBA7C);
        let jobs: Vec<(IntMatrix, IntMatrix)> = (0..12)
            .map(|_| {
                let k = rng.index(128) + 1;
                (
                    IntMatrix::random(&mut rng, 2, k, 2, false),
                    IntMatrix::random(&mut rng, k, 3, 2, false),
                )
            })
            .collect();
        let handles: Vec<_> = jobs
            .iter()
            .map(|(a, b)| {
                s.submit(GemmRequest::new(
                    a.clone(),
                    b.clone(),
                    Precision::unsigned(2, 2),
                ))
            })
            .collect();
        for (h, (a, b)) in handles.into_iter().zip(&jobs) {
            assert_eq!(h.wait().unwrap().result, a.matmul(b));
        }
    }

    #[test]
    fn shutdown_rejects_new_submissions_with_typed_error() {
        let s = svc();
        s.shutdown();
        let r = s.run(GemmRequest::new(
            IntMatrix::from_slice(1, 1, &[1]),
            IntMatrix::from_slice(1, 1, &[1]),
            Precision::unsigned(1, 1),
        ));
        assert!(matches!(r, Err(BismoError::ServiceShutdown)), "{r:?}");
        assert_eq!(s.submitted(), 0, "rejected submissions are not counted");
    }

    #[test]
    fn prepare_operand_prewarms_the_cache() {
        let s = svc();
        let mut rng = Rng::new(0x11E);
        let w = Arc::new(IntMatrix::random(&mut rng, 64, 4, 3, true));
        let (_, resident) = s.prepare_operand(&w, 3, true, true).unwrap();
        assert!(!resident, "first prepare packs");
        let (_, resident2) = s.prepare_operand(&w, 3, true, true).unwrap();
        assert!(resident2, "second prepare is already resident");
        // A request over the prepared weights hits the cache on its RHS.
        let x = IntMatrix::random(&mut rng, 2, 64, 2, false);
        let prec = Precision {
            wbits: 2,
            abits: 3,
            lsigned: false,
            rsigned: true,
        };
        let resp = s.run(GemmRequest::new(x.clone(), w.clone(), prec)).unwrap();
        assert!(resp.rhs_cached, "prepared packing served the request");
        assert_eq!(resp.result, x.matmul(&w));
    }

    #[test]
    fn cache_namespaces_isolate_tenants_end_to_end() {
        let s = svc();
        let mut rng = Rng::new(0x7E4A);
        let w = Arc::new(IntMatrix::random(&mut rng, 64, 4, 3, true));
        // Tenant A uploads weights into its namespace.
        let (_, resident) = s.prepare_operand_in(0xA, &w, 3, true, true).unwrap();
        assert!(!resident);
        let (_, resident_a) = s.prepare_operand_in(0xA, &w, 3, true, true).unwrap();
        assert!(resident_a, "tenant A re-prepare hits its own entry");
        // Tenant B preparing the *identical* weights misses: namespaces
        // partition identity even for bit-identical content.
        let (_, resident_b) = s.prepare_operand_in(0xB, &w, 3, true, true).unwrap();
        assert!(!resident_b, "tenant B must not see tenant A's packing");
        // Requests tagged with a namespace only hit that namespace.
        let x = IntMatrix::random(&mut rng, 2, 64, 2, false);
        let prec = Precision {
            wbits: 2,
            abits: 3,
            lsigned: false,
            rsigned: true,
        };
        let opts_a = RequestOptions {
            cache_namespace: 0xA,
            ..Default::default()
        };
        let resp = s
            .run(GemmRequest::with_opts(x.clone(), w.clone(), prec, opts_a))
            .unwrap();
        assert!(resp.rhs_cached, "tenant A request served from its upload");
        assert_eq!(resp.result, x.matmul(&w));
        // The default namespace sees neither tenant's entries.
        let resp0 = s.run(GemmRequest::new(x.clone(), w.clone(), prec)).unwrap();
        assert!(!resp0.rhs_cached, "default namespace is its own partition");
    }

    #[test]
    fn drop_drains_outstanding_requests() {
        let s = svc();
        let mut rng = Rng::new(0xD0);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let a = IntMatrix::random(&mut rng, 2, 64, 1, false);
                let b = IntMatrix::random(&mut rng, 64, 2, 1, false);
                s.submit(GemmRequest::new(a, b, Precision::unsigned(1, 1)))
            })
            .collect();
        drop(s);
        for h in handles {
            assert!(h.wait().is_ok(), "request completed during shutdown drain");
        }
    }

    #[test]
    fn sharded_request_matches_unsharded_on_both_backends() {
        let s = svc();
        let mut rng = Rng::new(0x54A2);
        let a = IntMatrix::random(&mut rng, 12, 150, 3, true);
        let b = IntMatrix::random(&mut rng, 150, 10, 2, false);
        let expect = a.matmul(&b);
        let prec = Precision {
            wbits: 3,
            abits: 2,
            lsigned: true,
            rsigned: false,
        };
        for backend in [Backend::Engine, Backend::Sim] {
            for sharding in [
                Sharding::Grid { rows: 2, cols: 2 },
                Sharding::Instances(3),
                Sharding::Instances(8),
            ] {
                let opts = RequestOptions {
                    backend,
                    sharding,
                    verify: true,
                    ..Default::default()
                };
                let resp = s
                    .run(GemmRequest::with_opts(a.clone(), b.clone(), prec, opts))
                    .unwrap();
                assert_eq!(resp.result, expect, "{} {sharding:?}", backend.name());
                assert!(resp.shards > 1, "{} {sharding:?}", backend.name());
                // Sim shards each carry a report; the merged report
                // aggregates their work.
                if backend == Backend::Sim {
                    let rep = resp.report.expect("merged sim report");
                    assert!(rep.cycles > 0);
                    assert!(rep.stats.binary_ops > 0);
                }
            }
        }
    }

    #[test]
    fn auto_sharding_picks_under_budget_and_stays_exact() {
        use crate::arch::PYNQ_Z1;
        let s = svc();
        let mut rng = Rng::new(0xA070);
        let a = IntMatrix::random(&mut rng, 32, 200, 2, false);
        let b = IntMatrix::random(&mut rng, 200, 32, 2, false);
        let expect = a.matmul(&b);
        let budget = ResourceBudget {
            luts: PYNQ_Z1.luts * 2,
            brams: PYNQ_Z1.brams * 2,
        };
        for backend in [Backend::Engine, Backend::Sim] {
            let opts = RequestOptions {
                backend,
                sharding: Sharding::Auto(budget),
                ..Default::default()
            };
            let resp = s
                .run(GemmRequest::with_opts(
                    a.clone(),
                    b.clone(),
                    Precision::unsigned(2, 2),
                    opts,
                ))
                .unwrap();
            assert_eq!(resp.result, expect, "{}", backend.name());
            assert!(resp.shards >= 2, "double budget affords >1 instance");
        }
    }

    #[test]
    fn degenerate_sharding_is_rejected_at_submission() {
        let s = svc();
        let mk = |sharding| {
            let opts = RequestOptions {
                sharding,
                ..Default::default()
            };
            GemmRequest::with_opts(
                IntMatrix::zeros(2, 2),
                IntMatrix::zeros(2, 2),
                Precision::unsigned(1, 1),
                opts,
            )
        };
        assert!(matches!(
            s.run(mk(Sharding::Grid { rows: 0, cols: 2 })),
            Err(BismoError::InvalidConfig(_))
        ));
        assert!(matches!(
            s.run(mk(Sharding::Instances(0))),
            Err(BismoError::InvalidConfig(_))
        ));
        // A 1-shard request takes the plain single-instance path.
        let resp = s.run(mk(Sharding::Instances(1))).unwrap();
        assert_eq!(resp.shards, 1);
    }

    #[test]
    fn oversharded_tiny_job_clamps_to_available_rows() {
        let s = svc();
        let a = IntMatrix::from_slice(1, 2, &[1, 2]);
        let b = IntMatrix::from_slice(2, 1, &[3, 4]);
        let opts = RequestOptions {
            sharding: Sharding::Grid { rows: 8, cols: 8 },
            ..Default::default()
        };
        let resp = s
            .run(GemmRequest::with_opts(a, b, Precision::unsigned(2, 3), opts))
            .unwrap();
        assert_eq!(resp.result, IntMatrix::from_slice(1, 1, &[11]));
        assert_eq!(resp.shards, 1, "1×1 output cannot split");
    }

    #[test]
    fn submit_lowered_executes_prepacked_lhs() {
        let s = svc();
        let mut rng = Rng::new(0x10E7);
        let a = IntMatrix::random(&mut rng, 6, 90, 3, false);
        let b = Arc::new(IntMatrix::random(&mut rng, 90, 5, 2, true));
        let expect = a.matmul(&b);
        let prec = Precision {
            wbits: 3,
            abits: 2,
            lsigned: false,
            rsigned: true,
        };
        let la = Arc::new(BitSerialMatrix::from_int(&a, 3, false));
        for backend in [Backend::Engine, Backend::Sim] {
            let opts = RequestOptions {
                backend,
                verify: true,
                ..Default::default()
            };
            let resp = s.submit_lowered(la.clone(), b.clone(), prec, opts).wait().unwrap();
            assert_eq!(resp.result, expect, "{}", backend.name());
            assert!(!resp.lhs_cached, "pre-packed lhs never touches the cache");
            assert_eq!(resp.report.is_some(), backend == Backend::Sim);
        }
        // Sharded lowered request merges bit-exactly too.
        let opts = RequestOptions {
            sharding: Sharding::Grid { rows: 2, cols: 2 },
            ..Default::default()
        };
        let resp = s.submit_lowered(la, b, prec, opts).wait().unwrap();
        assert_eq!(resp.result, expect);
        assert_eq!(resp.shards, 4);
    }

    #[test]
    fn submit_lowered_rejects_mismatched_packing() {
        let s = svc();
        let a = IntMatrix::from_slice(2, 3, &[1, 0, 1, 0, 1, 1]);
        let b = Arc::new(IntMatrix::zeros(3, 2));
        let la = Arc::new(BitSerialMatrix::from_int(&a, 2, false));
        let prec = |wbits, lsigned| Precision {
            wbits,
            abits: 1,
            lsigned,
            rsigned: false,
        };
        // Declared width disagrees with the packing.
        let r = s.submit_lowered(la.clone(), b.clone(), prec(3, false), RequestOptions::default());
        assert!(matches!(r.wait(), Err(BismoError::PrecisionUnsupported(_))));
        // Declared signedness disagrees.
        let r = s.submit_lowered(la.clone(), b.clone(), prec(2, true), RequestOptions::default());
        assert!(matches!(r.wait(), Err(BismoError::PrecisionUnsupported(_))));
        // k mismatch.
        let short = Arc::new(IntMatrix::zeros(2, 2));
        let r = s.submit_lowered(la.clone(), short, prec(2, false), RequestOptions::default());
        assert!(matches!(r.wait(), Err(BismoError::ShapeMismatch(_))));
        // The matching request still completes.
        let r = s.submit_lowered(la, b, prec(2, false), RequestOptions::default());
        assert_eq!(r.wait().unwrap().result, IntMatrix::zeros(2, 2));
    }

    #[test]
    fn verify_option_cross_checks() {
        let s = svc();
        let mut rng = Rng::new(0x7E7);
        let a = IntMatrix::random(&mut rng, 3, 70, 2, true);
        let b = IntMatrix::random(&mut rng, 70, 3, 2, true);
        let opts = RequestOptions {
            verify: true,
            backend: Backend::Sim,
            ..Default::default()
        };
        let resp = s
            .run(GemmRequest::with_opts(a.clone(), b.clone(), Precision::signed(2, 2), opts))
            .unwrap();
        assert_eq!(resp.result, a.matmul(&b));
    }
}
