//! The BISMO hardware parameter set (paper Table I) plus derived
//! quantities used by the scheduler, simulator and cost model.

use crate::api::BismoError;
use crate::util::{ceil_div, ceil_log2};

/// Design-time configuration of one BISMO overlay instance.
///
/// Mirrors Table I of the paper:
///
/// | Symbol      | Field        | Description                            |
/// |-------------|--------------|----------------------------------------|
/// | `D_m, D_n`  | `dm`, `dn`   | Rows/columns of DPUs in the DPA        |
/// | `D_k`       | `dk`         | DPU input bit width (popcount width)   |
/// | `B_m, B_n`  | `bm`, `bn`   | Depth of LHS/RHS matrix buffers (words)|
/// | `B_r`       | `br`         | Depth of result matrix buffer          |
/// | `A`         | `acc_bits`   | Accumulator bitwidth                   |
/// | `F`         | `fetch_bits` | Main-memory read channel width (bits)  |
/// | `R`         | `res_bits`   | Main-memory write channel width (bits) |
///
/// plus the clock frequency `fclk_mhz` (a run-time property of the
/// instance on a given board, used by performance/power reporting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BismoConfig {
    /// Number of DPU rows (LHS parallelism), `D_m`.
    pub dm: u32,
    /// DPU input bit width (popcount width), `D_k`.
    pub dk: u32,
    /// Number of DPU columns (RHS parallelism), `D_n`.
    pub dn: u32,
    /// Depth of each LHS matrix buffer in `D_k`-bit words, `B_m`.
    pub bm: u32,
    /// Depth of each RHS matrix buffer in `D_k`-bit words, `B_n`.
    pub bn: u32,
    /// Depth of the result buffer in full `D_m × D_n` result sets, `B_r`.
    pub br: u32,
    /// Accumulator width in bits, `A` (32 in the paper).
    pub acc_bits: u32,
    /// Main-memory read channel width in bits, `F` (64 on PYNQ-Z1).
    pub fetch_bits: u32,
    /// Main-memory write channel width in bits, `R` (64 on PYNQ-Z1).
    pub res_bits: u32,
    /// Clock frequency in MHz.
    pub fclk_mhz: u32,
}

impl BismoConfig {
    /// A small default suitable for tests: 2×64×2 DPA with shallow buffers.
    pub fn small() -> Self {
        BismoConfig {
            dm: 2,
            dk: 64,
            dn: 2,
            bm: 1024,
            bn: 1024,
            br: 2,
            acc_bits: 32,
            fetch_bits: 64,
            res_bits: 64,
            fclk_mhz: 200,
        }
    }

    /// Number of DPUs in the array.
    pub fn num_dpus(&self) -> u32 {
        self.dm * self.dn
    }

    /// Binary ops per cycle at peak: each DPU does `D_k` AND + `D_k`
    /// popcount-adds per cycle (the paper counts 2 ops per bit pair).
    pub fn binary_ops_per_cycle(&self) -> u64 {
        2 * self.dm as u64 * self.dn as u64 * self.dk as u64
    }

    /// Peak binary GOPS at the configured clock.
    pub fn peak_binary_gops(&self) -> f64 {
        self.binary_ops_per_cycle() as f64 * self.fclk_mhz as f64 * 1e6 / 1e9
    }

    /// DPA pipeline depth in cycles: popcount compressor-tree stages grow
    /// with `log2(D_k)`, plus a fixed pipeline overhead (AND stage,
    /// shift/negate, accumulate, buffer read latency, instruction decode).
    /// Fitted against Fig. 12 of the paper (see DESIGN.md §4).
    pub fn dpa_pipeline_depth(&self) -> u64 {
        ceil_log2(self.dk as u64) as u64 + 10
    }

    /// Capacity of one LHS matrix buffer in bits.
    pub fn lhs_buf_bits(&self) -> u64 {
        self.bm as u64 * self.dk as u64
    }

    /// Capacity of one RHS matrix buffer in bits.
    pub fn rhs_buf_bits(&self) -> u64 {
        self.bn as u64 * self.dk as u64
    }

    /// Total on-chip matrix-buffer capacity in bits (LHS + RHS).
    pub fn total_buf_bits(&self) -> u64 {
        self.dm as u64 * self.lhs_buf_bits() + self.dn as u64 * self.rhs_buf_bits()
    }

    /// How many `fetch_bits`-wide memory words make up one `D_k`-bit
    /// buffer word. The fetch interconnect requires `D_k` to be an
    /// integer multiple of `F` or vice versa (paper §III-B2 constraint).
    pub fn fetch_words_per_buf_word(&self) -> u64 {
        ceil_div(self.dk as u64, self.fetch_bits as u64)
    }

    /// Validate structural constraints the hardware generator imposes.
    pub fn validate(&self) -> Result<(), BismoError> {
        let bad = |m: String| Err(BismoError::InvalidConfig(m));
        if self.dm == 0 || self.dn == 0 || self.dk == 0 {
            return bad("DPA dimensions must be non-zero".into());
        }
        if !self.dk.is_power_of_two() {
            return bad(format!("D_k must be a power of two, got {}", self.dk));
        }
        if self.dk < 32 {
            return bad(format!("D_k must be >= 32 (one BRAM lane), got {}", self.dk));
        }
        if !self.fetch_bits.is_power_of_two() || !self.res_bits.is_power_of_two() {
            return bad("memory channel widths must be powers of two".into());
        }
        if self.dk % self.fetch_bits != 0 && self.fetch_bits % self.dk != 0 {
            return bad(format!(
                "D_k ({}) and F ({}) must be integer multiples of each other",
                self.dk, self.fetch_bits
            ));
        }
        if self.acc_bits == 0 {
            return bad("accumulator width must be at least 1 bit".into());
        }
        if self.acc_bits > 64 {
            return bad("accumulator width above 64 bits is unsupported".into());
        }
        if self.bm == 0 || self.bn == 0 || self.br == 0 {
            return bad("buffer depths must be non-zero".into());
        }
        if self.fclk_mhz == 0 {
            return bad("clock frequency must be non-zero".into());
        }
        Ok(())
    }

    /// With a different clock, e.g. for the Table V constant-GOPS rows.
    pub fn at_clock(mut self, fclk_mhz: u32) -> Self {
        self.fclk_mhz = fclk_mhz;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_gops_matches_table4() {
        // Table IV instance #3: 8×256×8 at 200 MHz = 6553.6 GOPS.
        let c = BismoConfig {
            dm: 8,
            dk: 256,
            dn: 8,
            ..BismoConfig::small()
        };
        assert!((c.peak_binary_gops() - 6553.6).abs() < 1e-6);
        // Instance #1: 8×64×8 = 1638.4 GOPS.
        let c1 = BismoConfig {
            dm: 8,
            dk: 64,
            dn: 8,
            ..BismoConfig::small()
        };
        assert!((c1.peak_binary_gops() - 1638.4).abs() < 1e-6);
    }

    #[test]
    fn pipeline_depth_grows_with_dk() {
        let mk = |dk| BismoConfig { dk, ..BismoConfig::small() }.dpa_pipeline_depth();
        assert_eq!(mk(64), 16);
        assert_eq!(mk(256), 18);
        assert!(mk(1024) > mk(32));
    }

    #[test]
    fn validation_catches_bad_configs() {
        assert!(BismoConfig::small().validate().is_ok());
        assert!(BismoConfig { dk: 48, ..BismoConfig::small() }.validate().is_err());
        assert!(BismoConfig { dk: 16, ..BismoConfig::small() }.validate().is_err());
        assert!(BismoConfig { dm: 0, ..BismoConfig::small() }.validate().is_err());
        assert!(BismoConfig { bm: 0, ..BismoConfig::small() }.validate().is_err());
        assert!(BismoConfig { fclk_mhz: 0, ..BismoConfig::small() }.validate().is_err());
        assert!(BismoConfig { acc_bits: 0, ..BismoConfig::small() }.validate().is_err());
        assert!(BismoConfig { acc_bits: 65, ..BismoConfig::small() }.validate().is_err());
        assert!(BismoConfig { acc_bits: 64, ..BismoConfig::small() }.validate().is_ok());
    }

    #[test]
    fn buffer_capacity() {
        let c = BismoConfig::small();
        assert_eq!(c.lhs_buf_bits(), 1024 * 64);
        assert_eq!(c.total_buf_bits(), 2 * 1024 * 64 + 2 * 1024 * 64);
    }

    #[test]
    fn at_clock_changes_only_clock() {
        let c = BismoConfig::small().at_clock(50);
        assert_eq!(c.fclk_mhz, 50);
        assert_eq!(c.dm, BismoConfig::small().dm);
    }
}
