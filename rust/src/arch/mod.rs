//! Hardware architecture description: overlay configuration parameters
//! (the paper's Table I), target-platform description (PYNQ-Z1 / Z7020),
//! and the Table IV instance presets used throughout the evaluation.

mod config;
mod instances;
mod platform;

pub use config::BismoConfig;
pub use instances::{all_instances, instance, try_instance, InstanceId};
pub use platform::{Platform, PYNQ_Z1};
