//! The six BISMO instances of Table IV, used for all runtime-performance
//! experiments (Figs 12–13, stage overlap, Table V power rows).
//!
//! Buffer depths are not listed in the paper's table; they are chosen
//! here to consume most of the Z7020's BRAM budget, matching the table's
//! reported BRAM utilization as closely as our BRAM model (Eq. 2) allows
//! (see `costmodel`). `B_m`/`B_n` are in `D_k`-bit words.

use super::config::BismoConfig;
use crate::api::BismoError;

/// Identifier of a Table IV instance (1-based, as in the paper).
pub type InstanceId = u32;

/// Fallible lookup of Table IV instance `id` (1..=6), at its default
/// 200 MHz clock. Unknown ids return
/// [`BismoError::InvalidConfig`] instead of panicking — the path the
/// CLI and anything handling untrusted ids should take.
///
/// | # | D_m | D_k | D_n | peak GOPS |
/// |---|-----|-----|-----|-----------|
/// | 1 | 8   | 64  | 8   | 1638.4    |
/// | 2 | 8   | 128 | 8   | 3276.8    |
/// | 3 | 8   | 256 | 8   | 6553.6    |
/// | 4 | 4   | 256 | 4   | 1638.4    |
/// | 5 | 8   | 256 | 4   | 3276.8    |
/// | 6 | 4   | 512 | 4   | 3276.8    |
pub fn try_instance(id: InstanceId) -> Result<BismoConfig, BismoError> {
    let base = BismoConfig {
        dm: 0,
        dk: 0,
        dn: 0,
        bm: 0,
        bn: 0,
        br: 2,
        acc_bits: 32,
        fetch_bits: 64,
        res_bits: 64,
        fclk_mhz: 200,
    };
    Ok(match id {
        // Dk=64 → 2 BRAM lanes/buffer-word: deep buffers are cheap, use
        // 4096-deep to soak up BRAM like the paper's 86% utilization row.
        1 => BismoConfig { dm: 8, dk: 64, dn: 8, bm: 4096, bn: 3072, ..base },
        2 => BismoConfig { dm: 8, dk: 128, dn: 8, bm: 2048, bn: 2048, ..base },
        3 => BismoConfig { dm: 8, dk: 256, dn: 8, bm: 1024, bn: 1024, ..base },
        4 => BismoConfig { dm: 4, dk: 256, dn: 4, bm: 2048, bn: 2048, ..base },
        5 => BismoConfig { dm: 8, dk: 256, dn: 4, bm: 1024, bn: 2048, ..base },
        6 => BismoConfig { dm: 4, dk: 512, dn: 4, bm: 1024, bn: 1024, ..base },
        _ => {
            return Err(BismoError::InvalidConfig(format!(
                "Table IV defines instances 1..=6, got {id}"
            )))
        }
    })
}

/// [`try_instance`] for trusted, hard-coded ids (benchmarks, tests):
/// panics on an unknown id. Prefer [`try_instance`] anywhere the id
/// comes from user input.
pub fn instance(id: InstanceId) -> BismoConfig {
    try_instance(id).unwrap_or_else(|e| panic!("{e}"))
}

/// All six Table IV instances in order.
pub fn all_instances() -> Vec<(InstanceId, BismoConfig)> {
    (1..=6).map(|i| (i, instance(i))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::platform::PYNQ_Z1;

    #[test]
    fn gops_match_table4() {
        let expect = [1638.4, 3276.8, 6553.6, 1638.4, 3276.8, 3276.8];
        for (i, &g) in expect.iter().enumerate() {
            let c = instance(i as u32 + 1);
            assert!(
                (c.peak_binary_gops() - g).abs() < 1e-6,
                "instance {} gops {}",
                i + 1,
                c.peak_binary_gops()
            );
        }
    }

    #[test]
    fn all_valid() {
        for (id, c) in all_instances() {
            c.validate().unwrap_or_else(|e| panic!("instance {id}: {e}"));
        }
    }

    #[test]
    fn buffers_hold_meaningful_tiles() {
        // Each instance must at least hold two bit-planes of an
        // 8-row × 4096-bit tile per buffer for double buffering.
        for (_, c) in all_instances() {
            assert!(c.lhs_buf_bits() >= 2 * 4096);
        }
    }

    #[test]
    fn unknown_instance_is_a_typed_error() {
        match try_instance(7) {
            Err(BismoError::InvalidConfig(msg)) => {
                assert!(msg.contains("instances 1..=6"), "{msg}")
            }
            other => panic!("expected InvalidConfig, got {other:?}"),
        }
        for id in 1..=6 {
            assert!(try_instance(id).is_ok());
        }
    }

    #[test]
    #[should_panic(expected = "instances 1..=6")]
    fn unknown_instance_panics() {
        instance(7);
    }

    #[test]
    fn bram_within_board_budget() {
        // The BRAM cost of every preset must fit the Z7020's 140 BRAMs.
        // (Uses the raw Eq. 2 array term; full model checked in costmodel.)
        for (id, c) in all_instances() {
            let lanes = (c.dk as u64 + 31) / 32;
            let bm_t = (c.bm as u64 * c.dk as u64 / c.dk as u64 + 1023) / 1024;
            let bn_t = (c.bn as u64 + 1023) / 1024;
            let array = lanes * (c.dm as u64 * bm_t + c.dn as u64 * bn_t);
            assert!(
                PYNQ_Z1.brams >= array,
                "instance {id} BRAM array cost {array} exceeds board budget"
            );
        }
    }
}
