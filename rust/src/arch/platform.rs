//! Target platform description. The paper evaluates on the Xilinx
//! PYNQ-Z1 board (Zynq Z7020 SoC); the cost model checks resource budgets
//! against it and the simulator takes its DRAM bandwidth from it.

/// An FPGA platform: resource budget + memory system.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Platform {
    /// Human-readable name.
    pub name: &'static str,
    /// Number of 6-input LUTs available.
    pub luts: u64,
    /// Number of 36-kbit BRAM blocks available.
    pub brams: u64,
    /// Peak DRAM bandwidth in bytes/second (shared across channels).
    pub dram_bandwidth_bps: u64,
    /// Width of one DRAM channel port in bits (AXI HP port on Zynq).
    pub dram_channel_bits: u32,
    /// DRAM read latency in accelerator cycles (DMA request to first
    /// beat). Modelled as a constant; real Zynq HP-port latency varies
    /// ~20–40 fabric cycles.
    pub dram_latency_cycles: u64,
}

/// The board used throughout the paper's evaluation: PYNQ-Z1 with a
/// Z7020 (53,200 LUTs, 140 BRAMs) and 3.2 GB/s of DRAM bandwidth.
pub const PYNQ_Z1: Platform = Platform {
    name: "PYNQ-Z1 (Xilinx Z7020)",
    luts: 53_200,
    brams: 140,
    dram_bandwidth_bps: 3_200_000_000,
    dram_channel_bits: 64,
    dram_latency_cycles: 32,
};

impl Platform {
    /// Does a (LUT, BRAM) requirement fit this device?
    pub fn fits(&self, luts: u64, brams: u64) -> bool {
        luts <= self.luts && brams <= self.brams
    }

    /// Utilization fractions for reporting (LUT, BRAM).
    pub fn utilization(&self, luts: u64, brams: u64) -> (f64, f64) {
        (
            luts as f64 / self.luts as f64,
            brams as f64 / self.brams as f64,
        )
    }

    /// Maximum bytes/cycle one DMA channel can move at `fclk_mhz`,
    /// accounting for the board-level DRAM bandwidth cap shared by all
    /// channels.
    pub fn channel_bytes_per_cycle(&self, fclk_mhz: u32, channel_bits: u32) -> f64 {
        let channel = channel_bits as f64 / 8.0;
        let board_cap = self.dram_bandwidth_bps as f64 / (fclk_mhz as f64 * 1e6);
        channel.min(board_cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pynq_budget() {
        assert!(PYNQ_Z1.fits(53_200, 140));
        assert!(!PYNQ_Z1.fits(53_201, 140));
        assert!(!PYNQ_Z1.fits(100, 141));
    }

    #[test]
    fn utilization_fractions() {
        let (l, b) = PYNQ_Z1.utilization(26_600, 70);
        assert!((l - 0.5).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn channel_rate_caps_at_board_bandwidth() {
        // At 200 MHz a 64-bit channel wants 8 B/cycle = 1.6 GB/s < 3.2 GB/s cap.
        let r = PYNQ_Z1.channel_bytes_per_cycle(200, 64);
        assert!((r - 8.0).abs() < 1e-9);
        // A hypothetical 512-bit channel at 200 MHz would want 12.8 GB/s,
        // capped to 3.2 GB/s = 16 B/cycle.
        let r = PYNQ_Z1.channel_bytes_per_cycle(200, 512);
        assert!((r - 16.0).abs() < 1e-9);
    }
}
