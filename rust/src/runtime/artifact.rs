//! Artifact manifest: what `python -m compile.aot` exported.

use crate::api::BismoError;
use crate::util::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Declared shape/dtype of one artifact input.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One exported computation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<InputSpec>,
}

/// The parsed `manifest.json`.
#[derive(Clone, Debug, Default)]
pub struct ArtifactManifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ArtifactManifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self, BismoError> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath).map_err(|e| {
            BismoError::Io(format!(
                "reading {}: {e} (run `make artifacts` first)",
                mpath.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text with artifact paths relative to `dir`.
    pub fn parse(text: &str, dir: &Path) -> Result<Self, BismoError> {
        let bad = |m: String| BismoError::Parse(m);
        let j = Json::parse(text).map_err(|e| bad(format!("manifest.json: {e}")))?;
        let obj = j
            .as_obj()
            .ok_or_else(|| bad("manifest root must be an object".into()))?;
        let mut artifacts = BTreeMap::new();
        for (name, entry) in obj {
            let file = entry
                .get("file")
                .and_then(|f| f.as_str())
                .ok_or_else(|| bad(format!("{name}: missing file")))?;
            let inputs = entry
                .get("inputs")
                .and_then(|i| i.as_arr())
                .ok_or_else(|| bad(format!("{name}: missing inputs")))?
                .iter()
                .map(|spec| {
                    let shape = spec
                        .get("shape")
                        .and_then(|s| s.as_arr())
                        .ok_or_else(|| bad(format!("{name}: input missing shape")))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| bad(format!("{name}: bad dim"))))
                        .collect::<Result<Vec<_>, _>>()?;
                    let dtype = spec
                        .get("dtype")
                        .and_then(|d| d.as_str())
                        .unwrap_or("int32")
                        .to_string();
                    Ok(InputSpec { shape, dtype })
                })
                .collect::<Result<Vec<_>, BismoError>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    path: dir.join(file),
                    inputs,
                },
            );
        }
        Ok(ArtifactManifest { artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec, BismoError> {
        self.artifacts.get(name).ok_or_else(|| {
            BismoError::Parse(format!(
                "artifact {name:?} not in manifest (have: {:?})",
                self.artifacts.keys().collect::<Vec<_>>()
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "bitserial_matmul_8x2048x8_w2a2_uu": {
        "file": "bitserial_matmul_8x2048x8_w2a2_uu.hlo.txt",
        "inputs": [
          {"shape": [8, 2048], "dtype": "int32"},
          {"shape": [2048, 8], "dtype": "int32"}
        ]
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        let a = m.get("bitserial_matmul_8x2048x8_w2a2_uu").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].shape, vec![8, 2048]);
        assert_eq!(a.inputs[0].elements(), 16384);
        assert_eq!(a.inputs[1].dtype, "int32");
        assert!(a.path.ends_with("bitserial_matmul_8x2048x8_w2a2_uu.hlo.txt"));
    }

    #[test]
    fn missing_artifact_reported() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp")).unwrap();
        assert!(m
            .get("nope")
            .unwrap_err()
            .to_string()
            .contains("not in manifest"));
    }

    #[test]
    fn real_manifest_if_built() {
        // If `make artifacts` has run, the real manifest must parse and
        // contain the expected entries.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = ArtifactManifest::load(&dir).unwrap();
            assert!(m.get("qnn_mlp_b16_w4a2").is_ok());
            assert!(m.get("bitserial_matmul_64x256x64_w4a4_ss").is_ok());
        }
    }
}
