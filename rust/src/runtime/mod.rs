//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The compile path (`make artifacts`) lowers the L2 model to HLO text;
//! this module loads `artifacts/*.hlo.txt` through the `xla` crate's
//! PJRT CPU client, compiles each module once, and exposes typed
//! execution — the only place Python-born compute is touched, and it is
//! touched as a binary artifact. Interchange is HLO *text*: jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.

mod artifact;
mod executor;

pub use artifact::{ArtifactManifest, ArtifactSpec, InputSpec};
pub use executor::{Executable, Runtime};
