//! PJRT CPU client wrapper: compile HLO-text artifacts once, execute
//! many times from the request path.

use super::artifact::{ArtifactManifest, ArtifactSpec};
use crate::bitmatrix::IntMatrix;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// The runtime: one PJRT CPU client + a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

/// A compiled computation bound to its input contract.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl Runtime {
    /// Connect to the CPU PJRT plugin and read the artifact manifest.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = ArtifactManifest::load(artifact_dir).map_err(|e| anyhow!(e))?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// Load (and cache) a compiled executable by artifact name.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name).map_err(|e| anyhow!(e))?.clone();
        let proto = xla::HloModuleProto::from_text_file(
            spec.path
                .to_str()
                .ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", spec.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let arc = std::sync::Arc::new(Executable { exe, spec });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), arc.clone());
        Ok(arc)
    }
}

impl Executable {
    /// Execute with i32 matrices (row-major), returning the first tuple
    /// element as an [`IntMatrix`] of the given output shape.
    pub fn run_i32(&self, inputs: &[&IntMatrix]) -> Result<IntMatrix> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "artifact {} wants {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (m, spec) in inputs.iter().zip(&self.spec.inputs) {
            if spec.shape != [m.rows, m.cols] {
                bail!(
                    "artifact {} input shape {:?} != matrix {}x{}",
                    self.spec.name,
                    spec.shape,
                    m.rows,
                    m.cols
                );
            }
            if spec.dtype != "int32" {
                bail!("run_i32 on non-int32 input ({})", spec.dtype);
            }
            let v: Vec<i32> = m.data().iter().map(|&x| x as i32).collect();
            lits.push(
                xla::Literal::vec1(&v)
                    .reshape(&[m.rows as i64, m.cols as i64])
                    .context("reshaping literal")?,
            );
        }
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let dims: Vec<usize> = out
            .array_shape()
            .context("result shape")?
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect();
        if dims.len() != 2 {
            bail!("expected rank-2 result, got {dims:?}");
        }
        let data: Vec<i64> = out
            .to_vec::<i32>()
            .context("reading i32 result")?
            .into_iter()
            .map(|x| x as i64)
            .collect();
        Ok(IntMatrix::from_slice(dims[0], dims[1], &data))
    }

    /// Execute with packed uint32 planes (popcount-form artifact).
    pub fn run_u32_pair(
        &self,
        a: (&[u32], [usize; 2]),
        b: (&[u32], [usize; 2]),
    ) -> Result<IntMatrix> {
        let mk = |(data, shape): (&[u32], [usize; 2])| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(data).reshape(&[shape[0] as i64, shape[1] as i64])?)
        };
        let lits = [mk(a)?, mk(b)?];
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let dims: Vec<usize> = out
            .array_shape()?
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect();
        let data: Vec<i64> = out
            .to_vec::<i32>()?
            .into_iter()
            .map(|x| x as i64)
            .collect();
        Ok(IntMatrix::from_slice(dims[0], dims[1], &data))
    }
}

#[cfg(test)]
mod tests {
    // PJRT-backed tests live in rust/tests/runtime_integration.rs: they
    // need built artifacts and a working libxla_extension, which unit
    // tests must not assume.
}
