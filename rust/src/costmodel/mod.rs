//! The BISMO hardware cost model (paper §III-B, Eqs 1–2).
//!
//! ```text
//! LUT_total  = LUT_base + D_m·D_n·(LUT_DPU + LUT_res)      (1a–b)
//! LUT_DPU    = α_DPU·D_k + β_DPU                           (1c)
//! BRAM_total = BRAM_base
//!            + ceil(D_k/32)·(D_m·ceil(B_m/1024) + D_n·ceil(B_n/1024))  (2)
//! ```
//!
//! Two sets of constants are provided: [`CostModel::paper`] uses the
//! values the authors fitted from Vivado synthesis (α=2.04, β=109.41,
//! LUT_base=718, LUT_res=120.1), and [`CostModel::fit_from_synth`]
//! re-derives them by least squares over this crate's virtual-synthesis
//! sweep — the same procedure the paper used, applied to our substrate
//! (DESIGN.md §Substitutions). Fig. 8/9's "actual" values come from
//! [`crate::synth::synth_instance`].

pub mod fit;
pub mod tune;

pub use fit::{least_squares, linear_fit};
pub use tune::{
    load_host_profile, profile_dir, tune_host, ClassTuning, CpuFingerprint, ShapeClass, SwFit,
    TuneConfig, TuneOutcome, TunedProfile,
};

use crate::api::BismoError;
use crate::arch::{BismoConfig, Platform, PYNQ_Z1};
use crate::partition::{GemmShape, ShardPlan};
use crate::synth::{synth_dpu, synth_instance};
use crate::util::ceil_div;

/// LUT/BRAM cost model constants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostModel {
    /// LUTs per popcount input bit in a DPU (Eq. 1c slope).
    pub alpha_dpu: f64,
    /// Fixed per-DPU LUTs: shifter, negator, accumulator (Eq. 1c offset).
    pub beta_dpu: f64,
    /// DPA-size-independent LUTs: DMA engines etc. (Eq. 1a).
    pub lut_base: f64,
    /// Per-DPU result-generation LUTs (Eq. 1b).
    pub lut_res: f64,
    /// DPA-size-independent BRAMs (Eq. 2a).
    pub bram_base: u64,
}

impl CostModel {
    /// Constants as fitted in the paper (§IV-A).
    pub fn paper() -> Self {
        CostModel {
            alpha_dpu: 2.04,
            beta_dpu: 109.41,
            lut_base: 718.0,
            lut_res: 120.1,
            bram_base: 1,
        }
    }

    /// Re-fit α/β from this crate's virtual synthesis over the Fig. 7
    /// `D_k` sweep, keeping the stage characterizations for base/res.
    pub fn fit_from_synth() -> Self {
        let dks = [32u32, 64, 128, 256, 512, 1024];
        let xs: Vec<f64> = dks.iter().map(|&d| d as f64).collect();
        let ys: Vec<f64> = dks.iter().map(|&d| synth_dpu(d, 32).luts).collect();
        let (alpha, beta) =
            linear_fit(&xs, &ys).expect("synthesis sweep is well-conditioned");
        CostModel {
            alpha_dpu: alpha,
            beta_dpu: beta,
            lut_base: 718.0,
            lut_res: 120.1,
            bram_base: 1,
        }
    }

    /// Eq. 1c: LUTs of one DPU.
    pub fn lut_dpu(&self, dk: u32) -> f64 {
        self.alpha_dpu * dk as f64 + self.beta_dpu
    }

    /// Eq. 1b: DPA-size-dependent LUTs.
    pub fn lut_array(&self, cfg: &BismoConfig) -> f64 {
        (cfg.dm * cfg.dn) as f64 * (self.lut_dpu(cfg.dk) + self.lut_res)
    }

    /// Eq. 1a: total LUTs.
    pub fn lut_total(&self, cfg: &BismoConfig) -> f64 {
        self.lut_base + self.lut_array(cfg)
    }

    /// Eq. 2b: matrix-buffer BRAMs.
    pub fn bram_array(&self, cfg: &BismoConfig) -> u64 {
        ceil_div(cfg.dk as u64, 32)
            * (cfg.dm as u64 * ceil_div(cfg.bm as u64, 1024)
                + cfg.dn as u64 * ceil_div(cfg.bn as u64, 1024))
    }

    /// Eq. 2a: total BRAMs.
    pub fn bram_total(&self, cfg: &BismoConfig) -> u64 {
        self.bram_base + self.bram_array(cfg)
    }

    /// Does `cfg` fit on `platform` under this model?
    pub fn fits(&self, cfg: &BismoConfig, platform: &Platform) -> bool {
        platform.fits(self.lut_total(cfg).round() as u64, self.bram_total(cfg))
    }
}

/// A LUT/BRAM resource budget for multi-instance selection — the
/// fabric (or fabric share) that [`select_sharding`] may fill with
/// overlay instances, each costed by Eqs 1–2.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResourceBudget {
    pub luts: u64,
    pub brams: u64,
}

impl ResourceBudget {
    /// The whole resource budget of a platform.
    pub fn of_platform(p: &Platform) -> ResourceBudget {
        ResourceBudget {
            luts: p.luts,
            brams: p.brams,
        }
    }

    /// A synthetic platform with this budget and the PYNQ-Z1 memory
    /// system — what the simulator backend runs auto-sharded instances
    /// against.
    pub fn as_platform(&self) -> Platform {
        Platform {
            name: "sharding budget",
            luts: self.luts,
            brams: self.brams,
            ..PYNQ_Z1
        }
    }
}

/// Outcome of cost-model-driven shard selection: how many instances to
/// run, how the output grid splits across them, and the per-instance
/// overlay configuration.
#[derive(Clone, Copy, Debug)]
pub struct ShardingChoice {
    /// Number of shards (= overlay instances) to run in parallel.
    pub shards: usize,
    /// Output split: `grid.0` row shards × `grid.1` column shards.
    pub grid: (usize, usize),
    /// The per-instance configuration (every instance identical).
    pub config: BismoConfig,
    /// Eq. 1 prediction for one instance.
    pub luts_per_instance: f64,
    /// Eq. 2 prediction for one instance.
    pub brams_per_instance: u64,
    /// Aggregate predictions across all instances.
    pub total_luts: f64,
    pub total_brams: u64,
    /// Aggregate peak binary GOPS across all instances.
    pub peak_gops: f64,
}

/// Upper bound on the shard counts [`select_sharding`] considers.
pub const MAX_SHARDS: usize = 16;

/// Pick a shard count and per-shard instance configuration for `shape`
/// under `budget` — the paper's §III-B scaling argument made
/// operational: Eqs 1–2 price each candidate configuration, the budget
/// caps how many replicas fit, and the expected throughput of the
/// resulting [`ShardPlan`] (aggregate peak, discounted for shards
/// smaller than the `D_m × D_n` array) scores the combination.
///
/// Deterministic; ties prefer fewer shards, then fewer total LUTs.
/// Errs with [`BismoError::CapacityExceeded`] when no candidate
/// instance fits the budget at all.
pub fn select_sharding(
    model: &CostModel,
    shape: &GemmShape,
    budget: ResourceBudget,
) -> Result<ShardingChoice, BismoError> {
    if shape.m == 0 || shape.n == 0 {
        return Err(BismoError::InvalidConfig(
            "cannot shard an empty output (m and n must be non-zero)".into(),
        ));
    }
    let mut best: Option<(f64, ShardingChoice)> = None;
    for &dm in &[2u32, 4, 8] {
        for &dn in &[2u32, 4, 8] {
            for &dk in &[64u32, 128, 256] {
                let cfg = BismoConfig {
                    dm,
                    dk,
                    dn,
                    bm: 1024,
                    bn: 1024,
                    ..BismoConfig::small()
                };
                if cfg.validate().is_err() {
                    continue;
                }
                let luts = model.lut_total(&cfg);
                let brams = model.bram_total(&cfg);
                if luts > budget.luts as f64 || brams > budget.brams {
                    continue;
                }
                let replicas = ((budget.luts as f64 / luts) as usize)
                    .min((budget.brams / brams) as usize)
                    .clamp(1, MAX_SHARDS);
                for want in 1..=replicas {
                    let plan = ShardPlan::for_instances(shape.m, shape.n, want);
                    let shards = plan.count();
                    // Aggregate throughput: each shard's peak, discounted
                    // by how much of the DPA its output block can keep
                    // busy (a shard smaller than the array wastes DPUs).
                    let mut utilization = 0.0;
                    for s in plan.shards() {
                        utilization += (s.rows.len().min(dm as usize) as f64 / dm as f64)
                            * (s.cols.len().min(dn as usize) as f64 / dn as f64);
                    }
                    let score = utilization * cfg.peak_binary_gops();
                    let choice = ShardingChoice {
                        shards,
                        grid: (plan.rows.count(), plan.cols.count()),
                        config: cfg,
                        luts_per_instance: luts,
                        brams_per_instance: brams,
                        total_luts: luts * shards as f64,
                        total_brams: brams * shards as u64,
                        peak_gops: cfg.peak_binary_gops() * shards as f64,
                    };
                    let better = match &best {
                        None => true,
                        Some((bs, bc)) => {
                            score > *bs + 1e-9
                                || ((score - *bs).abs() <= 1e-9
                                    && (choice.shards < bc.shards
                                        || (choice.shards == bc.shards
                                            && choice.total_luts < bc.total_luts - 1e-9)))
                        }
                    };
                    if better {
                        best = Some((score, choice));
                    }
                }
            }
        }
    }
    best.map(|(_, c)| c).ok_or_else(|| {
        BismoError::CapacityExceeded(format!(
            "budget ({} LUTs, {} BRAMs) fits no overlay instance",
            budget.luts, budget.brams
        ))
    })
}

/// One Fig. 8 validation point: model prediction vs virtual synthesis.
#[derive(Clone, Copy, Debug)]
pub struct ValidationPoint {
    pub dm: u32,
    pub dk: u32,
    pub dn: u32,
    pub predicted_luts: f64,
    pub actual_luts: f64,
    pub predicted_brams: u64,
    pub actual_brams: u64,
}

impl ValidationPoint {
    /// Relative LUT error (signed; positive = overestimate).
    pub fn lut_error(&self) -> f64 {
        (self.predicted_luts - self.actual_luts) / self.actual_luts
    }

    /// Prediction accuracy as the paper reports it (1 − |rel. error|).
    pub fn lut_accuracy(&self) -> f64 {
        1.0 - self.lut_error().abs()
    }
}

/// The paper's 34-design validation sweep (Fig. 8/9): every
/// `(D_m=D_n ∈ {2,4,8}) × (D_k ∈ {64,128,256})`-ish grid point from
/// (2,64,2) to (8,256,8), evaluated against virtual synthesis.
pub fn validation_sweep(model: &CostModel) -> Vec<ValidationPoint> {
    let mut out = Vec::new();
    for &dm in &[2u32, 4, 8] {
        for &dn in &[2u32, 4, 8] {
            for &dk in &[64u32, 128, 256] {
                // Match the paper's range: (2,64,2) .. (8,256,8), and
                // include the asymmetric shapes.
                let cfg = BismoConfig {
                    dm,
                    dk,
                    dn,
                    bm: 1024,
                    bn: 1024,
                    ..BismoConfig::small()
                };
                let s = synth_instance(&cfg);
                out.push(ValidationPoint {
                    dm,
                    dk,
                    dn,
                    predicted_luts: model.lut_total(&cfg),
                    actual_luts: s.total_luts,
                    predicted_brams: model.bram_total(&cfg),
                    actual_brams: s.brams,
                });
            }
        }
    }
    // 27 symmetric+asymmetric points; add 7 extra D_k=32/512 shapes to
    // reach the paper's 34 designs.
    for &(dm, dk, dn) in &[
        (2u32, 32u32, 2u32),
        (4, 32, 4),
        (8, 32, 8),
        (2, 512, 2),
        (4, 512, 4),
        (2, 128, 8),
        (8, 128, 2),
    ] {
        let cfg = BismoConfig {
            dm,
            dk,
            dn,
            bm: 1024,
            bn: 1024,
            ..BismoConfig::small()
        };
        let s = synth_instance(&cfg);
        out.push(ValidationPoint {
            dm,
            dk,
            dn,
            predicted_luts: model.lut_total(&cfg),
            actual_luts: s.total_luts,
            predicted_brams: model.bram_total(&cfg),
            actual_brams: s.brams,
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{instance, PYNQ_Z1};

    #[test]
    fn paper_constants_reproduce_table4_scale() {
        // With the paper's constants, Table IV LUT counts come out
        // within ~25% — note the paper's own Eq. 1 applied to its own
        // Table IV rows shows the same gap (e.g. instance #1: predicted
        // 23.8k vs measured 19.5k, +22%), the full-build optimization
        // effect Fig. 9 discusses.
        let m = CostModel::paper();
        let expect = [19545.0, 27740.0, 45573.0, 13352.0, 24202.0, 21755.0];
        for (i, &e) in expect.iter().enumerate() {
            let cfg = instance(i as u32 + 1);
            let got = m.lut_total(&cfg);
            let rel = (got - e).abs() / e;
            assert!(
                rel < 0.25,
                "instance {}: model {got:.0} vs paper {e} ({:.0}%)",
                i + 1,
                rel * 100.0
            );
        }
    }

    #[test]
    fn fitted_constants_near_paper() {
        let m = CostModel::fit_from_synth();
        assert!(
            (1.6..=2.5).contains(&m.alpha_dpu),
            "alpha {} vs paper 2.04",
            m.alpha_dpu
        );
        assert!(
            (60.0..=220.0).contains(&m.beta_dpu),
            "beta {} vs paper 109.41",
            m.beta_dpu
        );
    }

    #[test]
    fn validation_sweep_accuracy() {
        // Paper: 93.8% average accuracy; our fitted model against our
        // virtual synthesis should be at least 90%.
        let m = CostModel::fit_from_synth();
        let pts = validation_sweep(&m);
        assert_eq!(pts.len(), 34);
        let mean_acc: f64 =
            pts.iter().map(|p| p.lut_accuracy()).sum::<f64>() / pts.len() as f64;
        assert!(mean_acc > 0.90, "mean accuracy {:.3}", mean_acc);
    }

    #[test]
    fn bram_predictions_exact_on_sweep() {
        // Paper: "BRAM predictions were 100% accurate".
        let m = CostModel::fit_from_synth();
        for p in validation_sweep(&m) {
            assert_eq!(p.predicted_brams, p.actual_brams);
        }
    }

    #[test]
    fn small_designs_overestimated() {
        // Fig. 9's observation: the model overestimates small designs.
        let m = CostModel::fit_from_synth();
        let pts = validation_sweep(&m);
        let small: Vec<&ValidationPoint> =
            pts.iter().filter(|p| p.dm == 2 && p.dn == 2).collect();
        let large: Vec<&ValidationPoint> =
            pts.iter().filter(|p| p.dm == 8 && p.dn == 8).collect();
        let err = |v: &[&ValidationPoint]| {
            v.iter().map(|p| p.lut_error().abs()).sum::<f64>() / v.len() as f64
        };
        assert!(
            err(&small) > err(&large),
            "small {:.3} vs large {:.3}",
            err(&small),
            err(&large)
        );
    }

    #[test]
    fn instances_fit_pynq() {
        let m = CostModel::paper();
        for (id, cfg) in crate::arch::all_instances() {
            assert!(m.fits(&cfg, &PYNQ_Z1), "instance {id} should fit Z7020");
        }
    }

    #[test]
    fn sharding_on_pynq_prefers_one_big_instance() {
        // A single Z7020 affords one large array or a couple of small
        // ones; for a big job the single 8×256×8 instance wins on
        // aggregate peak (Eq. 1 prices two half-arrays above one full).
        let m = CostModel::paper();
        let shape = GemmShape {
            m: 512,
            k: 4096,
            n: 512,
        };
        let c = select_sharding(&m, &shape, ResourceBudget::of_platform(&PYNQ_Z1)).unwrap();
        assert_eq!(c.shards, 1, "{c:?}");
        assert_eq!((c.config.dm, c.config.dk, c.config.dn), (8, 256, 8));
        assert!(c.total_luts <= PYNQ_Z1.luts as f64);
        assert!(c.total_brams <= PYNQ_Z1.brams);
    }

    #[test]
    fn doubling_the_budget_buys_more_instances() {
        let m = CostModel::paper();
        let shape = GemmShape {
            m: 512,
            k: 4096,
            n: 512,
        };
        let single = ResourceBudget::of_platform(&PYNQ_Z1);
        let double = ResourceBudget {
            luts: single.luts * 2,
            brams: single.brams * 2,
        };
        let c1 = select_sharding(&m, &shape, single).unwrap();
        let c2 = select_sharding(&m, &shape, double).unwrap();
        assert!(c2.shards > c1.shards, "{c1:?} vs {c2:?}");
        assert!(c2.peak_gops > c1.peak_gops);
        assert!(c2.total_luts <= double.luts as f64);
        assert!(c2.total_brams <= double.brams);
        assert_eq!(c2.grid.0 * c2.grid.1, c2.shards);
    }

    #[test]
    fn tiny_jobs_are_not_oversharded() {
        // A 2×2 output cannot keep more DPUs busy by splitting: the
        // utilization discount makes extra shards worthless, so the
        // tie-break lands on a single small instance.
        let m = CostModel::paper();
        let shape = GemmShape { m: 2, k: 64, n: 2 };
        let budget = ResourceBudget {
            luts: PYNQ_Z1.luts * 4,
            brams: PYNQ_Z1.brams * 4,
        };
        let c = select_sharding(&m, &shape, budget).unwrap();
        assert_eq!(c.shards, 1, "{c:?}");
    }

    #[test]
    fn impossible_budget_is_capacity_exceeded() {
        let m = CostModel::paper();
        let shape = GemmShape {
            m: 64,
            k: 64,
            n: 64,
        };
        let r = select_sharding(&m, &shape, ResourceBudget { luts: 100, brams: 1 });
        assert!(matches!(r, Err(BismoError::CapacityExceeded(_))), "{r:?}");
    }
}
