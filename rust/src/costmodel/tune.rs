//! Closed-loop autotuning: measured tile/shard search plus cost-model
//! calibration, persisted as per-machine profiles.
//!
//! The paper prices configurations analytically (Eqs 1–2) and the
//! journal follow-up (Umuroglu et al., 2019) shows those predictions
//! only become actionable once calibrated against measurements. This
//! module is that loop for the software port:
//!
//! 1. **Measure** — [`tune_host`] benchmarks candidate
//!    [`KernelConfig`] tile shapes (`tile_m × tile_n × tile_k`) and
//!    [`ShardPlan`] instance counts on the actual host, across one
//!    representative workload per [`ShapeClass`]. Every candidate is
//!    verified bit-exact against the [`gemm_bitserial`] oracle *before*
//!    its timing counts — a fast-but-wrong configuration must be
//!    impossible to persist.
//! 2. **Fit** — the hardware cost model is re-fitted from the virtual
//!    synthesis sweep ([`CostModel::fit_from_synth`], the paper's own
//!    procedure) and a software-side linear cost `ns ≈ ns_per_op ·
//!    binary_ops + ns_base` is fitted over the measured best times via
//!    [`linear_fit`](super::fit::linear_fit).
//! 3. **Persist** — the result is a [`TunedProfile`] JSON file,
//!    content-addressed by CPU identity ([`CpuFingerprint`]: detected
//!    [`DispatchTier`] + core count). [`crate::api::Session`] loads the
//!    host's profile at startup (see [`load_host_profile`]), so kernel
//!    tile selection and `Sharding::Auto` pick from measured data; any
//!    missing, corrupt, or foreign-machine profile falls back to the
//!    analytical defaults.
//!
//! The profile directory is `tuned/` under the working directory, or
//! `$BISMO_TUNE_DIR` when set. Corrupt or fingerprint-mismatched files
//! are typed [`BismoError::Parse`] errors from the explicit loaders;
//! the implicit session-startup path swallows them into the fallback.

use super::CostModel;
use crate::api::BismoError;
use crate::baseline::{binary_ops, gemm_bitserial};
use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
use crate::kernel::{gemm_tiled_block, gemm_tiled_with, KernelConfig, WorkerPool};
use crate::partition::{GemmShape, ShardPlan};
use crate::simd::DispatchTier;
use crate::util::{BenchTimer, Json, Rng};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Profile file schema identifier; bumped on breaking layout changes.
pub const PROFILE_SCHEMA: &str = "bismo-tune-profile/v1";

/// Environment variable overriding the profile directory.
pub const TUNE_DIR_ENV: &str = "BISMO_TUNE_DIR";

/// The default profile directory (relative to the working directory).
pub const TUNE_DIR_DEFAULT: &str = "tuned";

/// Coarse GEMM shape classes the tuner sweeps — tile preferences are
/// driven by aspect ratio and depth far more than by exact sizes, so a
/// handful of classes covers the request space without a per-shape
/// database.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShapeClass {
    /// Tiny outputs (`m·n ≤ 256`): tiling overhead dominates.
    Small,
    /// Roughly square outputs at moderate depth.
    Square,
    /// Many more output rows than columns (`m ≥ 4n`).
    Tall,
    /// Many more output columns than rows (`n ≥ 4m`).
    Wide,
    /// Inner dimension dwarfs the output (`k > 8·max(m,n)`).
    Deep,
}

/// All classes, in sweep order.
pub const SHAPE_CLASSES: [ShapeClass; 5] = [
    ShapeClass::Small,
    ShapeClass::Square,
    ShapeClass::Tall,
    ShapeClass::Wide,
    ShapeClass::Deep,
];

impl ShapeClass {
    /// Stable lowercase name (profile files, bench reports).
    pub fn name(&self) -> &'static str {
        match self {
            ShapeClass::Small => "small",
            ShapeClass::Square => "square",
            ShapeClass::Tall => "tall",
            ShapeClass::Wide => "wide",
            ShapeClass::Deep => "deep",
        }
    }

    /// Inverse of [`ShapeClass::name`].
    pub fn parse(s: &str) -> Result<ShapeClass, BismoError> {
        SHAPE_CLASSES
            .into_iter()
            .find(|c| c.name() == s)
            .ok_or_else(|| BismoError::Parse(format!("unknown shape class {s:?}")))
    }

    /// Classify a request shape. Total order: tiny outputs are Small
    /// regardless of aspect; then depth beats aspect; then aspect.
    pub fn classify(shape: &GemmShape) -> ShapeClass {
        let (m, k, n) = (shape.m, shape.k, shape.n);
        if m * n <= 256 {
            ShapeClass::Small
        } else if k > 8 * m.max(n) {
            ShapeClass::Deep
        } else if m >= 4 * n {
            ShapeClass::Tall
        } else if n >= 4 * m {
            ShapeClass::Wide
        } else {
            ShapeClass::Square
        }
    }
}

impl std::fmt::Display for ShapeClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// What makes one machine's measurements transferable to another:
/// the resolved SIMD tier and the core count. Profiles are
/// content-addressed by this pair — a profile tuned on an AVX-512
/// 32-core box is rejected (typed, with fallback) on a NEON laptop.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CpuFingerprint {
    /// Resolved [`DispatchTier`] name (`"avx2"`, `"scalar"`, ...) —
    /// honors the `BISMO_SIMD` override, so a forced-scalar run tunes
    /// (and later loads) a scalar profile.
    pub simd_tier: String,
    /// Available hardware parallelism.
    pub cores: usize,
}

impl CpuFingerprint {
    /// Detect this host's fingerprint.
    pub fn detect() -> Result<CpuFingerprint, BismoError> {
        Ok(CpuFingerprint {
            simd_tier: DispatchTier::resolve()?.name().to_string(),
            cores: std::thread::available_parallelism().map_or(1, |n| n.get()),
        })
    }

    /// The content-address: `"<tier>-<cores>c"`, used in the profile
    /// filename and echoed by `bismo info`.
    pub fn key(&self) -> String {
        format!("{}-{}c", self.simd_tier, self.cores)
    }
}

/// Measured software cost fit: `ns ≈ ns_per_op · binary_ops + ns_base`
/// over the per-class best configurations.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SwFit {
    pub ns_per_op: f64,
    pub ns_base: f64,
}

impl SwFit {
    /// Predicted wall time for a workload of `ops` binary operations.
    pub fn predict_ns(&self, ops: u64) -> f64 {
        self.ns_per_op * ops as f64 + self.ns_base
    }
}

/// The winning configuration for one shape class.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClassTuning {
    pub class: ShapeClass,
    /// Best-measured tile geometry (verified bit-exact before timing).
    pub tile: KernelConfig,
    /// Best-measured shard count (1 = no sharding won).
    pub shards: usize,
    /// Shard grid behind `shards` (`rows × cols`).
    pub grid: (usize, usize),
    /// Throughput of the winning configuration (binary GOPS).
    pub measured_gops: f64,
    /// Throughput of the analytical default on the same workload.
    pub default_gops: f64,
}

/// A persisted per-machine tuning profile: the measured tile/shard
/// picks per shape class, the re-fitted hardware cost model, and the
/// measured software cost fit, all keyed by [`CpuFingerprint`].
#[derive(Clone, Debug, PartialEq)]
pub struct TunedProfile {
    pub fingerprint: CpuFingerprint,
    /// Measured-constant replacement for [`CostModel::paper`] — what
    /// `Sharding::Auto` scores candidates with when this profile is
    /// loaded.
    pub cost_model: CostModel,
    pub sw_fit: SwFit,
    pub classes: Vec<ClassTuning>,
    /// Unix seconds at tuning time (provenance only; never compared).
    pub generated_unix: u64,
}

impl TunedProfile {
    /// The content-address of this profile (its fingerprint's key).
    pub fn key(&self) -> String {
        self.fingerprint.key()
    }

    /// The measured tile geometry for `shape`'s class, if tuned.
    pub fn tile_for(&self, shape: &GemmShape) -> Option<KernelConfig> {
        let class = ShapeClass::classify(shape);
        self.classes.iter().find(|c| c.class == class).map(|c| c.tile)
    }

    /// Serialize to the `bismo-tune-profile/v1` JSON document.
    pub fn to_json(&self) -> Json {
        let mut fp = BTreeMap::new();
        fp.insert("simd_tier".into(), Json::str(&self.fingerprint.simd_tier));
        fp.insert("cores".into(), Json::num(self.fingerprint.cores as f64));
        let mut cm = BTreeMap::new();
        cm.insert("alpha_dpu".into(), Json::num(self.cost_model.alpha_dpu));
        cm.insert("beta_dpu".into(), Json::num(self.cost_model.beta_dpu));
        cm.insert("lut_base".into(), Json::num(self.cost_model.lut_base));
        cm.insert("lut_res".into(), Json::num(self.cost_model.lut_res));
        cm.insert("bram_base".into(), Json::num(self.cost_model.bram_base as f64));
        let mut sw = BTreeMap::new();
        sw.insert("ns_per_op".into(), Json::num(self.sw_fit.ns_per_op));
        sw.insert("ns_base".into(), Json::num(self.sw_fit.ns_base));
        let classes: Vec<Json> = self
            .classes
            .iter()
            .map(|c| {
                let mut o = BTreeMap::new();
                o.insert("class".into(), Json::str(c.class.name()));
                o.insert("tile_m".into(), Json::num(c.tile.tile_m as f64));
                o.insert("tile_n".into(), Json::num(c.tile.tile_n as f64));
                // `usize::MAX` ("stream whole k") has no faithful f64;
                // 0 is illegal as a real tile size, so it is the
                // on-disk sentinel for "unchunked".
                o.insert("tile_k".into(), Json::num(tile_k_to_disk(c.tile.tile_k)));
                o.insert("shards".into(), Json::num(c.shards as f64));
                o.insert("grid_rows".into(), Json::num(c.grid.0 as f64));
                o.insert("grid_cols".into(), Json::num(c.grid.1 as f64));
                o.insert("measured_gops".into(), Json::num(c.measured_gops));
                o.insert("default_gops".into(), Json::num(c.default_gops));
                Json::Obj(o)
            })
            .collect();
        let mut doc = BTreeMap::new();
        doc.insert("schema".into(), Json::str(PROFILE_SCHEMA));
        doc.insert("fingerprint".into(), Json::Obj(fp));
        doc.insert("cost_model".into(), Json::Obj(cm));
        doc.insert("sw_fit".into(), Json::Obj(sw));
        doc.insert("classes".into(), Json::Arr(classes));
        doc.insert("generated_unix".into(), Json::num(self.generated_unix as f64));
        Json::Obj(doc)
    }

    /// Parse a `bismo-tune-profile/v1` document. Every missing or
    /// ill-typed field is a [`BismoError::Parse`]; tile sizes are
    /// additionally validated so a hand-edited `tile_m: 0` cannot
    /// smuggle an invalid kernel config past the typed boundary.
    pub fn from_json(doc: &Json) -> Result<TunedProfile, BismoError> {
        let schema = req_str(doc, "schema")?;
        if schema != PROFILE_SCHEMA {
            return Err(BismoError::Parse(format!(
                "tune profile: schema {schema:?}, expected {PROFILE_SCHEMA:?}"
            )));
        }
        let fp = doc
            .get("fingerprint")
            .ok_or_else(|| missing("fingerprint"))?;
        let fingerprint = CpuFingerprint {
            simd_tier: req_str(fp, "simd_tier")?.to_string(),
            cores: req_usize(fp, "cores")?,
        };
        let cm = doc.get("cost_model").ok_or_else(|| missing("cost_model"))?;
        let cost_model = CostModel {
            alpha_dpu: req_f64(cm, "alpha_dpu")?,
            beta_dpu: req_f64(cm, "beta_dpu")?,
            lut_base: req_f64(cm, "lut_base")?,
            lut_res: req_f64(cm, "lut_res")?,
            bram_base: req_f64(cm, "bram_base")? as u64,
        };
        let sw = doc.get("sw_fit").ok_or_else(|| missing("sw_fit"))?;
        let sw_fit = SwFit {
            ns_per_op: req_f64(sw, "ns_per_op")?,
            ns_base: req_f64(sw, "ns_base")?,
        };
        let mut classes = Vec::new();
        for (i, c) in doc
            .get("classes")
            .and_then(|c| c.as_arr())
            .ok_or_else(|| missing("classes"))?
            .iter()
            .enumerate()
        {
            let class = ShapeClass::parse(req_str(c, "class")?)?;
            let tile = KernelConfig {
                tile_m: req_usize(c, "tile_m")?,
                tile_n: req_usize(c, "tile_n")?,
                tile_k: tile_k_from_disk(req_usize(c, "tile_k")?),
            };
            tile.validate().map_err(|e| {
                BismoError::Parse(format!("tune profile: classes[{i}]: {e}"))
            })?;
            let shards = req_usize(c, "shards")?;
            if shards < 1 {
                return Err(BismoError::Parse(format!(
                    "tune profile: classes[{i}]: shards must be >= 1"
                )));
            }
            classes.push(ClassTuning {
                class,
                tile,
                shards,
                grid: (req_usize(c, "grid_rows")?, req_usize(c, "grid_cols")?),
                measured_gops: req_f64(c, "measured_gops")?,
                default_gops: req_f64(c, "default_gops")?,
            });
        }
        Ok(TunedProfile {
            fingerprint,
            cost_model,
            sw_fit,
            classes,
            generated_unix: req_f64(doc, "generated_unix")? as u64,
        })
    }

    /// Load and parse one profile file. I/O problems are
    /// [`BismoError::Io`]; malformed content is [`BismoError::Parse`].
    pub fn load(path: &Path) -> Result<TunedProfile, BismoError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| BismoError::Io(format!("read {}: {e}", path.display())))?;
        let doc = Json::parse(&text)
            .map_err(|e| BismoError::Parse(format!("{}: {e}", path.display())))?;
        TunedProfile::from_json(&doc)
    }

    /// Write this profile into `dir` under its content-addressed
    /// filename (`bismo-tune-<key>.json`), creating the directory.
    pub fn save_in(&self, dir: &Path) -> Result<PathBuf, BismoError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| BismoError::Io(format!("create {}: {e}", dir.display())))?;
        let path = dir.join(profile_filename(&self.fingerprint));
        std::fs::write(&path, self.to_json().pretty(2) + "\n")
            .map_err(|e| BismoError::Io(format!("write {}: {e}", path.display())))?;
        Ok(path)
    }

    /// Load the profile addressed by `fp` from `dir`. A missing file is
    /// `Ok(None)` (nothing tuned yet — not an error); a file whose
    /// *content* names a different machine than its address is a typed
    /// [`BismoError::Parse`] (the file was copied or tampered with).
    pub fn load_for(dir: &Path, fp: &CpuFingerprint) -> Result<Option<TunedProfile>, BismoError> {
        let path = dir.join(profile_filename(fp));
        if !path.exists() {
            return Ok(None);
        }
        let profile = TunedProfile::load(&path)?;
        if &profile.fingerprint != fp {
            return Err(BismoError::Parse(format!(
                "tune profile {}: fingerprint mismatch (file says {}, host is {})",
                path.display(),
                profile.key(),
                fp.key()
            )));
        }
        Ok(Some(profile))
    }
}

/// `usize::MAX` (unchunked) serializes as the illegal-as-real-size 0.
fn tile_k_to_disk(tile_k: usize) -> f64 {
    if tile_k == usize::MAX {
        0.0
    } else {
        tile_k as f64
    }
}

fn tile_k_from_disk(v: usize) -> usize {
    if v == 0 {
        usize::MAX
    } else {
        v
    }
}

fn profile_filename(fp: &CpuFingerprint) -> String {
    format!("bismo-tune-{}.json", fp.key())
}

fn missing(key: &str) -> BismoError {
    BismoError::Parse(format!("tune profile: missing field {key:?}"))
}

fn req_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, BismoError> {
    j.get(key)
        .and_then(|v| v.as_str())
        .ok_or_else(|| BismoError::Parse(format!("tune profile: field {key:?} must be a string")))
}

fn req_f64(j: &Json, key: &str) -> Result<f64, BismoError> {
    j.get(key)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| BismoError::Parse(format!("tune profile: field {key:?} must be a number")))
}

fn req_usize(j: &Json, key: &str) -> Result<usize, BismoError> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| BismoError::Parse(format!("tune profile: field {key:?} must be a number")))
}

/// The profile directory: `$BISMO_TUNE_DIR`, else `tuned/`.
pub fn profile_dir() -> PathBuf {
    std::env::var_os(TUNE_DIR_ENV)
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(TUNE_DIR_DEFAULT))
}

/// The clean-fallback loader [`crate::api::Session`] startup uses:
/// this host's profile from [`profile_dir`], or `None` when anything —
/// fingerprint detection, the file, its schema, its fingerprint —
/// doesn't line up. Never errs: an unreadable profile must degrade to
/// the analytical defaults, not take the service down.
pub fn load_host_profile() -> Option<TunedProfile> {
    load_host_profile_in(&profile_dir())
}

/// [`load_host_profile`] against an explicit directory.
pub fn load_host_profile_in(dir: &Path) -> Option<TunedProfile> {
    let fp = CpuFingerprint::detect().ok()?;
    TunedProfile::load_for(dir, &fp).ok().flatten()
}

/// Tuning-run knobs.
#[derive(Clone, Copy, Debug)]
pub struct TuneConfig {
    /// Smoke sweep: smaller candidate grid, one-sample timing. What CI
    /// runs; full mode is for generating a real profile.
    pub quick: bool,
    /// Worker threads for the shard sweep (0 = all cores).
    pub threads: usize,
    /// Workload RNG seed.
    pub seed: u64,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            quick: false,
            threads: 0,
            seed: 0xB15_707E,
        }
    }
}

/// Everything measured for one shape class — the bench-report view of
/// a [`ClassTuning`] (which keeps only what the runtime needs).
#[derive(Clone, Copy, Debug)]
pub struct ClassOutcome {
    pub class: ShapeClass,
    pub shape: GemmShape,
    pub wbits: u32,
    pub abits: u32,
    pub binary_ops: u64,
    pub candidates: usize,
    pub default_ns: f64,
    pub default_gops: f64,
    pub tuned_ns: f64,
    pub tuned_gops: f64,
    pub tile: KernelConfig,
    pub shards: usize,
    pub grid: (usize, usize),
}

impl ClassOutcome {
    /// Tuned-over-default throughput ratio (≥ 1 by construction: the
    /// default is always in the candidate set).
    pub fn speedup(&self) -> f64 {
        self.tuned_gops / self.default_gops
    }
}

/// A completed tuning run: the persistable profile plus the full
/// per-class measurement record for `BENCH_tune.json`.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    pub profile: TunedProfile,
    pub classes: Vec<ClassOutcome>,
}

/// One representative workload per class. Sizes are chosen so quick
/// mode finishes in CI seconds while every [`ShapeClass::classify`]
/// branch maps its own workload back to itself (asserted in tests).
fn class_workload(class: ShapeClass, quick: bool) -> (GemmShape, u32, u32) {
    let (m, k, n, w, a) = if quick {
        match class {
            ShapeClass::Small => (12, 128, 12, 2, 2),
            ShapeClass::Square => (64, 256, 64, 4, 4),
            ShapeClass::Tall => (128, 256, 16, 3, 3),
            ShapeClass::Wide => (16, 256, 128, 3, 3),
            ShapeClass::Deep => (64, 4096, 64, 2, 2),
        }
    } else {
        match class {
            ShapeClass::Small => (16, 256, 16, 3, 3),
            ShapeClass::Square => (128, 512, 128, 4, 4),
            ShapeClass::Tall => (256, 512, 32, 3, 3),
            ShapeClass::Wide => (32, 512, 256, 3, 3),
            ShapeClass::Deep => (96, 8192, 96, 2, 2),
        }
    };
    (GemmShape { m, k, n }, w, a)
}

/// Candidate tile geometries for one sweep. Always contains the
/// analytical default — the tuned pick is an argmax over a set that
/// includes it, so the tuned throughput can never fall below the
/// default's on the same measurement.
fn tile_candidates(quick: bool) -> Vec<KernelConfig> {
    let (dims, ks): (&[usize], &[usize]) = if quick {
        (&[4, 8, 16], &[usize::MAX, 4096])
    } else {
        (&[2, 4, 8, 16, 32], &[2048, 8192, usize::MAX])
    };
    let mut out = vec![KernelConfig::default()];
    for &tm in dims {
        for &tn in dims {
            for &tk in ks {
                let cfg = KernelConfig {
                    tile_m: tm,
                    tile_n: tn,
                    tile_k: tk,
                };
                if !out.contains(&cfg) {
                    out.push(cfg);
                }
            }
        }
    }
    out
}

/// Run the closed loop on this host: sweep every shape class, verify
/// and time each candidate, fit the models, and return the profile
/// (not yet saved — the caller decides the directory).
pub fn tune_host(cfg: &TuneConfig) -> Result<TuneOutcome, BismoError> {
    let fingerprint = CpuFingerprint::detect()?;
    let threads = if cfg.threads == 0 {
        fingerprint.cores
    } else {
        cfg.threads
    };
    let timer = if cfg.quick {
        BenchTimer::smoke()
    } else {
        BenchTimer::heavy()
    };
    let pool = WorkerPool::global();

    let mut classes = Vec::new();
    let mut tunings = Vec::new();
    let mut fit_ops = Vec::new();
    let mut fit_ns = Vec::new();
    for (ci, &class) in SHAPE_CLASSES.iter().enumerate() {
        let (shape, wbits, abits) = class_workload(class, cfg.quick);
        debug_assert_eq!(ShapeClass::classify(&shape), class);
        let mut rng = Rng::new(cfg.seed ^ (0x5EED << 8) ^ ci as u64);
        let a = IntMatrix::random(&mut rng, shape.m, shape.k, wbits, true);
        let b = IntMatrix::random(&mut rng, shape.k, shape.n, abits, false);
        let la = BitSerialMatrix::from_int(&a, wbits, true);
        let rb = BitSerialMatrix::from_int_transposed(&b, abits, false);
        let oracle = gemm_bitserial(&la, &rb);
        let ops = binary_ops(
            shape.m as u64,
            shape.k as u64,
            shape.n as u64,
            wbits,
            abits,
        );

        // Tile sweep, single-threaded: every candidate proves itself
        // bit-exact before its timing counts.
        let candidates = tile_candidates(cfg.quick);
        let mut default_ns = f64::INFINITY;
        let mut best: Option<(f64, KernelConfig)> = None;
        for tile in &candidates {
            let got = gemm_tiled_with(&la, &rb, tile, None)?;
            if got != oracle {
                return Err(BismoError::VerifyFailed(format!(
                    "tune {class}: tile {}x{}x{} disagrees with the oracle on {shape}",
                    tile.tile_m, tile.tile_n, tile.tile_k
                )));
            }
            let ns = timer
                .run(|| gemm_tiled_with(&la, &rb, tile, None).expect("verified above"))
                .median();
            if *tile == KernelConfig::default() {
                default_ns = ns;
            }
            if best.is_none_or(|(b_ns, _)| ns < b_ns) {
                best = Some((ns, *tile));
            }
        }
        let (best_tile_ns, best_tile) = best.expect("candidate set is never empty");

        // Shard sweep with the winning tile: the plan each count
        // produces is assembled and verified once, then timed.
        let mut tuned_ns = best_tile_ns;
        let mut shards = 1usize;
        let mut grid = (1usize, 1usize);
        for count in [2usize, 4, 8] {
            if count > threads || count > shape.m.max(shape.n) {
                continue;
            }
            let plan = ShardPlan::for_instances(shape.m, shape.n, count);
            let run_shards = || -> Result<IntMatrix, BismoError> {
                let shard_list = plan.shards();
                let slots: Vec<Mutex<Option<Result<IntMatrix, BismoError>>>> =
                    shard_list.iter().map(|_| Mutex::new(None)).collect();
                pool.run_limited(shard_list.len(), threads, &|i| {
                    let s = &shard_list[i];
                    let r = gemm_tiled_block(
                        &la,
                        &rb,
                        s.rows.clone(),
                        s.cols.clone(),
                        s.planes.clone(),
                        &best_tile,
                        None,
                    );
                    *slots[i].lock().unwrap() = Some(r);
                });
                let mut parts = Vec::with_capacity(slots.len());
                for slot in &slots {
                    parts.push(slot.lock().unwrap().take().expect("shard ran")?);
                }
                plan.assemble(&parts)
            };
            if run_shards()? != oracle {
                return Err(BismoError::VerifyFailed(format!(
                    "tune {class}: {count}-shard plan disagrees with the oracle on {shape}"
                )));
            }
            let ns = timer.run(|| run_shards().expect("verified above")).median();
            if ns < tuned_ns {
                tuned_ns = ns;
                shards = plan.count();
                grid = (plan.rows.count(), plan.cols.count());
            }
        }

        let outcome = ClassOutcome {
            class,
            shape,
            wbits,
            abits,
            binary_ops: ops,
            candidates: candidates.len(),
            default_ns,
            default_gops: ops as f64 / default_ns,
            tuned_ns,
            tuned_gops: ops as f64 / tuned_ns,
            tile: best_tile,
            shards,
            grid,
        };
        tunings.push(ClassTuning {
            class,
            tile: best_tile,
            shards,
            grid,
            measured_gops: outcome.tuned_gops,
            default_gops: outcome.default_gops,
        });
        fit_ops.push(ops as f64);
        fit_ns.push(tuned_ns);
        classes.push(outcome);
    }

    // Software cost fit over the measured best times. One-sample quick
    // timings can be noisy enough to turn the fit degenerate; fall back
    // to a through-origin mean-rate fit rather than failing the run.
    let sw_fit = match super::fit::linear_fit(&fit_ops, &fit_ns) {
        Ok((ns_per_op, ns_base)) => SwFit { ns_per_op, ns_base },
        Err(_) => SwFit {
            ns_per_op: fit_ns.iter().sum::<f64>() / fit_ops.iter().sum::<f64>(),
            ns_base: 0.0,
        },
    };

    let generated_unix = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map_or(0, |d| d.as_secs());
    Ok(TuneOutcome {
        profile: TunedProfile {
            fingerprint,
            cost_model: CostModel::fit_from_synth(),
            sw_fit,
            classes: tunings,
            generated_unix,
        },
        classes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_profile() -> TunedProfile {
        TunedProfile {
            fingerprint: CpuFingerprint {
                simd_tier: "scalar".into(),
                cores: 4,
            },
            cost_model: CostModel::paper(),
            sw_fit: SwFit {
                ns_per_op: 0.002,
                ns_base: 1500.0,
            },
            classes: vec![
                ClassTuning {
                    class: ShapeClass::Square,
                    tile: KernelConfig {
                        tile_m: 16,
                        tile_n: 8,
                        tile_k: usize::MAX,
                    },
                    shards: 1,
                    grid: (1, 1),
                    measured_gops: 12.5,
                    default_gops: 10.0,
                },
                ClassTuning {
                    class: ShapeClass::Deep,
                    tile: KernelConfig {
                        tile_m: 8,
                        tile_n: 16,
                        tile_k: 4096,
                    },
                    shards: 4,
                    grid: (2, 2),
                    measured_gops: 30.0,
                    default_gops: 22.0,
                },
            ],
            generated_unix: 1_700_000_000,
        }
    }

    #[test]
    fn classify_covers_every_class() {
        let cases = [
            (GemmShape { m: 8, k: 64, n: 8 }, ShapeClass::Small),
            (GemmShape { m: 64, k: 256, n: 64 }, ShapeClass::Square),
            (GemmShape { m: 256, k: 256, n: 32 }, ShapeClass::Tall),
            (GemmShape { m: 32, k: 256, n: 256 }, ShapeClass::Wide),
            (GemmShape { m: 64, k: 4096, n: 64 }, ShapeClass::Deep),
        ];
        for (shape, want) in cases {
            assert_eq!(ShapeClass::classify(&shape), want, "{shape}");
        }
        // Each swept workload must classify back to its own class, in
        // both modes — otherwise `tile_for` would never find the entry
        // the tuner just measured.
        for quick in [false, true] {
            for class in SHAPE_CLASSES {
                let (shape, _, _) = class_workload(class, quick);
                assert_eq!(ShapeClass::classify(&shape), class, "quick={quick} {shape}");
            }
        }
    }

    #[test]
    fn class_names_roundtrip() {
        for class in SHAPE_CLASSES {
            assert_eq!(ShapeClass::parse(class.name()).unwrap(), class);
        }
        assert!(matches!(
            ShapeClass::parse("enormous"),
            Err(BismoError::Parse(_))
        ));
    }

    #[test]
    fn profile_json_roundtrips() {
        let p = sample_profile();
        let doc = p.to_json();
        // Through the parser too, not just the in-memory value.
        let reparsed = Json::parse(&doc.pretty(2)).unwrap();
        assert_eq!(TunedProfile::from_json(&reparsed).unwrap(), p);
        // The unchunked sentinel really is 0 on disk.
        let class0 = &doc.get("classes").unwrap().as_arr().unwrap()[0];
        assert_eq!(class0.get("tile_k").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn tile_for_selects_by_class() {
        let p = sample_profile();
        let sq = p
            .tile_for(&GemmShape { m: 64, k: 256, n: 64 })
            .expect("square is tuned");
        assert_eq!((sq.tile_m, sq.tile_n), (16, 8));
        let deep = p
            .tile_for(&GemmShape { m: 64, k: 4096, n: 64 })
            .expect("deep is tuned");
        assert_eq!(deep.tile_k, 4096);
        // Untuned class: fall back (None) instead of guessing.
        assert!(p.tile_for(&GemmShape { m: 256, k: 256, n: 32 }).is_none());
    }

    #[test]
    fn malformed_documents_are_parse_errors() {
        let good = sample_profile().to_json();
        // Wrong schema string.
        let mut doc = good.as_obj().unwrap().clone();
        doc.insert("schema".into(), Json::str("bismo-bench-gemm/v1"));
        assert!(matches!(
            TunedProfile::from_json(&Json::Obj(doc)),
            Err(BismoError::Parse(_))
        ));
        // Missing section.
        let mut doc = good.as_obj().unwrap().clone();
        doc.remove("cost_model");
        assert!(matches!(
            TunedProfile::from_json(&Json::Obj(doc)),
            Err(BismoError::Parse(_))
        ));
        // Ill-typed field.
        let mut doc = good.as_obj().unwrap().clone();
        doc.insert("sw_fit".into(), Json::str("fast"));
        assert!(matches!(
            TunedProfile::from_json(&Json::Obj(doc)),
            Err(BismoError::Parse(_))
        ));
        // A zero tile size must not survive parsing as a legal config.
        let text = good.pretty(2).replace("\"tile_m\": 16", "\"tile_m\": 0");
        let doc = Json::parse(&text).unwrap();
        assert!(matches!(
            TunedProfile::from_json(&doc),
            Err(BismoError::Parse(_))
        ));
    }

    #[test]
    fn fingerprint_key_shape() {
        let fp = CpuFingerprint {
            simd_tier: "avx2".into(),
            cores: 16,
        };
        assert_eq!(fp.key(), "avx2-16c");
        assert_eq!(profile_filename(&fp), "bismo-tune-avx2-16c.json");
    }

    #[test]
    fn candidate_set_always_contains_the_default() {
        for quick in [false, true] {
            let c = tile_candidates(quick);
            assert!(c.contains(&KernelConfig::default()), "quick={quick}");
            // No duplicate work in the sweep.
            for (i, a) in c.iter().enumerate() {
                assert!(!c[i + 1..].contains(a), "duplicate candidate {a:?}");
            }
        }
    }

    #[test]
    fn sw_fit_predicts_linearly() {
        let fit = SwFit {
            ns_per_op: 0.5,
            ns_base: 100.0,
        };
        assert_eq!(fit.predict_ns(0), 100.0);
        assert_eq!(fit.predict_ns(1000), 600.0);
    }
}
