//! Linear least squares via normal equations + Gaussian elimination.
//! Used to fit the cost-model constants (paper §IV-A) and the power
//! model (Table V).

/// Solve `min ‖X·β − y‖²` for β. `xs[i]` is the feature row of sample
/// `i` (include a constant-1 column for an intercept).
pub fn least_squares(xs: &[Vec<f64>], ys: &[f64]) -> Vec<f64> {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let p = xs[0].len();
    assert!(xs.iter().all(|r| r.len() == p), "ragged feature rows");
    assert!(xs.len() >= p, "need at least as many samples as features");

    // Normal equations: (XᵀX) β = Xᵀy.
    let mut a = vec![vec![0.0; p]; p];
    let mut b = vec![0.0; p];
    for (row, &y) in xs.iter().zip(ys) {
        for i in 0..p {
            b[i] += row[i] * y;
            for j in 0..p {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    solve(a, b)
}

/// Gaussian elimination with partial pivoting.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Vec<f64> {
    let n = b.len();
    for col in 0..n {
        // Pivot.
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().partial_cmp(&a[j][col].abs()).unwrap())
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        assert!(
            d.abs() > 1e-12,
            "singular system (collinear features) at column {col}"
        );
        for r in (col + 1)..n {
            let f = a[r][col] / d;
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for r in (0..n).rev() {
        let mut s = b[r];
        for c in (r + 1)..n {
            s -= a[r][c] * x[c];
        }
        x[r] = s / a[r][r];
    }
    x
}

/// Convenience: fit `y = slope·x + intercept`. Returns (slope, intercept).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let rows: Vec<Vec<f64>> = xs.iter().map(|&x| vec![x, 1.0]).collect();
    let beta = least_squares(&rows, ys);
    (beta[0], beta[1])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x - 1.0).collect();
        let (m, c) = linear_fit(&xs, &ys);
        assert!((m - 2.5).abs() < 1e-9);
        assert!((c + 1.0).abs() < 1e-9);
    }

    #[test]
    fn noisy_line_close() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 3.0 * x + 10.0 + if i % 2 == 0 { 0.5 } else { -0.5 })
            .collect();
        let (m, c) = linear_fit(&xs, &ys);
        assert!((m - 3.0).abs() < 0.01);
        assert!((c - 10.0).abs() < 0.6);
    }

    #[test]
    fn multivariate_plane() {
        // y = 2a + 3b + 5
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for a in 0..5 {
            for b in 0..5 {
                xs.push(vec![a as f64, b as f64, 1.0]);
                ys.push(2.0 * a as f64 + 3.0 * b as f64 + 5.0);
            }
        }
        let beta = least_squares(&xs, &ys);
        assert!((beta[0] - 2.0).abs() < 1e-9);
        assert!((beta[1] - 3.0).abs() < 1e-9);
        assert!((beta[2] - 5.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn collinear_detected() {
        let xs = vec![vec![1.0, 2.0], vec![2.0, 4.0], vec![3.0, 6.0]];
        let ys = vec![1.0, 2.0, 3.0];
        let _ = least_squares(&xs, &ys);
    }
}
