//! Linear least squares via normal equations + Gaussian elimination.
//! Used to fit the cost-model constants (paper §IV-A), the power
//! model (Table V) and the autotuner's measured software cost fit
//! (`costmodel::tune`).
//!
//! Degenerate inputs — empty systems, ragged rows, under-determined
//! systems, non-finite samples, collinear features — are typed
//! [`BismoError::InvalidConfig`] errors, never panics and never
//! silently-garbage coefficients: the autotuner persists whatever this
//! module returns, so a bad fit must be impossible to save.

use crate::api::BismoError;

/// Solve `min ‖X·β − y‖²` for β. `xs[i]` is the feature row of sample
/// `i` (include a constant-1 column for an intercept).
///
/// Errs with [`BismoError::InvalidConfig`] when the system is empty,
/// ragged, under-determined (fewer samples than features), contains a
/// non-finite value, or is singular (collinear features).
pub fn least_squares(xs: &[Vec<f64>], ys: &[f64]) -> Result<Vec<f64>, BismoError> {
    if xs.len() != ys.len() {
        return Err(BismoError::InvalidConfig(format!(
            "least squares: {} feature rows vs {} observations",
            xs.len(),
            ys.len()
        )));
    }
    if xs.is_empty() {
        return Err(BismoError::InvalidConfig(
            "least squares: no samples".into(),
        ));
    }
    let p = xs[0].len();
    if p == 0 {
        return Err(BismoError::InvalidConfig(
            "least squares: zero-width feature rows".into(),
        ));
    }
    if !xs.iter().all(|r| r.len() == p) {
        return Err(BismoError::InvalidConfig(
            "least squares: ragged feature rows".into(),
        ));
    }
    if xs.len() < p {
        return Err(BismoError::InvalidConfig(format!(
            "least squares: under-determined system ({} samples < {p} features)",
            xs.len()
        )));
    }
    if xs.iter().flatten().any(|v| !v.is_finite()) || ys.iter().any(|v| !v.is_finite()) {
        return Err(BismoError::InvalidConfig(
            "least squares: non-finite sample (NaN/inf)".into(),
        ));
    }

    // Normal equations: (XᵀX) β = Xᵀy.
    let mut a = vec![vec![0.0; p]; p];
    let mut b = vec![0.0; p];
    for (row, &y) in xs.iter().zip(ys) {
        for i in 0..p {
            b[i] += row[i] * y;
            for j in 0..p {
                a[i][j] += row[i] * row[j];
            }
        }
    }
    solve(a, b)
}

/// Gaussian elimination with partial pivoting. Inputs are finite by
/// the time this runs (checked in [`least_squares`]), so the only
/// remaining failure is a singular pivot.
fn solve(mut a: Vec<Vec<f64>>, mut b: Vec<f64>) -> Result<Vec<f64>, BismoError> {
    let n = b.len();
    for col in 0..n {
        // Pivot. Finite inputs make the total_cmp/partial_cmp question
        // moot, but total_cmp keeps this panic-free by construction.
        let piv = (col..n)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap();
        a.swap(col, piv);
        b.swap(col, piv);
        let d = a[col][col];
        if d.abs() <= 1e-12 {
            return Err(BismoError::InvalidConfig(format!(
                "least squares: singular system (collinear features) at column {col}"
            )));
        }
        for r in (col + 1)..n {
            let f = a[r][col] / d;
            for c in col..n {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for r in (0..n).rev() {
        let mut s = b[r];
        for c in (r + 1)..n {
            s -= a[r][c] * x[c];
        }
        x[r] = s / a[r][r];
    }
    Ok(x)
}

/// Convenience: fit `y = slope·x + intercept`. Returns
/// `(slope, intercept)`, or the same typed errors as
/// [`least_squares`] (identical xs are collinear with the intercept
/// column and reported as singular).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> Result<(f64, f64), BismoError> {
    let rows: Vec<Vec<f64>> = xs.iter().map(|&x| vec![x, 1.0]).collect();
    let beta = least_squares(&rows, ys)?;
    Ok((beta[0], beta[1]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x - 1.0).collect();
        let (m, c) = linear_fit(&xs, &ys).unwrap();
        assert!((m - 2.5).abs() < 1e-9);
        assert!((c + 1.0).abs() < 1e-9);
    }

    #[test]
    fn noisy_line_close() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 3.0 * x + 10.0 + if i % 2 == 0 { 0.5 } else { -0.5 })
            .collect();
        let (m, c) = linear_fit(&xs, &ys).unwrap();
        assert!((m - 3.0).abs() < 0.01);
        assert!((c - 10.0).abs() < 0.6);
    }

    #[test]
    fn multivariate_plane() {
        // y = 2a + 3b + 5
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for a in 0..5 {
            for b in 0..5 {
                xs.push(vec![a as f64, b as f64, 1.0]);
                ys.push(2.0 * a as f64 + 3.0 * b as f64 + 5.0);
            }
        }
        let beta = least_squares(&xs, &ys).unwrap();
        assert!((beta[0] - 2.0).abs() < 1e-9);
        assert!((beta[1] - 3.0).abs() < 1e-9);
        assert!((beta[2] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn collinear_is_typed_error() {
        let xs = vec![vec![1.0, 2.0], vec![2.0, 4.0], vec![3.0, 6.0]];
        let ys = vec![1.0, 2.0, 3.0];
        let r = least_squares(&xs, &ys);
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
        let msg = r.unwrap_err().to_string();
        assert!(msg.contains("singular"), "{msg}");
    }

    #[test]
    fn degenerate_inputs_are_typed_errors() {
        // Empty system.
        assert!(matches!(
            least_squares(&[], &[]),
            Err(BismoError::InvalidConfig(_))
        ));
        // Row/observation count mismatch.
        assert!(matches!(
            least_squares(&[vec![1.0]], &[1.0, 2.0]),
            Err(BismoError::InvalidConfig(_))
        ));
        // Ragged rows.
        assert!(matches!(
            least_squares(&[vec![1.0, 2.0], vec![1.0]], &[1.0, 2.0]),
            Err(BismoError::InvalidConfig(_))
        ));
        // Zero-width rows.
        assert!(matches!(
            least_squares(&[vec![], vec![]], &[1.0, 2.0]),
            Err(BismoError::InvalidConfig(_))
        ));
        // Under-determined: one sample, two features.
        assert!(matches!(
            least_squares(&[vec![1.0, 2.0]], &[1.0]),
            Err(BismoError::InvalidConfig(_))
        ));
        // Non-finite samples on either side.
        assert!(matches!(
            least_squares(&[vec![f64::NAN, 1.0], vec![2.0, 1.0]], &[1.0, 2.0]),
            Err(BismoError::InvalidConfig(_))
        ));
        assert!(matches!(
            least_squares(&[vec![1.0, 1.0], vec![2.0, 1.0]], &[1.0, f64::INFINITY]),
            Err(BismoError::InvalidConfig(_))
        ));
        // linear_fit surfaces the same errors.
        assert!(matches!(
            linear_fit(&[1.0, f64::NAN], &[1.0, 2.0]),
            Err(BismoError::InvalidConfig(_))
        ));
        // Constant xs are collinear with the intercept column.
        assert!(matches!(
            linear_fit(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]),
            Err(BismoError::InvalidConfig(_))
        ));
    }
}
