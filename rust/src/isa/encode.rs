//! Fixed 128-bit binary encoding of BISMO instructions.
//!
//! This is the contract a hardware instruction decoder would implement:
//! every field has a fixed (offset, width) slot and encoding asserts the
//! value fits. Field map (LSB-first offsets into the 128-bit word):
//!
//! ```text
//! [0:2)   kind      0=Wait 1=Signal 2=Run
//! [2:4)   stage     0=Fetch 1=Execute 2=Result
//! Wait/Signal:
//! [4:6)   channel   0=F→E 1=E→F 2=E→R 3=R→E
//! RunFetch (stage=0):
//! [4:36)  dram_base/8        [36:52) block_bytes/8
//! [52:68) block_stride/8     [68:84) num_blocks
//! [84:100) buf_offset        [100:106) buf_start
//! [106:112) buf_range        [112:126) words_per_buf
//! RunExecute (stage=1):
//! [4:20)  lhs_offset         [20:36) rhs_offset
//! [36:52) num_chunks         [52:58) shift
//! [58]    negate  [59] acc_reset  [60] commit_result
//! RunResult (stage=2):
//! [4:36)  dram_base/4        [36:64) offset/4
//! [64:72) rows               [72:80) cols
//! [80:104) row_stride_bytes/4
//! ```

use super::{ExecuteRun, FetchRun, Instr, ResultRun, Stage, SyncChannel};

/// Insert `value` into `word` at `[off, off+width)`, asserting range.
fn put(word: &mut u128, off: u32, width: u32, value: u64, what: &str) {
    assert!(
        width == 64 || (value >> width) == 0,
        "ISA field {what} = {value} does not fit {width} bits"
    );
    *word |= (value as u128) << off;
}

fn get(word: u128, off: u32, width: u32) -> u64 {
    ((word >> off) & ((1u128 << width) - 1)) as u64
}

fn chan_code(c: SyncChannel) -> u64 {
    match c {
        SyncChannel::FetchToExecute => 0,
        SyncChannel::ExecuteToFetch => 1,
        SyncChannel::ExecuteToResult => 2,
        SyncChannel::ResultToExecute => 3,
    }
}

fn chan_from(code: u64) -> SyncChannel {
    match code {
        0 => SyncChannel::FetchToExecute,
        1 => SyncChannel::ExecuteToFetch,
        2 => SyncChannel::ExecuteToResult,
        _ => SyncChannel::ResultToExecute,
    }
}

fn stage_code(s: Stage) -> u64 {
    match s {
        Stage::Fetch => 0,
        Stage::Execute => 1,
        Stage::Result => 2,
    }
}

/// Encode an instruction (as residing in `stage`'s queue) to 128 bits.
///
/// Panics if any field exceeds its encoding slot — the same values the
/// hardware's instruction-word layout could not express.
pub fn encode(instr: &Instr, stage: Stage) -> u128 {
    let mut w = 0u128;
    put(&mut w, 2, 2, stage_code(stage), "stage");
    match instr {
        Instr::Wait(c) => {
            put(&mut w, 0, 2, 0, "kind");
            put(&mut w, 4, 2, chan_code(*c), "channel");
        }
        Instr::Signal(c) => {
            put(&mut w, 0, 2, 1, "kind");
            put(&mut w, 4, 2, chan_code(*c), "channel");
        }
        Instr::Fetch(f) => {
            assert_eq!(stage, Stage::Fetch, "RunFetch must encode in fetch queue");
            put(&mut w, 0, 2, 2, "kind");
            assert_eq!(f.dram_base % 8, 0);
            assert_eq!(f.block_bytes % 8, 0);
            assert_eq!(f.block_stride_bytes % 8, 0);
            put(&mut w, 4, 32, f.dram_base / 8, "dram_base/8");
            put(&mut w, 36, 16, (f.block_bytes / 8) as u64, "block_bytes/8");
            put(&mut w, 52, 16, (f.block_stride_bytes / 8) as u64, "block_stride/8");
            put(&mut w, 68, 16, f.num_blocks as u64, "num_blocks");
            put(&mut w, 84, 16, f.buf_offset as u64, "buf_offset");
            put(&mut w, 100, 6, f.buf_start as u64, "buf_start");
            put(&mut w, 106, 6, f.buf_range as u64, "buf_range");
            put(&mut w, 112, 14, f.words_per_buf as u64, "words_per_buf");
        }
        Instr::Execute(e) => {
            assert_eq!(stage, Stage::Execute);
            put(&mut w, 0, 2, 2, "kind");
            put(&mut w, 4, 16, e.lhs_offset as u64, "lhs_offset");
            put(&mut w, 20, 16, e.rhs_offset as u64, "rhs_offset");
            put(&mut w, 36, 16, e.num_chunks as u64, "num_chunks");
            put(&mut w, 52, 6, e.shift as u64, "shift");
            put(&mut w, 58, 1, e.negate as u64, "negate");
            put(&mut w, 59, 1, e.acc_reset as u64, "acc_reset");
            put(&mut w, 60, 1, e.commit_result as u64, "commit_result");
        }
        Instr::Result(r) => {
            assert_eq!(stage, Stage::Result);
            put(&mut w, 0, 2, 2, "kind");
            assert_eq!(r.dram_base % 4, 0);
            assert_eq!(r.offset % 4, 0);
            assert_eq!(r.row_stride_bytes % 4, 0);
            put(&mut w, 4, 32, r.dram_base / 4, "dram_base/4");
            put(&mut w, 36, 28, r.offset / 4, "offset/4");
            put(&mut w, 64, 8, r.rows as u64, "rows");
            put(&mut w, 72, 8, r.cols as u64, "cols");
            put(&mut w, 80, 24, (r.row_stride_bytes / 4) as u64, "row_stride/4");
        }
    }
    w
}

/// Decode a 128-bit instruction word. Returns the instruction and the
/// stage whose queue it belongs to.
///
/// Permissive, like a hardware decoder that simply taps field wires:
/// reserved opcodes alias onto defined ones and reserved bits are
/// ignored. Software paths that ingest *untrusted* words (e.g.
/// [`super::Program::from_words`]) must use [`try_decode`] instead.
pub fn decode(w: u128) -> (Instr, Stage) {
    let kind = get(w, 0, 2);
    let stage = match get(w, 2, 2) {
        0 => Stage::Fetch,
        1 => Stage::Execute,
        _ => Stage::Result,
    };
    (decode_fields(w, kind, stage), stage)
}

/// Strict decode: rejects reserved opcode/stage codes and any set bit
/// outside the fields defined for the instruction's layout, so a
/// corrupted word is detected instead of silently aliasing onto a
/// different instruction. This is the entry point for untrusted words.
pub fn try_decode(w: u128) -> Result<(Instr, Stage), String> {
    let kind = get(w, 0, 2);
    if kind == 3 {
        return Err(format!("reserved instruction kind code 3 in word {w:#034x}"));
    }
    let stage = match get(w, 2, 2) {
        0 => Stage::Fetch,
        1 => Stage::Execute,
        2 => Stage::Result,
        c => return Err(format!("reserved stage code {c} in word {w:#034x}")),
    };
    // Union of defined field slots for this (kind, stage) layout.
    let low = |bits: u32| -> u128 { (1u128 << bits) - 1 };
    let mask: u128 = match (kind, stage) {
        // Wait/Signal: kind, stage, channel.
        (0, _) | (1, _) => low(6),
        // Run instructions (see the module-level field map).
        (_, Stage::Fetch) => low(126),
        (_, Stage::Execute) => low(61),
        (_, Stage::Result) => low(104),
    };
    if w & !mask != 0 {
        return Err(format!(
            "reserved bits set in {} instruction word {w:#034x}",
            stage.name()
        ));
    }
    Ok((decode_fields(w, kind, stage), stage))
}

/// Field extraction shared by [`decode`] and [`try_decode`]. `kind` is
/// 0 (Wait), 1 (Signal) or anything else (Run); `stage` selects the Run
/// layout.
fn decode_fields(w: u128, kind: u64, stage: Stage) -> Instr {
    match kind {
        0 => Instr::Wait(chan_from(get(w, 4, 2))),
        1 => Instr::Signal(chan_from(get(w, 4, 2))),
        _ => match stage {
            Stage::Fetch => Instr::Fetch(FetchRun {
                dram_base: get(w, 4, 32) * 8,
                block_bytes: get(w, 36, 16) as u32 * 8,
                block_stride_bytes: get(w, 52, 16) as u32 * 8,
                num_blocks: get(w, 68, 16) as u32,
                buf_offset: get(w, 84, 16) as u32,
                buf_start: get(w, 100, 6) as u8,
                buf_range: get(w, 106, 6) as u8,
                words_per_buf: get(w, 112, 14) as u32,
            }),
            Stage::Execute => Instr::Execute(ExecuteRun {
                lhs_offset: get(w, 4, 16) as u32,
                rhs_offset: get(w, 20, 16) as u32,
                num_chunks: get(w, 36, 16) as u32,
                shift: get(w, 52, 6) as u8,
                negate: get(w, 58, 1) == 1,
                acc_reset: get(w, 59, 1) == 1,
                commit_result: get(w, 60, 1) == 1,
            }),
            Stage::Result => Instr::Result(ResultRun {
                dram_base: get(w, 4, 32) * 4,
                offset: get(w, 36, 28) * 4,
                rows: get(w, 64, 8) as u8,
                cols: get(w, 72, 8) as u8,
                row_stride_bytes: get(w, 80, 24) as u32 * 4,
            }),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{property_sweep, Rng};

    fn roundtrip(i: Instr, s: Stage) {
        let w = encode(&i, s);
        let (i2, s2) = decode(w);
        assert_eq!(i, i2, "roundtrip failed for {i}");
        assert_eq!(s, s2);
    }

    #[test]
    fn sync_roundtrip_all_channels() {
        for c in SyncChannel::ALL {
            roundtrip(Instr::Wait(c), c.consumer());
            roundtrip(Instr::Signal(c), c.producer());
        }
    }

    fn rand_fetch(rng: &mut Rng) -> FetchRun {
        FetchRun {
            dram_base: rng.below(1 << 20) * 8,
            block_bytes: (rng.below(1 << 10) as u32 + 1) * 8,
            block_stride_bytes: rng.below(1 << 12) as u32 * 8,
            num_blocks: rng.below(1 << 12) as u32 + 1,
            buf_offset: rng.below(1 << 12) as u32,
            buf_start: rng.below(48) as u8,
            buf_range: rng.below(48) as u8 + 1,
            words_per_buf: rng.below(1 << 12) as u32 + 1,
        }
    }

    #[test]
    fn fetch_roundtrip_sweep() {
        property_sweep(0xF37C, 50, |rng, _| {
            roundtrip(Instr::Fetch(rand_fetch(rng)), Stage::Fetch);
        });
    }

    #[test]
    fn execute_roundtrip_sweep() {
        property_sweep(0xE8EC, 50, |rng, _| {
            let e = ExecuteRun {
                lhs_offset: rng.below(1 << 16) as u32,
                rhs_offset: rng.below(1 << 16) as u32,
                num_chunks: rng.below(1 << 16) as u32 + 1,
                shift: rng.below(63) as u8,
                negate: rng.chance(0.5),
                acc_reset: rng.chance(0.5),
                commit_result: rng.chance(0.5),
            };
            roundtrip(Instr::Execute(e), Stage::Execute);
        });
    }

    #[test]
    fn result_roundtrip_sweep() {
        property_sweep(0x4E57, 50, |rng, _| {
            let r = ResultRun {
                dram_base: rng.below(1 << 28) * 4,
                offset: rng.below(1 << 24) * 4,
                rows: rng.below(255) as u8 + 1,
                cols: rng.below(255) as u8 + 1,
                row_stride_bytes: rng.below(1 << 20) as u32 * 4,
            };
            roundtrip(Instr::Result(r), Stage::Result);
        });
    }

    #[test]
    fn try_decode_accepts_every_legal_encoding() {
        property_sweep(0x7D3C, 100, |rng, _| {
            let (i, s) = match rng.index(5) {
                0 => {
                    let c = *rng.pick(&SyncChannel::ALL);
                    (Instr::Wait(c), c.consumer())
                }
                1 => {
                    let c = *rng.pick(&SyncChannel::ALL);
                    (Instr::Signal(c), c.producer())
                }
                2 => (Instr::Fetch(rand_fetch(rng)), Stage::Fetch),
                3 => (
                    Instr::Execute(ExecuteRun {
                        lhs_offset: rng.below(1 << 16) as u32,
                        rhs_offset: rng.below(1 << 16) as u32,
                        num_chunks: rng.below(1 << 16) as u32 + 1,
                        shift: rng.below(63) as u8,
                        negate: rng.chance(0.5),
                        acc_reset: rng.chance(0.5),
                        commit_result: rng.chance(0.5),
                    }),
                    Stage::Execute,
                ),
                _ => (
                    Instr::Result(ResultRun {
                        dram_base: rng.below(1 << 28) * 4,
                        offset: rng.below(1 << 24) * 4,
                        rows: rng.below(255) as u8 + 1,
                        cols: rng.below(255) as u8 + 1,
                        row_stride_bytes: rng.below(1 << 20) as u32 * 4,
                    }),
                    Stage::Result,
                ),
            };
            let w = encode(&i, s);
            let (i2, s2) = try_decode(w).expect("legal encoding rejected");
            assert_eq!((i2, s2), (i, s));
        });
    }

    #[test]
    fn try_decode_rejects_reserved_codes_and_bits() {
        // Reserved kind code 3.
        assert!(try_decode(3).unwrap_err().contains("kind"));
        // Reserved stage code 3 on a Run instruction.
        assert!(try_decode(2 | (3 << 2)).unwrap_err().contains("stage"));
        // Reserved high bit on each Run layout.
        let f = encode(
            &Instr::Fetch(FetchRun {
                dram_base: 0,
                block_bytes: 8,
                block_stride_bytes: 0,
                num_blocks: 1,
                buf_offset: 0,
                buf_start: 0,
                buf_range: 1,
                words_per_buf: 1,
            }),
            Stage::Fetch,
        );
        assert!(try_decode(f | (1u128 << 127)).is_err());
        let e = encode(
            &Instr::Execute(ExecuteRun {
                lhs_offset: 0,
                rhs_offset: 0,
                num_chunks: 1,
                shift: 0,
                negate: false,
                acc_reset: false,
                commit_result: false,
            }),
            Stage::Execute,
        );
        assert!(try_decode(e | (1u128 << 61)).is_err());
        assert!(try_decode(e).is_ok());
        let r = encode(
            &Instr::Result(ResultRun {
                dram_base: 0,
                offset: 0,
                rows: 1,
                cols: 1,
                row_stride_bytes: 4,
            }),
            Stage::Result,
        );
        assert!(try_decode(r | (1u128 << 104)).is_err());
        // Reserved bits on a Wait word (anything above bit 6).
        let wait = encode(&Instr::Wait(SyncChannel::FetchToExecute), Stage::Execute);
        assert!(try_decode(wait | (1u128 << 40)).is_err());
        // The permissive decoder still accepts all of these.
        let _ = decode(f | (1u128 << 127));
        let _ = decode(wait | (1u128 << 40));
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn overflow_field_panics() {
        let e = ExecuteRun {
            lhs_offset: 1 << 16, // exceeds 16-bit slot
            rhs_offset: 0,
            num_chunks: 1,
            shift: 0,
            negate: false,
            acc_reset: false,
            commit_result: false,
        };
        let _ = encode(&Instr::Execute(e), Stage::Execute);
    }

    #[test]
    fn shift_field_is_6_bits_like_weight_unit() {
        // Largest legal shift (62) must roundtrip — 2^62 weights occur
        // only for absurd precisions but the slot must hold them.
        let e = ExecuteRun {
            lhs_offset: 0,
            rhs_offset: 0,
            num_chunks: 1,
            shift: 62,
            negate: true,
            acc_reset: false,
            commit_result: true,
        };
        roundtrip(Instr::Execute(e), Stage::Execute);
    }
}
