//! A complete BISMO program: the three per-stage instruction queues,
//! with legality validation, statistics and a disassembler.

use super::{encode, Instr, Stage, SyncChannel};
use crate::api::BismoError;

/// Per-stage instruction streams, executed in order by each stage.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub fetch: Vec<Instr>,
    pub execute: Vec<Instr>,
    pub result: Vec<Instr>,
}

/// Instruction-count statistics for a program.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProgramStats {
    pub fetch_runs: usize,
    pub execute_runs: usize,
    pub result_runs: usize,
    pub waits: usize,
    pub signals: usize,
    pub total: usize,
}

impl Program {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn queue(&self, s: Stage) -> &[Instr] {
        match s {
            Stage::Fetch => &self.fetch,
            Stage::Execute => &self.execute,
            Stage::Result => &self.result,
        }
    }

    pub fn queue_mut(&mut self, s: Stage) -> &mut Vec<Instr> {
        match s {
            Stage::Fetch => &mut self.fetch,
            Stage::Execute => &mut self.execute,
            Stage::Result => &mut self.result,
        }
    }

    pub fn push(&mut self, s: Stage, i: Instr) {
        self.queue_mut(s).push(i);
    }

    /// Validate every instruction against its queue's legality rules and
    /// check global token balance: along every sync channel, the number
    /// of signals must equal the number of waits (a completed program
    /// leaves no dangling tokens and no stage starved forever — a
    /// necessary, not sufficient, deadlock-freedom condition; the
    /// simulator's deadlock detector covers the rest).
    pub fn validate(&self) -> Result<(), BismoError> {
        for s in Stage::ALL {
            for (i, instr) in self.queue(s).iter().enumerate() {
                instr.legality(s).map_err(|e| {
                    BismoError::IllegalProgram(format!("{} queue[{i}]: {e}", s.name()))
                })?;
            }
        }
        for ch in SyncChannel::ALL {
            let signals = self.count_sync(ch, true);
            let waits = self.count_sync(ch, false);
            if signals != waits {
                return Err(BismoError::IllegalProgram(format!(
                    "token imbalance on {}: {} signals vs {} waits",
                    ch.name(),
                    signals,
                    waits
                )));
            }
        }
        Ok(())
    }

    fn count_sync(&self, ch: SyncChannel, signal: bool) -> usize {
        Stage::ALL
            .iter()
            .flat_map(|&s| self.queue(s).iter())
            .filter(|i| match (i, signal) {
                (Instr::Signal(c), true) => *c == ch,
                (Instr::Wait(c), false) => *c == ch,
                _ => false,
            })
            .count()
    }

    pub fn stats(&self) -> ProgramStats {
        let mut st = ProgramStats::default();
        for s in Stage::ALL {
            for i in self.queue(s) {
                match i {
                    Instr::Wait(_) => st.waits += 1,
                    Instr::Signal(_) => st.signals += 1,
                    Instr::Fetch(_) => st.fetch_runs += 1,
                    Instr::Execute(_) => st.execute_runs += 1,
                    Instr::Result(_) => st.result_runs += 1,
                }
                st.total += 1;
            }
        }
        st
    }

    /// Binary size of the encoded program in bytes (16 B per instruction).
    pub fn encoded_bytes(&self) -> usize {
        self.stats().total * 16
    }

    /// Encode all queues to 128-bit words (fetch, execute, result order).
    pub fn assemble(&self) -> Vec<u128> {
        let mut out = Vec::with_capacity(self.stats().total);
        for s in Stage::ALL {
            for i in self.queue(s) {
                out.push(encode(i, s));
            }
        }
        out
    }

    /// Upper bound on the instruction words [`Program::from_words`]
    /// accepts — far above any scheduler output (the largest bench
    /// programs are ~10^4 instructions) but small enough that a
    /// corrupted length field cannot drive a multi-GiB allocation.
    pub const MAX_WORDS: usize = 1 << 20;

    /// Rebuild a program from encoded instruction words — the path a
    /// host driver uses when loading a stored binary program into the
    /// accelerator's instruction queues.
    ///
    /// This is an untrusted-input boundary: words are decoded with the
    /// strict [`super::try_decode`] (reserved opcodes / set reserved
    /// bits are [`BismoError::Parse`]), oversized streams are rejected,
    /// and the decoded program is fully validated — corrupt bytes can
    /// never panic, only return a typed error.
    pub fn from_words(words: &[u128]) -> Result<Self, BismoError> {
        if words.len() > Self::MAX_WORDS {
            return Err(BismoError::Parse(format!(
                "instruction stream of {} words exceeds the {} cap",
                words.len(),
                Self::MAX_WORDS
            )));
        }
        let mut p = Program::new();
        for (i, &w) in words.iter().enumerate() {
            let (instr, stage) =
                super::try_decode(w).map_err(|e| BismoError::Parse(format!("word {i}: {e}")))?;
            instr
                .legality(stage)
                .map_err(|e| BismoError::IllegalProgram(format!("word {i}: {e}")))?;
            p.push(stage, instr);
        }
        p.validate()?;
        Ok(p)
    }

    /// Serialize to the binary on-disk / over-the-wire form: the
    /// assembled 128-bit words, little-endian, 16 bytes each.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.encoded_bytes());
        for w in self.assemble() {
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// Parse the binary form produced by [`Program::to_bytes`].
    /// Truncated streams (length not a multiple of the 16-byte
    /// instruction word) are [`BismoError::Parse`]; word-level
    /// corruption is diagnosed by [`Program::from_words`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Self, BismoError> {
        if bytes.len() % 16 != 0 {
            return Err(BismoError::Parse(format!(
                "truncated instruction stream: {} bytes is not a multiple of the 16-byte word",
                bytes.len()
            )));
        }
        let words: Vec<u128> = bytes
            .chunks_exact(16)
            .map(|c| {
                let mut b = [0u8; 16];
                b.copy_from_slice(c);
                u128::from_le_bytes(b)
            })
            .collect();
        Self::from_words(&words)
    }

    /// Order-sensitive 64-bit fingerprint over all three queues.
    ///
    /// Used by the suspendable simulator to verify that `step()` /
    /// `restore()` are driven with the same program that was armed.
    /// Hashes the in-memory instruction fields directly (not the binary
    /// encoding) so it is total: programs whose fields exceed their
    /// encoding slots still fingerprint fine, whereas `assemble()`
    /// would panic on them.
    pub fn fingerprint(&self) -> u64 {
        use crate::util::splitmix64;
        fn mix(h: &mut u64, v: u64) {
            *h = splitmix64(*h ^ v.wrapping_mul(0x9e37_79b9_7f4a_7c15));
        }
        let mut h = 0xb15_0f1d_u64;
        for s in Stage::ALL {
            mix(&mut h, self.queue(s).len() as u64);
            for i in self.queue(s) {
                match i {
                    Instr::Wait(c) => {
                        mix(&mut h, 1);
                        mix(&mut h, *c as u64);
                    }
                    Instr::Signal(c) => {
                        mix(&mut h, 2);
                        mix(&mut h, *c as u64);
                    }
                    Instr::Fetch(f) => {
                        mix(&mut h, 3);
                        mix(&mut h, f.dram_base);
                        mix(&mut h, f.block_bytes as u64);
                        mix(&mut h, f.block_stride_bytes as u64);
                        mix(&mut h, f.num_blocks as u64);
                        mix(&mut h, f.buf_offset as u64);
                        mix(&mut h, f.buf_start as u64);
                        mix(&mut h, f.buf_range as u64);
                        mix(&mut h, f.words_per_buf as u64);
                    }
                    Instr::Execute(e) => {
                        mix(&mut h, 4);
                        mix(&mut h, e.lhs_offset as u64);
                        mix(&mut h, e.rhs_offset as u64);
                        mix(&mut h, e.num_chunks as u64);
                        mix(&mut h, e.shift as u64);
                        let flags = e.negate as u64
                            | (e.acc_reset as u64) << 1
                            | (e.commit_result as u64) << 2;
                        mix(&mut h, flags);
                    }
                    Instr::Result(r) => {
                        mix(&mut h, 5);
                        mix(&mut h, r.dram_base);
                        mix(&mut h, r.offset);
                        mix(&mut h, r.rows as u64);
                        mix(&mut h, r.cols as u64);
                        mix(&mut h, r.row_stride_bytes as u64);
                    }
                }
            }
        }
        h
    }

    /// Human-readable disassembly of all three queues, in the style of
    /// the paper's Table III.
    pub fn disassemble(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        for s in Stage::ALL {
            let _ = writeln!(out, "{} queue ({} instrs):", s.name(), self.queue(s).len());
            for (i, instr) in self.queue(s).iter().enumerate() {
                let tag = match s {
                    Stage::Fetch => "F",
                    Stage::Execute => "E",
                    Stage::Result => "R",
                };
                let _ = writeln!(out, "  {tag}{:<4} {instr}", i + 1);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{ExecuteRun, FetchRun, ResultRun};

    fn tiny_program() -> Program {
        let mut p = Program::new();
        p.push(
            Stage::Fetch,
            Instr::Fetch(FetchRun {
                dram_base: 0,
                block_bytes: 64,
                block_stride_bytes: 0,
                num_blocks: 1,
                buf_offset: 0,
                buf_start: 0,
                buf_range: 1,
                words_per_buf: 8,
            }),
        );
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        p.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        p.push(
            Stage::Execute,
            Instr::Execute(ExecuteRun {
                lhs_offset: 0,
                rhs_offset: 0,
                num_chunks: 1,
                shift: 0,
                negate: false,
                acc_reset: true,
                commit_result: true,
            }),
        );
        p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToResult));
        p.push(Stage::Result, Instr::Wait(SyncChannel::ExecuteToResult));
        p.push(
            Stage::Result,
            Instr::Result(ResultRun {
                dram_base: 0,
                offset: 0,
                rows: 2,
                cols: 2,
                row_stride_bytes: 8,
            }),
        );
        p
    }

    #[test]
    fn valid_program_passes() {
        tiny_program().validate().unwrap();
    }

    #[test]
    fn imbalance_detected() {
        let mut p = tiny_program();
        p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToFetch));
        let err = p.validate().unwrap_err();
        assert!(matches!(err, BismoError::IllegalProgram(_)), "{err:?}");
        assert!(err.to_string().contains("token imbalance"), "{err}");
    }

    #[test]
    fn wrong_queue_detected() {
        let mut p = tiny_program();
        p.push(
            Stage::Fetch,
            Instr::Execute(ExecuteRun {
                lhs_offset: 0,
                rhs_offset: 0,
                num_chunks: 1,
                shift: 0,
                negate: false,
                acc_reset: false,
                commit_result: false,
            }),
        );
        assert!(p.validate().is_err());
    }

    #[test]
    fn stats_and_assembly() {
        let p = tiny_program();
        let st = p.stats();
        assert_eq!(st.fetch_runs, 1);
        assert_eq!(st.execute_runs, 1);
        assert_eq!(st.result_runs, 1);
        assert_eq!(st.waits, 2);
        assert_eq!(st.signals, 2);
        assert_eq!(st.total, 7);
        assert_eq!(p.assemble().len(), 7);
        assert_eq!(p.encoded_bytes(), 112);
    }

    #[test]
    fn binary_roundtrip_via_from_words() {
        let p = tiny_program();
        let words = p.assemble();
        let q = Program::from_words(&words).unwrap();
        assert_eq!(p.fetch, q.fetch);
        assert_eq!(p.execute, q.execute);
        assert_eq!(p.result, q.result);
    }

    #[test]
    fn from_words_rejects_imbalanced_binary() {
        let mut p = tiny_program();
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        let words = p.assemble();
        assert!(Program::from_words(&words).is_err());
    }

    #[test]
    fn disassembly_mentions_all() {
        let d = tiny_program().disassemble();
        assert!(d.contains("RunFetch"));
        assert!(d.contains("RunExecute"));
        assert!(d.contains("RunResult"));
        assert!(d.contains("fetch queue"));
    }

    #[test]
    fn bytes_roundtrip() {
        let p = tiny_program();
        let bytes = p.to_bytes();
        assert_eq!(bytes.len() % 16, 0);
        let q = Program::from_bytes(&bytes).unwrap();
        assert_eq!(p.fetch, q.fetch);
        assert_eq!(p.execute, q.execute);
        assert_eq!(p.result, q.result);
        assert_eq!(p.fingerprint(), q.fingerprint());
    }

    #[test]
    fn truncated_byte_stream_is_parse_error() {
        let mut bytes = tiny_program().to_bytes();
        bytes.pop(); // no longer a whole number of 16-byte words
        match Program::from_bytes(&bytes) {
            Err(BismoError::Parse(msg)) => assert!(msg.contains("truncated"), "{msg}"),
            other => panic!("expected Parse error, got {other:?}"),
        }
        // Chopping mid-word anywhere is equally rejected.
        assert!(Program::from_bytes(&bytes[..7]).is_err());
    }

    #[test]
    fn corrupt_words_are_parse_errors_never_panics() {
        let p = tiny_program();
        let mut words = p.assemble();
        // Reserved instruction-kind code 3.
        let orig = words[0];
        words[0] = (orig & !0b11) | 0b11;
        assert!(matches!(
            Program::from_words(&words),
            Err(BismoError::Parse(_))
        ));
        // Reserved stage code 3.
        words[0] = orig | 0b1100;
        assert!(matches!(
            Program::from_words(&words),
            Err(BismoError::Parse(_))
        ));
        // Reserved high bit set on a fetch Run word.
        words[0] = orig | (1u128 << 127);
        match Program::from_words(&words) {
            Err(BismoError::Parse(msg)) => assert!(msg.contains("word 0"), "{msg}"),
            other => panic!("expected Parse error, got {other:?}"),
        }
        words[0] = orig;
        assert!(Program::from_words(&words).is_ok());
    }

    #[test]
    fn oversized_stream_is_parse_error() {
        // Length alone must reject before any decode work happens.
        let words = vec![0u128; Program::MAX_WORDS + 1];
        match Program::from_words(&words) {
            Err(BismoError::Parse(msg)) => assert!(msg.contains("cap"), "{msg}"),
            other => panic!("expected Parse error, got {other:?}"),
        }
    }

    #[test]
    fn fingerprint_is_order_and_field_sensitive() {
        let p = tiny_program();
        let base = p.fingerprint();
        assert_eq!(base, tiny_program().fingerprint(), "must be deterministic");

        // Changing one field changes the fingerprint.
        let mut q = tiny_program();
        if let Some(Instr::Fetch(f)) = q.queue_mut(Stage::Fetch).first_mut() {
            f.dram_base += 8;
        }
        assert_ne!(base, q.fingerprint());

        // Moving an instruction between queues changes it too, even
        // though the multiset of instructions is identical.
        let mut r = tiny_program();
        let moved = r.queue_mut(Stage::Fetch).pop().unwrap();
        r.queue_mut(Stage::Execute).push(moved);
        assert_ne!(base, r.fingerprint());
    }
}
