//! A complete BISMO program: the three per-stage instruction queues,
//! with legality validation, statistics and a disassembler.

use super::{encode, Instr, Stage, SyncChannel};
use crate::api::BismoError;

/// Per-stage instruction streams, executed in order by each stage.
#[derive(Clone, Debug, Default)]
pub struct Program {
    pub fetch: Vec<Instr>,
    pub execute: Vec<Instr>,
    pub result: Vec<Instr>,
}

/// Instruction-count statistics for a program.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProgramStats {
    pub fetch_runs: usize,
    pub execute_runs: usize,
    pub result_runs: usize,
    pub waits: usize,
    pub signals: usize,
    pub total: usize,
}

impl Program {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn queue(&self, s: Stage) -> &[Instr] {
        match s {
            Stage::Fetch => &self.fetch,
            Stage::Execute => &self.execute,
            Stage::Result => &self.result,
        }
    }

    pub fn queue_mut(&mut self, s: Stage) -> &mut Vec<Instr> {
        match s {
            Stage::Fetch => &mut self.fetch,
            Stage::Execute => &mut self.execute,
            Stage::Result => &mut self.result,
        }
    }

    pub fn push(&mut self, s: Stage, i: Instr) {
        self.queue_mut(s).push(i);
    }

    /// Validate every instruction against its queue's legality rules and
    /// check global token balance: along every sync channel, the number
    /// of signals must equal the number of waits (a completed program
    /// leaves no dangling tokens and no stage starved forever — a
    /// necessary, not sufficient, deadlock-freedom condition; the
    /// simulator's deadlock detector covers the rest).
    pub fn validate(&self) -> Result<(), BismoError> {
        for s in Stage::ALL {
            for (i, instr) in self.queue(s).iter().enumerate() {
                instr.legality(s).map_err(|e| {
                    BismoError::IllegalProgram(format!("{} queue[{i}]: {e}", s.name()))
                })?;
            }
        }
        for ch in SyncChannel::ALL {
            let signals = self.count_sync(ch, true);
            let waits = self.count_sync(ch, false);
            if signals != waits {
                return Err(BismoError::IllegalProgram(format!(
                    "token imbalance on {}: {} signals vs {} waits",
                    ch.name(),
                    signals,
                    waits
                )));
            }
        }
        Ok(())
    }

    fn count_sync(&self, ch: SyncChannel, signal: bool) -> usize {
        Stage::ALL
            .iter()
            .flat_map(|&s| self.queue(s).iter())
            .filter(|i| match (i, signal) {
                (Instr::Signal(c), true) => *c == ch,
                (Instr::Wait(c), false) => *c == ch,
                _ => false,
            })
            .count()
    }

    pub fn stats(&self) -> ProgramStats {
        let mut st = ProgramStats::default();
        for s in Stage::ALL {
            for i in self.queue(s) {
                match i {
                    Instr::Wait(_) => st.waits += 1,
                    Instr::Signal(_) => st.signals += 1,
                    Instr::Fetch(_) => st.fetch_runs += 1,
                    Instr::Execute(_) => st.execute_runs += 1,
                    Instr::Result(_) => st.result_runs += 1,
                }
                st.total += 1;
            }
        }
        st
    }

    /// Binary size of the encoded program in bytes (16 B per instruction).
    pub fn encoded_bytes(&self) -> usize {
        self.stats().total * 16
    }

    /// Encode all queues to 128-bit words (fetch, execute, result order).
    pub fn assemble(&self) -> Vec<u128> {
        let mut out = Vec::with_capacity(self.stats().total);
        for s in Stage::ALL {
            for i in self.queue(s) {
                out.push(encode(i, s));
            }
        }
        out
    }

    /// Rebuild a program from encoded instruction words — the path a
    /// host driver uses when loading a stored binary program into the
    /// accelerator's instruction queues. Validates after decoding.
    pub fn from_words(words: &[u128]) -> Result<Self, BismoError> {
        let mut p = Program::new();
        for (i, &w) in words.iter().enumerate() {
            let (instr, stage) = super::decode(w);
            instr
                .legality(stage)
                .map_err(|e| BismoError::IllegalProgram(format!("word {i}: {e}")))?;
            p.push(stage, instr);
        }
        p.validate()?;
        Ok(p)
    }

    /// Human-readable disassembly of all three queues, in the style of
    /// the paper's Table III.
    pub fn disassemble(&self) -> String {
        use std::fmt::Write;
        let mut out = String::new();
        for s in Stage::ALL {
            let _ = writeln!(out, "{} queue ({} instrs):", s.name(), self.queue(s).len());
            for (i, instr) in self.queue(s).iter().enumerate() {
                let tag = match s {
                    Stage::Fetch => "F",
                    Stage::Execute => "E",
                    Stage::Result => "R",
                };
                let _ = writeln!(out, "  {tag}{:<4} {instr}", i + 1);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{ExecuteRun, FetchRun, ResultRun};

    fn tiny_program() -> Program {
        let mut p = Program::new();
        p.push(
            Stage::Fetch,
            Instr::Fetch(FetchRun {
                dram_base: 0,
                block_bytes: 64,
                block_stride_bytes: 0,
                num_blocks: 1,
                buf_offset: 0,
                buf_start: 0,
                buf_range: 1,
                words_per_buf: 8,
            }),
        );
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        p.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        p.push(
            Stage::Execute,
            Instr::Execute(ExecuteRun {
                lhs_offset: 0,
                rhs_offset: 0,
                num_chunks: 1,
                shift: 0,
                negate: false,
                acc_reset: true,
                commit_result: true,
            }),
        );
        p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToResult));
        p.push(Stage::Result, Instr::Wait(SyncChannel::ExecuteToResult));
        p.push(
            Stage::Result,
            Instr::Result(ResultRun {
                dram_base: 0,
                offset: 0,
                rows: 2,
                cols: 2,
                row_stride_bytes: 8,
            }),
        );
        p
    }

    #[test]
    fn valid_program_passes() {
        tiny_program().validate().unwrap();
    }

    #[test]
    fn imbalance_detected() {
        let mut p = tiny_program();
        p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToFetch));
        let err = p.validate().unwrap_err();
        assert!(matches!(err, BismoError::IllegalProgram(_)), "{err:?}");
        assert!(err.to_string().contains("token imbalance"), "{err}");
    }

    #[test]
    fn wrong_queue_detected() {
        let mut p = tiny_program();
        p.push(
            Stage::Fetch,
            Instr::Execute(ExecuteRun {
                lhs_offset: 0,
                rhs_offset: 0,
                num_chunks: 1,
                shift: 0,
                negate: false,
                acc_reset: false,
                commit_result: false,
            }),
        );
        assert!(p.validate().is_err());
    }

    #[test]
    fn stats_and_assembly() {
        let p = tiny_program();
        let st = p.stats();
        assert_eq!(st.fetch_runs, 1);
        assert_eq!(st.execute_runs, 1);
        assert_eq!(st.result_runs, 1);
        assert_eq!(st.waits, 2);
        assert_eq!(st.signals, 2);
        assert_eq!(st.total, 7);
        assert_eq!(p.assemble().len(), 7);
        assert_eq!(p.encoded_bytes(), 112);
    }

    #[test]
    fn binary_roundtrip_via_from_words() {
        let p = tiny_program();
        let words = p.assemble();
        let q = Program::from_words(&words).unwrap();
        assert_eq!(p.fetch, q.fetch);
        assert_eq!(p.execute, q.execute);
        assert_eq!(p.result, q.result);
    }

    #[test]
    fn from_words_rejects_imbalanced_binary() {
        let mut p = tiny_program();
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        let words = p.assemble();
        assert!(Program::from_words(&words).is_err());
    }

    #[test]
    fn disassembly_mentions_all() {
        let d = tiny_program().disassemble();
        assert!(d.contains("RunFetch"));
        assert!(d.contains("RunExecute"));
        assert!(d.contains("RunResult"));
        assert!(d.contains("fetch queue"));
    }
}
