//! The BISMO instruction set (paper Table II).
//!
//! Each pipeline stage (fetch / execute / result) consumes its own
//! in-order instruction queue. Three instruction kinds exist per stage:
//!
//! * `Wait(chan)` — block until a token is available on a sync FIFO,
//!   then pop it.
//! * `Signal(chan)` — push a token onto a sync FIFO.
//! * `Run*` — the stage's actual work (DMA read, DPA execution, DMA
//!   write).
//!
//! Tokens carry no payload: the *meaning* of a token (e.g. "buffer
//! region 0 is now full") is a software convention of the scheduler,
//! exactly as in the paper (§III-C1a).
//!
//! [`encode()`]/[`decode()`] give every instruction a fixed 128-bit
//! binary encoding with range-checked fields — the contract a hardware
//! instruction decoder would implement — and [`Program`] bundles
//! per-stage streams with legality validation and a disassembler.

mod encode;
mod program;

pub use encode::{decode, encode, try_decode};
pub use program::{Program, ProgramStats};

/// Pipeline stage that owns an instruction queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Stage {
    Fetch,
    Execute,
    Result,
}

impl Stage {
    pub const ALL: [Stage; 3] = [Stage::Fetch, Stage::Execute, Stage::Result];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Fetch => "fetch",
            Stage::Execute => "execute",
            Stage::Result => "result",
        }
    }
}

/// The four synchronization FIFOs between stage pairs (paper Fig. 2):
/// fetch↔execute and execute↔result.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SyncChannel {
    /// Fetch signals "data is in the matrix buffers"; execute waits.
    FetchToExecute,
    /// Execute signals "buffer region free for refill"; fetch waits.
    ExecuteToFetch,
    /// Execute signals "results committed to result buffer"; result waits.
    ExecuteToResult,
    /// Result signals "result-buffer slot drained"; execute waits.
    ResultToExecute,
}

impl SyncChannel {
    pub const ALL: [SyncChannel; 4] = [
        SyncChannel::FetchToExecute,
        SyncChannel::ExecuteToFetch,
        SyncChannel::ExecuteToResult,
        SyncChannel::ResultToExecute,
    ];

    /// Stage allowed to `Signal` this channel.
    pub fn producer(&self) -> Stage {
        match self {
            SyncChannel::FetchToExecute => Stage::Fetch,
            SyncChannel::ExecuteToFetch | SyncChannel::ExecuteToResult => Stage::Execute,
            SyncChannel::ResultToExecute => Stage::Result,
        }
    }

    /// Stage allowed to `Wait` on this channel.
    pub fn consumer(&self) -> Stage {
        match self {
            SyncChannel::FetchToExecute | SyncChannel::ResultToExecute => Stage::Execute,
            SyncChannel::ExecuteToFetch => Stage::Fetch,
            SyncChannel::ExecuteToResult => Stage::Result,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SyncChannel::FetchToExecute => "fetch->execute",
            SyncChannel::ExecuteToFetch => "execute->fetch",
            SyncChannel::ExecuteToResult => "execute->result",
            SyncChannel::ResultToExecute => "result->execute",
        }
    }
}

/// `RunFetch`: stream a strided region of DRAM into matrix buffers.
///
/// Source side (DRAM): `num_blocks` blocks of `block_bytes` bytes,
/// consecutive blocks separated by `block_stride_bytes` (supporting
/// strided/tiled reads). Destination side (matrix buffers): starting at
/// buffer `buf_start`, writing `words_per_buf` consecutive `D_k`-bit
/// buffer words starting at word `buf_offset`, then switching to the
/// next buffer, cyclically within `buf_range` buffers. Buffers are
/// enumerated `0 .. D_m + D_n - 1`: LHS row buffers first, then RHS
/// column buffers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FetchRun {
    /// DRAM base byte address (8-byte aligned).
    pub dram_base: u64,
    /// Contiguous bytes per block (multiple of 8).
    pub block_bytes: u32,
    /// Stride between block starts in bytes (multiple of 8).
    pub block_stride_bytes: u32,
    /// Number of blocks.
    pub num_blocks: u32,
    /// Destination word offset within each target buffer.
    pub buf_offset: u32,
    /// First destination buffer id.
    pub buf_start: u8,
    /// Number of consecutive buffers written cyclically.
    pub buf_range: u8,
    /// `D_k`-bit words written per buffer before switching.
    pub words_per_buf: u32,
}

/// `RunExecute`: one weighted binary matrix-multiply pass on the DPA.
///
/// The sequence generator reads `num_chunks` consecutive `D_k`-bit words
/// from every LHS buffer (starting at `lhs_offset`) and every RHS buffer
/// (starting at `rhs_offset`); each DPU ANDs + popcounts its pair,
/// applies `weight = (negate ? -1 : 1) << shift` and accumulates.
/// `acc_reset` clears the accumulators first; `commit_result` copies the
/// final `D_m × D_n` accumulator set into the result buffer afterwards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecuteRun {
    /// LHS buffer word offset.
    pub lhs_offset: u32,
    /// RHS buffer word offset.
    pub rhs_offset: u32,
    /// Number of `D_k`-bit chunks accumulated (dot length / `D_k`).
    pub num_chunks: u32,
    /// Left-shift amount of the plane-pair weight (`i + j`).
    pub shift: u8,
    /// Negate the weighted contribution (signed MSB planes).
    pub negate: bool,
    /// Clear accumulators before this pass.
    pub acc_reset: bool,
    /// Copy accumulators to the result buffer after this pass.
    pub commit_result: bool,
}

/// `RunResult`: write one committed `D_m × D_n` result tile from the
/// result buffer to DRAM, strided to scatter tile rows into the full
/// result matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResultRun {
    /// Result matrix base byte address (4-byte aligned).
    pub dram_base: u64,
    /// Byte offset of this tile's top-left accumulator.
    pub offset: u64,
    /// Tile rows to write (≤ `D_m`).
    pub rows: u8,
    /// Tile cols to write (≤ `D_n`).
    pub cols: u8,
    /// Byte stride between consecutive tile rows in DRAM (= 4·n).
    pub row_stride_bytes: u32,
}

/// One instruction for some stage's queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instr {
    Wait(SyncChannel),
    Signal(SyncChannel),
    Fetch(FetchRun),
    Execute(ExecuteRun),
    Result(ResultRun),
}

impl Instr {
    /// Check legality of this instruction in `stage`'s queue.
    pub fn check_legal(&self, stage: Stage) -> Result<(), crate::api::BismoError> {
        self.legality(stage)
            .map_err(crate::api::BismoError::IllegalProgram)
    }

    /// Legality with a bare message payload — shared by
    /// [`Instr::check_legal`] and [`Program::validate`], which adds
    /// queue/index context before wrapping into the typed error.
    pub(crate) fn legality(&self, stage: Stage) -> Result<(), String> {
        match self {
            Instr::Wait(ch) => {
                if ch.consumer() != stage {
                    return Err(format!(
                        "{} stage cannot Wait on {}",
                        stage.name(),
                        ch.name()
                    ));
                }
            }
            Instr::Signal(ch) => {
                if ch.producer() != stage {
                    return Err(format!(
                        "{} stage cannot Signal {}",
                        stage.name(),
                        ch.name()
                    ));
                }
            }
            Instr::Fetch(f) => {
                if stage != Stage::Fetch {
                    return Err(format!("RunFetch in {} queue", stage.name()));
                }
                if f.dram_base % 8 != 0 || f.block_bytes % 8 != 0 || f.block_stride_bytes % 8 != 0
                {
                    return Err("fetch addresses/sizes must be 8-byte multiples".into());
                }
                if f.num_blocks == 0 || f.block_bytes == 0 {
                    return Err("fetch must move at least one block of data".into());
                }
                if f.buf_range == 0 {
                    return Err("fetch buf_range must be >= 1".into());
                }
            }
            Instr::Execute(e) => {
                if stage != Stage::Execute {
                    return Err(format!("RunExecute in {} queue", stage.name()));
                }
                if e.num_chunks == 0 {
                    return Err("execute needs num_chunks >= 1".into());
                }
                if e.shift >= 63 {
                    return Err("shift must be < 63".into());
                }
            }
            Instr::Result(r) => {
                if stage != Stage::Result {
                    return Err(format!("RunResult in {} queue", stage.name()));
                }
                if (r.dram_base + r.offset) % 4 != 0 {
                    return Err("result address must be 4-byte aligned".into());
                }
                if r.rows == 0 || r.cols == 0 {
                    return Err("result tile must be non-empty".into());
                }
            }
        }
        Ok(())
    }
}

impl std::fmt::Display for Instr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Instr::Wait(ch) => write!(f, "Wait   {}", ch.name()),
            Instr::Signal(ch) => write!(f, "Signal {}", ch.name()),
            Instr::Fetch(x) => write!(
                f,
                "RunFetch   base=0x{:x} block={}B stride={}B n={} -> buf[{}..+{}]@{} wpb={}",
                x.dram_base,
                x.block_bytes,
                x.block_stride_bytes,
                x.num_blocks,
                x.buf_start,
                x.buf_range,
                x.buf_offset,
                x.words_per_buf
            ),
            Instr::Execute(x) => write!(
                f,
                "RunExecute lhs@{} rhs@{} chunks={} w={}{}{}{}",
                x.lhs_offset,
                x.rhs_offset,
                x.num_chunks,
                if x.negate { "-" } else { "+" },
                1u64 << x.shift,
                if x.acc_reset { " [reset]" } else { "" },
                if x.commit_result { " [commit]" } else { "" }
            ),
            Instr::Result(x) => write!(
                f,
                "RunResult  base=0x{:x}+{} tile={}x{} stride={}B",
                x.dram_base, x.offset, x.rows, x.cols, x.row_stride_bytes
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_endpoints() {
        use SyncChannel::*;
        assert_eq!(FetchToExecute.producer(), Stage::Fetch);
        assert_eq!(FetchToExecute.consumer(), Stage::Execute);
        assert_eq!(ExecuteToFetch.producer(), Stage::Execute);
        assert_eq!(ExecuteToFetch.consumer(), Stage::Fetch);
        assert_eq!(ExecuteToResult.consumer(), Stage::Result);
        assert_eq!(ResultToExecute.consumer(), Stage::Execute);
    }

    #[test]
    fn legality_matrix() {
        use SyncChannel::*;
        // Fetch may wait only on execute->fetch, signal only fetch->execute.
        assert!(Instr::Wait(ExecuteToFetch).check_legal(Stage::Fetch).is_ok());
        assert!(Instr::Wait(FetchToExecute).check_legal(Stage::Fetch).is_err());
        assert!(Instr::Signal(FetchToExecute).check_legal(Stage::Fetch).is_ok());
        assert!(Instr::Signal(ExecuteToResult).check_legal(Stage::Fetch).is_err());
        // Execute waits on both inbound channels.
        assert!(Instr::Wait(FetchToExecute).check_legal(Stage::Execute).is_ok());
        assert!(Instr::Wait(ResultToExecute).check_legal(Stage::Execute).is_ok());
        assert!(Instr::Signal(ExecuteToFetch).check_legal(Stage::Execute).is_ok());
        assert!(Instr::Signal(ExecuteToResult).check_legal(Stage::Execute).is_ok());
        assert!(Instr::Wait(ExecuteToFetch).check_legal(Stage::Execute).is_err());
        // Run instructions only in their own queue.
        let e = Instr::Execute(ExecuteRun {
            lhs_offset: 0,
            rhs_offset: 0,
            num_chunks: 1,
            shift: 0,
            negate: false,
            acc_reset: true,
            commit_result: false,
        });
        assert!(e.check_legal(Stage::Execute).is_ok());
        assert!(e.check_legal(Stage::Fetch).is_err());
    }

    #[test]
    fn fetch_field_validation() {
        let mut f = FetchRun {
            dram_base: 8,
            block_bytes: 64,
            block_stride_bytes: 128,
            num_blocks: 4,
            buf_offset: 0,
            buf_start: 0,
            buf_range: 4,
            words_per_buf: 8,
        };
        assert!(Instr::Fetch(f).check_legal(Stage::Fetch).is_ok());
        f.dram_base = 4;
        assert!(Instr::Fetch(f).check_legal(Stage::Fetch).is_err());
        f.dram_base = 8;
        f.num_blocks = 0;
        assert!(Instr::Fetch(f).check_legal(Stage::Fetch).is_err());
    }

    #[test]
    fn display_forms() {
        let s = format!("{}", Instr::Wait(SyncChannel::FetchToExecute));
        assert!(s.contains("Wait"));
        let e = Instr::Execute(ExecuteRun {
            lhs_offset: 3,
            rhs_offset: 5,
            num_chunks: 7,
            shift: 2,
            negate: true,
            acc_reset: true,
            commit_result: true,
        });
        let s = format!("{e}");
        assert!(s.contains("-4") && s.contains("[reset]") && s.contains("[commit]"));
    }
}
