//! The execute stage: the Dot Product Array and its sequence generator.
//!
//! Functionally, a `RunExecute` performs — for every DPU `(i, j)` — an
//! AND + popcount dot product over `num_chunks` consecutive `D_k`-bit
//! buffer words, applies the software-controlled weight
//! `(negate ? -1 : 1) << shift` and accumulates into the DPU's `A`-bit
//! register (paper Fig. 4). Accumulators wrap at `A` bits exactly like
//! the hardware register would; wrap events are counted.
//!
//! Timing (DESIGN.md §4, calibrated to paper Figs 12–13): a burst of
//! back-to-back accumulating RunExecutes fills the DPA pipeline once;
//! each instruction then streams one chunk per cycle:
//!
//! ```text
//! cycles = (acc_reset ? D_pipe : 0) + num_chunks  [+1 if commit]
//! ```
//!
//! `acc_reset` starts a fresh accumulation group, which in hardware must
//! wait for the previous group to drain out of the pipelined
//! AND→popcount→shift→accumulate datapath — the source of the paper's
//! narrow-matrix inefficiency (Fig. 12: 89% for D_k=64 vs 64% for
//! D_k=256 at k=8192, both reproduced by this model).

use super::buffers::{MatrixBuffers, ResultBuffer};
use super::StageFault;
use crate::arch::BismoConfig;
use crate::isa::ExecuteRun;
use crate::kernel::popcount_and;

/// Execute-stage state: the `D_m × D_n` accumulator registers.
pub struct ExecuteUnit {
    dm: usize,
    dn: usize,
    acc_bits: u32,
    pipeline_depth: u64,
    /// Accumulators, row-major `dm × dn`, modelled at i64 then wrapped
    /// to `acc_bits` on read-out (the register itself is `A` bits wide:
    /// we wrap on every update).
    accs: Vec<i64>,
    /// Wrap events observed (value exceeded the `A`-bit register).
    pub overflows: u64,
    /// Scratch: per-DPU-column RHS word ranges, revalidated per
    /// instruction but allocated once (this sits on the per-instruction
    /// hot path).
    rhs_scratch: Vec<std::ops::Range<usize>>,
}

impl ExecuteUnit {
    pub fn new(cfg: &BismoConfig) -> Self {
        ExecuteUnit {
            dm: cfg.dm as usize,
            dn: cfg.dn as usize,
            acc_bits: cfg.acc_bits,
            pipeline_depth: cfg.dpa_pipeline_depth(),
            accs: vec![0; (cfg.dm * cfg.dn) as usize],
            overflows: 0,
            rhs_scratch: Vec::with_capacity(cfg.dn as usize),
        }
    }

    /// Wrap `v` into the two's-complement range of an `acc_bits`-wide
    /// register (`1 <= acc_bits < 64`). Implemented as a shift-out /
    /// sign-extending shift-in so it is total over all i64 inputs —
    /// fuzzed programs reach this with extreme shift weights.
    #[inline]
    fn wrap_value(acc_bits: u32, v: i64) -> i64 {
        debug_assert!(acc_bits >= 1 && acc_bits < 64);
        let sh = 64 - acc_bits;
        (((v as u64) << sh) as i64) >> sh
    }

    /// Execute one `RunExecute`. Returns
    /// `(cycles, binary_ops, fill_cycles, committed)`.
    pub fn run(
        &mut self,
        e: &ExecuteRun,
        bufs: &MatrixBuffers,
        result_buf: &mut ResultBuffer,
    ) -> Result<(u64, u64, u64, bool), StageFault> {
        if e.acc_reset {
            self.accs.iter_mut().for_each(|a| *a = 0);
        }
        let weight = if e.negate {
            -(1i64 << e.shift)
        } else {
            1i64 << e.shift
        };

        // Hot path: one contiguous range per RHS buffer, validated once
        // per instruction and cached in reusable scratch (no
        // per-instruction heap allocation); the inner loop is the same
        // word-level AND+popcount the DPU datapath performs.
        let chunks = e.num_chunks as usize;
        self.rhs_scratch.clear();
        for j in 0..self.dn {
            let range = bufs
                .rhs_word_range(j, e.rhs_offset as usize, chunks)
                .map_err(|err| StageFault(format!("execute rhs: {err}")))?;
            self.rhs_scratch.push(range);
        }
        let rhs_data = bufs.rhs_data();
        // The `acc_bits == 64` check is hoisted out of the accumulate
        // loop: a full-width register never wraps, so that path skips
        // the wrap arithmetic entirely.
        if self.acc_bits == 64 {
            for i in 0..self.dm {
                let lw = bufs
                    .read_range(bufs.lhs_buf(i), e.lhs_offset as usize, chunks)
                    .map_err(|err| StageFault(format!("execute lhs: {err}")))?;
                for (j, range) in self.rhs_scratch.iter().enumerate() {
                    let pc = popcount_and(lw, &rhs_data[range.clone()]);
                    let idx = i * self.dn + j;
                    // A 64-bit register wraps mod 2^64 — exactly
                    // i64 wrapping arithmetic.
                    self.accs[idx] = self.accs[idx].wrapping_add(weight.wrapping_mul(pc as i64));
                }
            }
        } else {
            for i in 0..self.dm {
                let lw = bufs
                    .read_range(bufs.lhs_buf(i), e.lhs_offset as usize, chunks)
                    .map_err(|err| StageFault(format!("execute lhs: {err}")))?;
                for (j, range) in self.rhs_scratch.iter().enumerate() {
                    let pc = popcount_and(lw, &rhs_data[range.clone()]);
                    let idx = i * self.dn + j;
                    // Wrapping arithmetic: 2^acc_bits divides 2^64, so
                    // reducing the wrapped i64 sum mod 2^acc_bits gives
                    // the exact register value even when the ideal sum
                    // exceeds i64 range (shift can be up to 62).
                    let updated = self.accs[idx].wrapping_add(weight.wrapping_mul(pc as i64));
                    let wrapped = Self::wrap_value(self.acc_bits, updated);
                    if wrapped != updated {
                        self.overflows += 1;
                    }
                    self.accs[idx] = wrapped;
                }
            }
        }

        let committed = e.commit_result;
        if committed {
            let set: Vec<i32> = self.accs.iter().map(|&a| a as i32).collect();
            result_buf
                .commit(set)
                .map_err(|err| StageFault(format!("execute: {err}")))?;
        }

        // Timing (see module docs).
        let fill = if e.acc_reset { self.pipeline_depth } else { 0 };
        let cycles = fill + e.num_chunks as u64 + committed as u64;
        // Work: every DPU processes num_chunks·D_k bit pairs, 2 ops each.
        let dk_bits = bufs.words_per_chunk() as u64 * 64;
        let ops = 2 * self.dm as u64 * self.dn as u64 * e.num_chunks as u64 * dk_bits;
        Ok((cycles, ops, fill, committed))
    }

    /// Current accumulator values (wrapped to `A` bits), row-major.
    pub fn accumulators(&self) -> &[i64] {
        &self.accs
    }

    /// Overwrite accumulator state from a snapshot.
    pub fn restore_state(&mut self, accs: &[i64], overflows: u64) -> Result<(), StageFault> {
        if accs.len() != self.accs.len() {
            return Err(StageFault(format!(
                "accumulator snapshot of {} values does not match the {}×{} DPA",
                accs.len(),
                self.dm,
                self.dn
            )));
        }
        self.accs.copy_from_slice(accs);
        self.overflows = overflows;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::ExecuteRun;

    fn cfg() -> BismoConfig {
        BismoConfig::small() // 2×64×2
    }

    fn exec(
        unit: &mut ExecuteUnit,
        bufs: &MatrixBuffers,
        rb: &mut ResultBuffer,
        e: ExecuteRun,
    ) -> (u64, u64, u64, bool) {
        unit.run(&e, bufs, rb).unwrap()
    }

    fn basic_run(chunks: u32, shift: u8, negate: bool, reset: bool) -> ExecuteRun {
        ExecuteRun {
            lhs_offset: 0,
            rhs_offset: 0,
            num_chunks: chunks,
            shift,
            negate,
            acc_reset: reset,
            commit_result: false,
        }
    }

    #[test]
    fn popcount_and_weight() {
        let c = cfg();
        let mut bufs = MatrixBuffers::new(&c);
        // LHS buffer 0 word 0: 0b1111, RHS buffer word 0: 0b0110 → AND
        // popcount = 2.
        bufs.write_word(0, 0, &[0b1111]).unwrap();
        bufs.write_word(1, 0, &[0b1111]).unwrap();
        bufs.write_word(2, 0, &[0b0110]).unwrap();
        bufs.write_word(3, 0, &[0b0001]).unwrap();
        let mut unit = ExecuteUnit::new(&c);
        let mut rb = ResultBuffer::new(&c);
        exec(&mut unit, &bufs, &mut rb, basic_run(1, 2, false, true));
        // weight = 4: acc[0][0] = 4·2 = 8; acc[0][1] = 4·1 = 4.
        assert_eq!(unit.accumulators(), &[8, 4, 8, 4]);
        // Negated accumulation on top, weight = -1, no reset.
        exec(&mut unit, &bufs, &mut rb, basic_run(1, 0, true, false));
        assert_eq!(unit.accumulators(), &[6, 3, 6, 3]);
    }

    #[test]
    fn timing_model_fill_and_stream() {
        let c = cfg();
        let bufs = MatrixBuffers::new(&c);
        let mut unit = ExecuteUnit::new(&c);
        let mut rb = ResultBuffer::new(&c);
        let depth = c.dpa_pipeline_depth();
        let (cy, ops, fill, _) = exec(&mut unit, &bufs, &mut rb, basic_run(6, 0, false, true));
        assert_eq!(cy, depth + 6);
        assert_eq!(fill, depth);
        assert_eq!(ops, 2 * 2 * 2 * 6 * 64);
        // Warm pipeline: continuation costs only the chunks.
        let (cy2, _, fill2, _) = exec(&mut unit, &bufs, &mut rb, basic_run(6, 1, false, false));
        assert_eq!(cy2, 6);
        assert_eq!(fill2, 0);
    }

    #[test]
    fn commit_pushes_result_set() {
        let c = cfg();
        let mut bufs = MatrixBuffers::new(&c);
        bufs.write_word(0, 0, &[u64::MAX]).unwrap();
        bufs.write_word(1, 0, &[0]).unwrap();
        bufs.write_word(2, 0, &[u64::MAX]).unwrap();
        bufs.write_word(3, 0, &[u64::MAX]).unwrap();
        let mut unit = ExecuteUnit::new(&c);
        let mut rb = ResultBuffer::new(&c);
        let e = ExecuteRun {
            commit_result: true,
            ..basic_run(1, 0, false, true)
        };
        let (_, _, _, committed) = exec(&mut unit, &bufs, &mut rb, e);
        assert!(committed);
        assert_eq!(rb.drain().unwrap(), vec![64, 64, 0, 0]);
    }

    #[test]
    fn accumulator_wraps_at_a_bits() {
        let c = BismoConfig {
            acc_bits: 8,
            ..cfg()
        };
        let mut bufs = MatrixBuffers::new(&c);
        for b in 0..4 {
            bufs.write_word(b, 0, &[u64::MAX]).unwrap(); // popcount 64
        }
        let mut unit = ExecuteUnit::new(&c);
        let mut rb = ResultBuffer::new(&c);
        // 64 · 2 = 128 overflows an 8-bit register to -128.
        exec(&mut unit, &bufs, &mut rb, basic_run(1, 1, false, true));
        assert_eq!(unit.accumulators(), &[-128; 4]);
        assert_eq!(unit.overflows, 4);
    }

    #[test]
    fn full_width_accumulator_never_wraps() {
        let c = BismoConfig {
            acc_bits: 64,
            ..cfg()
        };
        let mut bufs = MatrixBuffers::new(&c);
        for b in 0..4 {
            bufs.write_word(b, 0, &[u64::MAX]).unwrap(); // popcount 64
        }
        let mut unit = ExecuteUnit::new(&c);
        let mut rb = ResultBuffer::new(&c);
        exec(&mut unit, &bufs, &mut rb, basic_run(1, 40, false, true));
        assert_eq!(unit.accumulators(), &[64i64 << 40; 4]);
        assert_eq!(unit.overflows, 0);
    }

    #[test]
    fn scratch_reuse_across_instructions() {
        // Many back-to-back instructions share the hoisted scratch; the
        // numerics must match a fresh unit per instruction.
        let c = cfg();
        let mut bufs = MatrixBuffers::new(&c);
        bufs.write_word(0, 0, &[0b1011]).unwrap();
        bufs.write_word(1, 0, &[0b0111]).unwrap();
        bufs.write_word(2, 0, &[0b1101]).unwrap();
        bufs.write_word(3, 0, &[0b1110]).unwrap();
        let mut unit = ExecuteUnit::new(&c);
        let mut rb = ResultBuffer::new(&c);
        exec(&mut unit, &bufs, &mut rb, basic_run(1, 0, false, true));
        let first = unit.accumulators().to_vec();
        for _ in 0..5 {
            exec(&mut unit, &bufs, &mut rb, basic_run(1, 0, false, true));
            assert_eq!(unit.accumulators(), &first[..]);
        }
    }

    #[test]
    fn extreme_shift_weights_never_panic() {
        // shift = 62 with dense data drives |weight·popcount| far past
        // i64 range after a few accumulations; wrapping arithmetic must
        // keep going (the register wraps, it does not trap).
        let c = BismoConfig {
            acc_bits: 32,
            ..cfg()
        };
        let mut bufs = MatrixBuffers::new(&c);
        for b in 0..4 {
            bufs.write_word(b, 0, &[u64::MAX]).unwrap();
        }
        let mut unit = ExecuteUnit::new(&c);
        let mut rb = ResultBuffer::new(&c);
        exec(&mut unit, &bufs, &mut rb, basic_run(1, 62, false, true));
        for _ in 0..4 {
            exec(&mut unit, &bufs, &mut rb, basic_run(1, 62, true, false));
        }
        assert!(unit.overflows > 0);
        // Same for the 64-bit full-width path.
        let c64 = BismoConfig {
            acc_bits: 64,
            ..cfg()
        };
        let mut u64unit = ExecuteUnit::new(&c64);
        exec(&mut u64unit, &bufs, &mut rb, basic_run(1, 62, false, true));
        exec(&mut u64unit, &bufs, &mut rb, basic_run(1, 62, false, false));
    }

    #[test]
    fn wrap_value_total_over_extremes() {
        assert_eq!(ExecuteUnit::wrap_value(8, 128), -128);
        assert_eq!(ExecuteUnit::wrap_value(8, -129), 127);
        assert_eq!(ExecuteUnit::wrap_value(1, 3), 1 - 2); // 1-bit reg: {-1, 0}
        assert_eq!(ExecuteUnit::wrap_value(63, i64::MAX), -1);
        assert_eq!(ExecuteUnit::wrap_value(32, i64::MIN), 0);
    }

    #[test]
    fn restore_state_roundtrip() {
        let c = cfg();
        let mut unit = ExecuteUnit::new(&c);
        unit.restore_state(&[1, -2, 3, -4], 7).unwrap();
        assert_eq!(unit.accumulators(), &[1, -2, 3, -4]);
        assert_eq!(unit.overflows, 7);
        assert!(unit.restore_state(&[1, 2], 0).is_err());
    }

    #[test]
    fn out_of_range_read_rejected() {
        let c = cfg();
        let bufs = MatrixBuffers::new(&c);
        let mut unit = ExecuteUnit::new(&c);
        let mut rb = ResultBuffer::new(&c);
        let e = ExecuteRun {
            lhs_offset: 1023,
            ..basic_run(2, 0, false, true)
        };
        assert!(unit.run(&e, &bufs, &mut rb).is_err());
    }
}
