//! The simulation engine: runs the three stage processes to completion
//! as a discrete-event fixpoint, with deadlock detection.
//!
//! Each stage is a sequential process with a local clock. Engine order
//! respects the token protocol: a stage blocked on `Wait` cannot advance
//! (or mutate shared state) until the producing stage has signalled —
//! so functional updates happen in a token-consistent order, matching
//! hardware for any correctly-synchronized schedule. Races *between*
//! synchronization points (a schedule that lets fetch overwrite a buffer
//! region execute is still reading) are schedule bugs in hardware too;
//! the engine executes them deterministically (fetch → execute → result
//! priority) rather than diagnosing them.
//!
//! The engine is *suspendable*: [`Simulation::begin`] arms a program and
//! [`Simulation::step`] advances it by a bounded number of instructions,
//! so a long job can be paused mid-run, snapshotted
//! ([`Simulation::snapshot`]), persisted, and later resumed bit- and
//! cycle-exactly from [`Simulation::restore`]. The scheduler is a
//! persistent round-robin cursor that executes instructions in exactly
//! the same greedy order as an uninterrupted run, which is what makes
//! suspension invisible to the result (DESIGN.md §10).

use super::buffers::{MatrixBuffers, ResultBuffer};
use super::dram::DmaTiming;
use super::execute::ExecuteUnit;
use super::fetch::FetchUnit;
use super::result::ResultUnit;
use super::snapshot::{FifoState, SimSnapshot};
use super::{RunStats, StageFault, TokenFifo};
use crate::api::BismoError;
use crate::arch::{BismoConfig, Platform};
use crate::bitmatrix::dram::DramImage;
use crate::isa::{Instr, Program, Stage, SyncChannel};
use crate::util::ceil_div;

/// Run-time simulation failure modes. Invalid configurations and
/// illegal programs never reach the simulator as `SimError`s: they are
/// rejected up front as [`crate::api::BismoError::InvalidConfig`] /
/// [`crate::api::BismoError::IllegalProgram`] — the structured variants
/// the rest of the crate uses — so no stringly-typed validation error
/// crosses a public boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SimError {
    /// No stage can make progress but instructions remain.
    Deadlock {
        /// (stage, next-pc, description of what it is blocked on)
        blocked: Vec<(&'static str, usize, String)>,
    },
    /// A Run instruction faulted (out-of-range access, over/underflow).
    Fault {
        stage: &'static str,
        pc: usize,
        msg: String,
    },
    /// An instruction budget ran out before the program completed
    /// (see `MatmulOptions::max_instrs`): the caller asked for a bounded
    /// run and the bound was hit.
    BudgetExceeded {
        /// The instruction budget that was exhausted.
        budget: u64,
    },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock { blocked } => {
                write!(f, "deadlock:")?;
                for (s, pc, what) in blocked {
                    write!(f, " [{s}@{pc}: {what}]")?;
                }
                Ok(())
            }
            SimError::Fault { stage, pc, msg } => {
                write!(f, "fault in {stage} queue at {pc}: {msg}")
            }
            SimError::BudgetExceeded { budget } => {
                write!(
                    f,
                    "instruction budget of {budget} exhausted before the program completed"
                )
            }
        }
    }
}

impl std::error::Error for SimError {}

/// One recorded span of stage activity (for Fig. 5-style timelines).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub stage: Stage,
    /// Short label: "F3 RunFetch", "E2 Wait", ...
    pub label: String,
    /// Start cycle (inclusive).
    pub start: u64,
    /// End cycle (exclusive).
    pub end: u64,
    /// Was this a stall (Wait blocked on a token)?
    pub stalled: bool,
}

/// Outcome of one bounded [`Simulation::step`] call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StepOutcome {
    /// The program ran to completion; final statistics attached.
    Completed(RunStats),
    /// The instruction budget ran out first; the simulation is paused at
    /// a consistent point and can be stepped again (or snapshotted).
    Suspended,
}

/// One overlay instance simulating programs against a DRAM image.
pub struct Simulation {
    cfg: BismoConfig,
    /// Main-memory image: operands in, results out.
    pub dram: DramImage,
    fetch_unit: FetchUnit,
    result_unit: ResultUnit,
    exec: ExecuteUnit,
    bufs: MatrixBuffers,
    result_buf: ResultBuffer,
    fifos: [TokenFifo; 4],
    trace: Option<Vec<TraceEvent>>,
    /// Scheduler state of the in-flight program (persistent so a run can
    /// suspend between [`Simulation::step`] calls).
    state: EngineState,
    /// Statistics accumulated so far by the in-flight program.
    stats: RunStats,
}

fn fifo_idx(ch: SyncChannel) -> usize {
    match ch {
        SyncChannel::FetchToExecute => 0,
        SyncChannel::ExecuteToFetch => 1,
        SyncChannel::ExecuteToResult => 2,
        SyncChannel::ResultToExecute => 3,
    }
}

/// Persistent scheduler state: per-stage program counters and local
/// clocks, the round-robin cursor, and the no-progress streak used for
/// deadlock detection. Captured verbatim by snapshots.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct EngineState {
    /// Per-stage next-instruction index (fetch, execute, result).
    pc: [usize; 3],
    /// Per-stage local clocks.
    t: [u64; 3],
    /// Round-robin cursor: which stage to try next.
    cur: usize,
    /// Consecutive stages that failed to advance; 3 means deadlock.
    stall_streak: usize,
    /// A program is armed (begin() called, not yet completed/faulted).
    running: bool,
    /// Fingerprint of the armed program — step() and restore() verify
    /// they are driven with the same program the state belongs to.
    fingerprint: u64,
}

impl Simulation {
    /// Build one instance. The configuration is validated first; a bad
    /// one is rejected as [`BismoError::InvalidConfig`].
    pub fn new(
        cfg: BismoConfig,
        platform: &Platform,
        dram: DramImage,
    ) -> Result<Self, BismoError> {
        cfg.validate()?;
        Ok(Simulation {
            fetch_unit: FetchUnit {
                timing: DmaTiming::fetch(&cfg, platform),
                words_per_chunk: ceil_div(cfg.dk as u64, 64) as usize,
            },
            result_unit: ResultUnit {
                timing: DmaTiming::result(&cfg, platform),
                dn: cfg.dn as usize,
            },
            exec: ExecuteUnit::new(&cfg),
            bufs: MatrixBuffers::new(&cfg),
            result_buf: ResultBuffer::new(&cfg),
            fifos: Default::default(),
            trace: None,
            state: EngineState::default(),
            stats: RunStats::default(),
            cfg,
            dram,
        })
    }

    pub fn config(&self) -> &BismoConfig {
        &self.cfg
    }

    /// Record per-instruction activity spans during `run` (Fig. 5
    /// timelines). Call before `run`; retrieve with [`Simulation::trace`].
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Recorded trace events (empty unless `enable_trace` was called).
    pub fn trace(&self) -> &[TraceEvent] {
        self.trace.as_deref().unwrap_or(&[])
    }

    fn record(
        &mut self,
        stage: Stage,
        pc: usize,
        instr: &Instr,
        start: u64,
        end: u64,
        stalled: bool,
    ) {
        if let Some(t) = self.trace.as_mut() {
            let kind = match instr {
                Instr::Wait(_) => "Wait",
                Instr::Signal(_) => "Signal",
                Instr::Fetch(_) => "RunFetch",
                Instr::Execute(_) => "RunExecute",
                Instr::Result(_) => "RunResult",
            };
            let tag = match stage {
                Stage::Fetch => 'F',
                Stage::Execute => 'E',
                Stage::Result => 'R',
            };
            t.push(TraceEvent {
                stage,
                label: format!("{tag}{} {kind}", pc + 1),
                start,
                end,
                stalled,
            });
        }
    }

    /// Maximum depth each sync FIFO reached (hardware sizing datum).
    pub fn fifo_high_water(&self) -> [(SyncChannel, usize); 4] {
        SyncChannel::ALL.map(|ch| (ch, self.fifos[fifo_idx(ch)].max_depth))
    }

    /// Run a program to completion. Illegal programs are rejected up
    /// front as [`BismoError::IllegalProgram`]; run-time deadlocks and
    /// stage faults surface as [`BismoError::SimFault`].
    pub fn run(&mut self, prog: &Program) -> Result<RunStats, BismoError> {
        self.begin(prog)?;
        match self.step(prog, u64::MAX)? {
            StepOutcome::Completed(stats) => Ok(stats),
            // Unreachable: u64::MAX instructions cannot be exhausted by
            // a validated (bounded-length) program.
            StepOutcome::Suspended => Err(SimError::BudgetExceeded { budget: u64::MAX }.into()),
        }
    }

    /// Arm `prog` for bounded execution via [`Simulation::step`].
    /// Validates the program and resets the scheduler state and per-run
    /// statistics; buffer/DRAM/accumulator contents persist (exactly as
    /// consecutive [`Simulation::run`] calls always behaved).
    pub fn begin(&mut self, prog: &Program) -> Result<(), BismoError> {
        prog.validate()?;
        self.state = EngineState {
            running: true,
            fingerprint: prog.fingerprint(),
            ..EngineState::default()
        };
        self.stats = RunStats::default();
        Ok(())
    }

    /// Advance the armed program by at most `budget` instructions.
    ///
    /// Returns [`StepOutcome::Completed`] with the final statistics when
    /// the program finishes, or [`StepOutcome::Suspended`] when the
    /// budget runs out first — in which case the simulation can be
    /// stepped again, or captured with [`Simulation::snapshot`] and
    /// resumed later. Instructions are executed in exactly the same
    /// order as an uninterrupted run, so suspension never changes the
    /// result or the cycle counts.
    pub fn step(&mut self, prog: &Program, mut budget: u64) -> Result<StepOutcome, BismoError> {
        if !self.state.running {
            return Err(BismoError::IllegalProgram(
                "no program armed: call begin() before step()".into(),
            ));
        }
        if self.state.fingerprint != prog.fingerprint() {
            return Err(BismoError::IllegalProgram(
                "step() driven with a different program than begin()".into(),
            ));
        }
        let queues = [&prog.fetch, &prog.execute, &prog.result];
        let stage_of = [Stage::Fetch, Stage::Execute, Stage::Result];
        loop {
            if (0..3).all(|s| self.state.pc[s] >= queues[s].len()) {
                self.state.running = false;
                self.stats.cycles = self.state.t.iter().copied().max().unwrap_or(0);
                self.stats.acc_overflows = self.exec.overflows;
                return Ok(StepOutcome::Completed(self.stats));
            }
            if budget == 0 {
                return Ok(StepOutcome::Suspended);
            }
            let s = self.state.cur;
            let advanced = if self.state.pc[s] < queues[s].len() {
                match self.try_advance(s, stage_of[s], queues[s]) {
                    Ok(a) => a,
                    Err(e) => {
                        self.state.running = false;
                        return Err(e);
                    }
                }
            } else {
                false
            };
            if advanced {
                // Stay on this stage — the greedy engine drains a stage
                // before moving on, matching hardware stage autonomy.
                budget -= 1;
                self.state.stall_streak = 0;
            } else {
                self.state.stall_streak += 1;
                if self.state.stall_streak >= 3 {
                    // All three stages failed in a row with no progress
                    // in between: classic token deadlock.
                    self.state.running = false;
                    let blocked = (0..3)
                        .filter(|&s| self.state.pc[s] < queues[s].len())
                        .map(|s| {
                            let what = match &queues[s][self.state.pc[s]] {
                                Instr::Wait(ch) => format!("waiting on {}", ch.name()),
                                other => format!("stuck at {other}"),
                            };
                            (stage_of[s].name(), self.state.pc[s], what)
                        })
                        .collect();
                    return Err(SimError::Deadlock { blocked }.into());
                }
                self.state.cur = (s + 1) % 3;
            }
        }
    }

    /// Execute the next instruction of stage `s` if it is not blocked.
    /// Returns `Ok(true)` on progress, `Ok(false)` if the stage is
    /// blocked on an empty token FIFO.
    fn try_advance(&mut self, s: usize, stage: Stage, queue: &[Instr]) -> Result<bool, BismoError> {
        let pc = self.state.pc[s];
        let instr = &queue[pc];
        let t_before = self.state.t[s];
        let mut stalled = false;
        match instr {
            Instr::Signal(ch) => {
                self.state.t[s] += 1;
                let t = self.state.t[s];
                self.fifos[fifo_idx(*ch)].push(t);
            }
            Instr::Wait(ch) => {
                let fifo = &mut self.fifos[fifo_idx(*ch)];
                match fifo.front() {
                    Some(tok_t) => {
                        fifo.pop();
                        let ready = self.state.t[s].max(tok_t);
                        let stall = ready - self.state.t[s];
                        stalled = stall > 0;
                        match stage {
                            Stage::Fetch => self.stats.fetch_stall += stall,
                            Stage::Execute => self.stats.execute_stall += stall,
                            Stage::Result => self.stats.result_stall += stall,
                        }
                        self.state.t[s] = ready + 1;
                    }
                    None => return Ok(false), // blocked; retry after others advance
                }
            }
            Instr::Fetch(fr) => {
                let (cy, bytes) = self
                    .fetch_unit
                    .run(fr, &self.dram, &mut self.bufs)
                    .map_err(|e| SimError::Fault {
                        stage: "fetch",
                        pc,
                        msg: e.0,
                    })?;
                self.state.t[s] += cy;
                self.stats.fetch_busy += cy;
                self.stats.bytes_fetched += bytes;
            }
            Instr::Execute(er) => {
                let (cy, ops, fill, committed) = self
                    .exec
                    .run(er, &self.bufs, &mut self.result_buf)
                    .map_err(|e| SimError::Fault {
                        stage: "execute",
                        pc,
                        msg: e.0,
                    })?;
                self.state.t[s] += cy;
                self.stats.execute_busy += cy;
                self.stats.binary_ops += ops;
                self.stats.pipeline_fill_cycles += fill;
                self.stats.commits += committed as u64;
            }
            Instr::Result(rr) => {
                let (cy, bytes) = self
                    .result_unit
                    .run(rr, &mut self.result_buf, &mut self.dram)
                    .map_err(|e| SimError::Fault {
                        stage: "result",
                        pc,
                        msg: e.0,
                    })?;
                self.state.t[s] += cy;
                self.stats.result_busy += cy;
                self.stats.bytes_written += bytes;
            }
        }
        self.record(stage, pc, instr, t_before, self.state.t[s], stalled);
        self.state.pc[s] += 1;
        Ok(true)
    }

    /// Capture the complete simulation state: scheduler position, local
    /// clocks, token FIFOs, matrix/result buffer contents, accumulators
    /// and the full DRAM image. The trace (if enabled) is *not*
    /// captured — it is a debugging aid, not simulation state.
    pub fn snapshot(&self) -> SimSnapshot {
        SimSnapshot {
            cfg: self.cfg,
            running: self.state.running,
            cur: self.state.cur,
            stall_streak: self.state.stall_streak,
            pc: self.state.pc,
            t: self.state.t,
            fingerprint: self.state.fingerprint,
            stats: self.stats,
            fifos: std::array::from_fn(|i| FifoState {
                tokens: self.fifos[i].tokens(),
                max_depth: self.fifos[i].max_depth,
                total: self.fifos[i].total,
            }),
            lhs: self.bufs.lhs_data().to_vec(),
            rhs: self.bufs.rhs_data().to_vec(),
            result_slots: self.result_buf.committed(),
            result_max_occupancy: self.result_buf.max_occupancy,
            accs: self.exec.accumulators().to_vec(),
            overflows: self.exec.overflows,
            dram: self.dram.as_bytes().to_vec(),
        }
    }

    /// Rebuild a simulation from a snapshot. The resumed instance
    /// continues bit- and cycle-exactly where [`Simulation::snapshot`]
    /// left off (drive it with the same program via
    /// [`Simulation::step`]). Inconsistent snapshots are rejected as
    /// [`BismoError::Parse`].
    pub fn restore(snap: &SimSnapshot, platform: &Platform) -> Result<Self, BismoError> {
        let bad = |e: StageFault| BismoError::Parse(format!("snapshot: {e}"));
        let mut sim =
            Simulation::new(snap.cfg, platform, DramImage::from_bytes(snap.dram.clone()))?;
        if snap.cur >= 3 {
            return Err(BismoError::Parse(format!(
                "snapshot: round-robin cursor {} out of range",
                snap.cur
            )));
        }
        sim.bufs.restore_contents(&snap.lhs, &snap.rhs).map_err(bad)?;
        sim.result_buf
            .restore_contents(snap.result_slots.clone(), snap.result_max_occupancy)
            .map_err(bad)?;
        sim.exec
            .restore_state(&snap.accs, snap.overflows)
            .map_err(bad)?;
        for (i, f) in snap.fifos.iter().enumerate() {
            sim.fifos[i] = TokenFifo::from_parts(f.tokens.clone(), f.max_depth, f.total);
        }
        sim.state = EngineState {
            pc: snap.pc,
            t: snap.t,
            cur: snap.cur,
            stall_streak: snap.stall_streak,
            running: snap.running,
            fingerprint: snap.fingerprint,
        };
        sim.stats = snap.stats;
        Ok(sim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PYNQ_Z1;
    use crate::bitmatrix::dram::{DramImage, OperandLayout, ResultLayout};
    use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
    use crate::isa::{ExecuteRun, FetchRun, ResultRun};

    fn cfg() -> BismoConfig {
        BismoConfig::small()
    }

    /// Hand-built program: binary 2×64×2 matmul, the smallest end-to-end
    /// flow exercising all three stages (in the spirit of Table III).
    fn binary_2x64x2() -> (Program, DramImage, IntMatrix, ResultLayout) {
        let c = cfg();
        let mut rng = crate::util::Rng::new(0xE2E);
        let a = IntMatrix::random(&mut rng, 2, 64, 1, false);
        let b = IntMatrix::random(&mut rng, 64, 2, 1, false);
        let expect = a.matmul(&b);
        let la = BitSerialMatrix::from_int(&a, 1, false);
        let rb = BitSerialMatrix::from_int(&b.transpose(), 1, false);

        let lhs_lay = OperandLayout::new(0, 2, 64, 1, c.dk);
        let rhs_lay = OperandLayout::new(lhs_lay.total_bytes(), 2, 64, 1, c.dk);
        let res_lay = ResultLayout::new(lhs_lay.total_bytes() + rhs_lay.total_bytes(), 2, 2);
        let mut dram = DramImage::new((res_lay.base + res_lay.total_bytes()) as usize);
        lhs_lay.store(&mut dram, &la);
        rhs_lay.store(&mut dram, &rb);

        let mut p = Program::new();
        // Fetch both operands: 2 rows each, one 8-byte chunk per row.
        p.push(
            Stage::Fetch,
            Instr::Fetch(FetchRun {
                dram_base: lhs_lay.base,
                block_bytes: 8,
                block_stride_bytes: lhs_lay.row_bytes() as u32,
                num_blocks: 2,
                buf_offset: 0,
                buf_start: 0,
                buf_range: 2,
                words_per_buf: 1,
            }),
        );
        p.push(
            Stage::Fetch,
            Instr::Fetch(FetchRun {
                dram_base: rhs_lay.base,
                block_bytes: 8,
                block_stride_bytes: rhs_lay.row_bytes() as u32,
                num_blocks: 2,
                buf_offset: 0,
                buf_start: 2,
                buf_range: 2,
                words_per_buf: 1,
            }),
        );
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        p.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        p.push(
            Stage::Execute,
            Instr::Execute(ExecuteRun {
                lhs_offset: 0,
                rhs_offset: 0,
                num_chunks: 1,
                shift: 0,
                negate: false,
                acc_reset: true,
                commit_result: true,
            }),
        );
        p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToResult));
        p.push(Stage::Result, Instr::Wait(SyncChannel::ExecuteToResult));
        p.push(
            Stage::Result,
            Instr::Result(ResultRun {
                dram_base: res_lay.base,
                offset: 0,
                rows: 2,
                cols: 2,
                row_stride_bytes: 8,
            }),
        );
        (p, dram, expect, res_lay)
    }

    #[test]
    fn end_to_end_binary_matmul() {
        let (p, dram, expect, res_lay) = binary_2x64x2();
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, dram).unwrap();
        let stats = sim.run(&p).unwrap();
        assert_eq!(res_lay.load(&sim.dram), expect);
        assert!(stats.cycles > 0);
        assert_eq!(stats.bytes_fetched, 32);
        assert_eq!(stats.bytes_written, 16);
        assert_eq!(stats.binary_ops, 2 * 2 * 2 * 64);
        assert_eq!(stats.commits, 1);
        assert_eq!(stats.acc_overflows, 0);
        // Execute must have stalled for the fetch (serial dependency).
        assert!(stats.execute_stall > 0);
    }

    #[test]
    fn timing_is_causal_and_stable() {
        let (p, dram, _, _) = binary_2x64x2();
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, dram.clone()).unwrap();
        let s1 = sim.run(&p).unwrap();
        // Total must be at least each stage's busy time and deterministic.
        assert!(s1.cycles >= s1.fetch_busy);
        assert!(s1.cycles >= s1.execute_busy + s1.execute_stall);
        let mut sim2 = Simulation::new(cfg(), &PYNQ_Z1, dram).unwrap();
        assert_eq!(sim2.run(&p).unwrap(), s1);
    }

    #[test]
    fn deadlock_detected() {
        let mut p = Program::new();
        p.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        p.push(Stage::Fetch, Instr::Wait(SyncChannel::ExecuteToFetch));
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToFetch));
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, DramImage::new(64)).unwrap();
        match sim.run(&p) {
            Err(BismoError::SimFault(SimError::Deadlock { blocked })) => {
                assert_eq!(blocked.len(), 2);
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn result_underflow_is_fault() {
        let mut p = Program::new();
        p.push(
            Stage::Result,
            Instr::Result(ResultRun {
                dram_base: 0,
                offset: 0,
                rows: 1,
                cols: 1,
                row_stride_bytes: 4,
            }),
        );
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, DramImage::new(64)).unwrap();
        match sim.run(&p) {
            Err(BismoError::SimFault(SimError::Fault { stage, .. })) => {
                assert_eq!(stage, "result")
            }
            other => panic!("expected fault, got {other:?}"),
        }
    }

    #[test]
    fn token_imbalance_rejected_up_front() {
        let mut p = Program::new();
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, DramImage::new(64)).unwrap();
        assert!(matches!(sim.run(&p), Err(BismoError::IllegalProgram(_))));
    }

    #[test]
    fn budgeted_step_suspends_and_resumes_in_place() {
        let (p, dram, expect, res_lay) = binary_2x64x2();
        // Uninterrupted reference.
        let mut base = Simulation::new(cfg(), &PYNQ_Z1, dram.clone()).unwrap();
        let ref_stats = base.run(&p).unwrap();
        // One instruction at a time.
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, dram).unwrap();
        sim.begin(&p).unwrap();
        let mut steps = 0;
        let stats = loop {
            match sim.step(&p, 1).unwrap() {
                StepOutcome::Completed(s) => break s,
                StepOutcome::Suspended => steps += 1,
            }
            assert!(steps < 10_000, "budgeted run failed to terminate");
        };
        assert_eq!(stats, ref_stats);
        assert_eq!(res_lay.load(&sim.dram), expect);
        // Every call retires exactly one instruction; the final call
        // sees completion, so it suspends total − 1 times.
        assert_eq!(steps as usize + 1, p.stats().total);
    }

    #[test]
    fn snapshot_restore_is_bit_and_cycle_exact_across_suspend_points() {
        let (p, dram, expect, res_lay) = binary_2x64x2();
        let mut base = Simulation::new(cfg(), &PYNQ_Z1, dram.clone()).unwrap();
        let ref_stats = base.run(&p).unwrap();
        let total = p.stats().total as u64;
        // Suspend at every possible instruction boundary, snapshot,
        // restore into a fresh instance, and finish there.
        for cut in 0..=total {
            let mut sim = Simulation::new(cfg(), &PYNQ_Z1, dram.clone()).unwrap();
            sim.begin(&p).unwrap();
            match sim.step(&p, cut).unwrap() {
                StepOutcome::Completed(s) => {
                    assert_eq!(cut, total);
                    assert_eq!(s, ref_stats);
                    continue;
                }
                StepOutcome::Suspended => {}
            }
            let snap = sim.snapshot();
            let mut resumed = Simulation::restore(&snap, &PYNQ_Z1).unwrap();
            match resumed.step(&p, u64::MAX).unwrap() {
                StepOutcome::Completed(s) => assert_eq!(s, ref_stats, "cut at {cut}"),
                StepOutcome::Suspended => panic!("unbounded step suspended"),
            }
            assert_eq!(res_lay.load(&resumed.dram), expect, "cut at {cut}");
            assert_eq!(
                resumed.dram.as_bytes(),
                base.dram.as_bytes(),
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn step_requires_begin_and_matching_program() {
        let (p, dram, _, _) = binary_2x64x2();
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, dram).unwrap();
        assert!(matches!(
            sim.step(&p, 1),
            Err(BismoError::IllegalProgram(_))
        ));
        sim.begin(&p).unwrap();
        let mut other = Program::new();
        other.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        other.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        assert!(matches!(
            sim.step(&other, 1),
            Err(BismoError::IllegalProgram(_))
        ));
        // The armed program still steps fine.
        assert!(sim.step(&p, 1).is_ok());
    }

    #[test]
    fn stage_overlap_reduces_makespan() {
        // Two independent fetch+execute rounds: with tokens allowing
        // lookahead, fetch round 2 overlaps execute round 1.
        let c = cfg();
        let mut dram = DramImage::new(1024);
        for i in 0..128 {
            dram.write_u64(i * 8, i as u64);
        }
        let mk_fetch = |base: u64, off: u32| {
            Instr::Fetch(FetchRun {
                dram_base: base,
                block_bytes: 256,
                block_stride_bytes: 0,
                num_blocks: 1,
                buf_offset: off,
                buf_start: 0,
                buf_range: 4,
                words_per_buf: 8,
            })
        };
        let mk_exec = |off: u32| {
            Instr::Execute(ExecuteRun {
                lhs_offset: off,
                rhs_offset: off,
                num_chunks: 8,
                shift: 0,
                negate: false,
                acc_reset: true,
                commit_result: false,
            })
        };
        // Overlapped: both fetches issued before waiting.
        let mut over = Program::new();
        over.push(Stage::Fetch, mk_fetch(0, 0));
        over.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        over.push(Stage::Fetch, mk_fetch(256, 8));
        over.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        over.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        over.push(Stage::Execute, mk_exec(0));
        over.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        over.push(Stage::Execute, mk_exec(8));
        // Serialized: execute acknowledges each fetch before the next.
        let mut ser = Program::new();
        ser.push(Stage::Fetch, mk_fetch(0, 0));
        ser.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        ser.push(Stage::Fetch, Instr::Wait(SyncChannel::ExecuteToFetch));
        ser.push(Stage::Fetch, mk_fetch(256, 8));
        ser.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        ser.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        ser.push(Stage::Execute, mk_exec(0));
        ser.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToFetch));
        ser.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        ser.push(Stage::Execute, mk_exec(8));

        let t_over = Simulation::new(c, &PYNQ_Z1, dram.clone())
            .unwrap()
            .run(&over)
            .unwrap()
            .cycles;
        let t_ser = Simulation::new(c, &PYNQ_Z1, dram)
            .unwrap()
            .run(&ser)
            .unwrap()
            .cycles;
        assert!(
            t_over < t_ser,
            "overlap ({t_over}) should beat serialized ({t_ser})"
        );
    }
}
