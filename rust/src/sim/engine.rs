//! The simulation engine: runs the three stage processes to completion
//! as a discrete-event fixpoint, with deadlock detection.
//!
//! Each stage is a sequential process with a local clock. Engine order
//! respects the token protocol: a stage blocked on `Wait` cannot advance
//! (or mutate shared state) until the producing stage has signalled —
//! so functional updates happen in a token-consistent order, matching
//! hardware for any correctly-synchronized schedule. Races *between*
//! synchronization points (a schedule that lets fetch overwrite a buffer
//! region execute is still reading) are schedule bugs in hardware too;
//! the engine executes them deterministically (fetch → execute → result
//! priority) rather than diagnosing them.

use super::buffers::{MatrixBuffers, ResultBuffer};
use super::dram::DmaTiming;
use super::execute::ExecuteUnit;
use super::fetch::FetchUnit;
use super::result::ResultUnit;
use super::{RunStats, TokenFifo};
use crate::api::BismoError;
use crate::arch::{BismoConfig, Platform};
use crate::bitmatrix::dram::DramImage;
use crate::isa::{Instr, Program, Stage, SyncChannel};
use crate::util::ceil_div;

/// Run-time simulation failure modes. Invalid configurations and
/// illegal programs never reach the simulator as `SimError`s: they are
/// rejected up front as [`crate::api::BismoError::InvalidConfig`] /
/// [`crate::api::BismoError::IllegalProgram`] — the structured variants
/// the rest of the crate uses — so no stringly-typed validation error
/// crosses a public boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SimError {
    /// No stage can make progress but instructions remain.
    Deadlock {
        /// (stage, next-pc, description of what it is blocked on)
        blocked: Vec<(&'static str, usize, String)>,
    },
    /// A Run instruction faulted (out-of-range access, over/underflow).
    Fault {
        stage: &'static str,
        pc: usize,
        msg: String,
    },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock { blocked } => {
                write!(f, "deadlock:")?;
                for (s, pc, what) in blocked {
                    write!(f, " [{s}@{pc}: {what}]")?;
                }
                Ok(())
            }
            SimError::Fault { stage, pc, msg } => {
                write!(f, "fault in {stage} queue at {pc}: {msg}")
            }
        }
    }
}

impl std::error::Error for SimError {}

/// One recorded span of stage activity (for Fig. 5-style timelines).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub stage: Stage,
    /// Short label: "F3 RunFetch", "E2 Wait", ...
    pub label: String,
    /// Start cycle (inclusive).
    pub start: u64,
    /// End cycle (exclusive).
    pub end: u64,
    /// Was this a stall (Wait blocked on a token)?
    pub stalled: bool,
}

/// One overlay instance simulating programs against a DRAM image.
pub struct Simulation {
    cfg: BismoConfig,
    /// Main-memory image: operands in, results out.
    pub dram: DramImage,
    fetch_unit: FetchUnit,
    result_unit: ResultUnit,
    exec: ExecuteUnit,
    bufs: MatrixBuffers,
    result_buf: ResultBuffer,
    fifos: [TokenFifo; 4],
    trace: Option<Vec<TraceEvent>>,
}

fn fifo_idx(ch: SyncChannel) -> usize {
    match ch {
        SyncChannel::FetchToExecute => 0,
        SyncChannel::ExecuteToFetch => 1,
        SyncChannel::ExecuteToResult => 2,
        SyncChannel::ResultToExecute => 3,
    }
}

struct StageState {
    pc: usize,
    t: u64,
}

impl Simulation {
    /// Build one instance. The configuration is validated first; a bad
    /// one is rejected as [`BismoError::InvalidConfig`].
    pub fn new(
        cfg: BismoConfig,
        platform: &Platform,
        dram: DramImage,
    ) -> Result<Self, BismoError> {
        cfg.validate()?;
        Ok(Simulation {
            fetch_unit: FetchUnit {
                timing: DmaTiming::fetch(&cfg, platform),
                words_per_chunk: ceil_div(cfg.dk as u64, 64) as usize,
            },
            result_unit: ResultUnit {
                timing: DmaTiming::result(&cfg, platform),
                dn: cfg.dn as usize,
            },
            exec: ExecuteUnit::new(&cfg),
            bufs: MatrixBuffers::new(&cfg),
            result_buf: ResultBuffer::new(&cfg),
            fifos: Default::default(),
            trace: None,
            cfg,
            dram,
        })
    }

    pub fn config(&self) -> &BismoConfig {
        &self.cfg
    }

    /// Record per-instruction activity spans during `run` (Fig. 5
    /// timelines). Call before `run`; retrieve with [`Simulation::trace`].
    pub fn enable_trace(&mut self) {
        self.trace = Some(Vec::new());
    }

    /// Recorded trace events (empty unless `enable_trace` was called).
    pub fn trace(&self) -> &[TraceEvent] {
        self.trace.as_deref().unwrap_or(&[])
    }

    fn record(&mut self, stage: Stage, pc: usize, instr: &Instr, start: u64, end: u64, stalled: bool) {
        if let Some(t) = self.trace.as_mut() {
            let kind = match instr {
                Instr::Wait(_) => "Wait",
                Instr::Signal(_) => "Signal",
                Instr::Fetch(_) => "RunFetch",
                Instr::Execute(_) => "RunExecute",
                Instr::Result(_) => "RunResult",
            };
            let tag = match stage {
                Stage::Fetch => 'F',
                Stage::Execute => 'E',
                Stage::Result => 'R',
            };
            t.push(TraceEvent {
                stage,
                label: format!("{tag}{} {kind}", pc + 1),
                start,
                end,
                stalled,
            });
        }
    }

    /// Maximum depth each sync FIFO reached (hardware sizing datum).
    pub fn fifo_high_water(&self) -> [(SyncChannel, usize); 4] {
        SyncChannel::ALL.map(|ch| (ch, self.fifos[fifo_idx(ch)].max_depth))
    }

    /// Run a program to completion. Illegal programs are rejected up
    /// front as [`BismoError::IllegalProgram`]; run-time deadlocks and
    /// stage faults surface as [`BismoError::SimFault`].
    pub fn run(&mut self, prog: &Program) -> Result<RunStats, BismoError> {
        prog.validate()?;
        let mut stats = RunStats::default();
        let mut st = [
            StageState { pc: 0, t: 0 },
            StageState { pc: 0, t: 0 },
            StageState { pc: 0, t: 0 },
        ];
        let queues = [&prog.fetch, &prog.execute, &prog.result];
        let stage_of = [Stage::Fetch, Stage::Execute, Stage::Result];

        loop {
            let mut progress = false;
            for s in 0..3 {
                // Advance stage `s` as far as it can go.
                while st[s].pc < queues[s].len() {
                    let instr = &queues[s][st[s].pc];
                    let t_before = st[s].t;
                    let mut stalled = false;
                    match instr {
                        Instr::Signal(ch) => {
                            st[s].t += 1;
                            self.fifos[fifo_idx(*ch)].push(st[s].t);
                        }
                        Instr::Wait(ch) => {
                            let fifo = &mut self.fifos[fifo_idx(*ch)];
                            match fifo.front() {
                                Some(tok_t) => {
                                    fifo.pop();
                                    let ready = st[s].t.max(tok_t);
                                    let stall = ready - st[s].t;
                                    stalled = stall > 0;
                                    match stage_of[s] {
                                        Stage::Fetch => stats.fetch_stall += stall,
                                        Stage::Execute => stats.execute_stall += stall,
                                        Stage::Result => stats.result_stall += stall,
                                    }
                                    st[s].t = ready + 1;
                                }
                                None => break, // blocked; retry after others advance
                            }
                        }
                        Instr::Fetch(fr) => {
                            let (cy, bytes) = self
                                .fetch_unit
                                .run(fr, &self.dram, &mut self.bufs)
                                .map_err(|e| SimError::Fault {
                                    stage: "fetch",
                                    pc: st[s].pc,
                                    msg: e.0,
                                })?;
                            st[s].t += cy;
                            stats.fetch_busy += cy;
                            stats.bytes_fetched += bytes;
                        }
                        Instr::Execute(er) => {
                            let (cy, ops, fill, committed) = self
                                .exec
                                .run(er, &self.bufs, &mut self.result_buf)
                                .map_err(|e| SimError::Fault {
                                    stage: "execute",
                                    pc: st[s].pc,
                                    msg: e.0,
                                })?;
                            st[s].t += cy;
                            stats.execute_busy += cy;
                            stats.binary_ops += ops;
                            stats.pipeline_fill_cycles += fill;
                            stats.commits += committed as u64;
                        }
                        Instr::Result(rr) => {
                            let (cy, bytes) = self
                                .result_unit
                                .run(rr, &mut self.result_buf, &mut self.dram)
                                .map_err(|e| SimError::Fault {
                                    stage: "result",
                                    pc: st[s].pc,
                                    msg: e.0,
                                })?;
                            st[s].t += cy;
                            stats.result_busy += cy;
                            stats.bytes_written += bytes;
                        }
                    }
                    self.record(stage_of[s], st[s].pc, instr, t_before, st[s].t, stalled);
                    st[s].pc += 1;
                    progress = true;
                }
            }
            let done = (0..3).all(|s| st[s].pc >= queues[s].len());
            if done {
                break;
            }
            if !progress {
                let blocked = (0..3)
                    .filter(|&s| st[s].pc < queues[s].len())
                    .map(|s| {
                        let what = match &queues[s][st[s].pc] {
                            Instr::Wait(ch) => format!("waiting on {}", ch.name()),
                            other => format!("stuck at {other}"),
                        };
                        (stage_of[s].name(), st[s].pc, what)
                    })
                    .collect();
                return Err(SimError::Deadlock { blocked }.into());
            }
        }

        stats.cycles = st.iter().map(|x| x.t).max().unwrap_or(0);
        stats.acc_overflows = self.exec.overflows;
        Ok(stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PYNQ_Z1;
    use crate::bitmatrix::dram::{DramImage, OperandLayout, ResultLayout};
    use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
    use crate::isa::{ExecuteRun, FetchRun, ResultRun};

    fn cfg() -> BismoConfig {
        BismoConfig::small()
    }

    /// Hand-built program: binary 2×64×2 matmul, the smallest end-to-end
    /// flow exercising all three stages (in the spirit of Table III).
    fn binary_2x64x2() -> (Program, DramImage, IntMatrix, ResultLayout) {
        let c = cfg();
        let mut rng = crate::util::Rng::new(0xE2E);
        let a = IntMatrix::random(&mut rng, 2, 64, 1, false);
        let b = IntMatrix::random(&mut rng, 64, 2, 1, false);
        let expect = a.matmul(&b);
        let la = BitSerialMatrix::from_int(&a, 1, false);
        let rb = BitSerialMatrix::from_int(&b.transpose(), 1, false);

        let lhs_lay = OperandLayout::new(0, 2, 64, 1, c.dk);
        let rhs_lay = OperandLayout::new(lhs_lay.total_bytes(), 2, 64, 1, c.dk);
        let res_lay = ResultLayout::new(lhs_lay.total_bytes() + rhs_lay.total_bytes(), 2, 2);
        let mut dram = DramImage::new((res_lay.base + res_lay.total_bytes()) as usize);
        lhs_lay.store(&mut dram, &la);
        rhs_lay.store(&mut dram, &rb);

        let mut p = Program::new();
        // Fetch both operands: 2 rows each, one 8-byte chunk per row.
        p.push(
            Stage::Fetch,
            Instr::Fetch(FetchRun {
                dram_base: lhs_lay.base,
                block_bytes: 8,
                block_stride_bytes: lhs_lay.row_bytes() as u32,
                num_blocks: 2,
                buf_offset: 0,
                buf_start: 0,
                buf_range: 2,
                words_per_buf: 1,
            }),
        );
        p.push(
            Stage::Fetch,
            Instr::Fetch(FetchRun {
                dram_base: rhs_lay.base,
                block_bytes: 8,
                block_stride_bytes: rhs_lay.row_bytes() as u32,
                num_blocks: 2,
                buf_offset: 0,
                buf_start: 2,
                buf_range: 2,
                words_per_buf: 1,
            }),
        );
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        p.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        p.push(
            Stage::Execute,
            Instr::Execute(ExecuteRun {
                lhs_offset: 0,
                rhs_offset: 0,
                num_chunks: 1,
                shift: 0,
                negate: false,
                acc_reset: true,
                commit_result: true,
            }),
        );
        p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToResult));
        p.push(Stage::Result, Instr::Wait(SyncChannel::ExecuteToResult));
        p.push(
            Stage::Result,
            Instr::Result(ResultRun {
                dram_base: res_lay.base,
                offset: 0,
                rows: 2,
                cols: 2,
                row_stride_bytes: 8,
            }),
        );
        (p, dram, expect, res_lay)
    }

    #[test]
    fn end_to_end_binary_matmul() {
        let (p, dram, expect, res_lay) = binary_2x64x2();
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, dram).unwrap();
        let stats = sim.run(&p).unwrap();
        assert_eq!(res_lay.load(&sim.dram), expect);
        assert!(stats.cycles > 0);
        assert_eq!(stats.bytes_fetched, 32);
        assert_eq!(stats.bytes_written, 16);
        assert_eq!(stats.binary_ops, 2 * 2 * 2 * 64);
        assert_eq!(stats.commits, 1);
        assert_eq!(stats.acc_overflows, 0);
        // Execute must have stalled for the fetch (serial dependency).
        assert!(stats.execute_stall > 0);
    }

    #[test]
    fn timing_is_causal_and_stable() {
        let (p, dram, _, _) = binary_2x64x2();
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, dram.clone()).unwrap();
        let s1 = sim.run(&p).unwrap();
        // Total must be at least each stage's busy time and deterministic.
        assert!(s1.cycles >= s1.fetch_busy);
        assert!(s1.cycles >= s1.execute_busy + s1.execute_stall);
        let mut sim2 = Simulation::new(cfg(), &PYNQ_Z1, dram).unwrap();
        assert_eq!(sim2.run(&p).unwrap(), s1);
    }

    #[test]
    fn deadlock_detected() {
        let mut p = Program::new();
        p.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        p.push(Stage::Fetch, Instr::Wait(SyncChannel::ExecuteToFetch));
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToFetch));
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, DramImage::new(64)).unwrap();
        match sim.run(&p) {
            Err(BismoError::SimFault(SimError::Deadlock { blocked })) => {
                assert_eq!(blocked.len(), 2);
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn result_underflow_is_fault() {
        let mut p = Program::new();
        p.push(
            Stage::Result,
            Instr::Result(ResultRun {
                dram_base: 0,
                offset: 0,
                rows: 1,
                cols: 1,
                row_stride_bytes: 4,
            }),
        );
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, DramImage::new(64)).unwrap();
        match sim.run(&p) {
            Err(BismoError::SimFault(SimError::Fault { stage, .. })) => {
                assert_eq!(stage, "result")
            }
            other => panic!("expected fault, got {other:?}"),
        }
    }

    #[test]
    fn token_imbalance_rejected_up_front() {
        let mut p = Program::new();
        p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        let mut sim = Simulation::new(cfg(), &PYNQ_Z1, DramImage::new(64)).unwrap();
        assert!(matches!(sim.run(&p), Err(BismoError::IllegalProgram(_))));
    }

    #[test]
    fn stage_overlap_reduces_makespan() {
        // Two independent fetch+execute rounds: with tokens allowing
        // lookahead, fetch round 2 overlaps execute round 1.
        let c = cfg();
        let mut dram = DramImage::new(1024);
        for i in 0..128 {
            dram.write_u64(i * 8, i as u64);
        }
        let mk_fetch = |base: u64, off: u32| {
            Instr::Fetch(FetchRun {
                dram_base: base,
                block_bytes: 256,
                block_stride_bytes: 0,
                num_blocks: 1,
                buf_offset: off,
                buf_start: 0,
                buf_range: 4,
                words_per_buf: 8,
            })
        };
        let mk_exec = |off: u32| {
            Instr::Execute(ExecuteRun {
                lhs_offset: off,
                rhs_offset: off,
                num_chunks: 8,
                shift: 0,
                negate: false,
                acc_reset: true,
                commit_result: false,
            })
        };
        // Overlapped: both fetches issued before waiting.
        let mut over = Program::new();
        over.push(Stage::Fetch, mk_fetch(0, 0));
        over.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        over.push(Stage::Fetch, mk_fetch(256, 8));
        over.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        over.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        over.push(Stage::Execute, mk_exec(0));
        over.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        over.push(Stage::Execute, mk_exec(8));
        // Serialized: execute acknowledges each fetch before the next.
        let mut ser = Program::new();
        ser.push(Stage::Fetch, mk_fetch(0, 0));
        ser.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        ser.push(Stage::Fetch, Instr::Wait(SyncChannel::ExecuteToFetch));
        ser.push(Stage::Fetch, mk_fetch(256, 8));
        ser.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
        ser.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        ser.push(Stage::Execute, mk_exec(0));
        ser.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToFetch));
        ser.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
        ser.push(Stage::Execute, mk_exec(8));

        let t_over = Simulation::new(c, &PYNQ_Z1, dram.clone())
            .unwrap()
            .run(&over)
            .unwrap()
            .cycles;
        let t_ser = Simulation::new(c, &PYNQ_Z1, dram)
            .unwrap()
            .run(&ser)
            .unwrap()
            .cycles;
        assert!(
            t_over < t_ser,
            "overlap ({t_over}) should beat serialized ({t_ser})"
        );
    }
}
