//! The fetch stage: StreamReader DMA engine + linear array interconnect.
//!
//! Executes `RunFetch` instructions: reads strided blocks from the DRAM
//! image and scatters them into the matrix buffers according to the
//! instruction's destination parameters (paper §III-A1 / Table II).
//! Returns the cycle duration from the DMA timing model; the data
//! movement itself is exact.

use super::buffers::MatrixBuffers;
use super::dram::DmaTiming;
use super::StageFault;
use crate::bitmatrix::dram::DramImage;
use crate::isa::FetchRun;

/// Stateless executor for the fetch stage (all state lives in the DRAM
/// image and matrix buffers it is handed).
pub struct FetchUnit {
    pub timing: DmaTiming,
    /// u64 words per `D_k`-bit buffer word (destination granularity).
    pub words_per_chunk: usize,
}

impl FetchUnit {
    /// Execute one `RunFetch`. Returns (cycles, bytes_moved).
    pub fn run(
        &self,
        f: &FetchRun,
        dram: &DramImage,
        bufs: &mut MatrixBuffers,
    ) -> Result<(u64, u64), StageFault> {
        let chunk_bytes = self.words_per_chunk as u64 * 8;
        if f.block_bytes as u64 % chunk_bytes != 0 {
            return Err(StageFault(format!(
                "fetch block of {} bytes is not a multiple of the {}-byte buffer word",
                f.block_bytes, chunk_bytes
            )));
        }
        if f.buf_range == 0 {
            return Err(StageFault("fetch buffer range must be non-empty".into()));
        }
        if f.buf_start as usize + f.buf_range as usize > bufs.num_buffers() {
            return Err(StageFault(format!(
                "fetch target buffers [{}, {}) out of range ({} buffers)",
                f.buf_start,
                f.buf_start + f.buf_range,
                bufs.num_buffers()
            )));
        }
        let words_per_block = f.block_bytes as u64 / chunk_bytes;
        let total_words = words_per_block * f.num_blocks as u64;

        // Destination walk: `words_per_buf` consecutive words per buffer,
        // then switch to the next buffer in [buf_start, buf_start+range),
        // cyclically; each buffer has its own write cursor starting at
        // buf_offset.
        let range = f.buf_range as usize;
        let mut cursors = vec![f.buf_offset as usize; range];
        let mut dst_buf = 0usize; // index within the range
        let mut words_in_buf = 0u32;

        // Program-derived addresses: all arithmetic is checked and all
        // DRAM accesses bounds-checked so a wild pointer is a typed
        // fault, not a panic (fuzzed programs reach this).
        let oob = |addr: u64| StageFault(format!("fetch: source address {addr:#x} overflows"));
        let mut word = vec![0u64; self.words_per_chunk];
        for blk in 0..f.num_blocks as u64 {
            let src = f
                .dram_base
                .checked_add(blk.wrapping_mul(f.block_stride_bytes as u64))
                .ok_or_else(|| oob(f.dram_base))?;
            for w in 0..words_per_block {
                for j in 0..self.words_per_chunk {
                    let addr = src
                        .checked_add(w * chunk_bytes + j as u64 * 8)
                        .ok_or_else(|| oob(src))?;
                    word[j] = dram
                        .try_read_u64(addr)
                        .map_err(|e| StageFault(format!("fetch: {e}")))?;
                }
                let buf = f.buf_start as usize + dst_buf;
                bufs.write_word(buf, cursors[dst_buf], &word)
                    .map_err(|e| StageFault(format!("fetch: {e}")))?;
                cursors[dst_buf] += 1;
                words_in_buf += 1;
                if words_in_buf == f.words_per_buf {
                    words_in_buf = 0;
                    dst_buf = (dst_buf + 1) % range;
                }
            }
        }

        // The interconnect is bandwidth-matched (paper: "bandwidth-matched
        // to the main-memory read channel"), so no extra serialization.
        let bytes = total_words * chunk_bytes;
        let cycles = self.timing.duration(bytes, f.num_blocks as u64);
        Ok((cycles, bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{BismoConfig, PYNQ_Z1};

    fn setup() -> (FetchUnit, DramImage, MatrixBuffers, BismoConfig) {
        let cfg = BismoConfig::small(); // dm=dn=2, dk=64 → 1 word/chunk
        let unit = FetchUnit {
            timing: DmaTiming::fetch(&cfg, &PYNQ_Z1),
            words_per_chunk: 1,
        };
        let mut dram = DramImage::new(4096);
        for i in 0..512 {
            dram.write_u64(i * 8, 0x1000 + i);
        }
        let bufs = MatrixBuffers::new(&cfg);
        (unit, dram, bufs, cfg)
    }

    #[test]
    fn single_block_single_buffer() {
        let (unit, dram, mut bufs, _) = setup();
        let f = FetchRun {
            dram_base: 0,
            block_bytes: 32, // 4 words
            block_stride_bytes: 0,
            num_blocks: 1,
            buf_offset: 10,
            buf_start: 0,
            buf_range: 1,
            words_per_buf: 4,
        };
        let (cycles, bytes) = unit.run(&f, &dram, &mut bufs).unwrap();
        assert_eq!(bytes, 32);
        assert_eq!(cycles, 32 + 1 + 4); // latency + 1 block + 4 beats
        for w in 0..4 {
            assert_eq!(bufs.read_word(0, 10 + w).unwrap(), &[0x1000 + w as u64]);
        }
        // Untouched elsewhere.
        assert_eq!(bufs.read_word(0, 14).unwrap(), &[0]);
    }

    #[test]
    fn strided_blocks_cycle_across_buffers() {
        let (unit, dram, mut bufs, _) = setup();
        // 4 blocks of 1 word, stride 16 bytes → words 0,2,4,6, one per
        // buffer cyclically across buffers 0..2 (words_per_buf = 1).
        let f = FetchRun {
            dram_base: 0,
            block_bytes: 8,
            block_stride_bytes: 16,
            num_blocks: 4,
            buf_offset: 0,
            buf_start: 0,
            buf_range: 2,
            words_per_buf: 1,
        };
        unit.run(&f, &dram, &mut bufs).unwrap();
        assert_eq!(bufs.read_word(0, 0).unwrap(), &[0x1000]); // word 0
        assert_eq!(bufs.read_word(1, 0).unwrap(), &[0x1002]); // word 2
        assert_eq!(bufs.read_word(0, 1).unwrap(), &[0x1004]); // word 4
        assert_eq!(bufs.read_word(1, 1).unwrap(), &[0x1006]); // word 6
    }

    #[test]
    fn rhs_buffers_reachable() {
        let (unit, dram, mut bufs, _) = setup();
        let f = FetchRun {
            dram_base: 64,
            block_bytes: 8,
            block_stride_bytes: 0,
            num_blocks: 1,
            buf_offset: 0,
            buf_start: 2, // first RHS buffer
            buf_range: 1,
            words_per_buf: 1,
        };
        unit.run(&f, &dram, &mut bufs).unwrap();
        assert_eq!(bufs.read_word(2, 0).unwrap(), &[0x1008]);
    }

    #[test]
    fn bad_targets_rejected() {
        let (unit, dram, mut bufs, _) = setup();
        let f = FetchRun {
            dram_base: 0,
            block_bytes: 8,
            block_stride_bytes: 0,
            num_blocks: 1,
            buf_offset: 0,
            buf_start: 3,
            buf_range: 2, // 3..5 but only 4 buffers exist
            words_per_buf: 1,
        };
        assert!(unit.run(&f, &dram, &mut bufs).is_err());
        // Misaligned block size vs chunk width.
        let f2 = FetchRun {
            block_bytes: 12,
            buf_start: 0,
            buf_range: 1,
            ..f
        };
        assert!(unit.run(&f2, &dram, &mut bufs).is_err());
    }

    #[test]
    fn out_of_range_dram_read_is_typed_fault() {
        let (unit, dram, mut bufs, _) = setup(); // 4096-byte image
        let f = FetchRun {
            dram_base: 4096, // first read already past the end
            block_bytes: 8,
            block_stride_bytes: 0,
            num_blocks: 1,
            buf_offset: 0,
            buf_start: 0,
            buf_range: 1,
            words_per_buf: 1,
        };
        let e = unit.run(&f, &dram, &mut bufs).unwrap_err();
        assert!(e.0.contains("out of range"), "{e}");
        // Address arithmetic that wraps u64 must also fault, not panic.
        let f2 = FetchRun {
            dram_base: u64::MAX - 4,
            ..f
        };
        assert!(unit.run(&f2, &dram, &mut bufs).is_err());
    }

    #[test]
    fn buffer_overflow_rejected() {
        let (unit, dram, mut bufs, _) = setup();
        let f = FetchRun {
            dram_base: 0,
            block_bytes: 16,
            block_stride_bytes: 0,
            num_blocks: 1,
            buf_offset: 1023, // second word runs past depth 1024
            buf_start: 0,
            buf_range: 1,
            words_per_buf: 2,
        };
        assert!(unit.run(&f, &dram, &mut bufs).is_err());
    }
}
