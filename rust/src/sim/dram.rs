//! DMA timing model shared by the fetch and result stages.
//!
//! A `Run` DMA transfer of `bytes` through a `channel_bits`-wide port
//! costs:
//!
//! ```text
//! latency + per_block·blocks + ceil(bytes / bytes_per_cycle)
//! ```
//!
//! where `bytes_per_cycle` is the channel width capped by the board's
//! shared DRAM bandwidth at the configured clock (PYNQ-Z1: 3.2 GB/s),
//! `latency` is the request-to-first-beat DRAM latency charged once per
//! instruction (the StreamReader pipelines block requests), and
//! `per_block` is the route/stride generation cost per block.

use crate::arch::{BismoConfig, Platform};

/// Timing calculator for one DMA channel.
#[derive(Clone, Copy, Debug)]
pub struct DmaTiming {
    /// Effective payload bytes per cycle (channel vs board cap).
    pub bytes_per_cycle: f64,
    /// Cycles from request to first beat, charged once per Run.
    pub latency: u64,
    /// Cycles of per-block overhead (address/route generation).
    pub per_block: u64,
}

impl DmaTiming {
    /// Fetch-channel timing for a configuration on a platform.
    pub fn fetch(cfg: &BismoConfig, plat: &Platform) -> Self {
        DmaTiming {
            bytes_per_cycle: plat.channel_bytes_per_cycle(cfg.fclk_mhz, cfg.fetch_bits),
            latency: plat.dram_latency_cycles,
            per_block: 1,
        }
    }

    /// Result-channel timing (write path; same latency model).
    pub fn result(cfg: &BismoConfig, plat: &Platform) -> Self {
        DmaTiming {
            bytes_per_cycle: plat.channel_bytes_per_cycle(cfg.fclk_mhz, cfg.res_bits),
            latency: plat.dram_latency_cycles,
            per_block: 1,
        }
    }

    /// Duration in cycles of moving `bytes` in `blocks` blocks.
    pub fn duration(&self, bytes: u64, blocks: u64) -> u64 {
        let beats = (bytes as f64 / self.bytes_per_cycle).ceil() as u64;
        self.latency + self.per_block * blocks + beats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::PYNQ_Z1;

    #[test]
    fn fetch_duration_bandwidth_bound() {
        let cfg = BismoConfig::small(); // F = 64 bits at 200 MHz → 8 B/cycle
        let t = DmaTiming::fetch(&cfg, &PYNQ_Z1);
        assert_eq!(t.bytes_per_cycle, 8.0);
        // 1 KiB in one block: 32 latency + 1 block + 128 beats.
        assert_eq!(t.duration(1024, 1), 32 + 1 + 128);
    }

    #[test]
    fn many_blocks_cost_route_overhead() {
        let cfg = BismoConfig::small();
        let t = DmaTiming::fetch(&cfg, &PYNQ_Z1);
        let one = t.duration(4096, 1);
        let many = t.duration(4096, 64);
        assert_eq!(many - one, 63);
    }

    #[test]
    fn board_cap_limits_wide_channels() {
        // A hypothetical 512-bit channel at 200 MHz is capped by the
        // 3.2 GB/s board bandwidth to 16 B/cycle.
        let cfg = BismoConfig {
            fetch_bits: 512,
            ..BismoConfig::small()
        };
        let t = DmaTiming::fetch(&cfg, &PYNQ_Z1);
        assert_eq!(t.bytes_per_cycle, 16.0);
    }

    #[test]
    fn zero_bytes_still_costs_latency() {
        let cfg = BismoConfig::small();
        let t = DmaTiming::result(&cfg, &PYNQ_Z1);
        assert_eq!(t.duration(0, 0), t.latency);
    }
}
