//! On-chip memories: the LHS/RHS matrix buffers (BRAM in hardware) and
//! the result buffer (LUTRAM in hardware).

use super::StageFault;
use crate::arch::BismoConfig;
use crate::util::ceil_div;

/// The `D_m + D_n` matrix buffers. Each buffer holds `depth` words of
//  `D_k` bits; a word is stored as `words_per_chunk` u64s (zero-padded
/// above `D_k`). Buffers `0..D_m` feed DPU rows (LHS), buffers
/// `D_m..D_m+D_n` feed DPU columns (RHS).
#[derive(Clone, Debug)]
pub struct MatrixBuffers {
    dm: usize,
    dn: usize,
    bm: usize,
    bn: usize,
    /// u64 words per `D_k`-bit buffer word.
    wpc: usize,
    /// LHS storage: `dm × bm × wpc`.
    lhs: Vec<u64>,
    /// RHS storage: `dn × bn × wpc`.
    rhs: Vec<u64>,
}

impl MatrixBuffers {
    pub fn new(cfg: &BismoConfig) -> Self {
        let wpc = ceil_div(cfg.dk as u64, 64) as usize;
        MatrixBuffers {
            dm: cfg.dm as usize,
            dn: cfg.dn as usize,
            bm: cfg.bm as usize,
            bn: cfg.bn as usize,
            wpc,
            lhs: vec![0; cfg.dm as usize * cfg.bm as usize * wpc],
            rhs: vec![0; cfg.dn as usize * cfg.bn as usize * wpc],
        }
    }

    /// Total number of addressable buffers.
    pub fn num_buffers(&self) -> usize {
        self.dm + self.dn
    }

    /// Depth in `D_k`-bit words of buffer `buf`.
    pub fn depth_of(&self, buf: usize) -> usize {
        if buf < self.dm {
            self.bm
        } else {
            self.bn
        }
    }

    /// u64 words per buffer word.
    pub fn words_per_chunk(&self) -> usize {
        self.wpc
    }

    fn slot(&self, buf: usize, word: usize) -> Result<usize, String> {
        if buf >= self.num_buffers() {
            return Err(format!(
                "buffer id {buf} out of range (have {})",
                self.num_buffers()
            ));
        }
        if word >= self.depth_of(buf) {
            return Err(format!(
                "word {word} out of range for buffer {buf} (depth {})",
                self.depth_of(buf)
            ));
        }
        Ok(if buf < self.dm {
            (buf * self.bm + word) * self.wpc
        } else {
            ((buf - self.dm) * self.bn + word) * self.wpc
        })
    }

    /// Write one `D_k`-bit buffer word (as `wpc` u64s).
    pub fn write_word(&mut self, buf: usize, word: usize, data: &[u64]) -> Result<(), StageFault> {
        if data.len() != self.wpc {
            return Err(StageFault(format!(
                "buffer write of {} words does not match the {}-word D_k chunk",
                data.len(),
                self.wpc
            )));
        }
        let s = self.slot(buf, word)?;
        let dst = if buf < self.dm {
            &mut self.lhs[s..s + self.wpc]
        } else {
            &mut self.rhs[s..s + self.wpc]
        };
        dst.copy_from_slice(data);
        Ok(())
    }

    /// Read one `D_k`-bit buffer word.
    pub fn read_word(&self, buf: usize, word: usize) -> Result<&[u64], StageFault> {
        let s = self.slot(buf, word)?;
        Ok(if buf < self.dm {
            &self.lhs[s..s + self.wpc]
        } else {
            &self.rhs[s..s + self.wpc]
        })
    }

    /// Storage-relative `u64` range of `nwords` consecutive buffer
    /// words, bounds-validated once (shared by [`MatrixBuffers::read_range`]
    /// and [`MatrixBuffers::rhs_word_range`]).
    fn word_range(
        &self,
        buf: usize,
        word: usize,
        nwords: usize,
    ) -> Result<std::ops::Range<usize>, String> {
        if nwords == 0 {
            return Ok(0..0);
        }
        let s = self.slot(buf, word)?;
        let _ = self.slot(buf, word + nwords - 1)?; // validate end
        Ok(s..s + nwords * self.wpc)
    }

    /// Read `nwords` consecutive `D_k`-bit words as one contiguous u64
    /// slice (buffer storage is word-major, so consecutive words are
    /// adjacent). Bounds are validated once — this is the execute
    /// stage's hot path.
    pub fn read_range(&self, buf: usize, word: usize, nwords: usize) -> Result<&[u64], StageFault> {
        let r = self.word_range(buf, word, nwords)?;
        Ok(if buf < self.dm {
            &self.lhs[r]
        } else {
            &self.rhs[r]
        })
    }

    /// Storage-relative `u64` range of `nwords` consecutive buffer words
    /// of the RHS buffer for DPU column `j` (an index range into
    /// [`MatrixBuffers::rhs_data`]). Bounds are validated here once so
    /// the execute stage can cache the ranges in scratch storage and
    /// slice without re-validating.
    pub fn rhs_word_range(
        &self,
        j: usize,
        word: usize,
        nwords: usize,
    ) -> Result<std::ops::Range<usize>, StageFault> {
        Ok(self.word_range(self.rhs_buf(j), word, nwords)?)
    }

    /// The raw RHS storage ([`MatrixBuffers::rhs_word_range`] indexes
    /// into this).
    pub fn rhs_data(&self) -> &[u64] {
        &self.rhs
    }

    /// LHS row buffer id for DPU row `i`.
    pub fn lhs_buf(&self, i: usize) -> usize {
        debug_assert!(i < self.dm);
        i
    }

    /// RHS column buffer id for DPU column `j`.
    pub fn rhs_buf(&self, j: usize) -> usize {
        debug_assert!(j < self.dn);
        self.dm + j
    }

    /// The raw LHS storage (snapshot capture; mirrors
    /// [`MatrixBuffers::rhs_data`]).
    pub fn lhs_data(&self) -> &[u64] {
        &self.lhs
    }

    /// Overwrite both storages from captured state (snapshot restore).
    /// Lengths must match the geometry this instance was built with.
    pub fn restore_contents(&mut self, lhs: &[u64], rhs: &[u64]) -> Result<(), StageFault> {
        if lhs.len() != self.lhs.len() || rhs.len() != self.rhs.len() {
            return Err(StageFault(format!(
                "buffer snapshot shape mismatch: lhs {} (want {}), rhs {} (want {})",
                lhs.len(),
                self.lhs.len(),
                rhs.len(),
                self.rhs.len()
            )));
        }
        self.lhs.copy_from_slice(lhs);
        self.rhs.copy_from_slice(rhs);
        Ok(())
    }
}

/// The result buffer: a FIFO of up to `B_r` committed `D_m × D_n`
/// accumulator sets, decoupling execute from the result writer.
#[derive(Clone, Debug)]
pub struct ResultBuffer {
    capacity: usize,
    dm: usize,
    dn: usize,
    slots: std::collections::VecDeque<Vec<i32>>,
    /// High-water mark of occupied slots.
    pub max_occupancy: usize,
}

impl ResultBuffer {
    pub fn new(cfg: &BismoConfig) -> Self {
        ResultBuffer {
            capacity: cfg.br as usize,
            dm: cfg.dm as usize,
            dn: cfg.dn as usize,
            slots: Default::default(),
            max_occupancy: 0,
        }
    }

    /// Execute-side: commit an accumulator set. Errors on overflow —
    /// a scheduler bug (missing `Wait(ResultToExecute)`).
    pub fn commit(&mut self, accs: Vec<i32>) -> Result<(), StageFault> {
        if accs.len() != self.dm * self.dn {
            return Err(StageFault(format!(
                "committed set of {} accumulators does not match the {}×{} DPA",
                accs.len(),
                self.dm,
                self.dn
            )));
        }
        if self.slots.len() == self.capacity {
            return Err(StageFault(format!(
                "result buffer overflow (B_r = {}): execute committed without a drained slot",
                self.capacity
            )));
        }
        self.slots.push_back(accs);
        self.max_occupancy = self.max_occupancy.max(self.slots.len());
        Ok(())
    }

    /// Result-side: drain the oldest committed set. Errors on underflow.
    pub fn drain(&mut self) -> Result<Vec<i32>, StageFault> {
        self.slots.pop_front().ok_or_else(|| {
            StageFault("result buffer underflow: RunResult with no committed results".to_string())
        })
    }

    pub fn occupancy(&self) -> usize {
        self.slots.len()
    }

    /// Accumulators per committed set.
    pub fn set_len(&self) -> usize {
        self.dm * self.dn
    }

    /// Committed-but-undrained sets, oldest first (snapshot capture).
    pub fn committed(&self) -> Vec<Vec<i32>> {
        self.slots.iter().cloned().collect()
    }

    /// Overwrite the FIFO from captured state (snapshot restore).
    pub fn restore_contents(
        &mut self,
        slots: Vec<Vec<i32>>,
        max_occupancy: usize,
    ) -> Result<(), StageFault> {
        if slots.len() > self.capacity {
            return Err(StageFault(format!(
                "result-buffer snapshot holds {} sets but B_r = {}",
                slots.len(),
                self.capacity
            )));
        }
        if let Some(bad) = slots.iter().find(|s| s.len() != self.set_len()) {
            return Err(StageFault(format!(
                "result-buffer snapshot set of {} accumulators does not match the {}×{} DPA",
                bad.len(),
                self.dm,
                self.dn
            )));
        }
        self.slots = slots.into();
        self.max_occupancy = max_occupancy.max(self.slots.len());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BismoConfig {
        BismoConfig::small() // 2×64×2, bm=bn=1024, br=2
    }

    #[test]
    fn buffer_rw_roundtrip() {
        let mut b = MatrixBuffers::new(&cfg());
        b.write_word(0, 5, &[0xAB]).unwrap();
        b.write_word(3, 1023, &[0xCD]).unwrap(); // RHS buffer 1, last word
        assert_eq!(b.read_word(0, 5).unwrap(), &[0xAB]);
        assert_eq!(b.read_word(3, 1023).unwrap(), &[0xCD]);
        assert_eq!(b.read_word(0, 6).unwrap(), &[0]);
    }

    #[test]
    fn buffer_bounds_checked() {
        let mut b = MatrixBuffers::new(&cfg());
        assert!(b.write_word(4, 0, &[0]).is_err()); // only 4 buffers (2+2)
        assert!(b.write_word(0, 1024, &[0]).is_err()); // depth exceeded
        assert!(b.read_word(9, 0).is_err());
    }

    #[test]
    fn buffer_id_mapping() {
        let b = MatrixBuffers::new(&cfg());
        assert_eq!(b.lhs_buf(0), 0);
        assert_eq!(b.lhs_buf(1), 1);
        assert_eq!(b.rhs_buf(0), 2);
        assert_eq!(b.rhs_buf(1), 3);
        assert_eq!(b.num_buffers(), 4);
    }

    #[test]
    fn wide_dk_uses_multiple_words() {
        let c = BismoConfig {
            dk: 256,
            ..BismoConfig::small()
        };
        let mut b = MatrixBuffers::new(&c);
        assert_eq!(b.words_per_chunk(), 4);
        b.write_word(0, 0, &[1, 2, 3, 4]).unwrap();
        assert_eq!(b.read_word(0, 0).unwrap(), &[1, 2, 3, 4]);
    }

    #[test]
    fn rhs_word_range_matches_read_range() {
        let mut b = MatrixBuffers::new(&cfg());
        b.write_word(2, 3, &[0x11]).unwrap();
        b.write_word(3, 4, &[0x22]).unwrap();
        for j in 0..2 {
            let range = b.rhs_word_range(j, 2, 4).unwrap();
            assert_eq!(&b.rhs_data()[range], b.read_range(b.rhs_buf(j), 2, 4).unwrap());
        }
        assert!(b.rhs_word_range(0, 1023, 2).is_err()); // end out of range
        assert_eq!(b.rhs_word_range(1, 0, 0).unwrap(), 0..0);
    }

    #[test]
    fn wrong_width_write_is_typed_fault() {
        let mut b = MatrixBuffers::new(&cfg());
        let e = b.write_word(0, 0, &[1, 2]).unwrap_err(); // wpc = 1
        assert!(e.0.contains("does not match"), "{e}");
        let mut r = ResultBuffer::new(&cfg());
        let e = r.commit(vec![1, 2, 3]).unwrap_err(); // set_len = 4
        assert!(e.0.contains("does not match"), "{e}");
    }

    #[test]
    fn snapshot_state_roundtrip() {
        let mut b = MatrixBuffers::new(&cfg());
        b.write_word(1, 7, &[0x77]).unwrap();
        b.write_word(2, 9, &[0x99]).unwrap();
        let (lhs, rhs) = (b.lhs_data().to_vec(), b.rhs_data().to_vec());
        let mut b2 = MatrixBuffers::new(&cfg());
        b2.restore_contents(&lhs, &rhs).unwrap();
        assert_eq!(b2.read_word(1, 7).unwrap(), &[0x77]);
        assert_eq!(b2.read_word(2, 9).unwrap(), &[0x99]);
        assert!(b2.restore_contents(&lhs[1..], &rhs).is_err());

        let mut r = ResultBuffer::new(&cfg());
        r.commit(vec![1, 2, 3, 4]).unwrap();
        let sets = r.committed();
        let mut r2 = ResultBuffer::new(&cfg());
        r2.restore_contents(sets, r.max_occupancy).unwrap();
        assert_eq!(r2.drain().unwrap(), vec![1, 2, 3, 4]);
        assert!(r2.restore_contents(vec![vec![0; 4]; 3], 3).is_err()); // over capacity
        assert!(r2.restore_contents(vec![vec![0; 3]], 1).is_err()); // bad set
    }

    #[test]
    fn result_fifo_protocol() {
        let mut r = ResultBuffer::new(&cfg());
        assert!(r.drain().is_err()); // underflow detected
        r.commit(vec![1, 2, 3, 4]).unwrap();
        r.commit(vec![5, 6, 7, 8]).unwrap();
        assert!(r.commit(vec![0; 4]).is_err()); // B_r = 2: overflow
        assert_eq!(r.drain().unwrap(), vec![1, 2, 3, 4]); // FIFO order
        assert_eq!(r.occupancy(), 1);
        assert_eq!(r.max_occupancy, 2);
    }
}
