//! Functional + cycle-level simulator of the BISMO overlay.
//!
//! The three pipeline stages (fetch / execute / result) run as
//! discrete-event sequential processes, synchronized only through the
//! four token FIFOs — exactly the hardware contract of paper Fig. 2.
//! Every `Run*` instruction both *does the work* (moves real bytes,
//! computes real AND+popcount dot products) and *advances time* by the
//! duration the hardware would take (DESIGN.md §4 gives the timing
//! model and its calibration against the paper's Figs 12–13).
//!
//! The simulator therefore produces, for every program:
//!
//! * a real result matrix in the DRAM image (checked against the CPU
//!   oracle in tests), and
//! * exact cycle counts, per-stage busy/stall breakdowns, and
//!   efficiency relative to the configuration's peak.
//!
//! Illegal schedules are detected, not silently mis-simulated: token
//! deadlock, result-buffer over/underflow and out-of-range buffer
//! accesses all surface as [`SimError`] (wrapped in
//! [`crate::api::BismoError::SimFault`]); invalid configurations and
//! malformed programs are rejected up front with the typed
//! `InvalidConfig` / `IllegalProgram` variants.

mod buffers;
mod dram;
mod engine;
mod execute;
mod fetch;
mod result;
pub mod snapshot;

pub use buffers::{MatrixBuffers, ResultBuffer};
pub use dram::DmaTiming;
pub use engine::{SimError, Simulation, StepOutcome, TraceEvent};
pub use execute::ExecuteUnit;
pub use fetch::FetchUnit;
pub use result::ResultUnit;
pub use snapshot::{digest_bytes, SimSnapshot};

/// A localized failure inside one stage unit: out-of-range buffer
/// access, result-FIFO over/underflow, misaligned fetch. The engine
/// wraps it into [`SimError::Fault`] with stage and program-counter
/// context; standalone users of the units see the bare message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StageFault(pub String);

impl std::fmt::Display for StageFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for StageFault {}

impl From<String> for StageFault {
    fn from(msg: String) -> Self {
        StageFault(msg)
    }
}

/// A simple token FIFO with unbounded depth (hardware uses small FIFOs;
/// depth is a scheduler property we check, not a correctness cliff) —
/// tokens carry the producer-side timestamp so the consumer's `Wait`
/// completes at `max(consumer_time, token_time)`.
#[derive(Clone, Debug, Default)]
pub struct TokenFifo {
    tokens: std::collections::VecDeque<u64>,
    /// High-water mark, for reporting hardware FIFO depth requirements.
    pub max_depth: usize,
    /// Total tokens ever pushed.
    pub total: u64,
}

impl TokenFifo {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, time: u64) {
        self.tokens.push_back(time);
        self.max_depth = self.max_depth.max(self.tokens.len());
        self.total += 1;
    }

    /// Peek the arrival time of the oldest token.
    pub fn front(&self) -> Option<u64> {
        self.tokens.front().copied()
    }

    pub fn pop(&mut self) -> Option<u64> {
        self.tokens.pop_front()
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Tokens currently queued, oldest first (snapshot capture).
    pub fn tokens(&self) -> Vec<u64> {
        self.tokens.iter().copied().collect()
    }

    /// Rebuild a FIFO from captured state (snapshot restore).
    pub fn from_parts(tokens: Vec<u64>, max_depth: usize, total: u64) -> Self {
        TokenFifo {
            tokens: tokens.into(),
            max_depth,
            total,
        }
    }
}

/// Cycle/byte/op statistics of one simulated program run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RunStats {
    /// Total cycles: latest finish time across the three stages.
    pub cycles: u64,
    /// Busy cycles per stage (time inside Run instructions).
    pub fetch_busy: u64,
    pub execute_busy: u64,
    pub result_busy: u64,
    /// Stall cycles per stage (time blocked in Wait instructions).
    pub fetch_stall: u64,
    pub execute_stall: u64,
    pub result_stall: u64,
    /// Bytes moved from / to DRAM.
    pub bytes_fetched: u64,
    pub bytes_written: u64,
    /// Binary operations performed (2 × AND-popcount bit pairs).
    pub binary_ops: u64,
    /// DPA pipeline fill cycles paid (drain/fill overhead).
    pub pipeline_fill_cycles: u64,
    /// Number of accumulator commits to the result buffer.
    pub commits: u64,
    /// Accumulator overflow events (value did not fit `A` bits).
    pub acc_overflows: u64,
}

impl RunStats {
    /// Achieved binary ops per cycle.
    pub fn ops_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.binary_ops as f64 / self.cycles as f64
        }
    }

    /// Efficiency vs a configuration's peak ops/cycle.
    pub fn efficiency(&self, peak_ops_per_cycle: u64) -> f64 {
        self.ops_per_cycle() / peak_ops_per_cycle as f64
    }

    /// Wall-clock seconds at `fclk_mhz`.
    pub fn seconds_at(&self, fclk_mhz: u32) -> f64 {
        self.cycles as f64 / (fclk_mhz as f64 * 1e6)
    }

    /// Achieved binary GOPS at `fclk_mhz`.
    pub fn gops_at(&self, fclk_mhz: u32) -> f64 {
        self.binary_ops as f64 / self.seconds_at(fclk_mhz) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_stats() {
        let mut f = TokenFifo::new();
        f.push(10);
        f.push(20);
        assert_eq!(f.len(), 2);
        assert_eq!(f.max_depth, 2);
        assert_eq!(f.front(), Some(10));
        assert_eq!(f.pop(), Some(10));
        assert_eq!(f.pop(), Some(20));
        assert_eq!(f.pop(), None);
        assert_eq!(f.total, 2);
    }

    #[test]
    fn stats_derived_metrics() {
        let s = RunStats {
            cycles: 1000,
            binary_ops: 500_000,
            ..Default::default()
        };
        assert!((s.ops_per_cycle() - 500.0).abs() < 1e-9);
        assert!((s.efficiency(1000) - 0.5).abs() < 1e-9);
        assert!((s.seconds_at(200) - 5e-6).abs() < 1e-12);
        assert!((s.gops_at(200) - 100.0).abs() < 1e-9);
    }
}
