//! The result stage: StreamWriter (downsizer + striding DMA engine).
//!
//! Executes `RunResult` instructions: drains the oldest committed
//! `D_m × D_n` accumulator set from the result buffer and writes a
//! `rows × cols` tile of it to DRAM, row-strided so the tile lands
//! inside the full result matrix (paper §III-A3). The downsizer
//! serializes `A`-bit accumulators onto the `R`-bit write channel; its
//! bandwidth is what the DMA timing model charges.

use super::buffers::ResultBuffer;
use super::dram::DmaTiming;
use super::StageFault;
use crate::bitmatrix::dram::DramImage;
use crate::isa::ResultRun;

/// Stateless executor for the result stage.
pub struct ResultUnit {
    pub timing: DmaTiming,
    /// DPU columns (`D_n`) — the row pitch inside a committed set.
    pub dn: usize,
}

impl ResultUnit {
    /// Execute one `RunResult`. Returns (cycles, bytes_written).
    pub fn run(
        &self,
        r: &ResultRun,
        result_buf: &mut ResultBuffer,
        dram: &mut DramImage,
    ) -> Result<(u64, u64), StageFault> {
        let set = result_buf
            .drain()
            .map_err(|e| StageFault(format!("result: {e}")))?;
        let rows = r.rows as usize;
        let cols = r.cols as usize;
        if cols > self.dn || rows * self.dn > set.len() {
            return Err(StageFault(format!(
                "result tile {}x{} exceeds committed set ({} accumulators, D_n={})",
                rows,
                cols,
                set.len(),
                self.dn
            )));
        }
        // Program-derived addresses: checked arithmetic + bounds-checked
        // writes so a wild destination is a typed fault, not a panic.
        let oob =
            |addr: u64| StageFault(format!("result: destination address {addr:#x} overflows"));
        let base = r.dram_base.checked_add(r.offset).ok_or_else(|| oob(r.dram_base))?;
        for tr in 0..rows {
            for tc in 0..cols {
                let v = set[tr * self.dn + tc];
                let addr = base
                    .checked_add((tr as u64).wrapping_mul(r.row_stride_bytes as u64))
                    .and_then(|a| a.checked_add(tc as u64 * 4))
                    .ok_or_else(|| oob(base))?;
                dram.try_write_i32(addr, v)
                    .map_err(|e| StageFault(format!("result: {e}")))?;
            }
        }
        let bytes = (rows * cols * 4) as u64;
        // One strided burst per tile row.
        let cycles = self.timing.duration(bytes, rows as u64);
        Ok((cycles, bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{BismoConfig, PYNQ_Z1};

    fn setup() -> (ResultUnit, ResultBuffer, DramImage) {
        let cfg = BismoConfig::small(); // 2×2 DPA
        let unit = ResultUnit {
            timing: DmaTiming::result(&cfg, &PYNQ_Z1),
            dn: cfg.dn as usize,
        };
        (unit, ResultBuffer::new(&cfg), DramImage::new(4096))
    }

    #[test]
    fn writes_strided_tile() {
        let (unit, mut rb, mut dram) = setup();
        rb.commit(vec![11, 12, 21, 22]).unwrap();
        let r = ResultRun {
            dram_base: 0,
            offset: 8, // tile lands at row 0, col 2 of an n=4 matrix
            rows: 2,
            cols: 2,
            row_stride_bytes: 16, // n=4 → 16-byte rows
        };
        let (cycles, bytes) = unit.run(&r, &mut rb, &mut dram).unwrap();
        assert_eq!(bytes, 16);
        assert!(cycles >= unit.timing.latency);
        assert_eq!(dram.read_i32(8), 11);
        assert_eq!(dram.read_i32(12), 12);
        assert_eq!(dram.read_i32(24), 21);
        assert_eq!(dram.read_i32(28), 22);
        // Neighbors untouched.
        assert_eq!(dram.read_i32(0), 0);
        assert_eq!(dram.read_i32(16), 0);
    }

    #[test]
    fn partial_tile_for_edge_of_matrix() {
        let (unit, mut rb, mut dram) = setup();
        rb.commit(vec![5, 6, 7, 8]).unwrap();
        let r = ResultRun {
            dram_base: 0,
            offset: 0,
            rows: 1,
            cols: 1,
            row_stride_bytes: 4,
        };
        let (_, bytes) = unit.run(&r, &mut rb, &mut dram).unwrap();
        assert_eq!(bytes, 4);
        assert_eq!(dram.read_i32(0), 5);
        assert_eq!(dram.read_i32(4), 0);
    }

    #[test]
    fn underflow_detected() {
        let (unit, mut rb, mut dram) = setup();
        let r = ResultRun {
            dram_base: 0,
            offset: 0,
            rows: 1,
            cols: 1,
            row_stride_bytes: 4,
        };
        assert!(unit.run(&r, &mut rb, &mut dram).is_err());
    }

    #[test]
    fn out_of_range_dram_write_is_typed_fault() {
        let (unit, mut rb, mut dram) = setup(); // 4096-byte image
        rb.commit(vec![1, 2, 3, 4]).unwrap();
        let r = ResultRun {
            dram_base: 4096,
            offset: 0,
            rows: 1,
            cols: 1,
            row_stride_bytes: 4,
        };
        let e = unit.run(&r, &mut rb, &mut dram).unwrap_err();
        assert!(e.0.contains("out of range"), "{e}");
        // u64-wrapping destination arithmetic must also fault.
        rb.commit(vec![1, 2, 3, 4]).unwrap();
        let r2 = ResultRun {
            dram_base: u64::MAX - 3,
            offset: 3,
            ..r
        };
        assert!(unit.run(&r2, &mut rb, &mut dram).is_err());
    }

    #[test]
    fn oversized_tile_rejected() {
        let (unit, mut rb, mut dram) = setup();
        rb.commit(vec![0; 4]).unwrap();
        let r = ResultRun {
            dram_base: 0,
            offset: 0,
            rows: 3, // > D_m
            cols: 2,
            row_stride_bytes: 8,
        };
        assert!(unit.run(&r, &mut rb, &mut dram).is_err());
    }
}
