//! Serializable simulation state (`bismo-sim-snapshot/v1`).
//!
//! A [`SimSnapshot`] is a complete, self-contained capture of one
//! [`super::Simulation`] between two instructions: scheduler position
//! (per-stage PCs, local clocks, round-robin cursor), partial run
//! statistics, the four token FIFOs, the LHS/RHS matrix buffers, the
//! result buffer, the DPA accumulators and the full DRAM image. A
//! restored snapshot resumes bit- and cycle-exactly (property-tested in
//! `tests/sim_snapshot.rs`).
//!
//! The JSON encoding (via `util::json`, no serde) represents every
//! 64-bit quantity as a `"0x…"` hex string: the JSON number type is an
//! f64, which silently loses precision above 2^53 — cycle counters and
//! DRAM addresses can legitimately exceed that. i64 accumulators are
//! stored via their two's-complement bit pattern; the DRAM image is one
//! contiguous hex string. Malformed input is rejected as
//! [`BismoError::Parse`], never a panic.

use super::RunStats;
use crate::api::BismoError;
use crate::arch::BismoConfig;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Schema tag embedded in every serialized snapshot.
pub const SNAPSHOT_SCHEMA: &str = "bismo-sim-snapshot/v1";

/// Captured state of one token FIFO.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FifoState {
    /// Queued producer timestamps, oldest first.
    pub tokens: Vec<u64>,
    /// High-water mark.
    pub max_depth: usize,
    /// Total tokens ever pushed.
    pub total: u64,
}

/// Complete state of a [`super::Simulation`] between two instructions.
///
/// Produced by [`super::Simulation::snapshot`], consumed by
/// [`super::Simulation::restore`]. The instruction trace (if tracing was
/// enabled) is deliberately *not* part of the snapshot: it is a
/// debugging aid and does not influence simulation results.
#[derive(Clone, Debug, PartialEq)]
pub struct SimSnapshot {
    /// Overlay configuration the state belongs to.
    pub cfg: BismoConfig,
    /// A program is armed and unfinished.
    pub running: bool,
    /// Round-robin scheduler cursor (0 = fetch, 1 = execute, 2 = result).
    pub cur: usize,
    /// Consecutive no-progress stage attempts (deadlock detector).
    pub stall_streak: usize,
    /// Per-stage next-instruction indices (fetch, execute, result).
    pub pc: [usize; 3],
    /// Per-stage local clocks.
    pub t: [u64; 3],
    /// Fingerprint of the armed program.
    pub fingerprint: u64,
    /// Statistics accumulated so far.
    pub stats: RunStats,
    /// The four sync FIFOs, in `fifo_idx` order (F→E, E→F, E→R, R→E).
    pub fifos: [FifoState; 4],
    /// LHS matrix-buffer storage (`dm × bm × words_per_chunk` u64s).
    pub lhs: Vec<u64>,
    /// RHS matrix-buffer storage (`dn × bn × words_per_chunk` u64s).
    pub rhs: Vec<u64>,
    /// Committed-but-undrained result sets, oldest first.
    pub result_slots: Vec<Vec<i32>>,
    /// Result-buffer occupancy high-water mark.
    pub result_max_occupancy: usize,
    /// DPA accumulator registers, row-major.
    pub accs: Vec<i64>,
    /// Accumulator wrap events so far.
    pub overflows: u64,
    /// The full DRAM image.
    pub dram: Vec<u8>,
}

fn hex(v: u64) -> Json {
    Json::Str(format!("{v:#x}"))
}

fn parse_hex(j: &Json, what: &str) -> Result<u64, BismoError> {
    let s = j
        .as_str()
        .ok_or_else(|| BismoError::Parse(format!("snapshot: {what} is not a hex string")))?;
    let digits = s
        .strip_prefix("0x")
        .ok_or_else(|| BismoError::Parse(format!("snapshot: {what} lacks the 0x prefix")))?;
    u64::from_str_radix(digits, 16)
        .map_err(|e| BismoError::Parse(format!("snapshot: {what}: {e}")))
}

fn field<'a>(obj: &'a Json, key: &str) -> Result<&'a Json, BismoError> {
    obj.get(key)
        .ok_or_else(|| BismoError::Parse(format!("snapshot: missing field '{key}'")))
}

fn parse_u32(j: &Json, what: &str) -> Result<u32, BismoError> {
    let f = j
        .as_f64()
        .ok_or_else(|| BismoError::Parse(format!("snapshot: {what} is not a number")))?;
    if f < 0.0 || f > u32::MAX as f64 || f.fract() != 0.0 {
        return Err(BismoError::Parse(format!(
            "snapshot: {what} = {f} is not a u32"
        )));
    }
    Ok(f as u32)
}

fn parse_usize(j: &Json, what: &str) -> Result<usize, BismoError> {
    Ok(parse_u32(j, what)? as usize)
}

fn dram_to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

fn dram_from_hex(s: &str) -> Result<Vec<u8>, BismoError> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        return Err(BismoError::Parse(
            "snapshot: dram hex string has odd length".into(),
        ));
    }
    let nib = |c: u8| -> Result<u8, BismoError> {
        match c {
            b'0'..=b'9' => Ok(c - b'0'),
            b'a'..=b'f' => Ok(c - b'a' + 10),
            b'A'..=b'F' => Ok(c - b'A' + 10),
            _ => Err(BismoError::Parse(format!(
                "snapshot: invalid dram hex digit '{}'",
                c as char
            ))),
        }
    };
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push((nib(pair[0])? << 4) | nib(pair[1])?);
    }
    Ok(out)
}

/// Fold a byte slice into a 64-bit digest (splitmix64 chaining). Used by
/// the golden-snapshot report to summarize the final DRAM image without
/// storing it twice.
pub fn digest_bytes(bytes: &[u8]) -> u64 {
    // Arbitrary non-zero seed so the empty slice has a distinctive digest.
    let mut h = 0x0b15_0d1e_57a7_e5ee_u64;
    for chunk in bytes.chunks(8) {
        let mut w = [0u8; 8];
        w[..chunk.len()].copy_from_slice(chunk);
        h = crate::util::splitmix64(h ^ u64::from_le_bytes(w));
    }
    // Length-extension guard: [0] and [0, 0] must differ.
    crate::util::splitmix64(h ^ bytes.len() as u64)
}

impl SimSnapshot {
    /// Encode as a `util::json` value (schema `bismo-sim-snapshot/v1`).
    pub fn to_json_value(&self) -> Json {
        let cfgv = |v: u32| Json::num(v as f64);
        let cfg = Json::Obj(BTreeMap::from([
            ("dm".into(), cfgv(self.cfg.dm)),
            ("dk".into(), cfgv(self.cfg.dk)),
            ("dn".into(), cfgv(self.cfg.dn)),
            ("bm".into(), cfgv(self.cfg.bm)),
            ("bn".into(), cfgv(self.cfg.bn)),
            ("br".into(), cfgv(self.cfg.br)),
            ("acc_bits".into(), cfgv(self.cfg.acc_bits)),
            ("fetch_bits".into(), cfgv(self.cfg.fetch_bits)),
            ("res_bits".into(), cfgv(self.cfg.res_bits)),
            ("fclk_mhz".into(), cfgv(self.cfg.fclk_mhz)),
        ]));
        let engine = Json::Obj(BTreeMap::from([
            ("running".into(), Json::Bool(self.running)),
            ("cur".into(), Json::num(self.cur as f64)),
            ("stall_streak".into(), Json::num(self.stall_streak as f64)),
            (
                "pc".into(),
                Json::Arr(self.pc.iter().map(|&p| Json::num(p as f64)).collect()),
            ),
            (
                "t".into(),
                Json::Arr(self.t.iter().map(|&t| hex(t)).collect()),
            ),
            ("fingerprint".into(), hex(self.fingerprint)),
        ]));
        let s = &self.stats;
        let stats = Json::Obj(BTreeMap::from([
            ("cycles".into(), hex(s.cycles)),
            ("fetch_busy".into(), hex(s.fetch_busy)),
            ("execute_busy".into(), hex(s.execute_busy)),
            ("result_busy".into(), hex(s.result_busy)),
            ("fetch_stall".into(), hex(s.fetch_stall)),
            ("execute_stall".into(), hex(s.execute_stall)),
            ("result_stall".into(), hex(s.result_stall)),
            ("bytes_fetched".into(), hex(s.bytes_fetched)),
            ("bytes_written".into(), hex(s.bytes_written)),
            ("binary_ops".into(), hex(s.binary_ops)),
            ("pipeline_fill_cycles".into(), hex(s.pipeline_fill_cycles)),
            ("commits".into(), hex(s.commits)),
            ("acc_overflows".into(), hex(s.acc_overflows)),
        ]));
        let fifos = Json::Arr(
            self.fifos
                .iter()
                .map(|f| {
                    Json::Obj(BTreeMap::from([
                        (
                            "tokens".into(),
                            Json::Arr(f.tokens.iter().map(|&t| hex(t)).collect()),
                        ),
                        ("max_depth".into(), Json::num(f.max_depth as f64)),
                        ("total".into(), hex(f.total)),
                    ]))
                })
                .collect(),
        );
        let words = |ws: &[u64]| Json::Arr(ws.iter().map(|&w| hex(w)).collect());
        let result = Json::Obj(BTreeMap::from([
            (
                "slots".into(),
                Json::Arr(
                    self.result_slots
                        .iter()
                        .map(|set| Json::Arr(set.iter().map(|&v| Json::num(v as f64)).collect()))
                        .collect(),
                ),
            ),
            (
                "max_occupancy".into(),
                Json::num(self.result_max_occupancy as f64),
            ),
        ]));
        let exec = Json::Obj(BTreeMap::from([
            (
                "accs".into(),
                Json::Arr(self.accs.iter().map(|&a| hex(a as u64)).collect()),
            ),
            ("overflows".into(), hex(self.overflows)),
        ]));
        Json::Obj(BTreeMap::from([
            ("schema".into(), Json::str(SNAPSHOT_SCHEMA)),
            ("cfg".into(), cfg),
            ("engine".into(), engine),
            ("stats".into(), stats),
            ("fifos".into(), fifos),
            ("lhs".into(), words(&self.lhs)),
            ("rhs".into(), words(&self.rhs)),
            ("result".into(), result),
            ("exec".into(), exec),
            ("dram".into(), Json::Str(dram_to_hex(&self.dram))),
        ]))
    }

    /// Serialize to pretty-printed JSON.
    pub fn to_json(&self) -> String {
        self.to_json_value().pretty(2)
    }

    /// Decode from a `util::json` value. Any structural problem —
    /// missing fields, wrong types, bad hex — is a
    /// [`BismoError::Parse`].
    pub fn from_json_value(v: &Json) -> Result<Self, BismoError> {
        let schema = field(v, "schema")?.as_str().unwrap_or("");
        if schema != SNAPSHOT_SCHEMA {
            return Err(BismoError::Parse(format!(
                "snapshot: unsupported schema '{schema}' (want {SNAPSHOT_SCHEMA})"
            )));
        }
        let c = field(v, "cfg")?;
        let cfg = BismoConfig {
            dm: parse_u32(field(c, "dm")?, "cfg.dm")?,
            dk: parse_u32(field(c, "dk")?, "cfg.dk")?,
            dn: parse_u32(field(c, "dn")?, "cfg.dn")?,
            bm: parse_u32(field(c, "bm")?, "cfg.bm")?,
            bn: parse_u32(field(c, "bn")?, "cfg.bn")?,
            br: parse_u32(field(c, "br")?, "cfg.br")?,
            acc_bits: parse_u32(field(c, "acc_bits")?, "cfg.acc_bits")?,
            fetch_bits: parse_u32(field(c, "fetch_bits")?, "cfg.fetch_bits")?,
            res_bits: parse_u32(field(c, "res_bits")?, "cfg.res_bits")?,
            fclk_mhz: parse_u32(field(c, "fclk_mhz")?, "cfg.fclk_mhz")?,
        };
        let e = field(v, "engine")?;
        let running = match field(e, "running")? {
            Json::Bool(b) => *b,
            _ => return Err(BismoError::Parse("snapshot: engine.running not bool".into())),
        };
        let pcs = field(e, "pc")?
            .as_arr()
            .ok_or_else(|| BismoError::Parse("snapshot: engine.pc not an array".into()))?;
        let ts = field(e, "t")?
            .as_arr()
            .ok_or_else(|| BismoError::Parse("snapshot: engine.t not an array".into()))?;
        if pcs.len() != 3 || ts.len() != 3 {
            return Err(BismoError::Parse(
                "snapshot: engine.pc / engine.t must have 3 entries".into(),
            ));
        }
        let mut pc = [0usize; 3];
        let mut t = [0u64; 3];
        for i in 0..3 {
            pc[i] = parse_usize(&pcs[i], "engine.pc[]")?;
            t[i] = parse_hex(&ts[i], "engine.t[]")?;
        }
        let s = field(v, "stats")?;
        let stat = |k: &str| parse_hex(field(s, k)?, k);
        let stats = RunStats {
            cycles: stat("cycles")?,
            fetch_busy: stat("fetch_busy")?,
            execute_busy: stat("execute_busy")?,
            result_busy: stat("result_busy")?,
            fetch_stall: stat("fetch_stall")?,
            execute_stall: stat("execute_stall")?,
            result_stall: stat("result_stall")?,
            bytes_fetched: stat("bytes_fetched")?,
            bytes_written: stat("bytes_written")?,
            binary_ops: stat("binary_ops")?,
            pipeline_fill_cycles: stat("pipeline_fill_cycles")?,
            commits: stat("commits")?,
            acc_overflows: stat("acc_overflows")?,
        };
        let fs = field(v, "fifos")?
            .as_arr()
            .ok_or_else(|| BismoError::Parse("snapshot: fifos not an array".into()))?;
        if fs.len() != 4 {
            return Err(BismoError::Parse("snapshot: want exactly 4 fifos".into()));
        }
        let mut fifos: Vec<FifoState> = Vec::with_capacity(4);
        for f in fs {
            let toks = field(f, "tokens")?
                .as_arr()
                .ok_or_else(|| BismoError::Parse("snapshot: fifo tokens not an array".into()))?
                .iter()
                .map(|t| parse_hex(t, "fifo token"))
                .collect::<Result<Vec<u64>, _>>()?;
            fifos.push(FifoState {
                tokens: toks,
                max_depth: parse_usize(field(f, "max_depth")?, "fifo max_depth")?,
                total: parse_hex(field(f, "total")?, "fifo total")?,
            });
        }
        let fifos: [FifoState; 4] = match fifos.try_into() {
            Ok(a) => a,
            Err(_) => unreachable!("length checked above"),
        };
        let words = |k: &str| -> Result<Vec<u64>, BismoError> {
            field(v, k)?
                .as_arr()
                .ok_or_else(|| BismoError::Parse(format!("snapshot: {k} not an array")))?
                .iter()
                .map(|w| parse_hex(w, k))
                .collect()
        };
        let r = field(v, "result")?;
        let mut result_slots = Vec::new();
        for set in r
            .get("slots")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| BismoError::Parse("snapshot: result.slots not an array".into()))?
        {
            let vals = set
                .as_arr()
                .ok_or_else(|| BismoError::Parse("snapshot: result set not an array".into()))?
                .iter()
                .map(|x| {
                    x.as_f64()
                        .filter(|f| {
                            f.fract() == 0.0 && *f >= i32::MIN as f64 && *f <= i32::MAX as f64
                        })
                        .map(|f| f as i32)
                        .ok_or_else(|| {
                            BismoError::Parse("snapshot: result value not an i32".into())
                        })
                })
                .collect::<Result<Vec<i32>, _>>()?;
            result_slots.push(vals);
        }
        let x = field(v, "exec")?;
        let accs = field(x, "accs")?
            .as_arr()
            .ok_or_else(|| BismoError::Parse("snapshot: exec.accs not an array".into()))?
            .iter()
            .map(|a| parse_hex(a, "exec.accs[]").map(|u| u as i64))
            .collect::<Result<Vec<i64>, _>>()?;
        let dram = dram_from_hex(
            field(v, "dram")?
                .as_str()
                .ok_or_else(|| BismoError::Parse("snapshot: dram not a string".into()))?,
        )?;
        Ok(SimSnapshot {
            cfg,
            running,
            cur: parse_usize(field(e, "cur")?, "engine.cur")?,
            stall_streak: parse_usize(field(e, "stall_streak")?, "engine.stall_streak")?,
            pc,
            t,
            fingerprint: parse_hex(field(e, "fingerprint")?, "engine.fingerprint")?,
            stats,
            fifos,
            lhs: words("lhs")?,
            rhs: words("rhs")?,
            result_slots,
            result_max_occupancy: parse_usize(
                r.get("max_occupancy").ok_or_else(|| {
                    BismoError::Parse("snapshot: missing result.max_occupancy".into())
                })?,
                "result.max_occupancy",
            )?,
            accs,
            overflows: parse_hex(field(x, "overflows")?, "exec.overflows")?,
            dram,
        })
    }

    /// Parse from serialized JSON text.
    pub fn from_json(text: &str) -> Result<Self, BismoError> {
        let v = Json::parse(text).map_err(|e| BismoError::Parse(format!("snapshot: {e}")))?;
        Self::from_json_value(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SimSnapshot {
        SimSnapshot {
            cfg: BismoConfig::small(),
            running: true,
            cur: 1,
            stall_streak: 2,
            pc: [3, 1, 0],
            t: [u64::MAX, 1 << 60, 7],
            fingerprint: 0xDEAD_BEEF_DEAD_BEEF,
            stats: RunStats {
                cycles: 1 << 55,
                binary_ops: u64::MAX - 1,
                ..RunStats::default()
            },
            fifos: [
                FifoState {
                    tokens: vec![1, u64::MAX],
                    max_depth: 2,
                    total: 9,
                },
                FifoState {
                    tokens: vec![],
                    max_depth: 0,
                    total: 0,
                },
                FifoState {
                    tokens: vec![5],
                    max_depth: 1,
                    total: 1,
                },
                FifoState {
                    tokens: vec![],
                    max_depth: 3,
                    total: 8,
                },
            ],
            lhs: vec![0, u64::MAX, 0x1234],
            rhs: vec![42; 5],
            result_slots: vec![vec![i32::MIN, -1, 0, i32::MAX]],
            result_max_occupancy: 2,
            accs: vec![i64::MIN, -3, 0, i64::MAX],
            overflows: 17,
            dram: vec![0x00, 0xFF, 0xA5, 0x5A],
        }
    }

    #[test]
    fn json_roundtrip_preserves_64_bit_extremes() {
        let snap = sample();
        let text = snap.to_json();
        let back = SimSnapshot::from_json(&text).unwrap();
        assert_eq!(back, snap);
    }

    #[test]
    fn malformed_inputs_are_typed_parse_errors() {
        assert!(matches!(
            SimSnapshot::from_json("not json"),
            Err(BismoError::Parse(_))
        ));
        assert!(matches!(
            SimSnapshot::from_json("{\"schema\": \"bogus/v9\"}"),
            Err(BismoError::Parse(_))
        ));
        // Drop a required field: serialize, surgically remove "dram".
        let text = sample().to_json();
        let v = Json::parse(&text).unwrap();
        if let Json::Obj(mut m) = v {
            m.remove("dram");
            let crippled = Json::Obj(m).dump();
            assert!(matches!(
                SimSnapshot::from_json(&crippled),
                Err(BismoError::Parse(_))
            ));
        } else {
            panic!("snapshot did not serialize to an object");
        }
        // Corrupt the hex encoding.
        let bad_hex = text.replace("0x", "0z");
        assert!(matches!(
            SimSnapshot::from_json(&bad_hex),
            Err(BismoError::Parse(_))
        ));
    }

    #[test]
    fn digest_is_stable_and_content_sensitive() {
        let a = digest_bytes(&[1, 2, 3, 4]);
        assert_eq!(a, digest_bytes(&[1, 2, 3, 4]));
        assert_ne!(a, digest_bytes(&[1, 2, 3, 5]));
        assert_ne!(digest_bytes(&[]), digest_bytes(&[0]));
    }
}
