//! [`ShardPlan`]: splitting one GEMM across independent overlay
//! instances, with exact reassembly.

use super::tile::EvenSplit;
use crate::api::BismoError;
use crate::bitmatrix::IntMatrix;
use std::ops::Range;

/// The shape of one GEMM job: `P(m×n) = L(m×k) · R(k×n)`. The minimal
/// vocabulary the partition and cost-model layers share.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl std::fmt::Display for GemmShape {
    /// The `MxKxN` form every bench table and report uses.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}x{}", self.m, self.k, self.n)
    }
}

/// One shard of a [`ShardPlan`]: an output block (`rows × cols`),
/// optionally restricted to a group of LHS bit-planes.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shard {
    /// Position in [`ShardPlan::shards`] order.
    pub index: usize,
    /// Output rows this shard produces (rows of `L`).
    pub rows: Range<usize>,
    /// Output columns this shard produces (rows of the transposed `R`).
    pub cols: Range<usize>,
    /// LHS bit-planes this shard covers; `None` means all planes. Plane
    /// groups at the same `(rows, cols)` block *sum* into the output
    /// (GEMM is linear in the bit-plane decomposition).
    pub planes: Option<Range<u32>>,
}

/// A decomposition of one GEMM into row-block × column-block ×
/// bit-plane-group shards, each an independent smaller GEMM. Row and
/// column blocks land in disjoint output regions; plane groups
/// accumulate into the same region — [`ShardPlan::assemble`] applies
/// both rules and is bit-exact by construction (integer adds over
/// disjoint or linear contributions).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Output rows (`m`) split across shards.
    pub rows: EvenSplit,
    /// Output columns (`n`) split across shards.
    pub cols: EvenSplit,
    /// Optional LHS bit-plane grouping (`total` = declared LHS bits).
    pub planes: Option<EvenSplit>,
}

impl ShardPlan {
    /// The trivial plan: one shard covering the whole output.
    pub fn single(m: usize, n: usize) -> ShardPlan {
        ShardPlan::grid(m, n, 1, 1)
    }

    /// A fixed `row_shards × col_shards` grid (each axis clamped so no
    /// shard is empty).
    pub fn grid(m: usize, n: usize, row_shards: usize, col_shards: usize) -> ShardPlan {
        ShardPlan {
            rows: EvenSplit::new(m, row_shards),
            cols: EvenSplit::new(n, col_shards),
            planes: None,
        }
    }

    /// A grid for (up to) `instances` shards, factored across the two
    /// output axes so shards stay as close to the job's own aspect
    /// ratio as the factorization allows (square-ish shards keep both
    /// DPA dimensions busy on every instance). The count is clamped to
    /// the available output parallelism (`m·n`) and a hard cap of 256 —
    /// shard counts beyond either are useless, and the clamp keeps the
    /// factorization scan bounded for adversarial inputs.
    pub fn for_instances(m: usize, n: usize, instances: usize) -> ShardPlan {
        let cap = m.max(1).saturating_mul(n.max(1)).min(256);
        let instances = instances.clamp(1, cap);
        let mut best: Option<(usize, f64, usize)> = None; // (effective, imbalance, r)
        for r in 1..=instances {
            if instances % r != 0 {
                continue;
            }
            let c = instances / r;
            let effective = r.min(m.max(1)) * c.min(n.max(1));
            // Aspect imbalance of one shard, in log space so 4:1 and
            // 1:4 score identically.
            let sm = (m.max(1) as f64 / r.min(m.max(1)) as f64).max(1.0);
            let sn = (n.max(1) as f64 / c.min(n.max(1)) as f64).max(1.0);
            let imbalance = (sm / sn).ln().abs();
            let better = match best {
                None => true,
                Some((be, bi, _)) => {
                    effective > be || (effective == be && imbalance < bi - 1e-12)
                }
            };
            if better {
                best = Some((effective, imbalance, r));
            }
        }
        let r = best.map(|(_, _, r)| r).unwrap_or(1);
        ShardPlan::grid(m, n, r, instances / r)
    }

    /// Additionally split the LHS bit-planes into `groups` near-equal
    /// groups (`lhs_bits` = the declared LHS precision). Plane-group
    /// shards are supported by the software kernel engine
    /// ([`crate::kernel::gemm_tiled_block`]); their partial products
    /// sum during [`ShardPlan::assemble`].
    pub fn with_plane_groups(mut self, lhs_bits: u32, groups: usize) -> ShardPlan {
        self.planes = Some(EvenSplit::new(lhs_bits as usize, groups));
        self
    }

    /// Total number of shards.
    pub fn count(&self) -> usize {
        self.rows.count() * self.cols.count() * self.planes.map_or(1, |p| p.count())
    }

    /// Is this the trivial single-shard plan?
    pub fn is_single(&self) -> bool {
        self.count() == 1
    }

    /// All shards, row-major over the grid, plane groups innermost.
    pub fn shards(&self) -> Vec<Shard> {
        let mut out = Vec::with_capacity(self.count());
        for ri in 0..self.rows.count() {
            for ci in 0..self.cols.count() {
                match self.planes {
                    None => out.push(Shard {
                        index: out.len(),
                        rows: self.rows.span(ri),
                        cols: self.cols.span(ci),
                        planes: None,
                    }),
                    Some(pl) => {
                        for pi in 0..pl.count() {
                            let span = pl.span(pi);
                            out.push(Shard {
                                index: out.len(),
                                rows: self.rows.span(ri),
                                cols: self.cols.span(ci),
                                planes: Some(span.start as u32..span.end as u32),
                            });
                        }
                    }
                }
            }
        }
        out
    }

    /// Merge per-shard partial results (in [`ShardPlan::shards`] order)
    /// into the full `m×n` product. Row/column blocks write disjoint
    /// regions; plane groups of the same block accumulate.
    pub fn assemble(&self, parts: &[IntMatrix]) -> Result<IntMatrix, BismoError> {
        let shards = self.shards();
        if parts.len() != shards.len() {
            return Err(BismoError::ShapeMismatch(format!(
                "{} shard results for a {}-shard plan",
                parts.len(),
                shards.len()
            )));
        }
        let mut out = IntMatrix::zeros(self.rows.total, self.cols.total);
        for (shard, part) in shards.iter().zip(parts) {
            if part.rows != shard.rows.len() || part.cols != shard.cols.len() {
                return Err(BismoError::ShapeMismatch(format!(
                    "shard {} produced {}×{}, expected {}×{}",
                    shard.index,
                    part.rows,
                    part.cols,
                    shard.rows.len(),
                    shard.cols.len()
                )));
            }
            for (i, r) in shard.rows.clone().enumerate() {
                for (j, c) in shard.cols.clone().enumerate() {
                    out.set(r, c, out.get(r, c) + part.get(i, j));
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_shape_displays_in_bench_form() {
        let s = GemmShape { m: 16, k: 784, n: 10 };
        assert_eq!(s.to_string(), "16x784x10");
    }

    #[test]
    fn grid_covers_output_disjointly() {
        let plan = ShardPlan::grid(10, 7, 3, 2);
        assert_eq!(plan.count(), 6);
        let mut covered = vec![vec![0u32; 7]; 10];
        for s in plan.shards() {
            assert!(s.planes.is_none());
            for r in s.rows.clone() {
                for c in s.cols.clone() {
                    covered[r][c] += 1;
                }
            }
        }
        assert!(covered.iter().flatten().all(|&c| c == 1), "exact cover");
    }

    #[test]
    fn for_instances_prefers_the_larger_axis() {
        // Tall job: the row axis should absorb the split.
        let p = ShardPlan::for_instances(64, 4, 4);
        assert_eq!((p.rows.count(), p.cols.count()), (4, 1));
        // Wide job: the column axis.
        let p = ShardPlan::for_instances(4, 64, 4);
        assert_eq!((p.rows.count(), p.cols.count()), (1, 4));
        // Square job, 4 instances: 2×2.
        let p = ShardPlan::for_instances(32, 32, 4);
        assert_eq!((p.rows.count(), p.cols.count()), (2, 2));
    }

    #[test]
    fn for_instances_clamps_to_available_work() {
        let p = ShardPlan::for_instances(2, 1, 8);
        assert!(p.count() <= 2, "no empty shards: {}", p.count());
        assert_eq!(ShardPlan::for_instances(1, 1, 8).count(), 1);
        assert_eq!(ShardPlan::for_instances(5, 5, 0).count(), 1);
        // Absurd requests terminate fast and clamp to useful work.
        assert!(ShardPlan::for_instances(4, 4, usize::MAX).count() <= 16);
        assert!(ShardPlan::for_instances(10_000, 10_000, usize::MAX).count() <= 256);
    }

    #[test]
    fn plane_groups_multiply_count() {
        let p = ShardPlan::grid(8, 8, 2, 2).with_plane_groups(5, 2);
        assert_eq!(p.count(), 8);
        let shards = p.shards();
        assert_eq!(shards[0].planes, Some(0..3));
        assert_eq!(shards[1].planes, Some(3..5));
        assert_eq!(shards[0].rows, shards[1].rows, "plane groups share a block");
    }

    #[test]
    fn assemble_copies_blocks_and_sums_plane_groups() {
        // 2×1 row split with 2 plane groups: four parts, plane pairs sum.
        let plan = ShardPlan::grid(2, 2, 2, 1).with_plane_groups(4, 2);
        let parts = vec![
            IntMatrix::from_slice(1, 2, &[1, 2]),
            IntMatrix::from_slice(1, 2, &[10, 20]),
            IntMatrix::from_slice(1, 2, &[3, 4]),
            IntMatrix::from_slice(1, 2, &[30, 40]),
        ];
        let out = plan.assemble(&parts).unwrap();
        assert_eq!(out, IntMatrix::from_slice(2, 2, &[11, 22, 33, 44]));
    }

    #[test]
    fn assemble_rejects_wrong_arity_and_shape() {
        let plan = ShardPlan::grid(4, 4, 2, 1);
        assert!(matches!(
            plan.assemble(&[IntMatrix::zeros(2, 4)]),
            Err(BismoError::ShapeMismatch(_))
        ));
        assert!(matches!(
            plan.assemble(&[IntMatrix::zeros(2, 4), IntMatrix::zeros(3, 4)]),
            Err(BismoError::ShapeMismatch(_))
        ));
    }
}
