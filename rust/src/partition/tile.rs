//! 1-D axis splits and the [`TilePlan`]: the single place where GEMM
//! tiling arithmetic lives.

use crate::util::ceil_div;
use std::ops::Range;

/// Uniform split of `0..total` into fixed-size blocks of `block`
/// elements; the last block is ragged when `block` does not divide
/// `total`. This is the tiling shape of fixed hardware resources: a
/// `D_m × D_n` DPA walks the output in `D_m`-row blocks, the software
/// kernel walks it in `tile_m`-row blocks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockSplit {
    /// Extent of the axis being split.
    pub total: usize,
    /// Nominal block size (>= 1).
    pub block: usize,
}

impl BlockSplit {
    /// Split `0..total` into `ceil(total / block)` blocks.
    pub fn new(total: usize, block: usize) -> BlockSplit {
        assert!(block >= 1, "block size must be >= 1");
        BlockSplit { total, block }
    }

    /// Number of blocks (`0` when the axis is empty).
    pub fn count(&self) -> usize {
        ceil_div(self.total as u64, self.block as u64) as usize
    }

    /// Half-open range of block `i`.
    pub fn span(&self, i: usize) -> Range<usize> {
        debug_assert!(i < self.count(), "block {i} of {}", self.count());
        let start = i * self.block;
        start..(start + self.block).min(self.total)
    }

    /// Length of block `i` (the last block may be shorter).
    pub fn len_of(&self, i: usize) -> usize {
        self.span(i).len()
    }

    /// All block spans, in order.
    pub fn iter(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.count()).map(|i| self.span(i))
    }
}

/// Near-equal split of `0..total` into `parts` contiguous pieces whose
/// sizes differ by at most one. This is the sharding shape: work divided
/// across `parts` equal instances, no instance idling on a ragged tail.
/// `parts` is clamped to `1..=max(total, 1)` so every piece is non-empty
/// (for a non-empty axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EvenSplit {
    /// Extent of the axis being split.
    pub total: usize,
    /// Number of pieces (clamped at construction).
    pub parts: usize,
}

impl EvenSplit {
    pub fn new(total: usize, parts: usize) -> EvenSplit {
        EvenSplit {
            total,
            parts: parts.max(1).min(total.max(1)),
        }
    }

    /// Number of pieces.
    pub fn count(&self) -> usize {
        self.parts
    }

    /// Half-open range of piece `i`: the first `total % parts` pieces
    /// get one extra element.
    pub fn span(&self, i: usize) -> Range<usize> {
        debug_assert!(i < self.parts, "piece {i} of {}", self.parts);
        let base = self.total / self.parts;
        let rem = self.total % self.parts;
        let start = i * base + i.min(rem);
        start..start + base + usize::from(i < rem)
    }

    /// All piece spans, in order.
    pub fn iter(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.parts).map(|i| self.span(i))
    }
}

/// The tiling decisions for one GEMM `P(m×n) = L(m×k)·R(k×n)`: output
/// rows in `tile_m`-blocks, output columns in `tile_n`-blocks, the
/// inner `k` dimension in `tile_k`-chunks.
///
/// Both tilers in the crate consume this one type: the scheduler plans
/// `D_m × D_n × D_k` hardware tiles ([`crate::scheduler::plan()`]) and
/// the software kernel walks `tile_m × tile_n` cache blocks
/// ([`crate::kernel::gemm_tiled_with`]) — the `ceil`-division and span
/// arithmetic is written here exactly once.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TilePlan {
    /// Output rows (`m`) in `tile_m`-blocks.
    pub rows: BlockSplit,
    /// Output columns (`n`) in `tile_n`-blocks.
    pub cols: BlockSplit,
    /// Inner dimension (`k`) in `tile_k`-chunks.
    pub depth: BlockSplit,
}

impl TilePlan {
    pub fn new(
        m: usize,
        n: usize,
        k: usize,
        tile_m: usize,
        tile_n: usize,
        tile_k: usize,
    ) -> TilePlan {
        TilePlan {
            rows: BlockSplit::new(m, tile_m),
            cols: BlockSplit::new(n, tile_n),
            depth: BlockSplit::new(k, tile_k),
        }
    }

    /// Output row tiles: `ceil(m / tile_m)`.
    pub fn row_tiles(&self) -> usize {
        self.rows.count()
    }

    /// Output column tiles: `ceil(n / tile_n)`.
    pub fn col_tiles(&self) -> usize {
        self.cols.count()
    }

    /// Inner-dimension chunks: `ceil(k / tile_k)`.
    pub fn k_chunks(&self) -> usize {
        self.depth.count()
    }

    /// Result-tile commits a full walk performs (= row × column tiles).
    pub fn commits(&self) -> usize {
        self.row_tiles() * self.col_tiles()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_split_counts_and_spans() {
        let s = BlockSplit::new(10, 4);
        assert_eq!(s.count(), 3);
        assert_eq!(s.span(0), 0..4);
        assert_eq!(s.span(1), 4..8);
        assert_eq!(s.span(2), 8..10);
        assert_eq!(s.len_of(2), 2);
        assert_eq!(BlockSplit::new(0, 4).count(), 0);
        assert_eq!(BlockSplit::new(4, 4).count(), 1);
    }

    #[test]
    fn block_split_covers_exactly() {
        for (total, block) in [(1, 1), (7, 3), (64, 8), (65, 8), (100, 64)] {
            let s = BlockSplit::new(total, block);
            let mut next = 0;
            for span in s.iter() {
                assert_eq!(span.start, next, "contiguous");
                assert!(!span.is_empty());
                next = span.end;
            }
            assert_eq!(next, total, "exhaustive");
        }
    }

    #[test]
    fn even_split_balanced() {
        let s = EvenSplit::new(10, 4);
        let lens: Vec<usize> = s.iter().map(|r| r.len()).collect();
        assert_eq!(lens, vec![3, 3, 2, 2]);
        assert_eq!(s.span(0), 0..3);
        assert_eq!(s.span(3), 8..10);
    }

    #[test]
    fn even_split_clamps_parts() {
        assert_eq!(EvenSplit::new(3, 8).count(), 3); // no empty pieces
        assert_eq!(EvenSplit::new(3, 0).count(), 1);
        assert_eq!(EvenSplit::new(0, 4).count(), 1);
        assert_eq!(EvenSplit::new(0, 4).span(0), 0..0);
    }

    #[test]
    fn even_split_covers_exactly() {
        for (total, parts) in [(1, 1), (10, 3), (64, 8), (65, 8), (7, 7)] {
            let s = EvenSplit::new(total, parts);
            let mut next = 0;
            let mut min = usize::MAX;
            let mut max = 0;
            for span in s.iter() {
                assert_eq!(span.start, next);
                min = min.min(span.len());
                max = max.max(span.len());
                next = span.end;
            }
            assert_eq!(next, total);
            assert!(max - min <= 1, "sizes differ by at most one");
        }
    }

    #[test]
    fn tile_plan_matches_ceil_division() {
        let t = TilePlan::new(5, 3, 100, 2, 2, 64);
        assert_eq!(t.row_tiles(), 3);
        assert_eq!(t.col_tiles(), 2);
        assert_eq!(t.k_chunks(), 2);
        assert_eq!(t.commits(), 6);
        assert_eq!(t.rows.span(2), 4..5);
    }
}
