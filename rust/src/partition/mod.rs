//! The partition layer: the single owner of GEMM decomposition.
//!
//! Two kinds of decomposition used to live in two places with their own
//! arithmetic — the software kernel's cache tiling (`kernel::engine`)
//! and the scheduler's buffer-capacity tiling (`scheduler::plan`). Both
//! now consume [`TilePlan`]; the `ceil`-division, span and raggedness
//! rules are written here exactly once.
//!
//! On top of the intra-instance tiling sits the *inter*-instance split:
//! [`ShardPlan`] decomposes one GEMM into row-block × column-block ×
//! bit-plane-group shards, each an independent smaller GEMM that a
//! separate overlay instance (or worker lane) can execute, with exact
//! reassembly metadata ([`ShardPlan::assemble`]). This is the shape of
//! the paper's scalability claim (§III-B): the cost model says how many
//! instances a fabric affords ([`crate::costmodel::select_sharding`]),
//! the shard plan says what each of them computes, and
//! [`crate::coordinator::BismoService`] dispatches and merges.
//!
//! Layering: `partition` depends only on `bitmatrix`/`api`/`util`;
//! `kernel`, `scheduler`, `costmodel` and `coordinator` all sit above
//! it.

mod shard;
mod tile;

pub use shard::{GemmShape, Shard, ShardPlan};
pub use tile::{BlockSplit, EvenSplit, TilePlan};
