//! # BISMO — Bit-Serial Matrix Multiplication Overlay (full-system reproduction)
//!
//! Reproduction of *BISMO: A Scalable Bit-Serial Matrix Multiplication
//! Overlay for Reconfigurable Computing* (Umuroglu, Rasnayake, Själander,
//! 2018) as a three-layer Rust + JAX + Pallas stack.
//!
//! The original artifact is an FPGA overlay for the Xilinx PYNQ-Z1. This
//! crate replaces the hardware with faithful software models (see
//! `DESIGN.md` §Substitutions) while keeping the paper's entire
//! hardware/software contract intact:
//!
//! * [`bitmatrix`] — bit-packed matrices and signed bit-plane decomposition
//!   (the data representation of Algorithm 1).
//! * [`arch`] — hardware configuration ([`arch::BismoConfig`]), the paper's
//!   Table IV instance presets and the PYNQ-Z1 platform description.
//! * [`isa`] — the three-stage instruction set (Table II): `Wait`, `Signal`,
//!   `RunFetch`, `RunExecute`, `RunResult`, with binary encode/decode.
//! * [`scheduler`] — the software half of the overlay: compiles a matmul
//!   job into per-stage instruction streams (tiling, stage overlap,
//!   bit-plane weights, sparse bit-skip).
//! * [`sim`] — functional *and* cycle-level simulator of the fetch /
//!   execute / result pipeline (DPA, matrix buffers, sync FIFOs, DMA).
//! * [`synth`] — netlist generator + 6-LUT technology mapper + Fmax model
//!   standing in for Vivado out-of-context synthesis (Figs 6–9, 11).
//! * [`costmodel`] — the paper's analytic LUT/BRAM cost model (Eqs 1–2)
//!   plus least-squares constant fitting.
//! * [`power`] — calibrated power model reproducing Table V.
//! * [`baseline`] — CPU bit-serial gemm (Umuroglu & Jahre) used both as a
//!   Table VI comparison point and as a correctness oracle.
//! * [`kernel`] — the fast software path: tiled, plane-fused,
//!   zero-plane-skipping bit-serial GEMM engine plus the persistent
//!   worker pool shared by every parallel path in the crate.
//! * [`lowering`] — convolution lowering: [`lowering::ConvSpec`] with
//!   im2col / kn2row lowering onto the GEMM stack, a
//!   zero-materialization packed-im2col path
//!   ([`lowering::pack_im2col`]) and the naive direct-convolution
//!   oracle ([`lowering::conv2d_direct`]) every lowered path is tested
//!   against.
//! * [`partition`] — the single owner of GEMM decomposition:
//!   [`partition::TilePlan`] (the tiling arithmetic both the scheduler
//!   and the kernel tiler consume) and [`partition::ShardPlan`]
//!   (row-block × column-block × bit-plane-group shards with exact
//!   reassembly — the unit of multi-instance execution).
//! * `runtime` — PJRT CPU client: loads the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them from Rust.
//!   Gated behind the `xla` cargo feature (needs the PJRT plugin and
//!   the `xla`/`anyhow` crates, absent from the offline registry), so
//!   it is deliberately not an intra-doc link here.
//! * [`api`] — **the crate's front door**: [`api::Session`] (owns the
//!   worker pool, packing cache and backends), [`api::MatmulBuilder`]
//!   (per-job options, validated before queueing) and [`api::Prepared`]
//!   (prepare-once-execute-many weights), all returning the typed
//!   [`api::BismoError`].
//! * [`coordinator`] — the machinery beneath the facade:
//!   [`coordinator::BismoContext`] for one synchronous matmul,
//!   [`coordinator::BismoBatchRunner`] for one pre-assembled batch, and
//!   [`coordinator::BismoService`] — the asynchronous serving layer
//!   with dynamic micro-batching, per-request backend selection and a
//!   weight-stationary packing cache (`DESIGN.md` §Serving-Layer).
//! * [`simd`] — runtime-dispatched SIMD strips for the AND+popcount
//!   datapath and bit-plane packing ([`simd::DispatchTier`]: AVX-512 /
//!   AVX2 Harley–Seal / NEON / scalar, overridable via `BISMO_SIMD`),
//!   property-tested bit-exact against the scalar reference strip at
//!   every host-supported tier (`DESIGN.md` §11).
//! * [`net`] — the network serving front door: length-prefixed binary
//!   wire protocol ([`net::wire`]) over std TCP, multi-tenant sessions
//!   with per-tenant cache namespaces and quotas, admission control
//!   with typed [`api::BismoError::Overloaded`] load shedding
//!   ([`net::NetServer`] / [`net::NetClient`], hosted by
//!   `bismo serve`; `DESIGN.md` §12).
//! * [`qnn`] — quantized-neural-network layers running on the overlay.
//! * [`fuzz`] — seeded structured fuzzing (legal / mutation /
//!   differential) and the golden snapshot report behind `bismo fuzz`
//!   and `bismo snapshot` (`DESIGN.md` §10).
//! * [`report`] — table/figure formatting used by the benchmark harness.
//! * [`util`] — PRNG, CSV, timing helpers (offline build: no external deps).

pub mod api;
pub mod arch;
pub mod baseline;
pub mod bitmatrix;
pub mod coordinator;
pub mod costmodel;
pub mod fuzz;
pub mod isa;
pub mod kernel;
pub mod lowering;
pub mod net;
pub mod partition;
pub mod power;
pub mod qnn;
pub mod report;
#[cfg(feature = "xla")]
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod simd;
pub mod synth;
pub mod util;

pub use api::{BismoError, MatmulBuilder, Prepared, Session, SessionConfig};
pub use arch::{BismoConfig, Platform};
pub use bitmatrix::{BitSerialMatrix, IntMatrix};
pub use coordinator::{BismoContext, BismoService, Precision, RunReport};
