//! Structured fuzzing of the ISA, the cycle-accurate simulator and the
//! serving backends — plus the deterministic golden-snapshot report the
//! CI ratchet checks.
//!
//! Everything here is driven by the repo's seeded [`Rng`] (xoshiro256**)
//! and never touches wall-clock time or OS randomness, so **every
//! failure is replayable from a one-line seed**: case `i` of a run with
//! seed `S` uses `case_seed(S, i)`, which the failure report prints.
//!
//! Four modes, mirrored by `bismo fuzz --mode`:
//!
//! * **legal** — [`generate_legal_program`] emits arbitrary-but-legal
//!   programs (token-causal generation order + a result-buffer credit
//!   protocol make them deadlock- and fault-free by construction). They
//!   must run to completion: no panic, no deadlock, no stage fault. The
//!   same case is then re-run to check determinism, and run a third
//!   time through a mid-run `snapshot → JSON → restore` cycle that must
//!   be bit- and cycle-exact.
//! * **mutation** — the same legal programs are serialized with
//!   [`Program::to_bytes`] and corrupted (bit flips, truncation,
//!   extension, garbage splices). Decoding and running the corpse must
//!   yield typed errors ([`BismoError::Parse`] /
//!   [`BismoError::IllegalProgram`] / [`BismoError::SimFault`]) — never
//!   a panic.
//! * **differential** — random shapes / precisions / sharding configs
//!   are served through both [`Backend::Engine`] and [`Backend::Sim`]
//!   on one [`BismoService`] and compared against the
//!   [`IntMatrix::matmul`] oracle, then re-run through the kernel
//!   pinned to the forced-scalar [`DispatchTier`] and to the best tier
//!   the host supports (packing compared word-for-word, results
//!   bit-exact). Failing cases are greedily minimized before being
//!   reported.
//! * **wire** — random legal [`crate::net::wire`] frames (every
//!   request and response kind) are round-tripped, then corrupted with
//!   the same byte mutations as the ISA mutation mode. Decoding the
//!   corpse must yield a typed [`BismoError::Parse`] or a valid decode
//!   — never a panic, an over-allocation or any other error class
//!   (the front door's frame-robustness guarantee).

use crate::api::BismoError;
use crate::arch::{BismoConfig, PYNQ_Z1};
use crate::bitmatrix::dram::{DramImage, OperandLayout, ResultLayout};
use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
use crate::coordinator::{
    Backend, BismoService, GemmRequest, Precision, RequestOptions, ServiceConfig, Sharding,
};
use crate::isa::{ExecuteRun, FetchRun, Instr, Program, ResultRun, Stage, SyncChannel};
use crate::kernel::gemm_tiled_tier;
use crate::scheduler::{self, MatmulJob, Overlap};
use crate::sim::{digest_bytes, SimSnapshot, Simulation, StepOutcome};
use crate::simd::DispatchTier;
use crate::util::json::Json;
use crate::util::{ceil_div, round_up, splitmix64, Rng};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// DRAM image size used by the legal/mutation modes. Big enough for any
/// generated access pattern, small enough to snapshot cheaply.
const FUZZ_DRAM_BYTES: usize = 1 << 16;

/// Derive the per-case seed printed in failure reports. Case `i` of a
/// run seeded `S` is fully reproduced by `Rng::new(case_seed(S, i))`.
pub fn case_seed(seed: u64, index: u64) -> u64 {
    splitmix64(seed ^ (index.wrapping_add(1)).wrapping_mul(0x9e37_79b9_7f4a_7c15))
}

/// One replayable fuzz failure.
#[derive(Clone, Debug)]
pub struct FuzzFailure {
    /// Mode name: `legal`, `mutation`, `differential` or `wire`.
    pub mode: &'static str,
    /// Case index within the run.
    pub index: u64,
    /// The derived per-case seed — the one-line repro handle.
    pub seed: u64,
    /// What went wrong (panic payload, mismatch diff, minimized case).
    pub detail: String,
}

/// Result of one fuzz mode run.
#[derive(Clone, Debug)]
pub struct FuzzOutcome {
    pub mode: &'static str,
    pub iters: u64,
    pub failures: Vec<FuzzFailure>,
}

impl FuzzOutcome {
    pub fn ok(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Render failure lists as the JSON artifact CI uploads.
pub fn failures_to_json(outcomes: &[FuzzOutcome]) -> String {
    let list: Vec<Json> = outcomes
        .iter()
        .flat_map(|o| o.failures.iter())
        .map(|f| {
            Json::Obj(
                [
                    ("mode".to_string(), Json::str(f.mode)),
                    ("index".to_string(), Json::num(f.index as f64)),
                    ("seed".to_string(), Json::Str(format!("{:#x}", f.seed))),
                    ("detail".to_string(), Json::str(&f.detail)),
                ]
                .into_iter()
                .collect(),
            )
        })
        .collect();
    Json::Arr(list).pretty(2)
}

/// Random overlay configuration drawn from the small end of the design
/// space (§V instances are too large to fuzz densely).
pub fn random_fuzz_config(rng: &mut Rng) -> BismoConfig {
    BismoConfig {
        dm: *rng.pick(&[2, 4]),
        dk: *rng.pick(&[64, 128]),
        dn: *rng.pick(&[2, 4]),
        bm: 64,
        bn: 64,
        br: *rng.pick(&[1, 2, 4]),
        acc_bits: *rng.pick(&[16, 32, 64]),
        ..BismoConfig::small()
    }
}

/// Generate an arbitrary-but-legal program for `cfg` over a
/// `dram_len`-byte image.
///
/// Legality by construction:
///
/// * **Token causality** — the generation order is itself a valid
///   sequential execution: a `Wait` is only emitted while its channel
///   has a pending generated `Signal`. Any concurrent stage
///   interleaving therefore has at least one runnable instruction until
///   the program drains (no deadlock).
/// * **Result-buffer credits** — the first `B_r` commits are free;
///   every later commit is preceded (in the execute queue) by a
///   `Wait(result→execute)` whose token is only ever produced by a
///   drained `RunResult`, so at commit *i* at least `i − B_r + 1` sets
///   have drained and occupancy stays below `B_r` under *any* runtime
///   interleaving (no overflow). Symmetrically every `RunResult` is
///   gated on a commit's `Signal(execute→result)` (no underflow).
/// * **Bounded addresses** — fetch/execute/result operand ranges are
///   drawn inside the buffer depths and the DRAM image.
pub fn generate_legal_program(rng: &mut Rng, cfg: &BismoConfig, dram_len: usize) -> Program {
    use SyncChannel::{ExecuteToFetch, ExecuteToResult, FetchToExecute, ResultToExecute};
    let wpc = ceil_div(cfg.dk as u64, 64);
    let chunk_bytes = wpc * 8;
    let num_bufs = (cfg.dm + cfg.dn) as usize;
    let depth = cfg.bm as i64; // bm == bn in fuzz configs
    let br = cfg.br as u64;

    let mut p = Program::new();
    // Pending generated-but-unconsumed tokens per channel
    // [F→E, E→F, E→R, R→E].
    let mut pending = [0u64; 4];
    let mut commits = 0u64;
    let mut drained = 0u64;

    let push_exec = |p: &mut Program, rng: &mut Rng, commit: bool| {
        let chunks = rng.range(1, 8) as u32;
        p.push(
            Stage::Execute,
            Instr::Execute(ExecuteRun {
                lhs_offset: rng.range(0, depth - chunks as i64) as u32,
                rhs_offset: rng.range(0, depth - chunks as i64) as u32,
                num_chunks: chunks,
                shift: rng.range(0, 20) as u8,
                negate: rng.chance(0.3),
                acc_reset: rng.chance(0.3),
                commit_result: commit,
            }),
        );
    };

    let ops = rng.range(8, 48);
    for _ in 0..ops {
        match rng.index(8) {
            0 => {
                p.push(Stage::Fetch, Instr::Signal(FetchToExecute));
                pending[0] += 1;
            }
            1 if pending[0] > 0 => {
                p.push(Stage::Execute, Instr::Wait(FetchToExecute));
                pending[0] -= 1;
            }
            2 => {
                p.push(Stage::Execute, Instr::Signal(ExecuteToFetch));
                pending[1] += 1;
            }
            3 if pending[1] > 0 => {
                p.push(Stage::Fetch, Instr::Wait(ExecuteToFetch));
                pending[1] -= 1;
            }
            4 => {
                // RunFetch: W words/block × B blocks, all cursors bounded
                // by buf_offset + W·B ≤ depth.
                let w = rng.range(1, 4) as u64;
                let b = rng.range(1, 4) as u64;
                let total_words = w * b; // ≤ 16
                let block_bytes = w * chunk_bytes;
                let stride = rng.range(0, 3) as u64 * chunk_bytes;
                let extent = (b - 1) * stride + block_bytes;
                let base = rng.below((dram_len as u64 - extent) / 8 + 1) * 8;
                let range = rng.range(1, (num_bufs as i64).min(4)) as u8;
                p.push(
                    Stage::Fetch,
                    Instr::Fetch(FetchRun {
                        dram_base: base,
                        block_bytes: block_bytes as u32,
                        block_stride_bytes: stride as u32,
                        num_blocks: b as u32,
                        buf_offset: rng.range(0, depth - total_words as i64) as u32,
                        buf_start: rng.range(0, num_bufs as i64 - range as i64) as u8,
                        buf_range: range,
                        words_per_buf: rng.range(1, 8) as u32,
                    }),
                );
            }
            5 => push_exec(&mut p, rng, false),
            6 => {
                // Commit: past the first B_r free slots, spend a
                // result→execute credit first.
                if commits >= br {
                    if pending[3] == 0 {
                        continue;
                    }
                    p.push(Stage::Execute, Instr::Wait(ResultToExecute));
                    pending[3] -= 1;
                }
                push_exec(&mut p, rng, true);
                p.push(Stage::Execute, Instr::Signal(ExecuteToResult));
                pending[2] += 1;
                commits += 1;
            }
            _ => {
                // RunResult triple, gated on a committed set.
                if drained >= commits || pending[2] == 0 {
                    continue;
                }
                p.push(Stage::Result, Instr::Wait(ExecuteToResult));
                pending[2] -= 1;
                let rows = rng.range(1, cfg.dm as i64);
                let cols = rng.range(1, cfg.dn as i64);
                let stride = 4 * rng.range(cols, cols + 16) as u64;
                let extent = (rows as u64 - 1) * stride + cols as u64 * 4;
                let base = rng.below((dram_len as u64 - extent) / 4 + 1) * 4;
                p.push(
                    Stage::Result,
                    Instr::Result(ResultRun {
                        dram_base: base,
                        offset: 0,
                        rows: rows as u8,
                        cols: cols as u8,
                        row_stride_bytes: stride as u32,
                    }),
                );
                p.push(Stage::Result, Instr::Signal(ResultToExecute));
                pending[3] += 1;
                drained += 1;
            }
        }
    }

    // Drain every committed-but-unwritten set (pending[2] == commits −
    // drained holds as an invariant of the cases above).
    while drained < commits {
        p.push(Stage::Result, Instr::Wait(ExecuteToResult));
        pending[2] -= 1;
        p.push(
            Stage::Result,
            Instr::Result(ResultRun {
                dram_base: 0,
                offset: 0,
                rows: 1,
                cols: 1,
                row_stride_bytes: 4,
            }),
        );
        p.push(Stage::Result, Instr::Signal(ResultToExecute));
        pending[3] += 1;
        drained += 1;
    }
    // Balance the remaining channels so `Program::validate` passes; all
    // these waits consume already-generated tokens, so they never stall
    // forever.
    for _ in 0..pending[0] {
        p.push(Stage::Execute, Instr::Wait(FetchToExecute));
    }
    for _ in 0..pending[1] {
        p.push(Stage::Fetch, Instr::Wait(ExecuteToFetch));
    }
    for _ in 0..pending[3] {
        p.push(Stage::Execute, Instr::Wait(ResultToExecute));
    }
    p
}

/// Seeded DRAM image for legal/mutation cases.
fn fuzz_dram(seed: u64) -> DramImage {
    let mut img = DramImage::new(FUZZ_DRAM_BYTES);
    for i in 0..(FUZZ_DRAM_BYTES as u64 / 8) {
        img.write_u64(i * 8, splitmix64(seed ^ i));
    }
    img
}

fn panic_payload(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        format!("panic: {s}")
    } else if let Some(s) = e.downcast_ref::<String>() {
        format!("panic: {s}")
    } else {
        "panic: <non-string payload>".to_string()
    }
}

/// Run one legal-mode case; `Err(detail)` on any violation.
fn legal_case(seed: u64) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let cfg = random_fuzz_config(&mut rng);
    let prog = generate_legal_program(&mut rng, &cfg, FUZZ_DRAM_BYTES);
    prog.validate()
        .map_err(|e| format!("generated program invalid: {e}"))?;

    // 1. Must run to completion with no fault and no deadlock.
    let mut sim = Simulation::new(cfg, &PYNQ_Z1, fuzz_dram(seed))
        .map_err(|e| format!("config rejected: {e}"))?;
    let stats = sim
        .run(&prog)
        .map_err(|e| format!("legal program errored: {e}"))?;

    // 2. Determinism: an identical fresh run is bit- and cycle-exact.
    let mut sim2 = Simulation::new(cfg, &PYNQ_Z1, fuzz_dram(seed)).unwrap();
    let stats2 = sim2.run(&prog).map_err(|e| format!("re-run errored: {e}"))?;
    if stats != stats2 || sim.dram.as_bytes() != sim2.dram.as_bytes() {
        return Err("two identical runs diverged (non-determinism)".to_string());
    }

    // 3. Mid-run snapshot → JSON → restore must converge to the same
    //    final state.
    let cut = rng.below(prog.stats().total as u64 + 1);
    let mut sim3 = Simulation::new(cfg, &PYNQ_Z1, fuzz_dram(seed)).unwrap();
    sim3.begin(&prog).unwrap();
    if let StepOutcome::Suspended = sim3
        .step(&prog, cut)
        .map_err(|e| format!("budgeted run errored: {e}"))?
    {
        let text = sim3.snapshot().to_json();
        let snap = SimSnapshot::from_json(&text)
            .map_err(|e| format!("snapshot JSON roundtrip failed: {e}"))?;
        let mut resumed = Simulation::restore(&snap, &PYNQ_Z1)
            .map_err(|e| format!("snapshot restore failed: {e}"))?;
        match resumed
            .step(&prog, u64::MAX)
            .map_err(|e| format!("resumed run errored: {e}"))?
        {
            StepOutcome::Completed(rstats) => {
                if rstats != stats || resumed.dram.as_bytes() != sim.dram.as_bytes() {
                    return Err(format!(
                        "resume after snapshot at instr {cut} diverged from uninterrupted run"
                    ));
                }
            }
            StepOutcome::Suspended => return Err("unbounded resume suspended".to_string()),
        }
    }
    Ok(())
}

/// Legal mode: arbitrary-but-legal programs must complete, be
/// deterministic and survive a snapshot/restore cycle.
pub fn fuzz_legal(iters: u64, seed: u64) -> FuzzOutcome {
    run_mode("legal", iters, seed, legal_case)
}

/// Corrupt `bytes` in 1–4 structured ways.
fn mutate_bytes(rng: &mut Rng, bytes: &mut Vec<u8>) {
    for _ in 0..rng.range(1, 4) {
        match rng.index(4) {
            0 if !bytes.is_empty() => {
                // Flip one bit.
                let i = rng.index(bytes.len());
                bytes[i] ^= 1 << rng.index(8);
            }
            1 if !bytes.is_empty() => {
                // Truncate a random suffix (often mid-word).
                let keep = rng.index(bytes.len());
                bytes.truncate(keep);
            }
            2 => {
                // Append garbage.
                for _ in 0..rng.range(1, 24) {
                    bytes.push(rng.below(256) as u8);
                }
            }
            _ => {
                // Splice a whole garbage word over a random offset.
                let word = rng.next_u64() as u128 | (rng.next_u64() as u128) << 64;
                let start = if bytes.len() >= 16 {
                    rng.index(bytes.len() - 15)
                } else {
                    bytes.resize(16, 0);
                    0
                };
                bytes[start..start + 16].copy_from_slice(&word.to_le_bytes());
            }
        }
    }
}

/// Run one mutation-mode case; `Err(detail)` only on a panic or an
/// untyped escape — typed errors are the expected outcome.
fn mutation_case(seed: u64) -> Result<(), String> {
    let mut rng = Rng::new(seed);
    let cfg = random_fuzz_config(&mut rng);
    let prog = generate_legal_program(&mut rng, &cfg, FUZZ_DRAM_BYTES);
    let mut bytes = prog.to_bytes();
    mutate_bytes(&mut rng, &mut bytes);

    match Program::from_bytes(&bytes) {
        Err(BismoError::Parse(_)) | Err(BismoError::IllegalProgram(_)) => Ok(()),
        Err(e) => Err(format!("unexpected error class from decode: {e}")),
        Ok(decoded) => {
            // The corruption produced a decodable, validated program —
            // running it must end in a typed outcome (ok, fault or
            // deadlock), never a panic (the catch_unwind wrapper in
            // `run_mode` converts panics into failures).
            let mut sim = Simulation::new(cfg, &PYNQ_Z1, fuzz_dram(seed))
                .map_err(|e| format!("config rejected: {e}"))?;
            match sim.run(&decoded) {
                Ok(_) | Err(BismoError::SimFault(_)) | Err(BismoError::IllegalProgram(_)) => Ok(()),
                Err(e) => Err(format!("unexpected error class from run: {e}")),
            }
        }
    }
}

/// Mutation mode: corrupted encodings must always yield typed errors.
pub fn fuzz_mutation(iters: u64, seed: u64) -> FuzzOutcome {
    run_mode("mutation", iters, seed, mutation_case)
}

/// One random legal wire frame: every request/response kind, with
/// small random payload shapes.
fn random_wire_frame(rng: &mut Rng) -> Result<Vec<u8>, BismoError> {
    use crate::lowering::{ConvSpec, Tensor};
    use crate::net::wire::{self, Request, Response, WireStats};
    let mat = |rng: &mut Rng| {
        let rows = rng.index(6) + 1;
        let cols = rng.index(80) + 1;
        let signed = rng.chance(0.5);
        IntMatrix::random(rng, rows, cols, 3, signed)
    };
    let prec = |rng: &mut Rng| Precision {
        wbits: rng.range(1, 4) as u32,
        abits: rng.range(1, 4) as u32,
        lsigned: rng.chance(0.5),
        rsigned: rng.chance(0.5),
    };
    let backend = |rng: &mut Rng| {
        if rng.chance(0.5) {
            Backend::Engine
        } else {
            Backend::Sim
        }
    };
    let req_id = rng.next_u64() as u32;
    match rng.index(12) {
        0 => wire::encode_request(
            req_id,
            &Request::Hello {
                tenant: format!("tenant-{}", rng.index(100)),
            },
        ),
        1 => wire::encode_request(
            req_id,
            &Request::Matmul {
                prec: prec(rng),
                backend: backend(rng),
                verify: rng.chance(0.5),
                a: mat(rng),
                b: mat(rng),
            },
        ),
        2 => wire::encode_request(
            req_id,
            &Request::PrepareWeights {
                bits: rng.range(1, 8) as u32,
                signed: rng.chance(0.5),
                weights: mat(rng),
            },
        ),
        3 => wire::encode_request(
            req_id,
            &Request::MatmulPrepared {
                weight_id: rng.next_u64(),
                prec: prec(rng),
                backend: backend(rng),
                verify: rng.chance(0.5),
                a: mat(rng),
            },
        ),
        4 => {
            let spec = ConvSpec::simple(
                rng.index(6) + 3,
                rng.index(6) + 3,
                rng.index(3) + 1,
                rng.index(3) + 1,
                3,
                1,
            );
            let input = Tensor::random(rng, 1, spec.in_h, spec.in_w, spec.in_c, 2, false);
            let weights = spec.weights_from_fn(|_, _, _, _| rng.operand(2, true));
            wire::encode_request(
                req_id,
                &Request::Conv {
                    spec,
                    mode: if rng.chance(0.5) {
                        crate::lowering::LoweringMode::Im2col
                    } else {
                        crate::lowering::LoweringMode::Kn2row
                    },
                    prec: prec(rng),
                    backend: backend(rng),
                    verify: rng.chance(0.5),
                    weights,
                    input,
                },
            )
        }
        5 => wire::encode_request(req_id, &Request::Stats),
        6 => wire::encode_response(
            req_id,
            &Response::HelloOk {
                namespace: rng.next_u64(),
            },
        ),
        7 => wire::encode_response(
            req_id,
            &Response::MatmulOk {
                lhs_cached: rng.chance(0.5),
                rhs_cached: rng.chance(0.5),
                shards: rng.index(16) as u32 + 1,
                total_ns: rng.next_u64() >> 20,
                result: mat(rng),
            },
        ),
        8 => wire::encode_response(
            req_id,
            &Response::PrepareOk {
                weight_id: rng.next_u64(),
                resident: rng.chance(0.5),
            },
        ),
        9 => {
            let (h, w) = (rng.index(5) + 1, rng.index(5) + 1);
            let t = Tensor::random(rng, 1, h, w, 2, 3, true);
            wire::encode_response(
                req_id,
                &Response::ConvOk {
                    gemms: rng.index(9) as u32 + 1,
                    weights_cached: rng.chance(0.5),
                    output: t,
                },
            )
        }
        10 => wire::encode_response(
            req_id,
            &Response::StatsOk(WireStats {
                cache_hits: rng.next_u64() >> 32,
                cache_misses: rng.next_u64() >> 32,
                ..WireStats::default()
            }),
        ),
        _ => wire::encode_response(
            req_id,
            &wire::error_frame(&BismoError::Overloaded {
                retry_after_ms: rng.index(1000) as u64,
            }),
        ),
    }
}

/// Run one wire-mode case; `Err(detail)` only on a panic, an untyped
/// escape or a broken clean round trip.
fn wire_case(seed: u64) -> Result<(), String> {
    use crate::net::wire::decode_frame;
    let mut rng = Rng::new(seed);
    let clean = random_wire_frame(&mut rng).map_err(|e| format!("encode failed: {e}"))?;
    // A clean frame must decode (round-trip sanity before corruption).
    decode_frame(&clean).map_err(|e| format!("clean frame failed to decode: {e}"))?;
    let mut bytes = clean;
    mutate_bytes(&mut rng, &mut bytes);
    match decode_frame(&bytes) {
        // The corruption may cancel out or land in a don't-care field
        // (req_id, flag payloads) — a valid decode is fine.
        Ok(_) => Ok(()),
        Err(BismoError::Parse(_)) => Ok(()),
        Err(e) => Err(format!("unexpected error class from wire decode: {e}")),
    }
}

/// Wire mode: corrupted frames must decode typed or not at all.
pub fn fuzz_wire(iters: u64, seed: u64) -> FuzzOutcome {
    run_mode("wire", iters, seed, wire_case)
}

/// One differential-fuzz case, fully determined by its fields (all
/// randomness is re-derived from `data_seed`).
#[derive(Clone, Copy, Debug)]
struct DiffCase {
    m: usize,
    k: usize,
    n: usize,
    wbits: u32,
    abits: u32,
    lsigned: bool,
    rsigned: bool,
    /// 0 = Single, 1 = Grid(gr×gc), 2 = Instances(ni).
    shard_kind: u8,
    gr: usize,
    gc: usize,
    ni: usize,
    data_seed: u64,
}

impl DiffCase {
    fn random(rng: &mut Rng) -> DiffCase {
        DiffCase {
            m: rng.range(1, 8) as usize,
            k: rng.range(1, 96) as usize,
            n: rng.range(1, 8) as usize,
            wbits: rng.range(1, 3) as u32,
            abits: rng.range(1, 3) as u32,
            lsigned: rng.chance(0.5),
            rsigned: rng.chance(0.5),
            shard_kind: rng.index(3) as u8,
            gr: rng.range(1, 2) as usize,
            gc: rng.range(1, 2) as usize,
            ni: rng.range(1, 3) as usize,
            data_seed: rng.next_u64(),
        }
    }

    fn sharding(&self) -> Sharding {
        match self.shard_kind {
            0 => Sharding::Single,
            1 => Sharding::Grid {
                rows: self.gr,
                cols: self.gc,
            },
            _ => Sharding::Instances(self.ni),
        }
    }

    fn describe(&self) -> String {
        format!(
            "{}x{}x{} w{}{} a{}{} sharding={:?}",
            self.m,
            self.k,
            self.n,
            self.wbits,
            if self.lsigned { "s" } else { "u" },
            self.abits,
            if self.rsigned { "s" } else { "u" },
            self.sharding()
        )
    }

    /// Serve the case through both backends; `Err(detail)` on any
    /// disagreement with the integer-matmul oracle.
    fn check(&self, svc: &BismoService) -> Result<(), String> {
        let mut rng = Rng::new(self.data_seed);
        let a = IntMatrix::random(&mut rng, self.m, self.k, self.wbits, self.lsigned);
        let b = IntMatrix::random(&mut rng, self.k, self.n, self.abits, self.rsigned);
        let expect = a.matmul(&b);
        let prec = Precision {
            wbits: self.wbits,
            abits: self.abits,
            lsigned: self.lsigned,
            rsigned: self.rsigned,
        };
        for backend in [Backend::Engine, Backend::Sim] {
            let opts = RequestOptions {
                backend,
                sharding: self.sharding(),
                ..RequestOptions::default()
            };
            let resp = svc
                .submit(GemmRequest::with_opts(a.clone(), b.clone(), prec, opts))
                .wait()
                .map_err(|e| format!("{} backend errored: {e}", backend.name()))?;
            if resp.result != expect {
                return Err(format!(
                    "{} backend disagrees with the integer oracle",
                    backend.name()
                ));
            }
        }
        // Cross-tier differential: the engine pinned to the scalar strip
        // vs the engine pinned to the best tier this host supports, with
        // packing compared word-for-word. On scalar-only hosts this
        // degenerates to one extra oracle check.
        let best = DispatchTier::detect();
        let l_scalar =
            BitSerialMatrix::from_int_tier(&a, self.wbits, self.lsigned, DispatchTier::Scalar);
        let r_t = BitSerialMatrix::from_int_transposed(&b, self.abits, self.rsigned);
        let scalar = gemm_tiled_tier(&l_scalar, &r_t, DispatchTier::Scalar)
            .map_err(|e| format!("forced-scalar engine rejected a legal case: {e}"))?;
        if scalar != expect {
            return Err("engine at forced-scalar tier disagrees with the integer oracle".into());
        }
        if best != DispatchTier::Scalar {
            let l_best = BitSerialMatrix::from_int_tier(&a, self.wbits, self.lsigned, best);
            if l_best != l_scalar {
                return Err(format!("{best} packing differs from scalar packing"));
            }
            let fast = gemm_tiled_tier(&l_best, &r_t, best)
                .map_err(|e| format!("{best} engine rejected a legal case: {e}"))?;
            if fast != scalar {
                return Err(format!(
                    "engine at {best} tier disagrees with forced-scalar engine"
                ));
            }
        }
        Ok(())
    }

    /// Greedy minimization: repeatedly try shrinking transformations,
    /// keeping any that still fail, until a fixed point.
    fn minimize(mut self, svc: &BismoService) -> DiffCase {
        for _ in 0..32 {
            let mut shrunk = false;
            let mut candidates: Vec<DiffCase> = Vec::new();
            for f in [
                (|c: &mut DiffCase| c.m = (c.m / 2).max(1)) as fn(&mut DiffCase),
                |c| c.k = (c.k / 2).max(1),
                |c| c.n = (c.n / 2).max(1),
                |c| c.wbits = 1,
                |c| c.abits = 1,
                |c| c.lsigned = false,
                |c| c.rsigned = false,
                |c| c.shard_kind = 0,
            ] {
                let mut cand = self;
                f(&mut cand);
                candidates.push(cand);
            }
            for cand in candidates {
                let differs = cand.m != self.m
                    || cand.k != self.k
                    || cand.n != self.n
                    || cand.wbits != self.wbits
                    || cand.abits != self.abits
                    || cand.lsigned != self.lsigned
                    || cand.rsigned != self.rsigned
                    || cand.shard_kind != self.shard_kind;
                if differs
                    && catch_unwind(AssertUnwindSafe(|| cand.check(svc).is_err())).unwrap_or(true)
                {
                    self = cand;
                    shrunk = true;
                }
            }
            if !shrunk {
                break;
            }
        }
        self
    }
}

/// Differential mode: engine vs sim vs integer oracle, minimized repros.
pub fn fuzz_differential(iters: u64, seed: u64) -> FuzzOutcome {
    let svc = match BismoService::new(ServiceConfig {
        workers: 2,
        max_batch: 8,
        cache_bytes: 1 << 20,
        overlay: BismoConfig::small(),
    }) {
        Ok(s) => s,
        Err(e) => {
            return FuzzOutcome {
                mode: "differential",
                iters,
                failures: vec![FuzzFailure {
                    mode: "differential",
                    index: 0,
                    seed,
                    detail: format!("service construction failed: {e}"),
                }],
            }
        }
    };
    let mut failures = Vec::new();
    for i in 0..iters {
        let cs = case_seed(seed, i);
        let case = DiffCase::random(&mut Rng::new(cs));
        let outcome = catch_unwind(AssertUnwindSafe(|| case.check(&svc)));
        let detail = match outcome {
            Ok(Ok(())) => continue,
            Ok(Err(d)) => d,
            Err(e) => panic_payload(e),
        };
        let min = case.minimize(&svc);
        failures.push(FuzzFailure {
            mode: "differential",
            index: i,
            seed: cs,
            detail: format!("{detail}; minimized to [{}]", min.describe()),
        });
    }
    svc.shutdown();
    FuzzOutcome {
        mode: "differential",
        iters,
        failures,
    }
}

/// Shared driver: run `case` under `catch_unwind` for each index.
fn run_mode(
    mode: &'static str,
    iters: u64,
    seed: u64,
    case: fn(u64) -> Result<(), String>,
) -> FuzzOutcome {
    let mut failures = Vec::new();
    for i in 0..iters {
        let cs = case_seed(seed, i);
        let detail = match catch_unwind(AssertUnwindSafe(|| case(cs))) {
            Ok(Ok(())) => continue,
            Ok(Err(d)) => d,
            Err(e) => panic_payload(e),
        };
        failures.push(FuzzFailure {
            mode,
            index: i,
            seed: cs,
            detail,
        });
    }
    FuzzOutcome {
        mode,
        iters,
        failures,
    }
}

/// Schema tag of the golden snapshot report in `ci/sim_snapshots.json`.
pub const GOLDEN_SCHEMA: &str = "bismo-sim-golden/v1";

/// Build the deterministic golden snapshot report the CI ratchet
/// compares against `ci/sim_snapshots.json` (regenerate with
/// `bismo snapshot --regen`).
///
/// The scenario is fixed: a seeded 6×96×5 signed 3-bit × unsigned 2-bit
/// job compiled by the real scheduler on the `small()` overlay, stepped
/// to a ladder of suspend points. At each cut the full simulator
/// snapshot is serialized and digested; the final entry records the
/// completed run's stats and a digest of the result DRAM. Any
/// externally visible timing or data change moves at least one digest.
pub fn golden_snapshot_report() -> Result<String, BismoError> {
    let cfg = BismoConfig::small();
    let mut rng = Rng::new(0xB150);
    let a = IntMatrix::random(&mut rng, 6, 96, 3, true);
    let b = IntMatrix::random(&mut rng, 96, 5, 2, false);
    let la = BitSerialMatrix::from_int(&a, 3, true);
    let rb = BitSerialMatrix::from_int_transposed(&b, 2, false);

    let lhs = OperandLayout::new(0, 6, 96, 3, cfg.dk);
    let rhs = OperandLayout::new(round_up(lhs.total_bytes(), 8), 5, 96, 2, cfg.dk);
    let res = ResultLayout::new(round_up(rhs.base + rhs.total_bytes(), 8), 6, 5);
    let mut dram = DramImage::new((res.base + res.total_bytes()) as usize);
    lhs.store(&mut dram, &la);
    rhs.store(&mut dram, &rb);
    let job = MatmulJob {
        m: 6,
        k: 96,
        n: 5,
        wbits: 3,
        abits: 2,
        lsigned: true,
        rsigned: false,
        lhs,
        rhs,
        res,
    };
    let prog = scheduler::compile(&job, &cfg, Overlap::Full)?;
    let total = prog.stats().total as u64;

    // Uninterrupted reference run.
    let mut reference = Simulation::new(cfg, &PYNQ_Z1, dram.clone())?;
    let ref_stats = reference.run(&prog)?;
    if res.load(&reference.dram) != a.matmul(&b) {
        return Err(BismoError::VerifyFailed(
            "golden scenario result != integer oracle".into(),
        ));
    }

    let hex = |v: u64| Json::Str(format!("{v:#x}"));
    let mut cuts = Vec::new();
    for cut in [1, total / 4, total / 2, total - 1] {
        let mut sim = Simulation::new(cfg, &PYNQ_Z1, dram.clone())?;
        sim.begin(&prog)?;
        match sim.step(&prog, cut)? {
            StepOutcome::Completed(_) => {
                return Err(BismoError::VerifyFailed(format!(
                    "golden scenario completed within {cut} of {total} instructions"
                )))
            }
            StepOutcome::Suspended => {}
        }
        let snap = sim.snapshot();
        let text = snap.to_json();
        // Internal consistency: the captured state must restore and
        // converge to the reference run before we publish its digest.
        let mut resumed = Simulation::restore(&SimSnapshot::from_json(&text)?, &PYNQ_Z1)?;
        match resumed.step(&prog, u64::MAX)? {
            StepOutcome::Completed(s) if s == ref_stats => {}
            _ => {
                return Err(BismoError::VerifyFailed(format!(
                    "restore from cut {cut} diverged from the uninterrupted run"
                )))
            }
        }
        cuts.push(Json::Obj(
            [
                ("instrs".to_string(), hex(cut)),
                (
                    "snapshot_digest".to_string(),
                    hex(digest_bytes(text.as_bytes())),
                ),
            ]
            .into_iter()
            .collect(),
        ));
    }

    let final_obj = Json::Obj(
        [
            ("cycles".to_string(), hex(ref_stats.cycles)),
            ("commits".to_string(), hex(ref_stats.commits)),
            ("bytes_fetched".to_string(), hex(ref_stats.bytes_fetched)),
            ("bytes_written".to_string(), hex(ref_stats.bytes_written)),
            ("binary_ops".to_string(), hex(ref_stats.binary_ops)),
            (
                "dram_digest".to_string(),
                hex(digest_bytes(reference.dram.as_bytes())),
            ),
        ]
        .into_iter()
        .collect(),
    );
    let report = Json::Obj(
        [
            ("schema".to_string(), Json::str(GOLDEN_SCHEMA)),
            ("instructions".to_string(), hex(total)),
            ("cuts".to_string(), Json::Arr(cuts)),
            ("final".to_string(), final_obj),
        ]
        .into_iter()
        .collect(),
    );
    Ok(report.pretty(2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legal_generator_emits_valid_programs() {
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let cfg = random_fuzz_config(&mut rng);
            let p = generate_legal_program(&mut rng, &cfg, FUZZ_DRAM_BYTES);
            p.validate().expect("generated program must validate");
        }
    }

    #[test]
    fn legal_mode_smoke() {
        let out = fuzz_legal(8, 0xF00D);
        assert!(out.ok(), "failures: {:?}", out.failures);
    }

    #[test]
    fn mutation_mode_smoke() {
        let out = fuzz_mutation(16, 0xF00D);
        assert!(out.ok(), "failures: {:?}", out.failures);
    }

    #[test]
    fn differential_mode_smoke() {
        let out = fuzz_differential(3, 0xF00D);
        assert!(out.ok(), "failures: {:?}", out.failures);
    }

    #[test]
    fn wire_mode_smoke() {
        let out = fuzz_wire(64, 0xF00D);
        assert!(out.ok(), "failures: {:?}", out.failures);
    }

    #[test]
    fn wire_cases_are_deterministic() {
        // Same case seed → same verdict, twice over: the replay
        // promise the failure report makes.
        for i in 0..8 {
            let s = case_seed(0x31BE, i);
            assert_eq!(wire_case(s), wire_case(s), "case {i}");
        }
    }

    #[test]
    fn case_seeds_are_distinct_and_stable() {
        assert_eq!(case_seed(42, 0), case_seed(42, 0));
        assert_ne!(case_seed(42, 0), case_seed(42, 1));
        assert_ne!(case_seed(42, 0), case_seed(43, 0));
    }

    #[test]
    fn golden_report_is_deterministic_and_tagged() {
        let a = golden_snapshot_report().unwrap();
        let b = golden_snapshot_report().unwrap();
        assert_eq!(a, b);
        let v = Json::parse(&a).unwrap();
        assert_eq!(v.get("schema").and_then(Json::as_str), Some(GOLDEN_SCHEMA));
    }

    #[test]
    fn failure_json_lists_seeds() {
        let out = FuzzOutcome {
            mode: "legal",
            iters: 1,
            failures: vec![FuzzFailure {
                mode: "legal",
                index: 3,
                seed: 0xabc,
                detail: "boom".into(),
            }],
        };
        let text = failures_to_json(&[out]);
        assert!(text.contains("0xabc") && text.contains("boom"));
    }
}
