//! Persistent worker pool with atomic work-claiming ("work-stealing"
//! over a shared index counter).
//!
//! The pool exists so the hot software paths — the tiled GEMM engine,
//! [`crate::baseline::gemm_bitserial_parallel`] and
//! [`crate::coordinator::BismoBatchRunner`] — stop paying a
//! `thread::spawn` + stack setup per call. Workers are spawned once
//! (lazily for the process-wide [`WorkerPool::global`] pool) and park
//! on a condvar between jobs.
//!
//! A job is a borrowed `Fn(usize)` closure plus a task count. Every
//! participant — the submitting thread included — claims task indices
//! from a shared atomic counter until the range is exhausted, so load
//! balances dynamically across workers regardless of per-task cost
//! (the work-stealing property that matters for row tiles of uneven
//! density).
//!
//! ## Safety
//!
//! The closure is lifetime-erased into a raw pointer so parked workers
//! can reach it. The invariant that makes this sound: a worker only
//! dereferences the pointer for a claimed index `i < tasks`, every
//! claimed index decrements `pending` exactly once *after* the call
//! returns, and [`WorkerPool::run_limited`] does not return before
//! `pending == 0`. Task closures run under `catch_unwind`, so a
//! panicking task cannot skip its `pending` decrement or unwind the
//! submitting frame early — the first panic payload is re-raised on
//! the submitting thread once the job has fully retired, preserving
//! scoped-thread panic semantics. Therefore no dereference can happen
//! after the submitting frame (which owns the closure and its
//! borrows) is gone.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// One in-flight job. `func` points into the submitting thread's stack;
/// see the module-level safety note.
struct Job {
    func: *const (dyn Fn(usize) + Sync),
    /// Next unclaimed task index.
    next: AtomicUsize,
    /// Tasks not yet completed.
    pending: AtomicUsize,
    tasks: usize,
    /// Helper workers that joined so far (the caller is not counted).
    helpers: AtomicUsize,
    /// Maximum helper workers allowed (`limit - 1`; the caller always
    /// takes one lane).
    max_helpers: usize,
    /// First panic payload from a task closure. Tasks run under
    /// `catch_unwind` so a panicking task can neither strand the
    /// submitter (un-decremented `pending`) nor let the submitting
    /// frame unwind while other participants still hold `func`; the
    /// payload is re-raised on the submitting thread once the job has
    /// fully retired.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

// The raw closure pointer is only dereferenced under the protocol above;
// all other fields are atomics / plain data.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct State {
    job: Option<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Signalled when a job is posted or retired (workers wait here).
    work: Condvar,
    /// Signalled when a job's last task completes (the caller waits here).
    done: Condvar,
}

/// A fixed set of persistent worker threads draining borrowed jobs.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    lanes: usize,
}

impl WorkerPool {
    /// A pool with `lanes`-way parallelism: `lanes - 1` helper threads
    /// plus the submitting thread, which always participates.
    pub fn new(lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                job: None,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..lanes - 1)
            .map(|_| {
                let sh = shared.clone();
                std::thread::spawn(move || Self::worker_loop(&sh))
            })
            .collect();
        WorkerPool {
            shared,
            handles,
            lanes,
        }
    }

    /// The process-wide pool, sized to the machine, created on first
    /// use. This is what the GEMM engine, the baseline parallel path
    /// and the batch runner share.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            WorkerPool::new(
                std::thread::available_parallelism()
                    .map(|p| p.get())
                    .unwrap_or(4),
            )
        })
    }

    /// Parallelism of this pool (helper threads + the caller).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Run `f(0..tasks)` across the pool; returns when every task has
    /// completed. Tasks must be independent (they run concurrently, in
    /// no particular order). This is the pool's job-submission entry
    /// point; [`WorkerPool::run_limited`] additionally caps concurrency.
    ///
    /// ```
    /// use bismo::kernel::WorkerPool;
    /// use std::sync::atomic::{AtomicU64, Ordering};
    ///
    /// let pool = WorkerPool::new(4);
    /// let sum = AtomicU64::new(0);
    /// pool.run(100, &|i| {
    ///     sum.fetch_add(i as u64, Ordering::SeqCst);
    /// });
    /// assert_eq!(sum.load(Ordering::SeqCst), 4950);
    /// ```
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        self.run_limited(tasks, usize::MAX, f);
    }

    /// Like [`WorkerPool::run`] but with at most `limit` concurrent
    /// executors (callers that model a fixed number of overlay
    /// instances use this). The pool's persistent workers serve one
    /// submitter at a time: if it is already busy — another thread's
    /// job, or the nested case where a pool task itself submits — the
    /// job falls back to one-off scoped threads, so a second
    /// concurrent submitter keeps its parallelism and the pool stays
    /// deadlock-free by construction.
    pub fn run_limited(&self, tasks: usize, limit: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        let limit = limit.max(1);
        if tasks == 1 || limit == 1 || self.lanes == 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        let job = Arc::new(Job {
            func: unsafe {
                std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(f)
            },
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(tasks),
            tasks,
            helpers: AtomicUsize::new(0),
            max_helpers: limit - 1,
            panic: Mutex::new(None),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.job.is_some() {
                // Busy (or nested submission from a pool task): rather
                // than queueing — which could deadlock the nested case
                // — run on freshly scoped threads so this submitter
                // still gets its parallelism.
                drop(st);
                Self::run_scoped(tasks, limit.min(self.lanes), f);
                return;
            }
            st.job = Some(job.clone());
            self.shared.work.notify_all();
        }
        // The caller is a full participant.
        Self::execute(&self.shared, &job);
        // Wait for helper stragglers still finishing claimed tasks.
        let mut st = self.shared.state.lock().unwrap();
        while job.pending.load(Ordering::SeqCst) != 0 {
            st = self.shared.done.wait(st).unwrap();
        }
        if st
            .job
            .as_ref()
            .is_some_and(|active| Arc::ptr_eq(active, &job))
        {
            st.job = None;
            self.shared.work.notify_all();
        }
        drop(st);
        // Every task has completed and the job is retired, so no
        // participant can reach `func` anymore: re-raising a task panic
        // here is safe and gives the caller scoped-thread semantics.
        if let Some(payload) = job.panic.lock().unwrap().take() {
            std::panic::resume_unwind(payload);
        }
    }

    /// Fallback when the persistent workers are taken: the same
    /// work-claiming drain over one-off scoped threads (the caller is
    /// one of the `workers` lanes). Panics propagate on scope join,
    /// matching the pooled path's semantics.
    fn run_scoped(tasks: usize, workers: usize, f: &(dyn Fn(usize) + Sync)) {
        let next = AtomicUsize::new(0);
        let drain = || loop {
            let i = next.fetch_add(1, Ordering::SeqCst);
            if i >= tasks {
                break;
            }
            f(i);
        };
        std::thread::scope(|scope| {
            for _ in 1..workers.max(1) {
                scope.spawn(drain);
            }
            drain();
        });
    }

    /// Claim-and-run loop shared by the caller and the helpers.
    fn execute(shared: &Shared, job: &Arc<Job>) {
        loop {
            let i = job.next.fetch_add(1, Ordering::SeqCst);
            if i >= job.tasks {
                return;
            }
            // SAFETY: a successful claim (`i < tasks`) proves this task
            // has not completed, so `pending > 0` and the submitting
            // frame that owns the closure is still blocked in
            // `run_limited`. A retired job always has `next >= tasks`,
            // so a stale worker can never reach this dereference.
            let f = unsafe { &*job.func };
            // Panics must not escape: an unwinding participant would
            // skip the `pending` decrement (stranding the submitter)
            // or — on the submitting thread itself — free the closure
            // while helpers still hold `func`. Capture the first
            // payload; `run_limited` re-raises it after retirement.
            if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))) {
                let mut slot = job.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
            if job.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                // Last task: retire the job and wake the caller plus any
                // workers parked on it.
                let mut st = shared.state.lock().unwrap();
                if st
                    .job
                    .as_ref()
                    .is_some_and(|active| Arc::ptr_eq(active, job))
                {
                    st.job = None;
                }
                shared.done.notify_all();
                shared.work.notify_all();
            }
        }
    }

    fn worker_loop(shared: &Shared) {
        loop {
            let job = {
                let mut st = shared.state.lock().unwrap();
                loop {
                    if st.shutdown {
                        return;
                    }
                    if let Some(j) = st.job.clone() {
                        break j;
                    }
                    st = shared.work.wait(st).unwrap();
                }
            };
            if job.helpers.fetch_add(1, Ordering::SeqCst) < job.max_helpers {
                Self::execute(shared, &job);
            }
            // Park until this job is retired (or shutdown) so an
            // exhausted or over-subscribed worker does not spin on it.
            let mut st = shared.state.lock().unwrap();
            while !st.shutdown
                && st
                    .job
                    .as_ref()
                    .is_some_and(|active| Arc::ptr_eq(active, &job))
            {
                st = shared.work.wait(st).unwrap();
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = WorkerPool::new(4);
        for tasks in [1usize, 2, 7, 64, 257] {
            let hits: Vec<AtomicU64> = (0..tasks).map(|_| AtomicU64::new(0)).collect();
            pool.run(tasks, &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "task {i} of {tasks}");
            }
        }
    }

    #[test]
    fn pool_is_reusable_across_many_jobs() {
        let pool = WorkerPool::new(3);
        let total = AtomicU64::new(0);
        for round in 0..200u64 {
            pool.run(5, &|i| {
                total.fetch_add(round + i as u64, Ordering::SeqCst);
            });
        }
        // Σ_round (5·round + 0+1+2+3+4)
        let expect: u64 = (0..200u64).map(|r| 5 * r + 10).sum();
        assert_eq!(total.load(Ordering::SeqCst), expect);
    }

    #[test]
    fn limit_bounds_concurrency() {
        let pool = WorkerPool::new(8);
        let live = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        pool.run_limited(32, 2, &|_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(1));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "peak {}", peak.load(Ordering::SeqCst));
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        let sum = AtomicU64::new(0);
        pool.run(10, &|i| {
            sum.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn nested_submission_falls_back_inline() {
        let pool = WorkerPool::new(4);
        let count = AtomicU64::new(0);
        pool.run(4, &|_| {
            // A pool task submitting to the same pool must not deadlock.
            pool.run(3, &|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(count.load(Ordering::SeqCst), 12);
    }

    #[test]
    fn task_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(4);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(8, &|i| {
                if i == 3 {
                    panic!("boom in task");
                }
            });
        }));
        let payload = caught.expect_err("panic must propagate to the submitter");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or_default();
        assert_eq!(msg, "boom in task");
        // The pool must stay fully usable afterwards.
        let sum = AtomicU64::new(0);
        pool.run(10, &|i| {
            sum.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }

    #[test]
    fn global_pool_exists_and_works() {
        let pool = WorkerPool::global();
        assert!(pool.lanes() >= 1);
        let sum = AtomicU64::new(0);
        pool.run(100, &|i| {
            sum.fetch_add(i as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), 4950);
    }
}
