//! The software kernel engine: a tiled, plane-fused bit-serial GEMM
//! plus the persistent worker pool the parallel paths share.
//!
//! [`crate::baseline::gemm_bitserial`] remains the bit-exact reference
//! oracle; this module is the *fast* software implementation of the
//! same contract:
//!
//! * [`gemm_tiled`] / [`gemm_tiled_with`] — cache-blocked,
//!   zero-plane-skipping GEMM over packed plane rows (see [`engine`]),
//!   tiled by the shared [`crate::partition::TilePlan`]. Application
//!   code should prefer the [`crate::api::Session`] facade, which runs
//!   this engine behind its `Engine` backend.
//! * [`gemm_tiled_block`] — one output block (row range × column range,
//!   optional LHS plane group): the shard granularity of
//!   [`crate::partition::ShardPlan`], used by the serving layer's
//!   multi-instance dispatch.
//! * [`WorkerPool`] — persistent work-claiming thread pool reused by
//!   the engine, [`crate::baseline::gemm_bitserial_parallel`],
//!   [`crate::coordinator::BismoBatchRunner`] and the micro-batches of
//!   [`crate::coordinator::BismoService`] (see [`pool`]).
//! * [`popcount_and`] — the AND+popcount word-strip primitive, also
//!   used by the simulator's execute stage. Since the SIMD datapath
//!   landed it dispatches through the process-wide
//!   [`crate::simd::DispatchTier`]; the explicit-tier entry points
//!   ([`gemm_tiled_tier`], [`gemm_tiled_block_tier`]) exist so the
//!   forced-dispatch test matrix and the cross-tier fuzz mode can pin
//!   a tier per call.

pub mod engine;
pub mod pool;

pub use engine::{
    gemm_tiled, gemm_tiled_block, gemm_tiled_block_tier, gemm_tiled_tier, gemm_tiled_with,
    KernelConfig,
};
pub use pool::WorkerPool;

use crate::simd::{self, DispatchTier};

/// Binary dot product of two equal-length packed words slices:
/// `Σ popcount(a[i] & b[i])`, computed by the process-wide
/// [`DispatchTier`]'s strip (see [`crate::simd`] — AVX-512 / AVX2
/// Harley–Seal / NEON, with the 4-word unrolled scalar strip as the
/// portable fallback and bit-exactness reference).
#[inline]
pub fn popcount_and(a: &[u64], b: &[u64]) -> u64 {
    simd::popcount_and_tier(DispatchTier::active(), a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{property_sweep, Rng};

    #[test]
    fn popcount_and_matches_naive() {
        property_sweep(0xA17D0, 25, |rng, _| {
            let len = rng.index(40); // covers 0, <4 and non-multiple-of-4
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let naive: u64 = a
                .iter()
                .zip(&b)
                .map(|(&x, &y)| (x & y).count_ones() as u64)
                .sum();
            assert_eq!(popcount_and(&a, &b), naive, "len={len}");
        });
    }

    #[test]
    fn popcount_and_extremes() {
        assert_eq!(popcount_and(&[], &[]), 0);
        assert_eq!(popcount_and(&[u64::MAX; 7], &[u64::MAX; 7]), 7 * 64);
        assert_eq!(popcount_and(&[u64::MAX; 5], &[0; 5]), 0);
    }
}
