//! The tiled, plane-fused bit-serial GEMM engine.
//!
//! Performs the same computation as [`crate::baseline::gemm_bitserial`]
//! (Algorithm 1 vectorized over `u64` words) but restructured for the
//! memory hierarchy, following the scheduling insights of the BISMO
//! journal follow-up (Umuroglu et al., 2019):
//!
//! * **Zero-plane skip** — all-zero bit-planes are dropped during
//!   packing via the shared [`BitSerialMatrix::nonzero_planes`] filter,
//!   so sparse operands cost proportionally less (the naive kernel pays
//!   full price).
//! * **Plane fusion** — the `(i, j)` plane-pair loops run over a flat
//!   precomputed `±2^{i+j}` weight table; no per-element closure
//!   dispatch, no per-pair weight recomputation.
//! * **Contiguous per-row plane packing** — operands are repacked from
//!   plane-major to row-major-plane-minor layout, so all planes of one
//!   row sit in adjacent cache lines and a whole `(row, col)` output
//!   needs exactly `(w·a·⌈k/64⌉)` sequential word reads.
//! * **Output tiling** — the output is walked in `tile_m × tile_n`
//!   blocks described by a [`crate::partition::TilePlan`] (the crate's
//!   single owner of tiling arithmetic); the RHS tile (all planes of
//!   `tile_n` packed rows) stays L1/L2-resident across the `tile_m`
//!   LHS rows instead of being restreamed per output row.
//! * **k-chunking** — when [`KernelConfig::tile_k`] is finite, packed
//!   rows are streamed in `⌈tile_k/64⌉`-word strips and partial products
//!   accumulate into the output tile, so very deep operands (`k` beyond
//!   L1/L2) reuse each RHS strip across the whole tile before moving on.
//!   The default streams whole rows — today's behavior and the right
//!   choice for moderate `k`. Integer accumulation makes the chunked
//!   walk bit-exact regardless of split.
//! * **SIMD strips** — the AND+popcount inner loop runs the strip of
//!   the process-wide [`crate::simd::DispatchTier`] (AVX-512 / AVX2
//!   Harley–Seal / NEON / scalar), resolved once per block so the hot
//!   loop never re-reads the dispatch state. The `*_tier` entry points
//!   pin an explicit tier — the hook the forced-dispatch test matrix
//!   and the cross-tier fuzz mode drive.
//!
//! [`gemm_tiled_block`] computes any output block (a row range × column
//! range, optionally restricted to a group of LHS bit-planes) without
//! touching the rest — the shard granularity of
//! [`crate::partition::ShardPlan`], packed zero-copy from
//! [`BitSerialMatrix::plane_rows`] block views.
//!
//! Row tiles are independent, which is exactly the granularity the
//! persistent [`WorkerPool`] distributes.
//!
//! Tile geometry is user-reachable (per-request via
//! [`crate::coordinator::RequestOptions`], per-host via tuned profiles
//! from [`crate::costmodel::tune`]), so malformed configurations are
//! typed [`BismoError::InvalidConfig`] returns, not panics.

use super::pool::WorkerPool;
use crate::api::BismoError;
use crate::bitmatrix::{BitSerialMatrix, IntMatrix};
use crate::partition::{BlockSplit, TilePlan};
use crate::simd::{popcount_and_tier, DispatchTier};
use std::ops::Range;
use std::sync::Mutex;

/// Tile geometry of the engine. Defaults hold one RHS tile
/// (`tile_n · abits` packed rows) plus one LHS row strip comfortably in
/// L1 for 8-bit operands at `k ≤ 16384`, streaming `k` unchunked —
/// the analytical fallback when no tuned profile overrides it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelConfig {
    /// Output rows per tile (the parallel work unit).
    pub tile_m: usize,
    /// Output columns per tile.
    pub tile_n: usize,
    /// Inner-dimension elements per chunk; `usize::MAX` streams whole
    /// packed rows (no chunking). Rounded up to a whole number of
    /// 64-bit words internally.
    pub tile_k: usize,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            tile_m: 8,
            tile_n: 8,
            tile_k: usize::MAX,
        }
    }
}

impl KernelConfig {
    /// Tile geometry must be at least 1 on every axis. Tile sizes are
    /// user-reachable (request options, tuned profiles), so violations
    /// are typed errors rather than panics.
    pub fn validate(&self) -> Result<(), BismoError> {
        if self.tile_m < 1 || self.tile_n < 1 || self.tile_k < 1 {
            return Err(BismoError::InvalidConfig(format!(
                "tile sizes must be >= 1 (got tile_m={}, tile_n={}, tile_k={})",
                self.tile_m, self.tile_n, self.tile_k
            )));
        }
        Ok(())
    }
}

/// One operand repacked for the tiled kernel: zero planes dropped,
/// layout `[row][plane][word]` (row-major, plane-minor). Packs any row
/// block and plane subset of the source, reading each plane's row range
/// through the zero-copy [`BitSerialMatrix::plane_rows`] view.
struct PackedOperand {
    /// Words per packed row (`⌈k/64⌉`).
    words: usize,
    /// Signed weight `±2^i` of each kept plane.
    weights: Vec<i64>,
    data: Vec<u64>,
}

impl PackedOperand {
    fn pack(m: &BitSerialMatrix, rows: Range<usize>, planes: Range<u32>) -> PackedOperand {
        let kept: Vec<u32> = m
            .nonzero_planes()
            .into_iter()
            .filter(|p| planes.contains(p))
            .collect();
        let weights: Vec<i64> = kept.iter().map(|&i| m.plane_weight(i)).collect();
        let words = m.words_per_row;
        let np = kept.len();
        let nrows = rows.len();
        let mut data = vec![0u64; nrows * np * words];
        for (pi, &plane) in kept.iter().enumerate() {
            let src = m.plane_rows(plane, rows.clone());
            for r in 0..nrows {
                let dst = (r * np + pi) * words;
                data[dst..dst + words].copy_from_slice(&src[r * words..(r + 1) * words]);
            }
        }
        PackedOperand {
            words,
            weights,
            data,
        }
    }

    fn planes(&self) -> usize {
        self.weights.len()
    }
}

/// Tiled bit-serial GEMM, single-threaded: `P = L · Rᵀ` with `L`
/// (`m×k`) and `r_t` the transposed RHS (`n×k`), both bit-plane
/// decomposed. Bit-exact against [`crate::baseline::gemm_bitserial`].
///
/// Errs with [`BismoError::ShapeMismatch`] when the operands disagree
/// on `k`.
///
/// ```
/// use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
/// use bismo::kernel::gemm_tiled;
///
/// // The paper's Fig. 1 operands at 2-bit unsigned precision.
/// let a = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
/// let b = IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]);
/// let la = BitSerialMatrix::from_int(&a, 2, false);
/// // The RHS is packed transposed (rows along k), in one fused pass.
/// let rb = BitSerialMatrix::from_int_transposed(&b, 2, false);
/// assert_eq!(gemm_tiled(&la, &rb).unwrap(), a.matmul(&b));
/// ```
pub fn gemm_tiled(l: &BitSerialMatrix, r_t: &BitSerialMatrix) -> Result<IntMatrix, BismoError> {
    gemm_tiled_with(l, r_t, &KernelConfig::default(), None)
}

/// [`gemm_tiled`] pinned to an explicit [`DispatchTier`] instead of
/// the process-wide one — the entry point of the forced-dispatch test
/// matrix and the cross-tier fuzz mode. The tier must be supported on
/// this host (see [`DispatchTier::supported`]).
pub fn gemm_tiled_tier(
    l: &BitSerialMatrix,
    r_t: &BitSerialMatrix,
    tier: DispatchTier,
) -> Result<IntMatrix, BismoError> {
    gemm_tiled_block_tier(
        l,
        r_t,
        0..l.rows,
        0..r_t.rows,
        None,
        &KernelConfig::default(),
        None,
        tier,
    )
}

/// Full-control entry point: explicit tile geometry and an optional
/// `(pool, lane limit)` to parallelize over row tiles.
pub fn gemm_tiled_with(
    l: &BitSerialMatrix,
    r_t: &BitSerialMatrix,
    cfg: &KernelConfig,
    pool: Option<(&WorkerPool, usize)>,
) -> Result<IntMatrix, BismoError> {
    gemm_tiled_block(l, r_t, 0..l.rows, 0..r_t.rows, None, cfg, pool)
}

/// Compute one output *block* of `P = L · Rᵀ`: rows `rows` × columns
/// `cols`, restricted to the LHS bit-planes in `lhs_planes` (`None` =
/// all planes). Returns the `rows.len() × cols.len()` partial product —
/// the shard granularity of [`crate::partition::ShardPlan`], whose
/// [`assemble`](crate::partition::ShardPlan::assemble) merges blocks
/// (and sums plane groups) back into the full product bit-exactly.
///
/// Each call repacks its own row/column blocks, so an `r×c` shard grid
/// packs every LHS row-block `c` times (and every RHS column-block `r`
/// times). That duplication is a deliberate trade for shard
/// independence: the repack is a straight memcpy of `rows·planes·⌈k/64⌉`
/// words, a factor of roughly `cols·planes` cheaper than the block's
/// AND+popcount work, so it stays ≲2% of shard runtime at the grid
/// sizes the service dispatches (≤8 per axis).
pub fn gemm_tiled_block(
    l: &BitSerialMatrix,
    r_t: &BitSerialMatrix,
    rows: Range<usize>,
    cols: Range<usize>,
    lhs_planes: Option<Range<u32>>,
    cfg: &KernelConfig,
    pool: Option<(&WorkerPool, usize)>,
) -> Result<IntMatrix, BismoError> {
    // The dispatch tier is resolved once per block, not per strip: the
    // inner loop sees a plain function parameter.
    gemm_tiled_block_tier(l, r_t, rows, cols, lhs_planes, cfg, pool, DispatchTier::active())
}

/// [`gemm_tiled_block`] pinned to an explicit [`DispatchTier`] — see
/// [`gemm_tiled_tier`]. The extra parameter is the whole point of this
/// variant, hence the argument count.
#[allow(clippy::too_many_arguments)]
pub fn gemm_tiled_block_tier(
    l: &BitSerialMatrix,
    r_t: &BitSerialMatrix,
    rows: Range<usize>,
    cols: Range<usize>,
    lhs_planes: Option<Range<u32>>,
    cfg: &KernelConfig,
    pool: Option<(&WorkerPool, usize)>,
    tier: DispatchTier,
) -> Result<IntMatrix, BismoError> {
    if l.cols != r_t.cols {
        return Err(BismoError::ShapeMismatch(format!(
            "k mismatch: lhs {}×{}, rhs(T) {}×{}",
            l.rows, l.cols, r_t.rows, r_t.cols
        )));
    }
    if rows.end > l.rows || cols.end > r_t.rows {
        return Err(BismoError::InvalidConfig(format!(
            "output block {rows:?}×{cols:?} out of range for {}×{}",
            l.rows, r_t.rows
        )));
    }
    cfg.validate()?;
    let bm = rows.len();
    let bn = cols.len();
    if bm == 0 || bn == 0 {
        return Ok(IntMatrix::zeros(bm, bn));
    }
    let lp = PackedOperand::pack(l, rows, lhs_planes.unwrap_or(0..l.bits));
    let rp = PackedOperand::pack(r_t, cols, 0..r_t.bits);
    if lp.planes() == 0 || rp.planes() == 0 {
        // Every scheduled plane zero: this block of the product is zero.
        return Ok(IntMatrix::zeros(bm, bn));
    }
    // Fused plane-pair weight table: pairw[i·rnp + j] = ±2^{i+j}.
    let mut pairw = Vec::with_capacity(lp.planes() * rp.planes());
    for &wl in &lp.weights {
        for &wr in &rp.weights {
            pairw.push(wl * wr);
        }
    }

    // The single source of tiling arithmetic: block rows in `tile_m`
    // strips (the parallel work unit), block columns in `tile_n` strips
    // (the cache-residency unit), packed words in `⌈tile_k/64⌉`-word
    // chunks (whole rows when tile_k is MAX). Oversized tile requests
    // clamp to the block extent, so any tile >= the axis behaves
    // identically to "one tile".
    let tm = cfg.tile_m.min(bm);
    let tn = cfg.tile_n.min(bn);
    let words = lp.words;
    let chunk_words = if cfg.tile_k == usize::MAX {
        words.max(1)
    } else {
        cfg.tile_k.div_ceil(64).clamp(1, words.max(1))
    };
    let tiles = TilePlan::new(
        bm,
        bn,
        l.cols,
        tm,
        tn,
        (chunk_words * 64).min(l.cols.max(1)),
    );
    let kplan = BlockSplit::new(words, chunk_words);
    let mut data = vec![0i64; bm * bn];
    match pool {
        None => {
            for (t, chunk) in data.chunks_mut(tm * bn).enumerate() {
                row_tile_kernel(
                    &lp,
                    &rp,
                    &pairw,
                    tiles.rows.span(t),
                    bn,
                    &tiles.cols,
                    &kplan,
                    chunk,
                    tier,
                );
            }
        }
        Some((pool, threads)) => {
            let slots: Vec<Mutex<&mut [i64]>> =
                data.chunks_mut(tm * bn).map(Mutex::new).collect();
            pool.run_limited(tiles.row_tiles(), threads.max(1), &|t| {
                let mut guard = slots[t].lock().unwrap();
                let chunk: &mut [i64] = &mut guard;
                row_tile_kernel(
                    &lp,
                    &rp,
                    &pairw,
                    tiles.rows.span(t),
                    bn,
                    &tiles.cols,
                    &kplan,
                    chunk,
                    tier,
                );
            });
        }
    }
    Ok(IntMatrix::from_slice(bm, bn, &data))
}

/// Accumulate output rows `rows` into `out` (row-major,
/// `rows.len() × n`, relative to `rows.start`, pre-zeroed by the
/// caller), walking the column tiles of `cols` so the packed RHS tile
/// stays cache-resident across the rows of this tile, and the packed
/// words in the strips of `kplan` so deep operands reuse each strip
/// across the whole tile. The dispatch tier arrives pre-resolved as a
/// plain parameter (hence the argument count).
#[allow(clippy::too_many_arguments)]
fn row_tile_kernel(
    lp: &PackedOperand,
    rp: &PackedOperand,
    pairw: &[i64],
    rows: Range<usize>,
    n: usize,
    cols: &BlockSplit,
    kplan: &BlockSplit,
    out: &mut [i64],
    tier: DispatchTier,
) {
    let words = lp.words;
    let lnp = lp.planes();
    let rnp = rp.planes();
    for kw in kplan.iter() {
        for ctile in cols.iter() {
            for r in rows.clone() {
                let lrow_all = &lp.data[r * lnp * words..(r + 1) * lnp * words];
                let out_row = &mut out[(r - rows.start) * n..(r - rows.start + 1) * n];
                for c in ctile.clone() {
                    let rrow_all = &rp.data[c * rnp * words..(c + 1) * rnp * words];
                    let mut acc = 0i64;
                    for (li, wrow) in pairw.chunks_exact(rnp).enumerate() {
                        let lstrip = &lrow_all[li * words + kw.start..li * words + kw.end];
                        for (ri, &w) in wrow.iter().enumerate() {
                            let rstrip = &rrow_all[ri * words + kw.start..ri * words + kw.end];
                            acc += w * popcount_and_tier(tier, lstrip, rstrip) as i64;
                        }
                    }
                    out_row[c] += acc;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::gemm_bitserial;
    use crate::partition::ShardPlan;
    use crate::util::{property_sweep, Rng};

    fn random_pair(
        rng: &mut Rng,
        m: usize,
        k: usize,
        n: usize,
        wbits: u32,
        abits: u32,
        lsigned: bool,
        rsigned: bool,
    ) -> (BitSerialMatrix, BitSerialMatrix, IntMatrix) {
        let a = IntMatrix::random(rng, m, k, wbits, lsigned);
        let b = IntMatrix::random(rng, k, n, abits, rsigned);
        let expect = a.matmul(&b);
        let la = BitSerialMatrix::from_int(&a, wbits, lsigned);
        let rb = BitSerialMatrix::from_int_transposed(&b, abits, rsigned);
        (la, rb, expect)
    }

    #[test]
    fn matches_reference_and_oracle() {
        property_sweep(0x71E5, 30, |rng, _| {
            let m = rng.index(20) + 1;
            let k = rng.index(200) + 1; // frequently not a multiple of 64
            let n = rng.index(20) + 1;
            let w = rng.index(8) as u32 + 1;
            let a = rng.index(8) as u32 + 1;
            let (ls, rs) = (rng.chance(0.5), rng.chance(0.5));
            let (la, rb, expect) = random_pair(rng, m, k, n, w, a, ls, rs);
            let tiled = gemm_tiled(&la, &rb).unwrap();
            assert_eq!(tiled, expect, "m={m} k={k} n={n} w={w} a={a}");
            assert_eq!(tiled, gemm_bitserial(&la, &rb));
        });
    }

    #[test]
    fn ragged_tile_boundaries() {
        let mut rng = Rng::new(0xED6E);
        // Shapes chosen to exercise every tile-edge combination,
        // including k not a multiple of 64 and m/n not multiples of the
        // tile size.
        for (m, k, n) in [(1, 1, 1), (7, 63, 9), (8, 64, 8), (9, 65, 7), (17, 129, 33)] {
            for (tm, tn) in [(1, 1), (3, 5), (8, 8), (32, 32)] {
                let (la, rb, expect) = random_pair(&mut rng, m, k, n, 3, 2, true, false);
                let cfg = KernelConfig {
                    tile_m: tm,
                    tile_n: tn,
                    ..KernelConfig::default()
                };
                assert_eq!(
                    gemm_tiled_with(&la, &rb, &cfg, None).unwrap(),
                    expect,
                    "m={m} k={k} n={n} tile={tm}x{tn}"
                );
            }
        }
    }

    #[test]
    fn k_chunked_matches_whole_k() {
        // Finite tile_k strips must accumulate to exactly the unchunked
        // product for every chunk/word alignment: chunks smaller than a
        // word (round up to one), word-aligned, straddling, and larger
        // than k (degenerate to whole-row).
        let mut rng = Rng::new(0xC4A);
        for (m, k, n) in [(5, 63, 7), (9, 64, 5), (7, 129, 9), (6, 500, 8)] {
            let (la, rb, expect) = random_pair(&mut rng, m, k, n, 4, 3, true, true);
            assert_eq!(gemm_tiled(&la, &rb).unwrap(), expect);
            for tk in [1usize, 64, 100, 128, 192, 4096] {
                let cfg = KernelConfig {
                    tile_k: tk,
                    ..KernelConfig::default()
                };
                assert_eq!(
                    gemm_tiled_with(&la, &rb, &cfg, None).unwrap(),
                    expect,
                    "m={m} k={k} n={n} tile_k={tk}"
                );
                // Chunking must also hold on the pool path (accumulation
                // happens per row-tile slot).
                assert_eq!(
                    gemm_tiled_with(&la, &rb, &cfg, Some((WorkerPool::global(), 4))).unwrap(),
                    expect,
                    "pooled m={m} k={k} n={n} tile_k={tk}"
                );
            }
        }
    }

    #[test]
    fn degenerate_tiles_are_typed_errors() {
        let mut rng = Rng::new(0xBAD);
        let (la, rb, _) = random_pair(&mut rng, 4, 70, 4, 2, 2, false, false);
        for cfg in [
            KernelConfig {
                tile_m: 0,
                ..KernelConfig::default()
            },
            KernelConfig {
                tile_n: 0,
                ..KernelConfig::default()
            },
            KernelConfig {
                tile_k: 0,
                ..KernelConfig::default()
            },
        ] {
            assert!(cfg.validate().is_err());
            let r = gemm_tiled_with(&la, &rb, &cfg, None);
            assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{cfg:?}");
        }
    }

    #[test]
    fn shape_and_range_violations_are_typed_errors() {
        let mut rng = Rng::new(0xBAD2);
        let (la, rb, _) = random_pair(&mut rng, 4, 70, 4, 2, 2, false, false);
        let (lb, _, _) = random_pair(&mut rng, 4, 71, 4, 2, 2, false, false);
        assert!(matches!(
            gemm_tiled(&lb, &rb),
            Err(BismoError::ShapeMismatch(_))
        ));
        let r = gemm_tiled_block(&la, &rb, 0..5, 0..4, None, &KernelConfig::default(), None);
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
    }

    #[test]
    fn sparse_planes_are_skipped_and_exact() {
        // Even values: LSB plane all-zero. Small values: high planes
        // all-zero. Both must stay bit-exact through the skip.
        let a = IntMatrix::from_fn(13, 100, |r, c| (((r * 7 + c) % 8) as i64) * 2);
        let b = IntMatrix::from_fn(100, 11, |r, c| ((r + c) % 2) as i64);
        let la = BitSerialMatrix::from_int(&a, 5, false);
        let rb = BitSerialMatrix::from_int_transposed(&b, 4, false);
        assert!(la.plane_is_zero(0) && la.plane_is_zero(4));
        assert!(rb.plane_is_zero(1));
        assert_eq!(gemm_tiled(&la, &rb).unwrap(), a.matmul(&b));
    }

    #[test]
    fn all_zero_operand_short_circuits() {
        let z = IntMatrix::zeros(5, 70);
        let mut rng = Rng::new(2);
        let b = IntMatrix::random(&mut rng, 70, 6, 3, false);
        let lz = BitSerialMatrix::from_int(&z, 4, false);
        let rb = BitSerialMatrix::from_int_transposed(&b, 3, false);
        assert_eq!(gemm_tiled(&lz, &rb).unwrap(), IntMatrix::zeros(5, 6));
    }

    #[test]
    fn parallel_matches_serial() {
        property_sweep(0x9B0, 8, |rng, _| {
            let m = rng.index(40) + 1;
            let k = rng.index(300) + 1;
            let n = rng.index(25) + 1;
            let (la, rb, expect) = random_pair(rng, m, k, n, 4, 3, true, true);
            let serial = gemm_tiled(&la, &rb).unwrap();
            assert_eq!(serial, expect);
            let cfg = KernelConfig::default();
            for threads in [1, 2, 3, 8] {
                assert_eq!(
                    gemm_tiled_with(&la, &rb, &cfg, Some((WorkerPool::global(), threads)))
                        .unwrap(),
                    serial
                );
            }
        });
    }

    #[test]
    fn block_equals_slice_of_full_product() {
        property_sweep(0xB10C2, 12, |rng, _| {
            let m = rng.index(16) + 2;
            let k = rng.index(150) + 1;
            let n = rng.index(16) + 2;
            let (la, rb, expect) = random_pair(rng, m, k, n, 3, 3, true, false);
            let r0 = rng.index(m);
            let r1 = r0 + rng.index(m - r0) + 1;
            let c0 = rng.index(n);
            let c1 = c0 + rng.index(n - c0) + 1;
            let block = gemm_tiled_block(
                &la,
                &rb,
                r0..r1,
                c0..c1,
                None,
                &KernelConfig::default(),
                None,
            )
            .unwrap();
            let want = IntMatrix::from_fn(r1 - r0, c1 - c0, |r, c| expect.get(r0 + r, c0 + c));
            assert_eq!(block, want, "m={m} k={k} n={n} block {r0}..{r1}×{c0}..{c1}");
        });
    }

    #[test]
    fn plane_group_shards_sum_to_full_product() {
        let mut rng = Rng::new(0x93A);
        let (la, rb, expect) = random_pair(&mut rng, 9, 130, 7, 5, 3, true, true);
        for groups in [1, 2, 3, 5] {
            let plan = ShardPlan::grid(9, 7, 2, 2).with_plane_groups(la.bits, groups);
            let parts: Vec<IntMatrix> = plan
                .shards()
                .iter()
                .map(|s| {
                    gemm_tiled_block(
                        &la,
                        &rb,
                        s.rows.clone(),
                        s.cols.clone(),
                        s.planes.clone(),
                        &KernelConfig::default(),
                        None,
                    )
                    .unwrap()
                })
                .collect();
            assert_eq!(plan.assemble(&parts).unwrap(), expect, "groups={groups}");
        }
    }

    #[test]
    fn explicit_tier_paths_match_the_default_dispatch() {
        let mut rng = Rng::new(0x71E6);
        let (la, rb, expect) = random_pair(&mut rng, 11, 130, 9, 3, 2, true, false);
        assert_eq!(gemm_tiled(&la, &rb).unwrap(), expect);
        for tier in DispatchTier::supported() {
            assert_eq!(gemm_tiled_tier(&la, &rb, tier).unwrap(), expect, "tier={tier}");
        }
    }

    #[test]
    fn signed_extremes() {
        for bits in [2u32, 4, 8] {
            let lo = -(1i64 << (bits - 1));
            let a = IntMatrix::from_fn(3, 70, |_, _| lo);
            let b = IntMatrix::from_fn(70, 3, |_, _| lo);
            let la = BitSerialMatrix::from_int(&a, bits, true);
            let rb = BitSerialMatrix::from_int_transposed(&b, bits, true);
            assert_eq!(gemm_tiled(&la, &rb).unwrap(), a.matmul(&b), "bits={bits}");
        }
    }
}
