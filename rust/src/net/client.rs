//! [`NetClient`]: the blocking wire-protocol client.
//!
//! One client owns one connection and one tenant session. Calls are
//! synchronous request/response pairs; errors the server reports come
//! back as the same typed [`BismoError`] kinds an in-process caller
//! would see — a shed request is a matchable
//! [`BismoError::Overloaded`] with its `retry_after_ms` hint intact.

use super::wire::{
    decode_header, decode_payload, encode_request, Message, Request, Response, WireStats,
    HEADER_BYTES,
};
use crate::api::{BismoError, ExecOpts};
use crate::bitmatrix::IntMatrix;
use crate::coordinator::{Backend, Precision};
use crate::lowering::{ConvSpec, LoweringMode, Tensor};
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};

/// What a remote matmul reports back (the wire subset of
/// [`crate::coordinator::GemmResponse`]).
#[derive(Clone, Debug)]
pub struct RemoteGemm {
    pub result: IntMatrix,
    pub lhs_cached: bool,
    pub rhs_cached: bool,
    pub shards: u32,
    /// Server-side submission-to-completion time, nanoseconds.
    pub total_ns: u64,
}

/// What a remote conv reports back.
#[derive(Clone, Debug)]
pub struct RemoteConv {
    pub output: Tensor,
    /// Lowered GEMM count (1 for im2col, `kh·kw` for kn2row).
    pub gemms: u32,
    pub weights_cached: bool,
}

/// A prepared-weight handle on the server: upload once with
/// [`NetClient::prepare_weights`], replay with
/// [`NetClient::matmul_prepared`].
#[derive(Clone, Copy, Debug)]
pub struct RemotePrepared {
    pub weight_id: u64,
    /// Whether the packing was already resident in this tenant's
    /// namespace at upload time.
    pub resident: bool,
}

/// Blocking client over one TCP connection, bound to one tenant.
pub struct NetClient {
    stream: TcpStream,
    next_id: u32,
    namespace: u64,
}

impl NetClient {
    /// Connect and establish the tenant session (the `Hello`
    /// handshake).
    pub fn connect(addr: impl ToSocketAddrs, tenant: &str) -> Result<NetClient, BismoError> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        let mut c = NetClient {
            stream,
            next_id: 1,
            namespace: 0,
        };
        match c.call(&Request::Hello {
            tenant: tenant.to_string(),
        })? {
            Response::HelloOk { namespace } => {
                c.namespace = namespace;
                Ok(c)
            }
            other => Err(unexpected(&other)),
        }
    }

    /// The cache namespace the server assigned this tenant
    /// (observability only; it is never sent back).
    pub fn namespace(&self) -> u64 {
        self.namespace
    }

    /// One remote matmul `a · b`.
    pub fn matmul(
        &mut self,
        a: &IntMatrix,
        b: &IntMatrix,
        prec: Precision,
        backend: Backend,
        verify: bool,
    ) -> Result<RemoteGemm, BismoError> {
        let resp = self.call(&Request::Matmul {
            prec,
            backend,
            verify,
            a: a.clone(),
            b: b.clone(),
        })?;
        into_gemm(resp)
    }

    /// Upload weights once; the server packs them into this tenant's
    /// namespace and returns a replayable id.
    pub fn prepare_weights(
        &mut self,
        weights: &IntMatrix,
        bits: u32,
        signed: bool,
    ) -> Result<RemotePrepared, BismoError> {
        match self.call(&Request::PrepareWeights {
            bits,
            signed,
            weights: weights.clone(),
        })? {
            Response::PrepareOk {
                weight_id,
                resident,
            } => Ok(RemotePrepared {
                weight_id,
                resident,
            }),
            other => Err(unexpected(&other)),
        }
    }

    /// Matmul against previously uploaded weights. `prec.abits` /
    /// `prec.rsigned` must match the upload.
    pub fn matmul_prepared(
        &mut self,
        prepared: RemotePrepared,
        a: &IntMatrix,
        prec: Precision,
        backend: Backend,
        verify: bool,
    ) -> Result<RemoteGemm, BismoError> {
        let resp = self.call(&Request::MatmulPrepared {
            weight_id: prepared.weight_id,
            prec,
            backend,
            verify,
            a: a.clone(),
        })?;
        into_gemm(resp)
    }

    /// One remote convolution layer, lowered server-side. Execution
    /// options travel as the shared [`ExecOpts`] value; the wire
    /// protocol carries the subset the server honors per request
    /// (backend and verification — cache policy is the server's
    /// per-tenant concern).
    pub fn conv(
        &mut self,
        spec: ConvSpec,
        mode: LoweringMode,
        input: &Tensor,
        weights: &IntMatrix,
        prec: Precision,
        opts: &ExecOpts,
    ) -> Result<RemoteConv, BismoError> {
        match self.call(&Request::Conv {
            spec,
            mode,
            prec,
            backend: opts.req.backend,
            verify: opts.req.verify,
            weights: weights.clone(),
            input: input.clone(),
        })? {
            Response::ConvOk {
                gemms,
                weights_cached,
                output,
            } => Ok(RemoteConv {
                output,
                gemms,
                weights_cached,
            }),
            other => Err(unexpected(&other)),
        }
    }

    /// Server-side cache and admission counters.
    pub fn stats(&mut self) -> Result<WireStats, BismoError> {
        match self.call(&Request::Stats)? {
            Response::StatsOk(s) => Ok(s),
            other => Err(unexpected(&other)),
        }
    }

    /// One request/response round trip. Error frames come back as
    /// `Err` with the server's typed error reconstructed.
    fn call(&mut self, req: &Request) -> Result<Response, BismoError> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1);
        let raw = encode_request(id, req)?;
        self.stream.write_all(&raw)?;
        self.stream.flush()?;
        let mut hdr = [0u8; HEADER_BYTES];
        self.stream.read_exact(&mut hdr)?;
        let header = decode_header(&hdr)?;
        let mut payload = vec![0u8; header.len];
        self.stream.read_exact(&mut payload)?;
        if header.req_id != id {
            return Err(BismoError::Parse(format!(
                "response for request {} while awaiting {}",
                header.req_id, id
            )));
        }
        let resp = match decode_payload(header.kind, &payload)? {
            Message::Response(r) => r,
            Message::Request(_) => {
                return Err(BismoError::Parse("server sent a request frame".into()))
            }
        };
        if let Some(e) = resp.to_error() {
            return Err(e);
        }
        Ok(resp)
    }
}

fn into_gemm(resp: Response) -> Result<RemoteGemm, BismoError> {
    match resp {
        Response::MatmulOk {
            lhs_cached,
            rhs_cached,
            shards,
            total_ns,
            result,
        } => Ok(RemoteGemm {
            result,
            lhs_cached,
            rhs_cached,
            shards,
            total_ns,
        }),
        other => Err(unexpected(&other)),
    }
}

fn unexpected(resp: &Response) -> BismoError {
    let kind = match resp {
        Response::HelloOk { .. } => "HelloOk",
        Response::MatmulOk { .. } => "MatmulOk",
        Response::PrepareOk { .. } => "PrepareOk",
        Response::ConvOk { .. } => "ConvOk",
        Response::StatsOk(_) => "StatsOk",
        Response::Error { .. } => "Error",
    };
    BismoError::Parse(format!("unexpected response frame: {kind}"))
}
