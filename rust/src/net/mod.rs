//! The network serving front door: a binary wire protocol, per-tenant
//! cache namespaces and quotas, and admission control with load
//! shedding over the in-process serving stack.
//!
//! Until this module, traffic could only originate in-process — the
//! paper's host-driven accelerator service stopped at the
//! [`crate::api::Session`] facade. `net` carries the same operations
//! over std TCP:
//!
//! - [`wire`] — length-prefixed, versioned frames with a strict
//!   `try_decode`-style parser (typed [`crate::api::BismoError::Parse`]
//!   on any corruption, never a panic; mirrored after the ISA decoder
//!   and property-fuzzed by `bismo fuzz --mode wire`).
//! - [`NetServer`] — one reader/writer thread per connection
//!   dispatching onto the shared worker lanes; multi-tenant sessions
//!   whose weight uploads live in per-tenant cache namespaces; global
//!   and per-tenant admission caps that shed excess load with typed
//!   [`crate::api::BismoError::Overloaded`] back-off hints; graceful
//!   drain on shutdown.
//! - [`NetClient`] — the blocking client: matmul, prepared-weight
//!   upload/replay, conv and stats, with server errors reconstructed
//!   as typed [`crate::api::BismoError`] values.
//!
//! Hosted by `bismo serve --port`; driven under load by
//! `bismo serve-bench --remote` (tail latency + shed rate into
//! `BENCH_serve.json`).

pub mod client;
pub mod server;
pub mod wire;

pub use client::{NetClient, RemoteConv, RemoteGemm, RemotePrepared};
pub use server::{NetServer, ServeConfig};
pub use wire::{Message, Request, Response, WireStats};
