//! [`NetServer`]: the TCP front door — tenant sessions, admission
//! control and load shedding over one shared [`Session`].
//!
//! One OS thread per connection reads frames, dispatches onto the
//! session's worker lanes and writes responses in request order.
//! Concurrency comes from concurrent connections: the serving layer
//! micro-batches across them exactly as it does for in-process
//! callers.
//!
//! ## Tenancy
//!
//! The first frame on every connection must be [`Request::Hello`],
//! naming a tenant. Each tenant name maps to a stable nonzero cache
//! namespace; every cache interaction the connection triggers is
//! scoped to it, so tenants share the packing cache's byte budget and
//! LRU order but can never hit each other's entries — even for
//! bit-identical weights.
//!
//! ## Admission control
//!
//! Work-bearing requests (matmul, prepared matmul, conv, weight
//! upload) pass an admission gate before touching the service queue: a
//! global in-flight cap and a per-tenant in-flight cap. A request
//! arriving over either cap is *shed* — answered immediately with a
//! typed [`BismoError::Overloaded`] carrying a depth-scaled
//! `retry_after_ms` hint — never queued, hung or dropped. Per-tenant
//! uploaded-weight bytes are capped separately
//! ([`BismoError::CapacityExceeded`]).
//!
//! ## Shutdown
//!
//! [`NetServer::shutdown`] stops the acceptor, lets every connection
//! finish its in-flight request, joins all threads and then drains the
//! underlying service — the graceful half of the serving story.

use super::wire::{
    decode_header, decode_payload, encode_response, error_frame, Header, Message, Request,
    Response, WireStats, HEADER_BYTES,
};
use crate::api::{BismoError, Session, SessionConfig};
use crate::bitmatrix::IntMatrix;
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// Topology and QoS limits of one [`NetServer`].
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// The shared serving stack beneath the front door.
    pub session: SessionConfig,
    /// Global admission cap: work-bearing requests in flight across
    /// all tenants. Arrivals over the cap are shed with
    /// [`BismoError::Overloaded`].
    pub max_in_flight: usize,
    /// Per-tenant admission cap (one noisy tenant cannot occupy the
    /// whole global window).
    pub tenant_max_in_flight: usize,
    /// Per-tenant cap on uploaded prepared-weight bytes (dense i64
    /// bytes of the retained source matrices); exceeding it is a typed
    /// [`BismoError::CapacityExceeded`].
    pub tenant_max_weight_bytes: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            session: SessionConfig::default(),
            max_in_flight: 64,
            tenant_max_in_flight: 16,
            tenant_max_weight_bytes: 16 << 20,
        }
    }
}

/// One uploaded weight matrix, retained for prepared replay.
struct StoredWeights {
    namespace: u64,
    bits: u32,
    signed: bool,
    weights: Arc<IntMatrix>,
}

/// All mutable server bookkeeping, under one mutex. Never held across
/// request execution — admit, drop the lock, execute, re-lock to
/// release — so the gate cannot serialize the actual GEMM work.
#[derive(Default)]
struct Book {
    in_flight: usize,
    tenant_in_flight: HashMap<u64, usize>,
    tenant_weight_bytes: HashMap<u64, usize>,
    /// Tenant name → namespace. Reconnects resolve to the same
    /// namespace, so a tenant's uploads survive its connections.
    tenants: HashMap<String, u64>,
    next_namespace: u64,
    weights: HashMap<u64, StoredWeights>,
    next_weight_id: u64,
    shed_total: u64,
    served_total: u64,
}

struct Shared {
    session: Session,
    cfg: ServeConfig,
    stop: AtomicBool,
    book: Mutex<Book>,
}

/// Depth-scaled back-off hint: the further over the cap the gate is,
/// the longer clients are told to wait. Bounded so a burst never turns
/// into a minutes-long advisory.
fn retry_hint_ms(in_flight: usize, cap: usize) -> u64 {
    let over = in_flight.saturating_sub(cap) as u64;
    (5 + 5 * over).min(1_000)
}

/// RAII admission slot: decrements the global and per-tenant gauges on
/// drop, so every exit path (success, error, panic-mapped error)
/// releases exactly once.
struct AdmitGuard {
    shared: Arc<Shared>,
    namespace: u64,
}

impl Drop for AdmitGuard {
    fn drop(&mut self) {
        let mut book = self.shared.book.lock().unwrap();
        book.in_flight -= 1;
        if let Some(t) = book.tenant_in_flight.get_mut(&self.namespace) {
            *t = t.saturating_sub(1);
        }
    }
}

fn try_admit(shared: &Arc<Shared>, namespace: u64) -> Result<AdmitGuard, BismoError> {
    let mut book = shared.book.lock().unwrap();
    let tenant_depth = book.tenant_in_flight.get(&namespace).copied().unwrap_or(0);
    let shed = if book.in_flight >= shared.cfg.max_in_flight {
        Some(retry_hint_ms(book.in_flight, shared.cfg.max_in_flight))
    } else if tenant_depth >= shared.cfg.tenant_max_in_flight {
        Some(retry_hint_ms(tenant_depth, shared.cfg.tenant_max_in_flight))
    } else {
        None
    };
    if let Some(retry_after_ms) = shed {
        book.shed_total += 1;
        return Err(BismoError::Overloaded { retry_after_ms });
    }
    book.in_flight += 1;
    *book.tenant_in_flight.entry(namespace).or_insert(0) += 1;
    Ok(AdmitGuard {
        shared: shared.clone(),
        namespace,
    })
}

/// The TCP serving front door. Bind with [`NetServer::bind`]; drop (or
/// call [`NetServer::shutdown`]) to stop accepting, drain and join.
pub struct NetServer {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    acceptor: Option<thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port), start
    /// the serving stack and the acceptor thread.
    pub fn bind(addr: &str, cfg: ServeConfig) -> Result<NetServer, BismoError> {
        if cfg.max_in_flight == 0 || cfg.tenant_max_in_flight == 0 {
            return Err(BismoError::InvalidConfig(
                "admission caps must be at least 1".into(),
            ));
        }
        let session = Session::new(cfg.session)?;
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            session,
            cfg,
            stop: AtomicBool::new(false),
            book: Mutex::new(Book {
                next_namespace: 1,
                next_weight_id: 1,
                ..Book::default()
            }),
        });
        let conns = Arc::new(Mutex::new(Vec::new()));
        let acceptor = {
            let shared = shared.clone();
            let conns = conns.clone();
            thread::spawn(move || accept_loop(&listener, &shared, &conns))
        };
        Ok(NetServer {
            shared,
            local_addr,
            acceptor: Some(acceptor),
            conns,
        })
    }

    /// The bound address (the actual port when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Requests shed with [`BismoError::Overloaded`] since startup.
    pub fn shed_total(&self) -> u64 {
        self.shared.book.lock().unwrap().shed_total
    }

    /// Work-bearing requests completed since startup.
    pub fn served_total(&self) -> u64 {
        self.shared.book.lock().unwrap().served_total
    }

    /// Packing-cache counters of the shared session (all tenants).
    pub fn cache_stats(&self) -> crate::coordinator::CacheStats {
        self.shared.session.cache_stats()
    }

    /// Graceful drain: stop accepting connections, let every
    /// connection finish its in-flight request, join all threads, then
    /// shut the serving layer down. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        self.shared.session.shutdown();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: &TcpListener,
    shared: &Arc<Shared>,
    conns: &Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
) {
    while !shared.stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let shared = shared.clone();
                let h = thread::spawn(move || handle_conn(&shared, stream));
                conns.lock().unwrap().push(h);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(10));
            }
            // Transient accept errors (e.g. aborted handshakes) are
            // not fatal to the server.
            Err(_) => thread::sleep(Duration::from_millis(10)),
        }
    }
}

/// Outcome of one bounded read attempt.
enum ReadStatus {
    /// The buffer is full.
    Full,
    /// Clean EOF before any byte of this read (the peer closed).
    Eof,
    /// Timed out with zero bytes read (poll again after checking the
    /// stop flag).
    Idle,
    /// The server is draining and nothing usable was read.
    Stopped,
}

/// Fill `buf` from `stream`, tolerating read-timeout polls. A timeout
/// with partial data keeps reading (the frame is mid-flight); a
/// timeout with no data returns [`ReadStatus::Idle`] so the caller can
/// check the stop flag between frames.
fn read_full(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
) -> Result<ReadStatus, BismoError> {
    let mut got = 0;
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                return if got == 0 {
                    Ok(ReadStatus::Eof)
                } else {
                    Err(BismoError::Io("connection closed mid-frame".into()))
                }
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if got == 0 {
                    return Ok(ReadStatus::Idle);
                }
                if stop.load(Ordering::SeqCst) {
                    // Draining with a half-received frame: give up on
                    // it (it was never admitted).
                    return Ok(ReadStatus::Stopped);
                }
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(ReadStatus::Full)
}

fn write_frame(stream: &mut TcpStream, req_id: u32, resp: &Response) -> Result<(), BismoError> {
    let raw = encode_response(req_id, resp)?;
    stream.write_all(&raw)?;
    stream.flush()?;
    Ok(())
}

fn handle_conn(shared: &Arc<Shared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    // The cache namespace this connection's Hello resolved to.
    let mut tenant: Option<u64> = None;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            return;
        }
        let mut hdr = [0u8; HEADER_BYTES];
        let header: Header = match read_full(&mut stream, &mut hdr, &shared.stop) {
            Ok(ReadStatus::Full) => match decode_header(&hdr) {
                Ok(h) => h,
                Err(e) => {
                    // The stream cannot be resynchronized after a bad
                    // header: report and close.
                    let _ = write_frame(&mut stream, 0, &error_frame(&e));
                    return;
                }
            },
            Ok(ReadStatus::Idle) => continue,
            Ok(ReadStatus::Eof | ReadStatus::Stopped) | Err(_) => return,
        };
        let mut payload = vec![0u8; header.len];
        match read_full(&mut stream, &mut payload, &shared.stop) {
            Ok(ReadStatus::Full) => {}
            Ok(_) | Err(_) => return,
        }
        let req = match decode_payload(header.kind, &payload) {
            Ok(Message::Request(r)) => r,
            Ok(Message::Response(_)) => {
                let e = BismoError::Parse("client sent a response frame".into());
                let _ = write_frame(&mut stream, header.req_id, &error_frame(&e));
                return;
            }
            Err(e) => {
                let _ = write_frame(&mut stream, header.req_id, &error_frame(&e));
                return;
            }
        };
        // Panics inside request handling (none are expected — worker
        // panics are already mapped by the service) must never take the
        // server down; they become typed WorkerPanicked responses.
        let resp = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handle_request(shared, &mut tenant, req)
        })) {
            Ok(Ok(resp)) => resp,
            Ok(Err(e)) => error_frame(&e),
            Err(_) => error_frame(&BismoError::WorkerPanicked(
                "request handler panicked".into(),
            )),
        };
        if write_frame(&mut stream, header.req_id, &resp).is_err() {
            return;
        }
    }
}

fn handle_request(
    shared: &Arc<Shared>,
    tenant: &mut Option<u64>,
    req: Request,
) -> Result<Response, BismoError> {
    // Hello and Stats work before/without admission; everything else
    // needs a tenant session first.
    if let Request::Hello { tenant: name } = &req {
        let mut book = shared.book.lock().unwrap();
        let next = book.next_namespace;
        let ns = *book.tenants.entry(name.clone()).or_insert(next);
        if ns == next {
            book.next_namespace += 1;
        }
        *tenant = Some(ns);
        return Ok(Response::HelloOk { namespace: ns });
    }
    if let Request::Stats = &req {
        let cache = shared.session.cache_stats();
        let book = shared.book.lock().unwrap();
        return Ok(Response::StatsOk(WireStats {
            cache_hits: cache.hits,
            cache_misses: cache.misses,
            cache_insertions: cache.insertions,
            cache_evictions: cache.evictions,
            cache_entries: shared.session.cache_entries() as u64,
            cache_resident_bytes: shared.session.cache_bytes() as u64,
            in_flight: book.in_flight as u64,
            shed_total: book.shed_total,
            served_total: book.served_total,
        }));
    }
    let ns = tenant.ok_or_else(|| {
        BismoError::IllegalProgram("first frame on a connection must be Hello".into())
    })?;
    // Admission gate: shed before anything reaches the service queue.
    let _guard = try_admit(shared, ns)?;
    let resp = match req {
        Request::Matmul {
            prec,
            backend,
            verify,
            a,
            b,
        } => {
            let r = shared
                .session
                .matmul(prec)
                .backend(backend)
                .verify(verify)
                .cache_namespace(ns)
                .run(a, b)?;
            Response::MatmulOk {
                lhs_cached: r.lhs_cached,
                rhs_cached: r.rhs_cached,
                shards: r.shards as u32,
                total_ns: r.total_ns,
                result: r.result,
            }
        }
        Request::PrepareWeights {
            bits,
            signed,
            weights,
        } => {
            let bytes = weights.data().len() * 8;
            {
                let mut book = shared.book.lock().unwrap();
                let used = book.tenant_weight_bytes.entry(ns).or_insert(0);
                if *used + bytes > shared.cfg.tenant_max_weight_bytes {
                    return Err(BismoError::CapacityExceeded(format!(
                        "tenant weight quota: {} + {} bytes exceeds the {} byte cap",
                        used, bytes, shared.cfg.tenant_max_weight_bytes
                    )));
                }
                *used += bytes;
            }
            let weights = Arc::new(weights);
            let (_, resident) = shared
                .session
                .service()
                .prepare_operand_in(ns, &weights, bits, signed, true)
                .inspect_err(|_| {
                    // A rejected upload (bad precision) must not eat
                    // quota.
                    let mut book = shared.book.lock().unwrap();
                    if let Some(used) = book.tenant_weight_bytes.get_mut(&ns) {
                        *used = used.saturating_sub(bytes);
                    }
                })?;
            let mut book = shared.book.lock().unwrap();
            let weight_id = book.next_weight_id;
            book.next_weight_id += 1;
            book.weights.insert(
                weight_id,
                StoredWeights {
                    namespace: ns,
                    bits,
                    signed,
                    weights,
                },
            );
            Response::PrepareOk {
                weight_id,
                resident,
            }
        }
        Request::MatmulPrepared {
            weight_id,
            prec,
            backend,
            verify,
            a,
        } => {
            let (weights, bits, signed) = {
                let book = shared.book.lock().unwrap();
                match book.weights.get(&weight_id) {
                    // A foreign tenant's id must be indistinguishable
                    // from an unknown one — no cross-tenant probing.
                    Some(w) if w.namespace == ns => (w.weights.clone(), w.bits, w.signed),
                    _ => {
                        return Err(BismoError::InvalidConfig(format!(
                            "unknown weight id {weight_id}"
                        )))
                    }
                }
            };
            if prec.abits != bits || prec.rsigned != signed {
                return Err(BismoError::PrecisionUnsupported(format!(
                    "weight id {weight_id} was prepared at {}-bit {}, requested {}-bit {}",
                    bits,
                    if signed { "signed" } else { "unsigned" },
                    prec.abits,
                    if prec.rsigned { "signed" } else { "unsigned" },
                )));
            }
            let r = shared
                .session
                .matmul(prec)
                .backend(backend)
                .verify(verify)
                .cache_namespace(ns)
                .run(a, weights)?;
            Response::MatmulOk {
                lhs_cached: r.lhs_cached,
                rhs_cached: r.rhs_cached,
                shards: r.shards as u32,
                total_ns: r.total_ns,
                result: r.result,
            }
        }
        Request::Conv {
            spec,
            mode,
            prec,
            backend,
            verify,
            weights,
            input,
        } => {
            let r = shared
                .session
                .conv(spec, prec)
                .lowering(mode)
                .backend(backend)
                .verify(verify)
                .cache_namespace(ns)
                .run(&input, weights)?;
            Response::ConvOk {
                gemms: r.gemms.len() as u32,
                weights_cached: r.weights_cached(),
                output: r.output,
            }
        }
        Request::Hello { .. } | Request::Stats => unreachable!("handled above"),
    };
    shared.book.lock().unwrap().served_total += 1;
    Ok(resp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_hint_scales_with_depth_and_saturates() {
        assert_eq!(retry_hint_ms(4, 4), 5);
        assert!(retry_hint_ms(10, 4) > retry_hint_ms(5, 4));
        assert_eq!(retry_hint_ms(usize::MAX, 1), 1_000);
    }

    #[test]
    fn zero_caps_are_rejected() {
        let cfg = ServeConfig {
            max_in_flight: 0,
            ..ServeConfig::default()
        };
        assert!(matches!(
            NetServer::bind("127.0.0.1:0", cfg),
            Err(BismoError::InvalidConfig(_))
        ));
    }
}
