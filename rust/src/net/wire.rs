//! The binary wire protocol: length-prefixed, versioned frames with a
//! strict `try_decode`-style parser.
//!
//! Every frame is a fixed 16-byte header followed by a typed payload:
//!
//! | offset | field     | type  | meaning                              |
//! |--------|-----------|-------|--------------------------------------|
//! | 0      | magic     | `u32` | `0x4F4D5342` (`"BSMO"` little-endian)|
//! | 4      | version   | `u16` | protocol version ([`VERSION`])       |
//! | 6      | kind      | `u16` | frame kind (request or response)     |
//! | 8      | req_id    | `u32` | echoed verbatim in the response      |
//! | 12     | len       | `u32` | payload bytes ([`MAX_FRAME_BYTES`])  |
//!
//! All integers are little-endian. Matrices travel as
//! `rows:u32 cols:u32` followed by `rows·cols` `i64` words; tensors as
//! `n:u32 h:u32 w:u32 c:u32` plus NHWC-ordered `i64` words; strings as
//! `len:u32` plus UTF-8 bytes.
//!
//! Decoding mirrors the ISA decoder discipline: every length is
//! bounds-checked against the bytes actually present *before* any
//! allocation (a corrupt `rows·cols` cannot trigger an out-of-memory
//! grab), element counts use `checked_mul`, trailing bytes are an
//! error, and every failure is a typed [`BismoError::Parse`] — the
//! decoder never panics on corrupt input (property-fuzzed by the
//! `wire` mode of `bismo fuzz`).

use crate::api::BismoError;
use crate::bitmatrix::IntMatrix;
use crate::coordinator::{Backend, Precision};
use crate::lowering::{ConvSpec, LoweringMode, Tensor};
use crate::sim::SimError;

/// `"BSMO"` read little-endian.
pub const MAGIC: u32 = 0x4F4D_5342;
/// Protocol version carried in every header; a mismatch is a typed
/// [`BismoError::Parse`], not a guess.
pub const VERSION: u16 = 1;
/// Upper bound on one frame's payload. Rejected at the header, before
/// the payload is read or buffered.
pub const MAX_FRAME_BYTES: usize = 64 << 20;
/// Upper bound on a tenant name.
pub const MAX_TENANT_LEN: usize = 256;

/// Header size in bytes.
pub const HEADER_BYTES: usize = 16;

// Frame kinds. Requests have the high bit clear, responses set.
const K_HELLO: u16 = 0x01;
const K_MATMUL: u16 = 0x02;
const K_PREPARE: u16 = 0x03;
const K_MATMUL_PREPARED: u16 = 0x04;
const K_CONV: u16 = 0x05;
const K_STATS: u16 = 0x06;
const K_HELLO_OK: u16 = 0x81;
const K_MATMUL_OK: u16 = 0x82;
const K_PREPARE_OK: u16 = 0x83;
const K_CONV_OK: u16 = 0x84;
const K_STATS_OK: u16 = 0x86;
const K_ERROR: u16 = 0xFF;

/// One client→server request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Request {
    /// First frame on every connection: names the tenant. The server
    /// answers [`Response::HelloOk`] with the tenant's cache namespace.
    Hello { tenant: String },
    /// One dense matmul `a · b`.
    Matmul {
        prec: Precision,
        backend: Backend,
        verify: bool,
        a: IntMatrix,
        b: IntMatrix,
    },
    /// Upload weights once; the server packs them into the tenant's
    /// cache namespace and returns a `weight_id` for replay.
    PrepareWeights {
        bits: u32,
        signed: bool,
        weights: IntMatrix,
    },
    /// Matmul against previously uploaded weights.
    MatmulPrepared {
        weight_id: u64,
        prec: Precision,
        backend: Backend,
        verify: bool,
        a: IntMatrix,
    },
    /// One convolution layer, lowered server-side.
    Conv {
        spec: ConvSpec,
        mode: LoweringMode,
        prec: Precision,
        backend: Backend,
        verify: bool,
        weights: IntMatrix,
        input: Tensor,
    },
    /// Server-side cache and admission counters.
    Stats,
}

/// Server-side cache/admission counters, as reported by
/// [`Response::StatsOk`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_insertions: u64,
    pub cache_evictions: u64,
    pub cache_entries: u64,
    pub cache_resident_bytes: u64,
    /// Work-bearing requests currently admitted, server-wide.
    pub in_flight: u64,
    /// Requests shed with [`BismoError::Overloaded`] since startup.
    pub shed_total: u64,
    /// Work-bearing requests completed since startup.
    pub served_total: u64,
}

/// One server→client response.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Response {
    /// Session established; carries the tenant's cache namespace (for
    /// observability — the client never sends it back).
    HelloOk { namespace: u64 },
    /// A matmul completed.
    MatmulOk {
        lhs_cached: bool,
        rhs_cached: bool,
        shards: u32,
        total_ns: u64,
        result: IntMatrix,
    },
    /// Weights uploaded and packed. `resident` is true when the
    /// packing was already in the tenant's namespace.
    PrepareOk { weight_id: u64, resident: bool },
    /// A convolution completed.
    ConvOk {
        gemms: u32,
        weights_cached: bool,
        output: Tensor,
    },
    /// Counters snapshot.
    StatsOk(WireStats),
    /// The request failed; `code`/`retry_after_ms`/`message` round-trip
    /// to a typed [`BismoError`] via [`Response::to_error`].
    Error {
        code: u16,
        retry_after_ms: u64,
        message: String,
    },
}

/// Either side of the conversation, as decoded off the wire.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    Request(Request),
    Response(Response),
}

/// Stable wire code for each [`BismoError`] kind.
pub fn error_code(e: &BismoError) -> u16 {
    match e {
        BismoError::InvalidConfig(_) => 1,
        BismoError::ShapeMismatch(_) => 2,
        BismoError::PrecisionUnsupported(_) => 3,
        BismoError::CapacityExceeded(_) => 4,
        BismoError::IllegalProgram(_) => 5,
        BismoError::SimFault(_) => 6,
        BismoError::VerifyFailed(_) => 7,
        BismoError::ServiceShutdown => 8,
        BismoError::ResultConsumed => 9,
        BismoError::WorkerPanicked(_) => 10,
        BismoError::Io(_) => 11,
        BismoError::Parse(_) => 12,
        BismoError::Overloaded { .. } => 13,
    }
}

/// Build the error-frame payload fields for `e`.
pub fn error_frame(e: &BismoError) -> Response {
    let retry_after_ms = match e {
        BismoError::Overloaded { retry_after_ms } => *retry_after_ms,
        _ => 0,
    };
    Response::Error {
        code: error_code(e),
        retry_after_ms,
        message: e.to_string(),
    }
}

impl Response {
    /// Reconstruct the typed error an [`Response::Error`] frame
    /// carries; `None` for non-error responses. Round-trips every
    /// [`BismoError`] kind (a `SimFault` comes back as a remote-stage
    /// fault carrying the original message).
    pub fn to_error(&self) -> Option<BismoError> {
        let (code, retry, msg) = match self {
            Response::Error {
                code,
                retry_after_ms,
                message,
            } => (*code, *retry_after_ms, message.clone()),
            _ => return None,
        };
        Some(match code {
            1 => BismoError::InvalidConfig(msg),
            2 => BismoError::ShapeMismatch(msg),
            3 => BismoError::PrecisionUnsupported(msg),
            4 => BismoError::CapacityExceeded(msg),
            5 => BismoError::IllegalProgram(msg),
            6 => BismoError::SimFault(SimError::Fault {
                stage: "remote",
                pc: 0,
                msg,
            }),
            7 => BismoError::VerifyFailed(msg),
            8 => BismoError::ServiceShutdown,
            9 => BismoError::ResultConsumed,
            10 => BismoError::WorkerPanicked(msg),
            11 => BismoError::Io(msg),
            13 => BismoError::Overloaded {
                retry_after_ms: retry,
            },
            // 12 and anything a newer server might send degrade to
            // Parse, keeping the message.
            _ => BismoError::Parse(msg),
        })
    }
}

fn perr(msg: impl Into<String>) -> BismoError {
    BismoError::Parse(msg.into())
}

// ---------------------------------------------------------------- encode

struct Enc {
    buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { buf: Vec::new() }
    }
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn dim(&mut self, v: usize) -> Result<(), BismoError> {
        let v = u32::try_from(v)
            .map_err(|_| BismoError::CapacityExceeded(format!("dimension {v} exceeds the wire")))?;
        self.u32(v);
        Ok(())
    }
    fn string(&mut self, s: &str) -> Result<(), BismoError> {
        self.dim(s.len())?;
        self.buf.extend_from_slice(s.as_bytes());
        Ok(())
    }
    fn words(&mut self, words: &[i64]) {
        for w in words {
            self.buf.extend_from_slice(&w.to_le_bytes());
        }
    }
    fn matrix(&mut self, m: &IntMatrix) -> Result<(), BismoError> {
        self.dim(m.rows)?;
        self.dim(m.cols)?;
        self.words(m.data());
        Ok(())
    }
    fn tensor(&mut self, t: &Tensor) -> Result<(), BismoError> {
        self.dim(t.n)?;
        self.dim(t.h)?;
        self.dim(t.w)?;
        self.dim(t.c)?;
        self.words(t.data());
        Ok(())
    }
    fn prec(&mut self, p: Precision) -> Result<(), BismoError> {
        for (name, bits) in [("wbits", p.wbits), ("abits", p.abits)] {
            if bits > u8::MAX as u32 {
                return Err(BismoError::PrecisionUnsupported(format!(
                    "{name} {bits} exceeds the wire's u8 field"
                )));
            }
        }
        self.u8(p.wbits as u8);
        self.u8(p.abits as u8);
        self.u8(u8::from(p.lsigned) | (u8::from(p.rsigned) << 1));
        Ok(())
    }
    fn backend(&mut self, b: Backend) {
        self.u8(match b {
            Backend::Engine => 0,
            Backend::Sim => 1,
        });
    }
    fn spec(&mut self, s: &ConvSpec) -> Result<(), BismoError> {
        for d in [
            s.in_h, s.in_w, s.in_c, s.out_c, s.kh, s.kw, s.stride.0, s.stride.1, s.pad.0, s.pad.1,
            s.dilation.0, s.dilation.1,
        ] {
            self.dim(d)?;
        }
        Ok(())
    }
}

fn frame(kind: u16, req_id: u32, payload: Vec<u8>) -> Result<Vec<u8>, BismoError> {
    if payload.len() > MAX_FRAME_BYTES {
        return Err(BismoError::CapacityExceeded(format!(
            "frame payload {} bytes exceeds the {} byte cap",
            payload.len(),
            MAX_FRAME_BYTES
        )));
    }
    let mut out = Vec::with_capacity(HEADER_BYTES + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&kind.to_le_bytes());
    out.extend_from_slice(&req_id.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&payload);
    Ok(out)
}

/// Encode one request as a complete frame (header + payload).
pub fn encode_request(req_id: u32, req: &Request) -> Result<Vec<u8>, BismoError> {
    let mut e = Enc::new();
    let kind = match req {
        Request::Hello { tenant } => {
            e.string(tenant)?;
            K_HELLO
        }
        Request::Matmul {
            prec,
            backend,
            verify,
            a,
            b,
        } => {
            e.prec(*prec)?;
            e.backend(*backend);
            e.u8(u8::from(*verify));
            e.matrix(a)?;
            e.matrix(b)?;
            K_MATMUL
        }
        Request::PrepareWeights {
            bits,
            signed,
            weights,
        } => {
            if *bits > u8::MAX as u32 {
                return Err(BismoError::PrecisionUnsupported(format!(
                    "bits {bits} exceeds the wire's u8 field"
                )));
            }
            e.u8(*bits as u8);
            e.u8(u8::from(*signed));
            e.matrix(weights)?;
            K_PREPARE
        }
        Request::MatmulPrepared {
            weight_id,
            prec,
            backend,
            verify,
            a,
        } => {
            e.u64(*weight_id);
            e.prec(*prec)?;
            e.backend(*backend);
            e.u8(u8::from(*verify));
            e.matrix(a)?;
            K_MATMUL_PREPARED
        }
        Request::Conv {
            spec,
            mode,
            prec,
            backend,
            verify,
            weights,
            input,
        } => {
            e.spec(spec)?;
            e.u8(match mode {
                LoweringMode::Im2col => 0,
                LoweringMode::Kn2row => 1,
            });
            e.prec(*prec)?;
            e.backend(*backend);
            e.u8(u8::from(*verify));
            e.matrix(weights)?;
            e.tensor(input)?;
            K_CONV
        }
        Request::Stats => K_STATS,
    };
    frame(kind, req_id, e.buf)
}

/// Encode one response as a complete frame (header + payload).
pub fn encode_response(req_id: u32, resp: &Response) -> Result<Vec<u8>, BismoError> {
    let mut e = Enc::new();
    let kind = match resp {
        Response::HelloOk { namespace } => {
            e.u64(*namespace);
            K_HELLO_OK
        }
        Response::MatmulOk {
            lhs_cached,
            rhs_cached,
            shards,
            total_ns,
            result,
        } => {
            e.u8(u8::from(*lhs_cached) | (u8::from(*rhs_cached) << 1));
            e.u32(*shards);
            e.u64(*total_ns);
            e.matrix(result)?;
            K_MATMUL_OK
        }
        Response::PrepareOk {
            weight_id,
            resident,
        } => {
            e.u64(*weight_id);
            e.u8(u8::from(*resident));
            K_PREPARE_OK
        }
        Response::ConvOk {
            gemms,
            weights_cached,
            output,
        } => {
            e.u32(*gemms);
            e.u8(u8::from(*weights_cached));
            e.tensor(output)?;
            K_CONV_OK
        }
        Response::StatsOk(s) => {
            for v in [
                s.cache_hits,
                s.cache_misses,
                s.cache_insertions,
                s.cache_evictions,
                s.cache_entries,
                s.cache_resident_bytes,
                s.in_flight,
                s.shed_total,
                s.served_total,
            ] {
                e.u64(v);
            }
            K_STATS_OK
        }
        Response::Error {
            code,
            retry_after_ms,
            message,
        } => {
            e.u16(*code);
            e.u64(*retry_after_ms);
            e.string(message)?;
            K_ERROR
        }
    };
    frame(kind, req_id, e.buf)
}

// ---------------------------------------------------------------- decode

/// Bounds-checked cursor over one payload.
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8]) -> Cur<'a> {
        Cur { b, pos: 0 }
    }
    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8], BismoError> {
        if self.remaining() < n {
            return Err(perr(format!(
                "payload truncated: needed {n} bytes at offset {}, {} left",
                self.pos,
                self.remaining()
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, BismoError> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16, BismoError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32, BismoError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64, BismoError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    /// A bit-flags byte where only the low `used` bits are defined:
    /// set undefined bits are corruption, not silently-ignored noise.
    fn flags(&mut self, used: u32) -> Result<u8, BismoError> {
        let v = self.u8()?;
        if u32::from(v) >> used != 0 {
            return Err(perr(format!("undefined flag bits set: {v:#04x}")));
        }
        Ok(v)
    }
    /// `count` i64 words, bounds-checked before allocation.
    fn words(&mut self, count: usize) -> Result<Vec<i64>, BismoError> {
        let bytes = count
            .checked_mul(8)
            .ok_or_else(|| perr("element count overflows"))?;
        let raw = self.take(bytes)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| i64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn string(&mut self, what: &str, max: usize) -> Result<String, BismoError> {
        let len = self.u32()? as usize;
        if len > max {
            return Err(perr(format!("{what} length {len} exceeds the {max} cap")));
        }
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| perr(format!("{what} is not UTF-8")))
    }
    fn matrix(&mut self) -> Result<IntMatrix, BismoError> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        let count = rows
            .checked_mul(cols)
            .ok_or_else(|| perr("matrix shape overflows"))?;
        Ok(IntMatrix::from_slice(rows, cols, &self.words(count)?))
    }
    fn tensor(&mut self) -> Result<Tensor, BismoError> {
        let n = self.u32()? as usize;
        let h = self.u32()? as usize;
        let w = self.u32()? as usize;
        let c = self.u32()? as usize;
        let count = n
            .checked_mul(h)
            .and_then(|v| v.checked_mul(w))
            .and_then(|v| v.checked_mul(c))
            .ok_or_else(|| perr("tensor shape overflows"))?;
        let hwc = h * w * c; // factors of `count`, so no overflow
        let m = IntMatrix::from_slice(n, hwc, &self.words(count)?);
        Ok(Tensor::from_matrix(&m, h, w, c))
    }
    fn prec(&mut self) -> Result<Precision, BismoError> {
        let wbits = u32::from(self.u8()?);
        let abits = u32::from(self.u8()?);
        let flags = self.flags(2)?;
        // Range validation (1..=32, accumulator fit) is the server's
        // Precision::validate gate, which reports the typed
        // PrecisionUnsupported the client expects.
        Ok(Precision {
            wbits,
            abits,
            lsigned: flags & 1 != 0,
            rsigned: flags & 2 != 0,
        })
    }
    fn backend(&mut self) -> Result<Backend, BismoError> {
        match self.u8()? {
            0 => Ok(Backend::Engine),
            1 => Ok(Backend::Sim),
            other => Err(perr(format!("unknown backend tag {other}"))),
        }
    }
    fn spec(&mut self) -> Result<ConvSpec, BismoError> {
        let mut d = [0usize; 12];
        for slot in &mut d {
            *slot = self.u32()? as usize;
        }
        Ok(ConvSpec {
            in_h: d[0],
            in_w: d[1],
            in_c: d[2],
            out_c: d[3],
            kh: d[4],
            kw: d[5],
            stride: (d[6], d[7]),
            pad: (d[8], d[9]),
            dilation: (d[10], d[11]),
        })
    }
    fn done(&self) -> Result<(), BismoError> {
        if self.remaining() != 0 {
            return Err(perr(format!(
                "{} trailing bytes after payload",
                self.remaining()
            )));
        }
        Ok(())
    }
}

/// A parsed frame header.
#[derive(Clone, Copy, Debug)]
pub struct Header {
    pub kind: u16,
    pub req_id: u32,
    pub len: usize,
}

/// Parse and validate one 16-byte header. Magic, version and the
/// payload-length cap are all checked here, *before* any payload is
/// read — a corrupt length field cannot make the reader buffer 4 GiB.
pub fn decode_header(raw: &[u8; HEADER_BYTES]) -> Result<Header, BismoError> {
    let magic = u32::from_le_bytes(raw[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(perr(format!("bad magic {magic:#010x}")));
    }
    let version = u16::from_le_bytes(raw[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(perr(format!(
            "protocol version {version} (this side speaks {VERSION})"
        )));
    }
    let kind = u16::from_le_bytes(raw[6..8].try_into().unwrap());
    let req_id = u32::from_le_bytes(raw[8..12].try_into().unwrap());
    let len = u32::from_le_bytes(raw[12..16].try_into().unwrap()) as usize;
    if len > MAX_FRAME_BYTES {
        return Err(perr(format!(
            "payload length {len} exceeds the {MAX_FRAME_BYTES} byte cap"
        )));
    }
    Ok(Header { kind, req_id, len })
}

/// Decode one payload against its header `kind`. Strict: unknown
/// kinds, truncation, overrun, undefined flag bits and trailing bytes
/// are all typed [`BismoError::Parse`] errors.
pub fn decode_payload(kind: u16, payload: &[u8]) -> Result<Message, BismoError> {
    let mut c = Cur::new(payload);
    let msg = match kind {
        K_HELLO => Message::Request(Request::Hello {
            tenant: c.string("tenant name", MAX_TENANT_LEN)?,
        }),
        K_MATMUL => Message::Request(Request::Matmul {
            prec: c.prec()?,
            backend: c.backend()?,
            verify: c.flags(1)? != 0,
            a: c.matrix()?,
            b: c.matrix()?,
        }),
        K_PREPARE => Message::Request(Request::PrepareWeights {
            bits: u32::from(c.u8()?),
            signed: c.flags(1)? != 0,
            weights: c.matrix()?,
        }),
        K_MATMUL_PREPARED => Message::Request(Request::MatmulPrepared {
            weight_id: c.u64()?,
            prec: c.prec()?,
            backend: c.backend()?,
            verify: c.flags(1)? != 0,
            a: c.matrix()?,
        }),
        K_CONV => Message::Request(Request::Conv {
            spec: c.spec()?,
            mode: match c.u8()? {
                0 => LoweringMode::Im2col,
                1 => LoweringMode::Kn2row,
                other => return Err(perr(format!("unknown lowering tag {other}"))),
            },
            prec: c.prec()?,
            backend: c.backend()?,
            verify: c.flags(1)? != 0,
            weights: c.matrix()?,
            input: c.tensor()?,
        }),
        K_STATS => Message::Request(Request::Stats),
        K_HELLO_OK => Message::Response(Response::HelloOk {
            namespace: c.u64()?,
        }),
        K_MATMUL_OK => {
            let flags = c.flags(2)?;
            Message::Response(Response::MatmulOk {
                lhs_cached: flags & 1 != 0,
                rhs_cached: flags & 2 != 0,
                shards: c.u32()?,
                total_ns: c.u64()?,
                result: c.matrix()?,
            })
        }
        K_PREPARE_OK => Message::Response(Response::PrepareOk {
            weight_id: c.u64()?,
            resident: c.flags(1)? != 0,
        }),
        K_CONV_OK => Message::Response(Response::ConvOk {
            gemms: c.u32()?,
            weights_cached: c.flags(1)? != 0,
            output: c.tensor()?,
        }),
        K_STATS_OK => Message::Response(Response::StatsOk(WireStats {
            cache_hits: c.u64()?,
            cache_misses: c.u64()?,
            cache_insertions: c.u64()?,
            cache_evictions: c.u64()?,
            cache_entries: c.u64()?,
            cache_resident_bytes: c.u64()?,
            in_flight: c.u64()?,
            shed_total: c.u64()?,
            served_total: c.u64()?,
        })),
        K_ERROR => Message::Response(Response::Error {
            code: c.u16()?,
            retry_after_ms: c.u64()?,
            message: c.string("error message", MAX_FRAME_BYTES)?,
        }),
        other => return Err(perr(format!("unknown frame kind {other:#06x}"))),
    };
    c.done()?;
    Ok(msg)
}

/// Decode one complete frame (header + payload) from a byte slice —
/// the in-memory path the fuzz harness drives. The streaming reader in
/// the server/client splits this into [`decode_header`] +
/// [`decode_payload`] so the length check happens before buffering.
pub fn decode_frame(raw: &[u8]) -> Result<(u32, Message), BismoError> {
    if raw.len() < HEADER_BYTES {
        return Err(perr(format!("frame shorter than a header: {}", raw.len())));
    }
    let header: &[u8; HEADER_BYTES] = raw[..HEADER_BYTES].try_into().unwrap();
    let h = decode_header(header)?;
    let payload = &raw[HEADER_BYTES..];
    if payload.len() != h.len {
        return Err(perr(format!(
            "header declares {} payload bytes, frame carries {}",
            h.len,
            payload.len()
        )));
    }
    Ok((h.req_id, decode_payload(h.kind, payload)?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn roundtrip_request(req: &Request) -> Request {
        let raw = encode_request(7, req).unwrap();
        let (id, msg) = decode_frame(&raw).unwrap();
        assert_eq!(id, 7);
        match msg {
            Message::Request(r) => r,
            other => panic!("decoded as {other:?}"),
        }
    }

    fn roundtrip_response(resp: &Response) -> Response {
        let raw = encode_response(9, resp).unwrap();
        let (id, msg) = decode_frame(&raw).unwrap();
        assert_eq!(id, 9);
        match msg {
            Message::Response(r) => r,
            other => panic!("decoded as {other:?}"),
        }
    }

    #[test]
    fn every_request_kind_roundtrips() {
        let mut rng = Rng::new(0x31A);
        let a = IntMatrix::random(&mut rng, 3, 70, 3, true);
        let b = IntMatrix::random(&mut rng, 70, 4, 2, false);
        let spec = ConvSpec::simple(5, 5, 2, 3, 3, 1);
        let input = Tensor::random(&mut rng, 1, 5, 5, 2, 2, false);
        let w = spec.weights_from_fn(|_, _, _, _| rng.operand(2, true));
        let reqs = [
            Request::Hello {
                tenant: "tenant-a".into(),
            },
            Request::Matmul {
                prec: Precision::signed(3, 2),
                backend: Backend::Sim,
                verify: true,
                a: a.clone(),
                b: b.clone(),
            },
            Request::PrepareWeights {
                bits: 2,
                signed: false,
                weights: b.clone(),
            },
            Request::MatmulPrepared {
                weight_id: 0xDEAD_BEEF,
                prec: Precision::unsigned(2, 2),
                backend: Backend::Engine,
                verify: false,
                a: a.clone(),
            },
            Request::Conv {
                spec,
                mode: LoweringMode::Kn2row,
                prec: Precision {
                    wbits: 2,
                    abits: 2,
                    lsigned: false,
                    rsigned: true,
                },
                backend: Backend::Engine,
                verify: false,
                weights: w,
                input,
            },
            Request::Stats,
        ];
        for req in &reqs {
            assert_eq!(&roundtrip_request(req), req);
        }
    }

    #[test]
    fn every_response_kind_roundtrips() {
        let mut rng = Rng::new(0x31B);
        let m = IntMatrix::random(&mut rng, 2, 5, 4, true);
        let t = Tensor::random(&mut rng, 1, 3, 3, 2, 3, false);
        let resps = [
            Response::HelloOk { namespace: 42 },
            Response::MatmulOk {
                lhs_cached: false,
                rhs_cached: true,
                shards: 4,
                total_ns: 123_456,
                result: m,
            },
            Response::PrepareOk {
                weight_id: 7,
                resident: true,
            },
            Response::ConvOk {
                gemms: 9,
                weights_cached: false,
                output: t,
            },
            Response::StatsOk(WireStats {
                cache_hits: 1,
                cache_misses: 2,
                cache_insertions: 3,
                cache_evictions: 4,
                cache_entries: 5,
                cache_resident_bytes: 6,
                in_flight: 7,
                shed_total: 8,
                served_total: 9,
            }),
            Response::Error {
                code: 13,
                retry_after_ms: 25,
                message: "overloaded: retry after 25 ms".into(),
            },
        ];
        for resp in &resps {
            assert_eq!(&roundtrip_response(resp), resp);
        }
    }

    #[test]
    fn typed_errors_roundtrip_through_error_frames() {
        let errs = [
            BismoError::InvalidConfig("zero workers".into()),
            BismoError::ShapeMismatch("2x3 · 4x2".into()),
            BismoError::PrecisionUnsupported("wbits 0".into()),
            BismoError::CapacityExceeded("quota".into()),
            BismoError::VerifyFailed("mismatch at (0,0)".into()),
            BismoError::ServiceShutdown,
            BismoError::Overloaded { retry_after_ms: 40 },
        ];
        for e in errs {
            let resp = roundtrip_response(&error_frame(&e));
            let back = resp.to_error().unwrap();
            assert_eq!(back.kind(), e.kind(), "{e:?}");
            if let BismoError::Overloaded { retry_after_ms } = back {
                assert_eq!(retry_after_ms, 40);
            }
        }
    }

    #[test]
    fn corrupt_frames_fail_typed() {
        let good = encode_request(
            1,
            &Request::Matmul {
                prec: Precision::unsigned(2, 2),
                backend: Backend::Engine,
                verify: false,
                a: IntMatrix::from_slice(1, 2, &[1, 2]),
                b: IntMatrix::from_slice(2, 1, &[3, 4]),
            },
        )
        .unwrap();
        // Bad magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(
            decode_frame(&bad),
            Err(BismoError::Parse(ref m)) if m.contains("magic")
        ));
        // Future protocol version.
        let mut bad = good.clone();
        bad[4] = 99;
        assert!(matches!(
            decode_frame(&bad),
            Err(BismoError::Parse(ref m)) if m.contains("version")
        ));
        // Truncated payload.
        let bad = &good[..good.len() - 3];
        assert!(decode_frame(bad).is_err());
        // Trailing garbage.
        let mut bad = good.clone();
        bad.extend_from_slice(&[0, 1, 2]);
        assert!(decode_frame(&bad).is_err());
        // Unknown kind.
        let mut bad = good.clone();
        bad[6] = 0x77;
        assert!(matches!(
            decode_frame(&bad),
            Err(BismoError::Parse(ref m)) if m.contains("kind")
        ));
        // Shorter than a header.
        assert!(decode_frame(&good[..7]).is_err());
    }

    #[test]
    fn hostile_lengths_are_rejected_before_allocation() {
        // A matmul frame whose matrix header claims u32::MAX × u32::MAX
        // elements with no backing bytes: must fail Parse, not OOM.
        let mut e = Enc::new();
        e.prec(Precision::unsigned(2, 2)).unwrap();
        e.backend(Backend::Engine);
        e.u8(0);
        e.u32(u32::MAX);
        e.u32(u32::MAX);
        let raw = frame(K_MATMUL, 1, e.buf).unwrap();
        let err = decode_frame(&raw).unwrap_err();
        assert!(matches!(err, BismoError::Parse(_)), "{err:?}");
        // Header payload length beyond the cap is rejected at the
        // header, before any payload byte is consumed.
        let mut hdr = [0u8; HEADER_BYTES];
        hdr[0..4].copy_from_slice(&MAGIC.to_le_bytes());
        hdr[4..6].copy_from_slice(&VERSION.to_le_bytes());
        hdr[6..8].copy_from_slice(&K_STATS.to_le_bytes());
        hdr[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(
            decode_header(&hdr),
            Err(BismoError::Parse(ref m)) if m.contains("cap")
        ));
    }

    #[test]
    fn oversized_tenant_name_is_rejected() {
        let raw = encode_request(
            0,
            &Request::Hello {
                tenant: "x".repeat(MAX_TENANT_LEN + 1),
            },
        )
        .unwrap();
        assert!(matches!(
            decode_frame(&raw),
            Err(BismoError::Parse(ref m)) if m.contains("cap")
        ));
    }
}
