//! Table/figure formatting used by the benchmark harness and CLI.

/// A simple fixed-width text table with a title, printed in the style
/// the benches use to mirror the paper's tables.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: &[String]) -> &mut Self {
        assert_eq!(fields.len(), self.headers.len(), "table row width");
        self.rows.push(fields.to_vec());
        self
    }

    pub fn rowf(&mut self, fields: &[&dyn std::fmt::Display]) -> &mut Self {
        let v: Vec<String> = fields.iter().map(|f| format!("{f}")).collect();
        self.row(&v)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, f) in widths.iter_mut().zip(row) {
                *w = (*w).max(f.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |fields: &[String], widths: &[usize]| -> String {
            fields
                .iter()
                .zip(widths)
                .map(|(f, w)| format!("{f:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Render a Fig. 5-style ASCII timeline from simulator trace events:
/// one lane per stage, `#` = running, `.` = stalled in a Wait, with
/// time scaled to `width` columns.
pub fn render_timeline(events: &[crate::sim::TraceEvent], width: usize) -> String {
    use crate::isa::Stage;
    let total = events.iter().map(|e| e.end).max().unwrap_or(0).max(1);
    let scale = |t: u64| ((t as f64 / total as f64) * width as f64) as usize;
    let mut out = String::new();
    for stage in Stage::ALL {
        let mut lane = vec![' '; width + 1];
        for e in events.iter().filter(|e| e.stage == stage) {
            let (a, b) = (scale(e.start), scale(e.end).max(scale(e.start) + 1));
            let ch = if e.stalled { '.' } else { '#' };
            for c in lane.iter_mut().take(b.min(width + 1)).skip(a) {
                // Running work wins over stall marks at the same column.
                if *c != '#' {
                    *c = ch;
                }
            }
        }
        out.push_str(&format!("{:>7} |", stage.name()));
        out.extend(lane);
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>7} +{}> {} cycles   (# running, . stalled)\n",
        "",
        "-".repeat(width),
        total
    ));
    out
}

/// Format a float with `d` decimals (bench helper).
pub fn f(v: f64, d: usize) -> String {
    format!("{v:.d$}")
}

/// Format a percentage.
pub fn pct(v: f64) -> String {
    format!("{:.1}%", v * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(&["1".into(), "2".into()]);
        t.rowf(&[&100, &"x"]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("  a  bbbb"));
        assert!(s.lines().count() == 5);
        // Right-aligned columns.
        assert!(s.contains("100     x"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(pct(0.938), "93.8%");
    }

    #[test]
    fn timeline_lanes() {
        use crate::isa::Stage;
        use crate::sim::TraceEvent;
        let events = vec![
            TraceEvent {
                stage: Stage::Fetch,
                label: "F1 RunFetch".into(),
                start: 0,
                end: 50,
                stalled: false,
            },
            TraceEvent {
                stage: Stage::Execute,
                label: "E1 Wait".into(),
                start: 0,
                end: 50,
                stalled: true,
            },
            TraceEvent {
                stage: Stage::Execute,
                label: "E2 RunExecute".into(),
                start: 50,
                end: 100,
                stalled: false,
            },
        ];
        let s = render_timeline(&events, 40);
        assert!(s.contains("fetch"));
        assert!(s.contains("execute"));
        assert!(s.contains("100 cycles"));
        // Execute lane has both a stalled and a running phase.
        let exec_lane = s.lines().find(|l| l.contains("execute")).unwrap();
        assert!(exec_lane.contains('.') && exec_lane.contains('#'));
    }
}
