//! Bit-plane decomposition: the data representation of Algorithm 1.

use super::int::IntMatrix;
use super::plane_sign;
use crate::simd::{self, DispatchTier};
use crate::util::ceil_div;

/// Inclusive value range of a `bits`-wide operand.
fn operand_range(bits: u32, signed: bool) -> (i64, i64) {
    if signed {
        (-(1i64 << (bits - 1)), (1i64 << (bits - 1)) - 1)
    } else {
        (0, ((1u128 << bits) - 1) as i64)
    }
}

/// Reproduce the exact packing panic for the first out-of-range value
/// in `chunk` (called only after `simd::pack_chunk` reports one).
fn bad_entry_panic(chunk: &[i64], lo: i64, hi: i64, bits: u32, signed: bool) -> ! {
    let v = chunk.iter().copied().find(|&v| v < lo || v > hi).unwrap();
    if bits == 1 {
        panic!("entry {v} does not fit 1-bit");
    }
    panic!(
        "matrix entry {v} does not fit {} {}-bit",
        if signed { "signed" } else { "unsigned" },
        bits
    );
}

/// A matrix decomposed into `bits` binary bit-planes, each bit-packed
/// into `u64` words along the columns (`k`) dimension.
///
/// For an operand matrix `M` of width `bits`:
///
/// ```text
/// M = Σ_{i=0}^{bits-1}  plane_sign(i) · 2^i · M[i]
/// ```
///
/// where `M[i]` is binary and `plane_sign` is −1 for the MSB plane of a
/// signed operand (two's complement), +1 otherwise. Storage is
/// plane-major, then row-major: `planes[i][row][word]` flattened.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitSerialMatrix {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    pub signed: bool,
    /// `ceil(cols / 64)` — words per packed row.
    pub words_per_row: usize,
    data: Vec<u64>,
}

impl BitSerialMatrix {
    /// All-zero decomposition.
    pub fn zeros(rows: usize, cols: usize, bits: u32, signed: bool) -> Self {
        assert!(bits >= 1 && bits <= 32, "1..=32 bit operands supported");
        let words_per_row = ceil_div(cols as u64, 64) as usize;
        BitSerialMatrix {
            rows,
            cols,
            bits,
            signed,
            words_per_row,
            data: vec![0; bits as usize * rows * words_per_row],
        }
    }

    /// Decompose an integer matrix. Panics if any entry does not fit the
    /// requested precision (validated inline — single pass). Packs with
    /// the process-wide [`DispatchTier`]; every tier produces
    /// word-identical planes (property-tested in
    /// `rust/tests/simd_dispatch.rs`).
    pub fn from_int(m: &IntMatrix, bits: u32, signed: bool) -> Self {
        Self::from_int_tier(m, bits, signed, DispatchTier::active())
    }

    /// [`BitSerialMatrix::from_int`] pinned to an explicit
    /// [`DispatchTier`] — the packing half of the forced-dispatch test
    /// matrix and the cross-tier fuzz mode.
    pub fn from_int_tier(m: &IntMatrix, bits: u32, signed: bool, tier: DispatchTier) -> Self {
        let (lo, hi) = operand_range(bits, signed);
        let mut out = Self::zeros(m.rows, m.cols, bits, signed);
        // Word-wise packing: 64 columns per plane at a time through the
        // shared chunk packer (scalar set-bit walk or the AVX2
        // sign-bit-movemask path) — this is on the coordinator's
        // request path.
        let mut words = vec![0u64; bits as usize];
        for r in 0..m.rows {
            let row = m.row(r);
            for (wi, colchunk) in row.chunks(64).enumerate() {
                if !simd::pack_chunk(tier, colchunk, lo, hi, &mut words) {
                    bad_entry_panic(colchunk, lo, hi, bits, signed);
                }
                for (i, &w) in words.iter().enumerate() {
                    let idx = out.idx(i as u32, r, wi);
                    out.data[idx] = w;
                }
            }
        }
        out
    }

    /// Decompose the *transpose* of `m` without materializing it:
    /// produces exactly `from_int(&m.transpose(), ...)` but in one pass
    /// over `m` (the coordinator packs the RHS this way — fusing the
    /// transpose saves a full 16-byte-per-element round trip).
    ///
    /// Stays scalar on every tier: it packs *along* `m.rows` (output
    /// bit position `r % 64` varies per input row, not per input
    /// column), so the 64-column chunk packer's access pattern does
    /// not apply. The fuzz differential mode still cross-checks it
    /// against scalar-packed transposes.
    pub fn from_int_transposed(m: &IntMatrix, bits: u32, signed: bool) -> Self {
        let (lo, hi) = operand_range(bits, signed);
        let mask = ((1u128 << bits) - 1) as u64;
        // Output: rows = m.cols, cols = m.rows (packed along m.rows).
        let mut out = Self::zeros(m.cols, m.rows, bits, signed);
        let wpr = out.words_per_row;
        for r in 0..m.rows {
            let (word_i, bitpos) = (r / 64, (r % 64) as u32);
            let row = m.row(r);
            for (c, &v) in row.iter().enumerate() {
                assert!(
                    v >= lo && v <= hi,
                    "matrix entry {v} does not fit {} {}-bit",
                    if signed { "signed" } else { "unsigned" },
                    bits
                );
                let mut p = (v as u64) & mask;
                while p != 0 {
                    let plane = p.trailing_zeros() as usize;
                    out.data[(plane * out.rows + c) * wpr + word_i] |= 1u64 << bitpos;
                    p &= p - 1;
                }
            }
        }
        out
    }

    /// Decompose a *virtual* matrix given by a value function, without
    /// materializing it: produces exactly
    /// `from_int(&IntMatrix::from_fn(rows, cols, f), ...)` but never
    /// allocates the dense `i64` matrix. This is the zero-copy hook the
    /// convolution lowering layer packs its im2col patch matrix
    /// through ([`crate::lowering::pack_im2col`]): the patch matrix is
    /// `kh·kw` times larger than the input tensor, so sampling it
    /// per-element straight into packed planes skips the largest
    /// allocation on the conv hot path. Word-wise packing, same as
    /// [`BitSerialMatrix::from_int`] (and the same [`DispatchTier`]);
    /// panics if any produced value does not fit the requested
    /// precision.
    pub fn from_int_fn<F: FnMut(usize, usize) -> i64>(
        rows: usize,
        cols: usize,
        bits: u32,
        signed: bool,
        f: F,
    ) -> Self {
        Self::from_int_fn_tier(rows, cols, bits, signed, DispatchTier::active(), f)
    }

    /// [`BitSerialMatrix::from_int_fn`] pinned to an explicit
    /// [`DispatchTier`]. The value function is sampled a whole
    /// 64-column chunk at a time into a stack buffer before the chunk
    /// is validated and packed, so `f` may be called for a few columns
    /// past the first out-of-range value before the panic fires.
    pub fn from_int_fn_tier<F: FnMut(usize, usize) -> i64>(
        rows: usize,
        cols: usize,
        bits: u32,
        signed: bool,
        tier: DispatchTier,
        mut f: F,
    ) -> Self {
        let (lo, hi) = operand_range(bits, signed);
        let mut out = Self::zeros(rows, cols, bits, signed);
        let mut words = vec![0u64; bits as usize];
        let mut vals = [0i64; 64];
        for r in 0..rows {
            for (wi, chunk) in (0..cols).step_by(64).enumerate() {
                let len = (cols - chunk).min(64);
                for (bi, slot) in vals[..len].iter_mut().enumerate() {
                    *slot = f(r, chunk + bi);
                }
                if !simd::pack_chunk(tier, &vals[..len], lo, hi, &mut words) {
                    bad_entry_panic(&vals[..len], lo, hi, bits, signed);
                }
                for (i, &w) in words.iter().enumerate() {
                    let idx = out.idx(i as u32, r, wi);
                    out.data[idx] = w;
                }
            }
        }
        out
    }

    /// Recompose to integers — exact inverse of [`BitSerialMatrix::from_int`].
    pub fn to_int(&self) -> IntMatrix {
        IntMatrix::from_fn(self.rows, self.cols, |r, c| {
            let mut v = 0i64;
            for i in 0..self.bits {
                if self.get_bit(i, r, c) {
                    v += plane_sign(i, self.bits, self.signed) * (1i64 << i);
                }
            }
            v
        })
    }

    #[inline]
    fn idx(&self, plane: u32, row: usize, word: usize) -> usize {
        debug_assert!(plane < self.bits && row < self.rows && word < self.words_per_row);
        (plane as usize * self.rows + row) * self.words_per_row + word
    }

    /// One packed row of one plane.
    #[inline]
    pub fn plane_row(&self, plane: u32, row: usize) -> &[u64] {
        let base = self.idx(plane, row, 0);
        &self.data[base..base + self.words_per_row]
    }

    #[inline]
    pub fn get_bit(&self, plane: u32, row: usize, col: usize) -> bool {
        let w = self.idx(plane, row, col / 64);
        (self.data[w] >> (col % 64)) & 1 == 1
    }

    #[inline]
    pub fn set_bit(&mut self, plane: u32, row: usize, col: usize, v: bool) {
        let w = self.idx(plane, row, col / 64);
        let mask = 1u64 << (col % 64);
        if v {
            self.data[w] |= mask;
        } else {
            self.data[w] &= !mask;
        }
    }

    /// Signed weight of plane `i`: `plane_sign(i) · 2^i`.
    #[inline]
    pub fn plane_weight(&self, i: u32) -> i64 {
        plane_sign(i, self.bits, self.signed) * (1i64 << i)
    }

    /// The contiguous packed slice of one whole plane: all rows,
    /// row-major (`rows · words_per_row` words). The tiled kernel engine
    /// packs its tiles from this view; padding bits above `cols` are
    /// always zero.
    #[inline]
    pub fn plane_slice(&self, plane: u32) -> &[u64] {
        let len = self.rows * self.words_per_row;
        let base = plane as usize * len;
        &self.data[base..base + len]
    }

    /// Zero-copy view of a contiguous row block within one plane:
    /// `rows.len() · words_per_row` packed words, row-major. This is
    /// the block view the partition layer's shard packing reads — a
    /// row-range of one plane is contiguous, so sharding an operand
    /// along its rows costs no copy at all.
    #[inline]
    pub fn plane_rows(&self, plane: u32, rows: std::ops::Range<usize>) -> &[u64] {
        debug_assert!(plane < self.bits);
        assert!(rows.end <= self.rows, "row block out of range");
        let base = (plane as usize * self.rows + rows.start) * self.words_per_row;
        &self.data[base..base + rows.len() * self.words_per_row]
    }

    /// Owned packed sub-matrix of a row block (all planes): exactly
    /// `from_int` of the corresponding row slice of the source matrix,
    /// but produced by per-plane `memcpy` of the packed words — no
    /// re-decomposition. The simulator backend executes shards of a
    /// cached packing through this view.
    pub fn row_block(&self, rows: std::ops::Range<usize>) -> BitSerialMatrix {
        assert!(rows.end <= self.rows, "row block out of range");
        let mut out = Self::zeros(rows.len(), self.cols, self.bits, self.signed);
        for p in 0..self.bits {
            let src = self.plane_rows(p, rows.clone());
            let base = p as usize * out.rows * out.words_per_row;
            out.data[base..base + src.len()].copy_from_slice(src);
        }
        out
    }

    /// Fraction of set bits in plane `i` (used by the sparse bit-skip
    /// scheduler extension). Single pass over the contiguous plane
    /// slice.
    pub fn plane_density(&self, i: u32) -> f64 {
        let ones: u64 = self
            .plane_slice(i)
            .iter()
            .map(|w| w.count_ones() as u64)
            .sum();
        ones as f64 / (self.rows * self.cols).max(1) as f64
    }

    /// Is plane `i` entirely zero? (bit-skip fast path) Single pass over
    /// the contiguous plane slice.
    pub fn plane_is_zero(&self, i: u32) -> bool {
        self.plane_slice(i).iter().all(|&w| w == 0)
    }

    /// Indices of planes that are not entirely zero — the shared
    /// zero-plane filter used by both the scheduler's bit-skip extension
    /// and the tiled software kernel.
    pub fn nonzero_planes(&self) -> Vec<u32> {
        (0..self.bits).filter(|&i| !self.plane_is_zero(i)).collect()
    }

    /// Binary dot product between a packed row of `self` and a packed row
    /// of `other` (both along k): AND + popcount — exactly what one DPU
    /// computes, at word granularity.
    pub fn binary_row_dot(&self, plane: u32, row: usize, other: &BitSerialMatrix, oplane: u32, orow: usize) -> u64 {
        debug_assert_eq!(self.cols, other.cols, "k mismatch");
        let a = self.plane_row(plane, row);
        let b = other.plane_row(oplane, orow);
        a.iter()
            .zip(b.iter())
            .map(|(&x, &y)| (x & y).count_ones() as u64)
            .sum()
    }

    /// Total payload size in bytes of the packed representation.
    pub fn packed_bytes(&self) -> usize {
        self.data.len() * 8
    }

    /// Raw plane data (plane-major, row-major, little-endian words).
    pub fn raw(&self) -> &[u64] {
        &self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{property_sweep, Rng};

    #[test]
    fn from_int_transposed_equals_transpose_then_pack() {
        property_sweep(0x7A5, 20, |rng, _| {
            let rows = rng.index(70) + 1;
            let cols = rng.index(70) + 1;
            let bits = rng.index(8) as u32 + 1;
            let signed = rng.chance(0.5);
            let m = IntMatrix::random(rng, rows, cols, bits, signed);
            let fused = BitSerialMatrix::from_int_transposed(&m, bits, signed);
            let naive = BitSerialMatrix::from_int(&m.transpose(), bits, signed);
            assert_eq!(fused, naive);
        });
    }

    #[test]
    fn from_int_fn_equals_materialize_then_pack() {
        property_sweep(0xF7, 20, |rng, _| {
            let rows = rng.index(12) + 1;
            let cols = rng.index(150) + 1; // frequently crosses word boundaries
            let bits = rng.index(8) as u32 + 1;
            let signed = rng.chance(0.5);
            let m = IntMatrix::random(rng, rows, cols, bits, signed);
            let virt = BitSerialMatrix::from_int_fn(rows, cols, bits, signed, |r, c| m.get(r, c));
            assert_eq!(virt, BitSerialMatrix::from_int(&m, bits, signed));
        });
    }

    #[test]
    fn roundtrip_unsigned() {
        let mut rng = Rng::new(1);
        for bits in [1u32, 2, 3, 4, 7, 8, 16] {
            let m = IntMatrix::random(&mut rng, 5, 9, bits, false);
            let bs = BitSerialMatrix::from_int(&m, bits, false);
            assert_eq!(bs.to_int(), m, "bits={bits}");
        }
    }

    #[test]
    fn roundtrip_signed() {
        let mut rng = Rng::new(2);
        for bits in [1u32, 2, 3, 4, 7, 8, 16] {
            let m = IntMatrix::random(&mut rng, 6, 5, bits, true);
            let bs = BitSerialMatrix::from_int(&m, bits, true);
            assert_eq!(bs.to_int(), m, "bits={bits}");
        }
    }

    #[test]
    fn paper_fig1_planes() {
        // L = [[2,0],[1,3]] = 2·[[1,0],[0,1]] + 1·[[0,0],[1,1]]
        let l = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
        let bs = BitSerialMatrix::from_int(&l, 2, false);
        // plane 0 (LSB): [[0,0],[1,1]]
        assert!(!bs.get_bit(0, 0, 0) && !bs.get_bit(0, 0, 1));
        assert!(bs.get_bit(0, 1, 0) && bs.get_bit(0, 1, 1));
        // plane 1: [[1,0],[0,1]]
        assert!(bs.get_bit(1, 0, 0) && !bs.get_bit(1, 0, 1));
        assert!(!bs.get_bit(1, 1, 0) && bs.get_bit(1, 1, 1));
        assert_eq!(bs.plane_weight(0), 1);
        assert_eq!(bs.plane_weight(1), 2);
    }

    #[test]
    fn signed_msb_weight_negative() {
        let m = IntMatrix::from_slice(1, 1, &[-8]);
        let bs = BitSerialMatrix::from_int(&m, 4, true);
        assert_eq!(bs.plane_weight(3), -8);
        assert!(bs.get_bit(3, 0, 0));
        assert!(!bs.get_bit(0, 0, 0));
        assert_eq!(bs.to_int().get(0, 0), -8);
    }

    #[test]
    fn weighted_plane_sum_reconstructs() {
        // Property: Σ_i weight(i)·plane_i == original, across shapes.
        property_sweep(0xB15, 25, |rng, _| {
            let rows = rng.index(6) + 1;
            let cols = rng.index(130) + 1;
            let bits = rng.index(8) as u32 + 1;
            let signed = rng.chance(0.5);
            let m = IntMatrix::random(rng, rows, cols, bits, signed);
            let bs = BitSerialMatrix::from_int(&m, bits, signed);
            let mut acc = IntMatrix::zeros(rows, cols);
            for i in 0..bits {
                let w = bs.plane_weight(i);
                for r in 0..rows {
                    for c in 0..cols {
                        if bs.get_bit(i, r, c) {
                            acc.set(r, c, acc.get(r, c) + w);
                        }
                    }
                }
            }
            assert_eq!(acc, m);
        });
    }

    #[test]
    fn binary_row_dot_matches_naive() {
        property_sweep(0xD07, 20, |rng, _| {
            let k = rng.index(200) + 1;
            let a = IntMatrix::random(rng, 1, k, 1, false);
            let b = IntMatrix::random(rng, 1, k, 1, false);
            let ab = BitSerialMatrix::from_int(&a, 1, false);
            let bb = BitSerialMatrix::from_int(&b, 1, false);
            let naive: i64 = (0..k).map(|i| a.get(0, i) * b.get(0, i)).sum();
            assert_eq!(ab.binary_row_dot(0, 0, &bb, 0, 0), naive as u64);
        });
    }

    #[test]
    fn density_and_zero_planes() {
        let m = IntMatrix::from_slice(2, 2, &[1, 1, 1, 1]); // only LSB set
        let bs = BitSerialMatrix::from_int(&m, 3, false);
        assert_eq!(bs.plane_density(0), 1.0);
        assert_eq!(bs.plane_density(1), 0.0);
        assert!(bs.plane_is_zero(2));
        assert!(!bs.plane_is_zero(0));
        assert_eq!(bs.nonzero_planes(), vec![0]);
    }

    #[test]
    fn plane_slice_is_rows_concatenated() {
        property_sweep(0x51C, 15, |rng, _| {
            let rows = rng.index(9) + 1;
            let cols = rng.index(150) + 1;
            let bits = rng.index(6) as u32 + 1;
            let m = IntMatrix::random(rng, rows, cols, bits, false);
            let bs = BitSerialMatrix::from_int(&m, bits, false);
            for i in 0..bits {
                let slice = bs.plane_slice(i);
                assert_eq!(slice.len(), rows * bs.words_per_row);
                for r in 0..rows {
                    assert_eq!(
                        &slice[r * bs.words_per_row..(r + 1) * bs.words_per_row],
                        bs.plane_row(i, r)
                    );
                }
            }
        });
    }

    #[test]
    fn nonzero_planes_match_per_plane_checks() {
        property_sweep(0x2E0, 15, |rng, _| {
            let rows = rng.index(7) + 1;
            let cols = rng.index(100) + 1;
            let bits = rng.index(8) as u32 + 1;
            let signed = rng.chance(0.5);
            // Bias toward sparse bit patterns so some planes are empty.
            let m = IntMatrix::from_fn(rows, cols, |_, _| {
                if rng.chance(0.6) {
                    0
                } else {
                    rng.operand(bits, signed) & 0b11
                }
            });
            let bs = BitSerialMatrix::from_int(&m, bits.max(3), signed);
            let expect: Vec<u32> = (0..bs.bits).filter(|&i| !bs.plane_is_zero(i)).collect();
            assert_eq!(bs.nonzero_planes(), expect);
        });
    }

    #[test]
    fn packing_crosses_word_boundaries() {
        // 70 columns forces two words per row.
        let m = IntMatrix::from_fn(1, 70, |_, c| (c >= 63) as i64);
        let bs = BitSerialMatrix::from_int(&m, 1, false);
        assert_eq!(bs.words_per_row, 2);
        assert!(!bs.get_bit(0, 0, 62));
        assert!(bs.get_bit(0, 0, 63));
        assert!(bs.get_bit(0, 0, 69));
        assert_eq!(bs.to_int(), m);
    }

    #[test]
    fn plane_rows_is_zero_copy_view_of_row_block() {
        property_sweep(0x6B0C, 12, |rng, _| {
            let rows = rng.index(12) + 2;
            let cols = rng.index(140) + 1;
            let bits = rng.index(5) as u32 + 1;
            let m = IntMatrix::random(rng, rows, cols, bits, false);
            let bs = BitSerialMatrix::from_int(&m, bits, false);
            let lo = rng.index(rows);
            let hi = lo + rng.index(rows - lo) + 1;
            for p in 0..bits {
                let view = bs.plane_rows(p, lo..hi);
                assert_eq!(view.len(), (hi - lo) * bs.words_per_row);
                for (i, r) in (lo..hi).enumerate() {
                    assert_eq!(
                        &view[i * bs.words_per_row..(i + 1) * bs.words_per_row],
                        bs.plane_row(p, r)
                    );
                }
            }
        });
    }

    #[test]
    fn row_block_equals_repacking_the_slice() {
        property_sweep(0xB10C, 12, |rng, _| {
            let rows = rng.index(12) + 2;
            let cols = rng.index(140) + 1;
            let bits = rng.index(5) as u32 + 1;
            let signed = rng.chance(0.5);
            let m = IntMatrix::random(rng, rows, cols, bits, signed);
            let bs = BitSerialMatrix::from_int(&m, bits, signed);
            let lo = rng.index(rows);
            let hi = lo + rng.index(rows - lo) + 1;
            let block = bs.row_block(lo..hi);
            let slice = IntMatrix::from_fn(hi - lo, cols, |r, c| m.get(lo + r, c));
            assert_eq!(block, BitSerialMatrix::from_int(&slice, bits, signed));
        });
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn from_int_checks_range() {
        let m = IntMatrix::from_slice(1, 1, &[16]);
        let _ = BitSerialMatrix::from_int(&m, 4, false);
    }
}
