//! Main-memory image and the bit-packed DRAM layout the overlay fetches.
//!
//! The paper (§IV-B) assumes operands "are stored in DRAM using a
//! bit-packed data layout, and that one matrix is transposed". The layout
//! implemented here is:
//!
//! * operands: plane-major → row-major → `D_k`-bit chunks, each chunk
//!   padded to whole 64-bit words. The LHS is stored `m×k`; the RHS is
//!   stored *transposed* (`n×k`) so both sides stream along `k`.
//! * results: row-major `A/8`-byte little-endian accumulators (`A` = 32).
//!
//! [`DramImage`] is a plain byte array with a small endian-aware access
//! API; all timing is modelled in `sim::dram`, not here.

use super::bitserial::BitSerialMatrix;
use crate::util::ceil_div;

/// Byte-addressable main-memory image.
#[derive(Clone, Debug)]
pub struct DramImage {
    bytes: Vec<u8>,
}

impl DramImage {
    pub fn new(size: usize) -> Self {
        DramImage {
            bytes: vec![0; size],
        }
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    pub fn read_u64(&self, addr: u64) -> u64 {
        let a = addr as usize;
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.bytes[a..a + 8]);
        u64::from_le_bytes(b)
    }

    /// Bounds-checked [`DramImage::read_u64`]: the stage units use this
    /// on program-derived addresses so an out-of-range DMA becomes a
    /// typed stage fault instead of a slice-index panic.
    pub fn try_read_u64(&self, addr: u64) -> Result<u64, String> {
        match addr.checked_add(8) {
            Some(end) if end <= self.bytes.len() as u64 => Ok(self.read_u64(addr)),
            _ => Err(format!(
                "DRAM read of 8 bytes at {:#x} out of range ({} byte image)",
                addr,
                self.bytes.len()
            )),
        }
    }

    /// Bounds-checked [`DramImage::write_i32`] (see
    /// [`DramImage::try_read_u64`]).
    pub fn try_write_i32(&mut self, addr: u64, v: i32) -> Result<(), String> {
        match addr.checked_add(4) {
            Some(end) if end <= self.bytes.len() as u64 => {
                self.write_i32(addr, v);
                Ok(())
            }
            _ => Err(format!(
                "DRAM write of 4 bytes at {:#x} out of range ({} byte image)",
                addr,
                self.bytes.len()
            )),
        }
    }

    pub fn write_u64(&mut self, addr: u64, v: u64) {
        let a = addr as usize;
        self.bytes[a..a + 8].copy_from_slice(&v.to_le_bytes());
    }

    pub fn read_i32(&self, addr: u64) -> i32 {
        let a = addr as usize;
        let mut b = [0u8; 4];
        b.copy_from_slice(&self.bytes[a..a + 4]);
        i32::from_le_bytes(b)
    }

    pub fn write_i32(&mut self, addr: u64, v: i32) {
        let a = addr as usize;
        self.bytes[a..a + 4].copy_from_slice(&v.to_le_bytes());
    }

    pub fn read_bytes(&self, addr: u64, len: usize) -> &[u8] {
        &self.bytes[addr as usize..addr as usize + len]
    }

    /// The full backing store (snapshot capture).
    pub fn as_bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Rebuild an image from raw bytes (snapshot restore).
    pub fn from_bytes(bytes: Vec<u8>) -> Self {
        DramImage { bytes }
    }
}

/// Placement of one bit-serial operand in DRAM.
///
/// Addressing: `addr(plane, row, chunk) = base + ((plane·rows + row)·cpr
/// + chunk) · wpc · 8` where `cpr` = chunks per row and `wpc` = 64-bit
/// words per chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OperandLayout {
    /// Base byte address (must be 8-byte aligned).
    pub base: u64,
    /// Logical rows of the stored matrix (for the RHS this is `n`).
    pub rows: usize,
    /// Logical columns = the shared `k` dimension.
    pub cols: usize,
    /// Bit-planes stored.
    pub bits: u32,
    /// Chunk width in bits (= the overlay's `D_k`).
    pub dk: u32,
    /// Chunks per row: `ceil(cols / dk)`.
    pub chunks_per_row: usize,
    /// 64-bit words per chunk: `ceil(dk / 64)`.
    pub words_per_chunk: usize,
}

impl OperandLayout {
    pub fn new(base: u64, rows: usize, cols: usize, bits: u32, dk: u32) -> Self {
        assert_eq!(base % 8, 0, "operand base must be 8-byte aligned");
        OperandLayout {
            base,
            rows,
            cols,
            bits,
            dk,
            chunks_per_row: ceil_div(cols as u64, dk as u64) as usize,
            words_per_chunk: ceil_div(dk as u64, 64) as usize,
        }
    }

    /// Byte address of a (plane, row, chunk) triple.
    pub fn addr(&self, plane: u32, row: usize, chunk: usize) -> u64 {
        debug_assert!(plane < self.bits && row < self.rows && chunk < self.chunks_per_row);
        let idx = (plane as u64 * self.rows as u64 + row as u64) * self.chunks_per_row as u64
            + chunk as u64;
        self.base + idx * self.words_per_chunk as u64 * 8
    }

    /// Bytes of one packed row of one plane.
    pub fn row_bytes(&self) -> u64 {
        self.chunks_per_row as u64 * self.words_per_chunk as u64 * 8
    }

    /// Bytes of one full plane.
    pub fn plane_bytes(&self) -> u64 {
        self.rows as u64 * self.row_bytes()
    }

    /// Total footprint in bytes.
    pub fn total_bytes(&self) -> u64 {
        self.bits as u64 * self.plane_bytes()
    }

    /// Serialize a decomposed matrix into the image at this layout.
    pub fn store(&self, img: &mut DramImage, m: &BitSerialMatrix) {
        assert_eq!(m.rows, self.rows);
        assert_eq!(m.cols, self.cols);
        assert_eq!(m.bits, self.bits);
        for p in 0..self.bits {
            for r in 0..self.rows {
                let row = m.plane_row(p, r);
                for ch in 0..self.chunks_per_row {
                    let a = self.addr(p, r, ch);
                    for w in 0..self.words_per_chunk {
                        // Chunk `ch` covers matrix bit-columns
                        // [ch·dk, (ch+1)·dk); word w within it covers 64
                        // of those, which may straddle source words only
                        // when dk < 64 — excluded by dk >= 64 elsewhere,
                        // but handle the general aligned case.
                        let src_word = (ch * self.dk as usize) / 64 + w;
                        let v = row.get(src_word).copied().unwrap_or(0);
                        img.write_u64(a + w as u64 * 8, v);
                    }
                }
            }
        }
    }

    /// Read back one chunk's words (for tests and the fetch stage).
    pub fn load_chunk(&self, img: &DramImage, plane: u32, row: usize, chunk: usize) -> Vec<u64> {
        let a = self.addr(plane, row, chunk);
        (0..self.words_per_chunk)
            .map(|w| img.read_u64(a + w as u64 * 8))
            .collect()
    }
}

/// Placement of the `m×n` result matrix (32-bit accumulators, row-major).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ResultLayout {
    pub base: u64,
    pub rows: usize,
    pub cols: usize,
}

impl ResultLayout {
    pub const ACC_BYTES: u64 = 4;

    pub fn new(base: u64, rows: usize, cols: usize) -> Self {
        assert_eq!(base % 4, 0, "result base must be 4-byte aligned");
        ResultLayout { base, rows, cols }
    }

    pub fn addr(&self, r: usize, c: usize) -> u64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.base + (r as u64 * self.cols as u64 + c as u64) * Self::ACC_BYTES
    }

    pub fn total_bytes(&self) -> u64 {
        self.rows as u64 * self.cols as u64 * Self::ACC_BYTES
    }

    /// Read the full result back as an [`super::IntMatrix`].
    pub fn load(&self, img: &DramImage) -> super::IntMatrix {
        super::IntMatrix::from_fn(self.rows, self.cols, |r, c| {
            img.read_i32(self.addr(r, c)) as i64
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bitmatrix::IntMatrix;
    use crate::util::Rng;

    #[test]
    fn image_rw_roundtrip() {
        let mut img = DramImage::new(64);
        img.write_u64(8, 0xDEAD_BEEF_0123_4567);
        assert_eq!(img.read_u64(8), 0xDEAD_BEEF_0123_4567);
        img.write_i32(4, -42);
        assert_eq!(img.read_i32(4), -42);
    }

    #[test]
    fn operand_layout_addressing_disjoint_and_dense() {
        let lay = OperandLayout::new(64, 3, 200, 2, 64);
        assert_eq!(lay.chunks_per_row, 4);
        assert_eq!(lay.words_per_chunk, 1);
        assert_eq!(lay.row_bytes(), 32);
        assert_eq!(lay.total_bytes(), 2 * 3 * 32);
        // All addresses unique and within [base, base+total).
        let mut seen = std::collections::HashSet::new();
        for p in 0..2 {
            for r in 0..3 {
                for ch in 0..4 {
                    let a = lay.addr(p, r, ch);
                    assert!(a >= 64 && a < 64 + lay.total_bytes());
                    assert!(seen.insert(a), "address reuse at {a}");
                }
            }
        }
        assert_eq!(seen.len(), 2 * 3 * 4);
    }

    #[test]
    fn store_load_chunk_roundtrip() {
        let mut rng = Rng::new(77);
        let m = IntMatrix::random(&mut rng, 4, 300, 3, false);
        let bs = BitSerialMatrix::from_int(&m, 3, false);
        let lay = OperandLayout::new(0, 4, 300, 3, 128);
        let mut img = DramImage::new(lay.total_bytes() as usize);
        lay.store(&mut img, &bs);
        // Every chunk word must equal the matching source word (zero-padded).
        for p in 0..3 {
            for r in 0..4 {
                for ch in 0..lay.chunks_per_row {
                    let words = lay.load_chunk(&img, p, r, ch);
                    for (w, &v) in words.iter().enumerate() {
                        let src = bs
                            .plane_row(p, r)
                            .get(ch * 2 + w)
                            .copied()
                            .unwrap_or(0);
                        assert_eq!(v, src, "p={p} r={r} ch={ch} w={w}");
                    }
                }
            }
        }
    }

    #[test]
    fn result_layout_roundtrip() {
        let lay = ResultLayout::new(128, 3, 5);
        let mut img = DramImage::new(1024);
        let m = IntMatrix::from_fn(3, 5, |r, c| r as i64 * 10 - c as i64);
        for r in 0..3 {
            for c in 0..5 {
                img.write_i32(lay.addr(r, c), m.get(r, c) as i32);
            }
        }
        assert_eq!(lay.load(&img), m);
        assert_eq!(lay.total_bytes(), 60);
    }

    #[test]
    #[should_panic(expected = "8-byte aligned")]
    fn operand_alignment_checked() {
        let _ = OperandLayout::new(4, 1, 64, 1, 64);
    }
}
