//! Matrix representations for bit-serial computation.
//!
//! * [`IntMatrix`] — plain row-major `i64` matrix, the user-facing type
//!   and the reference domain for correctness checks.
//! * [`BitSerialMatrix`] — a matrix decomposed into bit-planes: binary
//!   matrices `M[i]` such that `M = Σ_i sgn_i · 2^i · M[i]` (two's
//!   complement for signed operands, so `sgn_{bits-1} = -1`). This is the
//!   representation Algorithm 1 of the paper operates on, bit-packed into
//!   `u64` words along the `k` (columns) dimension.
//! * [`dram`] — the bit-packed main-memory layout fetched by the overlay
//!   (plane-major, row-major, `D_k`-bit chunks).

mod bitserial;
mod int;
pub mod dram;

pub use bitserial::BitSerialMatrix;
pub use int::IntMatrix;

/// Weight sign of bit-plane `i` of a `bits`-wide operand: two's
/// complement makes the MSB plane negative for signed operands
/// (Algorithm 1, lines 5–7).
#[inline]
pub fn plane_sign(i: u32, bits: u32, signed: bool) -> i64 {
    if signed && i == bits - 1 {
        -1
    } else {
        1
    }
}

/// Full weight of the (i, j) bit-plane pair: `sgnL·sgnR·2^{i+j}`.
#[inline]
pub fn pair_weight(i: u32, lbits: u32, lsigned: bool, j: u32, rbits: u32, rsigned: bool) -> i64 {
    plane_sign(i, lbits, lsigned) * plane_sign(j, rbits, rsigned) * (1i64 << (i + j))
}

/// Inclusive value range of a `bits`-wide (optionally signed) operand —
/// the single statement of the precision-bounds convention, shared by
/// every range check ([`IntMatrix::fits`],
/// [`crate::lowering::Tensor::fits`]) so they cannot drift from one
/// another. `bits` must be in `1..=32` (the packers' supported range).
#[inline]
pub fn value_bounds(bits: u32, signed: bool) -> (i64, i64) {
    debug_assert!(bits >= 1 && bits <= 32);
    if signed {
        (-(1i64 << (bits - 1)), (1i64 << (bits - 1)) - 1)
    } else {
        (0, (1i64 << bits) - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plane_sign_unsigned_always_positive() {
        for i in 0..8 {
            assert_eq!(plane_sign(i, 8, false), 1);
        }
    }

    #[test]
    fn plane_sign_signed_msb_negative() {
        assert_eq!(plane_sign(7, 8, true), -1);
        assert_eq!(plane_sign(6, 8, true), 1);
        assert_eq!(plane_sign(0, 8, true), 1);
        assert_eq!(plane_sign(0, 1, true), -1); // 1-bit signed = {-1? no: {0,-1}}
    }

    #[test]
    fn pair_weight_combines() {
        // Unsigned 2-bit × 2-bit: weights 1,2,2,4.
        assert_eq!(pair_weight(0, 2, false, 0, 2, false), 1);
        assert_eq!(pair_weight(1, 2, false, 0, 2, false), 2);
        assert_eq!(pair_weight(1, 2, false, 1, 2, false), 4);
        // Signed MSB on one side flips the sign.
        assert_eq!(pair_weight(1, 2, true, 0, 2, false), -2);
        // Both MSBs: positive again.
        assert_eq!(pair_weight(1, 2, true, 1, 2, true), 4);
    }
}
