//! Plain row-major integer matrix: the user-facing operand type and the
//! correctness-reference domain.

use crate::util::Rng;

/// Row-major `i64` matrix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct IntMatrix {
    pub rows: usize,
    pub cols: usize,
    data: Vec<i64>,
}

impl IntMatrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        IntMatrix {
            rows,
            cols,
            data: vec![0; rows * cols],
        }
    }

    /// Build from a function of (row, col).
    pub fn from_fn<F: FnMut(usize, usize) -> i64>(rows: usize, cols: usize, mut f: F) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        IntMatrix { rows, cols, data }
    }

    /// Build from a row-major slice.
    pub fn from_slice(rows: usize, cols: usize, v: &[i64]) -> Self {
        assert_eq!(v.len(), rows * cols);
        IntMatrix {
            rows,
            cols,
            data: v.to_vec(),
        }
    }

    /// Uniformly random matrix of `bits`-wide (optionally signed) entries.
    pub fn random(rng: &mut Rng, rows: usize, cols: usize, bits: u32, signed: bool) -> Self {
        Self::from_fn(rows, cols, |_, _| rng.operand(bits, signed))
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i64 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i64) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// Borrow one row.
    #[inline]
    pub fn row(&self, r: usize) -> &[i64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[i64] {
        &self.data
    }

    /// Reference matrix product `self · rhs` in i64 (the oracle for every
    /// other matmul path in the crate).
    pub fn matmul(&self, rhs: &IntMatrix) -> IntMatrix {
        assert_eq!(
            self.cols, rhs.rows,
            "matmul shape mismatch: {}×{} · {}×{}",
            self.rows, self.cols, rhs.rows, rhs.cols
        );
        let mut out = IntMatrix::zeros(self.rows, rhs.cols);
        for r in 0..self.rows {
            for d in 0..self.cols {
                let a = self.get(r, d);
                if a == 0 {
                    continue;
                }
                for c in 0..rhs.cols {
                    out.data[r * rhs.cols + c] += a * rhs.get(d, c);
                }
            }
        }
        out
    }

    /// Transposed copy (cache-blocked; this sits on the coordinator's
    /// request path for the RHS operand).
    pub fn transpose(&self) -> IntMatrix {
        const B: usize = 32;
        let mut out = IntMatrix::zeros(self.cols, self.rows);
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    let row = &self.data[r * self.cols..];
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = row[c];
                    }
                }
            }
        }
        out
    }

    /// Value range of the entries (min, max).
    pub fn value_range(&self) -> (i64, i64) {
        let mut lo = i64::MAX;
        let mut hi = i64::MIN;
        for &v in &self.data {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if self.data.is_empty() {
            (0, 0)
        } else {
            (lo, hi)
        }
    }

    /// Content hash over dimensions and row-major entries.
    ///
    /// This is the identity the coordinator's packing cache keys on: two
    /// matrices hash equal iff they have the same shape and entries
    /// (modulo the negligible 64-bit collision probability, which the
    /// cache accepts and documents). Not cryptographic.
    ///
    /// Sits on the serving layer's per-request lookup path, so it folds
    /// one splitmix64 avalanche per *word* (chained, so entry order
    /// matters) rather than hashing byte-wise — still a full pass over
    /// the operand, but several times cheaper than the repack it
    /// stands in for.
    pub fn content_hash(&self) -> u64 {
        #[inline]
        fn mix(h: u64, v: u64) -> u64 {
            crate::util::splitmix64(h.wrapping_add(v).wrapping_add(0x9e37_79b9_7f4a_7c15))
        }
        let mut h = mix(0xcbf2_9ce4_8422_2325, self.rows as u64);
        h = mix(h, self.cols as u64);
        for &v in &self.data {
            h = mix(h, v as u64);
        }
        h
    }

    /// Does every entry fit in `bits` (signed or unsigned)?
    pub fn fits(&self, bits: u32, signed: bool) -> bool {
        let (lo, hi) = super::value_bounds(bits, signed);
        self.data.iter().all(|&v| v >= lo && v <= hi)
    }
}

impl std::fmt::Display for IntMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for r in 0..self.rows {
            for c in 0..self.cols {
                write!(f, "{:>6}", self.get(r, c))?;
                if c + 1 < self.cols {
                    write!(f, " ")?;
                }
            }
            writeln!(f)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fig1_example() {
        // L = [[2,0],[1,3]], R = [[0,1],[1,2]] → P = [[0,2],[3,7]].
        let l = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
        let r = IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]);
        let p = l.matmul(&r);
        assert_eq!(p, IntMatrix::from_slice(2, 2, &[0, 2, 3, 7]));
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(5);
        let a = IntMatrix::random(&mut rng, 5, 7, 6, true);
        let id = IntMatrix::from_fn(7, 7, |r, c| (r == c) as i64);
        assert_eq!(a.matmul(&id), a);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(6);
        let a = IntMatrix::random(&mut rng, 4, 9, 8, true);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn fits_bounds() {
        let a = IntMatrix::from_slice(1, 2, &[0, 15]);
        assert!(a.fits(4, false));
        assert!(!a.fits(4, true));
        assert!(a.fits(5, true));
        let b = IntMatrix::from_slice(1, 2, &[-8, 7]);
        assert!(b.fits(4, true));
        assert!(!b.fits(4, false));
    }

    #[test]
    fn value_range() {
        let a = IntMatrix::from_slice(2, 2, &[-3, 0, 9, 1]);
        assert_eq!(a.value_range(), (-3, 9));
    }

    #[test]
    fn content_hash_distinguishes_shape_and_values() {
        let a = IntMatrix::from_slice(2, 3, &[1, 2, 3, 4, 5, 6]);
        // Equal content hashes equal.
        assert_eq!(a.content_hash(), a.clone().content_hash());
        // Same data, different shape: distinct.
        let b = IntMatrix::from_slice(3, 2, &[1, 2, 3, 4, 5, 6]);
        assert_ne!(a.content_hash(), b.content_hash());
        // One entry changed: distinct.
        let mut c = a.clone();
        c.set(1, 2, 7);
        assert_ne!(a.content_hash(), c.content_hash());
        // Sign matters (two's-complement mix must not collapse ±v).
        let d = IntMatrix::from_slice(1, 1, &[5]);
        let e = IntMatrix::from_slice(1, 1, &[-5]);
        assert_ne!(d.content_hash(), e.content_hash());
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn matmul_shape_checked() {
        let a = IntMatrix::zeros(2, 3);
        let b = IntMatrix::zeros(4, 2);
        let _ = a.matmul(&b);
    }
}
