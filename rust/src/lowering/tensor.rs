//! [`Tensor`]: the 4-D integer activation tensor convolution layers
//! consume and produce.

use crate::bitmatrix::IntMatrix;
use crate::util::Rng;

/// A dense `n × h × w × c` integer tensor in NHWC layout (channels
/// innermost). NHWC is chosen deliberately: one im2col patch element
/// run (all channels of one input pixel) is contiguous, and the
/// lowered GEMM result — rows indexed `(batch, y, x)`, columns indexed
/// by output channel — is *already* an NHWC tensor, so reshaping
/// between the GEMM domain and the tensor domain never copies
/// per-element.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    data: Vec<i64>,
}

impl Tensor {
    /// All-zero tensor.
    pub fn zeros(n: usize, h: usize, w: usize, c: usize) -> Tensor {
        Tensor {
            n,
            h,
            w,
            c,
            data: vec![0; n * h * w * c],
        }
    }

    /// Build from a function of `(batch, y, x, channel)`.
    pub fn from_fn<F: FnMut(usize, usize, usize, usize) -> i64>(
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        mut f: F,
    ) -> Tensor {
        let mut data = Vec::with_capacity(n * h * w * c);
        for ni in 0..n {
            for y in 0..h {
                for x in 0..w {
                    for ci in 0..c {
                        data.push(f(ni, y, x, ci));
                    }
                }
            }
        }
        Tensor { n, h, w, c, data }
    }

    /// Uniformly random tensor of `bits`-wide (optionally signed)
    /// entries.
    pub fn random(
        rng: &mut Rng,
        n: usize,
        h: usize,
        w: usize,
        c: usize,
        bits: u32,
        signed: bool,
    ) -> Tensor {
        Self::from_fn(n, h, w, c, |_, _, _, _| rng.operand(bits, signed))
    }

    /// Reinterpret an `n × (h·w·c)` matrix (one flattened NHWC image
    /// per row) as a tensor. The inverse of [`Tensor::flatten`].
    pub fn from_matrix(m: &IntMatrix, h: usize, w: usize, c: usize) -> Tensor {
        assert_eq!(m.cols, h * w * c, "matrix width != h·w·c");
        Tensor {
            n: m.rows,
            h,
            w,
            c,
            data: m.data().to_vec(),
        }
    }

    /// Reinterpret a lowered-GEMM result — rows indexed
    /// `(batch, y, x)`, columns indexed by output channel — as an NHWC
    /// tensor. Pure reshape: the row-major `(n·h·w) × c` matrix and
    /// the NHWC tensor share one memory order.
    pub fn from_gemm_rows(m: &IntMatrix, n: usize, h: usize, w: usize) -> Tensor {
        assert_eq!(m.rows, n * h * w, "matrix rows != n·h·w");
        Tensor {
            n,
            h,
            w,
            c: m.cols,
            data: m.data().to_vec(),
        }
    }

    #[inline]
    fn idx(&self, n: usize, y: usize, x: usize, c: usize) -> usize {
        debug_assert!(n < self.n && y < self.h && x < self.w && c < self.c);
        ((n * self.h + y) * self.w + x) * self.c + c
    }

    #[inline]
    pub fn get(&self, n: usize, y: usize, x: usize, c: usize) -> i64 {
        self.data[self.idx(n, y, x, c)]
    }

    #[inline]
    pub fn set(&mut self, n: usize, y: usize, x: usize, c: usize, v: i64) {
        let i = self.idx(n, y, x, c);
        self.data[i] = v;
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// Is the tensor empty (any zero-sized axis)?
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw NHWC data.
    pub fn data(&self) -> &[i64] {
        &self.data
    }

    /// Elementwise map (requantization, thresholding).
    pub fn map<F: FnMut(i64) -> i64>(&self, mut f: F) -> Tensor {
        Tensor {
            n: self.n,
            h: self.h,
            w: self.w,
            c: self.c,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Flatten to an `n × (h·w·c)` matrix, one NHWC image per row —
    /// the dense-layer input shape. Pure reshape: NHWC rows are
    /// already contiguous.
    pub fn flatten(&self) -> IntMatrix {
        IntMatrix::from_slice(self.n, self.h * self.w * self.c, &self.data)
    }

    /// Does every entry fit in `bits` (signed or unsigned)? Same
    /// bounds convention as [`IntMatrix::fits`], by construction.
    pub fn fits(&self, bits: u32, signed: bool) -> bool {
        let (lo, hi) = crate::bitmatrix::value_bounds(bits, signed);
        self.data.iter().all(|&v| v >= lo && v <= hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nhwc_layout_round_trips_through_flatten() {
        let t = Tensor::from_fn(2, 3, 4, 5, |n, y, x, c| (n * 1000 + y * 100 + x * 10 + c) as i64);
        assert_eq!(t.get(1, 2, 3, 4), 1234);
        let m = t.flatten();
        assert_eq!(m.rows, 2);
        assert_eq!(m.cols, 60);
        assert_eq!(Tensor::from_matrix(&m, 3, 4, 5), t);
    }

    #[test]
    fn fits_and_map() {
        let t = Tensor::from_fn(1, 2, 2, 1, |_, y, x, _| (y * 2 + x) as i64);
        assert!(t.fits(2, false));
        assert!(!t.fits(1, false));
        let doubled = t.map(|v| v * 2);
        assert_eq!(doubled.get(0, 1, 1, 0), 6);
    }
}
